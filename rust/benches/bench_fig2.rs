//! Bench harness for Fig. 2: accuracy + runtime vs R (mnist-like),
//! SC_RB vs RF-family, exact-SC reference. Bench-scale sweep; use
//! `examples/repro_fig2 --full` for paper-size runs.

use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let scale: usize = std::env::var("SCRB_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let cfg = PipelineConfig::builder().kmeans_replicates(3).build();
    let coord = Coordinator::new(cfg, scale);

    let rs = [16usize, 64, 256, 1024];
    let fig = experiment::fig2(&coord, &rs, 1024).expect("fig2 driver failed");
    println!("{}", report::render_fig2(&fig));

    let mut b = Bencher::from_env();
    for s in &fig.series {
        for p in &s.points {
            b.record_once(
                &format!("fig2/{}/R={}", s.label, p.x as usize),
                Duration::from_secs_f64(p.secs),
            );
        }
    }
    println!("{}", b.report());

    // acceptance shape: SC_RB at max R should be at/above SC_RF at max R
    let acc_at_max = |label: &str| {
        fig.series
            .iter()
            .find(|s| s.label == label)
            .and_then(|s| s.points.last())
            .map(|p| p.acc)
            .unwrap_or(f64::NAN)
    };
    let rb = acc_at_max("SC_RB");
    let rf = acc_at_max("SC_RF");
    println!("shape check: SC_RB({rb:.3}) vs SC_RF({rf:.3}) at their largest R — paper expects RB ≥ RF at same R");
}
