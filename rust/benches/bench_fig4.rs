//! Bench harness for Fig. 4: SC_RB linear scalability in N with the
//! per-stage breakdown (RB / SVD / K-means / total).

use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let cfg = PipelineConfig::builder().kmeans_replicates(3).build();
    let coord = Coordinator::new(cfg, 1);

    let ns: Vec<usize> = std::env::var("SCRB_BENCH_NS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.parse().ok()).collect())
        .unwrap_or_else(|| vec![1_000, 4_000, 16_000, 64_000]);
    let r = 256;

    let mut b = Bencher::from_env();
    for dataset in ["poker", "susy"] {
        let points = experiment::fig4(&coord, dataset, &ns, r).expect("fig4 driver failed");
        println!("{}", report::render_fig4(dataset, &points));
        for p in &points {
            b.record_once(
                &format!("fig4/{dataset}/N={}", p.n),
                Duration::from_secs_f64(p.total_secs),
            );
        }
    }
    println!("{}", b.report());
}
