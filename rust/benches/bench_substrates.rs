//! Substrate micro-benchmarks (the profile targets of the §Perf pass):
//! RB generation throughput, sparse matvec/matmat on both substrates
//! (Csr vs EllRb side-by-side, the eigensolver hot path), the fused
//! strip-tiled gram operator S·B vs its two-pass reference, dense gemm,
//! K-means assignment (native vs XLA ablation), kernel blocks (native vs
//! XLA).
//!
//!     cargo bench --bench bench_substrates
//!     SCRB_BENCH_BUDGET_MS=200 cargo bench   # quick mode
//!     SCRB_BENCH_SMOKE=1 cargo bench         # tiny-N CI smoke mode
//!
//! Results are also written machine-readably to `BENCH_substrates.json`
//! (override with SCRB_BENCH_JSON) — the cross-PR perf trajectory. The
//! gram section also records allocation counts per call (the binary runs
//! under the counting allocator) and the scratch/intermediate memory
//! accounting of the fused vs two-pass paths.

use scrb::config::Kernel;
use scrb::data::synth;
use scrb::kernels::kernel_block;
use scrb::kmeans::{AssignEngine, NativeAssign};
use scrb::linalg::Mat;
use scrb::rb::rb_features;
use scrb::rf::RfMap;
use scrb::runtime::{ArtifactKind, XlaRuntime};
use scrb::sparse::{implicit_degrees, GramScratch};
use scrb::util::alloc_count::{allocations, CountingAlloc};
use scrb::util::bench::Bencher;
use scrb::util::rng::Pcg;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let mut b = Bencher::from_env();
    // CI smoke mode: shrink the dataset so every kernel (including the
    // fused gram path) is exercised on each push within seconds.
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let scale = if smoke { 16 } else { 1 };
    println!(
        "== substrate micro-benchmarks (threads={}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    // ---- RB generation (the O(NRd) stage)
    let ds = synth::paper_benchmark("pendigits", scale, 42); // n=10992/scale, d=16
    let x = &ds.x;
    let n_pts = x.rows;
    for r in [64usize, 256] {
        let stats = b.bench(&format!("rb_features n={n_pts} d=16 R={r}"), || {
            rb_features(x, r, 0.25, 7)
        });
        let pts_per_s = (x.rows * r) as f64 / stats.median.as_secs_f64();
        println!("    -> {:.2e} point-grids/s", pts_per_s);
    }

    // ---- sparse substrates side-by-side on a realistic Z (the pendigits-
    // scale hot path: N≈11k, R=256): EllRb (fixed-stride, strip-parallel
    // transpose) vs the general Csr it bridges to.
    let rb = rb_features(x, 256, 0.25, 7);
    let ell = &rb.z;
    let csr = ell.to_csr();
    let (n, d, nnz) = (ell.rows, ell.cols, ell.nnz());
    println!(
        "    Z: {}x{} nnz={}  footprint: Csr {:.1} MB vs EllRb {:.1} MB",
        n,
        d,
        nnz,
        csr.bytes() as f64 / (1 << 20) as f64,
        ell.bytes() as f64 / (1 << 20) as f64,
    );
    let dense_v: Vec<f64> = (0..d).map(|i| (i % 13) as f64).collect();
    b.bench("csr_matvec (N x D)", || csr.matvec(&dense_v));
    b.bench("ell_matvec (N x D)", || ell.matvec(&dense_v));
    let dense_u: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
    b.bench("csr_t_matvec (D x N)", || csr.t_matvec(&dense_u));
    b.bench("ell_t_matvec (D x N)", || ell.t_matvec(&dense_u));
    for k in [8usize, 32] {
        let block = Mat::from_vec(d, k, (0..d * k).map(|i| (i % 5) as f64).collect());
        b.bench(&format!("csr_matmat k={k}"), || csr.matmat(&block));
        b.bench(&format!("ell_matmat k={k}"), || ell.matmat(&block));
        let blockn = Mat::from_vec(n, k, (0..n * k).map(|i| (i % 5) as f64).collect());
        b.bench(&format!("csr_t_matmat k={k}"), || csr.t_matmat(&blockn));
        b.bench(&format!("ell_t_matmat k={k}"), || ell.t_matmat(&blockn));
        // substrate traffic per t_matmat call: what each layout must stream
        // (indices + values + B read + C write), plus the per-thread D×k
        // accumulators the Csr path allocates, zeroes, and reduces.
        let nt = scrb::util::threads::num_threads();
        let csr_stream = 4 * nnz + 8 * nnz + 8 * (n + 1) + 8 * n * k + 8 * d * k;
        let csr_scratch = 8 * d * k * nt * 2; // zero-fill + reduction traffic
        let ell_stream = 4 * nnz + 8 * n + 8 * n * k + 8 * d * k;
        println!(
            "    t_matmat k={k} bytes/iter: Csr {:.1} MB (+{:.1} MB thread scratch) vs EllRb {:.1} MB",
            csr_stream as f64 / (1 << 20) as f64,
            csr_scratch as f64 / (1 << 20) as f64,
            ell_stream as f64 / (1 << 20) as f64,
        );
    }
    b.bench("implicit_degrees csr", || implicit_degrees(&csr));
    b.bench("implicit_degrees ell", || ell.implicit_degrees());

    // ---- fused gram operator S·B = Ẑ·(ẐᵀB) vs the two-pass reference —
    // the per-iteration product of the Davidson/Lanczos hot loop (one call
    // here = the solver's S-apply for one iteration), on the degree-
    // normalized Ẑ the solvers actually see.
    let mut zhat = ell.clone();
    let zdeg = zhat.implicit_degrees();
    zhat.normalize_by_degree(&zdeg);
    let gk = 8usize;
    let bn8 = Mat::from_vec(n, gk, (0..n * gk).map(|i| (i % 5) as f64 - 2.0).collect());
    let two_pass_med = b
        .bench(&format!("gram two-pass S·B k={gk} (apply∘apply_t)"), || {
            zhat.matmat(&zhat.t_matmat(&bn8))
        })
        .median;
    let mut gs = GramScratch::new();
    let mut gout = Mat::zeros(0, 0);
    zhat.gram_matmat_into(&bn8, &mut gout, &mut gs); // warm the scratch
    let fused_med = b
        .bench(&format!("gram fused    S·B k={gk} (strip-tiled)"), || {
            zhat.gram_matmat_into(&bn8, &mut gout, &mut gs)
        })
        .median;
    // correctness spot-check so the bench can't silently drift
    {
        let reference = zhat.matmat(&zhat.t_matmat(&bn8));
        let err = gout.sub(&reference).frob_norm() / (1.0 + reference.frob_norm());
        assert!(err < 1e-12, "fused gram drifted from two-pass: {err}");
    }
    // allocation accounting (this binary runs under the counting allocator)
    let reps = 5usize;
    let a0 = allocations();
    for _ in 0..reps {
        std::hint::black_box(zhat.matmat(&zhat.t_matmat(&bn8)));
    }
    let two_pass_allocs = (allocations() - a0) / reps;
    let a1 = allocations();
    for _ in 0..reps {
        zhat.gram_matmat_into(&bn8, &mut gout, &mut gs);
    }
    let fused_allocs = (allocations() - a1) / reps;
    // memory accounting: the D×k intermediate the two-pass path
    // materializes (plus its zero-fill) vs the fused kernel's cache-sized
    // tiles — the per-thread peak scratch bound of the acceptance bar.
    let intermediate_bytes = 8 * d * gk;
    let speedup = two_pass_med.as_secs_f64() / fused_med.as_secs_f64().max(1e-12);
    println!(
        "    gram S·B k={gk}: two-pass {:.3} ms vs fused {:.3} ms  ({speedup:.2}x)",
        two_pass_med.as_secs_f64() * 1e3,
        fused_med.as_secs_f64() * 1e3,
    );
    println!(
        "    intermediate: two-pass D×k = {:.2} MB materialized/iter vs fused scratch {:.1} KB total ({:.1} KB tile/thread); allocs/call {two_pass_allocs} vs {fused_allocs}",
        intermediate_bytes as f64 / (1 << 20) as f64,
        gs.scratch_bytes() as f64 / 1024.0,
        gs.tile_bytes() as f64 / 1024.0,
    );
    b.metric("gram_k", gk as f64);
    b.metric("gram_twopass_intermediate_bytes", intermediate_bytes as f64);
    b.metric("gram_fused_scratch_bytes", gs.scratch_bytes() as f64);
    b.metric("gram_fused_tile_bytes_per_thread", gs.tile_bytes() as f64);
    b.metric("gram_fused_speedup", speedup);
    b.metric("gram_twopass_allocs_per_call", two_pass_allocs as f64);
    b.metric("gram_fused_allocs_per_call", fused_allocs as f64);

    // ---- dense gemm (Rayleigh–Ritz shapes)
    let mut rng = Pcg::seed(3);
    let a = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    let c = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    b.bench("dense t_matmul 24x10000 * 10000x24", || a.t_matmul(&c));

    // ---- K-means assignment: native vs XLA (ablation)
    let km_x = synth::gaussian_blobs(8_192, 16, 10, 6.0, 5);
    let centroids = km_x.x.row_block(0, 10);
    b.bench("kmeans_assign native n=8192 d=16 k=10", || {
        NativeAssign.assign(&km_x.x, &centroids)
    });
    let xla = XlaRuntime::load("artifacts").ok();
    if let Some(rt) = &xla {
        b.bench("kmeans_assign XLA    n=8192 d=16 k=10", || {
            rt.kmeans_assign(&km_x.x, &centroids).unwrap()
        });
    } else {
        println!("    [XLA ablations skipped: run `make artifacts`]");
    }

    // ---- kernel block: native vs XLA
    let kb_x = km_x.x.row_block(0, 1024);
    let kb_y = km_x.x.row_block(1024, 2048);
    b.bench("kernel_block native 1024x1024 lap", || {
        kernel_block(Kernel::Laplacian { sigma: 0.5 }, &kb_x, &kb_y)
    });
    if let Some(rt) = &xla {
        b.bench("kernel_block XLA    1024x1024 lap", || {
            rt.kernel_block(ArtifactKind::KernelBlockLaplacian, &kb_x, &kb_y, 2.0).unwrap()
        });
    }

    // ---- RF features: native vs XLA
    let map = RfMap::sample(Kernel::Laplacian { sigma: 0.5 }, 16, 512, 3);
    b.bench("rf_features native n=8192 R=512", || map.features(&km_x.x));
    if let Some(rt) = &xla {
        b.bench("rf_features XLA    n=8192 R=512", || {
            rt.rf_features(&km_x.x, &map.w, &map.b).unwrap()
        });
    }

    println!("\n{}", b.report());

    // machine-readable trajectory (BENCH_*.json, one file per bench target)
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_substrates.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("[bench json not written: {e}]"),
    }
}
