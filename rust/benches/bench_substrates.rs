//! Substrate micro-benchmarks (the profile targets of the §Perf pass):
//! RB generation throughput, sparse matvec/matmat, dense gemm, K-means
//! assignment (native vs XLA ablation), kernel blocks (native vs XLA).
//!
//!     cargo bench --bench bench_substrates
//!     SCRB_BENCH_BUDGET_MS=200 cargo bench   # quick mode

use scrb::config::Kernel;
use scrb::data::synth;
use scrb::kernels::kernel_block;
use scrb::kmeans::{AssignEngine, NativeAssign};
use scrb::linalg::Mat;
use scrb::rb::rb_features;
use scrb::rf::RfMap;
use scrb::runtime::{ArtifactKind, XlaRuntime};
use scrb::sparse::implicit_degrees;
use scrb::util::bench::Bencher;
use scrb::util::rng::Pcg;

fn main() {
    let mut b = Bencher::from_env();
    println!("== substrate micro-benchmarks (threads={}) ==", scrb::util::threads::num_threads());

    // ---- RB generation (the O(NRd) stage)
    let ds = synth::paper_benchmark("pendigits", 1, 42); // n=10992, d=16
    let x = &ds.x;
    for r in [64usize, 256] {
        let stats = b.bench(&format!("rb_features n=10992 d=16 R={r}"), || {
            rb_features(x, r, 0.25, 7)
        });
        let pts_per_s = (x.rows * r) as f64 / stats.median.as_secs_f64();
        println!("    -> {:.2e} point-grids/s", pts_per_s);
    }

    // ---- sparse ops on a realistic Z
    let rb = rb_features(x, 256, 0.25, 7);
    let z = &rb.z;
    println!(
        "    Z: {}x{} nnz={} ({} MB)",
        z.rows,
        z.cols,
        z.nnz(),
        z.bytes() / (1 << 20)
    );
    let dense_v: Vec<f64> = (0..z.cols).map(|i| (i % 13) as f64).collect();
    b.bench("csr_matvec (N x D)", || z.matvec(&dense_v));
    let dense_u: Vec<f64> = (0..z.rows).map(|i| (i % 7) as f64).collect();
    b.bench("csr_t_matvec (D x N)", || z.t_matvec(&dense_u));
    let block = Mat::from_vec(z.cols, 10, (0..z.cols * 10).map(|i| (i % 5) as f64).collect());
    b.bench("csr_matmat k=10", || z.matmat(&block));
    let blockn = Mat::from_vec(z.rows, 10, (0..z.rows * 10).map(|i| (i % 5) as f64).collect());
    b.bench("csr_t_matmat k=10", || z.t_matmat(&blockn));
    b.bench("implicit_degrees", || implicit_degrees(z));

    // ---- dense gemm (Rayleigh–Ritz shapes)
    let mut rng = Pcg::seed(3);
    let a = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    let c = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    b.bench("dense t_matmul 24x10000 * 10000x24", || a.t_matmul(&c));

    // ---- K-means assignment: native vs XLA (ablation)
    let km_x = synth::gaussian_blobs(8_192, 16, 10, 6.0, 5);
    let centroids = km_x.x.row_block(0, 10);
    b.bench("kmeans_assign native n=8192 d=16 k=10", || {
        NativeAssign.assign(&km_x.x, &centroids)
    });
    let xla = XlaRuntime::load("artifacts").ok();
    if let Some(rt) = &xla {
        b.bench("kmeans_assign XLA    n=8192 d=16 k=10", || {
            rt.kmeans_assign(&km_x.x, &centroids).unwrap()
        });
    } else {
        println!("    [XLA ablations skipped: run `make artifacts`]");
    }

    // ---- kernel block: native vs XLA
    let kb_x = km_x.x.row_block(0, 1024);
    let kb_y = km_x.x.row_block(1024, 2048);
    b.bench("kernel_block native 1024x1024 lap", || {
        kernel_block(Kernel::Laplacian { sigma: 0.5 }, &kb_x, &kb_y)
    });
    if let Some(rt) = &xla {
        b.bench("kernel_block XLA    1024x1024 lap", || {
            rt.kernel_block(ArtifactKind::KernelBlockLaplacian, &kb_x, &kb_y, 2.0).unwrap()
        });
    }

    // ---- RF features: native vs XLA
    let map = RfMap::sample(Kernel::Laplacian { sigma: 0.5 }, 16, 512, 3);
    b.bench("rf_features native n=8192 R=512", || map.features(&km_x.x));
    if let Some(rt) = &xla {
        b.bench("rf_features XLA    n=8192 R=512", || {
            rt.rf_features(&km_x.x, &map.w, &map.b).unwrap()
        });
    }

    println!("\n{}", b.report());
}
