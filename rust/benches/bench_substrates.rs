//! Substrate micro-benchmarks (the profile targets of the §Perf pass):
//! RB generation throughput, sparse matvec/matmat on both substrates
//! (Csr vs EllRb side-by-side, the eigensolver hot path), dense gemm,
//! K-means assignment (native vs XLA ablation), kernel blocks (native vs
//! XLA).
//!
//!     cargo bench --bench bench_substrates
//!     SCRB_BENCH_BUDGET_MS=200 cargo bench   # quick mode
//!
//! Results are also written machine-readably to `BENCH_substrates.json`
//! (override with SCRB_BENCH_JSON) — the cross-PR perf trajectory.

use scrb::config::Kernel;
use scrb::data::synth;
use scrb::kernels::kernel_block;
use scrb::kmeans::{AssignEngine, NativeAssign};
use scrb::linalg::Mat;
use scrb::rb::rb_features;
use scrb::rf::RfMap;
use scrb::runtime::{ArtifactKind, XlaRuntime};
use scrb::sparse::implicit_degrees;
use scrb::util::bench::Bencher;
use scrb::util::rng::Pcg;

fn main() {
    let mut b = Bencher::from_env();
    println!("== substrate micro-benchmarks (threads={}) ==", scrb::util::threads::num_threads());

    // ---- RB generation (the O(NRd) stage)
    let ds = synth::paper_benchmark("pendigits", 1, 42); // n=10992, d=16
    let x = &ds.x;
    for r in [64usize, 256] {
        let stats = b.bench(&format!("rb_features n=10992 d=16 R={r}"), || {
            rb_features(x, r, 0.25, 7)
        });
        let pts_per_s = (x.rows * r) as f64 / stats.median.as_secs_f64();
        println!("    -> {:.2e} point-grids/s", pts_per_s);
    }

    // ---- sparse substrates side-by-side on a realistic Z (the pendigits-
    // scale hot path: N≈11k, R=256): EllRb (fixed-stride, strip-parallel
    // transpose) vs the general Csr it bridges to.
    let rb = rb_features(x, 256, 0.25, 7);
    let ell = &rb.z;
    let csr = ell.to_csr();
    let (n, d, nnz) = (ell.rows, ell.cols, ell.nnz());
    println!(
        "    Z: {}x{} nnz={}  footprint: Csr {:.1} MB vs EllRb {:.1} MB",
        n,
        d,
        nnz,
        csr.bytes() as f64 / (1 << 20) as f64,
        ell.bytes() as f64 / (1 << 20) as f64,
    );
    let dense_v: Vec<f64> = (0..d).map(|i| (i % 13) as f64).collect();
    b.bench("csr_matvec (N x D)", || csr.matvec(&dense_v));
    b.bench("ell_matvec (N x D)", || ell.matvec(&dense_v));
    let dense_u: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
    b.bench("csr_t_matvec (D x N)", || csr.t_matvec(&dense_u));
    b.bench("ell_t_matvec (D x N)", || ell.t_matvec(&dense_u));
    for k in [8usize, 32] {
        let block = Mat::from_vec(d, k, (0..d * k).map(|i| (i % 5) as f64).collect());
        b.bench(&format!("csr_matmat k={k}"), || csr.matmat(&block));
        b.bench(&format!("ell_matmat k={k}"), || ell.matmat(&block));
        let blockn = Mat::from_vec(n, k, (0..n * k).map(|i| (i % 5) as f64).collect());
        b.bench(&format!("csr_t_matmat k={k}"), || csr.t_matmat(&blockn));
        b.bench(&format!("ell_t_matmat k={k}"), || ell.t_matmat(&blockn));
        // substrate traffic per t_matmat call: what each layout must stream
        // (indices + values + B read + C write), plus the per-thread D×k
        // accumulators the Csr path allocates, zeroes, and reduces.
        let nt = scrb::util::threads::num_threads();
        let csr_stream = 4 * nnz + 8 * nnz + 8 * (n + 1) + 8 * n * k + 8 * d * k;
        let csr_scratch = 8 * d * k * nt * 2; // zero-fill + reduction traffic
        let ell_stream = 4 * nnz + 8 * n + 8 * n * k + 8 * d * k;
        println!(
            "    t_matmat k={k} bytes/iter: Csr {:.1} MB (+{:.1} MB thread scratch) vs EllRb {:.1} MB",
            csr_stream as f64 / (1 << 20) as f64,
            csr_scratch as f64 / (1 << 20) as f64,
            ell_stream as f64 / (1 << 20) as f64,
        );
    }
    b.bench("implicit_degrees csr", || implicit_degrees(&csr));
    b.bench("implicit_degrees ell", || ell.implicit_degrees());

    // ---- dense gemm (Rayleigh–Ritz shapes)
    let mut rng = Pcg::seed(3);
    let a = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    let c = Mat::from_vec(10_000, 24, (0..240_000).map(|_| rng.f64()).collect());
    b.bench("dense t_matmul 24x10000 * 10000x24", || a.t_matmul(&c));

    // ---- K-means assignment: native vs XLA (ablation)
    let km_x = synth::gaussian_blobs(8_192, 16, 10, 6.0, 5);
    let centroids = km_x.x.row_block(0, 10);
    b.bench("kmeans_assign native n=8192 d=16 k=10", || {
        NativeAssign.assign(&km_x.x, &centroids)
    });
    let xla = XlaRuntime::load("artifacts").ok();
    if let Some(rt) = &xla {
        b.bench("kmeans_assign XLA    n=8192 d=16 k=10", || {
            rt.kmeans_assign(&km_x.x, &centroids).unwrap()
        });
    } else {
        println!("    [XLA ablations skipped: run `make artifacts`]");
    }

    // ---- kernel block: native vs XLA
    let kb_x = km_x.x.row_block(0, 1024);
    let kb_y = km_x.x.row_block(1024, 2048);
    b.bench("kernel_block native 1024x1024 lap", || {
        kernel_block(Kernel::Laplacian { sigma: 0.5 }, &kb_x, &kb_y)
    });
    if let Some(rt) = &xla {
        b.bench("kernel_block XLA    1024x1024 lap", || {
            rt.kernel_block(ArtifactKind::KernelBlockLaplacian, &kb_x, &kb_y, 2.0).unwrap()
        });
    }

    // ---- RF features: native vs XLA
    let map = RfMap::sample(Kernel::Laplacian { sigma: 0.5 }, 16, 512, 3);
    b.bench("rf_features native n=8192 R=512", || map.features(&km_x.x));
    if let Some(rt) = &xla {
        b.bench("rf_features XLA    n=8192 R=512", || {
            rt.rf_features(&km_x.x, &map.w, &map.b).unwrap()
        });
    }

    println!("\n{}", b.report());

    // machine-readable trajectory (BENCH_*.json, one file per bench target)
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_substrates.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("[bench json not written: {e}]"),
    }
}
