//! Serving-throughput benchmark: fit SC_RB once on the pendigits-scale
//! benchmark (N=10992, R=256, k=10), then measure the `predict_batch`
//! hot path — points/sec, single-point latency, and steady-state
//! allocations per call (the binary runs under the counting allocator).
//!
//!     cargo bench --bench bench_serving
//!     SCRB_BENCH_BUDGET_MS=200 cargo bench --bench bench_serving  # quick
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_serving        # CI smoke
//!
//! Results land in `BENCH_serving.json` (override with SCRB_BENCH_JSON):
//! `metrics.serving_points_per_sec` is the acceptance number (target
//! ≥ 1e6 points/sec at R=256, k=10 on a full-size run), and
//! `metrics.predict_batch_allocs_per_call` pins the zero-allocation
//! steady state that `tests/alloc.rs` enforces single-threaded.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::model::{FittedModel, ServeWorkspace};
use scrb::util::alloc_count::{allocations, CountingAlloc};
use scrb::util::bench::Bencher;
use std::time::Instant;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let scale = if smoke { 16 } else { 1 };

    // pendigits-scale workload: n = 10992/scale, d = 16, k = 10
    let ds = synth::paper_benchmark("pendigits", scale, 42);
    let n = ds.n();
    println!(
        "== serving bench (threads={}, n={n}, R=256, k=10{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    let cfg = PipelineConfig::builder()
        .k(10)
        .r(256)
        .kernel(Kernel::Laplacian { sigma: 0.25 })
        .engine(Engine::Native)
        .kmeans_replicates(3)
        .seed(42)
        .build();

    // fit once (recorded, not iterated — it is the amortized cost)
    let t0 = Instant::now();
    let fitted = MethodKind::ScRb.fit(&Env::new(cfg), &ds.x).expect("SC_RB fit failed");
    let fit_time = t0.elapsed();
    b.record_once(&format!("fit n={n} R=256 k=10"), fit_time);
    println!("    fit: {:?} (amortized once per model)", fit_time);

    let model = fitted.model;
    let mut ws = ServeWorkspace::new();
    let mut labels: Vec<usize> = Vec::new();

    // warm the workspace + sanity-check the serving contract
    model.predict_batch(&ds.x, &mut ws, &mut labels).expect("predict_batch failed");
    let agree = labels.iter().zip(fitted.output.labels.iter()).filter(|(a, b)| a == b).count();
    println!("    train-set agreement: {agree}/{n}");

    // steady-state allocation accounting (threaded runs add only
    // O(threads) fork/join bookkeeping; single-threaded this is 0)
    let a0 = allocations();
    model.predict_batch(&ds.x, &mut ws, &mut labels).unwrap();
    let allocs_per_call = allocations() - a0;

    // the serving hot path: full-batch predict, points/sec
    let median = b
        .bench(&format!("predict_batch n={n} R=256 k=10"), || {
            model.predict_batch(&ds.x, &mut ws, &mut labels).unwrap();
        })
        .median;
    let pts_per_sec = n as f64 / median.as_secs_f64().max(1e-12);
    println!("    -> {pts_per_sec:.3e} points/s");

    // single-point latency (the interactive-request shape)
    let one = ds.x.row_block(0, 1);
    let mut ws_one = ServeWorkspace::new();
    let mut label_one: Vec<usize> = Vec::new();
    model.predict_batch(&one, &mut ws_one, &mut label_one).unwrap();
    let median_one = b
        .bench("predict single point", || {
            model.predict_batch(&one, &mut ws_one, &mut label_one).unwrap();
        })
        .median;
    println!("    -> {:.2} µs/point single", median_one.as_nanos() as f64 / 1e3);

    b.metric("serving_n", n as f64);
    b.metric("serving_points_per_sec", pts_per_sec);
    b.metric("predict_point_us", median_one.as_nanos() as f64 / 1e3);
    b.metric("predict_batch_allocs_per_call", allocs_per_call as f64);
    b.metric("train_agreement", agree as f64 / n as f64);
    b.metric("fit_secs", fit_time.as_secs_f64());

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("[saved {json_path}]"),
        Err(e) => eprintln!("[failed to save {json_path}: {e}]"),
    }
}
