//! Pipeline artifact-cache benchmark (ISSUE 5 acceptance): cold vs
//! artifact-cached wall time for a 5-point σ-sweep and a 5-point k-sweep
//! of SC_RB at pendigits scale (N=10992, R=256).
//!
//!     cargo bench --bench bench_pipeline
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_pipeline   # CI smoke
//!
//! Results land in `BENCH_pipeline.json` (override with SCRB_BENCH_JSON):
//! `metrics.k_sweep_speedup` is the acceptance number — a cached k-sweep
//! (embedding width pinned via `embed_dim`, so featurization *and* the
//! SVD embedding are computed once and reused) must be ≥ 3× faster than
//! the cold per-point sweep at full size. The σ-sweep is the honest
//! contrast: σ re-fingerprints the featurization, so only the normalized
//! input frame is reused and the speedup is necessarily marginal.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::pipeline::{ArtifactCache, MinMaxNormalize};
use scrb::util::bench::Bencher;
use std::time::{Duration, Instant};

/// One SC_RB fit through the pipeline (min-max normalize stage attached,
/// matching the file-based CLI flow), against the given cache.
fn fit_point(cfg: &PipelineConfig, x: &scrb::linalg::Mat, cache: &mut ArtifactCache) {
    let env = Env::new(cfg.clone());
    MethodKind::ScRb
        .pipeline(cfg)
        .with_normalize(Box::new(MinMaxNormalize))
        .fit_cached(&env, x, cache)
        .expect("pipeline fit failed");
}

fn sweep(cfgs: &[PipelineConfig], x: &scrb::linalg::Mat, cached: bool) -> (Duration, usize) {
    let mut cache = if cached { ArtifactCache::new() } else { ArtifactCache::disabled() };
    let t0 = Instant::now();
    for cfg in cfgs {
        fit_point(cfg, x, &mut cache);
    }
    (t0.elapsed(), cache.hits)
}

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (scale, r) = if smoke { (16, 64) } else { (1, 256) };

    // pendigits-scale workload: n = 10992/scale, d = 16, 10 classes
    let ds = synth::paper_benchmark("pendigits", scale, 42);
    let n = ds.n();
    println!(
        "== pipeline cache bench (threads={}, n={n}, R={r}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    // replicates kept low so the reusable stages (featurize + embed)
    // dominate a grid point, as they do at production scale
    let base = PipelineConfig::builder()
        .k(10)
        .r(r)
        .kernel(Kernel::Laplacian { sigma: 0.25 })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .seed(42)
        .build();

    // ---- 5-point σ-sweep: featurize/embed/cluster all re-run; the
    // cached run reuses the normalized input frame
    let sigmas = [0.15f64, 0.2, 0.25, 0.3, 0.35];
    let sigma_cfgs: Vec<PipelineConfig> = sigmas
        .iter()
        .map(|&s| base.rebuild(|bb| bb.sigma(s)).expect("valid sigma point"))
        .collect();
    let (sigma_cold, _) = sweep(&sigma_cfgs, &ds.x, false);
    b.record_once("sigma-sweep 5pt cold", sigma_cold);
    let (sigma_cached, sigma_hits) = sweep(&sigma_cfgs, &ds.x, true);
    b.record_once("sigma-sweep 5pt cached", sigma_cached);
    let sigma_speedup = sigma_cold.as_secs_f64() / sigma_cached.as_secs_f64().max(1e-12);
    println!(
        "    sigma-sweep: cold {:.3}s vs cached {:.3}s ({sigma_speedup:.2}x, {sigma_hits} hits)",
        sigma_cold.as_secs_f64(),
        sigma_cached.as_secs_f64()
    );

    // ---- 5-point k-sweep with the embedding width pinned to the max k:
    // featurization AND the SVD embedding are computed once; only the
    // K-means stage runs per point
    let ks = [4usize, 6, 8, 10, 12];
    let k_cfgs: Vec<PipelineConfig> = ks
        .iter()
        .map(|&k| base.rebuild(|bb| bb.embed_dim(12).k(k)).expect("valid k point"))
        .collect();
    let (k_cold, _) = sweep(&k_cfgs, &ds.x, false);
    b.record_once("k-sweep 5pt cold", k_cold);
    let (k_cached, k_hits) = sweep(&k_cfgs, &ds.x, true);
    b.record_once("k-sweep 5pt cached", k_cached);
    let k_speedup = k_cold.as_secs_f64() / k_cached.as_secs_f64().max(1e-12);
    println!(
        "    k-sweep:     cold {:.3}s vs cached {:.3}s ({k_speedup:.2}x, {k_hits} hits)",
        k_cold.as_secs_f64(),
        k_cached.as_secs_f64()
    );
    if !smoke && k_speedup < 3.0 {
        println!("    !! below the 3x acceptance bar for the cached k-sweep");
    }

    b.metric("pipeline_n", n as f64);
    b.metric("pipeline_r", r as f64);
    b.metric("sigma_sweep_cold_secs", sigma_cold.as_secs_f64());
    b.metric("sigma_sweep_cached_secs", sigma_cached.as_secs_f64());
    b.metric("sigma_sweep_speedup", sigma_speedup);
    b.metric("sigma_sweep_cache_hits", sigma_hits as f64);
    b.metric("k_sweep_cold_secs", k_cold.as_secs_f64());
    b.metric("k_sweep_cached_secs", k_cached.as_secs_f64());
    b.metric("k_sweep_speedup", k_speedup);
    b.metric("k_sweep_cache_hits", k_hits as f64);

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_pipeline.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("[saved {json_path}]"),
        Err(e) => eprintln!("[failed to save {json_path}: {e}]"),
    }
}
