//! Streaming-ingestion benchmark: generate a synthetic LibSVM file, then
//! time the chunked stages — raw chunk reading, the stats pass, and the
//! block-wise featurize pass — reporting rows/sec per stage and the
//! streaming memory-bound accounting (dense chunk scratch bytes, peak
//! substrate block bytes). A fourth stage measures fault tolerance:
//! featurize with the quarantine policy layer engaged on the clean file
//! (`metrics.policy_overhead_pct`) and on a copy with ~1% corrupted
//! records (`metrics.degraded_featurize_rows_per_sec`,
//! `metrics.quarantined_rows`). A fifth stage measures shard scaling:
//! the full sharded featurization (plan → parallel workers → codebook
//! merge) at 1/2/4/8 shards (`metrics.shard_scaling_rows_per_sec_K`,
//! `metrics.shard_scaling_speedup_K`, `metrics.shard_merge_secs_K`).
//!
//!     cargo bench --bench bench_ingest
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_ingest   # CI smoke
//!
//! Full mode streams a 1,000,000-row file (the ISSUE 4 acceptance scale:
//! resident input is one `chunk_rows × d` scratch, never the file);
//! smoke mode shrinks to 20k rows. Results land in `BENCH_ingest.json`
//! (override with SCRB_BENCH_JSON): `metrics.featurize_rows_per_sec` is
//! the headline number, `metrics.peak_block_bytes` the memory bound.

use scrb::shard::{featurize_sharded, ShardFormat, ShardPlanner};
use scrb::stream::{
    corrupt_libsvm_text, stats_pass, ChunkReader, GuardedReader, IngestPolicy, LibsvmChunks,
    OnBadRecord, SparseChunk, StreamFeaturizer,
};
use scrb::util::bench::Bencher;
use scrb::util::rng::Pcg;
use std::io::Write as _;
use std::time::Instant;

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let n: usize = if smoke { 20_000 } else { 1_000_000 };
    let d: usize = 20;
    let nnz_per_row: usize = 6;
    let r: usize = 32;
    let chunk_rows: usize = 4096;
    let block_rows: usize = 65_536;
    println!(
        "== ingest bench (threads={}, n={n}, d={d}, r={r}, chunk_rows={chunk_rows}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    // synthetic sparse LibSVM file (deterministic)
    let path = std::env::temp_dir()
        .join(format!("scrb_bench_ingest_{}.libsvm", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    let t0 = Instant::now();
    {
        use std::fmt::Write as _;
        let file = std::fs::File::create(&path).expect("create bench file");
        let mut w = std::io::BufWriter::new(file);
        let mut rng = Pcg::seed(42);
        let mut line = String::new();
        let mut cols: Vec<usize> = Vec::with_capacity(nnz_per_row);
        for _ in 0..n {
            line.clear();
            write!(line, "{}", rng.below(3) + 1).unwrap();
            // LibSVM requires strictly ascending indices per row
            cols.clear();
            cols.extend((0..nnz_per_row).map(|_| rng.below(d) + 1));
            cols.sort_unstable();
            cols.dedup();
            for &col in &cols {
                let val = (rng.f64() * 1000.0).round() / 1000.0;
                write!(line, " {col}:{val}").unwrap();
            }
            line.push('\n');
            w.write_all(line.as_bytes()).unwrap();
        }
        w.flush().unwrap();
    }
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!("    generated {} MB in {:?}", file_bytes / (1 << 20), t0.elapsed());

    let mut reader = LibsvmChunks::from_path(&path, chunk_rows).expect("open bench file");
    let mut chunk = SparseChunk::new();

    // stage 1: raw chunked reading (parse only)
    let t0 = Instant::now();
    let mut rows = 0usize;
    while reader.next_chunk(&mut chunk).expect("read chunk") {
        rows += chunk.rows();
    }
    let read_time = t0.elapsed();
    assert_eq!(rows, n);
    b.record_once(&format!("chunk read n={n}"), read_time);
    let read_rps = n as f64 / read_time.as_secs_f64().max(1e-12);
    println!("    read:      {read_rps:.3e} rows/s");

    // stage 2: the stats pass (read + min/span/census accumulation)
    reader.reset().expect("rewind");
    let t0 = Instant::now();
    let stats = stats_pass(&mut reader, &mut chunk).expect("stats pass");
    let stats_time = t0.elapsed();
    assert_eq!(stats.n, n);
    b.record_once(&format!("stats pass n={n}"), stats_time);
    let stats_rps = n as f64 / stats_time.as_secs_f64().max(1e-12);
    println!("    stats:     {stats_rps:.3e} rows/s");
    let dim = reader.dim();
    let (lo, span) = stats.finalize(dim);

    // stage 3: the featurize pass (read + densify + bin + block assembly)
    reader.reset().expect("rewind");
    let mut fz =
        StreamFeaturizer::new(r, dim, 0.5, 7, lo, span, block_rows, n);
    let t0 = Instant::now();
    while reader.next_chunk(&mut chunk).expect("read chunk") {
        fz.push_chunk(&chunk);
    }
    let feats = fz.finish().expect("featurize");
    let feat_time = t0.elapsed();
    b.record_once(&format!("featurize pass n={n} r={r}"), feat_time);
    let feat_rps = n as f64 / feat_time.as_secs_f64().max(1e-12);
    println!(
        "    featurize: {feat_rps:.3e} rows/s (D={}, kappa={:.2}, {} blocks)",
        feats.codebook.dim,
        feats.kappa,
        feats.z.n_blocks()
    );

    // stage 4: fault-tolerance cost (ISSUE 6) — the same featurize pass
    // with the GuardedReader policy layer engaged, first on the clean file
    // (pure policy overhead) and then on a copy with ~1% of its lines
    // corrupted (degraded-mode throughput with quarantine skipping).
    let policy = IngestPolicy {
        on_bad_record: OnBadRecord::Quarantine,
        retry_backoff_ms: 0,
        ..IngestPolicy::default()
    };
    let guarded_featurize = |path: &str| {
        let mut inner = LibsvmChunks::from_path(path, chunk_rows).expect("open bench file");
        let mut guarded = GuardedReader::new(&mut inner, policy.clone());
        let mut chunk = SparseChunk::new();
        let stats = stats_pass(&mut guarded, &mut chunk).expect("stats pass");
        let dim = guarded.dim();
        let (lo, span) = stats.finalize(dim);
        guarded.reset().expect("rewind");
        let mut fz = StreamFeaturizer::new(r, dim, 0.5, 7, lo, span, block_rows, stats.n);
        let t0 = Instant::now();
        while guarded.next_chunk(&mut chunk).expect("read chunk") {
            fz.push_chunk(&chunk);
        }
        let _ = fz.finish().expect("featurize");
        (t0.elapsed(), stats.n, guarded.report().skipped())
    };

    let (clean_time, clean_rows, clean_skipped) = guarded_featurize(&path);
    assert_eq!((clean_rows, clean_skipped), (n, 0));
    let policy_overhead_pct =
        (clean_time.as_secs_f64() / feat_time.as_secs_f64().max(1e-12) - 1.0) * 100.0;
    b.record_once(&format!("featurize+policy n={n} r={r}"), clean_time);
    println!(
        "    policy:    {:.3e} rows/s ({policy_overhead_pct:+.1}% vs bare featurize)",
        n as f64 / clean_time.as_secs_f64().max(1e-12)
    );

    let dirty_path = format!("{path}.dirty");
    let (dirty, replaced) =
        corrupt_libsvm_text(&std::fs::read(&path).expect("reread bench file"), 42, 10);
    std::fs::write(&dirty_path, &dirty).expect("write dirty bench file");
    let (deg_time, deg_rows, deg_skipped) = guarded_featurize(&dirty_path);
    assert_eq!(deg_skipped, replaced.len(), "quarantine counts are exact");
    assert_eq!(deg_rows + deg_skipped, n);
    let deg_rps = deg_rows as f64 / deg_time.as_secs_f64().max(1e-12);
    b.record_once(&format!("featurize degraded 1% bad n={n} r={r}"), deg_time);
    println!("    degraded:  {deg_rps:.3e} rows/s ({deg_skipped} rows quarantined)");
    std::fs::remove_file(&dirty_path).ok();

    // stage 5: shard scaling (ISSUE 8) — the full sharded two-pass
    // featurization (plan → K parallel workers → codebook merge) at 1, 2,
    // 4 and 8 shards over the same file, with the merge step accounted
    // separately. The merged fit is bit-identical at every K, so the
    // rows/sec curve is a pure parallel-speedup measurement.
    let mut base_rps = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let plan = ShardPlanner::new(shards, chunk_rows, ShardFormat::Libsvm)
            .plan(&[path.clone()])
            .expect("shard plan");
        let mut readers = ShardPlanner::open(&plan).expect("open shards");
        let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
            readers.iter_mut().map(|r| r.as_mut()).collect();
        let t0 = Instant::now();
        let sharded = featurize_sharded(r, 0.5, 7, &mut refs, block_rows, &policy)
            .expect("sharded featurize");
        let total = t0.elapsed();
        assert_eq!(sharded.n, n);
        assert_eq!(sharded.features.codebook.dim, feats.codebook.dim, "codebooks must merge");
        let rps = n as f64 / total.as_secs_f64().max(1e-12);
        if shards == 1 {
            base_rps = rps;
        }
        b.record_once(&format!("sharded featurize n={n} r={r} shards={shards}"), total);
        println!(
            "    shards={shards}: {rps:.3e} rows/s ({:.1}x vs 1 shard; merge {:.1} ms)",
            rps / base_rps.max(1e-12),
            sharded.merge_time.as_secs_f64() * 1e3
        );
        b.metric(&format!("shard_scaling_rows_per_sec_{shards}"), rps);
        b.metric(&format!("shard_scaling_speedup_{shards}"), rps / base_rps.max(1e-12));
        b.metric(&format!("shard_merge_secs_{shards}"), sharded.merge_time.as_secs_f64());
    }

    // memory-bound accounting: resident input scratch vs substrate blocks
    let scratch_bytes = chunk_rows * dim * 8;
    let peak_block = feats.z.peak_block_bytes();
    let substrate = feats.z.bytes();
    println!(
        "    memory: chunk scratch {} KB, peak block {} KB, substrate total {} MB",
        scratch_bytes / 1024,
        peak_block / 1024,
        substrate / (1 << 20)
    );

    b.metric("ingest_n", n as f64);
    b.metric("ingest_dim", dim as f64);
    b.metric("ingest_file_bytes", file_bytes as f64);
    b.metric("read_rows_per_sec", read_rps);
    b.metric("stats_rows_per_sec", stats_rps);
    b.metric("featurize_rows_per_sec", feat_rps);
    b.metric("chunk_scratch_bytes", scratch_bytes as f64);
    b.metric("peak_block_bytes", peak_block as f64);
    b.metric("substrate_bytes", substrate as f64);
    b.metric("feature_dim", feats.codebook.dim as f64);
    b.metric("policy_overhead_pct", policy_overhead_pct);
    b.metric("degraded_featurize_rows_per_sec", deg_rps);
    b.metric("quarantined_rows", deg_skipped as f64);

    std::fs::remove_file(&path).ok();

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_ingest.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("[saved {json_path}]"),
        Err(e) => eprintln!("[failed to save {json_path}: {e}]"),
    }
}
