//! Online-maintenance benchmark (ISSUE 10): per-chunk incremental update
//! cost against the full streamed refit it replaces, plus admission
//! throughput and the drift-signal trajectory under sustained shift.
//!
//!     cargo bench --bench bench_update
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_update   # CI smoke
//!
//! Full mode runs at pendigits scale (n=12,000, d=16, K=10); smoke mode
//! shrinks the row count. Results land in `BENCH_update.json` (override
//! with SCRB_BENCH_JSON). Headline numbers:
//!
//! - `metrics.update_speedup_vs_refit`: full-refit seconds over mean
//!   per-chunk update seconds — the acceptance bar is >= 5x;
//! - `metrics.update_rows_per_sec`: steady-state absorption rate;
//! - `metrics.admit_rows_per_sec`: absorption rate when every row
//!   admits new bins (codebook growth engaged);
//! - `metrics.residual_ewma_step_T` / `metrics.unseen_ewma_step_T`: the
//!   drift trajectory that feeds the refit trigger.

use scrb::cluster::Env;
use scrb::config::{Kernel, PipelineConfig, UpdateConfig};
use scrb::data::synth;
use scrb::linalg::Mat;
use scrb::stream::{fit_streaming, LibsvmChunks, SparseChunk, StreamOpts};
use scrb::update::{UpdateOutcome, UpdateWorkspace};
use scrb::util::bench::Bencher;
use std::fmt::Write as _;
use std::time::Instant;

fn to_libsvm(x: &Mat, y: &[usize]) -> Vec<u8> {
    let mut s = String::new();
    for i in 0..x.rows {
        write!(s, "{}", y[i]).unwrap();
        for (j, &v) in x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(s, " {}:{v}", j + 1).unwrap();
            }
        }
        s.push('\n');
    }
    s.into_bytes()
}

fn chunk_of(x: &Mat, lo: usize, hi: usize) -> SparseChunk {
    let mut c = SparseChunk::new();
    for i in lo..hi {
        c.begin_row(0);
        for (j, &v) in x.row(i).iter().enumerate() {
            if v != 0.0 {
                c.push_entry(j as u32, v);
            }
        }
        c.end_row();
    }
    c
}

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    // pendigits scale: n ~= 11k, d = 16, K = 10
    let n: usize = if smoke { 1_600 } else { 12_000 };
    let n_base = n * 2 / 3; // fit on two thirds, maintain with the rest
    let (d, k, r) = (16usize, 10usize, 128usize);
    let chunk_rows: usize = 512;
    println!(
        "== update bench (threads={}, n={n}, d={d}, k={k}, r={r}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    let ds = synth::gaussian_blobs(n, d, k, 9.0, 42);
    let cfg = PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma: 0.7 })
        .kmeans_replicates(2)
        .seed(42)
        .build();
    let opts = StreamOpts { k: Some(k), ..Default::default() };

    // baseline 1: streamed fit over the base two thirds (the model being
    // maintained)
    let base_text = to_libsvm(&ds.x.row_block(0, n_base), &ds.y[..n_base]);
    let mut reader = LibsvmChunks::from_bytes(base_text, 4096);
    let t0 = Instant::now();
    let fit = fit_streaming(&Env::new(cfg.clone()), &mut reader, &opts).expect("base fit");
    let base_secs = t0.elapsed().as_secs_f64();
    let mut model = fit.model;
    println!("    base fit:   {n_base} rows in {base_secs:.3}s (D={})", model.codebook.dim);
    b.record_once(&format!("streamed fit n={n_base}"), t0.elapsed());

    // baseline 2: the full streamed refit an update replaces — fit over
    // everything (base + maintenance rows)
    let full_text = to_libsvm(&ds.x, &ds.y);
    let mut reader = LibsvmChunks::from_bytes(full_text, 4096);
    let t0 = Instant::now();
    let _refit = fit_streaming(&Env::new(cfg), &mut reader, &opts).expect("full refit");
    let refit_secs = t0.elapsed().as_secs_f64();
    println!("    full refit: {n} rows in {refit_secs:.3}s");
    b.record_once(&format!("streamed refit n={n}"), t0.elapsed());

    // stage 1: per-chunk incremental updates over the held-out third —
    // same distribution, so this is the steady-state maintenance cost
    let ucfg = UpdateConfig::default();
    let mut ws = UpdateWorkspace::new();
    let mut lo = n_base;
    let mut chunks = 0usize;
    let mut admitted = 0usize;
    let t0 = Instant::now();
    while lo < n {
        let hi = (lo + chunk_rows).min(n);
        let rep = model.update(&chunk_of(&ds.x, lo, hi), &ucfg, &mut ws).expect("update");
        admitted += rep.admitted;
        chunks += 1;
        lo = hi;
    }
    let upd_secs = t0.elapsed().as_secs_f64();
    let upd_rows = n - n_base;
    let chunk_secs = upd_secs / chunks.max(1) as f64;
    let speedup = refit_secs / chunk_secs.max(1e-12);
    let upd_rps = upd_rows as f64 / upd_secs.max(1e-12);
    b.record_once(&format!("update {chunks} chunks of {chunk_rows}"), t0.elapsed());
    println!(
        "    update:     {upd_rows} rows in {upd_secs:.3}s ({upd_rps:.3e} rows/s, \
         {admitted} bins admitted)"
    );
    println!(
        "    per chunk:  {:.3} ms -> {speedup:.1}x faster than the full refit",
        chunk_secs * 1e3
    );

    // stage 2: admission throughput — every row lands outside the fitted
    // frame, so codebook growth and projection widening run on each chunk
    let mut shifted = ds.x.row_block(n_base, n);
    for v in shifted.data.iter_mut() {
        *v += 50.0;
    }
    let dim_before = model.codebook.dim;
    let t0 = Instant::now();
    let mut lo = 0usize;
    while lo < shifted.rows {
        let hi = (lo + chunk_rows).min(shifted.rows);
        model.update(&chunk_of(&shifted, lo, hi), &ucfg, &mut ws).expect("admit update");
        lo = hi;
    }
    let admit_secs = t0.elapsed().as_secs_f64();
    let admit_rps = shifted.rows as f64 / admit_secs.max(1e-12);
    let grown = model.codebook.dim - dim_before;
    b.record_once(&format!("admitting update {} rows", shifted.rows), t0.elapsed());
    println!(
        "    admission:  {} rows in {admit_secs:.3}s ({admit_rps:.3e} rows/s, D {} -> {})",
        shifted.rows, dim_before, model.codebook.dim
    );

    // stage 3: drift trajectory — progressive shift until the trigger
    // fires; the EWMAs are what `scrb serve` STATUS exposes
    let ds2 = synth::gaussian_blobs(n_base, d, k, 9.0, 43);
    let base_text = to_libsvm(&ds2.x, &ds2.y);
    let mut reader = LibsvmChunks::from_bytes(base_text, 4096);
    let cfg2 = PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma: 0.7 })
        .kmeans_replicates(2)
        .seed(43)
        .build();
    let mut model = fit_streaming(&Env::new(cfg2), &mut reader, &opts).expect("fit").model;
    let steps = 8usize;
    let probe = (n_base / 4).max(64).min(1_000);
    let mut fired = None;
    for step in 0..steps {
        let mut xs = ds2.x.row_block(0, probe);
        for v in xs.data.iter_mut() {
            *v += 4.0 * (step + 1) as f64;
        }
        let rep = model.update(&chunk_of(&xs, 0, probe), &ucfg, &mut ws).expect("drift update");
        b.metric(&format!("unseen_ewma_step_{step}"), rep.unseen_ewma);
        b.metric(&format!("residual_ewma_step_{step}"), rep.residual_ewma);
        println!(
            "    drift {step}: unseen_ewma={:.4} residual_ewma={:.4}{}",
            rep.unseen_ewma,
            rep.residual_ewma,
            if rep.outcome == UpdateOutcome::RefitNeeded { "  [refit signaled]" } else { "" }
        );
        if rep.outcome == UpdateOutcome::RefitNeeded {
            fired = Some(step);
            break;
        }
    }
    if let Some(step) = fired {
        b.metric("refit_trigger_step", step as f64);
    }

    b.metric("update_n", n as f64);
    b.metric("update_chunk_rows", chunk_rows as f64);
    b.metric("base_fit_secs", base_secs);
    b.metric("refit_secs", refit_secs);
    b.metric("update_chunk_secs", chunk_secs);
    b.metric("update_speedup_vs_refit", speedup);
    b.metric("update_rows_per_sec", upd_rps);
    b.metric("admit_rows_per_sec", admit_rps);
    b.metric("bins_admitted", grown as f64);

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_update.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("[saved {json_path}]"),
        Err(e) => eprintln!("[failed to save {json_path}: {e}]"),
    }
}
