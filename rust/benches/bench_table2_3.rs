//! Bench harness for Tables 2 & 3: runs the full method × dataset grid at
//! a bench-friendly scale and prints both paper tables plus per-method
//! wallclock lines. (`examples/repro_table2_3` is the full-fidelity
//! driver; this target exists so `cargo bench` regenerates every table.)
//!
//!     cargo bench --bench bench_table2_3
//!     SCRB_BENCH_SCALE=256 cargo bench --bench bench_table2_3

use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let scale: usize = std::env::var("SCRB_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let cfg = PipelineConfig::builder().r(256).kmeans_replicates(3).build();
    let coord = Coordinator::new(cfg, scale);

    println!("== Table 2/3 bench (scale=1/{scale}, R={}) ==", coord.base_cfg.r);
    let names: Vec<String> = experiment::TABLE_DATASETS.iter().map(|s| s.to_string()).collect();
    let grid = experiment::table2_3(&coord, &names).expect("table driver failed");

    println!("\nTable 2: average rank scores (lower = better)");
    println!("{}", report::render_table2(&grid));
    println!("Table 3: computational time (seconds)");
    println!("{}", report::render_table3(&grid));

    // criterion-style lines for regression tracking
    let mut b = Bencher::from_env();
    for row in &grid.datasets {
        for run in row.runs.iter().flatten() {
            b.record_once(
                &format!("table3/{}/{}", row.name, run.method.name()),
                Duration::from_secs_f64(run.secs),
            );
        }
    }
    println!("{}", b.report());
}
