//! Bench harness for Fig. 5: per-method runtime scaling in R on the four
//! panel datasets (pendigits, letter, mnist, acoustic).

use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let scale: usize = std::env::var("SCRB_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let cfg = PipelineConfig::builder().kmeans_replicates(3).build();
    let coord = Coordinator::new(cfg, scale);

    let rs = [16usize, 64, 256];
    let mut b = Bencher::from_env();
    for dataset in ["pendigits", "letter", "mnist", "acoustic"] {
        let series = experiment::fig5(&coord, dataset, &rs).expect("fig5 driver failed");
        println!(
            "{}",
            report::render_series(&format!("Fig. 5: runtime vs R ({dataset})"), &series, "R")
        );
        for s in &series {
            for p in &s.points {
                b.record_once(
                    &format!("fig5/{dataset}/{}/R={}", s.label, p.x as usize),
                    Duration::from_secs_f64(p.secs),
                );
            }
        }
    }
    println!("{}", b.report());
}
