//! Serving-daemon load benchmark: a real `scrb serve` daemon on a
//! loopback socket, hammered by concurrent blocking clients.
//!
//!     cargo bench --bench bench_serve_load
//!     SCRB_BENCH_BUDGET_MS=200 cargo bench --bench bench_serve_load  # quick
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_serve_load        # CI smoke
//!
//! Two scenarios:
//!
//! 1. **Throughput/latency** at 1, 8, and 64 concurrent clients against
//!    a healthy daemon: per-request p50/p99 round-trip latency and
//!    aggregate points/sec (whole stack: framing, checksums, admission,
//!    micro-batch coalescing, `predict_batch`, response).
//! 2. **Overload**: one worker with an injected per-request stall and a
//!    tiny queue, 32 clients — measures the shed rate, i.e. how much of
//!    the offered load the daemon explicitly refuses (typed
//!    `Overloaded`) instead of queueing into collapse.
//!
//! Results land in `BENCH_serve_load.json` (override with
//! SCRB_BENCH_JSON): `metrics.serve_points_per_sec_c8` is the headline
//! number; `metrics.serve_overload_shed_rate` must be > 0 — a daemon
//! that never sheds under that setup is queueing unboundedly.

use scrb::linalg::Mat;
use scrb::serve::{test_model, ErrorCode, ServeClient, ServeConfig, ServeError, Server};
use scrb::stream::ServeFaultPlan;
use scrb::util::bench::Bencher;
use scrb::util::rng::Pcg;
use std::time::{Duration, Instant};

fn batch(rows: usize, seed: u64) -> Mat {
    let mut rng = Pcg::seed(seed);
    Mat::from_vec(rows, 3, (0..rows * 3).map(|_| rng.f64()).collect())
}

fn percentile_us(sorted: &[Duration], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx].as_nanos() as f64 / 1e3
}

/// Run `clients` concurrent connections against `addr` for `dur`,
/// returning (per-request latencies, requests, points).
fn hammer(addr: &str, clients: usize, rows: usize, dur: Duration) -> (Vec<Duration>, u64, u64) {
    let threads: Vec<_> = (0..clients)
        .map(|t| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).expect("connect");
                let x = batch(rows, 0xbe7c ^ t as u64);
                let mut lat = Vec::new();
                let begin = Instant::now();
                while begin.elapsed() < dur {
                    let s = Instant::now();
                    c.predict(&x).expect("predict under load");
                    lat.push(s.elapsed());
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::new();
    for th in threads {
        all.extend(th.join().expect("client thread"));
    }
    let requests = all.len() as u64;
    (all, requests, requests * rows as u64)
}

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (model_n, model_r, model_k) = if smoke { (60, 8, 4) } else { (1000, 64, 10) };
    let phase = if smoke { Duration::from_millis(150) } else { Duration::from_millis(1500) };
    let rows = 16;

    println!(
        "== serve load bench (threads={}, model n={model_n} R={model_r} k={model_k}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    // -- scenario 1: healthy daemon, rising concurrency
    let cfg = ServeConfig {
        workers: 4,
        queue_cap: 1024,
        max_batch: 64,
        default_deadline_ms: 30_000,
        ..ServeConfig::default()
    };
    let server = Server::bind(cfg, test_model(model_n, model_r, model_k, 42)).expect("bind");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr().to_string();

    for &clients in &[1usize, 8, 64] {
        let begin = Instant::now();
        let (mut lat, requests, points) = hammer(&addr, clients, rows, phase);
        let wall = begin.elapsed();
        lat.sort();
        let p50 = percentile_us(&lat, 0.50);
        let p99 = percentile_us(&lat, 0.99);
        let pts_per_sec = points as f64 / wall.as_secs_f64().max(1e-9);
        b.record_once(&format!("serve load, {clients} client(s)"), wall);
        b.metric(&format!("serve_p50_us_c{clients}"), p50);
        b.metric(&format!("serve_p99_us_c{clients}"), p99);
        b.metric(&format!("serve_points_per_sec_c{clients}"), pts_per_sec);
        println!(
            "  {clients:>2} client(s): {requests:>6} reqs, p50 {p50:.1} µs, p99 {p99:.1} µs, \
             {pts_per_sec:.3e} points/s"
        );
    }
    {
        let mut c = ServeClient::connect(&addr).expect("connect for drain");
        c.drain().expect("drain");
    }
    handle.join().expect("healthy daemon drains cleanly");

    // -- scenario 2: overload — one stalled worker, tiny queue, 32 clients
    let overload_cfg = ServeConfig {
        workers: 1,
        queue_cap: 8,
        max_batch: 8,
        default_deadline_ms: 30_000,
        fault: ServeFaultPlan {
            seed: 42,
            panic_permille: 0,
            stall_permille: 1000,
            stall_ms: if smoke { 5 } else { 20 },
        },
        ..ServeConfig::default()
    };
    let server =
        Server::bind(overload_cfg, test_model(model_n, model_r, model_k, 42)).expect("bind");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr().to_string();

    let begin = Instant::now();
    let outcomes: Vec<_> = (0..32usize)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).expect("connect");
                let x = batch(4, 0x10ad ^ t as u64);
                let (mut served, mut shed, mut timeout) = (0u64, 0u64, 0u64);
                let begin = Instant::now();
                while begin.elapsed() < phase {
                    match c.predict(&x) {
                        Ok(_) => served += 1,
                        Err(ServeError::Rejected { code: ErrorCode::Overloaded, .. }) => shed += 1,
                        Err(ServeError::Rejected { code: ErrorCode::Timeout, .. }) => timeout += 1,
                        Err(e) => panic!("unexpected failure under overload: {e}"),
                    }
                }
                (served, shed, timeout)
            })
        })
        .collect();
    let (mut served, mut shed, mut timeout) = (0u64, 0u64, 0u64);
    for th in outcomes {
        let (s, h, t) = th.join().expect("overload client");
        served += s;
        shed += h;
        timeout += t;
    }
    let wall = begin.elapsed();
    let total = served + shed + timeout;
    let shed_rate = shed as f64 / (total as f64).max(1.0);
    b.record_once("serve overload, 32 clients", wall);
    b.metric("serve_overload_total", total as f64);
    b.metric("serve_overload_served", served as f64);
    b.metric("serve_overload_shed", shed as f64);
    b.metric("serve_overload_timeouts", timeout as f64);
    b.metric("serve_overload_shed_rate", shed_rate);
    println!(
        "  overload: {total} reqs -> {served} served, {shed} shed ({:.1}%), {timeout} timed out",
        shed_rate * 100.0
    );
    {
        let mut c = ServeClient::connect(&addr).expect("connect for drain");
        c.drain().expect("drain");
    }
    handle.join().expect("overloaded daemon still drains cleanly");

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_serve_load.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("[saved {json_path}]"),
        Err(e) => eprintln!("[failed to save {json_path}: {e}]"),
    }
}
