//! Bench harness for Fig. 3: SVD-solver ablation (davidson/PRIMME vs
//! lanczos/svds) on the clustered-spectrum covtype-like benchmark.

use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let scale: usize = std::env::var("SCRB_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let cfg = PipelineConfig::builder().kmeans_replicates(3).build();
    let coord = Coordinator::new(cfg, scale);

    let rs = [16usize, 32, 64, 128];
    let series = experiment::fig3(&coord, &rs).expect("fig3 driver failed");
    println!(
        "{}",
        report::render_series("Fig. 3: SVD solver comparison (covtype-like)", &series, "R")
    );

    let mut b = Bencher::from_env();
    for s in &series {
        for p in &s.points {
            b.record_once(
                &format!("fig3/{}/R={}", s.label, p.x as usize),
                Duration::from_secs_f64(p.secs),
            );
        }
    }
    println!("{}", b.report());
}
