//! Solver shoot-out (ISSUE 9 acceptance): time-to-embedding of the three
//! SVD solvers — Davidson, Lanczos, and the compressive Chebyshev filter
//! at orders p ∈ {10, 25, 50} — on the same degree-normalized RB operator
//! at pendigits scale, plus the end-to-end SC_RB NMI each solver reaches
//! through the full pipeline.
//!
//!     cargo bench --bench bench_solvers
//!     SCRB_BENCH_SMOKE=1 cargo bench --bench bench_solvers   # CI smoke
//!
//! Results land in `BENCH_solvers.json` (override with SCRB_BENCH_JSON):
//! `metrics.compressive_best_embed_secs` vs `metrics.lanczos_embed_secs`
//! is the acceptance pair — at full scale some swept order must reach an
//! embedding at least as fast as Lanczos (`compressive_beats_lanczos`).
//! All series share one warm `SolverWorkspace`, so the numbers are the
//! steady-state solve cost a sweep driver sees, not first-call
//! provisioning.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, Solver};
use scrb::data::synth;
use scrb::eigen::{svds_ws, SolverWorkspace, SvdsOpts};
use scrb::metrics::all_metrics;
use scrb::rb::rb_features;
use scrb::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let smoke = std::env::var("SCRB_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (scale, r) = if smoke { (16, 64) } else { (1, 256) };

    // pendigits-scale workload: n = 10992/scale, d = 16, 10 classes
    let ds = synth::paper_benchmark("pendigits", scale, 42);
    let (n, k) = (ds.n(), ds.k);
    println!(
        "== solver bench (threads={}, n={n}, R={r}, k={k}{}) ==",
        scrb::util::threads::num_threads(),
        if smoke { ", SMOKE" } else { "" }
    );

    // ---- time-to-embedding on the identical operator: featurize once,
    // degree-normalize, and hand every solver the same Ẑ through the
    // shared `svds_ws` entry point (the SC_RB embed stage's hot call).
    let rb = rb_features(&ds.x, r, 0.25, 7);
    let mut zhat = rb.z.clone();
    let zdeg = zhat.implicit_degrees();
    zhat.normalize_by_degree(&zdeg);

    let mut ws = SolverWorkspace::new();
    let mut series: Vec<(String, Solver, usize)> = vec![
        ("davidson".into(), Solver::Davidson, 0),
        ("lanczos".into(), Solver::Lanczos, 0),
    ];
    for p in [10usize, 25, 50] {
        series.push((format!("compressive p={p}"), Solver::Compressive, p));
    }
    let mut embed_secs: Vec<(String, f64)> = Vec::new();
    for (name, solver, order) in &series {
        let mut opts = SvdsOpts::new(k, *solver);
        if *order > 0 {
            opts.cheb_order = *order;
        }
        svds_ws(&zhat, &opts, 42, &mut ws); // warm the workspace
        let stats = b.bench(&format!("{name:<16} embed k={k}"), || {
            svds_ws(&zhat, &opts, 42, &mut ws)
        });
        let med = stats.median.as_secs_f64();
        let matvecs = svds_ws(&zhat, &opts, 42, &mut ws).stats.matvecs;
        println!("    {name:<16} {:.1} ms/solve, {matvecs} matvecs", med * 1e3);
        embed_secs.push((name.clone(), med));
    }
    let lanczos_secs = embed_secs[1].1;
    let best_csc = embed_secs[2..]
        .iter()
        .min_by(|a, c| a.1.total_cmp(&c.1))
        .expect("compressive series present");
    println!(
        "    best compressive ({}, {:.1} ms) vs lanczos ({:.1} ms)",
        best_csc.0,
        best_csc.1 * 1e3,
        lanczos_secs * 1e3
    );
    if !smoke && best_csc.1 > lanczos_secs {
        println!("    !! no swept order reached an embedding as fast as Lanczos");
    }

    // ---- end-to-end NMI through the full pipeline: same data, same
    // seed, only `--solver` changes (compressive at the default p=25).
    let base = PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma: 0.25 })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .seed(42)
        .build();
    for solver in Solver::ALL {
        let cfg = base.rebuild(|bb| bb.solver(solver)).expect("solver point");
        let env = Env::new(cfg);
        let t0 = std::time::Instant::now();
        let fitted = MethodKind::ScRb.fit(&env, &ds.x).expect("fit failed");
        let fit_secs = t0.elapsed().as_secs_f64();
        let m = all_metrics(&fitted.output.labels, &ds.y);
        println!(
            "    {:<12} end-to-end: nmi={:.3} acc={:.3} in {:.2}s",
            solver.name(),
            m.nmi,
            m.accuracy,
            fit_secs
        );
        b.metric(&format!("{}_nmi", solver.name()), m.nmi);
        b.metric(&format!("{}_fit_secs", solver.name()), fit_secs);
    }

    b.metric("solver_n", n as f64);
    b.metric("solver_r", r as f64);
    b.metric("davidson_embed_secs", embed_secs[0].1);
    b.metric("lanczos_embed_secs", lanczos_secs);
    for (name, secs) in &embed_secs[2..] {
        let p: String = name.chars().filter(|c| c.is_ascii_digit()).collect();
        b.metric(&format!("compressive_p{p}_embed_secs"), *secs);
    }
    b.metric("compressive_best_embed_secs", best_csc.1);
    b.metric(
        "compressive_beats_lanczos",
        if best_csc.1 <= lanczos_secs { 1.0 } else { 0.0 },
    );

    println!("\n{}", b.report());
    let json_path =
        std::env::var("SCRB_BENCH_JSON").unwrap_or_else(|_| "BENCH_solvers.json".into());
    match b.write_json(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("[bench json not written: {e}]"),
    }
}
