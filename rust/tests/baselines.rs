//! Cross-method behavioural tests: the *relationships* between methods the
//! paper's evaluation hinges on (who wins where), at test-sized scales.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::metrics::{accuracy, average_rank_scores, nmi};

fn cfg(k: usize, r: usize, sigma: f64) -> PipelineConfig {
    PipelineConfig::builder()
        .engine(Engine::Native)
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .kmeans_replicates(3)
        .build()
}

#[test]
fn rb_converges_faster_than_rf_at_small_r() {
    // Theorem 1's practical consequence (and Fig. 2's shape): at a small
    // feature budget, SC_RB extracts more of the kernel than SC_RF.
    // Averaged over seeds to avoid flakiness.
    let mut rb_total = 0.0;
    let mut rf_total = 0.0;
    for seed in 0..3u64 {
        let ds = synth::concentric_rings(400, 2, 2, 0.12, 100 + seed);
        let mut c = cfg(2, 32, 0.3);
        c.seed = seed;
        let rb = MethodKind::ScRb.run(&Env::new(c.clone()), &ds.x).unwrap();
        let rf = MethodKind::ScRf.run(&Env::new(c), &ds.x).unwrap();
        rb_total += nmi(&rb.labels, &ds.y);
        rf_total += nmi(&rf.labels, &ds.y);
    }
    assert!(
        rb_total >= rf_total,
        "SC_RB ({rb_total:.3}) should beat SC_RF ({rf_total:.3}) at R=32"
    );
}

#[test]
fn sc_family_beats_similarity_family_on_manifolds() {
    // §5.1: "SC type methods … generally achieve better ranking scores
    // compared to similarity-based methods" — test on ring geometry.
    let ds = synth::concentric_rings(500, 2, 2, 0.1, 77);
    let c = cfg(2, 128, 0.3);
    let sc_rb = MethodKind::ScRb.run(&Env::new(c.clone()), &ds.x).unwrap();
    let kk_rf = MethodKind::KkRf.run(&Env::new(c), &ds.x).unwrap();
    let a_rb = accuracy(&sc_rb.labels, &ds.y);
    let a_kk = accuracy(&kk_rf.labels, &ds.y);
    assert!(
        a_rb > a_kk + 0.1,
        "Laplacian-approx SC_RB ({a_rb:.3}) should beat W-approx KK_RF ({a_kk:.3}) on rings"
    );
}

#[test]
fn rank_aggregation_orders_methods_sensibly() {
    // run four methods on an easy dataset and check the rank machinery
    let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 55);
    let c = cfg(3, 128, 0.5);
    let methods = [MethodKind::ScRb, MethodKind::KMeans, MethodKind::ScNys, MethodKind::KkRs];
    let scores: Vec<_> = methods
        .iter()
        .map(|m| {
            let out = m.run(&Env::new(c.clone()), &ds.x).unwrap();
            scrb::metrics::all_metrics(&out.labels, &ds.y)
        })
        .collect();
    let ranks = average_rank_scores(&scores);
    assert_eq!(ranks.len(), 4);
    let sum: f64 = ranks.iter().sum();
    assert!((sum - (1..=4).sum::<usize>() as f64).abs() < 1e-9, "ranks {ranks:?}");
}

#[test]
fn nystrom_and_lsc_track_exact_sc_on_blobs() {
    let ds = synth::gaussian_blobs(300, 3, 3, 9.0, 61);
    let c = cfg(3, 64, 0.5);
    let exact = MethodKind::ScExact.run(&Env::new(c.clone()), &ds.x).unwrap();
    let nys = MethodKind::ScNys.run(&Env::new(c.clone()), &ds.x).unwrap();
    let lsc = MethodKind::ScLsc.run(&Env::new(c), &ds.x).unwrap();
    let a_exact = accuracy(&exact.labels, &ds.y);
    let a_nys = accuracy(&nys.labels, &ds.y);
    let a_lsc = accuracy(&lsc.labels, &ds.y);
    assert!(a_exact > 0.95, "exact {a_exact}");
    assert!(a_nys > a_exact - 0.1, "nystrom {a_nys} vs exact {a_exact}");
    assert!(a_lsc > a_exact - 0.1, "lsc {a_lsc} vs exact {a_exact}");
}

#[test]
fn gaussian_kernel_path_works_for_rf_family() {
    // RF methods support both kernels; smoke the Gaussian path end-to-end
    let ds = synth::gaussian_blobs(250, 4, 2, 8.0, 67);
    let mut c = cfg(2, 256, 1.0);
    c.kernel = Kernel::Gaussian { sigma: 1.0 };
    for m in [MethodKind::ScRf, MethodKind::SvRf, MethodKind::KkRf] {
        let out = m.run(&Env::new(c.clone()), &ds.x).unwrap();
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "{m:?} gaussian acc {acc}");
    }
}

#[test]
fn poker_like_data_flattens_method_differences() {
    // the paper's poker row: near-structureless data → everyone ties-ish
    let ds = synth::paper_benchmark("poker", 4096, 5);
    let c = cfg(ds.k, 64, 0.5);
    let rb = MethodKind::ScRb.run(&Env::new(c.clone()), &ds.x).unwrap();
    let km = MethodKind::KMeans.run(&Env::new(c), &ds.x).unwrap();
    let n_rb = nmi(&rb.labels, &ds.y);
    let n_km = nmi(&km.labels, &ds.y);
    assert!(n_rb < 0.2 && n_km < 0.2, "poker-like should be near-structureless: {n_rb} {n_km}");
}
