//! Counting-allocator verification of the zero-allocation solver contract
//! (ISSUE 2 acceptance): once a `GramScratch` / `SolverWorkspace` is warm,
//! the fused gram product allocates nothing, and Davidson/Lanczos
//! steady-state iterations allocate nothing — runs with different matvec
//! budgets (hence different iteration counts) perform *identical* numbers
//! of allocations, because only entry provisioning and the returned
//! triplets ever touch the heap. The compressive filter and its Tikhonov
//! CG interpolation (ISSUE 9) are held to the same bar: allocations are
//! invariant to the Chebyshev order and the CG iteration budget.
//!
//! The serving contract (ISSUE 3 acceptance) is verified the same way:
//! once a `ServeWorkspace` is warm and the output vector is sized,
//! steady-state `predict_batch` calls perform **zero** heap allocations.
//!
//! The online-maintenance contract (ISSUE 10) too: once an
//! `UpdateWorkspace` is warm, in-vocabulary update chunks — with the
//! subspace fold *forced* via a negative `residual_tol`, so the whole
//! incremental-SVD + Lloyd-polish path runs — allocate nothing, and the
//! allocation count is invariant to the Lloyd iteration budget.
//!
//! Measured single-threaded (`SCRB_THREADS=1`): with worker threads the
//! scoped fork/join bookkeeping allocates O(threads) per parallel section —
//! data-size independent — which is the documented residual. Everything is
//! in one #[test] because the allocator counters are process-global.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, UpdateConfig};
use scrb::eigen::compressive::{sample_rows, tikhonov_interpolate};
use scrb::eigen::{
    compressive_svd_ws, davidson_svd_ws, lanczos_svd_ws, CompressiveOpts, DavidsonOpts,
    LanczosOpts, SolverWorkspace,
};
use scrb::linalg::Mat;
use scrb::model::{FittedModel, ScRbModel, ServeWorkspace};
use scrb::rb::rb_features;
use scrb::stream::{ChunkReader, LibsvmChunks, SparseChunk, StreamFeaturizer, StreamStats};
use scrb::update::UpdateWorkspace;
use scrb::util::alloc_count::{allocations, CountingAlloc};
use scrb::util::rng::Pcg;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn fused_gram_and_solver_steady_state_are_allocation_free() {
    // counters are process-global: single-threaded mode for the whole test
    std::env::set_var("SCRB_THREADS", "1");

    // -- a realistic small Ẑ on the EllRb substrate
    let mut rng = Pcg::seed(17);
    let n = 300;
    let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.f64()).collect());
    let mut zhat = rb_features(&x, 32, 0.4, 5).z;
    let deg = zhat.implicit_degrees();
    zhat.normalize_by_degree(&deg);

    // -- fused gram product: zero allocations once the scratch is warm
    let k = 6;
    let b = Mat::from_vec(n, k, (0..n * k).map(|_| rng.range_f64(-1.0, 1.0)).collect());
    let mut gs = scrb::sparse::GramScratch::new();
    let mut out = Mat::zeros(0, 0);
    zhat.gram_matmat_into(&b, &mut out, &mut gs); // warm: provisions scratch + out
    let before = allocations();
    for _ in 0..5 {
        zhat.gram_matmat_into(&b, &mut out, &mut gs);
    }
    assert_eq!(
        allocations() - before,
        0,
        "fused gram_matmat_into allocated in steady state"
    );

    // -- Davidson: allocations must not depend on the iteration count.
    // tol < 0 can never be met, so both runs exhaust their budgets; the
    // warm-up run provisions the workspace for this shape.
    let mut ws = SolverWorkspace::new();
    let opts = |budget: usize| DavidsonOpts {
        tol: -1.0,
        max_matvecs: budget,
        ..DavidsonOpts::new(4)
    };
    let _warm = davidson_svd_ws(&zhat, &opts(60), 9, &mut ws);
    let a0 = allocations();
    let short = davidson_svd_ws(&zhat, &opts(60), 9, &mut ws);
    let short_allocs = allocations() - a0;
    let a1 = allocations();
    let long = davidson_svd_ws(&zhat, &opts(600), 9, &mut ws);
    let long_allocs = allocations() - a1;
    assert!(
        long.stats.matvecs > 2 * short.stats.matvecs,
        "budget did not scale iterations: {:?} vs {:?}",
        short.stats,
        long.stats
    );
    assert_eq!(
        short_allocs, long_allocs,
        "Davidson iterations allocate: {short_allocs} vs {long_allocs} \
         ({} vs {} matvecs)",
        short.stats.matvecs, long.stats.matvecs
    );

    // -- Lanczos: same invariant across budgets.
    let lopts = |budget: usize| LanczosOpts {
        tol: -1.0,
        max_matvecs: budget,
        ..LanczosOpts::new(3)
    };
    let _warm = lanczos_svd_ws(&zhat, &lopts(80), 4, &mut ws);
    let a2 = allocations();
    let short = lanczos_svd_ws(&zhat, &lopts(80), 4, &mut ws);
    let short_allocs = allocations() - a2;
    let a3 = allocations();
    let long = lanczos_svd_ws(&zhat, &lopts(800), 4, &mut ws);
    let long_allocs = allocations() - a3;
    assert!(long.stats.iterations > short.stats.iterations, "budget did not add cycles");
    assert_eq!(
        short_allocs, long_allocs,
        "Lanczos restart cycles allocate: {short_allocs} vs {long_allocs} \
         ({} vs {} cycles)",
        short.stats.iterations, long.stats.iterations
    );

    // -- compressive filter: the matvec cost is fixed by (p, η) up front,
    // so runs at different orders take different numbers of recurrence
    // steps — yet must allocate identically, because the filter loop, the
    // dichotomy, and the Rayleigh–Ritz epilogue all live in the warm
    // workspace. Warm at the LARGEST order first so the coefficient
    // buffer's capacity covers every measured run.
    let copts = |order: usize| {
        let mut o = CompressiveOpts::new(4);
        o.order = order;
        o.signals = Some(8);
        o
    };
    let _warm = compressive_svd_ws(&zhat, &copts(60), 9, &mut ws);
    let a4 = allocations();
    let short = compressive_svd_ws(&zhat, &copts(20), 9, &mut ws);
    let short_allocs = allocations() - a4;
    let a5 = allocations();
    let long = compressive_svd_ws(&zhat, &copts(60), 9, &mut ws);
    let long_allocs = allocations() - a5;
    assert!(
        long.stats.matvecs > short.stats.matvecs,
        "order did not scale the filter cost: {:?} vs {:?}",
        short.stats,
        long.stats
    );
    assert_eq!(
        short_allocs, long_allocs,
        "compressive filter orders allocate differently: {short_allocs} vs {long_allocs} \
         ({} vs {} matvecs)",
        short.stats.matvecs, long.stats.matvecs
    );

    // -- Tikhonov interpolation: CG iterations are one warm gram product
    // plus scalar recurrences each — budgets that run more iterations
    // must not allocate more (only the returned score matrix does).
    let mut idx = Vec::new();
    sample_rows(n, 40, 3, &mut idx);
    let labs: Vec<u32> = (0..idx.len()).map(|i| (i % 4) as u32).collect();
    let lmax = long.s[0] * long.s[0] * 1.05;
    let _warm = tikhonov_interpolate(&zhat, &idx, &labs, 4, lmax, 0.1, 1e-14, 20, &mut ws);
    let a6 = allocations();
    let (_, mv_short) = tikhonov_interpolate(&zhat, &idx, &labs, 4, lmax, 0.1, 1e-14, 5, &mut ws);
    let short_allocs = allocations() - a6;
    let a7 = allocations();
    let (_, mv_long) = tikhonov_interpolate(&zhat, &idx, &labs, 4, lmax, 0.1, 1e-14, 20, &mut ws);
    let long_allocs = allocations() - a7;
    assert!(mv_long > mv_short, "CG budget did not add iterations");
    assert_eq!(
        short_allocs, long_allocs,
        "Tikhonov CG iterations allocate: {short_allocs} vs {long_allocs} \
         ({mv_short} vs {mv_long} matvecs)"
    );

    // -- serving hot path: once the workspace is warm and the output
    // vector is sized, predict_batch allocates nothing per batch.
    let cfg = PipelineConfig::builder()
        .k(3)
        .r(32)
        .kernel(Kernel::Laplacian { sigma: 0.4 })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .build();
    let fitted = MethodKind::ScRb.fit(&Env::new(cfg), &x).expect("SC_RB fit");
    let mut serve_ws = ServeWorkspace::new();
    let mut labels: Vec<usize> = Vec::new();
    fitted.model.predict_batch(&x, &mut serve_ws, &mut labels).unwrap(); // warm
    assert_eq!(labels, fitted.output.labels, "train predictions must match fit");
    let before = allocations();
    for _ in 0..5 {
        fitted.model.predict_batch(&x, &mut serve_ws, &mut labels).unwrap();
    }
    assert_eq!(
        allocations() - before,
        0,
        "predict_batch allocated in steady state"
    );

    // -- streaming ingestion (ISSUE 4 acceptance): once the chunk buffers
    // and per-grid state are warm, the chunk loop allocates nothing. The
    // file repeats one 8-row block, so every column, class, and bin is
    // discovered in chunk 1; chunks 2..N are pure steady state.
    let base = "\
1 1:0.25 3:0.75
2 2:0.5
1 1:0.1 2:0.9 3:0.3
3 4:1.0
2 1:0.6 4:0.2
1 3:0.45
3 2:0.15 3:0.85 4:0.05
2 1:0.35 2:0.65
";
    let mut text = String::new();
    let repeats = 20usize;
    for _ in 0..repeats {
        text.push_str(base);
    }
    let n_stream = 8 * repeats;
    let mut reader = LibsvmChunks::from_bytes(text.into_bytes(), 8);
    let mut chunk = SparseChunk::new();

    // stats pass: warm the chunk buffers with chunk 1, then the loop over
    // the remaining chunks must not touch the heap
    let mut stats = StreamStats::new();
    assert!(reader.next_chunk(&mut chunk).unwrap());
    stats.update(&chunk);
    let before = allocations();
    while reader.next_chunk(&mut chunk).unwrap() {
        stats.update(&chunk);
    }
    assert_eq!(allocations() - before, 0, "stats chunk loop allocated in steady state");
    assert_eq!(stats.n, n_stream);
    let d = reader.dim();
    let (lo, span) = stats.finalize(d);

    // featurize pass: chunk 1 provisions the dense scratch, the per-grid
    // dictionaries, and the (single, exactly-reserved) block; every later
    // chunk re-bins known bins into reused buffers — zero allocations
    reader.reset().unwrap();
    let mut fz = StreamFeaturizer::new(8, d, 0.5, 3, lo, span, n_stream, n_stream);
    assert!(reader.next_chunk(&mut chunk).unwrap());
    fz.push_chunk(&chunk);
    let before = allocations();
    while reader.next_chunk(&mut chunk).unwrap() {
        fz.push_chunk(&chunk);
    }
    assert_eq!(
        allocations() - before,
        0,
        "featurize chunk loop allocated in steady state beyond the block being built"
    );
    let feats = fz.finish().unwrap();
    assert_eq!(feats.z.rows, n_stream);
    assert_eq!(feats.labels.len(), n_stream);

    // -- online update hot path (ISSUE 10): in-vocabulary chunks with the
    // subspace fold FORCED (residual_tol < 0), so every stage runs —
    // binning, incremental SVD, centroid rotation, Lloyd polish, drift
    // tracking. Once the workspace is warm, steady-state updates must not
    // touch the heap: only an actual bin admission may allocate.
    let mut model = *fitted.model.into_any().downcast::<ScRbModel>().ok().unwrap();
    chunk.clear();
    for i in 0..64 {
        chunk.begin_row(0);
        for (j, &v) in x.row(i).iter().enumerate() {
            chunk.push_entry(j as u32, v);
        }
        chunk.end_row();
    }
    let ucfg = |lloyd: usize| UpdateConfig {
        residual_tol: -1.0,
        lloyd_iters: lloyd,
        ..Default::default()
    };
    let mut uws = UpdateWorkspace::new();
    // warm twice: the first call provisions every buffer and the tracker
    for _ in 0..2 {
        let rep = model.update(&chunk, &ucfg(3), &mut uws).unwrap();
        assert_eq!(rep.admitted, 0, "training rows must all be in vocabulary");
    }
    let before = allocations();
    for _ in 0..5 {
        model.update(&chunk, &ucfg(3), &mut uws).unwrap();
    }
    assert_eq!(allocations() - before, 0, "update allocated in steady state");

    // the Lloyd budget changes the work, not the allocation count
    let a8 = allocations();
    model.update(&chunk, &ucfg(1), &mut uws).unwrap();
    let lloyd_short = allocations() - a8;
    let a9 = allocations();
    model.update(&chunk, &ucfg(5), &mut uws).unwrap();
    let lloyd_long = allocations() - a9;
    assert_eq!(
        lloyd_short, lloyd_long,
        "Lloyd passes allocate: {lloyd_short} vs {lloyd_long}"
    );
}
