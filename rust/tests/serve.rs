//! Resilience contract of the `scrb serve` daemon (ISSUE 6 acceptance).
//!
//! Every scenario runs a real daemon on `127.0.0.1:0` and talks to it
//! over TCP; fault injection is seeded through `SCRB_FAULT_SEED` (the
//! same sweep CI uses for `tests/faults.rs`), so "passes for seed 42"
//! is backed by passes for 7 and 1234 too. The load-bearing assertions:
//!
//! - every `Labels` response is **bit-equal** to `predict_batch` run
//!   directly against whichever model version served it, including
//!   responses coalesced into micro-batches and responses racing a hot
//!   swap;
//! - shed / timeout / restart counters are **exact**, not "at least
//!   one" — lost updates or double counts fail the suite;
//! - protocol abuse (garbage, torn frames, oversized frames, corrupt
//!   payloads) gets *typed* errors and never kills the daemon.

use scrb::linalg::Mat;
use scrb::model::{FittedModel, ScRbModel, ServeWorkspace, WARN_EVERY};
use scrb::serve::protocol::{decode_error, encode_frame, encode_predict, HEADER_LEN};
use scrb::serve::{
    test_model, ErrorCode, FrameKind, ServeClient, ServeConfig, ServeError, Server, ServerHandle,
};
use scrb::stream::{corrupt_model_bytes, tear_frame, ServeFaultPlan};
use scrb::util::json::Json;
use scrb::util::rng::Pcg;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Seed for fault injection; CI sweeps SCRB_FAULT_SEED ∈ {42, 7, 1234}.
fn fault_seed() -> u64 {
    std::env::var("SCRB_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("scrb_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create tmpdir");
    dir
}

/// A d=3 batch matching [`test_model`]'s input dimensionality.
fn batch(rows: usize, seed: u64) -> Mat {
    let mut rng = Pcg::seed(seed);
    Mat::from_vec(rows, 3, (0..rows * 3).map(|_| rng.f64()).collect())
}

/// Ground truth: `predict_batch` straight against a local model.
fn direct_labels(model: &ScRbModel, x: &Mat) -> Vec<usize> {
    let mut ws = ServeWorkspace::new();
    let mut labels = Vec::new();
    model.predict_batch(x, &mut ws, &mut labels).expect("direct predict");
    labels
}

/// Default test config: short torn-frame bound so tear tests are fast.
fn quick_cfg() -> ServeConfig {
    ServeConfig { frame_stall_ms: 300, ..ServeConfig::default() }
}

fn start(cfg: ServeConfig, model: ScRbModel) -> (ServerHandle, String) {
    let server = Server::bind(cfg, model).expect("bind");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr().to_string();
    (handle, addr)
}

/// Drain through the protocol and require a clean daemon exit.
fn drain_and_join(addr: &str, handle: ServerHandle) {
    let mut c = ServeClient::connect(addr).expect("connect for drain");
    c.drain().expect("drain ack");
    handle.join().expect("daemon exits cleanly after drain");
}

fn stat_u64(status: &Json, key: &str) -> u64 {
    status
        .get(key)
        .and_then(|j| j.as_f64())
        .unwrap_or_else(|| panic!("status field {key} missing or not a number"))
        as u64
}

// ---------------------------------------------------------------------
// Happy path
// ---------------------------------------------------------------------

#[test]
fn predict_roundtrip_is_bit_equal_to_direct() {
    let seed = fault_seed();
    let model = test_model(60, 8, 4, seed);
    let reference = test_model(60, 8, 4, seed); // identical twin
    let (handle, addr) = start(quick_cfg(), model);

    let mut c = ServeClient::connect(&addr).unwrap();
    c.ping().expect("ping");
    for i in 0..5u64 {
        let x = batch(7, seed ^ (i + 1));
        let (version, labels) = c.predict(&x).expect("predict");
        assert_eq!(version, 1, "no swap happened");
        assert_eq!(labels, direct_labels(&reference, &x), "batch {i} must be bit-equal");
    }
    drop(c);
    drain_and_join(&addr, handle);
}

#[test]
fn concurrent_clients_coalesce_with_exact_counters_and_bit_equal_labels() {
    let seed = fault_seed();
    let model = test_model(60, 8, 4, seed);
    let reference = Arc::new(test_model(60, 8, 4, seed));
    let (handle, addr) =
        start(ServeConfig { workers: 3, max_batch: 16, ..quick_cfg() }, model);

    let threads: Vec<_> = (0..8u64)
        .map(|t| {
            let addr = addr.clone();
            let reference = Arc::clone(&reference);
            thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).unwrap();
                for i in 0..20u64 {
                    let x = batch(5, seed ^ (t * 1000 + i + 1));
                    let (_, labels) = c.predict(&x).expect("predict under concurrency");
                    assert_eq!(
                        labels,
                        direct_labels(&reference, &x),
                        "client {t} batch {i} must be bit-equal even when coalesced"
                    );
                }
            })
        })
        .collect();
    for th in threads {
        th.join().expect("client thread");
    }

    let mut c = ServeClient::connect(&addr).unwrap();
    let status = c.status().expect("status");
    assert_eq!(stat_u64(&status, "served_requests"), 160, "8 clients x 20 requests, none lost");
    assert_eq!(stat_u64(&status, "served_points"), 800, "5 rows per request");
    let batches = stat_u64(&status, "batches");
    assert!((1..=160).contains(&batches), "batches {batches} out of range");
    assert_eq!(stat_u64(&status, "shed"), 0);
    assert_eq!(stat_u64(&status, "timeouts"), 0);
    assert_eq!(stat_u64(&status, "restarts"), 0);
    drop(c);
    drain_and_join(&addr, handle);
}

// ---------------------------------------------------------------------
// Protocol abuse
// ---------------------------------------------------------------------

#[test]
fn garbage_header_gets_typed_error_then_close() {
    let (handle, addr) = start(quick_cfg(), test_model(40, 8, 3, 7));
    let mut c = ServeClient::connect(&addr).unwrap();
    // 33 zero bytes: the header checksum cannot match, framing is lost
    c.send_raw(&[0u8; HEADER_LEN]).unwrap();
    let reply = c.read_raw().expect("typed reply before close");
    assert_eq!(reply.kind, FrameKind::Error);
    let (code, msg) = decode_error(&reply.payload).expect("decodable error");
    assert_eq!(code, ErrorCode::Malformed);
    assert!(!msg.is_empty());
    assert!(c.read_raw().is_err(), "connection must be closed after broken framing");
    drain_and_join(&addr, handle);
}

#[test]
fn corrupt_payload_is_rejected_but_connection_survives() {
    let seed = fault_seed();
    let model = test_model(40, 8, 3, seed);
    let reference = test_model(40, 8, 3, seed);
    let (handle, addr) = start(quick_cfg(), model);
    let mut c = ServeClient::connect(&addr).unwrap();

    let x = batch(4, seed);
    let mut bytes = encode_frame(FrameKind::Predict, 99, &encode_predict(0, &x));
    // flip one payload byte (header stays intact → framing survives)
    let flip = HEADER_LEN + (seed as usize % (bytes.len() - HEADER_LEN));
    bytes[flip] ^= 0x40;
    c.send_raw(&bytes).unwrap();
    let reply = c.read_raw().expect("typed reply");
    assert_eq!(reply.kind, FrameKind::Error);
    let (code, _) = decode_error(&reply.payload).unwrap();
    assert_eq!(code, ErrorCode::Malformed);

    // the same connection still serves correct answers afterwards
    let (_, labels) = c.predict(&x).expect("predict after recoverable error");
    assert_eq!(labels, direct_labels(&reference, &x));
    drop(c);
    drain_and_join(&addr, handle);
}

#[test]
fn oversized_frame_is_rejected_and_connection_survives() {
    let seed = fault_seed();
    let model = test_model(40, 8, 3, seed);
    let reference = test_model(40, 8, 3, seed);
    let (handle, addr) =
        start(ServeConfig { max_frame_bytes: 4096, ..quick_cfg() }, model);
    let mut c = ServeClient::connect(&addr).unwrap();

    // 300 rows x 3 cols x 8 bytes ≈ 7.2 KB payload > the 4 KB cap
    let big = batch(300, seed);
    c.send_raw(&encode_frame(FrameKind::Predict, 5, &encode_predict(0, &big))).unwrap();
    let reply = c.read_raw().expect("typed reply");
    assert_eq!(reply.kind, FrameKind::Error);
    let (code, msg) = decode_error(&reply.payload).unwrap();
    assert_eq!(code, ErrorCode::Oversized);
    assert!(msg.contains("4096"), "message should name the cap: {msg}");

    // the oversized payload was discarded in bounded chunks; the
    // connection is intact and a small batch goes through
    let small = batch(3, seed ^ 1);
    let (_, labels) = c.predict(&small).expect("predict after oversized reject");
    assert_eq!(labels, direct_labels(&reference, &small));
    drop(c);
    drain_and_join(&addr, handle);
}

#[test]
fn torn_frame_gets_typed_error_then_close() {
    let seed = fault_seed();
    let (handle, addr) =
        start(ServeConfig { frame_stall_ms: 200, ..ServeConfig::default() }, test_model(40, 8, 3, seed));

    let full = encode_frame(FrameKind::Predict, 1, &encode_predict(0, &batch(6, seed)));
    let mut torn = tear_frame(&full, seed);
    assert!(torn.len() < full.len(), "tear_frame must strictly truncate");
    if torn.is_empty() {
        // an empty tear is just "never connected"; send one byte so the
        // server has a started frame to declare torn
        torn = full[..1].to_vec();
    }
    let mut c = ServeClient::connect(&addr).unwrap();
    c.send_raw(&torn).unwrap();
    // send nothing more: within frame_stall_ms the daemon must declare
    // the frame torn, answer with a typed error, and close
    let reply = c.read_raw().expect("typed reply for torn frame");
    assert_eq!(reply.kind, FrameKind::Error);
    let (code, _) = decode_error(&reply.payload).unwrap();
    assert_eq!(code, ErrorCode::Malformed);
    assert!(c.read_raw().is_err(), "connection closed after torn frame");

    // the daemon is unharmed
    let mut c2 = ServeClient::connect(&addr).unwrap();
    c2.ping().expect("daemon alive after torn frame");
    drop(c2);
    drain_and_join(&addr, handle);
}

// ---------------------------------------------------------------------
// Load shedding and deadlines
// ---------------------------------------------------------------------

/// One worker stalled 400 ms per request + a 2-slot queue: requests
/// 1..=3 are admitted, 4 and 5 must be shed — exactly, on both the
/// client side and the daemon's counters.
#[test]
fn overload_sheds_excess_requests_with_exact_counts() {
    let seed = fault_seed();
    let cfg = ServeConfig {
        workers: 1,
        queue_cap: 2,
        default_deadline_ms: 10_000,
        fault: ServeFaultPlan { seed, panic_permille: 0, stall_permille: 1000, stall_ms: 600 },
        ..quick_cfg()
    };
    let (handle, addr) = start(cfg, test_model(40, 8, 3, seed));

    // (start delay ms, expect admitted). The single worker picks up the
    // first request within a few ms and stalls on it until t=600; the
    // next two fill the queue at t=150; the last two arrive at t=250
    // against a full queue and a busy worker.
    let plan = [(0u64, true), (150, true), (150, true), (250, false), (250, false)];
    let threads: Vec<_> = plan
        .iter()
        .enumerate()
        .map(|(i, &(delay, _))| {
            let addr = addr.clone();
            thread::spawn(move || {
                thread::sleep(Duration::from_millis(delay));
                let mut c = ServeClient::connect(&addr).unwrap();
                c.predict(&batch(3, seed ^ (i as u64 + 1)))
            })
        })
        .collect();
    let mut served = 0u64;
    let mut shed = 0u64;
    for (i, th) in threads.into_iter().enumerate() {
        match th.join().expect("client thread") {
            Ok(_) => served += 1,
            Err(ServeError::Rejected { code: ErrorCode::Overloaded, message }) => {
                assert!(message.contains("cap 2"), "shed message names the cap: {message}");
                shed += 1;
            }
            Err(e) => panic!("client {i}: unexpected {e}"),
        }
    }
    assert_eq!(served, 3, "worker slot + 2 queue slots");
    assert_eq!(shed, 2, "exactly the overflow is shed");

    let mut c = ServeClient::connect(&addr).unwrap();
    let status = c.status().unwrap();
    assert_eq!(stat_u64(&status, "shed"), 2);
    assert_eq!(stat_u64(&status, "served_requests"), 3);
    assert_eq!(stat_u64(&status, "timeouts"), 0);
    drop(c);
    drain_and_join(&addr, handle);
}

/// Requests whose deadline expires while queued behind a stalled worker
/// are answered `Timeout` — exactly those, the patient request is served.
#[test]
fn expired_deadlines_get_timeout_with_exact_counts() {
    let seed = fault_seed();
    let cfg = ServeConfig {
        workers: 1,
        queue_cap: 16,
        default_deadline_ms: 10_000,
        fault: ServeFaultPlan { seed, panic_permille: 0, stall_permille: 1000, stall_ms: 400 },
        ..quick_cfg()
    };
    let (handle, addr) = start(cfg, test_model(40, 8, 3, seed));

    // patient request occupies the worker until t=400
    let patient = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut c = ServeClient::connect(&addr).unwrap();
            c.predict(&batch(3, seed ^ 1))
        })
    };
    thread::sleep(Duration::from_millis(120));
    // two 100 ms-deadline requests queue at t=120, expire at t≈220,
    // and are only reached by the worker at t≈400
    let hasty: Vec<_> = (0..2u64)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).unwrap();
                c.predict_deadline(&batch(3, seed ^ (i + 10)), 100)
            })
        })
        .collect();

    assert!(patient.join().unwrap().is_ok(), "patient request is served");
    for th in hasty {
        match th.join().unwrap() {
            Err(ServeError::Rejected { code: ErrorCode::Timeout, .. }) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    let mut c = ServeClient::connect(&addr).unwrap();
    let status = c.status().unwrap();
    assert_eq!(stat_u64(&status, "timeouts"), 2);
    assert_eq!(stat_u64(&status, "served_requests"), 1);
    assert_eq!(stat_u64(&status, "shed"), 0);
    drop(c);
    drain_and_join(&addr, handle);
}

// ---------------------------------------------------------------------
// Hot swap
// ---------------------------------------------------------------------

/// The headline acceptance test: 8 clients stream predictions while the
/// model is hot-swapped to a re-fitted one and then a swap to a
/// *corrupted* file is rolled back. Zero requests may be dropped, and
/// every response must be bit-equal to a direct `predict_batch` against
/// whichever model version the daemon says served it.
#[test]
fn hot_swap_under_load_drops_nothing_and_labels_match_serving_version() {
    let seed = fault_seed();
    let dir = tmpdir("swap");
    let v1 = test_model(60, 8, 4, seed);
    let ref1 = Arc::new(test_model(60, 8, 4, seed));
    let v2 = test_model(60, 8, 4, seed ^ 0x5eed);
    let ref2 = Arc::new(test_model(60, 8, 4, seed ^ 0x5eed));

    let good_path = dir.join("v2.scrb").to_str().unwrap().to_string();
    v2.save(&good_path).expect("save v2");
    let bad_path = dir.join("corrupt.scrb").to_str().unwrap().to_string();
    std::fs::write(&bad_path, corrupt_model_bytes(&v2.to_bytes(), seed)).expect("write corrupt");

    let (handle, addr) =
        start(ServeConfig { workers: 3, default_deadline_ms: 10_000, ..quick_cfg() }, v1);

    // clients stream until each has seen the new version several times
    // (bounded by wall clock, not iterations, so a fast machine cannot
    // finish before the swap lands)
    let clients: Vec<_> = (0..8u64)
        .map(|t| {
            let addr = addr.clone();
            thread::spawn(move || -> Vec<(u32, u64, Vec<usize>)> {
                let mut c = ServeClient::connect(&addr).unwrap();
                let mut seen: Vec<(u32, u64, Vec<usize>)> = Vec::new();
                let mut v2_count = 0usize;
                let begin = std::time::Instant::now();
                let mut i = 0u64;
                while begin.elapsed() < Duration::from_secs(10) {
                    i += 1;
                    let bseed = seed ^ (t * 10_000 + i);
                    let (version, labels) = c.predict(&batch(4, bseed)).expect("no drops allowed");
                    assert_eq!(labels.len(), 4);
                    seen.push((version, bseed, labels));
                    if version >= 2 {
                        v2_count += 1;
                        if v2_count >= 10 {
                            break;
                        }
                    }
                    thread::sleep(Duration::from_millis(1));
                }
                seen
            })
        })
        .collect();

    thread::sleep(Duration::from_millis(80));
    let mut admin = ServeClient::connect(&addr).unwrap();
    let new_version = admin.swap(&good_path).expect("swap to re-fitted model");
    assert_eq!(new_version, 2);
    match admin.swap(&bad_path) {
        Err(ServeError::Rejected { code: ErrorCode::BadModel, message }) => {
            assert!(message.contains("corrupt.scrb"), "rejection names the file: {message}");
        }
        other => panic!("corrupt swap must be rejected, got {other:?}"),
    }

    let mut v1_seen = 0usize;
    let mut v2_seen = 0usize;
    for th in clients {
        let seen = th.join().expect("client thread");
        assert!(!seen.is_empty());
        for (version, bseed, labels) in seen {
            let x = batch(4, bseed);
            let want = match version {
                1 => {
                    v1_seen += 1;
                    direct_labels(&ref1, &x)
                }
                2 => {
                    v2_seen += 1;
                    direct_labels(&ref2, &x)
                }
                v => panic!("impossible model version {v}"),
            };
            assert_eq!(
                labels, want,
                "response must be bit-equal to version {version}'s direct prediction"
            );
        }
    }
    assert!(v1_seen > 0, "some traffic must have been served by v1 before the swap");
    assert!(v2_seen > 0, "every client loops until it sees v2");

    // rollback is visible in the audit trail; the daemon still runs v2
    let status = admin.status().unwrap();
    assert_eq!(stat_u64(&status, "model_version"), 2, "failed swap must not unpublish v2");
    assert_eq!(stat_u64(&status, "swaps_ok"), 1);
    assert_eq!(stat_u64(&status, "swaps_failed"), 1);
    let history = status.get("swap_history").and_then(|j| j.as_arr()).expect("swap_history");
    assert_eq!(history.len(), 2);
    assert_eq!(history[0].get("ok").and_then(|j| j.as_bool()), Some(true));
    assert_eq!(history[1].get("ok").and_then(|j| j.as_bool()), Some(false));
    drop(admin);
    drain_and_join(&addr, handle);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Worker panic isolation
// ---------------------------------------------------------------------

/// Seeded panic injection, serial traffic, `max_batch = 1`: the set of
/// panicking request ids is known in advance, so restart and rejection
/// counters must match it *exactly*, and every non-panicking request
/// must still be answered bit-equal.
#[test]
fn injected_worker_panics_restart_worker_with_exact_counts() {
    let seed = fault_seed();
    let plan = ServeFaultPlan { seed, panic_permille: 250, stall_permille: 0, stall_ms: 0 };
    let cfg = ServeConfig { workers: 1, max_batch: 1, fault: plan, ..quick_cfg() };
    let model = test_model(40, 8, 3, seed);
    let reference = test_model(40, 8, 3, seed);
    let (handle, addr) = start(cfg, model);

    let mut c = ServeClient::connect(&addr).unwrap();
    let mut expected_panics = 0u64;
    for id in 1..=30u64 {
        // the client assigns ids 1, 2, 3, ... on this connection, so the
        // injection decision for each request is known ahead of time
        let x = batch(3, seed ^ (id + 100));
        let result = c.predict(&x);
        if plan.panics(id) {
            expected_panics += 1;
            match result {
                Err(ServeError::Rejected { code: ErrorCode::Internal, message }) => {
                    assert!(message.contains("restarted"), "reply explains the restart: {message}");
                }
                other => panic!("request {id} should hit an injected panic, got {other:?}"),
            }
        } else {
            let (_, labels) = result.unwrap_or_else(|e| panic!("request {id} failed: {e}"));
            assert_eq!(labels, direct_labels(&reference, &x), "request {id} served after restarts");
        }
    }
    c.ping().expect("daemon alive after all injected panics");

    let status = c.status().unwrap();
    assert_eq!(stat_u64(&status, "restarts"), expected_panics, "one restart per injected panic");
    assert_eq!(stat_u64(&status, "internal_rejects"), expected_panics);
    assert_eq!(stat_u64(&status, "served_requests"), 30 - expected_panics);
    drop(c);
    drain_and_join(&addr, handle);
}

// ---------------------------------------------------------------------
// Status & drift
// ---------------------------------------------------------------------

#[test]
fn status_surfaces_drift_stats_and_config() {
    let seed = fault_seed();
    let cfg = ServeConfig { workers: 2, queue_cap: 31, ..quick_cfg() };
    let (handle, addr) = start(cfg, test_model(60, 8, 4, seed));

    let mut c = ServeClient::connect(&addr).unwrap();
    // in-distribution batch, then one far off the training range: the
    // served model's drift monitor must see both
    c.predict(&batch(8, seed ^ 2)).unwrap();
    let mut far = batch(8, seed ^ 3);
    for v in far.data.iter_mut() {
        *v += 1e4;
    }
    c.predict(&far).unwrap();

    let status = c.status().unwrap();
    assert_eq!(stat_u64(&status, "model_version"), 1);
    assert_eq!(stat_u64(&status, "workers"), 2);
    assert_eq!(stat_u64(&status, "queue_cap"), 31);
    assert_eq!(status.get("draining").and_then(|j| j.as_bool()), Some(false));
    let drift = status.get("drift").expect("drift object");
    assert_eq!(drift.get("points").and_then(|j| j.as_f64()), Some(16.0), "8 + 8 served points");
    let lookups = drift.get("lookups").and_then(|j| j.as_f64()).unwrap();
    let unseen = drift.get("unseen").and_then(|j| j.as_f64()).unwrap();
    assert!(lookups > 0.0);
    assert!(unseen > 0.0, "the far-out batch must register unseen bins");
    assert!(drift.get("rate").and_then(|j| j.as_f64()).unwrap() > 0.0);
    assert!(drift.get("over_threshold").and_then(|j| j.as_f64()).is_some());
    assert!(drift.get("warnings").and_then(|j| j.as_f64()).is_some());
    let history = status.get("swap_history").and_then(|j| j.as_arr()).unwrap();
    assert!(history.is_empty(), "no swaps yet");
    drop(c);
    drain_and_join(&addr, handle);
}

// ---------------------------------------------------------------------
// Graceful drain
// ---------------------------------------------------------------------

/// Drain while one request is on the worker and another is queued:
/// both must be answered before the daemon exits, and new work is
/// rejected with a typed `Draining`.
#[test]
fn drain_finishes_inflight_work_and_rejects_new() {
    let seed = fault_seed();
    let cfg = ServeConfig {
        workers: 1,
        default_deadline_ms: 10_000,
        fault: ServeFaultPlan { seed, panic_permille: 0, stall_permille: 1000, stall_ms: 400 },
        ..quick_cfg()
    };
    let model = test_model(40, 8, 3, seed);
    let reference = test_model(40, 8, 3, seed);
    let (handle, addr) = start(cfg, model);

    let spawn_predict = |delay_ms: u64, bseed: u64| {
        let addr = addr.clone();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(delay_ms));
            let mut c = ServeClient::connect(&addr).unwrap();
            c.predict(&batch(3, bseed)).map(|(_, labels)| labels)
        })
    };
    let on_worker = spawn_predict(0, seed ^ 21); // stalls on the worker until t≈400
    let queued = spawn_predict(120, seed ^ 22); // sits in the queue behind it

    thread::sleep(Duration::from_millis(200));
    let mut lagging = ServeClient::connect(&addr).unwrap();
    let mut admin = ServeClient::connect(&addr).unwrap();
    admin.drain().expect("drain ack");

    // new work after the drain is refused (typed) or the connection is
    // already gone — but never silently hangs or gets served
    match lagging.predict(&batch(3, seed ^ 23)) {
        Err(ServeError::Rejected { code: ErrorCode::Draining, .. }) | Err(ServeError::Transport(_)) => {}
        Ok(_) => panic!("a post-drain request must not be admitted"),
        Err(e) => panic!("unexpected rejection: {e}"),
    }

    // both in-flight requests complete with correct answers
    let a = on_worker.join().unwrap().expect("request on the worker survives drain");
    assert_eq!(a, direct_labels(&reference, &batch(3, seed ^ 21)));
    let b = queued.join().unwrap().expect("queued request survives drain");
    assert_eq!(b, direct_labels(&reference, &batch(3, seed ^ 22)));

    handle.join().expect("daemon exits after finishing in-flight work");
}

// ---------------------------------------------------------------------
// Drift counters under concurrency (satellite: exactness, no lost
// updates)
// ---------------------------------------------------------------------

/// Hammer one model with `predict_batch` from 8 threads and replay the
/// identical batches serially on a twin: every drift counter must match
/// exactly. Relaxed atomic increments may not lose updates.
#[test]
fn drift_counters_are_exact_under_concurrent_predict_batch() {
    let seed = fault_seed();
    let subject = Arc::new(test_model(60, 8, 4, seed));
    let twin = test_model(60, 8, 4, seed);

    // all batches far outside the training range so every call trips the
    // drift threshold deterministically, independent of interleaving
    let mk_far = |bseed: u64| {
        let mut x = batch(6, bseed);
        for v in x.data.iter_mut() {
            *v += 1e3;
        }
        x
    };

    let threads: Vec<_> = (0..8u64)
        .map(|t| {
            let subject = Arc::clone(&subject);
            thread::spawn(move || {
                let mut ws = ServeWorkspace::new();
                let mut labels = Vec::new();
                for i in 0..50u64 {
                    let x = mk_far(seed ^ (t * 777 + i + 1));
                    subject.predict_batch(&x, &mut ws, &mut labels).expect("predict");
                }
            })
        })
        .collect();
    for th in threads {
        th.join().expect("stress thread");
    }

    let mut ws = ServeWorkspace::new();
    let mut labels = Vec::new();
    for t in 0..8u64 {
        for i in 0..50u64 {
            let x = mk_far(seed ^ (t * 777 + i + 1));
            twin.predict_batch(&x, &mut ws, &mut labels).expect("predict");
        }
    }

    let got = subject.drift_stats();
    let want = twin.drift_stats();
    assert_eq!(got.points, want.points, "points lost under concurrency");
    assert_eq!(got.points, 8 * 50 * 6);
    assert_eq!(got.lookups, want.lookups, "lookups lost under concurrency");
    assert_eq!(got.unseen, want.unseen, "unseen lost under concurrency");
    assert_eq!(got.over_threshold, want.over_threshold, "over_threshold drifted");
    assert_eq!(
        got.warnings,
        (got.over_threshold).div_ceil(WARN_EVERY),
        "rate-limited warning count is a pure function of over_threshold"
    );
}
