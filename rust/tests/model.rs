//! Out-of-sample serving correctness (ISSUE 3 acceptance):
//! - `predict` on the training set reproduces `fit` labels exactly;
//! - held-out points from two_moons / gaussian_blobs land in the correct
//!   cluster with accuracy ≥ 0.9;
//! - save → load → predict round-trips bit-identically;
//! - error paths (dimension mismatch, missing/corrupt model files) are
//!   typed `ScrbError`s, never panics.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::{synth, Dataset};
use scrb::error::ScrbError;
use scrb::linalg::Mat;
use scrb::metrics::accuracy;
use scrb::model::{ClusterModel, FitResult, FittedModel, ScRbModel, ServeWorkspace};
use scrb::util::rng::Pcg;

fn rb_cfg(k: usize, r: usize, sigma: f64, seed: u64) -> PipelineConfig {
    PipelineConfig::builder()
        .engine(Engine::Native)
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .kmeans_replicates(3)
        .seed(seed)
        .build()
}

fn fit_scrb(cfg: PipelineConfig, x: &Mat) -> FitResult {
    MethodKind::ScRb.fit(&Env::new(cfg), x).expect("SC_RB fit")
}

/// Split a shuffled dataset into (train, test) at `n_train`.
fn split(ds: &Dataset, n_train: usize) -> (Mat, Vec<usize>, Mat, Vec<usize>) {
    let train_idx: Vec<usize> = (0..n_train).collect();
    let test_idx: Vec<usize> = (n_train..ds.n()).collect();
    (
        ds.x.select_rows(&train_idx),
        train_idx.iter().map(|&i| ds.y[i]).collect(),
        ds.x.select_rows(&test_idx),
        test_idx.iter().map(|&i| ds.y[i]).collect(),
    )
}

#[test]
fn predict_reproduces_fit_labels_on_training_set() {
    // moons: the non-convex geometry the paper leads with
    for seed in [3u64, 11, 29] {
        let ds = synth::two_moons(400, 0.05, seed);
        let fitted = fit_scrb(rb_cfg(2, 128, 0.15, seed), &ds.x);
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted, fitted.output.labels, "moons seed {seed}");
    }
    // blobs across K
    for (seed, k) in [(5u64, 3usize), (17, 4)] {
        let ds = synth::gaussian_blobs(300, 4, k, 8.0, seed);
        let fitted = fit_scrb(rb_cfg(k, 64, 0.6, seed), &ds.x);
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted, fitted.output.labels, "blobs seed {seed} k {k}");
    }
}

#[test]
fn prop_training_predictions_match_fit() {
    // property over random shapes: predict == fit labels on the training
    // set for every sampled (n, k, r)
    scrb::util::prop::check_named("predict==fit on train", 6, |rng, case| {
        let k = 2 + rng.below(2);
        let n = 150 + rng.below(150);
        let r: usize = 32 << rng.below(2);
        let ds = synth::gaussian_blobs(n, 3, k, 8.0, 1000 + case as u64);
        let fitted = fit_scrb(rb_cfg(k, r, 0.7, case as u64), &ds.x);
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted, fitted.output.labels, "n={n} k={k} r={r}");
    });
}

#[test]
fn held_out_moons_predicted_correctly() {
    let mut ds = synth::two_moons(800, 0.05, 7);
    ds.shuffle(&mut Pcg::seed(1));
    let (train_x, train_y, test_x, test_y) = split(&ds, 600);
    let fitted = fit_scrb(rb_cfg(2, 256, 0.15, 7), &train_x);
    let train_acc = accuracy(&fitted.output.labels, &train_y);
    assert!(train_acc > 0.9, "train accuracy {train_acc}");
    let predicted = fitted.model.predict(&test_x).unwrap();
    let test_acc = accuracy(&predicted, &test_y);
    assert!(test_acc >= 0.9, "held-out moons accuracy {test_acc}");
}

#[test]
fn held_out_blobs_predicted_correctly() {
    let mut ds = synth::gaussian_blobs(500, 4, 3, 8.0, 13);
    ds.shuffle(&mut Pcg::seed(2));
    let (train_x, train_y, test_x, test_y) = split(&ds, 350);
    let fitted = fit_scrb(rb_cfg(3, 128, 0.7, 13), &train_x);
    let train_acc = accuracy(&fitted.output.labels, &train_y);
    assert!(train_acc > 0.9, "train accuracy {train_acc}");
    let predicted = fitted.model.predict(&test_x).unwrap();
    let test_acc = accuracy(&predicted, &test_y);
    assert!(test_acc >= 0.9, "held-out blobs accuracy {test_acc}");
}

#[test]
fn save_load_predict_roundtrip_is_exact() {
    let ds = synth::gaussian_blobs(300, 4, 3, 8.0, 21);
    let fitted = fit_scrb(rb_cfg(3, 64, 0.7, 21), &ds.x);

    let dir = std::env::temp_dir().join("scrb_test_model");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.scrb");
    let path = path.to_str().unwrap();
    fitted.model.save(path).unwrap();

    let loaded = ScRbModel::load(path).unwrap();
    // identical predictions on the training set and on fresh points
    assert_eq!(
        fitted.model.predict(&ds.x).unwrap(),
        loaded.predict(&ds.x).unwrap()
    );
    let fresh = synth::gaussian_blobs(120, 4, 3, 8.0, 99).x;
    assert_eq!(
        fitted.model.predict(&fresh).unwrap(),
        loaded.predict(&fresh).unwrap()
    );
    // transform agrees bit for bit (f64 round-trips exactly)
    let a = fitted.model.transform(&fresh).unwrap();
    let b = loaded.transform(&fresh).unwrap();
    assert_eq!(a.data, b.data);
    // and predict on training data still equals fit labels after reload
    assert_eq!(loaded.predict(&ds.x).unwrap(), fitted.output.labels);
}

#[test]
fn predict_batch_matches_predict_across_batch_sizes() {
    let ds = synth::gaussian_blobs(240, 3, 3, 8.0, 31);
    let fitted = fit_scrb(rb_cfg(3, 64, 0.7, 31), &ds.x);
    let reference = fitted.model.predict(&ds.x).unwrap();
    let mut ws = ServeWorkspace::new();
    let mut out = Vec::new();
    // full batch, then shrinking batches reusing the same workspace
    for take in [240usize, 240, 17, 1] {
        let block = ds.x.row_block(0, take);
        fitted.model.predict_batch(&block, &mut ws, &mut out).unwrap();
        assert_eq!(&out[..], &reference[..take], "batch size {take}");
    }
}

#[test]
fn kmeans_fitted_model_is_exact_on_training_set() {
    let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 41);
    let cfg = PipelineConfig::builder()
        .engine(Engine::Native)
        .k(3)
        .kmeans_replicates(3)
        .build();
    let fitted = MethodKind::KMeans.fit(&Env::new(cfg), &ds.x).unwrap();
    assert_eq!(fitted.model.predict(&ds.x).unwrap(), fitted.output.labels);
}

#[test]
fn transductive_fallback_serves_baselines() {
    // SC_Nys has no native out-of-sample path; its class-mean fallback
    // should still place held-out blob points well.
    let mut ds = synth::gaussian_blobs(400, 4, 3, 9.0, 51);
    ds.shuffle(&mut Pcg::seed(4));
    let (train_x, _train_y, test_x, test_y) = split(&ds, 300);
    let cfg = PipelineConfig::builder()
        .engine(Engine::Native)
        .k(3)
        .r(64)
        .kernel(Kernel::Gaussian { sigma: 0.6 })
        .kmeans_replicates(3)
        .build();
    let fitted = MethodKind::ScNys.fit(&Env::new(cfg), &train_x).unwrap();
    let predicted = fitted.model.predict(&test_x).unwrap();
    let acc = accuracy(&predicted, &test_y);
    assert!(acc > 0.85, "class-mean fallback accuracy {acc}");
}

#[test]
fn every_method_fits_through_the_model_trait() {
    // the ClusterModel routing covers all nine methods
    let ds = synth::gaussian_blobs(180, 3, 2, 9.0, 61);
    let cfg = PipelineConfig::builder()
        .engine(Engine::Native)
        .k(2)
        .r(32)
        .kernel(Kernel::Gaussian { sigma: 0.6 })
        .kmeans_replicates(2)
        .build();
    for kind in MethodKind::ALL {
        let model: &dyn ClusterModel = &kind;
        let fitted = model.fit(&Env::new(cfg.clone()), &ds.x).unwrap();
        assert_eq!(fitted.output.labels.len(), 180, "{kind:?}");
        assert_eq!(fitted.model.n_clusters(), 2, "{kind:?}");
        assert_eq!(fitted.model.input_dim(), 3, "{kind:?}");
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted.len(), 180, "{kind:?}");
        assert!(predicted.iter().all(|&l| l < 2), "{kind:?}");
    }
}

// ---------------------------------------------------------------------------
// SCRBMODL version compatibility (ISSUE 10 satellite)
//
// The committed fixtures under tests/fixtures/ are byte-frozen v1 and v2
// images (provenance: tests/fixtures/make_fixtures.py). They pin the
// promise that files written by older builds keep loading verbatim — a
// promise that cannot be tested by round-tripping through the current
// writer, which only emits the current version.
// ---------------------------------------------------------------------------

/// The two frozen pre-v3 images, as (version, bytes).
const FIXTURES: [(u32, &[u8]); 2] = [
    (1, include_bytes!("fixtures/model_v1.scrb")),
    (2, include_bytes!("fixtures/model_v2.scrb")),
];

#[test]
fn committed_v1_and_v2_fixtures_load_under_the_v3_reader() {
    use scrb::model::UpdateState;
    for (version, bytes) in FIXTURES {
        let model = ScRbModel::from_bytes(bytes)
            .unwrap_or_else(|e| panic!("v{version} fixture failed to load: {e}"));
        // header fields as written by make_fixtures.py
        assert_eq!(model.codebook.r, 2, "v{version}");
        assert_eq!(model.codebook.d_in, 2, "v{version}");
        assert_eq!(model.codebook.dim, 4, "v{version}");
        assert_eq!(model.codebook.seed, 7, "v{version}");
        assert_eq!(model.s.len(), 2, "v{version}");
        assert_eq!(model.n_clusters(), 2, "v{version}");
        assert_eq!(model.input_dim(), 2, "v{version}");
        assert!(model.norm.is_none(), "v{version}");
        // pre-v3 files carry no trailer: maintenance state starts fresh
        assert_eq!(model.update_state, UpdateState::default(), "v{version}");
        // the hand-written model must actually serve
        let x = Mat::from_vec(2, 2, vec![0.3, 0.9, 1.4, 0.2]);
        let labels = model.predict(&x).unwrap();
        assert!(labels.iter().all(|&l| l < 2), "v{version}: {labels:?}");
        // and re-saving writes a loadable v3 image with the same behavior
        let v3 = model.to_bytes();
        let reloaded = ScRbModel::from_bytes(&v3).unwrap();
        assert_eq!(reloaded.predict(&x).unwrap(), labels, "v{version}");
    }
}

#[test]
fn v2_fixture_with_flipped_payload_fails_its_checksum() {
    // the v2 footer guards the payload: any flipped bit is caught
    let (_, bytes) = FIXTURES[1];
    let mut bad = bytes.to_vec();
    bad[40] ^= 0x10; // somewhere in the header scalars
    assert!(matches!(
        ScRbModel::from_bytes(&bad).unwrap_err(),
        ScrbError::Model(_)
    ));
}

/// Fit a small real model and return its v3 bytes.
fn v3_bytes() -> Vec<u8> {
    let ds = synth::gaussian_blobs(150, 3, 2, 8.0, 81);
    let fitted = fit_scrb(rb_cfg(2, 32, 0.7, 81), &ds.x);
    fitted.model.to_bytes()
}

#[test]
fn v3_truncation_at_any_cut_is_a_typed_model_error() {
    let bytes = v3_bytes();
    let n = bytes.len();
    // every cut through the trailer + footer, plus strided interior cuts
    let cuts = (0..n)
        .filter(|&c| c + 128 >= n || c % 101 == 0)
        .collect::<Vec<_>>();
    for cut in cuts {
        match ScRbModel::from_bytes(&bytes[..cut]) {
            Err(ScrbError::Model(_)) => {}
            Err(other) => panic!("cut at {cut}/{n}: wrong error kind {other}"),
            Ok(_) => panic!("cut at {cut}/{n} still loaded"),
        }
    }
}

#[test]
fn v3_bit_flips_are_typed_model_errors() {
    let bytes = v3_bytes();
    let n = bytes.len();
    // every bit of the trailer + footer, plus strided interior bytes
    let positions = (0..n)
        .filter(|&p| p + 56 + 8 >= n || p % 61 == 0)
        .collect::<Vec<_>>();
    for pos in positions {
        for bit in 0..8 {
            let mut bad = bytes.clone();
            bad[pos] ^= 1 << bit;
            match ScRbModel::from_bytes(&bad) {
                Err(ScrbError::Model(_)) => {}
                Err(other) => panic!("flip {pos}.{bit}: wrong error kind {other}"),
                Ok(_) => panic!("flip {pos}.{bit} still loaded"),
            }
        }
    }
}

#[test]
fn model_error_paths_are_typed() {
    let ds = synth::gaussian_blobs(150, 3, 2, 8.0, 71);
    let fitted = fit_scrb(rb_cfg(2, 32, 0.7, 71), &ds.x);

    // dimension mismatch
    let bad = Mat::zeros(4, 9);
    assert!(matches!(
        fitted.model.predict(&bad).unwrap_err(),
        ScrbError::InvalidInput(_)
    ));

    // missing model file
    assert!(matches!(
        ScRbModel::load("/no/such/dir/model.scrb").unwrap_err(),
        ScrbError::Io { .. }
    ));

    // corrupt model file
    let dir = std::env::temp_dir().join("scrb_test_model");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corrupt.scrb");
    std::fs::write(&path, b"definitely not a model").unwrap();
    assert!(matches!(
        ScRbModel::load(path.to_str().unwrap()).unwrap_err(),
        ScrbError::Model(_)
    ));

    // truncated model file
    let good = dir.join("truncated.scrb");
    fitted.model.save(good.to_str().unwrap()).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    std::fs::write(&good, &bytes[..bytes.len() / 2]).unwrap();
    assert!(ScRbModel::load(good.to_str().unwrap()).is_err());

    // exact SC refuses oversized input through the trait, as an Err
    let huge = Mat::zeros(scrb::cluster::sc_exact::MAX_EXACT_N + 1, 2);
    let cfg = PipelineConfig::builder().engine(Engine::Native).k(2).build();
    assert!(MethodKind::ScExact.fit(&Env::new(cfg), &huge).is_err());
}
