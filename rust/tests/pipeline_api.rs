//! Pipeline-API acceptance (ISSUE 5):
//!
//! - **pipeline ↔ legacy equivalence**: every `MethodKind` fitted through
//!   the new stage composition reproduces the pre-redesign inline
//!   scaffolding bit-exactly on seeded synthetic data — each legacy flow
//!   is replicated here, step for step, from the deleted per-method
//!   `fit` bodies (labels equal; for SC_RB, serialized model bytes
//!   equal);
//! - **cache correctness**: a sweep through a shared [`ArtifactCache`]
//!   produces bit-identical results to the same sweep with caching
//!   disabled, while actually hitting the cache;
//! - **k-sweep reuse**: with a pinned `embed_dim`, a k-sweep reuses the
//!   featurize *and* embed artifacts (only K-means re-runs);
//! - the streaming/in-memory single-driver contract is pinned separately
//!   in `tests/stream.rs` (model bytes equal).

use scrb::cluster::sc_exact::SymOp;
use scrb::cluster::sc_nys::kernel_block_env;
use scrb::cluster::sc_rf::rf_matrix;
use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, Solver};
use scrb::eigen::{svds, svds_ws, SolverWorkspace, SvdsOpts};
use scrb::kernels::kernel_matrix;
use scrb::kmeans::{kmeans, AssignEngine, KmeansOpts, NativeAssign};
use scrb::linalg::{cholesky_jittered, whiten_rows, Mat};
use scrb::model::{FittedModel, ScRbModel};
use scrb::pipeline::{normalize_dense_by_degree, ArtifactCache};
use scrb::rb::rb_features_with_codebook;
use scrb::util::rng::Pcg;

fn test_cfg() -> PipelineConfig {
    PipelineConfig::builder()
        .k(3)
        .r(24)
        .kernel(Kernel::Gaussian { sigma: 0.6 })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .seed(42)
        .build()
}

fn test_data() -> Mat {
    scrb::data::synth::gaussian_blobs(180, 4, 3, 8.0, 11).x
}

fn kopts(cfg: &PipelineConfig) -> KmeansOpts {
    KmeansOpts {
        k: cfg.k,
        replicates: cfg.kmeans_replicates,
        max_iters: cfg.kmeans_max_iters,
        tol: 1e-6,
        seed: cfg.seed,
        batch: None,
    }
}

fn sopts(cfg: &PipelineConfig) -> SvdsOpts {
    let mut o = SvdsOpts::new(cfg.k, cfg.solver);
    o.tol = cfg.svd_tol;
    o.max_matvecs = cfg.svd_max_iters;
    o
}

fn as_usize(labels: Vec<u32>) -> Vec<usize> {
    labels.into_iter().map(|l| l as usize).collect()
}

/// The pre-redesign inline flow of each method, replicated from the old
/// per-method `fit` bodies (native engine, no XLA). Returns the final
/// training labels.
fn legacy_labels(kind: MethodKind, cfg: &PipelineConfig, x: &Mat) -> Vec<usize> {
    let env = Env::new(cfg.clone());
    match kind {
        MethodKind::KMeans => {
            let km = kmeans(x, &kopts(cfg), &NativeAssign);
            // legacy relabeled through the model's native assignment
            let (lab, _) = NativeAssign.assign(x, &km.centroids);
            as_usize(lab)
        }
        MethodKind::ScExact => {
            let w = kernel_matrix(cfg.kernel, x);
            let n = w.rows;
            let mut scale = vec![0.0; n];
            for i in 0..n {
                let d: f64 = w.row(i).iter().sum();
                scale[i] = if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 };
            }
            let mut s = w;
            for i in 0..n {
                let si = scale[i];
                for j in 0..n {
                    s.set(i, j, si * s.at(i, j) * scale[j]);
                }
            }
            let op = SymOp(&s);
            let svd = svds(&op, &sopts(cfg), cfg.seed ^ 0xe8ac7);
            let mut u = svd.u;
            u.normalize_rows();
            as_usize(kmeans(&u, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::KkRs => {
            let m = cfg.r.min(x.rows);
            let mut rng = Pcg::new(cfg.seed, 0x4b72);
            let idx = rng.sample_indices(x.rows, m);
            let landmarks = x.select_rows(&idx);
            let c = kernel_block_env(&env, x, &landmarks);
            let w11 = kernel_block_env(&env, &landmarks, &landmarks);
            let l = cholesky_jittered(&w11);
            let z = whiten_rows(&c, &l);
            as_usize(kmeans(&z, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::KkRf => {
            let z = rf_matrix(&env, x);
            as_usize(kmeans(&z, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::SvRf => {
            let z = rf_matrix(&env, x);
            let svd = svds(&z, &sopts(cfg), cfg.seed ^ 0x57f5);
            let mut scores = svd.u;
            for j in 0..svd.s.len() {
                for i in 0..scores.rows {
                    scores.set(i, j, scores.at(i, j) * svd.s[j]);
                }
            }
            as_usize(kmeans(&scores, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::ScLsc => {
            let p = cfg.r.min(x.rows);
            let s_near = scrb::cluster::sc_lsc::S_NEAREST.min(p);
            let landmarks = {
                let mut rng = Pcg::new(cfg.seed, 0x15c0);
                let sub = (10 * p).min(x.rows);
                let idx = rng.sample_indices(x.rows, sub);
                let xs = x.select_rows(&idx);
                let opts =
                    KmeansOpts { k: p, replicates: 1, max_iters: 10, ..KmeansOpts::new(p) };
                kmeans(&xs, &opts, &NativeAssign).centroids
            };
            let a = {
                let n = x.rows;
                let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
                for i in 0..n {
                    let xi = x.row(i);
                    let mut vals: Vec<(u32, f64)> = (0..p)
                        .map(|l| (l as u32, cfg.kernel.eval(xi, landmarks.row(l))))
                        .collect();
                    vals.sort_by(|u, v| v.1.partial_cmp(&u.1).unwrap());
                    vals.truncate(s_near);
                    let sum: f64 = vals.iter().map(|(_, w)| w).sum();
                    if sum > 1e-300 {
                        for e in vals.iter_mut() {
                            e.1 /= sum;
                        }
                    }
                    rows.push(vals);
                }
                scrb::sparse::Csr::from_rows(n, p, rows)
            };
            let lam = a.col_sums();
            let mut ahat = a;
            let scale: Vec<f64> =
                lam.iter().map(|&l| if l > 1e-300 { 1.0 / l.sqrt() } else { 0.0 }).collect();
            for e in 0..ahat.data.len() {
                ahat.data[e] *= scale[ahat.indices[e] as usize];
            }
            let svd = svds(&ahat, &sopts(cfg), cfg.seed ^ 0x15ce);
            let mut u = svd.u;
            u.normalize_rows();
            as_usize(kmeans(&u, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::ScNys => {
            let m = cfg.r.min(x.rows);
            let mut rng = Pcg::new(cfg.seed, 0x4e79);
            let idx = rng.sample_indices(x.rows, m);
            let landmarks = x.select_rows(&idx);
            let c = kernel_block_env(&env, x, &landmarks);
            let w11 = kernel_block_env(&env, &landmarks, &landmarks);
            let l = cholesky_jittered(&w11);
            let mut z = whiten_rows(&c, &l);
            normalize_dense_by_degree(&mut z);
            let svd = svds(&z, &sopts(cfg), cfg.seed ^ 0x4ce5);
            let mut u = svd.u;
            u.normalize_rows();
            as_usize(kmeans(&u, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::ScRf => {
            let mut z = rf_matrix(&env, x);
            normalize_dense_by_degree(&mut z);
            let svd = svds(&z, &sopts(cfg), cfg.seed ^ 0x5cf5);
            let mut u = svd.u;
            u.normalize_rows();
            as_usize(kmeans(&u, &kopts(cfg), &NativeAssign).labels)
        }
        MethodKind::ScRb => legacy_scrb(cfg, x).1,
    }
}

/// The pre-redesign SC_RB fit (the old `sc_rb::fit` body, batch path):
/// RB features + codebook, implicit degrees, SVD, projection fold,
/// embedding through the serving model's own transform, K-means, native
/// relabel. Returns (serialized model bytes, labels).
fn legacy_scrb(cfg: &PipelineConfig, x: &Mat) -> (Vec<u8>, Vec<usize>) {
    let (rb, codebook) = rb_features_with_codebook(x, cfg.r, cfg.kernel.sigma(), cfg.seed);
    let mut zhat = rb.z;
    let d = zhat.implicit_degrees();
    zhat.normalize_by_degree(&d);
    let mut ws = SolverWorkspace::new();
    let svd = svds_ws(&zhat, &sopts(cfg), cfg.seed ^ 0x5bd5, &mut ws);
    let (s, v) = (svd.s, svd.v);
    let mut proj = v;
    let s0 = s.first().copied().unwrap_or(0.0).max(1e-300);
    let rsqrt = 1.0 / (cfg.r as f64).sqrt();
    let col_scale: Vec<f64> =
        s.iter().map(|&sj| if sj > 1e-12 * s0 { rsqrt / sj } else { 0.0 }).collect();
    for i in 0..proj.rows {
        for (pv, cs) in proj.row_mut(i).iter_mut().zip(col_scale.iter()) {
            *pv *= *cs;
        }
    }
    let mut model = ScRbModel {
        codebook,
        kernel: cfg.kernel,
        s,
        proj,
        centroids: Mat::zeros(0, 0),
        norm: None,
        drift: Default::default(),
        unseen_warn: scrb::model::DEFAULT_UNSEEN_WARN,
    };
    let emb = model.transform(x).unwrap();
    let km = kmeans(&emb, &kopts(cfg), &NativeAssign);
    model.centroids = km.centroids;
    let (lab, _) = NativeAssign.assign(&emb, &model.centroids);
    (model.to_bytes(), as_usize(lab))
}

fn model_bytes(model: &dyn FittedModel, tag: &str) -> Vec<u8> {
    let path = std::env::temp_dir()
        .join(format!("scrb_pipeline_api_{tag}_{}.scrb", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

#[test]
fn every_method_reproduces_the_legacy_flow_bit_exactly() {
    let x = test_data();
    let cfg = test_cfg();
    for kind in MethodKind::ALL {
        let expected = legacy_labels(kind, &cfg, &x);
        let fitted = kind.fit(&Env::new(cfg.clone()), &x).unwrap();
        assert_eq!(
            fitted.output.labels,
            expected,
            "{} through the stage composition diverged from the legacy inline flow",
            kind.name()
        );
    }
}

#[test]
fn scrb_pipeline_model_bytes_match_legacy_fit() {
    let x = test_data();
    // Laplacian kernel (RB's native one), both solvers
    for solver in [Solver::Davidson, Solver::Lanczos] {
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(16)
            .kernel(Kernel::Laplacian { sigma: 0.5 })
            .engine(Engine::Native)
            .solver(solver)
            .kmeans_replicates(2)
            .seed(7)
            .build();
        let (legacy_bytes, legacy_lab) = legacy_scrb(&cfg, &x);
        let fitted = MethodKind::ScRb.fit(&Env::new(cfg.clone()), &x).unwrap();
        assert_eq!(fitted.output.labels, legacy_lab, "{solver:?} labels");
        assert_eq!(
            model_bytes(fitted.model.as_ref(), "legacy_eq"),
            legacy_bytes,
            "{solver:?}: pipeline-built SC_RB model must serialize byte-identically \
             to the pre-redesign fit"
        );
    }
}

#[test]
fn cached_sweep_equals_uncached_sweep() {
    let x = test_data();
    let base = test_cfg();
    let mut cache = ArtifactCache::new();

    // σ-sweep × method subset: cache on vs cache off, bit-equal
    for &sigma in &[0.4f64, 0.6, 0.8] {
        let cfg = base.rebuild(|b| b.sigma(sigma)).unwrap();
        for kind in [MethodKind::ScRb, MethodKind::ScRf, MethodKind::KkRf] {
            let env = Env::new(cfg.clone());
            let cached = kind.pipeline(&cfg).fit_cached(&env, &x, &mut cache).unwrap();
            let cold = kind
                .pipeline(&cfg)
                .fit_cached(&env, &x, &mut ArtifactCache::disabled())
                .unwrap();
            assert_eq!(
                cached.result.output.labels, cold.result.output.labels,
                "{} σ={sigma}: cached sweep diverged",
                kind.name()
            );
            assert_eq!(cached.result.output.info.inertia, cold.result.output.info.inertia);
        }
    }
    // SC_RF and KK_RF share one RF featurization per σ, and the repeat
    // fits above hit embeds/clusters too
    assert!(cache.hits > 0, "sweep never reused an artifact");

    // a repeated identical fit is a full-pipeline hit with equal bytes
    let cfg = base.rebuild(|b| b.sigma(0.4)).unwrap();
    let env = Env::new(cfg.clone());
    let a = MethodKind::ScRb.pipeline(&cfg).fit_cached(&env, &x, &mut cache).unwrap();
    let b = MethodKind::ScRb.pipeline(&cfg).fit_cached(&env, &x, &mut cache).unwrap();
    assert_eq!(a.result.output.labels, b.result.output.labels);
    assert_eq!(
        model_bytes(a.result.model.as_ref(), "rep_a"),
        model_bytes(b.result.model.as_ref(), "rep_b")
    );
}

#[test]
fn k_sweep_reuses_featurize_and_embed() {
    let x = test_data();
    let base = PipelineConfig::builder()
        .r(24)
        .kernel(Kernel::Laplacian { sigma: 0.5 })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .embed_dim(5)
        .k(2)
        .build();
    let mut cache = ArtifactCache::new();

    let mut labels_by_k = Vec::new();
    for k in [2usize, 3, 4, 5] {
        let cfg = base.rebuild(|b| b.k(k)).unwrap();
        let env = Env::new(cfg.clone());
        let fitted = MethodKind::ScRb.pipeline(&cfg).fit_cached(&env, &x, &mut cache).unwrap();
        assert_eq!(fitted.embedding.u.cols, 5, "embedding width pinned by embed_dim");
        labels_by_k.push(fitted.result.output.labels.clone());
    }
    // 4 grid points: featurize + embed computed once (2 misses), then 3×2
    // hits; cluster always misses (k differs)
    assert!(cache.hits >= 6, "k-sweep should reuse featurize + embed, hits={}", cache.hits);

    // and the cached sweep equals fresh fits point for point
    for (i, k) in [2usize, 3, 4, 5].into_iter().enumerate() {
        let cfg = base.rebuild(|b| b.k(k)).unwrap();
        let env = Env::new(cfg.clone());
        let cold = MethodKind::ScRb
            .pipeline(&cfg)
            .fit_cached(&env, &x, &mut ArtifactCache::disabled())
            .unwrap();
        assert_eq!(cold.result.output.labels, labels_by_k[i], "k={k}");
    }
}

#[test]
fn embedding_artifact_exports_standalone() {
    let x = test_data();
    let cfg = test_cfg();
    let env = Env::new(cfg.clone());
    let fitted = MethodKind::ScRb
        .pipeline(&cfg)
        .fit_cached(&env, &x, &mut ArtifactCache::disabled())
        .unwrap();
    // Σ descending, embedding row count = N, serving projection present
    let s = &fitted.embedding.s;
    assert_eq!(s.len(), cfg.k);
    assert!(s.windows(2).all(|w| w[0] >= w[1]), "Σ must be descending: {s:?}");
    assert_eq!(fitted.embedding.u.rows, x.rows);
    assert!(fitted.embedding.proj.is_some());
    assert_eq!(fitted.features.feature_dim, fitted.embedding.proj.as_ref().unwrap().rows);
}

#[test]
fn transductive_assembly_needs_the_input_matrix() {
    // fit_features (the stream entry) rejects class-mean assembly typed
    let x = test_data();
    let cfg = test_cfg();
    let env = Env::new(cfg.clone());
    let mut cache = ArtifactCache::disabled();
    let fitted = MethodKind::ScNys.pipeline(&cfg).fit_cached(&env, &x, &mut cache).unwrap();
    let err = MethodKind::ScNys
        .pipeline(&cfg)
        .fit_features(&env, fitted.features.clone(), &mut cache)
        .unwrap_err();
    assert!(matches!(err, scrb::error::ScrbError::Unsupported(_)), "{err}");
}
