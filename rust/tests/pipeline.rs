//! End-to-end pipeline integration: the full Algorithm 2 stack (data →
//! RB → degrees → SVD → K-means → metrics) and its agreement with exact
//! spectral clustering — the paper's central claim, in miniature.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Kernel, PipelineConfig, Solver};
use scrb::coordinator::{experiment, Coordinator};
use scrb::data::synth;
use scrb::metrics::{accuracy, all_metrics, nmi};

fn native_cfg() -> PipelineConfig {
    PipelineConfig::builder()
        .engine(scrb::config::Engine::Native)
        .kmeans_replicates(3)
        .build()
}

#[test]
fn sc_rb_converges_to_exact_sc_in_r() {
    // Fig. 2 in miniature: as R grows, SC_RB's clustering approaches the
    // exact SC result on a non-trivial (ring) geometry.
    let ds = synth::concentric_rings(500, 2, 2, 0.12, 21);
    let mut cfg = native_cfg();
    cfg.k = 2;
    cfg.kernel = Kernel::Laplacian { sigma: 0.2 };

    let exact = MethodKind::ScExact.run(&Env::new(cfg.clone()), &ds.x).unwrap();
    let exact_acc = accuracy(&exact.labels, &ds.y);
    assert!(exact_acc > 0.95, "exact SC should solve rings: {exact_acc}");

    let mut accs = Vec::new();
    for r in [8usize, 64, 512] {
        cfg.r = r;
        let rb = MethodKind::ScRb.run(&Env::new(cfg.clone()), &ds.x).unwrap();
        accs.push(accuracy(&rb.labels, &ds.y));
    }
    assert!(
        accs[2] >= exact_acc - 0.03,
        "R=512 should reach exact SC: rb={accs:?} exact={exact_acc}"
    );
    assert!(accs[2] >= accs[0] - 0.02, "accuracy should not degrade with R: {accs:?}");
}

#[test]
fn sc_beats_kmeans_on_nonconvex() {
    // the motivating contrast of the paper's intro
    let ds = synth::two_moons(800, 0.06, 5);
    let mut cfg = native_cfg();
    cfg.k = 2;
    cfg.r = 256;
    cfg.kernel = Kernel::Laplacian { sigma: 0.15 };
    let km = MethodKind::KMeans.run(&Env::new(cfg.clone()), &ds.x).unwrap();
    let rb = MethodKind::ScRb.run(&Env::new(cfg), &ds.x).unwrap();
    let km_nmi = nmi(&km.labels, &ds.y);
    let rb_nmi = nmi(&rb.labels, &ds.y);
    assert!(
        rb_nmi > km_nmi + 0.2,
        "SC_RB ({rb_nmi:.3}) should beat K-means ({km_nmi:.3}) on moons"
    );
}

#[test]
fn all_methods_produce_valid_output_on_benchmark() {
    // every Table-2 method runs end-to-end on a scaled paper benchmark
    let coord = Coordinator::new(native_cfg(), 2048);
    let ds = experiment::dataset(&coord, "pendigits");
    let cfg = coord.cfg_for(&ds, None);
    for kind in MethodKind::ALL {
        let run = coord.run_method(kind, &ds, &cfg).unwrap();
        assert_eq!(run.method, kind);
        let m = run.metrics;
        for v in m.as_array() {
            assert!((0.0..=1.0).contains(&v), "{kind:?} metric out of range: {m:?}");
        }
        // any real method should beat the trivial lower bound by a margin
        assert!(m.accuracy >= 1.0 / ds.k as f64 * 0.8, "{kind:?} acc {}", m.accuracy);
    }
}

#[test]
fn solver_choice_does_not_change_clusters_when_converged() {
    let ds = synth::gaussian_blobs(300, 4, 3, 8.0, 31);
    let mut cfg = native_cfg();
    cfg.k = 3;
    cfg.r = 128;
    cfg.kernel = Kernel::Laplacian { sigma: 0.5 };
    cfg.svd_tol = 1e-8;
    cfg.svd_max_iters = 30_000;
    let mut outs = Vec::new();
    for solver in [Solver::Davidson, Solver::Lanczos] {
        cfg.solver = solver;
        let out = MethodKind::ScRb.run(&Env::new(cfg.clone()), &ds.x).unwrap();
        assert!(out.info.svd.as_ref().unwrap().converged, "{solver:?} converged");
        outs.push(out);
    }
    // same partition up to label permutation
    let m = all_metrics(&outs[0].labels, &outs[1].labels);
    assert!(m.accuracy > 0.98, "solver disagreement: {m:?}");
}

#[test]
fn deterministic_across_runs() {
    let ds = synth::paper_benchmark("cod_rna", 2048, 3);
    let coord = Coordinator::new(native_cfg(), 2048);
    let cfg = coord.cfg_for(&ds, None);
    let a = coord.run_method(MethodKind::ScRb, &ds, &cfg).unwrap();
    let b = coord.run_method(MethodKind::ScRb, &ds, &cfg).unwrap();
    assert_eq!(a.metrics, b.metrics, "same seed must give identical metrics");
}

#[test]
fn libsvm_file_roundtrip_through_pipeline() {
    // write a tiny LibSVM file, load it, cluster it
    let dir = std::env::temp_dir().join("scrb_test_libsvm");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("toy.libsvm");
    let mut text = String::new();
    let ds = synth::gaussian_blobs(120, 3, 2, 8.0, 13);
    for i in 0..ds.n() {
        text.push_str(&format!("{}", ds.y[i]));
        for (j, v) in ds.x.row(i).iter().enumerate() {
            text.push_str(&format!(" {}:{:.6}", j + 1, v));
        }
        text.push('\n');
    }
    std::fs::write(&path, text).unwrap();
    let mut loaded = scrb::data::load_libsvm(path.to_str().unwrap()).unwrap();
    loaded.minmax_normalize();
    assert_eq!(loaded.n(), 120);
    assert_eq!(loaded.k, 2);
    let mut cfg = native_cfg();
    cfg.k = 2;
    cfg.r = 64;
    cfg.kernel = Kernel::Laplacian { sigma: 0.4 };
    let out = MethodKind::ScRb.run(&Env::new(cfg), &loaded.x).unwrap();
    assert!(accuracy(&out.labels, &loaded.y) > 0.9);
}

#[test]
fn kappa_rate_improves_over_plain_rf_rate() {
    // Theorem 1's κ: RB's measured κ should exceed 1 (the plain-RF rate)
    // by a clear margin on real-ish data.
    let ds = synth::paper_benchmark("pendigits", 512, 7);
    let rb = scrb::rb::rb_features(&ds.x, 64, 0.25, 3);
    assert!(rb.kappa > 2.0, "κ = {} should exceed plain-RF rate 1", rb.kappa);
}
