//! Integration: the AOT artifacts (built by `make artifacts`) loaded and
//! executed through the PJRT runtime, validated against the native Rust
//! implementations of the same math (which are themselves validated
//! against the jnp oracles on the python side — closing the three-layer
//! loop).
//!
//! Tests skip (pass trivially) when `artifacts/` is absent so plain
//! `cargo test` works before `make artifacts`; `make test` runs both.

use scrb::config::Kernel;
use scrb::kernels::kernel_block;
use scrb::kmeans::{AssignEngine, NativeAssign};
use scrb::linalg::Mat;
use scrb::rf::RfMap;
use scrb::runtime::{ArtifactKind, XlaAssign, XlaRuntime};
use scrb::util::rng::Pcg;

fn runtime() -> Option<XlaRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(XlaRuntime::load("artifacts").expect("runtime should load when artifacts exist"))
}

fn rand_mat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
}

#[test]
fn kmeans_assign_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg::seed(1);
    // n not a tile multiple, d not a variant dim, k < kp — exercises padding
    for (n, d, k) in [(500usize, 7usize, 3usize), (2048, 32, 10), (3000, 60, 26)] {
        let x = rand_mat(&mut rng, n, d);
        let c = rand_mat(&mut rng, k, d);
        let (labels, dists) = rt.kmeans_assign(&x, &c).expect("variant should fit");
        let (nlabels, ndists) = NativeAssign.assign(&x, &c);
        let mut mismatches = 0;
        for i in 0..n {
            // f32 vs f64 can flip ties; tolerate only near-tie flips
            if labels[i] != nlabels[i] {
                let diff = (dists[i] - ndists[i]).abs();
                assert!(diff < 1e-3 * (1.0 + ndists[i]), "row {i}: {} vs {}", dists[i], ndists[i]);
                mismatches += 1;
            } else {
                assert!(
                    (dists[i] - ndists[i]).abs() < 1e-3 * (1.0 + ndists[i]),
                    "row {i} dist {} vs {}",
                    dists[i],
                    ndists[i]
                );
            }
        }
        assert!(mismatches < n / 100 + 2, "too many label mismatches: {mismatches}");
    }
}

#[test]
fn kmeans_assign_rejects_oversize() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg::seed(2);
    let x = rand_mat(&mut rng, 64, 900); // d > 800: no variant
    let c = rand_mat(&mut rng, 3, 900);
    assert!(rt.kmeans_assign(&x, &c).is_none());
    let x2 = rand_mat(&mut rng, 64, 8);
    let c2 = rand_mat(&mut rng, 40, 8); // k > kp=32
    assert!(rt.kmeans_assign(&x2, &c2).is_none());
}

#[test]
fn kernel_blocks_match_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg::seed(3);
    let x = rand_mat(&mut rng, 300, 20);
    let y = rand_mat(&mut rng, 700, 20); // forces multiple y tiles
    let sigma = 0.8;

    let lap = rt
        .kernel_block(ArtifactKind::KernelBlockLaplacian, &x, &y, 1.0 / sigma)
        .expect("laplacian variant");
    let lap_native = kernel_block(Kernel::Laplacian { sigma }, &x, &y);
    assert!(
        lap.sub(&lap_native).frob_norm() < 1e-4 * lap_native.frob_norm(),
        "laplacian mismatch"
    );

    let gau = rt
        .kernel_block(ArtifactKind::KernelBlockGaussian, &x, &y, 1.0 / (2.0 * sigma * sigma))
        .expect("gaussian variant");
    let gau_native = kernel_block(Kernel::Gaussian { sigma }, &x, &y);
    assert!(
        gau.sub(&gau_native).frob_norm() < 1e-4 * gau_native.frob_norm(),
        "gaussian mismatch"
    );
}

#[test]
fn rf_features_match_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg::seed(4);
    let x = rand_mat(&mut rng, 2500, 10); // two tiles
    let kernel = Kernel::Gaussian { sigma: 1.0 };
    let map = RfMap::sample(kernel, 10, 300, 7);
    let mut z = rt.rf_features(&x, &map.w, &map.b).expect("rf variant");
    z.scale((2.0 / 300f64).sqrt());
    let zn = map.features(&x);
    assert_eq!(z.rows, zn.rows);
    assert_eq!(z.cols, zn.cols);
    assert!(
        z.sub(&zn).frob_norm() < 1e-4 * zn.frob_norm().max(1.0),
        "rf mismatch {} vs {}",
        z.frob_norm(),
        zn.frob_norm()
    );
}

#[test]
fn xla_assign_engine_runs_kmeans() {
    let Some(rt) = runtime() else { return };
    let ds = scrb::data::gaussian_blobs(600, 4, 3, 9.0, 5);
    let engine = XlaAssign { runtime: &rt, force: true };
    let opts = scrb::kmeans::KmeansOpts { replicates: 3, ..scrb::kmeans::KmeansOpts::new(3) };
    let result = scrb::kmeans::kmeans(&ds.x, &opts, &engine);
    let labels: Vec<usize> = result.labels.iter().map(|&l| l as usize).collect();
    let acc = scrb::metrics::accuracy(&labels, &ds.y);
    assert!(acc > 0.95, "XLA-assign kmeans accuracy {acc}");
}

#[test]
fn full_pipeline_with_xla_engine_matches_native() {
    let Some(rt) = runtime() else { return };
    use scrb::cluster::{Env, MethodKind};
    use scrb::config::PipelineConfig;

    let ds = scrb::data::two_moons(500, 0.05, 9);
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(128)
        .kernel(Kernel::Laplacian { sigma: 0.15 })
        .kmeans_replicates(3)
        .build();

    let native = MethodKind::ScRb.run(&Env::with_xla(cfg.clone(), None), &ds.x).unwrap();
    let xla = MethodKind::ScRb.run(&Env::with_xla(cfg, Some(&rt)), &ds.x).unwrap();
    let acc_native = scrb::metrics::accuracy(&native.labels, &ds.y);
    let acc_xla = scrb::metrics::accuracy(&xla.labels, &ds.y);
    assert!(acc_native > 0.9, "native {acc_native}");
    assert!(acc_xla > 0.9, "xla {acc_xla}");
}
