//! Streaming-ingestion integration tests (ISSUE 4 acceptance):
//!
//! - chunk-boundary property: streamed featurization/fit is invariant to
//!   the reader's chunk size (1, 7, 64, N — identical down to the model
//!   bytes, hence including the phase-1 column assignment);
//! - the tentpole contract: a streamed fit on the same data and seed
//!   reproduces the in-memory fit's model **byte-identically** (save
//!   bytes equal) and its training labels exactly;
//! - the streamed model serves: training-set predict reproduces fit
//!   labels; save → load round-trips;
//! - the mini-batch K-means path for huge N engages and still clusters;
//! - the dense-CSV backend fits to the same bytes as the LibSVM backend
//!   on the same underlying data.

use scrb::cluster::{sc_rb, Env};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::coordinator::Coordinator;
use scrb::data::{parse_libsvm, synth, Dataset};
use scrb::model::{FittedModel, ScRbModel};
use scrb::stream::{fit_streaming, CsvChunks, LibsvmChunks, StreamOpts};
use std::fmt::Write as _;

/// Serialize a dataset as LibSVM text (1-based indices, exact `{}` f64
/// round-trip formatting, zeros omitted — the sparse shape).
fn to_libsvm(ds: &Dataset) -> Vec<u8> {
    let mut s = String::new();
    for i in 0..ds.n() {
        write!(s, "{}", ds.y[i] as i64).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(s, " {}:{v}", j + 1).unwrap();
            }
        }
        s.push('\n');
    }
    s.into_bytes()
}

/// Serialize a dataset as dense CSV text (`label,v1,...,vd`).
fn to_csv(ds: &Dataset) -> Vec<u8> {
    let mut s = String::new();
    for i in 0..ds.n() {
        write!(s, "{}", ds.y[i] as i64).unwrap();
        for &v in ds.x.row(i) {
            write!(s, ",{v}").unwrap();
        }
        s.push('\n');
    }
    s.into_bytes()
}

fn test_cfg(k: usize, r: usize, sigma: f64) -> PipelineConfig {
    PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .engine(Engine::Native)
        .kmeans_replicates(3)
        .seed(42)
        .build()
}

/// In-memory reference flow — exactly what `scrb fit --data f.libsvm`
/// does: parse, normalize by the training stats, fit, store the frame.
/// Returns the model's serialized bytes (via the same `save` path the CLI
/// uses) and the training labels.
fn fit_in_memory(bytes: &[u8], cfg: &PipelineConfig) -> (Vec<u8>, Vec<usize>) {
    let mut ds = parse_libsvm(std::io::Cursor::new(bytes), "t").unwrap();
    let (lo, span) = ds.minmax_params();
    ds.apply_minmax(&lo, &span);
    let fitted = sc_rb::fit(&Env::new(cfg.clone()), &ds.x).unwrap();
    let labels = fitted.output.labels;
    let mut model = fitted.model;
    model.set_input_norm(lo, span);
    let path = temp_path("inmem_ref");
    model.save(&path).unwrap();
    let model_bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    (model_bytes, labels)
}

fn temp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("scrb_stream_test_{tag}_{}.bin", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn streamed_fit_is_bit_identical_to_in_memory_fit() {
    let ds = synth::gaussian_blobs(240, 3, 3, 8.0, 5);
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(3, 32, 0.6);
    let (ref_bytes, ref_labels) = fit_in_memory(&bytes, &cfg);

    let mut reader = LibsvmChunks::from_bytes(bytes.clone(), 37);
    let opts = StreamOpts { k: Some(3), block_rows: 64, ..StreamOpts::default() };
    let fit = fit_streaming(&Env::new(cfg.clone()), &mut reader, &opts).unwrap();
    assert_eq!(fit.n, 240);
    assert_eq!(fit.d, 3);
    assert_eq!(fit.k_true, 3);
    assert_eq!(fit.output.labels, ref_labels, "training labels must match the batch fit");
    assert_eq!(fit.y, ds.y, "ground-truth labels must round-trip through the stream");
    assert_eq!(
        fit.model.to_bytes(),
        ref_bytes,
        "streamed model must serialize byte-identically to the in-memory fit"
    );
}

#[test]
fn streamed_fit_bit_identical_with_lanczos_too() {
    let ds = synth::gaussian_blobs(150, 2, 2, 8.0, 11);
    let bytes = to_libsvm(&ds);
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(16)
        .kernel(Kernel::Laplacian { sigma: 0.5 })
        .solver(scrb::config::Solver::Lanczos)
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .seed(7)
        .build();
    let (ref_bytes, ref_labels) = fit_in_memory(&bytes, &cfg);
    let mut reader = LibsvmChunks::from_bytes(bytes, 16);
    let opts = StreamOpts { k: Some(2), block_rows: 50, ..StreamOpts::default() };
    let fit = fit_streaming(&Env::new(cfg), &mut reader, &opts).unwrap();
    assert_eq!(fit.output.labels, ref_labels);
    assert_eq!(fit.model.to_bytes(), ref_bytes);
}

#[test]
fn streamed_fit_is_invariant_to_chunk_size() {
    let ds = synth::gaussian_blobs(130, 3, 2, 8.0, 9);
    let n = ds.n();
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(2, 16, 0.5);
    let opts = StreamOpts { k: Some(2), block_rows: 41, ..StreamOpts::default() };
    let reference = {
        let mut reader = LibsvmChunks::from_bytes(bytes.clone(), n);
        fit_streaming(&Env::new(cfg.clone()), &mut reader, &opts).unwrap()
    };
    for chunk_rows in [1usize, 7, 64] {
        let mut reader = LibsvmChunks::from_bytes(bytes.clone(), chunk_rows);
        let fit = fit_streaming(&Env::new(cfg.clone()), &mut reader, &opts).unwrap();
        assert_eq!(
            fit.model.to_bytes(),
            reference.model.to_bytes(),
            "model must not depend on chunk_rows={chunk_rows}"
        );
        assert_eq!(fit.output.labels, reference.output.labels);
        assert_eq!(fit.output.info.kappa, reference.output.info.kappa);
        assert_eq!(fit.output.info.feature_dim, reference.output.info.feature_dim);
    }
}

#[test]
fn streamed_model_serves_and_roundtrips() {
    let ds = synth::gaussian_blobs(160, 3, 3, 8.0, 13);
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(3, 24, 0.6);
    let mut reader = LibsvmChunks::from_bytes(bytes.clone(), 50);
    let fit = fit_streaming(
        &Env::new(cfg),
        &mut reader,
        &StreamOpts { k: Some(3), block_rows: 64, ..StreamOpts::default() },
    )
    .unwrap();
    // training-set predict reproduces fit labels bit-exactly: bring the
    // raw file back into the fitted frame (what `scrb predict` does)
    let mut raw = parse_libsvm(std::io::Cursor::new(&bytes[..]), "t").unwrap();
    fit.model.apply_input_norm(&mut raw.x);
    let predicted = fit.model.predict(&raw.x).unwrap();
    assert_eq!(predicted, fit.output.labels);
    // save → load → identical serving
    let path = temp_path("roundtrip");
    fit.model.save(&path).unwrap();
    let back = ScRbModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.to_bytes(), fit.model.to_bytes());
    assert_eq!(back.predict(&raw.x).unwrap(), predicted);
}

#[test]
fn coordinator_streams_from_disk() {
    let ds = synth::gaussian_blobs(120, 2, 2, 8.0, 17);
    let bytes = to_libsvm(&ds);
    let path = temp_path("coord");
    std::fs::write(&path, &bytes).unwrap();
    let cfg = test_cfg(2, 16, 0.5);
    let coord = Coordinator::new(cfg.clone(), 1);
    // file-backed fit (exercises the seek-rewind between passes)
    let from_disk = coord
        .fit_streaming(
            &path,
            33,
            0.5,
            StreamOpts { k: Some(2), block_rows: 64, ..StreamOpts::default() },
        )
        .unwrap();
    std::fs::remove_file(&path).ok();
    // must equal the in-memory-bytes streamed fit bit for bit
    let mut reader = LibsvmChunks::from_bytes(bytes, 33);
    let opts = StreamOpts { k: Some(2), block_rows: 64, ..StreamOpts::default() };
    let from_mem = fit_streaming(&Env::new(cfg), &mut reader, &opts).unwrap();
    assert_eq!(from_disk.model.to_bytes(), from_mem.model.to_bytes());
    assert_eq!(from_disk.output.labels, from_mem.output.labels);
}

#[test]
fn minibatch_path_engages_for_huge_n() {
    let ds = synth::gaussian_blobs(300, 2, 3, 10.0, 19);
    let bytes = to_libsvm(&ds);
    // the streamed fit normalizes into the unit box, so the bandwidth is
    // chosen for [0,1]-scale coordinates
    let cfg = test_cfg(3, 16, 0.2);
    let mut reader = LibsvmChunks::from_bytes(bytes, 64);
    // threshold 0 ⇒ the mini-batch K-means path runs
    let fit = fit_streaming(
        &Env::new(cfg),
        &mut reader,
        &StreamOpts {
            k: Some(3),
            block_rows: 128,
            minibatch_threshold: 0,
            minibatch_size: 100,
            ..StreamOpts::default()
        },
    )
    .unwrap();
    let acc = scrb::metrics::accuracy(&fit.output.labels, &fit.y);
    assert!(acc > 0.9, "mini-batch streamed SC_RB accuracy: {acc}");
}

#[test]
fn csv_backend_matches_libsvm_backend() {
    let ds = synth::gaussian_blobs(90, 3, 2, 8.0, 23);
    let cfg = test_cfg(2, 16, 0.5);
    let opts = StreamOpts { k: Some(2), block_rows: 32, ..StreamOpts::default() };
    let mut lib = LibsvmChunks::from_bytes(to_libsvm(&ds), 20);
    let a = fit_streaming(&Env::new(cfg.clone()), &mut lib, &opts).unwrap();
    let mut csv = CsvChunks::from_bytes(to_csv(&ds), 20);
    let b = fit_streaming(&Env::new(cfg), &mut csv, &opts).unwrap();
    assert_eq!(a.model.to_bytes(), b.model.to_bytes());
    assert_eq!(a.output.labels, b.output.labels);
    assert_eq!(a.y, b.y);
}

#[test]
fn streamed_fit_error_paths() {
    let cfg = test_cfg(2, 8, 0.5);
    // empty stream
    let mut empty = LibsvmChunks::from_bytes(Vec::new(), 8);
    assert!(fit_streaming(&Env::new(cfg.clone()), &mut empty, &StreamOpts::default()).is_err());
    // malformed line surfaces as a typed parse error
    let mut bad = LibsvmChunks::from_bytes(b"1 nocolon\n".to_vec(), 8);
    assert!(fit_streaming(&Env::new(cfg.clone()), &mut bad, &StreamOpts::default()).is_err());
    // k = 0 rejected
    let ds = synth::gaussian_blobs(30, 2, 2, 8.0, 3);
    let mut r = LibsvmChunks::from_bytes(to_libsvm(&ds), 8);
    let opts = StreamOpts { k: Some(0), ..StreamOpts::default() };
    assert!(fit_streaming(&Env::new(cfg), &mut r, &opts).is_err());
    // missing file is a clean io error
    assert!(LibsvmChunks::from_path("/no/such/file.libsvm", 8).is_err());
    // degenerate streaming knobs are typed errors at the coordinator API
    let coord = Coordinator::new(test_cfg(2, 8, 0.5), 1);
    let with_blocks =
        |block_rows: usize| StreamOpts { block_rows, ..StreamOpts::default() };
    assert!(coord.fit_streaming("/no/such.libsvm", 0, 0.5, with_blocks(64)).is_err());
    assert!(coord.fit_streaming("/no/such.libsvm", 8, 0.5, with_blocks(0)).is_err());
    assert!(coord.fit_streaming("/no/such.libsvm", 8, -1.0, with_blocks(64)).is_err());
}
