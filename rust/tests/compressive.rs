//! Integration contract of the compressive solver (ISSUE 9 acceptance):
//!
//! - end-to-end SC_RB quality with `--solver compressive` stays within
//!   0.05 NMI of the Davidson reference on the same data and seed;
//! - the compressive embed path runs on the block substrate (streamed
//!   fits) and is invariant to the chunk/block layout;
//! - the compressive core — filter, Rayleigh–Ritz, Tikhonov
//!   interpolation — is **bit-identical across thread counts**, verified
//!   by respawning this test binary under different `SCRB_THREADS`
//!   (thread count is resolved once per process, so in-process toggling
//!   cannot exercise it). The signals are drawn once up front and the
//!   fused gram kernel accumulates in a fixed order regardless of
//!   partitioning, which is what makes this a guarantee rather than a
//!   probability. The k-means stages are deliberately outside the hash:
//!   their centroid partial sums are grouped by worker count, so their
//!   floating-point association — unlike the compressive core — is
//!   thread-count-dependent.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, Solver};
use scrb::data::synth;
use scrb::eigen::compressive::{sample_rows, tikhonov_interpolate};
use scrb::eigen::{compressive_svd_ws, CompressiveOpts, SolverWorkspace};
use scrb::metrics::all_metrics;
use scrb::rb::rb_features;
use scrb::stream::{fit_streaming, LibsvmChunks, StreamOpts};
use std::fmt::Write as _;
use std::process::Command;

fn base_cfg(k: usize, r: usize, solver: Solver) -> PipelineConfig {
    PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma: 0.25 })
        .engine(Engine::Native)
        .solver(solver)
        .seed(42)
        .build()
}

/// The acceptance pin: on the pendigits stand-in, the compressive path
/// must land within 0.05 NMI of the Davidson reference fit.
#[test]
fn compressive_nmi_within_pin_of_davidson() {
    let ds = synth::paper_benchmark("pendigits", 16, 42);
    let mut nmi = [0.0f64; 2];
    for (slot, solver) in [Solver::Davidson, Solver::Compressive].into_iter().enumerate() {
        let env = Env::new(base_cfg(ds.k, 64, solver));
        let fitted = MethodKind::ScRb.fit(&env, &ds.x).expect("fit failed");
        nmi[slot] = all_metrics(&fitted.output.labels, &ds.y).nmi;
    }
    let (davidson, compressive) = (nmi[0], nmi[1]);
    assert!(davidson > 0.5, "davidson reference degenerated: nmi={davidson:.3}");
    assert!(
        compressive >= davidson - 0.05,
        "compressive nmi {compressive:.3} fell more than 0.05 below davidson {davidson:.3}"
    );
}

/// Streamed fits featurize into `BlockEllRb`, so this exercises the
/// compressive embed on the block substrate — and because every block
/// kernel reproduces the monolithic result bit for bit, the labels must
/// not depend on the chunk/block layout at all.
#[test]
fn streamed_compressive_is_chunk_layout_invariant() {
    let ds = synth::gaussian_blobs(600, 4, 3, 8.0, 11);
    let mut text = String::new();
    for i in 0..ds.n() {
        write!(text, "{}", ds.y[i]).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(text, " {}:{v}", j + 1).unwrap();
            }
        }
        text.push('\n');
    }
    let bytes = text.into_bytes();
    let cfg = base_cfg(3, 64, Solver::Compressive);
    let mut labels_by_chunk = Vec::new();
    for chunk_rows in [96usize, 512] {
        let mut reader = LibsvmChunks::from_bytes(bytes.clone(), chunk_rows);
        let streamed = fit_streaming(
            &Env::new(cfg.clone()),
            &mut reader,
            &StreamOpts { k: Some(3), ..StreamOpts::default() },
        )
        .expect("streamed compressive fit failed");
        let m = all_metrics(&streamed.output.labels, &streamed.y);
        assert!(m.accuracy > 0.9, "chunk_rows={chunk_rows}: acc={:.3}", m.accuracy);
        labels_by_chunk.push(streamed.output.labels.clone());
    }
    assert_eq!(
        labels_by_chunk[0], labels_by_chunk[1],
        "labels changed with the chunk/block layout"
    );
}

const CHILD_ENV: &str = "SCRB_COMPRESSIVE_CHILD";
const HASH_PREFIX: &str = "COMPRESSIVE_HASH ";

fn fnv1a64(acc: u64, word: u64) -> u64 {
    let mut h = acc;
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Child half of the cross-thread-count determinism test: runs the full
/// compressive core on a fixed seed and prints one hash line. A no-op
/// under a normal `cargo test` run (the parent sets `CHILD_ENV` when
/// respawning).
#[test]
fn child_emits_compressive_hash() {
    if std::env::var(CHILD_ENV).is_err() {
        return;
    }
    let ds = synth::gaussian_blobs(400, 4, 3, 6.0, 13);
    let mut zhat = rb_features(&ds.x, 48, 0.3, 7).z;
    let deg = zhat.implicit_degrees();
    zhat.normalize_by_degree(&deg);
    let n = zhat.rows;

    let mut opts = CompressiveOpts::new(3);
    opts.order = 20;
    opts.signals = Some(8);
    let mut ws = SolverWorkspace::new();
    let res = compressive_svd_ws(&zhat, &opts, 5, &mut ws);

    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &s in &res.s {
        h = fnv1a64(h, s.to_bits());
    }
    for &v in &res.u.data {
        h = fnv1a64(h, v.to_bits());
    }
    h = fnv1a64(h, res.stats.matvecs as u64);

    // Interpolate deterministic sample labels (no k-means in the loop —
    // see the module docs) and fold the interpolated labels in too.
    let mut idx = Vec::new();
    sample_rows(n, 80, 99, &mut idx);
    let labs: Vec<u32> = (0..idx.len()).map(|i| (i % 3) as u32).collect();
    let lmax = res.s[0] * res.s[0] * 1.05;
    let (scores, _mv) = tikhonov_interpolate(&zhat, &idx, &labs, 3, lmax, 0.1, 1e-8, 20, &mut ws);
    for i in 0..n {
        let row = scores.row(i);
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        h = fnv1a64(h, best as u64);
    }
    println!("{HASH_PREFIX}{h:016x}");
}

/// Respawn this test binary under `SCRB_THREADS` 1 and 3 and demand the
/// child hashes — singular values, embedding bits, matvec count, and
/// interpolated labels — agree exactly.
#[test]
fn compressive_core_is_bit_identical_across_thread_counts() {
    let exe = std::env::current_exe().expect("test binary path");
    let mut hashes = Vec::new();
    for nt in ["1", "3"] {
        let out = Command::new(&exe)
            .args(["child_emits_compressive_hash", "--exact", "--nocapture", "--test-threads", "1"])
            .env(CHILD_ENV, "1")
            .env("SCRB_THREADS", nt)
            .output()
            .expect("respawn test binary");
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        assert!(out.status.success(), "child (SCRB_THREADS={nt}) failed:\n{stdout}");
        let hash = stdout
            .lines()
            .find_map(|l| l.strip_prefix(HASH_PREFIX))
            .unwrap_or_else(|| panic!("no hash line from child (SCRB_THREADS={nt}):\n{stdout}"))
            .to_string();
        hashes.push(hash);
    }
    assert_eq!(hashes[0], hashes[1], "compressive core drifted across thread counts");
}
