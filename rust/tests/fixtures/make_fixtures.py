#!/usr/bin/env python3
"""Generate the committed SCRBMODL v1/v2 fixture files.

The version-compat contract ("old model files keep loading") is only
testable against images whose bytes are *frozen* — re-deriving them from
the current writer would test nothing (the writer only emits the current
version). This script is the provenance of `model_v1.scrb` and
`model_v2.scrb`: a tiny but loader-valid model laid out by hand,
byte-compatible with the v1/v2 readers:

  v1: no checksum footer, no update trailer
  v2: FNV-1a 64 footer over the payload, no update trailer

Layout (little-endian; see rust/src/model/scrb.rs):
  magic "SCRBMODL" | u32 version | u8 ktag | f64 ksigma | u64 seed |
  u32 r | u32 d_in | u64 dim | u32 k_embed | u32 k_clusters |
  f64 cb_sigma | u8 norm_tag (0) | f64 s[k_embed] |
  r × (f64 widths[d_in], f64 biases[d_in]) |
  r × (u32 n, n × (u64 hash, u32 col)) |
  f64 proj[dim × k_embed] | f64 centroids[k_clusters × k_embed]

Run from this directory: python3 make_fixtures.py
"""

import struct
from pathlib import Path

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x00000100000001B3


def fnv64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def payload(version: int) -> bytes:
    out = bytearray()
    out += b"SCRBMODL"
    out += struct.pack("<I", version)
    out += struct.pack("<B", 0)  # kernel tag: laplacian
    out += struct.pack("<d", 0.5)  # kernel sigma
    out += struct.pack("<Q", 7)  # codebook seed
    out += struct.pack("<I", 2)  # r (grids)
    out += struct.pack("<I", 2)  # d_in
    out += struct.pack("<Q", 4)  # dim (global bins)
    out += struct.pack("<I", 2)  # k_embed
    out += struct.pack("<I", 2)  # k_clusters
    out += struct.pack("<d", 0.5)  # codebook sigma
    out += struct.pack("<B", 0)  # no input normalization
    out += struct.pack("<2d", 1.0, 0.5)  # singular values, descending
    # grids: widths must be positive/finite, biases finite
    for bias in (0.1, 0.2):
        out += struct.pack("<2d", 0.7, 0.7)  # widths
        out += struct.pack("<2d", bias, bias / 2)  # biases
    # bin tables: columns partition 0..dim, ascending per table
    for cols in ((0, 1), (2, 3)):
        out += struct.pack("<I", len(cols))
        for col in cols:
            out += struct.pack("<QI", 0x1000 + 7 * col, col)
    # projection rows (dim × k_embed) and centroids (k_clusters × k_embed)
    out += struct.pack("<8d", 0.5, 0.1, -0.2, 0.4, 0.3, -0.1, 0.0, 0.25)
    out += struct.pack("<4d", 0.9, 0.1, -0.1, 0.8)
    return bytes(out)


def main() -> None:
    here = Path(__file__).resolve().parent
    v1 = payload(1)
    (here / "model_v1.scrb").write_bytes(v1)
    v2 = payload(2)
    (here / "model_v2.scrb").write_bytes(v2 + struct.pack("<Q", fnv64(v2)))
    print(f"model_v1.scrb: {len(v1)} bytes")
    print(f"model_v2.scrb: {len(v2) + 8} bytes")


if __name__ == "__main__":
    main()
