//! Fault-tolerance integration tests (ISSUE 6 acceptance): the ingest
//! stack under injected faults, end to end through `fit_streaming`.
//!
//! - **Quarantine exactness**: a fit over corrupted text with
//!   `--on-bad-record quarantine` skips exactly the corrupted rows and
//!   produces the **byte-identical** model of a fit over the clean
//!   subset; strict mode surfaces the first offender as a located
//!   `ScrbError::BadRecord`.
//! - **Transient invisibility**: injected transient I/O errors are
//!   absorbed by the bounded-retry layer without changing a single model
//!   byte; exhausted retries surface with the attempt count.
//! - **Kill and resume**: a fit killed mid-featurize by an injected
//!   permanent failure resumes from its checkpoint directory and produces
//!   the byte-identical model of an uninterrupted fit — including under
//!   simultaneous quarantine + transient faults, and resuming with
//!   different parameters is a typed `ScrbError::Checkpoint`.
//! - **Model integrity**: any truncation or byte flip of a saved `.scrb`
//!   image is a typed `ScrbError::Model`, never a panic or a
//!   silently-wrong model.
//!
//! The injection seed is `SCRB_FAULT_SEED` (default 42); CI sweeps
//! several values.

use scrb::cluster::{sc_rb, Env};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::{synth, Dataset};
use scrb::error::ScrbError;
use scrb::model::{FittedModel as _, ScRbModel};
use scrb::stream::{
    corrupt_libsvm_text, corrupt_model_bytes, fit_streaming, CheckpointCfg, FaultPlan,
    FaultyReader, IngestPolicy, LibsvmChunks, OnBadRecord, StreamOpts,
};
use std::fmt::Write as _;

/// Injection seed: `SCRB_FAULT_SEED` env var, default 42. CI runs the
/// suite at several values; the properties below must hold for all of
/// them.
fn fault_seed() -> u64 {
    std::env::var("SCRB_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn to_libsvm(ds: &Dataset) -> Vec<u8> {
    let mut s = String::new();
    for i in 0..ds.n() {
        write!(s, "{}", ds.y[i] as i64).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(s, " {}:{v}", j + 1).unwrap();
            }
        }
        s.push('\n');
    }
    s.into_bytes()
}

fn test_cfg(k: usize, r: usize, sigma: f64) -> PipelineConfig {
    PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .engine(Engine::Native)
        .kmeans_replicates(2)
        .seed(42)
        .build()
}

/// Streaming-fit knobs shared by the tests: no retry sleeps.
fn base_opts(k: usize, block_rows: usize) -> StreamOpts {
    StreamOpts {
        k: Some(k),
        block_rows,
        policy: IngestPolicy { retry_backoff_ms: 0, ..IngestPolicy::default() },
        ..StreamOpts::default()
    }
}

fn quarantine_opts(k: usize, block_rows: usize) -> StreamOpts {
    let mut opts = base_opts(k, block_rows);
    opts.policy.on_bad_record = OnBadRecord::Quarantine;
    opts
}

fn tmpdir(tag: &str) -> String {
    let dir = std::env::temp_dir()
        .join(format!("scrb_faults_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The original text minus the lines `corrupt_libsvm_text` replaced: what
/// a quarantined fit of the dirty text must be exactly equivalent to.
fn drop_lines(bytes: &[u8], dropped: &[usize]) -> Vec<u8> {
    let text = std::str::from_utf8(bytes).unwrap();
    let mut out = String::new();
    for (i, line) in text.lines().enumerate() {
        if !dropped.contains(&i) {
            out.push_str(line);
            out.push('\n');
        }
    }
    out.into_bytes()
}

#[test]
fn quarantined_fit_equals_the_clean_subset_fit() {
    let ds = synth::gaussian_blobs(220, 3, 3, 8.0, 5);
    let bytes = to_libsvm(&ds);
    let (dirty, replaced) = corrupt_libsvm_text(&bytes, fault_seed(), 120);
    assert!(!replaced.is_empty(), "the corruption plan must replace some lines");
    let cfg = test_cfg(3, 24, 0.6);

    let opts = quarantine_opts(3, 64);
    let mut dirty_reader = LibsvmChunks::from_bytes(dirty, 37);
    let fit_q = fit_streaming(&Env::new(cfg.clone()), &mut dirty_reader, &opts).unwrap();

    // exact counts, capped samples, full source context on every sample
    assert_eq!(fit_q.quarantine.skipped(), replaced.len(), "counts are exact");
    assert_eq!(fit_q.n, 220 - replaced.len());
    assert!(!fit_q.quarantine.samples.is_empty());
    assert!(fit_q.quarantine.samples.len() <= opts.policy.sample_cap);
    assert_eq!(fit_q.quarantine.samples[0].line, replaced[0] + 1);
    for s in &fit_q.quarantine.samples {
        assert_eq!(s.file, "<memory>");
        assert!(s.line >= 1);
    }

    // skipping the bad rows is *exactly* dropping them: byte-identical to
    // a strict fit on the clean subset
    let mut clean_reader = LibsvmChunks::from_bytes(drop_lines(&bytes, &replaced), 37);
    let fit_c = fit_streaming(&Env::new(cfg), &mut clean_reader, &base_opts(3, 64)).unwrap();
    assert_eq!(fit_c.quarantine.skipped(), 0);
    assert_eq!(
        fit_q.model.to_bytes(),
        fit_c.model.to_bytes(),
        "quarantined fit must equal the clean-subset fit byte for byte"
    );
    assert_eq!(fit_q.output.labels, fit_c.output.labels);
    assert_eq!(fit_q.y, fit_c.y);
}

#[test]
fn strict_mode_surfaces_the_first_offender_with_location() {
    let ds = synth::gaussian_blobs(100, 2, 2, 8.0, 9);
    let bytes = to_libsvm(&ds);
    let (dirty, replaced) = corrupt_libsvm_text(&bytes, fault_seed(), 150);
    assert!(!replaced.is_empty());
    // expected byte offset of the first corrupted line's start
    let text = std::str::from_utf8(&dirty).unwrap();
    let mut byte = 0u64;
    for (i, line) in text.lines().enumerate() {
        if i == replaced[0] {
            break;
        }
        byte += line.len() as u64 + 1;
    }

    let cfg = test_cfg(2, 16, 0.5);
    let mut reader = LibsvmChunks::from_bytes(dirty, 16);
    let err = fit_streaming(&Env::new(cfg), &mut reader, &base_opts(2, 32)).unwrap_err();
    let ScrbError::BadRecord(rec) = err else { panic!("expected BadRecord, got {err}") };
    assert_eq!(rec.file, "<memory>");
    assert_eq!(rec.line, replaced[0] + 1, "1-based line of the first corrupted row");
    assert_eq!(rec.byte, byte, "byte offset of the offending line's start");
    assert!(!rec.token.is_empty());
}

#[test]
fn injected_transients_are_byte_invisible_after_retry() {
    let ds = synth::gaussian_blobs(150, 3, 3, 8.0, 13);
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(3, 16, 0.6);
    let reference = {
        let mut r = LibsvmChunks::from_bytes(bytes.clone(), 29);
        fit_streaming(&Env::new(cfg.clone()), &mut r, &base_opts(3, 64)).unwrap()
    };

    // every next_chunk call fails exactly once before succeeding
    let mut inner = LibsvmChunks::from_bytes(bytes, 29);
    let plan =
        FaultPlan { seed: fault_seed(), transient_permille: 1000, ..FaultPlan::default() };
    let mut faulty = FaultyReader::new(&mut inner, plan);
    let fit = fit_streaming(&Env::new(cfg), &mut faulty, &base_opts(3, 64)).unwrap();
    assert!(fit.quarantine.retries > 0, "the retry layer must have absorbed faults");
    assert_eq!(fit.quarantine.skipped(), 0);
    assert_eq!(
        fit.model.to_bytes(),
        reference.model.to_bytes(),
        "absorbed transients must not change a single model byte"
    );
    assert_eq!(fit.output.labels, reference.output.labels);
}

#[test]
fn exhausted_retries_surface_with_the_attempt_count() {
    let ds = synth::gaussian_blobs(60, 2, 2, 8.0, 3);
    let mut inner = LibsvmChunks::from_bytes(to_libsvm(&ds), 16);
    // a permanent failure from the first stats-pass read
    let plan = FaultPlan { seed: fault_seed(), fail_at: Some((0, 0)), ..FaultPlan::default() };
    let mut faulty = FaultyReader::new(&mut inner, plan);
    let mut opts = base_opts(2, 32);
    opts.policy.max_retries = 2;
    let err = fit_streaming(&Env::new(test_cfg(2, 8, 0.5)), &mut faulty, &opts).unwrap_err();
    match err {
        ScrbError::Transient { attempts, .. } => {
            assert_eq!(attempts, 3, "max_retries + the final failing attempt")
        }
        other => panic!("expected Transient, got {other}"),
    }
}

#[test]
fn kill_and_resume_reproduces_the_uninterrupted_fit() {
    let ds = synth::gaussian_blobs(200, 3, 3, 8.0, 7);
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(3, 16, 0.6);
    let reference = {
        let mut r = LibsvmChunks::from_bytes(bytes.clone(), 16);
        fit_streaming(&Env::new(cfg.clone()), &mut r, &base_opts(3, 32)).unwrap()
    };

    let dir = tmpdir("resume");
    let ckpt = |resume: bool| CheckpointCfg {
        every_rows: 48,
        resume,
        ..CheckpointCfg::new(dir.clone())
    };

    // run 1: killed mid-featurize (pass 1) once 120 rows have streamed
    let killed = {
        let mut inner = LibsvmChunks::from_bytes(bytes.clone(), 16);
        let plan =
            FaultPlan { seed: fault_seed(), fail_at: Some((1, 120)), ..FaultPlan::default() };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        let opts = StreamOpts { checkpoint: Some(ckpt(false)), ..base_opts(3, 32) };
        fit_streaming(&Env::new(cfg.clone()), &mut faulty, &opts)
    };
    assert!(matches!(killed.unwrap_err(), ScrbError::Transient { .. }));
    let d = std::path::Path::new(&dir);
    assert!(d.join("stats.bin").exists(), "pass-1 stats persisted before the kill");
    assert!(d.join("state.bin").exists(), "mid-pass state persisted before the kill");

    // run 2: fresh "process", fault gone, --resume
    let resumed = {
        let mut r = LibsvmChunks::from_bytes(bytes.clone(), 16);
        let opts = StreamOpts { checkpoint: Some(ckpt(true)), ..base_opts(3, 32) };
        fit_streaming(&Env::new(cfg.clone()), &mut r, &opts).unwrap()
    };
    assert_eq!(
        resumed.model.to_bytes(),
        reference.model.to_bytes(),
        "resumed fit must serialize byte-identically to the uninterrupted fit"
    );
    assert_eq!(resumed.output.labels, reference.output.labels);
    assert_eq!(resumed.y, reference.y);

    // resuming under different fit parameters is a typed checkpoint error
    let err = {
        let mut r = LibsvmChunks::from_bytes(bytes, 16);
        let opts = StreamOpts { checkpoint: Some(ckpt(true)), ..base_opts(3, 32) };
        fit_streaming(&Env::new(test_cfg(3, 16, 0.9)), &mut r, &opts).unwrap_err()
    };
    assert!(matches!(err, ScrbError::Checkpoint(_)), "got {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_and_resume_stays_byte_identical_under_quarantine_and_transients() {
    let ds = synth::gaussian_blobs(200, 3, 3, 8.0, 11);
    let (dirty, replaced) = corrupt_libsvm_text(&to_libsvm(&ds), fault_seed(), 100);
    assert!(!replaced.is_empty());
    let cfg = test_cfg(3, 16, 0.6);

    // uninterrupted reference: quarantine policy, no faults, no checkpoint
    let reference = {
        let mut r = LibsvmChunks::from_bytes(dirty.clone(), 14);
        fit_streaming(&Env::new(cfg.clone()), &mut r, &quarantine_opts(3, 32)).unwrap()
    };
    assert_eq!(reference.quarantine.skipped(), replaced.len());

    let dir = tmpdir("resume_faulty");
    let ckpt = |resume: bool| CheckpointCfg {
        every_rows: 40,
        resume,
        ..CheckpointCfg::new(dir.clone())
    };

    // run 1: transient faults throughout, killed mid-featurize
    let killed = {
        let mut inner = LibsvmChunks::from_bytes(dirty.clone(), 14);
        let plan = FaultPlan {
            seed: fault_seed(),
            transient_permille: 300,
            fail_at: Some((1, 120)),
            ..FaultPlan::default()
        };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        let opts = StreamOpts { checkpoint: Some(ckpt(false)), ..quarantine_opts(3, 32) };
        fit_streaming(&Env::new(cfg.clone()), &mut faulty, &opts)
    };
    assert!(matches!(killed.unwrap_err(), ScrbError::Transient { .. }));
    assert!(std::path::Path::new(&dir).join("state.bin").exists());

    // run 2: resume with the kill gone but transient faults still firing
    let resumed = {
        let mut inner = LibsvmChunks::from_bytes(dirty, 14);
        let plan =
            FaultPlan { seed: fault_seed(), transient_permille: 300, ..FaultPlan::default() };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        let opts = StreamOpts { checkpoint: Some(ckpt(true)), ..quarantine_opts(3, 32) };
        fit_streaming(&Env::new(cfg), &mut faulty, &opts).unwrap()
    };
    assert_eq!(resumed.quarantine.skipped(), replaced.len(), "per-pass skips stay exact");
    assert_eq!(
        resumed.model.to_bytes(),
        reference.model.to_bytes(),
        "resume under quarantine + transient faults must stay byte-identical"
    );
    assert_eq!(resumed.output.labels, reference.output.labels);
    assert_eq!(resumed.y, reference.y);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn model_corruption_is_always_a_typed_error() {
    // a small real model keeps the exhaustive position sweeps fast
    let ds = synth::gaussian_blobs(40, 2, 2, 8.0, 3);
    let fitted = sc_rb::fit(&Env::new(test_cfg(2, 4, 0.5)), &ds.x).unwrap();
    let model = fitted.model.into_any().downcast::<ScRbModel>().ok().unwrap();
    let bytes = model.to_bytes();
    assert!(ScRbModel::from_bytes(&bytes).is_ok());

    // every truncation length
    for cut in 0..bytes.len() {
        match ScRbModel::from_bytes(&bytes[..cut]) {
            Err(ScrbError::Model(_)) => {}
            Err(other) => panic!("cut at {cut}: expected Model error, got {other}"),
            Ok(_) => panic!("cut at {cut} loaded"),
        }
    }
    // a single-bit flip at every byte position (bit chosen by position)
    for pos in 0..bytes.len() {
        let mut b = bytes.clone();
        b[pos] ^= 1 << (pos % 8);
        match ScRbModel::from_bytes(&b) {
            Err(ScrbError::Model(_)) => {}
            Err(other) => panic!("flip at {pos}: expected Model error, got {other}"),
            Ok(_) => panic!("flip at {pos} loaded"),
        }
    }
    // seeded structured corruptions: flips, overwrites, truncations
    let seed = fault_seed();
    for i in 0..200u64 {
        let b = corrupt_model_bytes(&bytes, seed.wrapping_add(i));
        assert_ne!(b, bytes, "corrupter must change the image (seed {i})");
        match ScRbModel::from_bytes(&b) {
            Err(ScrbError::Model(_)) => {}
            Err(other) => panic!("seed {i}: expected Model error, got {other}"),
            Ok(_) => panic!("seed {i}: corrupted image loaded"),
        }
    }
}

#[test]
fn drift_monitor_counts_unseen_bins_on_streamed_models() {
    let ds = synth::gaussian_blobs(120, 3, 2, 8.0, 17);
    let bytes = to_libsvm(&ds);
    let cfg = test_cfg(2, 16, 0.5);
    let mut reader = LibsvmChunks::from_bytes(bytes, 30);
    let fit = fit_streaming(&Env::new(cfg), &mut reader, &base_opts(2, 64)).unwrap();
    // far off the training distribution: every grid lookup misses
    let far = scrb::linalg::Mat::from_vec(2, 3, vec![1.0e3; 6]);
    fit.model.predict(&far).unwrap();
    let stats = fit.model.drift_stats();
    assert_eq!(stats.points, 2);
    assert!(stats.unseen > 0, "far-out points must miss the fit-time codebook");
    assert!(stats.rate() > 0.0);
}
