//! Sharded-featurization acceptance tests (ISSUE 8):
//!
//! - **byte-identity**: `fit_streaming_sharded` over K byte-range shards
//!   of one file — and over multi-file datasets — serializes the model
//!   **byte-identically** to the sequential `fit_streaming` over the same
//!   bytes, for K ∈ {1, 2, 3, 8}, including zero-row shards and shards
//!   with disjoint or fully-overlapping bin populations;
//! - the seeded-fault sweep (`SCRB_FAULT_SEED` ∈ {42, 7, 1234} in CI):
//!   quarantined rows land in different shards, yet the model bytes, the
//!   exact per-reason counts, and the deterministic sample order all
//!   match the sequential quarantined fit;
//! - per-shard transient faults retry transparently (counted in the
//!   merged report) without touching the fitted bytes;
//! - `--shards K > 1` plus checkpointing is a typed `Config` refusal,
//!   not a silently ignored flag.

use scrb::cluster::Env;
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::{synth, Dataset};
use scrb::shard::{ShardFormat, ShardPlanner};
use scrb::stream::{
    corrupt_libsvm_text, fit_streaming, fit_streaming_sharded, CheckpointCfg, ChunkReader,
    FaultPlan, FaultyReader, IngestPolicy, LibsvmChunks, OnBadRecord, StreamFit, StreamOpts,
};
use std::fmt::Write as _;

/// Injection seed: `SCRB_FAULT_SEED` env var, default 42. CI runs the
/// suite at several values; the properties below must hold for all of
/// them.
fn fault_seed() -> u64 {
    std::env::var("SCRB_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn to_libsvm(ds: &Dataset) -> Vec<u8> {
    let mut s = String::new();
    for i in 0..ds.n() {
        write!(s, "{}", ds.y[i] as i64).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(s, " {}:{v}", j + 1).unwrap();
            }
        }
        s.push('\n');
    }
    s.into_bytes()
}

fn test_cfg(k: usize, r: usize, sigma: f64) -> PipelineConfig {
    PipelineConfig::builder()
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .engine(Engine::Native)
        .kmeans_replicates(3)
        .seed(42)
        .build()
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("scrb_shard_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Sequential reference fit over `bytes`.
fn fit_sequential(bytes: &[u8], cfg: &PipelineConfig, opts: &StreamOpts) -> StreamFit {
    let mut reader = LibsvmChunks::from_bytes(bytes.to_vec(), 37);
    fit_streaming(&Env::new(cfg.clone()), &mut reader, opts).unwrap()
}

/// Sharded fit over `patterns` planned into `shards` shards.
fn fit_sharded(
    patterns: &[String],
    shards: usize,
    cfg: &PipelineConfig,
    opts: &StreamOpts,
) -> StreamFit {
    let plan = ShardPlanner::new(shards, 37, ShardFormat::Libsvm).plan(patterns).unwrap();
    let mut readers = ShardPlanner::open(&plan).unwrap();
    let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
        readers.iter_mut().map(|r| r.as_mut()).collect();
    fit_streaming_sharded(&Env::new(cfg.clone()), &mut refs, opts).unwrap()
}

fn assert_fits_equal(got: &StreamFit, want: &StreamFit, ctx: &str) {
    assert_eq!(got.n, want.n, "{ctx}: row count");
    assert_eq!(got.d, want.d, "{ctx}: dimensionality");
    assert_eq!(got.k_true, want.k_true, "{ctx}: class census");
    assert_eq!(got.y, want.y, "{ctx}: ground-truth labels");
    assert_eq!(got.output.labels, want.output.labels, "{ctx}: training labels");
    assert_eq!(
        got.model.to_bytes(),
        want.model.to_bytes(),
        "{ctx}: model bytes must be identical"
    );
}

#[test]
fn single_file_byte_range_shards_are_bit_identical_for_any_k() {
    let ds = synth::gaussian_blobs(240, 3, 3, 8.0, 5);
    let bytes = to_libsvm(&ds);
    let dir = tmpdir("single");
    let path = dir.join("data.libsvm").to_str().unwrap().to_string();
    std::fs::write(&path, &bytes).unwrap();

    let cfg = test_cfg(3, 32, 0.6);
    let opts = StreamOpts { k: Some(3), block_rows: 64, ..StreamOpts::default() };
    let want = fit_sequential(&bytes, &cfg, &opts);

    for k in [1usize, 2, 3, 8] {
        let got = fit_sharded(&[path.clone()], k, &cfg, &opts);
        assert_fits_equal(&got, &want, &format!("shards={k}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_file_and_glob_datasets_are_bit_identical_for_any_k() {
    // three files of uneven size — shard plans will chain and split
    // across file boundaries differently at each K
    let dir = tmpdir("multi");
    let mut all = Vec::new();
    for (f, n) in [(0usize, 110usize), (1, 40), (2, 90)] {
        let ds = synth::gaussian_blobs(n, 3, 3, 8.0, 5 + f as u64);
        let bytes = to_libsvm(&ds);
        all.extend_from_slice(&bytes);
        std::fs::write(dir.join(format!("part-{f}.libsvm")), &bytes).unwrap();
    }

    let cfg = test_cfg(3, 32, 0.6);
    let opts = StreamOpts { k: Some(3), block_rows: 64, ..StreamOpts::default() };
    let want = fit_sequential(&all, &cfg, &opts);

    let paths: Vec<String> = (0..3)
        .map(|f| dir.join(format!("part-{f}.libsvm")).to_str().unwrap().to_string())
        .collect();
    for k in [2usize, 3, 8] {
        let got = fit_sharded(&paths, k, &cfg, &opts);
        assert_fits_equal(&got, &want, &format!("multi-file shards={k}"));
    }
    // the same dataset named by a glob (expanded in sorted order)
    let glob = format!("{}/part-?.libsvm", dir.to_str().unwrap());
    let got = fit_sharded(&[glob], 3, &cfg, &opts);
    assert_fits_equal(&got, &want, "glob shards=3");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero_row_shards_and_empty_files_are_noops() {
    // 5 rows over 8 shards: most byte-range shards hold 0 or 1 rows
    let ds = synth::gaussian_blobs(5, 2, 2, 8.0, 9);
    let bytes = to_libsvm(&ds);
    let dir = tmpdir("tiny");
    let path = dir.join("tiny.libsvm").to_str().unwrap().to_string();
    std::fs::write(&path, &bytes).unwrap();

    let cfg = test_cfg(2, 16, 0.6);
    let opts = StreamOpts { k: Some(2), block_rows: 8, ..StreamOpts::default() };
    let want = fit_sequential(&bytes, &cfg, &opts);
    let got = fit_sharded(&[path.clone()], 8, &cfg, &opts);
    assert_fits_equal(&got, &want, "tiny file, shards=8");

    // a multi-file dataset with an empty member file
    let empty = dir.join("empty.libsvm").to_str().unwrap().to_string();
    std::fs::write(&empty, b"").unwrap();
    let got = fit_sharded(&[empty, path], 3, &cfg, &opts);
    assert_fits_equal(&got, &want, "empty member file, shards=3");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn disjoint_and_overlapping_bin_populations_merge_exactly() {
    // first half of the file lives in one corner of the cube, the second
    // half far away: front/back byte-range shards see *disjoint* bin
    // sets. Then a file whose second half repeats the first: every shard
    // sees the *same* bins. Both extremes must merge to the sequential
    // codebook bit-exactly.
    let ds = synth::gaussian_blobs(120, 3, 2, 40.0, 7);
    let disjoint = to_libsvm(&ds);
    let mut overlapping = to_libsvm(&ds);
    overlapping.extend_from_slice(&to_libsvm(&ds));

    let dir = tmpdir("bins");
    let cfg = test_cfg(2, 32, 0.6);
    let opts = StreamOpts { k: Some(2), block_rows: 32, ..StreamOpts::default() };
    for (tag, bytes) in [("disjoint", &disjoint), ("overlapping", &overlapping)] {
        let path = dir.join(format!("{tag}.libsvm")).to_str().unwrap().to_string();
        std::fs::write(&path, bytes).unwrap();
        let want = fit_sequential(bytes, &cfg, &opts);
        for k in [2usize, 3, 8] {
            let got = fit_sharded(&[path.clone()], k, &cfg, &opts);
            assert_fits_equal(&got, &want, &format!("{tag} shards={k}"));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quarantined_rows_across_shards_match_the_sequential_report() {
    let ds = synth::gaussian_blobs(360, 3, 3, 8.0, 13);
    let clean = to_libsvm(&ds);
    let (dirty, replaced) = corrupt_libsvm_text(&clean, fault_seed(), 25);
    assert!(!replaced.is_empty(), "the sweep needs at least one corrupt row");
    let dir = tmpdir("faults");
    let path = dir.join("dirty.libsvm").to_str().unwrap().to_string();
    std::fs::write(&path, &dirty).unwrap();

    let cfg = test_cfg(3, 32, 0.6);
    let opts = StreamOpts {
        k: Some(3),
        block_rows: 64,
        policy: IngestPolicy {
            on_bad_record: OnBadRecord::Quarantine,
            sample_cap: 4096, // keep every offender so the reports compare exactly
            ..IngestPolicy::default()
        },
        ..StreamOpts::default()
    };
    let want = fit_sequential(&dirty, &cfg, &opts);
    assert!(want.quarantine.skipped() > 0, "corruption must actually quarantine rows");

    for k in [2usize, 3, 8] {
        let got = fit_sharded(&[path.clone()], k, &cfg, &opts);
        assert_fits_equal(&got, &want, &format!("quarantine shards={k}"));
        // exact per-reason counts survive the merge
        assert_eq!(got.quarantine.malformed, want.quarantine.malformed, "shards={k}");
        assert_eq!(got.quarantine.non_finite, want.quarantine.non_finite, "shards={k}");
        assert_eq!(got.quarantine.samples.len(), want.quarantine.samples.len(), "shards={k}");
        // samples are located (absolute byte offsets survive byte-range
        // windows) and deterministically ordered: the merged order is
        // shard-index first, line order within a shard — i.e. byte order
        // overall, since shards are contiguous byte ranges
        let got_bytes: Vec<u64> = got.quarantine.samples.iter().map(|s| s.byte).collect();
        let mut want_bytes: Vec<u64> = want.quarantine.samples.iter().map(|s| s.byte).collect();
        want_bytes.sort_unstable();
        assert_eq!(got_bytes, want_bytes, "shards={k}: sample order");
        // determinism: a second identical run reproduces the report
        let again = fit_sharded(&[path.clone()], k, &cfg, &opts);
        let again_bytes: Vec<u64> = again.quarantine.samples.iter().map(|s| s.byte).collect();
        assert_eq!(got_bytes, again_bytes, "shards={k}: report must be deterministic");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_shard_transient_faults_retry_without_changing_the_fit() {
    let ds = synth::gaussian_blobs(240, 3, 3, 8.0, 5);
    let bytes = to_libsvm(&ds);
    let dir = tmpdir("transient");
    let path = dir.join("data.libsvm").to_str().unwrap().to_string();
    std::fs::write(&path, &bytes).unwrap();

    let cfg = test_cfg(3, 32, 0.6);
    let opts = StreamOpts {
        k: Some(3),
        block_rows: 64,
        policy: IngestPolicy { retry_backoff_ms: 0, ..IngestPolicy::default() },
        ..StreamOpts::default()
    };
    let want = fit_sharded(&[path.clone()], 3, &cfg, &opts);

    // same plan, but every shard reader wrapped in a transient-fault
    // injector: each next_chunk call site fails once, then succeeds
    let plan = ShardPlanner::new(3, 37, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
    let mut readers = ShardPlanner::open(&plan).unwrap();
    let fault = FaultPlan {
        seed: fault_seed(),
        transient_permille: 1000,
        ..FaultPlan::default()
    };
    let mut faulty: Vec<FaultyReader<'_>> =
        readers.iter_mut().map(|r| FaultyReader::new(r.as_mut(), fault)).collect();
    let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
        faulty.iter_mut().map(|r| r as &mut (dyn ChunkReader + Send)).collect();
    let got = fit_streaming_sharded(&Env::new(cfg.clone()), &mut refs, &opts).unwrap();

    assert_fits_equal(&got, &want, "transient faults");
    assert!(got.quarantine.retries > 0, "retries must be counted in the merged report");
    assert_eq!(got.quarantine.skipped(), 0, "transient errors must not quarantine rows");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sharded_checkpointing_is_a_typed_config_refusal() {
    let ds = synth::gaussian_blobs(40, 2, 2, 8.0, 3);
    let bytes = to_libsvm(&ds);
    let dir = tmpdir("ckpt");
    let path = dir.join("data.libsvm").to_str().unwrap().to_string();
    std::fs::write(&path, &bytes).unwrap();
    let ckpt_dir = dir.join("ckpt").to_str().unwrap().to_string();

    let cfg = test_cfg(2, 16, 0.6);
    let opts = StreamOpts {
        k: Some(2),
        block_rows: 8,
        checkpoint: Some(CheckpointCfg::new(&ckpt_dir)),
        ..StreamOpts::default()
    };
    let plan = ShardPlanner::new(2, 37, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
    let mut readers = ShardPlanner::open(&plan).unwrap();
    let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
        readers.iter_mut().map(|r| r.as_mut()).collect();
    let err = fit_streaming_sharded(&Env::new(cfg.clone()), &mut refs, &opts).unwrap_err();
    assert!(matches!(err, scrb::error::ScrbError::Config(_)), "{err}");
    assert!(err.to_string().contains("--shards"), "{err}");

    // one shard delegates to the sequential path, where checkpointing is
    // supported — the same opts must succeed
    let plan = ShardPlanner::new(1, 37, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
    let mut readers = ShardPlanner::open(&plan).unwrap();
    let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
        readers.iter_mut().map(|r| r.as_mut()).collect();
    let fit = fit_streaming_sharded(&Env::new(cfg.clone()), &mut refs, &opts).unwrap();
    assert_eq!(fit.n, 40);
    std::fs::remove_dir_all(&dir).ok();
}
