//! Online model maintenance, end to end (ISSUE 10 acceptance):
//!
//! - **Byte invisibility**: a zero-row chunk and an all-known,
//!   below-threshold chunk leave the saved model byte-identical except
//!   for the persisted update counters (the v3 trailer + checksum).
//! - **Admission**: drifted rows grow the codebook and the projection in
//!   lockstep, and the grown model save/load round-trips exactly.
//! - **Quality**: after absorbing held-out chunks incrementally, the
//!   updated model's NMI on the full set is within 0.05 of a full refit
//!   over everything.
//! - **Determinism**: under a fixed [`UpdateConfig::seed`] the
//!   drift-triggered refit escalation fires at the same chunk index on
//!   every run.
//! - **Hardened ingest**: `update_streaming` passes chunks through the
//!   same quarantine/retry stack as the streamed fit.
//!
//! The suite honors `SCRB_FAULT_SEED` (default 42); CI sweeps several
//! values.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, UpdateConfig};
use scrb::data::synth;
use scrb::linalg::Mat;
use scrb::metrics::nmi;
use scrb::model::{FittedModel as _, ScRbModel, UPDATE_TRAILER_BYTES};
use scrb::stream::{IngestPolicy, LibsvmChunks, OnBadRecord, SparseChunk};
use scrb::update::{update_streaming, UpdateOutcome, UpdateWorkspace};
use std::fmt::Write as _;

/// Scenario seed: `SCRB_FAULT_SEED` env var, default 42. The properties
/// below must hold at every swept value.
fn fault_seed() -> u64 {
    std::env::var("SCRB_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn rb_cfg(k: usize, r: usize, sigma: f64, seed: u64) -> PipelineConfig {
    PipelineConfig::builder()
        .engine(Engine::Native)
        .k(k)
        .r(r)
        .kernel(Kernel::Laplacian { sigma })
        .kmeans_replicates(3)
        .seed(seed)
        .build()
}

/// Fit SC_RB and hand back the concrete serving model.
fn fit_model(cfg: PipelineConfig, x: &Mat) -> ScRbModel {
    let fitted = MethodKind::ScRb.fit(&Env::new(cfg), x).expect("SC_RB fit");
    *fitted.model.into_any().downcast::<ScRbModel>().ok().unwrap()
}

/// Rows `lo..hi` of a dense matrix as one sparse update chunk.
fn chunk_of(x: &Mat, lo: usize, hi: usize) -> SparseChunk {
    let mut c = SparseChunk::new();
    for i in lo..hi {
        c.begin_row(0);
        for (j, &v) in x.row(i).iter().enumerate() {
            if v != 0.0 {
                c.push_entry(j as u32, v);
            }
        }
        c.end_row();
    }
    c
}

/// Model bytes with the mutable tail (v3 trailer + checksum) stripped.
fn frozen_prefix(m: &ScRbModel) -> Vec<u8> {
    let mut b = m.to_bytes();
    b.truncate(b.len() - UPDATE_TRAILER_BYTES - 8);
    b
}

#[test]
fn benign_chunks_are_byte_invisible_modulo_counters() {
    let seed = fault_seed();
    let ds = synth::gaussian_blobs(300, 4, 3, 8.0, seed);
    let mut m = fit_model(rb_cfg(3, 64, 0.7, seed), &ds.x);
    let before = frozen_prefix(&m);
    let full_before = m.to_bytes();
    let mut ws = UpdateWorkspace::new();
    let cfg = UpdateConfig { seed, ..Default::default() };

    // zero rows: only the call counter moves
    let rep = m.update(&SparseChunk::new(), &cfg, &mut ws).unwrap();
    assert_eq!(rep.outcome, UpdateOutcome::Updated);
    assert_eq!(m.update_state.updates, 1);
    assert_eq!(m.update_state.rows_absorbed, 0);
    assert_eq!(frozen_prefix(&m), before);

    // training rows replayed: all in vocabulary, below the residual
    // gate, so the fold never runs
    let rep = m.update(&chunk_of(&ds.x, 0, 300), &cfg, &mut ws).unwrap();
    assert_eq!(rep.outcome, UpdateOutcome::Updated);
    assert_eq!(rep.admitted, 0, "training rows admit nothing");
    assert_eq!(rep.unseen_rate, 0.0);
    assert_eq!(frozen_prefix(&m), before, "model bytes unchanged outside the trailer");
    assert_eq!(m.update_state.rows_absorbed, 300);

    // the full images differ only in the trailer+checksum suffix
    let full_after = m.to_bytes();
    assert_eq!(full_after.len(), full_before.len());
    let cut = full_before.len() - UPDATE_TRAILER_BYTES - 8;
    assert_eq!(full_after[..cut], full_before[..cut]);
    assert_ne!(full_after[cut..], full_before[cut..], "counters did persist");
}

#[test]
fn admission_grows_codebook_and_projection_in_lockstep() {
    let seed = fault_seed();
    let ds = synth::gaussian_blobs(250, 4, 3, 8.0, seed ^ 1);
    let mut m = fit_model(rb_cfg(3, 64, 0.7, seed ^ 1), &ds.x);
    let dim0 = m.codebook.dim;

    // shift the frame far outside every fitted bin
    let mut shifted = ds.x.clone();
    for v in shifted.data.iter_mut() {
        *v += 25.0;
    }
    let mut ws = UpdateWorkspace::new();
    let cfg = UpdateConfig { seed, ..Default::default() };
    let rep = m.update(&chunk_of(&shifted, 0, 120), &cfg, &mut ws).unwrap();
    assert!(rep.admitted > 0, "shifted rows must admit new bins");
    assert!(rep.unseen_rate > 0.5, "unseen rate {}", rep.unseen_rate);
    assert_eq!(m.codebook.dim, dim0 + rep.admitted);
    assert_eq!(m.proj.rows, m.codebook.dim, "P widened with the codebook");
    assert_eq!(m.proj.cols, m.s.len());

    // the grown model persists through the file round-trip exactly
    let dir = std::env::temp_dir().join("scrb_test_update");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("grown_{seed}.scrb"));
    let path = path.to_str().unwrap();
    m.save(path).unwrap();
    let back = ScRbModel::load(path).unwrap();
    assert_eq!(back.to_bytes(), m.to_bytes());
    assert_eq!(back.update_state, m.update_state);

    // both frames serve without error, and identically across the trip
    assert_eq!(m.predict(&ds.x).unwrap(), back.predict(&ds.x).unwrap());
    assert_eq!(m.predict(&shifted).unwrap(), back.predict(&shifted).unwrap());
}

#[test]
fn incremental_updates_track_full_refit_quality() {
    // fit on half the data, absorb the rest in chunks; clustering
    // quality on everything must stay within 0.05 NMI of refitting on
    // everything (ISSUE 10 acceptance)
    let seed = fault_seed();
    let mut ds = synth::gaussian_blobs(600, 4, 3, 9.0, seed);
    ds.shuffle(&mut scrb::util::rng::Pcg::seed(seed ^ 0xabc));
    let mut m = fit_model(rb_cfg(3, 128, 0.7, seed), &ds.x.row_block(0, 300));
    let mut ws = UpdateWorkspace::new();
    let cfg = UpdateConfig { seed, ..Default::default() };
    let mut lo = 300usize;
    while lo < 600 {
        let hi = (lo + 100).min(600);
        m.update(&chunk_of(&ds.x, lo, hi), &cfg, &mut ws).unwrap();
        lo = hi;
    }
    assert_eq!(m.update_state.rows_absorbed, 300);

    let upd_nmi = nmi(&m.predict(&ds.x).unwrap(), &ds.y);
    let refit = fit_model(rb_cfg(3, 128, 0.7, seed), &ds.x);
    let refit_nmi = nmi(&refit.predict(&ds.x).unwrap(), &ds.y);
    assert!(refit_nmi > 0.9, "refit baseline degenerate: {refit_nmi}");
    assert!(
        upd_nmi >= refit_nmi - 0.05,
        "updated NMI {upd_nmi} vs refit NMI {refit_nmi}"
    );
}

#[test]
fn refit_trigger_is_deterministic_under_a_fixed_seed() {
    let seed = fault_seed();
    let ds = synth::gaussian_blobs(200, 4, 3, 8.0, seed ^ 2);
    let cfg = UpdateConfig {
        seed,
        ewma: 0.6,
        unseen_refit: 0.25,
        ..Default::default()
    };

    // drifting scenario: each step shifts further off the training frame
    let run = || {
        let mut m = fit_model(rb_cfg(3, 64, 0.7, seed ^ 2), &ds.x);
        let mut ws = UpdateWorkspace::new();
        let mut fired_at = None;
        for step in 0..8usize {
            let mut shifted = ds.x.clone();
            for v in shifted.data.iter_mut() {
                *v += 30.0 * (step + 1) as f64;
            }
            let rep = m.update(&chunk_of(&shifted, 0, 60), &cfg, &mut ws).unwrap();
            if rep.outcome == UpdateOutcome::RefitNeeded {
                fired_at = Some(step);
                break;
            }
        }
        (fired_at, m.update_state)
    };

    let (fire_a, state_a) = run();
    let (fire_b, state_b) = run();
    assert!(fire_a.is_some(), "sustained drift must escalate");
    assert_eq!(fire_a, fire_b, "trigger step must replay exactly");
    assert_eq!(state_a, state_b, "persisted drift state must replay exactly");
    assert_eq!(state_a.refits_signaled, 1);
}

#[test]
fn update_streaming_quarantines_bad_records_like_the_fit() {
    let seed = fault_seed();
    let ds = synth::gaussian_blobs(120, 3, 2, 8.0, seed ^ 3);
    let mut m = fit_model(rb_cfg(2, 32, 0.7, seed ^ 3), &ds.x);

    // libsvm text of the training rows with two corrupt lines spliced in
    let mut text = String::new();
    for i in 0..60 {
        write!(text, "{}", ds.y[i]).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(text, " {}:{v}", j + 1).unwrap();
            }
        }
        text.push('\n');
        if i == 20 || i == 40 {
            text.push_str("0 1:not_a_number 2:nan\n");
        }
    }
    let cfg = UpdateConfig { seed, ..Default::default() };
    let mut ws = UpdateWorkspace::new();

    // strict: the first offender is fatal, as in the streamed fit
    let mut strict = LibsvmChunks::from_bytes(text.clone().into_bytes(), 16);
    let policy = IngestPolicy { retry_backoff_ms: 0, ..Default::default() };
    assert!(update_streaming(&mut m, &mut strict, &cfg, policy, &mut ws).is_err());

    // quarantine: both offenders skipped, every clean row absorbed
    let mut m = fit_model(rb_cfg(2, 32, 0.7, seed ^ 3), &ds.x);
    let mut reader = LibsvmChunks::from_bytes(text.into_bytes(), 16);
    let policy = IngestPolicy {
        on_bad_record: OnBadRecord::Quarantine,
        retry_backoff_ms: 0,
        ..Default::default()
    };
    let out = update_streaming(&mut m, &mut reader, &cfg, policy, &mut ws).unwrap();
    assert_eq!(out.quarantine.skipped(), 2, "both corrupt lines quarantined");
    assert_eq!(out.rows, 60, "clean rows all absorbed");
    assert!(!out.refit_needed, "in-vocabulary rows must not trigger a refit");
    assert_eq!(m.update_state.rows_absorbed, 60);
}
