//! Property-based invariants (via the in-tree `util::prop` harness —
//! proptest is unavailable offline). Each property runs across many
//! seeded random cases and reports the reproducing seed on failure.

use scrb::eigen::SvdOp;
use scrb::linalg::Mat;
use scrb::metrics;
use scrb::rb::rb_features;
use scrb::sparse::{implicit_degrees, Csr, EllRb, GramScratch};
use scrb::util::prop::{check, check_named, gen};
use scrb::util::rng::Pcg;

fn rand_mat(rng: &mut Pcg, r: usize, c: usize, lo: f64, hi: f64) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(lo, hi)).collect())
}

/// Elementwise agreement with the ISSUE's 1e-12 bar, scaled by magnitude so
/// legitimately-reordered summations over hundreds of terms still qualify.
fn assert_vec_close(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (u, v)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (u - v).abs() <= 1e-12 * (1.0 + v.abs()),
            "{what}[{i}]: {u} vs {v}"
        );
    }
}

fn assert_mat_close(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape mismatch");
    assert_vec_close(&a.data, &b.data, what);
}

// --------------------------------------------------------------- RB / graph

#[test]
fn prop_rb_row_structure() {
    // ∀ data, R, σ: every row of Z has exactly R nonzeros of value 1/√R,
    // and the implicit degrees equal the explicit Gram row sums.
    check("rb-row-structure", |rng, _case| {
        let n = gen::len(rng, 5, 60);
        let d = gen::len(rng, 1, 6);
        let r = gen::len(rng, 1, 24);
        let sigma = rng.range_f64(0.1, 3.0);
        let x = rand_mat(rng, n, d, 0.0, 1.0);
        let rb = rb_features(&x, r, sigma, rng.next_u64());
        assert_eq!(rb.z.nnz(), n * r);
        let v = 1.0 / (r as f64).sqrt();
        assert!(rb.z.scale.iter().all(|&x| (x - v).abs() < 1e-14));
        let deg = rb.z.implicit_degrees();
        let w = rb.z.gram_dense();
        for i in 0..n {
            let expl: f64 = w.row(i).iter().sum();
            assert!((deg[i] - expl).abs() < 1e-9 * (1.0 + expl));
        }
    });
}

#[test]
fn prop_normalized_gram_is_stochastic_like() {
    // Ẑ Ẑᵀ row sums … D^{-1/2} W D^{-1/2}: its Perron vector is D^{1/2}1;
    // check all eigen-relevant invariants: symmetry, PSD diag, and row sums
    // of D^{-1/2}WD^{-1/2}·D^{1/2}1 = D^{1/2}1.
    check("normalized-gram-perron", |rng, _case| {
        let n = gen::len(rng, 5, 40);
        let d = gen::len(rng, 1, 4);
        let r = gen::len(rng, 2, 16);
        let x = rand_mat(rng, n, d, 0.0, 1.0);
        let rb = rb_features(&x, r, 0.5, rng.next_u64());
        let mut zhat = rb.z;
        let deg = zhat.implicit_degrees();
        zhat.normalize_by_degree(&deg);
        let sqrt_d: Vec<f64> = deg.iter().map(|v| v.sqrt()).collect();
        // S·(D^{1/2}1) = D^{1/2}1
        let t = zhat.t_matvec(&sqrt_d);
        let s_sqrt_d = zhat.matvec(&t);
        for i in 0..n {
            assert!(
                (s_sqrt_d[i] - sqrt_d[i]).abs() < 1e-8 * (1.0 + sqrt_d[i]),
                "Perron violated at {i}: {} vs {}",
                s_sqrt_d[i],
                sqrt_d[i]
            );
        }
    });
}

// ------------------------------------------------------------------ sparse

/// Run the full substrate-equivalence battery on one RB output: `EllRb` and
/// its `to_csr()` bridge must agree on every operator the solver touches.
fn check_substrate_equivalence(rng: &mut Pcg, mut ell: scrb::sparse::EllRb, normalized: bool) {
    if normalized {
        let deg = ell.implicit_degrees();
        ell.normalize_by_degree(&deg);
    }
    let csr = ell.to_csr();
    assert_eq!(ell.nnz(), csr.nnz());

    // matvec / t_matvec
    let xv = gen::vec_f64(rng, ell.cols, -1.0, 1.0);
    assert_vec_close(&ell.matvec(&xv), &csr.matvec(&xv), "matvec");
    let xu = gen::vec_f64(rng, ell.rows, -1.0, 1.0);
    assert_vec_close(&ell.t_matvec(&xu), &csr.t_matvec(&xu), "t_matvec");

    // matmat / t_matmat (the solver's block applies)
    let k = gen::len(rng, 1, 6);
    let bf = rand_mat(rng, ell.cols, k, -1.0, 1.0);
    assert_mat_close(&ell.matmat(&bf), &csr.matmat(&bf), "matmat");
    let bt = rand_mat(rng, ell.rows, k, -1.0, 1.0);
    assert_mat_close(&ell.t_matmat(&bt), &csr.t_matmat(&bt), "t_matmat");

    // gram_diag (Davidson preconditioner)
    let gd_ell = SvdOp::gram_diag(&ell).expect("EllRb exposes gram_diag");
    let gd_csr = SvdOp::gram_diag(&csr).expect("Csr exposes gram_diag");
    assert_vec_close(&gd_ell, &gd_csr, "gram_diag");

    // implicit degrees (Eq. 6) and the aggregate sums behind them
    assert_vec_close(&ell.implicit_degrees(), &implicit_degrees(&csr), "implicit_degrees");
    assert_vec_close(&ell.row_sums(), &csr.row_sums(), "row_sums");
    assert_vec_close(&ell.col_sums(), &csr.col_sums(), "col_sums");
}

#[test]
fn prop_ell_csr_equivalence_across_r() {
    // ∀ data and R ∈ {1, 16, 256}: EllRb and Csr agree on matvec, t_matvec,
    // matmat, t_matmat, gram_diag, and implicit_degrees — both with the raw
    // 1/√R scale and after degree normalization.
    check_named("ell-csr-equivalence", 24, |rng, case| {
        let r = [1usize, 16, 256][case % 3];
        let n = gen::len(rng, 2, 40);
        let d = gen::len(rng, 1, 4);
        let x = rand_mat(rng, n, d, 0.0, 1.0);
        let sigma = rng.range_f64(0.15, 2.0);
        let rb = rb_features(&x, r, sigma, rng.next_u64());
        check_substrate_equivalence(rng, rb.z, case % 2 == 1);
    });
}

#[test]
fn prop_ell_csr_equivalence_degenerate() {
    // degenerate shapes: a single row (N=1) and a single grid (R=1)
    check_named("ell-csr-degenerate", 8, |rng, case| {
        let (n, r) = if case % 2 == 0 { (1, [1usize, 16, 256][case % 3]) } else { (gen::len(rng, 1, 20), 1) };
        let d = gen::len(rng, 1, 3);
        let x = rand_mat(rng, n, d, 0.0, 1.0);
        let rb = rb_features(&x, r, 0.5, rng.next_u64());
        check_substrate_equivalence(rng, rb.z, case >= 4);
    });
}

#[test]
fn prop_fused_gram_equals_two_pass() {
    // ∀ RB-structured Z, R ∈ {1, 16, 256}, k ∈ {1, 8, 33}: the fused
    // strip-tiled gram product Ẑ·(ẐᵀB) equals the two-pass
    // apply(apply_t(b)) reference to the 1e-12 bar, raw and normalized.
    check_named("fused-gram-vs-two-pass", 18, |rng, case| {
        let r = [1usize, 16, 256][case % 3];
        let k = [1usize, 8, 33][(case / 3) % 3];
        let n = gen::len(rng, 2, 40);
        let d = gen::len(rng, 1, 4);
        let x = rand_mat(rng, n, d, 0.0, 1.0);
        let mut z = rb_features(&x, r, rng.range_f64(0.15, 1.5), rng.next_u64()).z;
        if case % 2 == 1 {
            let deg = z.implicit_degrees();
            z.normalize_by_degree(&deg);
        }
        let b = rand_mat(rng, n, k, -1.0, 1.0);
        let reference = z.matmat(&z.t_matmat(&b));
        // inherent fused kernel and the SvdOp fast path must both agree
        assert_mat_close(&z.gram_matmat(&b), &reference, "gram_matmat");
        assert_mat_close(&SvdOp::gram_matmat(&z, &b), &reference, "SvdOp::gram_matmat");
    });
}

#[test]
fn prop_fused_gram_degenerate_and_scratch_reuse() {
    // Degenerate shapes — single row, empty-column-heavy operators — and a
    // single GramScratch reused across differently-shaped operators and
    // block widths (the solver workspace pattern).
    check_named("fused-gram-degenerate", 18, |rng, case| {
        let mut ws = GramScratch::new();
        let mut out = Mat::zeros(0, 0);
        // full (R, k) grid: indices decoupled so off-diagonal pairs
        // (e.g. R=256 with k=1, R=1 with k=33) are all exercised
        let r = [1usize, 16, 256][case % 3];
        let k = [1usize, 8, 33][(case / 3) % 3];
        // single row
        let bpg = gen::len(rng, 1, 5);
        let cols = r * bpg;
        let idx: Vec<u32> =
            (0..r).map(|j| (j * bpg + rng.below(bpg)) as u32).collect();
        let single = EllRb::new(1, cols, r, idx, vec![rng.range_f64(0.1, 2.0)]);
        let b1 = rand_mat(rng, 1, k, -1.0, 1.0);
        single.gram_matmat_into(&b1, &mut out, &mut ws);
        assert_mat_close(&out, &single.matmat(&single.t_matmat(&b1)), "single-row gram");

        // empty-column-heavy: most of the column space never referenced
        // (every row hits bin 0 of its grid, bins_per_grid = 7)
        let n = gen::len(rng, 2, 25);
        let r2 = gen::len(rng, 1, 9);
        let cols2 = r2 * 7;
        let mut idx2 = Vec::with_capacity(n * r2);
        for _ in 0..n {
            for j in 0..r2 {
                idx2.push((j * 7) as u32);
            }
        }
        let scale2: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 2.0)).collect();
        let sparse_cols = EllRb::new(n, cols2, r2, idx2, scale2);
        let b2 = rand_mat(rng, n, k, -1.0, 1.0);
        // same scratch, different operator shape: must re-provision itself
        sparse_cols.gram_matmat_into(&b2, &mut out, &mut ws);
        assert_mat_close(
            &out,
            &sparse_cols.matmat(&sparse_cols.t_matmat(&b2)),
            "empty-column gram",
        );
    });
}

#[test]
fn prop_csr_matvec_linearity_and_transpose_adjoint() {
    // ⟨A x, y⟩ = ⟨x, Aᵀ y⟩ for random sparse A
    check("csr-adjoint", |rng, _case| {
        let n = gen::len(rng, 2, 50);
        let m = gen::len(rng, 2, 50);
        let per = gen::len(rng, 1, 6).min(m);
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let mut entries = Vec::new();
            for _ in 0..per {
                entries.push((rng.below(m) as u32, rng.range_f64(-2.0, 2.0)));
            }
            rows.push(entries);
        }
        let a = Csr::from_rows(n, m, rows);
        let x = gen::vec_f64(rng, m, -1.0, 1.0);
        let y = gen::vec_f64(rng, n, -1.0, 1.0);
        let ax = a.matvec(&x);
        let aty = a.t_matvec(&y);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f64 = x.iter().zip(&aty).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    });
}

// ----------------------------------------------------------------- metrics

#[test]
fn prop_metrics_bounded_and_permutation_invariant() {
    check("metrics-invariants", |rng, _case| {
        let n = gen::len(rng, 2, 120);
        let k = gen::len(rng, 1, 6);
        let truth = gen::labels(rng, n, k);
        let pred = gen::labels(rng, n, k);
        let m = metrics::all_metrics(&pred, &truth);
        for v in m.as_array() {
            assert!((0.0..=1.0 + 1e-12).contains(&v), "{m:?}");
        }
        // permuting predicted label names changes nothing
        let perm: Vec<usize> = {
            let mut p: Vec<usize> = (0..k.max(1)).collect();
            rng.shuffle(&mut p);
            p
        };
        let renamed: Vec<usize> = pred.iter().map(|&c| perm[c]).collect();
        let m2 = metrics::all_metrics(&renamed, &truth);
        assert!((m.nmi - m2.nmi).abs() < 1e-9);
        assert!((m.accuracy - m2.accuracy).abs() < 1e-9);
        assert!((m.rand_index - m2.rand_index).abs() < 1e-9);
        // symmetry of RI
        let m3 = metrics::rand_index(&truth, &pred);
        assert!((m.rand_index - m3).abs() < 1e-9);
    });
}

#[test]
fn prop_accuracy_upper_bounds_and_perfect_case() {
    check("accuracy-bounds", |rng, _case| {
        let n = gen::len(rng, 2, 80);
        let k = gen::len(rng, 1, 5);
        let truth = gen::labels(rng, n, k);
        // accuracy(truth, truth) == 1
        assert!((metrics::accuracy(&truth, &truth) - 1.0).abs() < 1e-12);
        // accuracy ≥ share of the largest true class (map-all-to-one bound)
        let pred = vec![0usize; n];
        let mut sizes = vec![0usize; k];
        for &c in &truth {
            sizes[c] += 1;
        }
        let maxshare = *sizes.iter().max().unwrap() as f64 / n as f64;
        let acc = metrics::accuracy(&pred, &truth);
        assert!(acc >= maxshare - 1e-12, "acc {acc} < max share {maxshare}");
    });
}

// ------------------------------------------------------------------ kmeans

#[test]
fn prop_kmeans_labels_in_range_and_inertia_optimal_vs_random() {
    check("kmeans-validity", |rng, case| {
        let n = gen::len(rng, 10, 120);
        let d = gen::len(rng, 1, 4);
        let k = gen::len(rng, 1, 5).min(n);
        let x = rand_mat(rng, n, d, -2.0, 2.0);
        let opts = scrb::kmeans::KmeansOpts {
            replicates: 2,
            seed: case as u64,
            ..scrb::kmeans::KmeansOpts::new(k)
        };
        let res = scrb::kmeans::kmeans(&x, &opts, &scrb::kmeans::NativeAssign);
        assert_eq!(res.labels.len(), n);
        assert!(res.labels.iter().all(|&l| (l as usize) < k));
        assert!(res.inertia.is_finite() && res.inertia >= 0.0);
        // inertia is no worse than assigning everything to the mean
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (j, v) in x.row(i).iter().enumerate() {
                mean[j] += v / n as f64;
            }
        }
        let single: f64 = (0..n).map(|i| scrb::linalg::sqdist(x.row(i), &mean)).sum();
        assert!(res.inertia <= single + 1e-9, "{} > {}", res.inertia, single);
    });
}

// ----------------------------------------------------------------- eigen

#[test]
fn prop_svd_values_match_dense_on_random_sparse() {
    check("svds-vs-dense", |rng, _case| {
        let n = gen::len(rng, 10, 50);
        let m = gen::len(rng, 5, 25);
        let per = gen::len(rng, 1, 4).min(m);
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let mut entries = Vec::new();
            for _ in 0..per {
                entries.push((rng.below(m) as u32, rng.range_f64(0.05, 1.0)));
            }
            rows.push(entries);
        }
        let a = Csr::from_rows(n, m, rows);
        let dense = scrb::linalg::svd_thin(&a.to_dense());
        let k = 2.min(m);
        let mut opts = scrb::eigen::SvdsOpts::new(k, scrb::config::Solver::Davidson);
        opts.tol = 1e-8;
        opts.max_matvecs = 40_000;
        let r = scrb::eigen::svds(&a, &opts, rng.next_u64());
        for j in 0..k {
            assert!(
                (r.s[j] - dense.s[j]).abs() < 1e-5 * (1.0 + dense.s[0]),
                "σ_{j}: {} vs {}",
                r.s[j],
                dense.s[j]
            );
        }
    });
}
