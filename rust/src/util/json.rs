//! Minimal JSON reader/writer (no serde in the offline vendor set).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut o = Json::obj();
        o.set("name", Json::Str("kmeans_assign_d32".into()))
            .set("tile", Json::Num(2048.0))
            .set("ok", Json::Bool(true))
            .set("dims", Json::Arr(vec![Json::Num(2048.0), Json::Num(32.0)]));
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parse_nested_with_ws() {
        let t = r#" { "a" : [ 1 , 2.5 , -3e2 ] , "b" : { "c" : null } } "#;
        let v = Json::parse(t).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
