//! Counting global allocator — the measurement side of the
//! zero-allocation solver contract.
//!
//! The library only defines the allocator type and its counters; binaries
//! that want the accounting (the allocation test in `tests/alloc.rs`, the
//! substrate bench) opt in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: scrb::util::alloc_count::CountingAlloc =
//!     scrb::util::alloc_count::CountingAlloc;
//! ```
//!
//! Counters are process-global and include every thread, so measurements
//! of "allocations per solver iteration" capture worker-side allocations
//! too. Two relaxed atomic adds per malloc — noise next to the malloc
//! itself.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);
static BYTES: AtomicUsize = AtomicUsize::new(0);

/// Pass-through `System` allocator that counts calls and bytes.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total allocation calls (alloc + alloc_zeroed + realloc) so far.
pub fn allocations() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// Total bytes requested so far.
pub fn allocated_bytes() -> usize {
    BYTES.load(Ordering::Relaxed)
}
