//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Adaptive-iteration timing with warmup, reporting min/median/mean like
//! criterion's summary line. Used by everything under `rust/benches/`.
//! [`Bencher::write_json`] emits the same results machine-readably (the
//! `BENCH_*.json` perf trajectory tracked across PRs).

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
    results: Vec<BenchStats>,
    /// Named scalar side-metrics (bytes of scratch, allocations per call,
    /// speedup ratios …) emitted alongside the timings in the JSON
    /// trajectory.
    metrics: Vec<(String, f64)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_secs(2),
            max_iters: 1000,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher honouring `SCRB_BENCH_BUDGET_MS`.
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if let Ok(v) = std::env::var("SCRB_BENCH_BUDGET_MS") {
            if let Ok(ms) = v.parse::<u64>() {
                b.budget = Duration::from_millis(ms);
                b.warmup = Duration::from_millis((ms / 10).max(1));
            }
        }
        b
    }

    /// Time `f` (which should include only the work of interest) adaptively.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup / calibration.
        let t0 = Instant::now();
        let mut one = Duration::ZERO;
        let mut warm_iters = 0usize;
        while t0.elapsed() < self.warmup || warm_iters == 0 {
            let s = Instant::now();
            std::hint::black_box(f());
            one = s.elapsed();
            warm_iters += 1;
            if warm_iters >= 3 && one > self.warmup {
                break;
            }
        }
        let per = one.max(Duration::from_nanos(1));
        let n = ((self.budget.as_nanos() / per.as_nanos().max(1)) as usize)
            .clamp(3, self.max_iters);
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            min: samples[0],
            median: samples[samples.len() / 2],
            mean,
            max: *samples.last().unwrap(),
        };
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Record a single pre-measured duration (for long end-to-end cases that
    /// should run exactly once).
    pub fn record_once(&mut self, name: &str, d: Duration) -> &BenchStats {
        self.results.push(BenchStats {
            name: name.to_string(),
            iters: 1,
            min: d,
            median: d,
            mean: d,
            max: d,
        });
        self.results.last().unwrap()
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12}",
            "benchmark", "min", "median", "mean"
        )
    }

    pub fn report(&self) -> String {
        let mut s = Self::header();
        s.push('\n');
        s.push_str(&"-".repeat(84));
        s.push('\n');
        for r in &self.results {
            s.push_str(&r.line());
            s.push('\n');
        }
        s
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Record a named scalar side-metric (memory accounting, allocation
    /// counts, derived ratios). Lands in the JSON `metrics` object.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Look up a result by exact case name.
    pub fn stats(&self, name: &str) -> Option<&BenchStats> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Machine-readable view of the results (nanosecond durations).
    pub fn to_json(&self) -> Json {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("name", Json::Str(r.name.clone()))
                    .set("iters", Json::Num(r.iters as f64))
                    .set("min_ns", Json::Num(r.min.as_nanos() as f64))
                    .set("median_ns", Json::Num(r.median.as_nanos() as f64))
                    .set("mean_ns", Json::Num(r.mean.as_nanos() as f64))
                    .set("max_ns", Json::Num(r.max.as_nanos() as f64));
                o
            })
            .collect();
        let mut root = Json::obj();
        root.set("threads", Json::Num(crate::util::threads::num_threads() as f64))
            .set("results", Json::Arr(cases));
        if !self.metrics.is_empty() {
            let mut m = Json::obj();
            for (name, value) in &self.metrics {
                m.set(name, Json::Num(*value));
            }
            root.set("metrics", m);
        }
        root
    }

    /// Write the JSON results to `path` (the `BENCH_*.json` trajectory).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(20),
            max_iters: 50,
            ..Bencher::default()
        };
        let stats = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(stats.min > Duration::ZERO);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
        assert!(b.report().contains("spin"));
    }

    #[test]
    fn json_roundtrips() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(5),
            max_iters: 10,
            ..Bencher::default()
        };
        b.record_once("case_a", Duration::from_micros(123));
        b.metric("scratch_bytes", 4096.0);
        let j = b.to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        let arr = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "case_a");
        assert_eq!(arr[0].get("median_ns").unwrap().as_f64().unwrap(), 123_000.0);
        assert!(back.get("threads").unwrap().as_f64().unwrap() >= 1.0);
        let metrics = back.get("metrics").unwrap();
        assert_eq!(metrics.get("scratch_bytes").unwrap().as_f64().unwrap(), 4096.0);
        assert_eq!(b.stats("case_a").unwrap().iters, 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with(" s"));
    }
}
