//! Scoped-thread data parallelism (no rayon in the offline vendor set).
//!
//! The paper parallelizes RB generation over grids and the solver matvecs
//! over row panels; both map onto `parallel_for_chunks` below. Thread count
//! comes from `SCRB_THREADS` or `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

static NUM_THREADS: OnceLock<usize> = OnceLock::new();

/// Number of worker threads to use. Resolved once per process (first
/// call wins): `std::env::var` copies the value into a fresh `OsString`
/// on every read, which would put a heap allocation — and an env-lock
/// acquisition — inside every parallel section of the solver hot loop,
/// breaking the zero-allocation steady-state contract. Set `SCRB_THREADS`
/// before first use.
pub fn num_threads() -> usize {
    *NUM_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("SCRB_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Run `f(chunk_index, start, end)` over `[0, n)` split into contiguous
/// chunks, one logical chunk per worker, using scoped threads.
///
/// `f` must be `Sync` (shared by reference across workers). For mutable
/// output, give each chunk its own disjoint slice via `split_at_mut` outside
/// or use interior indexing with non-overlapping ranges.
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for t in 0..nt {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            s.spawn(move || fr(t, lo, hi));
        }
    });
}

/// Dynamic work-stealing loop over `[0, n)` in blocks of `block`; good when
/// per-item cost is skewed (e.g. RB grids with different bin counts).
pub fn parallel_for_dynamic<F>(n: usize, block: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nt {
            let fr = &f;
            let cur = &cursor;
            s.spawn(move || loop {
                let lo = cur.fetch_add(block, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + block).min(n);
                fr(lo, hi);
            });
        }
    });
}

/// Map each index in `[0, n)` to a value, in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    parallel_chunks_mut(&mut out, num_threads(), |start, slice| {
        for (k, slot) in slice.iter_mut().enumerate() {
            *slot = f(start + k);
        }
    });
    out
}

/// Parallel mutable-slice map: split `out` into per-chunk disjoint slices and
/// call `f(start_index, slice)` on each in parallel.
pub fn parallel_chunks_mut<T, F>(out: &mut [T], n_chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let nt = n_chunks.clamp(1, n);
    if nt <= 1 {
        // inline fast path: no scoped-thread fork/join (and no spawn
        // allocations — the zero-allocation solver contract relies on it)
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fr = &f;
            let st = start;
            s.spawn(move || fr(st, head));
            start += take;
            rest = tail;
        }
    });
}

/// Row-aligned parallel mutable map: split `out` (a row-major buffer with
/// rows of `row_len` elements) into whole-row chunks and call
/// `f(first_row_index, rows_slice)` on each in parallel.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    parallel_rows_mut_in(out, row_len, num_threads(), f)
}

/// [`parallel_rows_mut`] with an explicit worker budget instead of the
/// process-wide pool — for callers that already run inside their own
/// parallel section (the sharded featurize runs K featurizers at once and
/// hands each `num_threads() / K` workers so the machine is not
/// oversubscribed).
pub fn parallel_rows_mut_in<T, F>(out: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0, "buffer not row-aligned");
    let n_rows = out.len() / row_len;
    if n_rows == 0 {
        return;
    }
    let nt = threads.clamp(1, n_rows);
    if nt <= 1 {
        // inline fast path: no fork/join, no spawn allocations
        f(0, out);
        return;
    }
    let rows_per = n_rows.div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fr = &f;
            let r0 = row0;
            s.spawn(move || fr(r0, head));
            row0 += take / row_len;
            rest = tail;
        }
    });
}

/// Parallel mutable map over *irregular* row strips: split `out` (a
/// row-major buffer with rows of `row_len` elements) at the given ascending
/// row `boundaries` (`boundaries[0] == 0`, `boundaries.last() == n_rows`)
/// and call `f(strip_index, first_row, rows_slice)` on each non-empty strip
/// in parallel.
///
/// This is the write-side of the transpose-aware SpMM: the `EllRb` CSC
/// layout hands each worker a contiguous, nnz-balanced column strip, so the
/// output rows it owns form one contiguous slice — no per-thread
/// accumulators and no reduction step.
pub fn parallel_row_ranges_mut<T, F>(out: &mut [T], row_len: usize, boundaries: &[usize], f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0, "buffer not row-aligned");
    let n_rows = out.len() / row_len;
    assert!(
        boundaries.len() >= 2
            && boundaries[0] == 0
            && *boundaries.last().unwrap() == n_rows,
        "boundaries must span [0, n_rows]"
    );
    if boundaries.len() == 2 {
        // single strip: run inline, no fork/join, no spawn allocations
        if !out.is_empty() {
            f(0, 0, out);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut prev = 0usize;
        for (si, &b) in boundaries[1..].iter().enumerate() {
            assert!(b >= prev && b <= n_rows, "boundaries must be ascending");
            let take = (b - prev) * row_len;
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            if !head.is_empty() {
                let fr = &f;
                let first_row = prev;
                s.spawn(move || fr(si, first_row, head));
            }
            prev = b;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_007;
        let acc = AtomicU64::new(0);
        parallel_for_chunks(n, |_, lo, hi| {
            let mut s = 0u64;
            for i in lo..hi {
                s += i as u64;
            }
            acc.fetch_add(s, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn dynamic_covers_everything_once() {
        let n = 5000;
        let acc = AtomicU64::new(0);
        parallel_for_dynamic(n, 64, |lo, hi| {
            acc.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(1000, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn chunks_mut_writes_disjoint() {
        let mut v = vec![0usize; 777];
        parallel_chunks_mut(&mut v, 8, |start, s| {
            for (k, x) in s.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn rows_mut_aligned_and_complete() {
        let mut v = vec![0usize; 35 * 7];
        parallel_rows_mut(&mut v, 7, |row0, rows| {
            assert_eq!(rows.len() % 7, 0);
            for (k, x) in rows.iter_mut().enumerate() {
                *x = (row0 * 7) + k;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn rows_mut_in_respects_budget_and_covers() {
        for nt in [1usize, 2, 3, 16] {
            let mut v = vec![0usize; 11 * 4];
            parallel_rows_mut_in(&mut v, 4, nt, |row0, rows| {
                for (k, x) in rows.iter_mut().enumerate() {
                    *x = row0 * 4 + k;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i, "nt={nt}");
            }
        }
    }

    #[test]
    fn row_ranges_mut_irregular_strips() {
        let mut v = vec![0usize; 20 * 3];
        // strips of 0, 7, 5, 8 rows — including an empty strip
        parallel_row_ranges_mut(&mut v, 3, &[0, 0, 7, 12, 20], |_si, row0, rows| {
            for (k, x) in rows.iter_mut().enumerate() {
                *x = row0 * 3 + k;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn single_item_runs_inline() {
        let acc = AtomicU64::new(0);
        parallel_for_chunks(1, |_, lo, hi| {
            acc.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 1);
    }
}
