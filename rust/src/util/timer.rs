//! Per-stage wallclock accounting for the pipeline (the paper reports RB
//! generation / eigendecomposition / K-means / total, Fig. 4).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates named stage durations; a stage can run multiple times.
#[derive(Default, Clone, Debug)]
pub struct StageTimer {
    stages: BTreeMap<String, Duration>,
    order: Vec<String>,
}

impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`, returning its value.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let v = f();
        self.add(name, t0.elapsed());
        v
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if !self.stages.contains_key(name) {
            self.order.push(name.to_string());
        }
        *self.stages.entry(name.to_string()).or_default() += d;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.stages.get(name).copied().unwrap_or_default()
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.get(name).as_secs_f64()
    }

    pub fn total(&self) -> Duration {
        self.stages.values().sum()
    }

    /// Stage names in first-seen order.
    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// Merge another timer into this one.
    pub fn merge(&mut self, other: &StageTimer) {
        for name in other.names() {
            self.add(name, other.get(name));
        }
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for name in &self.order {
            s.push_str(&format!("{name}: {:.3}s  ", self.secs(name)));
        }
        s.push_str(&format!("total: {:.3}s", self.total().as_secs_f64()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_orders() {
        let mut t = StageTimer::new();
        t.time("a", || std::thread::sleep(Duration::from_millis(2)));
        t.time("b", || {});
        t.time("a", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(t.names(), &["a".to_string(), "b".to_string()]);
        assert!(t.secs("a") >= 0.004);
        assert!(t.total() >= t.get("a"));
    }

    #[test]
    fn merge_adds() {
        let mut a = StageTimer::new();
        a.add("x", Duration::from_millis(5));
        let mut b = StageTimer::new();
        b.add("x", Duration::from_millis(7));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(12));
        assert_eq!(a.get("y"), Duration::from_millis(1));
    }
}
