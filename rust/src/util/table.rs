//! Aligned plain-text table printing for the paper-style reports
//! (Tables 2–3, figure series dumps).

/// Column-aligned text table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (j, h) in self.header.iter().enumerate() {
            widths[j] = widths[j].max(h.chars().count());
        }
        for row in &self.rows {
            for (j, c) in row.iter().enumerate() {
                widths[j] = widths[j].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (j, c) in cells.iter().enumerate() {
                if j > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..widths[j] {
                    out.push(' ');
                }
            }
            // trim trailing pad
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }

    /// Render as CSV (for plotting externally).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed significant style used in the paper tables.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return "-".to_string();
    }
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["Dataset", "Acc", "Time"]);
        t.row(vec!["pendigits".to_string(), fnum(0.712), fnum(1.8)]);
        t.row(vec!["covtype-mult".to_string(), fnum(0.3), fnum(1593.0)]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Dataset"));
        assert!(lines[3].contains("1593"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x,y"]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\n");
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.7123), "0.71");
        assert_eq!(fnum(25.04), "25.0");
        assert_eq!(fnum(1593.2), "1593");
        assert_eq!(fnum(f64::NAN), "-");
    }
}
