//! The one FNV-1a 64-bit implementation of the crate.
//!
//! Four subsystems checksum or fingerprint bytes — model-file footers
//! (`model::persist`), checkpoint footers (`stream::checkpoint`, via the
//! persist writer), pipeline stage fingerprints (`pipeline::fingerprint`),
//! and the gram-scratch staleness fingerprint (`sparse::ell`). They all
//! use the same hash family, and they used to each carry their own copy of
//! the constants and fold loop; a typo'd prime in one copy would have let
//! a "checksummed" artifact verify against the wrong digest. This module
//! is the single definition they all fold through.
//!
//! FNV-1a is integrity against *accidental* corruption (bit rot,
//! truncation, torn writes) and identity for cache keys — it is not a
//! cryptographic MAC and none of the call sites treat it as one.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One-shot FNV-1a digest of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// Incremental FNV-1a hasher: byte-for-byte identical to [`fnv64`] over
/// the concatenation of everything written.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(FNV_OFFSET)
    }

    /// Fold raw bytes.
    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }

    /// Fold a `u64` as its little-endian bytes (the convention every
    /// persisted format in the crate uses).
    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// The accumulated digest.
    #[inline]
    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv64(b"foobar"));
        let mut h2 = Fnv64::new();
        h2.write_u64(0x0102_0304_0506_0708);
        assert_eq!(h2.finish(), fnv64(&[8, 7, 6, 5, 4, 3, 2, 1]));
    }
}
