//! Deterministic pseudo-random number generation and the samplers the paper
//! needs (uniform, Normal for RF/Gaussian, Cauchy for RF/Laplacian,
//! Gamma(2, σ) for Random Binning grid widths).
//!
//! Offline build: no `rand` crate in the vendor set, so we carry our own
//! PCG-XSH-RR 64/32 generator (O'Neill 2014). It is deterministic across
//! platforms, which the experiment protocol relies on ("all methods use the
//! same random seeds").

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotated output.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id (distinct streams are
    /// statistically independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-arg constructor with the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (for per-thread / per-grid use).
    pub fn split(&mut self, tag: u64) -> Pcg {
        let s = self.next_u64();
        Pcg::new(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag.wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire-style).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        // 64-bit multiply-shift with rejection on the low word.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not RNG-bound anywhere in the pipeline).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard Cauchy: the ω distribution for RF approximation of the
    /// Laplacian kernel k(δ)=exp(-|δ|/σ) (Fourier transform pairs).
    pub fn cauchy(&mut self) -> f64 {
        let u = self.f64();
        (std::f64::consts::PI * (u - 0.5)).tan()
    }

    /// Exponential(1).
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln()
    }

    /// Gamma(shape=2, scale): the RB width distribution for the Laplacian
    /// kernel. p(ω) ∝ ω·k″(ω) with k(δ)=e^{−δ/σ} gives p(ω) = ω/σ² e^{−ω/σ},
    /// i.e. Gamma(2, σ) = σ·(E₁+E₂), sum of two unit exponentials.
    pub fn gamma2(&mut self, scale: f64) -> f64 {
        scale * (self.exponential() + self.exponential())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates on an
    /// index map; O(k) memory when k ≪ n via hash map).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        use std::collections::HashMap;
        let mut swaps: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            let vi = *swaps.get(&i).unwrap_or(&i);
            out.push(vj);
            swaps.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg::seed(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_small() {
        let mut r = Pcg::seed(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn gamma2_moments() {
        // Gamma(2, s): mean 2s, var 2s².
        let mut r = Pcg::seed(13);
        let s = 0.7;
        let n = 200_000;
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for _ in 0..n {
            let x = r.gamma2(s);
            assert!(x > 0.0);
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 = m2 / n as f64 - m1 * m1;
        assert!((m1 - 2.0 * s).abs() < 0.02, "mean {m1}");
        assert!((m2 - 2.0 * s * s).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn cauchy_median_zero() {
        let mut r = Pcg::seed(17);
        let n = 100_000;
        let below = (0..n).filter(|_| r.cauchy() < 0.0).count();
        assert!((below as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::seed(19);
        let idx = r.sample_indices(1000, 100);
        assert_eq!(idx.len(), 100);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
        assert!(*sorted.last().unwrap() < 1000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seed(23);
        let mut xs: Vec<usize> = (0..500).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..500).collect::<Vec<_>>());
        assert_ne!(xs, (0..500).collect::<Vec<_>>());
    }
}
