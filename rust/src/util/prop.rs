//! Tiny property-based testing harness (proptest is not in the offline
//! vendor set). A property is a closure over a seeded RNG; we run many
//! cases and on failure report the reproducing seed.

use super::rng::Pcg;

/// Number of cases per property (override with SCRB_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("SCRB_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop(rng, case_index)` for `cases` seeds; panic with the seed on the
/// first failure (re-run with `check_seeded` to debug).
pub fn check_named(name: &str, cases: usize, prop: impl Fn(&mut Pcg, usize)) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg::seed(seed);
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default number of cases.
pub fn check(name: &str, prop: impl Fn(&mut Pcg, usize)) {
    check_named(name, default_cases(), prop);
}

/// Helpers for building random test inputs.
pub mod gen {
    use super::Pcg;

    /// Random length in [lo, hi].
    pub fn len(rng: &mut Pcg, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Vector of uniform values in [lo, hi).
    pub fn vec_f64(rng: &mut Pcg, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| rng.range_f64(lo, hi)).collect()
    }

    /// Random label assignment over k classes.
    pub fn labels(rng: &mut Pcg, n: usize, k: usize) -> Vec<usize> {
        (0..n).map(|_| rng.below(k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_named("sum-commutes", 16, |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check_named("always-fails", 4, |_, _| panic!("boom"));
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }
}
