//! Utility substrates built in-tree because the offline vendor set has no
//! rand / rayon / serde / clap / criterion / proptest.

pub mod alloc_count;
pub mod bench;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod threads;
pub mod timer;
