//! Exact kernel machinery: Gaussian and Laplacian similarity functions,
//! blocked dense kernel panels (the `O(N²d)` path the paper is replacing),
//! full kernel matrices for the exact-SC baseline, and cross-kernel blocks
//! for Nyström / landmark methods.

use crate::config::Kernel;
use crate::linalg::{l1dist, sqdist, Mat};
use crate::util::threads::parallel_rows_mut;

impl Kernel {
    /// Evaluate k(a, b).
    #[inline]
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Kernel::Gaussian { sigma } => (-sqdist(a, b) / (2.0 * sigma * sigma)).exp(),
            Kernel::Laplacian { sigma } => (-l1dist(a, b) / sigma).exp(),
        }
    }
}

/// Dense kernel block K[i][j] = k(x_i, y_j) for row sets `x` (m×d) and
/// `y` (p×d); parallel over rows of the output.
pub fn kernel_block(kernel: Kernel, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols, y.cols, "dimension mismatch");
    let (m, p) = (x.rows, y.rows);
    let mut out = Mat::zeros(m, p);
    parallel_rows_mut(&mut out.data, p, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(p).enumerate() {
            let xi = x.row(row0 + r);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = kernel.eval(xi, y.row(j));
            }
        }
    });
    out
}

/// Full symmetric kernel matrix W (the exact-SC similarity graph);
/// exploits symmetry, O(N²d/2).
pub fn kernel_matrix(kernel: Kernel, x: &Mat) -> Mat {
    let n = x.rows;
    let mut w = Mat::zeros(n, n);
    // parallel over rows; each row i computes j <= i, mirror later
    parallel_rows_mut(&mut w.data, n, |row0, chunk| {
        for (r, wrow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let xi = x.row(i);
            for (j, wv) in wrow.iter_mut().enumerate().take(i + 1) {
                *wv = kernel.eval(xi, x.row(j));
            }
        }
    });
    // mirror lower triangle to upper
    for i in 0..n {
        for j in (i + 1)..n {
            let v = w.at(j, i);
            w.set(i, j, v);
        }
    }
    w
}

/// Median-heuristic bandwidth: median pairwise distance on a subsample
/// (the paper selects σ by cross-validation in [0.01, 100]; the median
/// heuristic is our automatic default, override with `--sigma`).
pub fn median_heuristic_sigma(kernel_name: &str, x: &Mat, seed: u64) -> f64 {
    let n = x.rows;
    let sample = 200.min(n);
    let mut rng = crate::util::rng::Pcg::new(seed, 0x51337);
    let idx = rng.sample_indices(n, sample);
    let mut dists = Vec::with_capacity(sample * (sample - 1) / 2);
    for a in 0..sample {
        for b in 0..a {
            let d = match kernel_name {
                "laplacian" => l1dist(x.row(idx[a]), x.row(idx[b])),
                _ => sqdist(x.row(idx[a]), x.row(idx[b])).sqrt(),
            };
            dists.push(d);
        }
    }
    if dists.is_empty() {
        return 1.0;
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = dists[dists.len() / 2];
    if med > 0.0 {
        med
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn rand_data(rng: &mut Pcg, n: usize, d: usize) -> Mat {
        Mat::from_vec(n, d, (0..n * d).map(|_| rng.f64()).collect())
    }

    #[test]
    fn kernel_values_bounded_and_unit_diag() {
        let mut rng = Pcg::seed(101);
        let x = rand_data(&mut rng, 40, 6);
        for kernel in [Kernel::Gaussian { sigma: 0.7 }, Kernel::Laplacian { sigma: 0.7 }] {
            let w = kernel_matrix(kernel, &x);
            for i in 0..40 {
                assert!((w.at(i, i) - 1.0).abs() < 1e-12);
                for j in 0..40 {
                    assert!(w.at(i, j) > 0.0 && w.at(i, j) <= 1.0 + 1e-12);
                    assert!((w.at(i, j) - w.at(j, i)).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn block_matches_matrix() {
        let mut rng = Pcg::seed(102);
        let x = rand_data(&mut rng, 25, 4);
        let k = Kernel::Gaussian { sigma: 1.3 };
        let w = kernel_matrix(k, &x);
        let b = kernel_block(k, &x, &x);
        assert!(w.sub(&b).frob_norm() < 1e-12);
    }

    #[test]
    fn known_values() {
        let g = Kernel::Gaussian { sigma: 1.0 };
        assert!((g.eval(&[0.0], &[2.0]) - (-2.0f64).exp()).abs() < 1e-12);
        let l = Kernel::Laplacian { sigma: 2.0 };
        assert!((l.eval(&[0.0, 0.0], &[1.0, -1.0]) - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn median_sigma_positive_scales() {
        let mut rng = Pcg::seed(103);
        let x = rand_data(&mut rng, 100, 3);
        let s1 = median_heuristic_sigma("gaussian", &x, 1);
        assert!(s1 > 0.0);
        let mut x10 = x.clone();
        x10.scale(10.0);
        let s10 = median_heuristic_sigma("gaussian", &x10, 1);
        assert!(s10 > 5.0 * s1, "sigma should scale with the data: {s1} -> {s10}");
    }
}
