//! Typed configuration for the clustering pipeline and experiment drivers.
//!
//! Configs come from (lowest to highest precedence): built-in defaults, an
//! optional `key = value` config file (`--config path`), then CLI options.
//! Programmatic callers use the fluent [`PipelineConfig::builder`]:
//!
//! ```
//! use scrb::config::{Kernel, PipelineConfig};
//! let cfg = PipelineConfig::builder()
//!     .k(2)
//!     .r(256)
//!     .kernel(Kernel::Laplacian { sigma: 0.15 })
//!     .build();
//! assert_eq!(cfg.k, 2);
//! ```

use crate::cli::Args;
use crate::error::ScrbError;
use std::collections::BTreeMap;
use std::fmt;

/// Similarity kernel for graph construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = exp(-||x-y||_1 / sigma). RB's native kernel (p(ω)∝ω·k″(ω) is
    /// Gamma(2, σ)); RF approximates it with Cauchy-distributed ω.
    Laplacian { sigma: f64 },
    /// k(x,y) = exp(-||x-y||² / (2σ²)). RF approximates it with Normal ω.
    Gaussian { sigma: f64 },
}

impl Kernel {
    pub fn sigma(&self) -> f64 {
        match self {
            Kernel::Laplacian { sigma } | Kernel::Gaussian { sigma } => *sigma,
        }
    }

    pub fn with_sigma(&self, sigma: f64) -> Kernel {
        match self {
            Kernel::Laplacian { .. } => Kernel::Laplacian { sigma },
            Kernel::Gaussian { .. } => Kernel::Gaussian { sigma },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Laplacian { .. } => "laplacian",
            Kernel::Gaussian { .. } => "gaussian",
        }
    }

    pub fn parse(name: &str, sigma: f64) -> Result<Kernel, ScrbError> {
        match name {
            "laplacian" | "lap" | "l1" => Ok(Kernel::Laplacian { sigma }),
            "gaussian" | "rbf" | "l2" => Ok(Kernel::Gaussian { sigma }),
            other => Err(ScrbError::config(format!("unknown kernel '{other}' (laplacian|gaussian)"))),
        }
    }
}

/// Which iterative SVD solver backs step 3 of Algorithm 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// PRIMME-analogue: block Generalized-Davidson (GD+k) with thick restart.
    Davidson,
    /// Matlab-`svds` analogue: restarted Lanczos bidiagonalization.
    Lanczos,
    /// Compressive spectral clustering (Tremblay et al.): Chebyshev
    /// low-pass filtering of random signals instead of eigendecomposition,
    /// tuned by the `cheb_*` knobs.
    Compressive,
}

impl Solver {
    /// Every solver, in presentation order. `parse` derives its error
    /// message from this list so it can never go stale.
    pub const ALL: [Solver; 3] = [Solver::Davidson, Solver::Lanczos, Solver::Compressive];

    pub fn parse(s: &str) -> Result<Solver, ScrbError> {
        match s {
            "davidson" | "primme" | "gd+k" => Ok(Solver::Davidson),
            "lanczos" | "svds" | "lbd" => Ok(Solver::Lanczos),
            "compressive" | "csc" | "cheb" => Ok(Solver::Compressive),
            other => {
                let names: Vec<&str> = Solver::ALL.iter().map(|s| s.name()).collect();
                Err(ScrbError::config(format!(
                    "unknown solver '{other}' ({})",
                    names.join("|")
                )))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Solver::Davidson => "davidson",
            Solver::Lanczos => "lanczos",
            Solver::Compressive => "compressive",
        }
    }
}

/// Dense-compute engine: native Rust or AOT-compiled XLA artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Native,
    Xla,
    /// Use XLA when artifacts are present, otherwise native.
    Auto,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine, ScrbError> {
        match s {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            "auto" => Ok(Engine::Auto),
            other => Err(ScrbError::config(format!("unknown engine '{other}' (native|xla|auto)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Xla => "xla",
            Engine::Auto => "auto",
        }
    }
}

/// Streaming-ingestion section of a [`PipelineConfig`]: present iff the
/// fit reads a chunked out-of-core source (`scrb fit --stream`). Kept in
/// the config so [`PipelineConfig::validate`] covers *both* fit paths —
/// the in-memory k/R checks and the stream-only knobs live in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamConfig {
    /// Rows per streamed reader chunk (resident input ≈ `chunk_rows × d`).
    pub chunk_rows: usize,
    /// Substrate block granularity in rows (independent of `chunk_rows`).
    pub block_rows: usize,
    /// Parallel featurization shards (`scrb fit --stream --shards K`);
    /// 1 = the sequential single-reader scan. Any K yields bit-identical
    /// models (see [`crate::shard`]).
    pub shards: usize,
}

/// Online-maintenance knobs for [`crate::update`] (`scrb update` /
/// [`crate::model::ScRbModel`]`::update`): EWMA smoothing of the drift
/// signals, the refit-trigger thresholds, and the bounded warm-start
/// K-means polish. Standalone (not a [`PipelineConfig`] section) because
/// updates run against a *fitted* model, whose pipeline parameters are
/// already frozen inside the artifact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UpdateConfig {
    /// EWMA decay α for the per-update drift signals (`new = α·obs +
    /// (1−α)·old`). Larger = more reactive trigger.
    pub ewma: f64,
    /// Refit trigger: unseen-bin-rate EWMA above this returns
    /// [`crate::update::UpdateOutcome::RefitNeeded`].
    pub unseen_refit: f64,
    /// Refit trigger: subspace-residual EWMA above this returns
    /// `RefitNeeded` (fraction of chunk embedding energy the tracked
    /// subspace cannot express).
    pub residual_refit: f64,
    /// Chunks with **no** admitted bins skip the subspace refresh unless
    /// their residual ratio exceeds this — the gate that keeps all-known
    /// in-distribution chunks byte-invisible (only the persisted update
    /// counters move). Set negative to force the refresh on every chunk.
    pub residual_tol: f64,
    /// Bounded warm-start Lloyd passes over each update chunk's
    /// embedding (centroids re-seeded from the previous solution).
    pub lloyd_iters: usize,
    /// Rows per incremental-SVD sub-block (the rank of one Brand-style
    /// subspace fold; bounds the small-SVD cost per step).
    pub block: usize,
    /// Seed for the drift tracker's jittered re-arm delay after a refit
    /// signal (deterministic trigger under a fixed seed).
    pub seed: u64,
}

impl Default for UpdateConfig {
    fn default() -> Self {
        UpdateConfig {
            ewma: 0.3,
            unseen_refit: 0.2,
            residual_refit: 0.98,
            residual_tol: 0.999,
            lloyd_iters: 3,
            block: 64,
            seed: 42,
        }
    }
}

impl UpdateConfig {
    /// Validate ranges; the same typed-rejection posture as
    /// [`PipelineConfig::validate`].
    pub fn validate(&self) -> Result<(), ScrbError> {
        if !(self.ewma > 0.0 && self.ewma <= 1.0) {
            return Err(ScrbError::config(format!(
                "update: ewma must be in (0, 1], got {}",
                self.ewma
            )));
        }
        for (name, v) in [("unseen-refit", self.unseen_refit), ("residual-refit", self.residual_refit)]
        {
            if !(0.0..=1.0).contains(&v) {
                return Err(ScrbError::config(format!(
                    "update: {name} must be a rate in [0, 1], got {v}"
                )));
            }
        }
        if !self.residual_tol.is_finite() || self.residual_tol > 1.0 {
            return Err(ScrbError::config(format!(
                "update: residual-tol must be finite and <= 1, got {}",
                self.residual_tol
            )));
        }
        if self.block == 0 {
            return Err(ScrbError::config("update: block must be >= 1 rows"));
        }
        Ok(())
    }

    /// Apply the `scrb update` CLI options (highest precedence), then
    /// validate.
    pub fn apply_args(&mut self, args: &Args) -> Result<(), ScrbError> {
        self.ewma = args.get_f64("ewma", self.ewma)?;
        self.unseen_refit = args.get_f64("unseen-refit", self.unseen_refit)?;
        self.residual_refit = args.get_f64("residual-refit", self.residual_refit)?;
        self.residual_tol = args.get_f64("residual-tol", self.residual_tol)?;
        self.lloyd_iters = args.get_usize("lloyd-iters", self.lloyd_iters)?;
        self.block = args.get_usize("update-block", self.block)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.validate()
    }
}

/// Full pipeline configuration (Algorithm 2 + baselines).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of clusters K.
    pub k: usize,
    /// Number of RB grids / RF features / landmarks R (method-dependent rank).
    pub r: usize,
    pub kernel: Kernel,
    pub seed: u64,
    pub solver: Solver,
    pub engine: Engine,
    /// K-means replicates (paper: Matlab kmeans with 10 replicates).
    pub kmeans_replicates: usize,
    pub kmeans_max_iters: usize,
    /// Eigensolver convergence tolerance (paper §5.3 uses 1e-5).
    pub svd_tol: f64,
    pub svd_max_iters: usize,
    /// Spectral embedding width (singular triplets kept); `None` = `k`.
    /// Sweep drivers pin this so a k-sweep reuses one embedding artifact
    /// across every grid point (see [`crate::pipeline`]).
    pub embed_dim: Option<usize>,
    /// Chebyshev filter order p for `--solver compressive`. Higher orders
    /// sharpen the ideal-low-pass approximation (better cluster recovery)
    /// at one fused gram product per order.
    pub cheb_order: usize,
    /// Number of random Gaussian signals η filtered by the compressive
    /// solver; `None` = auto, O(log n) but at least the embedding width.
    pub cheb_signals: Option<usize>,
    /// Rows sampled for the compressive solver's k-means + label
    /// interpolation stage; `None` = auto, O(k·log n).
    pub cheb_sample: Option<usize>,
    /// Streaming-ingestion section; `Some` iff the fit reads a chunked
    /// source. Validation then additionally requires an explicit σ (no
    /// data matrix exists to run bandwidth selection on).
    pub stream: Option<StreamConfig>,
    /// Whether σ was pinned explicitly (builder `sigma`/`kernel` setter,
    /// config-file/CLI `sigma` key) rather than left at the default. A
    /// streamed fit refuses to run on an un-pinned bandwidth.
    pub sigma_explicit: bool,
    /// Directory with AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    pub verbose: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k: 2,
            r: 256,
            kernel: Kernel::Laplacian { sigma: 1.0 },
            seed: 42,
            solver: Solver::Davidson,
            engine: Engine::Auto,
            kmeans_replicates: 10,
            kmeans_max_iters: 100,
            svd_tol: 1e-5,
            svd_max_iters: 3000,
            embed_dim: None,
            cheb_order: 25,
            cheb_signals: None,
            cheb_sample: None,
            stream: None,
            sigma_explicit: false,
            artifacts_dir: "artifacts".to_string(),
            verbose: false,
        }
    }
}

impl PipelineConfig {
    /// Start a fluent builder seeded with the defaults:
    /// `PipelineConfig::builder().k(2).r(256).build()`.
    pub fn builder() -> PipelineConfigBuilder {
        PipelineConfigBuilder::default()
    }

    /// Validate every domain precondition, enumerating accepted values in
    /// the error message. One routine covers both fit paths: the
    /// in-memory k/R/solver checks *and* the streaming section's
    /// chunk-rows / block-rows / explicit-σ requirements. Called from
    /// [`PipelineConfigBuilder::build`], [`PipelineConfig::rebuild`], and
    /// the CLI after option layering.
    pub fn validate(&self) -> Result<(), ScrbError> {
        if self.k < 1 {
            return Err(ScrbError::config("k must be >= 1 (number of clusters)"));
        }
        if self.r < 1 {
            return Err(ScrbError::config(
                "r must be >= 1 (RB grids / RF features / landmarks)",
            ));
        }
        let sigma = self.kernel.sigma();
        if !sigma.is_finite() || sigma <= 0.0 {
            return Err(ScrbError::config(format!(
                "sigma must be a positive finite number, got {sigma}"
            )));
        }
        if self.kmeans_replicates < 1 {
            return Err(ScrbError::config("kmeans_replicates must be >= 1"));
        }
        if self.kmeans_max_iters < 1 {
            return Err(ScrbError::config("kmeans_max_iters must be >= 1"));
        }
        if !self.svd_tol.is_finite() || self.svd_tol <= 0.0 {
            return Err(ScrbError::config(format!(
                "svd_tol must be a positive finite number, got {}",
                self.svd_tol
            )));
        }
        if self.svd_max_iters < 1 {
            return Err(ScrbError::config("svd_max_iters must be >= 1"));
        }
        if let Some(dim) = self.embed_dim {
            if dim < self.k {
                return Err(ScrbError::config(format!(
                    "embed_dim must be >= k (clustering {k} clusters needs at least a \
                     {k}-dimensional embedding, got embed_dim={dim})",
                    k = self.k
                )));
            }
        }
        if self.cheb_order < 2 {
            return Err(ScrbError::config(
                "cheb_order must be >= 2 (Chebyshev filter order for --solver compressive)",
            ));
        }
        if let Some(eta) = self.cheb_signals {
            if eta < 1 {
                return Err(ScrbError::config(
                    "cheb_signals must be >= 1 (random signals filtered by --solver compressive)",
                ));
            }
        }
        if let Some(m) = self.cheb_sample {
            if m < self.k {
                return Err(ScrbError::config(format!(
                    "cheb_sample must be >= k (k-means on {k} clusters needs at least {k} \
                     sampled rows, got cheb_sample={m})",
                    k = self.k
                )));
            }
        }
        if let Some(stream) = &self.stream {
            if stream.chunk_rows < 1 || stream.block_rows < 1 {
                return Err(ScrbError::config(
                    "streaming fit needs chunk_rows >= 1 and block_rows >= 1",
                ));
            }
            if stream.shards < 1 {
                return Err(ScrbError::config(
                    "streaming fit needs shards >= 1 (1 = the sequential scan)",
                ));
            }
            if !self.sigma_explicit {
                return Err(ScrbError::config(
                    "a streamed fit cannot run the in-memory bandwidth selection; \
                     pin the kernel bandwidth explicitly (pass --sigma S, or set \
                     sigma/kernel on the builder)",
                ));
            }
        }
        Ok(())
    }

    /// Re-derive a validated config through the builder: reconstructs a
    /// builder holding this config, applies `f`, and re-validates. The
    /// sanctioned way for sweep drivers to vary a knob — field pokes on a
    /// built config bypass validation, `rebuild` cannot:
    ///
    /// ```
    /// use scrb::config::PipelineConfig;
    /// let cfg = PipelineConfig::builder().k(3).build();
    /// let swept = cfg.rebuild(|b| b.sigma(0.25)).unwrap();
    /// assert_eq!(swept.kernel.sigma(), 0.25);
    /// assert_eq!(swept.k, 3);
    /// assert!(cfg.rebuild(|b| b.r(0)).is_err());
    /// ```
    pub fn rebuild(
        &self,
        f: impl FnOnce(PipelineConfigBuilder) -> PipelineConfigBuilder,
    ) -> Result<PipelineConfig, ScrbError> {
        f(PipelineConfigBuilder { cfg: self.clone() }).try_build()
    }

    /// Apply a parsed `key = value` map (config file layer).
    pub fn apply_map(&mut self, map: &BTreeMap<String, String>) -> Result<(), ScrbError> {
        for (k, v) in map {
            self.apply_kv(k, v)?;
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, val: &str) -> Result<(), ScrbError> {
        let bad = |k: &str, v: &str| ScrbError::config(format!("config: bad value '{v}' for '{k}'"));
        match key {
            "k" => self.k = val.parse().map_err(|_| bad(key, val))?,
            "r" => self.r = val.parse().map_err(|_| bad(key, val))?,
            "sigma" => {
                let s: f64 = val.parse().map_err(|_| bad(key, val))?;
                self.kernel = self.kernel.with_sigma(s);
                self.sigma_explicit = true;
            }
            "kernel" => self.kernel = Kernel::parse(val, self.kernel.sigma())?,
            "embed_dim" => self.embed_dim = Some(val.parse().map_err(|_| bad(key, val))?),
            "cheb_order" => self.cheb_order = val.parse().map_err(|_| bad(key, val))?,
            "cheb_signals" => self.cheb_signals = Some(val.parse().map_err(|_| bad(key, val))?),
            "cheb_sample" => self.cheb_sample = Some(val.parse().map_err(|_| bad(key, val))?),
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "solver" => self.solver = Solver::parse(val)?,
            "engine" => self.engine = Engine::parse(val)?,
            "kmeans_replicates" => {
                self.kmeans_replicates = val.parse().map_err(|_| bad(key, val))?
            }
            "kmeans_max_iters" => self.kmeans_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "svd_tol" => self.svd_tol = val.parse().map_err(|_| bad(key, val))?,
            "svd_max_iters" => self.svd_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "verbose" => self.verbose = val.parse().map_err(|_| bad(key, val))?,
            other => return Err(ScrbError::config(format!("config: unknown key '{other}'"))),
        }
        Ok(())
    }

    /// Apply CLI options (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), ScrbError> {
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path).map_err(|e| ScrbError::io(path, e))?;
            self.apply_map(&parse_kv_file(&text)?)?;
        }
        for key in [
            "k",
            "r",
            "sigma",
            "kernel",
            "seed",
            "solver",
            "engine",
            "kmeans_replicates",
            "kmeans_max_iters",
            "svd_tol",
            "svd_max_iters",
            "embed_dim",
            "cheb_order",
            "cheb_signals",
            "cheb_sample",
            "artifacts_dir",
        ] {
            if let Some(v) = args.get(key) {
                self.apply_kv(key, v)?;
            }
        }
        if args.flag("verbose") {
            self.verbose = true;
        }
        Ok(())
    }
}

impl fmt::Display for PipelineConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "k={} r={} kernel={}(sigma={}) solver={} engine={} seed={}",
            self.k,
            self.r,
            self.kernel.name(),
            self.kernel.sigma(),
            self.solver.name(),
            self.engine.name(),
            self.seed
        )
    }
}

/// Fluent builder for [`PipelineConfig`], seeded with the defaults. Each
/// setter consumes and returns the builder, so configs assemble in one
/// expression instead of the mutate-a-default pattern.
#[derive(Clone, Debug, Default)]
pub struct PipelineConfigBuilder {
    cfg: PipelineConfig,
}

impl PipelineConfigBuilder {
    /// Number of clusters K.
    pub fn k(mut self, k: usize) -> Self {
        self.cfg.k = k;
        self
    }

    /// Number of RB grids / RF features / landmarks R.
    pub fn r(mut self, r: usize) -> Self {
        self.cfg.r = r;
        self
    }

    /// Similarity kernel (kind + bandwidth). Pins σ explicitly.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.cfg.kernel = kernel;
        self.cfg.sigma_explicit = true;
        self
    }

    /// Kernel bandwidth, keeping the current kernel kind. Pins σ
    /// explicitly (a streamed fit requires this).
    pub fn sigma(mut self, sigma: f64) -> Self {
        self.cfg.kernel = self.cfg.kernel.with_sigma(sigma);
        self.cfg.sigma_explicit = true;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn solver(mut self, solver: Solver) -> Self {
        self.cfg.solver = solver;
        self
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.cfg.engine = engine;
        self
    }

    pub fn kmeans_replicates(mut self, n: usize) -> Self {
        self.cfg.kmeans_replicates = n;
        self
    }

    pub fn kmeans_max_iters(mut self, n: usize) -> Self {
        self.cfg.kmeans_max_iters = n;
        self
    }

    pub fn svd_tol(mut self, tol: f64) -> Self {
        self.cfg.svd_tol = tol;
        self
    }

    pub fn svd_max_iters(mut self, n: usize) -> Self {
        self.cfg.svd_max_iters = n;
        self
    }

    /// Spectral embedding width (singular triplets kept). Pin it across a
    /// k-sweep so every grid point reuses one embedding artifact.
    pub fn embed_dim(mut self, dim: usize) -> Self {
        self.cfg.embed_dim = Some(dim);
        self
    }

    /// Chebyshev filter order p for `--solver compressive`.
    pub fn cheb_order(mut self, p: usize) -> Self {
        self.cfg.cheb_order = p;
        self
    }

    /// Number of random signals η for `--solver compressive`.
    pub fn cheb_signals(mut self, eta: usize) -> Self {
        self.cfg.cheb_signals = Some(eta);
        self
    }

    /// Sampled-row count for the compressive k-means + interpolation.
    pub fn cheb_sample(mut self, m: usize) -> Self {
        self.cfg.cheb_sample = Some(m);
        self
    }

    /// Attach the streaming-ingestion section (`scrb fit --stream`
    /// knobs); validation then also requires an explicitly pinned σ.
    pub fn stream(mut self, chunk_rows: usize, block_rows: usize) -> Self {
        let shards = self.cfg.stream.map_or(1, |s| s.shards);
        self.cfg.stream = Some(StreamConfig { chunk_rows, block_rows, shards });
        self
    }

    /// Number of parallel featurization shards for a streamed fit
    /// (`--shards K`); attaches a default streaming section first if
    /// [`Self::stream`] hasn't. Bit-identical models for any K.
    pub fn shards(mut self, shards: usize) -> Self {
        let mut s = self
            .cfg
            .stream
            .unwrap_or(StreamConfig { chunk_rows: 4096, block_rows: 65_536, shards: 1 });
        s.shards = shards;
        self.cfg.stream = Some(s);
        self
    }

    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn verbose(mut self, verbose: bool) -> Self {
        self.cfg.verbose = verbose;
        self
    }

    /// Validate and return the config, or the typed
    /// [`ScrbError::Config`] naming the offending knob and its accepted
    /// values. The CLI and sweep drivers use this form.
    pub fn try_build(self) -> Result<PipelineConfig, ScrbError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }

    /// Validate and return the config, panicking on an invalid
    /// combination — the programmatic-builder form, where an invalid
    /// config is a caller bug. Fallible callers (CLI layering, sweep
    /// drivers) use [`PipelineConfigBuilder::try_build`] /
    /// [`PipelineConfig::rebuild`] for the typed error instead.
    pub fn build(self) -> PipelineConfig {
        match self.try_build() {
            Ok(cfg) => cfg,
            Err(e) => panic!("invalid PipelineConfig: {e}"),
        }
    }
}

/// Parse a `key = value` config file (TOML-subset: comments with '#',
/// blank lines ignored, no sections).
pub fn parse_kv_file(text: &str) -> Result<BTreeMap<String, String>, ScrbError> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ScrbError::parse(format!("config line {}: expected key = value", lineno + 1))
        })?;
        let v = v.trim().trim_matches('"').trim_matches('\'');
        map.insert(k.trim().to_string(), v.to_string());
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli() {
        let mut cfg = PipelineConfig::default();
        let file = "k = 10\nsigma = 2.0  # comment\nsolver = lanczos\n";
        cfg.apply_map(&parse_kv_file(file).unwrap()).unwrap();
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.kernel.sigma(), 2.0);
        assert_eq!(cfg.solver, Solver::Lanczos);

        let args = Args::parse(
            "run --k 7 --solver davidson --verbose".split_whitespace().map(String::from),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.solver, Solver::Davidson);
        assert!(cfg.verbose);
        // untouched key keeps file value
        assert_eq!(cfg.kernel.sigma(), 2.0);
    }

    #[test]
    fn builder_sets_every_field() {
        let cfg = PipelineConfig::builder()
            .k(7)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 2.0 })
            .sigma(3.0)
            .seed(9)
            .solver(Solver::Lanczos)
            .engine(Engine::Native)
            .kmeans_replicates(4)
            .kmeans_max_iters(55)
            .svd_tol(1e-7)
            .svd_max_iters(123)
            .embed_dim(9)
            .cheb_order(40)
            .cheb_signals(12)
            .cheb_sample(500)
            .stream(1024, 4096)
            .artifacts_dir("arts")
            .verbose(true)
            .build();
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.r, 512);
        assert_eq!(cfg.kernel, Kernel::Gaussian { sigma: 3.0 });
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.solver, Solver::Lanczos);
        assert_eq!(cfg.engine, Engine::Native);
        assert_eq!(cfg.kmeans_replicates, 4);
        assert_eq!(cfg.kmeans_max_iters, 55);
        assert_eq!(cfg.svd_tol, 1e-7);
        assert_eq!(cfg.svd_max_iters, 123);
        assert_eq!(cfg.embed_dim, Some(9));
        assert_eq!(cfg.cheb_order, 40);
        assert_eq!(cfg.cheb_signals, Some(12));
        assert_eq!(cfg.cheb_sample, Some(500));
        assert_eq!(cfg.stream, Some(StreamConfig { chunk_rows: 1024, block_rows: 4096, shards: 1 }));
        assert!(cfg.sigma_explicit);
        assert_eq!(cfg.artifacts_dir, "arts");
        assert!(cfg.verbose);
        // untouched fields keep their defaults
        let d = PipelineConfig::builder().build();
        assert_eq!(d.k, PipelineConfig::default().k);
        assert_eq!(d.r, PipelineConfig::default().r);
        assert!(!d.sigma_explicit);
        assert_eq!(d.stream, None);
    }

    #[test]
    fn validate_rejects_every_bad_knob() {
        assert!(PipelineConfig::default().validate().is_ok());
        let bad = [
            PipelineConfig { k: 0, ..Default::default() },
            PipelineConfig { r: 0, ..Default::default() },
            PipelineConfig { kernel: Kernel::Laplacian { sigma: 0.0 }, ..Default::default() },
            PipelineConfig {
                kernel: Kernel::Gaussian { sigma: f64::NAN },
                ..Default::default()
            },
            PipelineConfig { kmeans_replicates: 0, ..Default::default() },
            PipelineConfig { kmeans_max_iters: 0, ..Default::default() },
            PipelineConfig { svd_tol: -1.0, ..Default::default() },
            PipelineConfig { svd_max_iters: 0, ..Default::default() },
            PipelineConfig { k: 5, embed_dim: Some(3), ..Default::default() },
            PipelineConfig { cheb_order: 1, ..Default::default() },
            PipelineConfig { cheb_signals: Some(0), ..Default::default() },
            PipelineConfig { k: 5, cheb_sample: Some(3), ..Default::default() },
        ];
        for cfg in bad {
            let err = cfg.validate().unwrap_err();
            assert!(matches!(err, ScrbError::Config(_)), "{err}");
        }
    }

    #[test]
    fn stream_section_requires_explicit_sigma() {
        // stream knobs validated through the same routine
        let bad = PipelineConfig {
            stream: Some(StreamConfig { chunk_rows: 0, block_rows: 64, shards: 1 }),
            sigma_explicit: true,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // zero shards is rejected the same way
        let no_shards = PipelineConfig {
            stream: Some(StreamConfig { chunk_rows: 64, block_rows: 64, shards: 0 }),
            sigma_explicit: true,
            ..Default::default()
        };
        assert!(no_shards.validate().is_err());
        // `.shards()` composes with `.stream()` in either order
        let sharded = PipelineConfig::builder().sigma(0.5).shards(4).stream(64, 64).build();
        assert_eq!(sharded.stream, Some(StreamConfig { chunk_rows: 64, block_rows: 64, shards: 4 }));
        let sharded = PipelineConfig::builder().sigma(0.5).stream(64, 64).shards(4).build();
        assert_eq!(sharded.stream, Some(StreamConfig { chunk_rows: 64, block_rows: 64, shards: 4 }));
        // un-pinned sigma is rejected for streamed fits only
        let unpinned = PipelineConfig {
            stream: Some(StreamConfig { chunk_rows: 64, block_rows: 64, shards: 1 }),
            ..Default::default()
        };
        let err = unpinned.validate().unwrap_err();
        assert!(err.to_string().contains("sigma"), "{err}");
        // builder .sigma() pins it
        let ok = PipelineConfig::builder().sigma(0.5).stream(64, 64).try_build();
        assert!(ok.is_ok());
    }

    #[test]
    fn rebuild_revalidates() {
        let cfg = PipelineConfig::builder().k(3).r(64).build();
        let swept = cfg.rebuild(|b| b.sigma(0.25)).unwrap();
        assert_eq!(swept.kernel.sigma(), 0.25);
        assert_eq!(swept.k, 3);
        assert!(swept.sigma_explicit);
        // the original is untouched
        assert_eq!(cfg.kernel.sigma(), PipelineConfig::default().kernel.sigma());
        // invalid deltas surface as typed config errors, not silent state
        assert!(matches!(cfg.rebuild(|b| b.r(0)), Err(ScrbError::Config(_))));
        assert!(matches!(cfg.rebuild(|b| b.sigma(-2.0)), Err(ScrbError::Config(_))));
    }

    #[test]
    #[should_panic(expected = "invalid PipelineConfig")]
    fn build_panics_on_invalid_combination() {
        let _ = PipelineConfig::builder().k(0).build();
    }

    #[test]
    fn kernel_switch_keeps_sigma() {
        let mut cfg = PipelineConfig::default();
        cfg.apply_kv("sigma", "3.5").unwrap();
        cfg.apply_kv("kernel", "gaussian").unwrap();
        assert_eq!(cfg.kernel, Kernel::Gaussian { sigma: 3.5 });
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply_kv("nope", "1").is_err());
    }

    #[test]
    fn parse_enums() {
        assert_eq!(Solver::parse("primme").unwrap(), Solver::Davidson);
        assert_eq!(Solver::parse("svds").unwrap(), Solver::Lanczos);
        assert_eq!(Solver::parse("csc").unwrap(), Solver::Compressive);
        assert_eq!(Solver::parse("compressive").unwrap(), Solver::Compressive);
        assert_eq!(Engine::parse("xla").unwrap(), Engine::Xla);
        assert!(Kernel::parse("poly", 1.0).is_err());
    }

    #[test]
    fn solver_parse_error_enumerates_every_canonical_name() {
        // derived from Solver::ALL — adding a solver cannot leave the
        // message stale
        let err = Solver::parse("nope").unwrap_err().to_string();
        for s in Solver::ALL {
            assert!(err.contains(s.name()), "'{err}' missing '{}'", s.name());
        }
        // round-trip: every canonical name parses back to its variant
        for s in Solver::ALL {
            assert_eq!(Solver::parse(s.name()).unwrap(), s);
        }
    }

    #[test]
    fn cheb_knobs_layer_through_file_and_cli() {
        let mut cfg = PipelineConfig::default();
        let file = "solver = compressive\ncheb_order = 30\ncheb_signals = 8\n";
        cfg.apply_map(&parse_kv_file(file).unwrap()).unwrap();
        assert_eq!(cfg.solver, Solver::Compressive);
        assert_eq!(cfg.cheb_order, 30);
        assert_eq!(cfg.cheb_signals, Some(8));
        assert_eq!(cfg.cheb_sample, None);
        let args = Args::parse(
            "run --cheb_order 50 --cheb_sample 400".split_whitespace().map(String::from),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.cheb_order, 50);
        assert_eq!(cfg.cheb_sample, Some(400));
        assert_eq!(cfg.cheb_signals, Some(8)); // untouched key keeps file value
        assert!(cfg.validate().is_ok());
    }
}
