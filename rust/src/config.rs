//! Typed configuration for the clustering pipeline and experiment drivers.
//!
//! Configs come from (lowest to highest precedence): built-in defaults, an
//! optional `key = value` config file (`--config path`), then CLI options.

use crate::cli::Args;
use std::collections::BTreeMap;
use std::fmt;

/// Similarity kernel for graph construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = exp(-||x-y||_1 / sigma). RB's native kernel (p(ω)∝ω·k″(ω) is
    /// Gamma(2, σ)); RF approximates it with Cauchy-distributed ω.
    Laplacian { sigma: f64 },
    /// k(x,y) = exp(-||x-y||² / (2σ²)). RF approximates it with Normal ω.
    Gaussian { sigma: f64 },
}

impl Kernel {
    pub fn sigma(&self) -> f64 {
        match self {
            Kernel::Laplacian { sigma } | Kernel::Gaussian { sigma } => *sigma,
        }
    }

    pub fn with_sigma(&self, sigma: f64) -> Kernel {
        match self {
            Kernel::Laplacian { .. } => Kernel::Laplacian { sigma },
            Kernel::Gaussian { .. } => Kernel::Gaussian { sigma },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Laplacian { .. } => "laplacian",
            Kernel::Gaussian { .. } => "gaussian",
        }
    }

    pub fn parse(name: &str, sigma: f64) -> Result<Kernel, String> {
        match name {
            "laplacian" | "lap" | "l1" => Ok(Kernel::Laplacian { sigma }),
            "gaussian" | "rbf" | "l2" => Ok(Kernel::Gaussian { sigma }),
            other => Err(format!("unknown kernel '{other}' (laplacian|gaussian)")),
        }
    }
}

/// Which iterative SVD solver backs step 3 of Algorithm 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// PRIMME-analogue: block Generalized-Davidson (GD+k) with thick restart.
    Davidson,
    /// Matlab-`svds` analogue: restarted Lanczos bidiagonalization.
    Lanczos,
}

impl Solver {
    pub fn parse(s: &str) -> Result<Solver, String> {
        match s {
            "davidson" | "primme" | "gd+k" => Ok(Solver::Davidson),
            "lanczos" | "svds" | "lbd" => Ok(Solver::Lanczos),
            other => Err(format!("unknown solver '{other}' (davidson|lanczos)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Solver::Davidson => "davidson",
            Solver::Lanczos => "lanczos",
        }
    }
}

/// Dense-compute engine: native Rust or AOT-compiled XLA artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Native,
    Xla,
    /// Use XLA when artifacts are present, otherwise native.
    Auto,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine, String> {
        match s {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            "auto" => Ok(Engine::Auto),
            other => Err(format!("unknown engine '{other}' (native|xla|auto)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Xla => "xla",
            Engine::Auto => "auto",
        }
    }
}

/// Full pipeline configuration (Algorithm 2 + baselines).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of clusters K.
    pub k: usize,
    /// Number of RB grids / RF features / landmarks R (method-dependent rank).
    pub r: usize,
    pub kernel: Kernel,
    pub seed: u64,
    pub solver: Solver,
    pub engine: Engine,
    /// K-means replicates (paper: Matlab kmeans with 10 replicates).
    pub kmeans_replicates: usize,
    pub kmeans_max_iters: usize,
    /// Eigensolver convergence tolerance (paper §5.3 uses 1e-5).
    pub svd_tol: f64,
    pub svd_max_iters: usize,
    /// Directory with AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    pub verbose: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k: 2,
            r: 256,
            kernel: Kernel::Laplacian { sigma: 1.0 },
            seed: 42,
            solver: Solver::Davidson,
            engine: Engine::Auto,
            kmeans_replicates: 10,
            kmeans_max_iters: 100,
            svd_tol: 1e-5,
            svd_max_iters: 3000,
            artifacts_dir: "artifacts".to_string(),
            verbose: false,
        }
    }
}

impl PipelineConfig {
    /// Apply a parsed `key = value` map (config file layer).
    pub fn apply_map(&mut self, map: &BTreeMap<String, String>) -> Result<(), String> {
        for (k, v) in map {
            self.apply_kv(k, v)?;
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, val: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("config: bad value '{v}' for '{k}'");
        match key {
            "k" => self.k = val.parse().map_err(|_| bad(key, val))?,
            "r" => self.r = val.parse().map_err(|_| bad(key, val))?,
            "sigma" => {
                let s: f64 = val.parse().map_err(|_| bad(key, val))?;
                self.kernel = self.kernel.with_sigma(s);
            }
            "kernel" => self.kernel = Kernel::parse(val, self.kernel.sigma())?,
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "solver" => self.solver = Solver::parse(val)?,
            "engine" => self.engine = Engine::parse(val)?,
            "kmeans_replicates" => {
                self.kmeans_replicates = val.parse().map_err(|_| bad(key, val))?
            }
            "kmeans_max_iters" => self.kmeans_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "svd_tol" => self.svd_tol = val.parse().map_err(|_| bad(key, val))?,
            "svd_max_iters" => self.svd_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "verbose" => self.verbose = val.parse().map_err(|_| bad(key, val))?,
            other => return Err(format!("config: unknown key '{other}'")),
        }
        Ok(())
    }

    /// Apply CLI options (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), String> {
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("cannot read config '{path}': {e}"))?;
            self.apply_map(&parse_kv_file(&text)?)?;
        }
        for key in [
            "k",
            "r",
            "sigma",
            "kernel",
            "seed",
            "solver",
            "engine",
            "kmeans_replicates",
            "kmeans_max_iters",
            "svd_tol",
            "svd_max_iters",
            "artifacts_dir",
        ] {
            if let Some(v) = args.get(key) {
                self.apply_kv(key, v)?;
            }
        }
        if args.flag("verbose") {
            self.verbose = true;
        }
        Ok(())
    }
}

impl fmt::Display for PipelineConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "k={} r={} kernel={}(sigma={}) solver={} engine={} seed={}",
            self.k,
            self.r,
            self.kernel.name(),
            self.kernel.sigma(),
            self.solver.name(),
            self.engine.name(),
            self.seed
        )
    }
}

/// Parse a `key = value` config file (TOML-subset: comments with '#',
/// blank lines ignored, no sections).
pub fn parse_kv_file(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("config line {}: expected key = value", lineno + 1))?;
        let v = v.trim().trim_matches('"').trim_matches('\'');
        map.insert(k.trim().to_string(), v.to_string());
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli() {
        let mut cfg = PipelineConfig::default();
        let file = "k = 10\nsigma = 2.0  # comment\nsolver = lanczos\n";
        cfg.apply_map(&parse_kv_file(file).unwrap()).unwrap();
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.kernel.sigma(), 2.0);
        assert_eq!(cfg.solver, Solver::Lanczos);

        let args = Args::parse(
            "run --k 7 --solver davidson --verbose".split_whitespace().map(String::from),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.solver, Solver::Davidson);
        assert!(cfg.verbose);
        // untouched key keeps file value
        assert_eq!(cfg.kernel.sigma(), 2.0);
    }

    #[test]
    fn kernel_switch_keeps_sigma() {
        let mut cfg = PipelineConfig::default();
        cfg.apply_kv("sigma", "3.5").unwrap();
        cfg.apply_kv("kernel", "gaussian").unwrap();
        assert_eq!(cfg.kernel, Kernel::Gaussian { sigma: 3.5 });
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply_kv("nope", "1").is_err());
    }

    #[test]
    fn parse_enums() {
        assert_eq!(Solver::parse("primme").unwrap(), Solver::Davidson);
        assert_eq!(Solver::parse("svds").unwrap(), Solver::Lanczos);
        assert_eq!(Engine::parse("xla").unwrap(), Engine::Xla);
        assert!(Kernel::parse("poly", 1.0).is_err());
    }
}
