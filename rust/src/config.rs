//! Typed configuration for the clustering pipeline and experiment drivers.
//!
//! Configs come from (lowest to highest precedence): built-in defaults, an
//! optional `key = value` config file (`--config path`), then CLI options.
//! Programmatic callers use the fluent [`PipelineConfig::builder`]:
//!
//! ```
//! use scrb::config::{Kernel, PipelineConfig};
//! let cfg = PipelineConfig::builder()
//!     .k(2)
//!     .r(256)
//!     .kernel(Kernel::Laplacian { sigma: 0.15 })
//!     .build();
//! assert_eq!(cfg.k, 2);
//! ```

use crate::cli::Args;
use crate::error::ScrbError;
use std::collections::BTreeMap;
use std::fmt;

/// Similarity kernel for graph construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// k(x,y) = exp(-||x-y||_1 / sigma). RB's native kernel (p(ω)∝ω·k″(ω) is
    /// Gamma(2, σ)); RF approximates it with Cauchy-distributed ω.
    Laplacian { sigma: f64 },
    /// k(x,y) = exp(-||x-y||² / (2σ²)). RF approximates it with Normal ω.
    Gaussian { sigma: f64 },
}

impl Kernel {
    pub fn sigma(&self) -> f64 {
        match self {
            Kernel::Laplacian { sigma } | Kernel::Gaussian { sigma } => *sigma,
        }
    }

    pub fn with_sigma(&self, sigma: f64) -> Kernel {
        match self {
            Kernel::Laplacian { .. } => Kernel::Laplacian { sigma },
            Kernel::Gaussian { .. } => Kernel::Gaussian { sigma },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Laplacian { .. } => "laplacian",
            Kernel::Gaussian { .. } => "gaussian",
        }
    }

    pub fn parse(name: &str, sigma: f64) -> Result<Kernel, ScrbError> {
        match name {
            "laplacian" | "lap" | "l1" => Ok(Kernel::Laplacian { sigma }),
            "gaussian" | "rbf" | "l2" => Ok(Kernel::Gaussian { sigma }),
            other => Err(ScrbError::config(format!("unknown kernel '{other}' (laplacian|gaussian)"))),
        }
    }
}

/// Which iterative SVD solver backs step 3 of Algorithm 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// PRIMME-analogue: block Generalized-Davidson (GD+k) with thick restart.
    Davidson,
    /// Matlab-`svds` analogue: restarted Lanczos bidiagonalization.
    Lanczos,
}

impl Solver {
    pub fn parse(s: &str) -> Result<Solver, ScrbError> {
        match s {
            "davidson" | "primme" | "gd+k" => Ok(Solver::Davidson),
            "lanczos" | "svds" | "lbd" => Ok(Solver::Lanczos),
            other => Err(ScrbError::config(format!("unknown solver '{other}' (davidson|lanczos)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Solver::Davidson => "davidson",
            Solver::Lanczos => "lanczos",
        }
    }
}

/// Dense-compute engine: native Rust or AOT-compiled XLA artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Native,
    Xla,
    /// Use XLA when artifacts are present, otherwise native.
    Auto,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine, ScrbError> {
        match s {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            "auto" => Ok(Engine::Auto),
            other => Err(ScrbError::config(format!("unknown engine '{other}' (native|xla|auto)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Xla => "xla",
            Engine::Auto => "auto",
        }
    }
}

/// Full pipeline configuration (Algorithm 2 + baselines).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of clusters K.
    pub k: usize,
    /// Number of RB grids / RF features / landmarks R (method-dependent rank).
    pub r: usize,
    pub kernel: Kernel,
    pub seed: u64,
    pub solver: Solver,
    pub engine: Engine,
    /// K-means replicates (paper: Matlab kmeans with 10 replicates).
    pub kmeans_replicates: usize,
    pub kmeans_max_iters: usize,
    /// Eigensolver convergence tolerance (paper §5.3 uses 1e-5).
    pub svd_tol: f64,
    pub svd_max_iters: usize,
    /// Directory with AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    pub verbose: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k: 2,
            r: 256,
            kernel: Kernel::Laplacian { sigma: 1.0 },
            seed: 42,
            solver: Solver::Davidson,
            engine: Engine::Auto,
            kmeans_replicates: 10,
            kmeans_max_iters: 100,
            svd_tol: 1e-5,
            svd_max_iters: 3000,
            artifacts_dir: "artifacts".to_string(),
            verbose: false,
        }
    }
}

impl PipelineConfig {
    /// Start a fluent builder seeded with the defaults:
    /// `PipelineConfig::builder().k(2).r(256).build()`.
    pub fn builder() -> PipelineConfigBuilder {
        PipelineConfigBuilder::default()
    }

    /// Apply a parsed `key = value` map (config file layer).
    pub fn apply_map(&mut self, map: &BTreeMap<String, String>) -> Result<(), ScrbError> {
        for (k, v) in map {
            self.apply_kv(k, v)?;
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, val: &str) -> Result<(), ScrbError> {
        let bad = |k: &str, v: &str| ScrbError::config(format!("config: bad value '{v}' for '{k}'"));
        match key {
            "k" => self.k = val.parse().map_err(|_| bad(key, val))?,
            "r" => self.r = val.parse().map_err(|_| bad(key, val))?,
            "sigma" => {
                let s: f64 = val.parse().map_err(|_| bad(key, val))?;
                self.kernel = self.kernel.with_sigma(s);
            }
            "kernel" => self.kernel = Kernel::parse(val, self.kernel.sigma())?,
            "seed" => self.seed = val.parse().map_err(|_| bad(key, val))?,
            "solver" => self.solver = Solver::parse(val)?,
            "engine" => self.engine = Engine::parse(val)?,
            "kmeans_replicates" => {
                self.kmeans_replicates = val.parse().map_err(|_| bad(key, val))?
            }
            "kmeans_max_iters" => self.kmeans_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "svd_tol" => self.svd_tol = val.parse().map_err(|_| bad(key, val))?,
            "svd_max_iters" => self.svd_max_iters = val.parse().map_err(|_| bad(key, val))?,
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "verbose" => self.verbose = val.parse().map_err(|_| bad(key, val))?,
            other => return Err(ScrbError::config(format!("config: unknown key '{other}'"))),
        }
        Ok(())
    }

    /// Apply CLI options (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), ScrbError> {
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path).map_err(|e| ScrbError::io(path, e))?;
            self.apply_map(&parse_kv_file(&text)?)?;
        }
        for key in [
            "k",
            "r",
            "sigma",
            "kernel",
            "seed",
            "solver",
            "engine",
            "kmeans_replicates",
            "kmeans_max_iters",
            "svd_tol",
            "svd_max_iters",
            "artifacts_dir",
        ] {
            if let Some(v) = args.get(key) {
                self.apply_kv(key, v)?;
            }
        }
        if args.flag("verbose") {
            self.verbose = true;
        }
        Ok(())
    }
}

impl fmt::Display for PipelineConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "k={} r={} kernel={}(sigma={}) solver={} engine={} seed={}",
            self.k,
            self.r,
            self.kernel.name(),
            self.kernel.sigma(),
            self.solver.name(),
            self.engine.name(),
            self.seed
        )
    }
}

/// Fluent builder for [`PipelineConfig`], seeded with the defaults. Each
/// setter consumes and returns the builder, so configs assemble in one
/// expression instead of the mutate-a-default pattern.
#[derive(Clone, Debug, Default)]
pub struct PipelineConfigBuilder {
    cfg: PipelineConfig,
}

impl PipelineConfigBuilder {
    /// Number of clusters K.
    pub fn k(mut self, k: usize) -> Self {
        self.cfg.k = k;
        self
    }

    /// Number of RB grids / RF features / landmarks R.
    pub fn r(mut self, r: usize) -> Self {
        self.cfg.r = r;
        self
    }

    /// Similarity kernel (kind + bandwidth).
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.cfg.kernel = kernel;
        self
    }

    /// Kernel bandwidth, keeping the current kernel kind.
    pub fn sigma(mut self, sigma: f64) -> Self {
        self.cfg.kernel = self.cfg.kernel.with_sigma(sigma);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn solver(mut self, solver: Solver) -> Self {
        self.cfg.solver = solver;
        self
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.cfg.engine = engine;
        self
    }

    pub fn kmeans_replicates(mut self, n: usize) -> Self {
        self.cfg.kmeans_replicates = n;
        self
    }

    pub fn kmeans_max_iters(mut self, n: usize) -> Self {
        self.cfg.kmeans_max_iters = n;
        self
    }

    pub fn svd_tol(mut self, tol: f64) -> Self {
        self.cfg.svd_tol = tol;
        self
    }

    pub fn svd_max_iters(mut self, n: usize) -> Self {
        self.cfg.svd_max_iters = n;
        self
    }

    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn verbose(mut self, verbose: bool) -> Self {
        self.cfg.verbose = verbose;
        self
    }

    pub fn build(self) -> PipelineConfig {
        self.cfg
    }
}

/// Parse a `key = value` config file (TOML-subset: comments with '#',
/// blank lines ignored, no sections).
pub fn parse_kv_file(text: &str) -> Result<BTreeMap<String, String>, ScrbError> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ScrbError::parse(format!("config line {}: expected key = value", lineno + 1))
        })?;
        let v = v.trim().trim_matches('"').trim_matches('\'');
        map.insert(k.trim().to_string(), v.to_string());
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli() {
        let mut cfg = PipelineConfig::default();
        let file = "k = 10\nsigma = 2.0  # comment\nsolver = lanczos\n";
        cfg.apply_map(&parse_kv_file(file).unwrap()).unwrap();
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.kernel.sigma(), 2.0);
        assert_eq!(cfg.solver, Solver::Lanczos);

        let args = Args::parse(
            "run --k 7 --solver davidson --verbose".split_whitespace().map(String::from),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.solver, Solver::Davidson);
        assert!(cfg.verbose);
        // untouched key keeps file value
        assert_eq!(cfg.kernel.sigma(), 2.0);
    }

    #[test]
    fn builder_sets_every_field() {
        let cfg = PipelineConfig::builder()
            .k(7)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 2.0 })
            .sigma(3.0)
            .seed(9)
            .solver(Solver::Lanczos)
            .engine(Engine::Native)
            .kmeans_replicates(4)
            .kmeans_max_iters(55)
            .svd_tol(1e-7)
            .svd_max_iters(123)
            .artifacts_dir("arts")
            .verbose(true)
            .build();
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.r, 512);
        assert_eq!(cfg.kernel, Kernel::Gaussian { sigma: 3.0 });
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.solver, Solver::Lanczos);
        assert_eq!(cfg.engine, Engine::Native);
        assert_eq!(cfg.kmeans_replicates, 4);
        assert_eq!(cfg.kmeans_max_iters, 55);
        assert_eq!(cfg.svd_tol, 1e-7);
        assert_eq!(cfg.svd_max_iters, 123);
        assert_eq!(cfg.artifacts_dir, "arts");
        assert!(cfg.verbose);
        // untouched fields keep their defaults
        let d = PipelineConfig::builder().build();
        assert_eq!(d.k, PipelineConfig::default().k);
        assert_eq!(d.r, PipelineConfig::default().r);
    }

    #[test]
    fn kernel_switch_keeps_sigma() {
        let mut cfg = PipelineConfig::default();
        cfg.apply_kv("sigma", "3.5").unwrap();
        cfg.apply_kv("kernel", "gaussian").unwrap();
        assert_eq!(cfg.kernel, Kernel::Gaussian { sigma: 3.5 });
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply_kv("nope", "1").is_err());
    }

    #[test]
    fn parse_enums() {
        assert_eq!(Solver::parse("primme").unwrap(), Solver::Davidson);
        assert_eq!(Solver::parse("svds").unwrap(), Solver::Lanczos);
        assert_eq!(Engine::parse("xla").unwrap(), Engine::Xla);
        assert!(Kernel::parse("poly", 1.0).is_err());
    }
}
