//! `scrb` — CLI for the SC_RB reproduction.
//!
//! Commands:
//!   scrb info                         environment + artifact status
//!   scrb run <dataset> [opts]         one method on one benchmark
//!   scrb table <1|2|3> [opts]         regenerate a paper table
//!   scrb fig <2|3|4|5|theory> [opts]  regenerate a paper figure's data
//!
//! Common options: --method NAME --r N --sigma S --kernel laplacian|gaussian
//! --k K --seed S --solver davidson|lanczos --engine native|xla|auto
//! --scale DIV (dataset size divisor; --full = paper sizes) --verbose
//! --data path.libsvm (real data instead of the synthetic stand-in)

// Same clippy posture as the library crate root (CI: -D warnings).
#![allow(clippy::needless_range_loop, clippy::field_reassign_with_default)]

use scrb::cli::Args;
use scrb::cluster::MethodKind;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::data;
use scrb::util::table::fnum;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    match args.command.as_str() {
        "" | "help" => {
            print_help();
            Ok(())
        }
        "info" => cmd_info(args),
        "run" => cmd_run(args),
        "table" => cmd_table(args),
        "fig" => cmd_fig(args),
        other => Err(format!("unknown command '{other}' (try: scrb help)")),
    }
}

fn print_help() {
    println!(
        "scrb {} — Scalable Spectral Clustering using Random Binning features (KDD'18)\n\n\
         usage: scrb <command> [options]\n\n\
         commands:\n\
         \x20 info                        environment + artifacts status\n\
         \x20 run <dataset>               run one method (default SC_RB) on a benchmark\n\
         \x20 table <1|2|3>               regenerate a paper table\n\
         \x20 fig <2|3|4|5|theory>        regenerate a paper figure's series\n\n\
         common options:\n\
         \x20 --method NAME   one of: {}\n\
         \x20 --r N           grids/features/landmarks rank (default 256)\n\
         \x20 --sigma S       kernel bandwidth (default: median heuristic)\n\
         \x20 --kernel NAME   laplacian (RB-native) | gaussian\n\
         \x20 --solver NAME   davidson (PRIMME-like) | lanczos (svds-like)\n\
         \x20 --engine NAME   native | xla | auto (default auto)\n\
         \x20 --scale DIV     dataset size divisor (default 64); --full = paper sizes\n\
         \x20 --data PATH     load a real LibSVM file instead of synthetic data\n\
         \x20 --seed N --verbose",
        scrb::VERSION,
        MethodKind::ALL.map(|m| m.name()).join(", ")
    );
}

fn base_config(args: &Args) -> Result<PipelineConfig, String> {
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn scale_of(args: &Args) -> Result<usize, String> {
    if args.flag("full") {
        Ok(1)
    } else {
        args.get_usize("scale", 64)
    }
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    println!("scrb {}", scrb::VERSION);
    println!("threads: {}", scrb::util::threads::num_threads());
    println!("config: {cfg}");
    match scrb::runtime::Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("artifacts: {} entries in {}/", m.entries.len(), cfg.artifacts_dir);
            for e in &m.entries {
                println!(
                    "  {:<36} kind={:?} tile={} dim={} kp={} r={}",
                    e.name, e.kind, e.tile, e.dim, e.kp, e.r
                );
            }
            match scrb::runtime::XlaRuntime::load(&cfg.artifacts_dir) {
                Ok(_) => println!("PJRT CPU client: ok"),
                Err(e) => println!("PJRT CPU client: FAILED ({e:#})"),
            }
        }
        Err(e) => println!("artifacts: not available ({e}); run `make artifacts`"),
    }
    println!("benchmarks: {}", data::PAPER_BENCHMARKS.map(|s| s.name).join(", "));
    Ok(())
}

fn load_dataset(args: &Args, coord: &Coordinator) -> Result<data::Dataset, String> {
    if let Some(path) = args.get("data") {
        let mut ds = data::load_libsvm(path)?;
        ds.minmax_normalize();
        return Ok(ds);
    }
    let name = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "pendigits".to_string());
    Ok(experiment::dataset(coord, &name))
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let method = MethodKind::parse(args.get_or("method", "sc_rb"))?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    let ds = load_dataset(args, &coord)?;
    println!("dataset {} n={} d={} k={}", ds.name, ds.n(), ds.d(), ds.k);
    let sigma = args.get_f64("sigma", f64::NAN).ok().filter(|s| s.is_finite());
    let run = experiment::single_run(&coord, method, &ds, sigma);
    println!(
        "{}: acc={:.3} nmi={:.3} ri={:.3} fm={:.3} time={}s",
        run.method.name(),
        run.metrics.accuracy,
        run.metrics.nmi,
        run.metrics.rand_index,
        run.metrics.f_measure,
        fnum(run.secs)
    );
    for (stage, secs) in &run.stages {
        println!("  {stage}: {}s", fnum(*secs));
    }
    if let Some(k) = run.kappa {
        println!("  kappa: {k:.2} (Definition 1)");
    }
    if run.svd_matvecs > 0 {
        println!("  svd matvecs: {} converged: {}", run.svd_matvecs, run.svd_converged);
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("2");
    let scale = scale_of(args)?;
    match which {
        "1" => {
            println!("{}", report::render_table1(scale));
            Ok(())
        }
        "2" | "3" | "23" => {
            let cfg = base_config(args)?;
            let coord = Coordinator::new(cfg, scale);
            let names: Vec<String> = args.get_str_list("datasets", &experiment::TABLE_DATASETS);
            let grid = experiment::table2_3(&coord, &names);
            println!("Table 2: average rank scores (lower = better), R={}", coord.base_cfg.r);
            println!("{}", report::render_table2(&grid));
            println!("Table 3: computational time (seconds)");
            println!("{}", report::render_table3(&grid));
            if args.flag("detail") {
                println!("{}", report::render_detail(&grid));
            }
            let json = report::grid_to_json(&grid).to_string();
            let path = report::save("table2_3.json", &json).map_err(|e| e.to_string())?;
            eprintln!("[saved {path}]");
            Ok(())
        }
        other => Err(format!("unknown table '{other}' (1|2|3)")),
    }
}

fn cmd_fig(args: &Args) -> Result<(), String> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("2");
    let cfg = base_config(args)?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    match which {
        "2" => {
            let rs = args.get_usize_list("rs", &[16, 64, 256, 1024, 4096])?;
            let rb_max = args.get_usize("rb-max-r", 1024)?;
            let fig = experiment::fig2(&coord, &rs, rb_max);
            println!("{}", report::render_fig2(&fig));
        }
        "3" => {
            let rs = args.get_usize_list("rs", &[16, 32, 64, 128])?;
            let series = experiment::fig3(&coord, &rs);
            println!(
                "{}",
                report::render_series("Fig. 3: SVD solver comparison (covtype-like)", &series, "R")
            );
        }
        "4" => {
            let name = args.get_or("dataset", "poker").to_string();
            let ns = args.get_usize_list("ns", &[1_000, 4_000, 16_000, 64_000, 256_000])?;
            let r = args.get_usize("r", 256)?;
            let points = experiment::fig4(&coord, &name, &ns, r);
            println!("{}", report::render_fig4(&name, &points));
        }
        "5" => {
            let rs = args.get_usize_list("rs", &[16, 64, 256, 1024])?;
            let names = args.get_str_list("datasets", &["pendigits", "letter", "mnist", "acoustic"]);
            for name in names {
                let series = experiment::fig5(&coord, &name, &rs);
                println!(
                    "{}",
                    report::render_series(
                        &format!("Fig. 5: runtime vs R ({name})"),
                        &series,
                        "R"
                    )
                );
            }
        }
        "theory" => {
            let n = args.get_usize("n", 300)?;
            let rs = args.get_usize_list("rs", &[4, 8, 16, 32, 64, 128, 256])?;
            let points = experiment::theory_convergence(&coord, n, &rs);
            println!("{}", report::render_theory(&points));
        }
        other => return Err(format!("unknown figure '{other}' (2|3|4|5|theory)")),
    }
    Ok(())
}
