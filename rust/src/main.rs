//! `scrb` — CLI for the SC_RB reproduction.
//!
//! Commands:
//!   scrb info                         environment + artifact status
//!   scrb run <dataset> [opts]         one method on one benchmark (batch)
//!   scrb fit [dataset] --save m.scrb  fit SC_RB once, persist the model
//!   scrb fit --stream --data f.libsvm --chunk-rows M --sigma S --save m.scrb
//!                                     out-of-core fit (bounded input memory)
//!   scrb predict --model m.scrb ...   label new points with a saved model
//!   scrb update --model m.scrb --data new.libsvm --save m2.scrb
//!                                     absorb new data incrementally; escalates
//!                                     to a full refit when drift demands it
//!   scrb table <1|2|3> [opts]         regenerate a paper table
//!   scrb fig <2|3|4|5|theory> [opts]  regenerate a paper figure's data
//!
//! Common options: --method NAME --r N --sigma S --kernel laplacian|gaussian
//! --k K --seed S --solver davidson|lanczos|compressive --engine native|xla|auto
//! --cheb_order P --cheb_signals N --cheb_sample M (compressive-solver knobs)
//! --scale DIV (dataset size divisor; --full = paper sizes) --verbose
//! --data path.libsvm (real data instead of the synthetic stand-in)

// Same clippy posture as the library crate root (CI: -D warnings).
#![allow(clippy::needless_range_loop)]

use scrb::cli::Args;
use scrb::cluster::{Env, MethodKind};
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};
use scrb::data;
use scrb::error::ScrbError;
use scrb::metrics::all_metrics;
use scrb::model::{FittedModel, ScRbModel, ServeWorkspace};
use scrb::util::table::fnum;
use std::time::Instant;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<(), ScrbError> {
    match args.command.as_str() {
        "" | "help" => {
            print_help();
            Ok(())
        }
        "info" => cmd_info(args),
        "run" => cmd_run(args),
        "fit" => cmd_fit(args),
        "predict" => cmd_predict(args),
        "update" => cmd_update(args),
        "serve" => cmd_serve(args),
        "table" => cmd_table(args),
        "fig" => cmd_fig(args),
        other => Err(ScrbError::config(format!("unknown command '{other}' (try: scrb help)"))),
    }
}

fn print_help() {
    println!(
        "scrb {} — Scalable Spectral Clustering using Random Binning features (KDD'18)\n\n\
         usage: scrb <command> [options]\n\n\
         commands:\n\
         \x20 info                        environment + artifacts status\n\
         \x20 run <dataset>               run one method (default SC_RB) on a benchmark\n\
         \x20 fit [dataset]               fit SC_RB once and persist the model\n\
         \x20   --save PATH                 model artifact to write (required)\n\
         \x20   --stream                    out-of-core fit from --data (two chunked passes;\n\
         \x20                               requires --sigma; input memory ~ chunk_rows x d)\n\
         \x20                               --data takes comma-separated paths and/or\n\
         \x20                               name globs (*.libsvm) for multi-file datasets\n\
         \x20   --chunk-rows M              rows per streamed chunk (default 4096)\n\
         \x20   --block-rows M              substrate block granularity (default 65536)\n\
         \x20   --shards K                  parallel featurization shards (default 1);\n\
         \x20                               any K yields bit-identical model bytes\n\
         \x20   --on-bad-record P           strict (fail on first bad line, default) |\n\
         \x20                               quarantine (skip, count, sample offenders)\n\
         \x20   --quarantine-sample N       offender samples kept in the report (default 16)\n\
         \x20   --max-retries N             transient-error retries per read (default 3)\n\
         \x20   --checkpoint DIR            persist resumable fit state into DIR\n\
         \x20   --checkpoint-every N        rows between state saves (default 262144)\n\
         \x20   --resume                    continue from DIR's checkpoint after a kill\n\
         \x20 predict                     label points with a saved model\n\
         \x20   --model PATH                model artifact from `scrb fit --save`\n\
         \x20   --out PATH                  write one label per line (optional)\n\
         \x20   --unseen-warn T             warn when a call's unseen-bin rate exceeds T\n\
         \x20                               (default 0.25; rate is printed after predict)\n\
         \x20 update                      maintain a saved model from new data\n\
         \x20   --model PATH                model to update (from `scrb fit --save`)\n\
         \x20   --data PATH                 new rows (LibSVM), streamed in chunks\n\
         \x20   --save PATH                 updated (or refitted) model to write\n\
         \x20   --chunk-rows M              rows per streamed chunk (default 4096)\n\
         \x20   --update-block M            rows per incremental-SVD fold (default 64)\n\
         \x20   --ewma A                    drift EWMA decay (default 0.3)\n\
         \x20   --unseen-refit T            unseen-bin-rate EWMA refit trigger (0.2)\n\
         \x20   --residual-refit T          subspace-residual EWMA refit trigger (0.98)\n\
         \x20   --residual-tol T            fold gate for no-admission chunks (0.999)\n\
         \x20   --lloyd-iters N             warm-start k-means polish passes (3)\n\
         \x20   --on-bad-record P           strict | quarantine (as in fit --stream)\n\
         \x20   --refit                     on a drift signal, run the full streamed\n\
         \x20                               refit (model-frozen r/sigma/k/seed) over\n\
         \x20                               --refit-data (default: --data)\n\
         \x20   --swap HOST:PORT            publish the saved model to a running\n\
         \x20                               daemon via validated hot swap\n\
         \x20 serve                       serve a saved model as a daemon (TCP)\n\
         \x20   --model PATH                model artifact from `scrb fit --save`\n\
         \x20   --addr HOST:PORT            bind address (default 127.0.0.1:7878)\n\
         \x20   --workers N                 micro-batching worker threads (default 2)\n\
         \x20   --queue-cap N               admission queue bound; beyond it requests\n\
         \x20                               are shed with a typed Overloaded reject (256)\n\
         \x20   --max-batch N               requests coalesced per predict call (64)\n\
         \x20   --deadline-ms N             default per-request deadline (1000)\n\
         \x20   --max-frame-mb N            per-frame payload cap (64)\n\
         \x20                               SIGTERM or a Drain frame exits gracefully\n\
         \x20 table <1|2|3>               regenerate a paper table\n\
         \x20 fig <2|3|4|5|theory>        regenerate a paper figure's series\n\n\
         common options:\n\
         \x20 --method NAME   one of: {}\n\
         \x20 --r N           grids/features/landmarks rank (default 256)\n\
         \x20 --sigma S       kernel bandwidth (default: median heuristic)\n\
         \x20 --kernel NAME   laplacian (RB-native) | gaussian\n\
         \x20 --solver NAME   davidson (PRIMME-like) | lanczos (svds-like) |\n\
         \x20                 compressive (Chebyshev filter, CSC)\n\
         \x20 --cheb_order P  compressive filter order (default 25; higher = sharper\n\
         \x20                 spectral cut, linearly more gram products)\n\
         \x20 --cheb_signals N  compressive random signals (default: O(log n))\n\
         \x20 --cheb_sample M   rows clustered before label interpolation\n\
         \x20                 (default: max(100, 4K·ln n))\n\
         \x20 --embed_dim N   spectral embedding width (default: K; pin it so a\n\
         \x20                 k-sweep reuses one cached embedding artifact)\n\
         \x20 --engine NAME   native | xla | auto (default auto)\n\
         \x20 --scale DIV     dataset size divisor (default 64); --full = paper sizes\n\
         \x20 --data PATH     load a real LibSVM file instead of synthetic data\n\
         \x20 --seed N --verbose\n\n\
         serving example:\n\
         \x20 scrb fit pendigits --save m.scrb && scrb predict --model m.scrb pendigits",
        scrb::VERSION,
        MethodKind::ALL.map(|m| m.name()).join(", ")
    );
}

fn base_config(args: &Args) -> Result<PipelineConfig, ScrbError> {
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(args)?;
    // one validation routine for every fit path (defaults + file + CLI
    // layering can combine into invalid states; reject them typed here)
    cfg.validate()?;
    Ok(cfg)
}

fn scale_of(args: &Args) -> Result<usize, ScrbError> {
    if args.flag("full") {
        Ok(1)
    } else {
        args.get_usize("scale", 64)
    }
}

fn cmd_info(args: &Args) -> Result<(), ScrbError> {
    let cfg = base_config(args)?;
    println!("scrb {}", scrb::VERSION);
    println!("threads: {}", scrb::util::threads::num_threads());
    println!("config: {cfg}");
    match scrb::runtime::Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("artifacts: {} entries in {}/", m.entries.len(), cfg.artifacts_dir);
            for e in &m.entries {
                println!(
                    "  {:<36} kind={:?} tile={} dim={} kp={} r={}",
                    e.name, e.kind, e.tile, e.dim, e.kp, e.r
                );
            }
            match scrb::runtime::XlaRuntime::load(&cfg.artifacts_dir) {
                Ok(_) => println!("PJRT CPU client: ok"),
                Err(e) => println!("PJRT CPU client: FAILED ({e:#})"),
            }
        }
        Err(e) => println!("artifacts: not available ({e}); run `make artifacts`"),
    }
    println!("benchmarks: {}", data::PAPER_BENCHMARKS.map(|s| s.name).join(", "));
    Ok(())
}

/// Load the requested dataset **without** normalizing it; the bool says
/// whether it came from a `--data` file (synthetic benchmarks are already
/// in their generated frame).
fn load_dataset_raw(args: &Args, coord: &Coordinator) -> Result<(data::Dataset, bool), ScrbError> {
    if let Some(path) = args.get("data") {
        return Ok((data::load_libsvm(path)?, true));
    }
    let name = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "pendigits".to_string());
    Ok((experiment::dataset(coord, &name), false))
}

/// Batch-local loading for the one-shot commands (`run`): `--data` files
/// are min-max normalized by their own statistics.
fn load_dataset(args: &Args, coord: &Coordinator) -> Result<data::Dataset, ScrbError> {
    let (mut ds, from_file) = load_dataset_raw(args, coord)?;
    if from_file {
        ds.minmax_normalize();
    }
    Ok(ds)
}

/// `--sigma` if present: absence is None, a malformed or non-positive
/// value is a hard error (a bad bandwidth must never be silently ignored
/// or end up in a persisted model — NaN/0 widths degenerate the binning).
fn sigma_override(args: &Args) -> Result<Option<f64>, ScrbError> {
    match args.get("sigma") {
        None => Ok(None),
        Some(_) => {
            let s = args.get_f64("sigma", f64::NAN)?;
            if !s.is_finite() || s <= 0.0 {
                return Err(ScrbError::config(format!(
                    "--sigma must be a positive finite number, got '{s}'"
                )));
            }
            Ok(Some(s))
        }
    }
}

fn cmd_run(args: &Args) -> Result<(), ScrbError> {
    let cfg = base_config(args)?;
    let method = MethodKind::parse(args.get_or("method", "sc_rb"))?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    let ds = load_dataset(args, &coord)?;
    println!("dataset {} n={} d={} k={}", ds.name, ds.n(), ds.d(), ds.k);
    let run = experiment::single_run(&coord, method, &ds, sigma_override(args)?)?;
    println!(
        "{}: acc={:.3} nmi={:.3} ri={:.3} fm={:.3} time={}s",
        run.method.name(),
        run.metrics.accuracy,
        run.metrics.nmi,
        run.metrics.rand_index,
        run.metrics.f_measure,
        fnum(run.secs)
    );
    for (stage, secs) in &run.stages {
        println!("  {stage}: {}s", fnum(*secs));
    }
    if let Some(k) = run.kappa {
        println!("  kappa: {k:.2} (Definition 1)");
    }
    if run.svd_matvecs > 0 {
        println!("  svd matvecs: {} converged: {}", run.svd_matvecs, run.svd_converged);
    }
    Ok(())
}

/// `scrb fit [dataset] --save model.scrb`: run Algorithm 2 once and
/// persist the serving artifact (grids, bin→column maps, Σ/V projection,
/// centroids).
fn cmd_fit(args: &Args) -> Result<(), ScrbError> {
    let method = MethodKind::parse(args.get_or("method", "sc_rb"))?;
    if method != MethodKind::ScRb {
        return Err(ScrbError::config(format!(
            "`scrb fit` serves SC_RB models; {} has no persistable out-of-sample artifact \
             (use `scrb run --method {}` for batch clustering)",
            method.name(),
            method.name()
        )));
    }
    let save = args
        .get("save")
        .ok_or_else(|| ScrbError::config("fit: missing --save PATH for the model artifact"))?;
    let cfg = base_config(args)?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    if args.flag("stream") {
        return cmd_fit_stream(args, &coord, save);
    }
    let (mut ds, from_file) = load_dataset_raw(args, &coord)?;
    // File data is min-max normalized for the fit; the frame (per-feature
    // min/span) is stored in the model so `scrb predict` can bring new
    // batches into the *same* frame instead of their own statistics.
    let norm = if from_file {
        let (lo, span) = ds.minmax_params();
        ds.apply_minmax(&lo, &span);
        Some((lo, span))
    } else {
        None
    };
    println!("dataset {} n={} d={} k={}", ds.name, ds.n(), ds.d(), ds.k);
    let cfg = coord.cfg_for(&ds, sigma_override(args)?);
    let env = Env::with_xla(cfg.clone(), coord.xla.as_ref());
    let t0 = Instant::now();
    let mut fitted = MethodKind::ScRb.fit(&env, &ds.x)?;
    if let Some((lo, span)) = norm {
        fitted.model.set_input_norm(lo, span);
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = all_metrics(&fitted.output.labels, &ds.y);
    println!(
        "fit SC_RB ({cfg}): acc={:.3} nmi={:.3} time={}s",
        m.accuracy,
        m.nmi,
        fnum(secs)
    );
    fitted.model.save(save)?;
    let bytes = std::fs::metadata(save).map(|m| m.len()).unwrap_or(0);
    println!(
        "model saved to {save} ({} clusters, {} KB)",
        fitted.model.n_clusters(),
        bytes / 1024
    );
    Ok(())
}

/// `scrb fit --stream --data big.libsvm --chunk-rows M --sigma S --save
/// model.scrb`: the out-of-core fit — two chunked passes over the file
/// (stats, then block-wise RB featurization), resident input memory
/// bounded by `chunk_rows × d`, and a model byte-identical to the
/// in-memory fit on the same data and seed. `--shards K` featurizes K
/// byte-range (or whole-file, for comma-separated/glob `--data`) shards
/// in parallel and merges the codebooks — same model bytes for any K.
/// Fault handling rides on `--on-bad-record strict|quarantine` (plus
/// `--quarantine-sample`, `--max-retries`); long single-shard fits add
/// `--checkpoint DIR [--checkpoint-every N] [--resume]` to survive
/// kills.
fn cmd_fit_stream(args: &Args, coord: &Coordinator, save: &str) -> Result<(), ScrbError> {
    if args.get("data").is_none() {
        return Err(ScrbError::config(
            "fit --stream reads from files; pass --data path.libsvm (comma-separated paths \
             and/or globs for a multi-file dataset)",
        ));
    }
    let paths = args.get_str_list("data", &[]);
    let path = paths[0].as_str();
    let chunk_rows = args.get_usize("chunk-rows", 4096)?;
    let block_rows = args.get_usize("block-rows", 65_536)?;
    let shards = args.get_usize("shards", 1)?;
    // Attach the streaming section and re-validate: the one
    // `PipelineConfig::validate` routine now enforces chunk/block-rows ≥ 1,
    // shards ≥ 1, *and* an explicitly pinned σ (no data matrix exists to
    // run the eigengap bandwidth selection on — silently falling back to
    // the config default would bake a wrong bandwidth into a persisted
    // model).
    let cfg = coord.base_cfg.rebuild(|b| b.stream(chunk_rows, block_rows).shards(shards))?;
    let sigma = cfg.kernel.sigma();
    // K: explicit --k wins; otherwise the stream's label census decides.
    let k_override = args.get("k").is_some().then_some(coord.base_cfg.k);
    let policy = scrb::stream::IngestPolicy {
        on_bad_record: scrb::stream::OnBadRecord::parse(args.get_or("on-bad-record", "strict"))?,
        sample_cap: args.get_usize("quarantine-sample", 16)?,
        max_retries: args.get_usize("max-retries", 3)? as u32,
        ..scrb::stream::IngestPolicy::default()
    };
    let checkpoint = match args.get("checkpoint") {
        Some(dir) => Some(scrb::stream::CheckpointCfg {
            every_rows: args.get_usize("checkpoint-every", 262_144)?,
            resume: args.flag("resume"),
            ..scrb::stream::CheckpointCfg::new(dir)
        }),
        None => {
            if args.flag("resume") {
                return Err(ScrbError::config(
                    "--resume needs --checkpoint DIR (the directory the interrupted fit \
                     was checkpointing into)",
                ));
            }
            None
        }
    };
    // loud typed refusal instead of a silently ignored flag — sharded
    // checkpointing is tracked as follow-up work
    if shards > 1 && checkpoint.is_some() {
        return Err(ScrbError::config(
            "checkpoint/resume (--checkpoint/--resume) is not yet supported with --shards > 1; \
             drop the checkpoint flags or fit with --shards 1",
        ));
    }
    let opts = scrb::stream::StreamOpts {
        block_rows,
        k: k_override,
        policy,
        checkpoint,
        ..scrb::stream::StreamOpts::default()
    };
    let t0 = Instant::now();
    // plain single-file single-shard fits keep the direct sequential path
    // (and with it checkpoint/resume); anything wider goes through the
    // planner — which yields the same model bytes either way
    let fit = if shards == 1 && paths.len() == 1 && !path.contains('*') && !path.contains('?') {
        coord.fit_streaming(path, chunk_rows, sigma, opts)?
    } else {
        coord.fit_streaming_sharded(&paths, shards, chunk_rows, sigma, opts)?
    };
    let secs = t0.elapsed().as_secs_f64();
    if fit.quarantine.skipped() > 0 || fit.quarantine.retries > 0 {
        println!("quarantine: {}", fit.quarantine.summary());
        for rec in &fit.quarantine.samples {
            println!("  skipped {rec}");
        }
    }
    println!(
        "dataset {path} (streamed) n={} d={} classes={} chunk_rows={chunk_rows} shards={shards}",
        fit.n, fit.d, fit.k_true
    );
    let m = all_metrics(&fit.output.labels, &fit.y);
    println!(
        "fit SC_RB --stream (r={} sigma={sigma}): acc={:.3} nmi={:.3} time={}s",
        coord.base_cfg.r,
        m.accuracy,
        m.nmi,
        fnum(secs)
    );
    for stage in fit.output.timer.names() {
        println!("  {stage}: {}s", fnum(fit.output.timer.secs(stage)));
    }
    if let Some(kappa) = fit.output.info.kappa {
        println!("  kappa: {kappa:.2} (Definition 1)");
    }
    fit.model.save(save)?;
    let bytes = std::fs::metadata(save).map(|m| m.len()).unwrap_or(0);
    println!(
        "model saved to {save} ({} clusters, {} KB)",
        fit.model.n_clusters(),
        bytes / 1024
    );
    Ok(())
}

/// `scrb predict --model model.scrb [--data new.libsvm | dataset]`: label
/// points with a previously fitted model — no solver, no refit.
fn cmd_predict(args: &Args) -> Result<(), ScrbError> {
    let model_path = args
        .get("model")
        .ok_or_else(|| ScrbError::config("predict: missing --model PATH (from `scrb fit --save`)"))?;
    let mut model = ScRbModel::load(model_path)?;
    // drift sensitivity: warn when a call's unseen-bin rate crosses this
    if args.get("unseen-warn").is_some() {
        let t = args.get_f64("unseen-warn", scrb::model::DEFAULT_UNSEEN_WARN)?;
        if !(0.0..=1.0).contains(&t) {
            return Err(ScrbError::config(format!(
                "--unseen-warn must be a rate in [0, 1], got '{t}'"
            )));
        }
        model.unseen_warn = t;
    }
    let cfg = base_config(args)?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    let (mut ds, from_file) = load_dataset_raw(args, &coord)?;
    if from_file {
        // bring the batch into the frame the model was *fitted* in —
        // normalizing by the batch's own min/max would shift every bin
        if model.input_norm().is_none() {
            eprintln!(
                "warning: model stores no input normalization; \
                 serving the file's raw feature values"
            );
        }
        model.apply_input_norm(&mut ds.x);
    }
    println!(
        "model {model_path}: {} clusters, {} input dims, R={} grids, D={} bins",
        model.n_clusters(),
        model.input_dim(),
        model.codebook.r,
        model.codebook.dim
    );
    let mut ws = ServeWorkspace::new();
    let mut labels: Vec<usize> = Vec::new();
    let t0 = Instant::now();
    model.predict_batch(&ds.x, &mut ws, &mut labels)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "predicted {} points in {}s ({:.3e} points/s)",
        labels.len(),
        fnum(secs),
        labels.len() as f64 / secs.max(1e-12)
    );
    let m = all_metrics(&labels, &ds.y);
    println!("vs file labels: acc={:.3} nmi={:.3}", m.accuracy, m.nmi);
    let drift = model.drift_stats();
    println!(
        "unseen-bin rate: {:.4} ({} of {} lookups missed the codebook)",
        drift.rate(),
        drift.unseen,
        drift.lookups
    );
    if args.get("unseen-warn").is_some() {
        // the caller asked for drift sensitivity: close with the same
        // summary the serve daemon's STATUS reports, so a scripted
        // predict can grep one line to decide on `scrb update`.
        let st = model.update_state;
        println!(
            "drift summary: {} serving call(s) over the {:.1}% unseen threshold, {} warning(s) \
             emitted; model history: {} update(s), {} bins admitted, unseen EWMA {:.4}",
            drift.over_threshold,
            model.unseen_warn * 100.0,
            drift.warnings,
            st.updates,
            st.bins_admitted,
            st.unseen_ewma
        );
    }
    if let Some(out_path) = args.get("out") {
        let mut text = String::with_capacity(labels.len() * 3);
        for l in &labels {
            text.push_str(&l.to_string());
            text.push('\n');
        }
        std::fs::write(out_path, text).map_err(|e| ScrbError::io(out_path, e))?;
        println!("labels written to {out_path}");
    }
    Ok(())
}

/// `scrb update --model m.scrb --data new.libsvm --save m2.scrb`:
/// online model maintenance ([`scrb::update`]). New rows stream through
/// the hardened ingest stack and are absorbed incrementally — unseen
/// bins admitted as new codebook columns, the spectral subspace folded
/// forward, centroids warm-start polished. When the persisted drift
/// EWMAs cross their thresholds the pass stops with a refit signal;
/// `--refit` then escalates to the full streamed refit using the
/// model's frozen parameters (r, σ, K, seed), and `--swap HOST:PORT`
/// publishes whichever model was saved to a running daemon through the
/// validated hot-swap slot.
fn cmd_update(args: &Args) -> Result<(), ScrbError> {
    let model_path = args
        .get("model")
        .ok_or_else(|| ScrbError::config("update: missing --model PATH (from `scrb fit --save`)"))?;
    let data = args
        .get("data")
        .ok_or_else(|| ScrbError::config("update: missing --data PATH (new rows, LibSVM)"))?;
    let save = args
        .get("save")
        .ok_or_else(|| ScrbError::config("update: missing --save PATH for the updated model"))?;
    let mut ucfg = scrb::config::UpdateConfig::default();
    ucfg.apply_args(args)?;
    let chunk_rows = args.get_usize("chunk-rows", 4096)?;
    let policy = scrb::stream::IngestPolicy {
        on_bad_record: scrb::stream::OnBadRecord::parse(args.get_or("on-bad-record", "strict"))?,
        sample_cap: args.get_usize("quarantine-sample", 16)?,
        max_retries: args.get_usize("max-retries", 3)? as u32,
        ..scrb::stream::IngestPolicy::default()
    };
    let mut model = ScRbModel::load(model_path)?;
    let dim0 = model.codebook.dim;
    let mut reader = scrb::stream::LibsvmChunks::from_path(data, chunk_rows)?;
    let mut ws = scrb::update::UpdateWorkspace::new();
    let t0 = Instant::now();
    let out = scrb::update::update_streaming(&mut model, &mut reader, &ucfg, policy, &mut ws)?;
    let secs = t0.elapsed().as_secs_f64();
    if out.quarantine.skipped() > 0 || out.quarantine.retries > 0 {
        println!("quarantine: {}", out.quarantine.summary());
        for rec in &out.quarantine.samples {
            println!("  skipped {rec}");
        }
    }
    let st = model.update_state;
    println!(
        "update {model_path}: absorbed {} rows in {} chunk(s), admitted {} bins \
         (D {dim0} -> {}) in {}s",
        out.rows,
        out.reports.len(),
        out.admitted,
        model.codebook.dim,
        fnum(secs)
    );
    println!(
        "drift: unseen EWMA {:.4} (trigger {}), residual EWMA {:.4} (trigger {}); \
         lifetime: {} update(s), {} rows, {} refit signal(s)",
        st.unseen_ewma,
        ucfg.unseen_refit,
        st.residual_ewma,
        ucfg.residual_refit,
        st.updates,
        st.rows_absorbed,
        st.refits_signaled
    );
    if out.refit_needed && args.flag("refit") {
        println!("drift thresholds crossed: escalating to a full streamed refit");
        cmd_update_refit(args, &model, data, save, chunk_rows, policy)?;
    } else {
        if out.refit_needed {
            println!(
                "drift thresholds crossed after {} rows — the incremental path stopped; \
                 rerun with --refit to rebuild from the model's frozen parameters",
                out.rows
            );
        }
        model.save(save)?;
        let bytes = std::fs::metadata(save).map(|m| m.len()).unwrap_or(0);
        println!("updated model saved to {save} ({} KB)", bytes / 1024);
    }
    if let Some(addr) = args.get("swap") {
        let mut c = scrb::serve::ServeClient::connect(addr)
            .map_err(|e| ScrbError::config(format!("swap: cannot reach daemon at {addr}: {e}")))?;
        let version = c
            .swap(save)
            .map_err(|e| ScrbError::config(format!("swap rejected by daemon at {addr}: {e}")))?;
        println!("published {save} to {addr} as model version {version}");
    }
    Ok(())
}

/// The `--refit` escalation: a full streamed fit over `--refit-data`
/// (default: the update's `--data`) with the pipeline parameters frozen
/// inside the drifted model — same R, kernel bandwidth, cluster count,
/// and seed — so the rebuilt model is the one the original fit would
/// have produced on the wider data.
fn cmd_update_refit(
    args: &Args,
    model: &ScRbModel,
    data: &str,
    save: &str,
    chunk_rows: usize,
    policy: scrb::stream::IngestPolicy,
) -> Result<(), ScrbError> {
    let refit_data = args.get_or("refit-data", data);
    let block_rows = args.get_usize("block-rows", 65_536)?;
    let cfg = PipelineConfig::builder()
        .r(model.codebook.r)
        .kernel(model.kernel)
        .k(model.n_clusters())
        .seed(model.codebook.seed)
        .stream(chunk_rows, block_rows)
        .build();
    let opts = scrb::stream::StreamOpts {
        block_rows,
        k: Some(model.n_clusters()),
        policy,
        ..scrb::stream::StreamOpts::default()
    };
    let mut reader = scrb::stream::LibsvmChunks::from_path(refit_data, chunk_rows)?;
    let t0 = Instant::now();
    let fit = scrb::stream::fit_streaming(&Env::new(cfg), &mut reader, &opts)?;
    let secs = t0.elapsed().as_secs_f64();
    if fit.quarantine.skipped() > 0 || fit.quarantine.retries > 0 {
        println!("refit quarantine: {}", fit.quarantine.summary());
    }
    println!(
        "refit over {refit_data}: n={} d={} D={} bins in {}s",
        fit.n,
        fit.d,
        fit.model.codebook.dim,
        fnum(secs)
    );
    fit.model.save(save)?;
    let bytes = std::fs::metadata(save).map(|m| m.len()).unwrap_or(0);
    println!("refitted model saved to {save} ({} KB)", bytes / 1024);
    Ok(())
}

/// `scrb serve --model model.scrb --addr 127.0.0.1:7878`: run the
/// clustering-as-a-service daemon until a `Drain` frame or SIGTERM
/// completes a graceful drain (see [`scrb::serve`]).
fn cmd_serve(args: &Args) -> Result<(), ScrbError> {
    let model_path = args
        .get("model")
        .ok_or_else(|| ScrbError::config("serve: missing --model PATH (from `scrb fit --save`)"))?;
    let model = ScRbModel::load(model_path)?;
    let (clusters, dims) = (model.n_clusters(), model.input_dim());
    let cfg = scrb::serve::ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        workers: args.get_usize("workers", 2)?.max(1),
        queue_cap: args.get_usize("queue-cap", 256)?.max(1),
        max_batch: args.get_usize("max-batch", 64)?.max(1),
        default_deadline_ms: args.get_u64("deadline-ms", 1000)?.max(1),
        max_frame_bytes: args.get_usize("max-frame-mb", 64)?.max(1) << 20,
        ..scrb::serve::ServeConfig::default()
    };
    scrb::serve::install_sigterm_drain();
    let server = scrb::serve::Server::bind(cfg, model)?;
    println!(
        "serving {model_path} ({clusters} clusters, {dims} input dims) on {}",
        server.local_addr()?
    );
    server.run()?;
    println!("drained; exiting");
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), ScrbError> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("2");
    let scale = scale_of(args)?;
    match which {
        "1" => {
            println!("{}", report::render_table1(scale));
            Ok(())
        }
        "2" | "3" | "23" => {
            let cfg = base_config(args)?;
            let coord = Coordinator::new(cfg, scale);
            let names: Vec<String> = args.get_str_list("datasets", &experiment::TABLE_DATASETS);
            let grid = experiment::table2_3(&coord, &names)?;
            println!("Table 2: average rank scores (lower = better), R={}", coord.base_cfg.r);
            println!("{}", report::render_table2(&grid));
            println!("Table 3: computational time (seconds)");
            println!("{}", report::render_table3(&grid));
            if args.flag("detail") {
                println!("{}", report::render_detail(&grid));
            }
            let json = report::grid_to_json(&grid).to_string();
            let path = report::save("table2_3.json", &json)
                .map_err(|e| ScrbError::io("table2_3.json", e))?;
            eprintln!("[saved {path}]");
            Ok(())
        }
        other => Err(ScrbError::config(format!("unknown table '{other}' (1|2|3)"))),
    }
}

fn cmd_fig(args: &Args) -> Result<(), ScrbError> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("2");
    let cfg = base_config(args)?;
    let coord = Coordinator::new(cfg, scale_of(args)?);
    match which {
        "2" => {
            let rs = args.get_usize_list("rs", &[16, 64, 256, 1024, 4096])?;
            let rb_max = args.get_usize("rb-max-r", 1024)?;
            let fig = experiment::fig2(&coord, &rs, rb_max)?;
            println!("{}", report::render_fig2(&fig));
        }
        "3" => {
            let rs = args.get_usize_list("rs", &[16, 32, 64, 128])?;
            let series = experiment::fig3(&coord, &rs)?;
            println!(
                "{}",
                report::render_series("Fig. 3: SVD solver comparison (covtype-like)", &series, "R")
            );
        }
        "4" => {
            let name = args.get_or("dataset", "poker").to_string();
            let ns = args.get_usize_list("ns", &[1_000, 4_000, 16_000, 64_000, 256_000])?;
            let r = args.get_usize("r", 256)?;
            let points = experiment::fig4(&coord, &name, &ns, r)?;
            println!("{}", report::render_fig4(&name, &points));
        }
        "5" => {
            let rs = args.get_usize_list("rs", &[16, 64, 256, 1024])?;
            let names = args.get_str_list("datasets", &["pendigits", "letter", "mnist", "acoustic"]);
            for name in names {
                let series = experiment::fig5(&coord, &name, &rs)?;
                println!(
                    "{}",
                    report::render_series(
                        &format!("Fig. 5: runtime vs R ({name})"),
                        &series,
                        "R"
                    )
                );
            }
        }
        "theory" => {
            let n = args.get_usize("n", 300)?;
            let rs = args.get_usize_list("rs", &[4, 8, 16, 32, 64, 128, 256])?;
            let points = experiment::theory_convergence(&coord, n, &rs)?;
            println!("{}", report::render_theory(&points));
        }
        other => return Err(ScrbError::config(format!("unknown figure '{other}' (2|3|4|5|theory)"))),
    }
    Ok(())
}
