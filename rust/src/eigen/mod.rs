//! Iterative sparse SVD substrate — the PRIMME role in Algorithm 2 step 3.
//!
//! Three solvers behind one driver:
//! - [`davidson`] — block Generalized Davidson (GD+k flavour) with thick
//!   restart and diagonal preconditioning: the PRIMME_SVDS analogue.
//! - [`lanczos`] — restarted Golub–Kahan bidiagonalization with naive
//!   restart: the Matlab `svds` analogue used as the Fig. 3 comparator.
//! - [`compressive`] — Chebyshev low-pass filtering of random signals
//!   (Compressive Spectral Clustering): no basis orthogonalization per
//!   iteration, just p fused gram block products, with Rayleigh–Ritz on
//!   the filtered span when honest singular triplets are needed.
//!
//! All three touch the matrix only through [`op::SvdOp`] block products,
//! so the sparse Ẑ never needs an explicit Laplacian. Every S·B = A·(Aᵀ·B)
//! product goes through the fused [`op::SvdOp::gram_matmat_into`] fast
//! path, and each solver threads a reusable [`SolverWorkspace`] so
//! steady-state iterations are allocation-free — see [`workspace`].

pub mod compressive;
pub mod davidson;
pub mod lanczos;
pub mod op;
pub mod workspace;

pub use compressive::{compressive_svd, compressive_svd_ws, CompressiveOpts};
pub use davidson::{davidson_svd, davidson_svd_ws, DavidsonOpts};
pub use lanczos::{lanczos_svd, lanczos_svd_ws, LanczosOpts};
pub use op::{CountingOp, SvdOp};
pub use workspace::SolverWorkspace;

use crate::config::Solver;
use crate::linalg::Mat;

/// Solver execution statistics (the paper's iteration count m).
#[derive(Clone, Debug, Default)]
pub struct SvdStats {
    /// Operator applications counted per column (A or Aᵀ each count 1).
    pub matvecs: usize,
    /// Outer iterations (restart cycles / expansions).
    pub iterations: usize,
    pub converged: bool,
}

/// Top-k singular triplets, descending.
pub struct SvdResult {
    /// Left singular vectors, n×k — the spectral embedding U of Algorithm 2.
    pub u: Mat,
    pub s: Vec<f64>,
    /// Right singular vectors, d×k.
    pub v: Mat,
    pub stats: SvdStats,
}

/// Unified driver options.
#[derive(Clone, Debug)]
pub struct SvdsOpts {
    pub k: usize,
    pub tol: f64,
    pub max_matvecs: usize,
    pub solver: Solver,
    /// Chebyshev filter order p (only read by [`Solver::Compressive`]).
    pub cheb_order: usize,
    /// Random-signal count η; `None` = O(log n) auto (compressive only).
    pub cheb_signals: Option<usize>,
}

impl SvdsOpts {
    pub fn new(k: usize, solver: Solver) -> Self {
        SvdsOpts { k, tol: 1e-5, max_matvecs: 5000, solver, cheb_order: 25, cheb_signals: None }
    }
}

/// Compute the top-k left singular triplets of `a` with the selected
/// solver, using a fresh private workspace.
pub fn svds<O: SvdOp + ?Sized>(a: &O, opts: &SvdsOpts, seed: u64) -> SvdResult {
    let mut ws = SolverWorkspace::new();
    svds_ws(a, opts, seed, &mut ws)
}

/// [`svds`] with an explicit, reusable [`SolverWorkspace`]: callers running
/// sweeps (the coordinator drivers, SC_RB pipelines) amortize one
/// workspace's buffers over every solve.
pub fn svds_ws<O: SvdOp + ?Sized>(
    a: &O,
    opts: &SvdsOpts,
    seed: u64,
    ws: &mut SolverWorkspace,
) -> SvdResult {
    match opts.solver {
        Solver::Davidson => {
            let mut o = DavidsonOpts::new(opts.k);
            o.tol = opts.tol;
            o.max_matvecs = opts.max_matvecs;
            davidson_svd_ws(a, &o, seed, ws)
        }
        Solver::Lanczos => {
            let mut o = LanczosOpts::new(opts.k);
            o.tol = opts.tol;
            o.max_matvecs = opts.max_matvecs;
            lanczos_svd_ws(a, &o, seed, ws)
        }
        Solver::Compressive => {
            let mut o = CompressiveOpts::new(opts.k);
            o.order = opts.cheb_order;
            o.signals = opts.cheb_signals;
            o.tol = opts.tol;
            o.max_matvecs = opts.max_matvecs;
            compressive_svd_ws(a, &o, seed, ws)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg;

    #[test]
    fn driver_dispatches_both_solvers_on_sparse() {
        let mut rng = Pcg::seed(81);
        let mut rows = Vec::new();
        for _ in 0..120 {
            let mut r = Vec::new();
            for _ in 0..4 {
                r.push((rng.below(40) as u32, rng.f64() + 0.05));
            }
            rows.push(r);
        }
        let z = Csr::from_rows(120, 40, rows);
        let dense = crate::linalg::svd_thin(&z.to_dense());
        for solver in [Solver::Davidson, Solver::Lanczos] {
            let mut opts = SvdsOpts::new(3, solver);
            opts.tol = 1e-8;
            opts.max_matvecs = 30_000;
            let r = svds(&z, &opts, 4);
            assert!(r.stats.converged, "{solver:?} did not converge");
            for j in 0..3 {
                assert!(
                    (r.s[j] - dense.s[j]).abs() < 1e-5 * dense.s[0],
                    "{solver:?} σ_{j}: {} vs {}",
                    r.s[j],
                    dense.s[j]
                );
            }
        }
    }

    #[test]
    fn counting_op_reports_matvecs() {
        let mut rng = Pcg::seed(82);
        let a = Mat::from_vec(30, 10, (0..300).map(|_| rng.f64()).collect());
        let counter = CountingOp::new(&a);
        let r = svds(&counter, &SvdsOpts::new(2, Solver::Davidson), 1);
        assert!(counter.matvecs() > 0);
        assert!(r.stats.matvecs > 0);
    }
}
