//! Operator abstraction for the iterative SVD solvers.
//!
//! The solvers only ever touch the matrix through block products `A·B` and
//! `Aᵀ·B`, so the weighted RB feature matrix Ẑ (sparse CSR), dense matrices,
//! and test operators all plug in through this trait — the paper's point
//! that PRIMME needs no explicit form of L̂.

use crate::linalg::Mat;
use crate::sparse::{Csr, EllRb};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A (possibly implicit) m×n linear operator with block apply.
pub trait SvdOp: Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Y = A · B, with B of shape ncols×k.
    fn apply(&self, b: &Mat) -> Mat;
    /// Y = Aᵀ · B, with B of shape nrows×k.
    fn apply_t(&self, b: &Mat) -> Mat;
    /// Diagonal of A·Aᵀ (row squared norms) if cheaply available — used by
    /// the Davidson diagonal preconditioner.
    fn gram_diag(&self) -> Option<Vec<f64>> {
        None
    }
}

impl SvdOp for Csr {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmat(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmat(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        let mut d = vec![0.0; self.rows];
        for i in 0..self.rows {
            d[i] = self.data[self.row_range(i)].iter().map(|v| v * v).sum();
        }
        Some(d)
    }
}

impl SvdOp for EllRb {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmat(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmat(b)
    }
    /// Closed form R·scale[i]² — no pass over the matrix at all.
    fn gram_diag(&self) -> Option<Vec<f64>> {
        Some(EllRb::gram_diag(self))
    }
}

impl SvdOp for Mat {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmul(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmul(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        Some((0..self.rows).map(|i| crate::linalg::dot(self.row(i), self.row(i))).collect())
    }
}

/// Wrapper that counts block-applies (each apply of width k counts k
/// matvecs, matching how the paper reports solver iterations m).
pub struct CountingOp<'a, O: SvdOp + ?Sized> {
    pub inner: &'a O,
    matvecs: AtomicUsize,
}

impl<'a, O: SvdOp + ?Sized> CountingOp<'a, O> {
    pub fn new(inner: &'a O) -> Self {
        CountingOp { inner, matvecs: AtomicUsize::new(0) }
    }

    pub fn matvecs(&self) -> usize {
        self.matvecs.load(Ordering::Relaxed)
    }
}

impl<'a, O: SvdOp + ?Sized> SvdOp for CountingOp<'a, O> {
    fn nrows(&self) -> usize {
        self.inner.nrows()
    }
    fn ncols(&self) -> usize {
        self.inner.ncols()
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matvecs.fetch_add(b.cols, Ordering::Relaxed);
        self.inner.apply(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.matvecs.fetch_add(b.cols, Ordering::Relaxed);
        self.inner.apply_t(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        self.inner.gram_diag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_wrapper_counts() {
        let a = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = CountingOp::new(&a);
        let b = Mat::from_vec(2, 4, vec![0.0; 8]);
        let _ = c.apply(&b);
        let b2 = Mat::from_vec(3, 2, vec![0.0; 6]);
        let _ = c.apply_t(&b2);
        assert_eq!(c.matvecs(), 6);
    }

    #[test]
    fn gram_diag_matches() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 2., 0., 3., 4.]);
        assert_eq!(a.gram_diag().unwrap(), vec![9.0, 25.0]);
        let z = Csr::from_rows(2, 3, vec![vec![(0, 1.0), (1, 2.0), (2, 2.0)], vec![(1, 3.0), (2, 4.0)]]);
        assert_eq!(z.gram_diag().unwrap(), vec![9.0, 25.0]);
    }

    #[test]
    fn ellrb_op_matches_csr_bridge() {
        // EllRb plugged into the solver interface agrees with its CSR view
        let e = EllRb::new(3, 4, 2, vec![0, 2, 1, 3, 0, 3], vec![0.5, 2.0, 1.5]);
        let c = e.to_csr();
        let b = Mat::from_vec(4, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        assert!(e.apply(&b).sub(&c.apply(&b)).frob_norm() < 1e-14);
        let b2 = Mat::from_vec(3, 2, vec![1., -1., 2., 0.5, -3., 4.]);
        assert!(e.apply_t(&b2).sub(&c.apply_t(&b2)).frob_norm() < 1e-14);
        let gd = SvdOp::gram_diag(&e).unwrap();
        let gd0 = c.gram_diag().unwrap();
        for (u, v) in gd.iter().zip(gd0.iter()) {
            assert!((u - v).abs() < 1e-14);
        }
    }
}
