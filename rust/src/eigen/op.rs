//! Operator abstraction for the iterative SVD solvers.
//!
//! The solvers only ever touch the matrix through block products `A·B` and
//! `Aᵀ·B`, so the weighted RB feature matrix Ẑ (natively the fixed-stride
//! [`EllRb`] substrate since PR 1), general [`Csr`] matrices, dense
//! matrices, and test operators all plug in through this trait — the
//! paper's point that PRIMME needs no explicit form of L̂.
//!
//! # The `gram_matmat` contract
//!
//! Every solver iteration is one application of the symmetric PSD operator
//! S = A·Aᵀ to a block. [`SvdOp::gram_matmat`] computes exactly
//! `apply(apply_t(b))` — same result, same matvec accounting (2·k per
//! block of width k) — but operators may fuse the two passes.
//! [`EllRb`] does: its strip-tiled kernel never materializes the D×k
//! intermediate, streaming substrate bytes once per pass end-to-end with
//! only cache-sized per-thread tiles (see [`EllRb::gram_matmat_into`]).
//! The `_into` variant additionally writes into a caller-owned output and
//! reuses a [`GramScratch`], so the solver hot loop performs zero heap
//! allocations in steady state. Default implementations fall back to the
//! two-pass product, so `Mat`, `Csr`, and custom test operators keep
//! working unchanged.

use crate::linalg::Mat;
use crate::sparse::{BlockEllRb, Csr, EllRb, GramScratch};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A (possibly implicit) m×n linear operator with block apply.
pub trait SvdOp: Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Y = A · B, with B of shape ncols×k.
    fn apply(&self, b: &Mat) -> Mat;
    /// Y = Aᵀ · B, with B of shape nrows×k.
    fn apply_t(&self, b: &Mat) -> Mat;
    /// Y = A·(Aᵀ·B), the gram (S = A·Aᵀ) block product, B of shape
    /// nrows×k. Semantically identical to `apply(apply_t(b))`; operators
    /// with structure (notably [`EllRb`]) fuse the two passes.
    fn gram_matmat(&self, b: &Mat) -> Mat {
        self.apply(&self.apply_t(b))
    }
    /// Allocation-aware gram product: write A·(Aᵀ·B) into `out`
    /// (reshaped as needed), reusing `scratch` across calls. The default
    /// falls back to the allocating two-pass product; [`EllRb`] overrides
    /// with the fused strip-tiled kernel, which is allocation-free once
    /// `scratch` is warm.
    fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, scratch: &mut GramScratch) {
        let _ = scratch;
        *out = self.gram_matmat(b);
    }
    /// Pre-provision `scratch` for gram products up to block width
    /// `k_max` (called once at solver entry so steady-state iterations
    /// never re-provision). Default: nothing to provision.
    fn prepare_gram(&self, scratch: &mut GramScratch, k_max: usize) {
        let _ = (scratch, k_max);
    }
    /// y = A·x into a caller-owned buffer (single-vector hot path of the
    /// Lanczos bidiagonalization). Default allocates via the block apply.
    fn apply_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols());
        assert_eq!(y.len(), self.nrows());
        let b = Mat::from_vec(x.len(), 1, x.to_vec());
        y.copy_from_slice(&self.apply(&b).data);
    }
    /// y = Aᵀ·x into a caller-owned buffer. Default allocates via the
    /// block apply.
    fn apply_t_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.nrows());
        assert_eq!(y.len(), self.ncols());
        let b = Mat::from_vec(x.len(), 1, x.to_vec());
        y.copy_from_slice(&self.apply_t(&b).data);
    }
    /// Diagonal of A·Aᵀ (row squared norms) if cheaply available — used by
    /// the Davidson diagonal preconditioner.
    fn gram_diag(&self) -> Option<Vec<f64>> {
        None
    }
}

impl SvdOp for Csr {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmat(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmat(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        let mut d = vec![0.0; self.rows];
        for i in 0..self.rows {
            d[i] = self.data[self.row_range(i)].iter().map(|v| v * v).sum();
        }
        Some(d)
    }
}

impl SvdOp for EllRb {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmat(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmat(b)
    }
    /// Fused strip-tiled S·B — no D×k intermediate.
    fn gram_matmat(&self, b: &Mat) -> Mat {
        EllRb::gram_matmat(self, b)
    }
    fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, scratch: &mut GramScratch) {
        EllRb::gram_matmat_into(self, b, out, scratch)
    }
    fn prepare_gram(&self, scratch: &mut GramScratch, k_max: usize) {
        scratch.prepare(self, k_max);
    }
    fn apply_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
    fn apply_t_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.t_matvec_into(x, y);
    }
    /// Closed form R·scale[i]² — no pass over the matrix at all.
    fn gram_diag(&self) -> Option<Vec<f64>> {
        Some(EllRb::gram_diag(self))
    }
}

impl SvdOp for BlockEllRb {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmat(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmat(b)
    }
    /// Transpose-then-forward through the scratch-resident intermediate —
    /// bit-identical to the monolithic fused kernel (see
    /// [`BlockEllRb::gram_matmat_into`]), so the solver trajectory on a
    /// streamed Ẑ matches the in-memory one exactly.
    fn gram_matmat(&self, b: &Mat) -> Mat {
        BlockEllRb::gram_matmat(self, b)
    }
    fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, scratch: &mut GramScratch) {
        BlockEllRb::gram_matmat_into(self, b, out, scratch)
    }
    fn prepare_gram(&self, scratch: &mut GramScratch, k_max: usize) {
        BlockEllRb::prepare_gram(self, scratch, k_max);
    }
    fn apply_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
    fn apply_t_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.t_matvec_into(x, y);
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        Some(BlockEllRb::gram_diag(self))
    }
}

impl SvdOp for Mat {
    fn nrows(&self) -> usize {
        self.rows
    }
    fn ncols(&self) -> usize {
        self.cols
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matmul(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.t_matmul(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        Some((0..self.rows).map(|i| crate::linalg::dot(self.row(i), self.row(i))).collect())
    }
}

/// Wrapper that counts block-applies (each apply of width k counts k
/// matvecs, matching how the paper reports solver iterations m).
pub struct CountingOp<'a, O: SvdOp + ?Sized> {
    pub inner: &'a O,
    matvecs: AtomicUsize,
}

impl<'a, O: SvdOp + ?Sized> CountingOp<'a, O> {
    pub fn new(inner: &'a O) -> Self {
        CountingOp { inner, matvecs: AtomicUsize::new(0) }
    }

    pub fn matvecs(&self) -> usize {
        self.matvecs.load(Ordering::Relaxed)
    }
}

impl<'a, O: SvdOp + ?Sized> SvdOp for CountingOp<'a, O> {
    fn nrows(&self) -> usize {
        self.inner.nrows()
    }
    fn ncols(&self) -> usize {
        self.inner.ncols()
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.matvecs.fetch_add(b.cols, Ordering::Relaxed);
        self.inner.apply(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.matvecs.fetch_add(b.cols, Ordering::Relaxed);
        self.inner.apply_t(b)
    }
    /// A fused gram product is still 2k matvecs — one A and one Aᵀ pass
    /// per column — matching the two-pass accounting exactly.
    fn gram_matmat(&self, b: &Mat) -> Mat {
        self.matvecs.fetch_add(2 * b.cols, Ordering::Relaxed);
        self.inner.gram_matmat(b)
    }
    fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, scratch: &mut GramScratch) {
        self.matvecs.fetch_add(2 * b.cols, Ordering::Relaxed);
        self.inner.gram_matmat_into(b, out, scratch);
    }
    fn prepare_gram(&self, scratch: &mut GramScratch, k_max: usize) {
        self.inner.prepare_gram(scratch, k_max);
    }
    fn apply_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvecs.fetch_add(1, Ordering::Relaxed);
        self.inner.apply_vec_into(x, y);
    }
    fn apply_t_vec_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvecs.fetch_add(1, Ordering::Relaxed);
        self.inner.apply_t_vec_into(x, y);
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        self.inner.gram_diag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_wrapper_counts() {
        let a = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = CountingOp::new(&a);
        let b = Mat::from_vec(2, 4, vec![0.0; 8]);
        let _ = c.apply(&b);
        let b2 = Mat::from_vec(3, 2, vec![0.0; 6]);
        let _ = c.apply_t(&b2);
        assert_eq!(c.matvecs(), 6);
    }

    #[test]
    fn gram_diag_matches() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 2., 0., 3., 4.]);
        assert_eq!(a.gram_diag().unwrap(), vec![9.0, 25.0]);
        let z = Csr::from_rows(2, 3, vec![vec![(0, 1.0), (1, 2.0), (2, 2.0)], vec![(1, 3.0), (2, 4.0)]]);
        assert_eq!(z.gram_diag().unwrap(), vec![9.0, 25.0]);
    }

    #[test]
    fn gram_matmat_default_and_fused_agree() {
        // default (two-pass) on Csr vs fused override on EllRb, same matrix
        let e = EllRb::new(4, 6, 2, vec![0, 3, 1, 4, 2, 5, 0, 5], vec![0.5, 1.0, 2.0, 0.25]);
        let c = e.to_csr();
        let b = Mat::from_vec(4, 3, (0..12).map(|i| (i as f64) * 0.5 - 2.0).collect());
        let fused = SvdOp::gram_matmat(&e, &b);
        let two_pass = c.gram_matmat(&b);
        assert!(fused.sub(&two_pass).frob_norm() < 1e-13);
        // _into with a reused scratch matches too
        let mut out = Mat::zeros(0, 0);
        let mut ws = GramScratch::new();
        SvdOp::gram_matmat_into(&e, &b, &mut out, &mut ws);
        assert!(out.sub(&two_pass).frob_norm() < 1e-13);
    }

    #[test]
    fn counting_wrapper_counts_gram_and_vec_applies() {
        let a = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c = CountingOp::new(&a);
        let b = Mat::from_vec(3, 4, vec![0.25; 12]);
        let _ = c.gram_matmat(&b); // 2·4 matvecs
        let mut y = vec![0.0; 3];
        c.apply_vec_into(&[1.0, 2.0], &mut y); // +1
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
        let mut t = vec![0.0; 2];
        c.apply_t_vec_into(&[1.0, 1.0, 1.0], &mut t); // +1
        assert_eq!(t, vec![2.0, 2.0]);
        assert_eq!(c.matvecs(), 10);
    }

    /// Forwards only `apply`/`apply_t`, so every other method exercises
    /// the trait's *default* implementation — the two-pass `gram_matmat`
    /// and the allocating single-vector applies.
    struct DefaultsOnly<'a, O: SvdOp>(&'a O);

    impl<'a, O: SvdOp> SvdOp for DefaultsOnly<'a, O> {
        fn nrows(&self) -> usize {
            self.0.nrows()
        }
        fn ncols(&self) -> usize {
            self.0.ncols()
        }
        fn apply(&self, b: &Mat) -> Mat {
            self.0.apply(b)
        }
        fn apply_t(&self, b: &Mat) -> Mat {
            self.0.apply_t(b)
        }
    }

    #[test]
    fn block_substrate_default_paths_match_monolithic() {
        // one 4×6 stride-2 substrate, monolithic and split into two row
        // blocks over the same column space
        let idx = vec![0u32, 3, 1, 4, 2, 5, 0, 5];
        let scale = vec![0.5, 1.0, 2.0, 0.25];
        let mono = EllRb::new(4, 6, 2, idx.clone(), scale.clone());
        let blocked = BlockEllRb::from_blocks(vec![
            EllRb::new(2, 6, 2, idx[..4].to_vec(), scale[..2].to_vec()),
            EllRb::new(2, 6, 2, idx[4..].to_vec(), scale[2..].to_vec()),
        ]);

        // default two-pass gram (via the defaults-only wrapper) vs the
        // fused overrides, across substrates — all bitwise equal (the
        // block kernels accumulate in the monolithic order by contract)
        let b = Mat::from_vec(4, 3, (0..12).map(|i| (i as f64) * 0.5 - 2.0).collect());
        let two_pass_block = DefaultsOnly(&blocked).gram_matmat(&b);
        let fused_block = SvdOp::gram_matmat(&blocked, &b);
        let fused_mono = SvdOp::gram_matmat(&mono, &b);
        assert_eq!(two_pass_block.data, fused_block.data, "default vs fused on BlockEllRb");
        assert_eq!(fused_block.data, fused_mono.data, "BlockEllRb vs EllRb gram");

        // single-vector applies: the default (block apply of width 1) and
        // the overridden matvec_into paths agree bitwise on both substrates
        let x = vec![1.0, -2.0, 0.5, 3.0, -0.25, 4.0];
        let mut y_def = vec![0.0; 4];
        let mut y_block = vec![0.0; 4];
        let mut y_mono = vec![0.0; 4];
        DefaultsOnly(&blocked).apply_vec_into(&x, &mut y_def);
        SvdOp::apply_vec_into(&blocked, &x, &mut y_block);
        SvdOp::apply_vec_into(&mono, &x, &mut y_mono);
        assert_eq!(y_def, y_block, "default vs overridden apply_vec_into");
        assert_eq!(y_block, y_mono, "BlockEllRb vs EllRb apply_vec_into");

        let u = vec![2.0, -1.0, 0.75, 1.5];
        let mut t_def = vec![0.0; 6];
        let mut t_block = vec![0.0; 6];
        let mut t_mono = vec![0.0; 6];
        DefaultsOnly(&blocked).apply_t_vec_into(&u, &mut t_def);
        SvdOp::apply_t_vec_into(&blocked, &u, &mut t_block);
        SvdOp::apply_t_vec_into(&mono, &u, &mut t_mono);
        assert_eq!(t_def, t_block, "default vs overridden apply_t_vec_into");
        assert_eq!(t_block, t_mono, "BlockEllRb vs EllRb apply_t_vec_into");
    }

    #[test]
    fn ellrb_op_matches_csr_bridge() {
        // EllRb plugged into the solver interface agrees with its CSR view
        let e = EllRb::new(3, 4, 2, vec![0, 2, 1, 3, 0, 3], vec![0.5, 2.0, 1.5]);
        let c = e.to_csr();
        let b = Mat::from_vec(4, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        assert!(e.apply(&b).sub(&c.apply(&b)).frob_norm() < 1e-14);
        let b2 = Mat::from_vec(3, 2, vec![1., -1., 2., 0.5, -3., 4.]);
        assert!(e.apply_t(&b2).sub(&c.apply_t(&b2)).frob_norm() < 1e-14);
        let gd = SvdOp::gram_diag(&e).unwrap();
        let gd0 = c.gram_diag().unwrap();
        for (u, v) in gd.iter().zip(gd0.iter()) {
            assert!((u - v).abs() < 1e-14);
        }
    }
}
