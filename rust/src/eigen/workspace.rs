//! Reusable solver workspaces — the zero-allocation substrate under
//! Davidson and Lanczos.
//!
//! Both solvers are restructured around two ideas:
//!
//! 1. **Column-major bases with reserved capacity** ([`ColBasis`]): basis
//!    growth, thick restarts, re-orthogonalization, and Ritz extraction are
//!    all column operations; storing columns contiguously makes each of
//!    them a streaming pass and makes "append a column" a plain
//!    `extend_from_slice` into reserved capacity instead of the
//!    re-layout + re-allocation of a row-major `hcat`.
//! 2. **One [`SolverWorkspace`] threaded through the solver** holding every
//!    buffer an iteration touches — bases, S·V cache, Ritz/residual blocks,
//!    projected-problem scratch ([`SymEigWs`] / [`SmallSvdWs`]), the fused
//!    gram kernel's [`GramScratch`], and the row-major bridge blocks the
//!    sparse kernels consume. After `ensure_*` provisions capacities at
//!    solver entry, steady-state iterations perform **zero heap
//!    allocations** (verified by the counting-allocator test in
//!    `tests/alloc.rs`; in multi-threaded runs the scoped-thread fork/join
//!    bookkeeping is the only remaining per-call allocation, O(threads) and
//!    data-size independent).
//!
//! The workspace is reusable across solves — `svds_ws` callers (e.g. the
//! R-sweep in `coordinator::experiment::theory_convergence`) amortize one
//! workspace over a whole experiment grid.

use crate::linalg::{dot, nrm2, Mat, PowerIterWs, SmallSvdWs, SymEigWs};
use crate::sparse::GramScratch;
use crate::util::threads::{num_threads, parallel_chunks_mut, parallel_rows_mut};

/// Column-major tall matrix with reserved column capacity: column j lives
/// at `data[j·rows .. (j+1)·rows]`. The basis/block container of the
/// solver hot path — all appends and column reads are contiguous.
pub struct ColBasis {
    rows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl Default for ColBasis {
    fn default() -> Self {
        Self::new()
    }
}

impl ColBasis {
    pub fn new() -> ColBasis {
        ColBasis { rows: 0, ncols: 0, data: Vec::new() }
    }

    /// Empty the basis and (re)provision capacity for `cap_cols` columns of
    /// `rows` entries. Allocates only when capacity grows.
    pub fn reset(&mut self, rows: usize, cap_cols: usize) {
        self.rows = rows;
        self.ncols = 0;
        self.data.clear();
        let want = rows * cap_cols;
        if self.data.capacity() < want {
            self.data.reserve(want);
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Drop all columns, keep shape and capacity.
    pub fn clear_cols(&mut self) {
        self.ncols = 0;
        self.data.clear();
    }

    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.ncols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.ncols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Append a column (no allocation when within reserved capacity).
    pub fn push_col(&mut self, src: &[f64]) {
        debug_assert_eq!(src.len(), self.rows);
        self.data.extend_from_slice(src);
        self.ncols += 1;
    }

    /// Append a zeroed column and return it for in-place filling.
    pub fn push_zero_col(&mut self) -> &mut [f64] {
        let rows = self.rows;
        self.data.resize(self.data.len() + rows, 0.0);
        self.ncols += 1;
        self.col_mut(self.ncols - 1)
    }

    /// Append column `j` of a row-major `Mat` (strided gather).
    pub fn push_col_from_mat(&mut self, m: &Mat, j: usize) {
        debug_assert_eq!(m.rows, self.rows);
        let rows = self.rows;
        self.data.reserve(rows); // no-op within reserved capacity
        for i in 0..rows {
            self.data.push(m.at(i, j));
        }
        self.ncols += 1;
    }

    /// Scatter column `j` into column `jm` of a row-major `Mat`.
    pub fn store_col_to_mat(&self, j: usize, m: &mut Mat, jm: usize) {
        debug_assert_eq!(m.rows, self.rows);
        let col = self.col(j);
        for (i, &v) in col.iter().enumerate() {
            m.set(i, jm, v);
        }
    }

    /// Become a copy of `other` (no allocation when capacity suffices).
    pub fn copy_from(&mut self, other: &ColBasis) {
        self.rows = other.rows;
        self.ncols = other.ncols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }
}

/// Fill `v` with `n` standard normals (resized in place; allocation-free
/// within reserved capacity). Shared by both solvers' start/refresh paths.
pub(crate) fn fill_normal(v: &mut Vec<f64>, n: usize, rng: &mut crate::util::rng::Pcg) {
    v.clear();
    v.resize(n, 0.0);
    for x in v.iter_mut() {
        *x = rng.normal();
    }
}

/// Gather basis columns `[from, ncols)` into a row-major `Mat` — the
/// bridge shape the sparse gram kernels consume — parallel over rows.
pub(crate) fn gather_cols_to_mat(src: &ColBasis, from: usize, out: &mut Mat) {
    let rows = src.rows();
    let cols = src.ncols() - from;
    out.reset(rows, cols);
    if rows == 0 || cols == 0 {
        return;
    }
    if !worth_forking(rows * cols) {
        for (i, row) in out.data.chunks_mut(cols).enumerate() {
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = src.col(from + t)[i];
            }
        }
        return;
    }
    parallel_rows_mut(&mut out.data, cols, |i0, chunk| {
        for (di, row) in chunk.chunks_mut(cols).enumerate() {
            let i = i0 + di;
            for (t, slot) in row.iter_mut().enumerate() {
                *slot = src.col(from + t)[i];
            }
        }
    });
}

/// Below roughly this many flops, a scoped-thread fork/join costs more
/// than the work it parallelizes (spawn/join is tens of µs; 64k mul-adds
/// are single-digit µs) — the helpers below run inline under it.
const PAR_WORK_THRESHOLD: usize = 1 << 16;

#[inline]
fn worth_forking(work: usize) -> bool {
    work >= PAR_WORK_THRESHOLD && num_threads() > 1
}

/// coeff\[j\] = basisⱼ · v for all current columns — the coefficient pass
/// of blocked classical Gram–Schmidt, parallel over columns when the
/// total work justifies the fork.
pub fn dots_into(basis: &ColBasis, v: &[f64], coeff: &mut [f64]) {
    let m = basis.ncols();
    debug_assert_eq!(coeff.len(), m);
    if m == 0 {
        return;
    }
    if !worth_forking(m * v.len()) {
        for (j, c) in coeff.iter_mut().enumerate() {
            *c = dot(basis.col(j), v);
        }
        return;
    }
    parallel_chunks_mut(coeff, num_threads(), |j0, cc| {
        for (t, c) in cc.iter_mut().enumerate() {
            *c = dot(basis.col(j0 + t), v);
        }
    });
}

/// v −= Σⱼ coeffⱼ · basisⱼ — the update pass of blocked CGS, parallel over
/// row chunks (each worker streams the same basis columns over its slice).
pub fn subtract_combo(basis: &ColBasis, coeff: &[f64], v: &mut [f64]) {
    let m = basis.ncols();
    debug_assert_eq!(coeff.len(), m);
    if m == 0 {
        return;
    }
    if !worth_forking(m * v.len()) {
        for (j, &cj) in coeff.iter().enumerate() {
            if cj != 0.0 {
                crate::linalg::axpy(-cj, basis.col(j), v);
            }
        }
        return;
    }
    parallel_chunks_mut(v, num_threads(), |lo, chunk| {
        for j in 0..m {
            let cj = coeff[j];
            if cj == 0.0 {
                continue;
            }
            let col = &basis.col(j)[lo..lo + chunk.len()];
            for (y, x) in chunk.iter_mut().zip(col.iter()) {
                *y -= cj * *x;
            }
        }
    });
}

/// Two-round blocked CGS of `v` against the columns of `basis` — replaces
/// the vector-at-a-time dot/axpy interleave with two streaming passes per
/// round (`coeff` is caller-owned scratch, resized in place within its
/// reserved capacity).
pub fn reorth_blocked(basis: &ColBasis, v: &mut [f64], coeff: &mut Vec<f64>) {
    let m = basis.ncols();
    if m == 0 {
        return;
    }
    coeff.clear();
    coeff.resize(m, 0.0);
    for _round in 0..2 {
        dots_into(basis, v, &mut coeff[..m]);
        subtract_combo(basis, &coeff[..m], v);
    }
}

/// Orthonormalize `v` against `basis` (blocked CGS2) and append it if it
/// stays independent (relative tolerance against its incoming norm, as in
/// `orthonormalize_against`). Returns whether the column was kept.
pub fn append_orthonormalized(
    basis: &mut ColBasis,
    v: &mut [f64],
    coeff: &mut Vec<f64>,
) -> bool {
    let nrm0 = nrm2(v);
    if nrm0 <= 1e-300 {
        return false;
    }
    reorth_blocked(basis, v, coeff);
    let nrm = nrm2(v);
    if nrm <= 1e-10 * nrm0 {
        return false;
    }
    let inv = 1.0 / nrm;
    for x in v.iter_mut() {
        *x *= inv;
    }
    basis.push_col(v);
    true
}

/// H = AᵀB over two column bases with the same row count: h (row-major
/// m×m slice) gets h\[i·m+j\] = aᵢ · bⱼ. Parallel over rows of H.
pub fn gram_pairs_into(a: &ColBasis, b: &ColBasis, h: &mut [f64], m: usize) {
    debug_assert_eq!(a.ncols(), m);
    debug_assert_eq!(b.ncols(), m);
    debug_assert_eq!(h.len(), m * m);
    if m == 0 {
        return;
    }
    if !worth_forking(m * m * a.rows()) {
        for (i, hrow) in h.chunks_mut(m).enumerate() {
            let ai = a.col(i);
            for (j, hj) in hrow.iter_mut().enumerate() {
                *hj = dot(ai, b.col(j));
            }
        }
        return;
    }
    parallel_rows_mut(h, m, |i0, rows| {
        for (di, hrow) in rows.chunks_mut(m).enumerate() {
            let ai = a.col(i0 + di);
            for (j, hj) in hrow.iter_mut().enumerate() {
                *hj = dot(ai, b.col(j));
            }
        }
    });
}

/// Symmetrize a row-major m×m slice in place by averaging mirrored pairs.
pub fn symmetrize_in_place(h: &mut [f64], m: usize) {
    for i in 0..m {
        for j in 0..i {
            let avg = 0.5 * (h[i * m + j] + h[j * m + i]);
            h[i * m + j] = avg;
            h[j * m + i] = avg;
        }
    }
}

/// out = basis · Q\[:, ..take\]: out column j = Σₗ q\[l,j\]·basisₗ.
/// Parallel over output columns (each is a contiguous slice).
pub fn combine_into(basis: &ColBasis, q: &Mat, take: usize, out: &mut ColBasis) {
    let rows = basis.rows();
    let m = basis.ncols();
    debug_assert_eq!(q.rows, m);
    debug_assert!(take <= q.cols);
    out.rows = rows;
    out.ncols = take;
    out.data.clear();
    out.data.resize(rows * take, 0.0);
    if take == 0 || rows == 0 {
        return;
    }
    if !worth_forking(take * m * rows) {
        for (j, ocol) in out.data.chunks_mut(rows).enumerate() {
            for l in 0..m {
                let w = q.at(l, j);
                if w == 0.0 {
                    continue;
                }
                for (o, x) in ocol.iter_mut().zip(basis.col(l).iter()) {
                    *o += w * *x;
                }
            }
        }
        return;
    }
    parallel_rows_mut(&mut out.data, rows, |c0, cols| {
        for (dc, ocol) in cols.chunks_mut(rows).enumerate() {
            let j = c0 + dc;
            for l in 0..m {
                let w = q.at(l, j);
                if w == 0.0 {
                    continue;
                }
                for (o, x) in ocol.iter_mut().zip(basis.col(l).iter()) {
                    *o += w * *x;
                }
            }
        }
    });
}

/// Everything a Davidson or Lanczos run touches per iteration, preallocated
/// once and reused. See the module docs for the zero-allocation contract.
pub struct SolverWorkspace {
    /// Fused gram kernel scratch (strip schedule + per-thread tiles).
    pub gram: GramScratch,
    // ---- row-major bridge blocks (input/output of the sparse kernels)
    pub(crate) blk: Mat,
    pub(crate) s_blk: Mat,
    // ---- Davidson
    pub(crate) basis: ColBasis,
    pub(crate) s_basis: ColBasis,
    pub(crate) prev: ColBasis,
    pub(crate) x: ColBasis,
    pub(crate) sx: ColBasis,
    pub(crate) resid: ColBasis,
    pub(crate) h: Mat,
    pub(crate) q: Mat,
    pub(crate) vals: Vec<f64>,
    pub(crate) eig: SymEigWs,
    pub(crate) coeff: Vec<f64>,
    pub(crate) tmp_col: Vec<f64>,
    // ---- Lanczos
    pub(crate) us: ColBasis,
    pub(crate) vs: ColBasis,
    pub(crate) locked: ColBasis,
    pub(crate) last: ColBasis,
    pub(crate) uritz: ColBasis,
    pub(crate) alphas: Vec<f64>,
    pub(crate) betas: Vec<f64>,
    pub(crate) start: Vec<f64>,
    pub(crate) vtmp: Vec<f64>,
    pub(crate) utmp: Vec<f64>,
    pub(crate) locked_vals: Vec<f64>,
    pub(crate) last_vals: Vec<f64>,
    pub(crate) bmat: Mat,
    pub(crate) svd: SmallSvdWs,
    // ---- Compressive (Chebyshev filter + Tikhonov interpolation)
    /// Random Gaussian signals (n×η), drawn once up front per solve.
    pub(crate) cb_sig: Mat,
    /// Leading-column slice of the signals used by the eigencount
    /// dichotomy (narrower block → cheaper counting filters).
    pub(crate) cb_cnt: Mat,
    /// Chebyshev recurrence rotation: T_{j−1}·B, T_j·B, S·(T_j·B).
    pub(crate) cb_prev: Mat,
    pub(crate) cb_cur: Mat,
    pub(crate) cb_sg: Mat,
    /// Filter accumulator Σⱼ gⱼcⱼ·Tⱼ·B.
    pub(crate) cb_acc: Mat,
    /// Damped Chebyshev coefficients gⱼ·cⱼ, j = 0..=p.
    pub(crate) cb_coef: Vec<f64>,
    /// Orthonormalized filtered signals and their S-images (Rayleigh–Ritz).
    pub(crate) cb_basis: ColBasis,
    pub(crate) cb_sbasis: ColBasis,
    /// λ_max power-iteration buffers.
    pub(crate) power: PowerIterWs,
    // block-CG buffers for the Tikhonov label interpolation
    pub(crate) cg_x: Mat,
    pub(crate) cg_r: Mat,
    pub(crate) cg_p: Mat,
    pub(crate) cg_ap: Mat,
    pub(crate) cg_scal: Vec<f64>,
    pub(crate) cg_rs: Vec<f64>,
    pub(crate) cg_rs2: Vec<f64>,
    pub(crate) cg_mask: Vec<f64>,
    pub(crate) cb_sample_idx: Vec<usize>,
}

impl Default for SolverWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl SolverWorkspace {
    pub fn new() -> SolverWorkspace {
        SolverWorkspace {
            gram: GramScratch::new(),
            blk: Mat::zeros(0, 0),
            s_blk: Mat::zeros(0, 0),
            basis: ColBasis::new(),
            s_basis: ColBasis::new(),
            prev: ColBasis::new(),
            x: ColBasis::new(),
            sx: ColBasis::new(),
            resid: ColBasis::new(),
            h: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            vals: Vec::new(),
            eig: SymEigWs::new(),
            coeff: Vec::new(),
            tmp_col: Vec::new(),
            us: ColBasis::new(),
            vs: ColBasis::new(),
            locked: ColBasis::new(),
            last: ColBasis::new(),
            uritz: ColBasis::new(),
            alphas: Vec::new(),
            betas: Vec::new(),
            start: Vec::new(),
            vtmp: Vec::new(),
            utmp: Vec::new(),
            locked_vals: Vec::new(),
            last_vals: Vec::new(),
            bmat: Mat::zeros(0, 0),
            svd: SmallSvdWs::new(),
            cb_sig: Mat::zeros(0, 0),
            cb_cnt: Mat::zeros(0, 0),
            cb_prev: Mat::zeros(0, 0),
            cb_cur: Mat::zeros(0, 0),
            cb_sg: Mat::zeros(0, 0),
            cb_acc: Mat::zeros(0, 0),
            cb_coef: Vec::new(),
            cb_basis: ColBasis::new(),
            cb_sbasis: ColBasis::new(),
            power: PowerIterWs::new(),
            cg_x: Mat::zeros(0, 0),
            cg_r: Mat::zeros(0, 0),
            cg_p: Mat::zeros(0, 0),
            cg_ap: Mat::zeros(0, 0),
            cg_scal: Vec::new(),
            cg_rs: Vec::new(),
            cg_rs2: Vec::new(),
            cg_mask: Vec::new(),
            cb_sample_idx: Vec::new(),
        }
    }

    /// Provision every buffer a Davidson run of (n, k, max_basis) touches.
    pub(crate) fn ensure_davidson(&mut self, n: usize, k: usize, max_basis: usize) {
        self.basis.reset(n, max_basis);
        self.s_basis.reset(n, max_basis);
        self.prev.reset(n, k);
        self.x.reset(n, k);
        self.sx.reset(n, k);
        self.resid.reset(n, k);
        self.blk.reserve_for(n, max_basis);
        self.s_blk.reserve_for(n, max_basis);
        self.h.reserve_for(max_basis, max_basis);
        self.q.reserve_for(max_basis, k);
        self.eig.reserve(max_basis);
        reserve_vec(&mut self.vals, k);
        reserve_vec(&mut self.coeff, max_basis);
        reserve_vec(&mut self.tmp_col, n);
    }

    /// Provision every buffer a Lanczos run of (n, d, subspace m, k)
    /// touches.
    pub(crate) fn ensure_lanczos(&mut self, n: usize, d: usize, m: usize, k: usize) {
        self.us.reset(n, m + 1);
        self.vs.reset(d, m + 1);
        self.locked.reset(n, k);
        self.last.reset(n, k + 1);
        self.uritz.reset(n, k + 1);
        self.blk.reserve_for(n, k + 1);
        self.s_blk.reserve_for(n, k + 1);
        self.bmat.reserve_for(m + 1, m + 1);
        self.svd.reserve(m + 1, m + 1);
        reserve_vec(&mut self.alphas, m + 1);
        reserve_vec(&mut self.betas, m + 1);
        reserve_vec(&mut self.start, n);
        reserve_vec(&mut self.vtmp, d);
        reserve_vec(&mut self.utmp, n);
        reserve_vec(&mut self.locked_vals, k);
        reserve_vec(&mut self.last_vals, k + 1);
        reserve_vec(&mut self.coeff, m + 1);
        self.locked.clear_cols();
        self.locked_vals.clear();
        self.last.clear_cols();
        self.last_vals.clear();
    }

    /// Provision every buffer a compressive run of (n rows, η signals,
    /// order p, k interpolation columns) touches — the filter recurrence,
    /// the Rayleigh–Ritz extraction, and the block-CG interpolation.
    pub(crate) fn ensure_compressive(&mut self, n: usize, eta: usize, order: usize, k: usize) {
        self.cb_sig.reserve_for(n, eta);
        self.cb_cnt.reserve_for(n, eta);
        self.cb_prev.reserve_for(n, eta);
        self.cb_cur.reserve_for(n, eta);
        self.cb_sg.reserve_for(n, eta);
        self.cb_acc.reserve_for(n, eta);
        reserve_vec(&mut self.cb_coef, order + 1);
        self.cb_basis.reset(n, eta);
        self.cb_sbasis.reset(n, eta);
        self.blk.reserve_for(n, eta);
        self.s_blk.reserve_for(n, eta);
        self.h.reserve_for(eta, eta);
        self.q.reserve_for(eta, eta);
        self.eig.reserve(eta);
        reserve_vec(&mut self.vals, eta);
        reserve_vec(&mut self.coeff, eta);
        reserve_vec(&mut self.tmp_col, n);
        self.cg_x.reserve_for(n, k);
        self.cg_r.reserve_for(n, k);
        self.cg_p.reserve_for(n, k);
        self.cg_ap.reserve_for(n, k);
        reserve_vec(&mut self.cg_scal, k);
        reserve_vec(&mut self.cg_rs, k);
        reserve_vec(&mut self.cg_rs2, k);
        reserve_vec(&mut self.cg_mask, n);
        if self.cb_sample_idx.capacity() < n {
            self.cb_sample_idx.reserve(n - self.cb_sample_idx.len());
        }
    }
}

fn reserve_vec(v: &mut Vec<f64>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn rand_basis(rng: &mut Pcg, rows: usize, cols: usize) -> ColBasis {
        let mut b = ColBasis::new();
        b.reset(rows, cols + 2);
        let mut coeff = Vec::new();
        let mut v = vec![0.0; rows];
        for _ in 0..cols {
            for x in v.iter_mut() {
                *x = rng.normal();
            }
            assert!(append_orthonormalized(&mut b, &mut v, &mut coeff));
        }
        b
    }

    #[test]
    fn append_builds_orthonormal_basis() {
        let mut rng = Pcg::seed(41);
        let b = rand_basis(&mut rng, 60, 6);
        assert_eq!(b.ncols(), 6);
        for i in 0..6 {
            for j in 0..6 {
                let d = dot(b.col(i), b.col(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-10, "({i},{j}): {d}");
            }
        }
    }

    #[test]
    fn append_rejects_dependent_columns() {
        let mut rng = Pcg::seed(42);
        let mut b = rand_basis(&mut rng, 30, 4);
        let mut coeff = Vec::new();
        // a vector inside span(b) must be rejected
        let mut v = vec![0.0; 30];
        for j in 0..4 {
            let w = rng.range_f64(-1.0, 1.0);
            for (x, c) in v.iter_mut().zip(b.col(j).iter()) {
                *x += w * c;
            }
        }
        assert!(!append_orthonormalized(&mut b, &mut v, &mut coeff));
        assert_eq!(b.ncols(), 4);
    }

    #[test]
    fn gram_pairs_matches_dense() {
        let mut rng = Pcg::seed(43);
        let (rows, m) = (25, 5);
        let mut a = ColBasis::new();
        a.reset(rows, m);
        let mut b = ColBasis::new();
        b.reset(rows, m);
        for _ in 0..m {
            let ca: Vec<f64> = (0..rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let cb: Vec<f64> = (0..rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            a.push_col(&ca);
            b.push_col(&cb);
        }
        let mut h = vec![0.0; m * m];
        gram_pairs_into(&a, &b, &mut h, m);
        for i in 0..m {
            for j in 0..m {
                let want = dot(a.col(i), b.col(j));
                assert!((h[i * m + j] - want).abs() < 1e-12);
            }
        }
        symmetrize_in_place(&mut h, m);
        for i in 0..m {
            for j in 0..m {
                assert_eq!(h[i * m + j], h[j * m + i]);
            }
        }
    }

    #[test]
    fn combine_matches_explicit_sum() {
        let mut rng = Pcg::seed(44);
        let (rows, m, take) = (40, 6, 3);
        let basis = rand_basis(&mut rng, rows, m);
        let q = Mat::from_vec(m, take, (0..m * take).map(|_| rng.range_f64(-1.0, 1.0)).collect());
        let mut out = ColBasis::new();
        out.reset(rows, take);
        combine_into(&basis, &q, take, &mut out);
        for j in 0..take {
            for i in 0..rows {
                let mut want = 0.0;
                for l in 0..m {
                    want += q.at(l, j) * basis.col(l)[i];
                }
                assert!((out.col(j)[i] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reorth_blocked_removes_components() {
        let mut rng = Pcg::seed(45);
        let basis = rand_basis(&mut rng, 50, 5);
        let mut v: Vec<f64> = (0..50).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut coeff = Vec::new();
        reorth_blocked(&basis, &mut v, &mut coeff);
        for j in 0..5 {
            let d = dot(basis.col(j), &v);
            assert!(d.abs() < 1e-11, "residual projection on {j}: {d}");
        }
    }

    #[test]
    fn col_mat_bridges_roundtrip() {
        let mut b = ColBasis::new();
        b.reset(3, 2);
        b.push_col(&[1.0, 2.0, 3.0]);
        let mut m = Mat::zeros(3, 2);
        b.store_col_to_mat(0, &mut m, 1);
        assert_eq!(m.col(1), vec![1.0, 2.0, 3.0]);
        let mut b2 = ColBasis::new();
        b2.reset(3, 1);
        b2.push_col_from_mat(&m, 1);
        assert_eq!(b2.col(0), &[1.0, 2.0, 3.0][..]);
    }
}
