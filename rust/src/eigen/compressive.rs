//! Compressive spectral solver (Tremblay et al., *Compressive Spectral
//! Clustering*): Chebyshev graph filtering of random signals instead of
//! an eigendecomposition.
//!
//! Where Davidson and Lanczos orthogonalize a growing basis every
//! iteration, this backend approximates the ideal low-pass filter
//! `h_λk(S)` (an indicator of the top-k spectral interval of the gram
//! operator S = Ẑ·Ẑᵀ) by an order-p Chebyshev polynomial with Jackson
//! damping and applies it to η = O(log n) random Gaussian signals. Each
//! recurrence step is one fused [`SvdOp::gram_matmat_into`] block product
//! — the no-intermediate strip-tiled kernel of PR 2 — so the whole solve
//! is p sweeps over the substrate with zero steady-state allocations
//! (buffers live in [`SolverWorkspace`], enforced by `tests/alloc.rs`).
//!
//! The spectral interval comes from a power iteration bounding λ_max
//! ([`crate::linalg::power_lambda_max`]) plus the CSC **eigencount
//! dichotomy**: `‖h_t(S)·R‖²_F / η` estimates #{λᵢ ≥ t}, so bisecting t
//! locates λ_k without ever computing an eigenvalue. The counting
//! filters run on a narrower leading slice of the same up-front-drawn
//! signals (count estimates need far fewer probes than the embedding).
//!
//! Two consumers share this machinery:
//! - [`compressive_svd_ws`] — filter + Rayleigh–Ritz on the filtered
//!   span, producing honest singular triplets behind the standard
//!   [`super::svds`] driver (`Solver::Compressive`).
//! - SC_RB's `FilterEmbed` stage — the full CSC path: k-means on a
//!   uniformly sampled row subset of the filtered signals, then
//!   [`tikhonov_interpolate`] spreads the sample labels to all N rows
//!   through a block-CG solve on the same gram kernel.

use super::davidson::finalize;
use super::op::SvdOp;
use super::workspace::{
    append_orthonormalized, combine_into, gather_cols_to_mat, gram_pairs_into,
    symmetrize_in_place, SolverWorkspace,
};
use super::SvdResult;
use crate::linalg::{power_lambda_max, sym_eig_into, Mat};
use crate::util::rng::Pcg;

/// Options for the compressive solver.
#[derive(Clone, Debug)]
pub struct CompressiveOpts {
    /// Singular triplets kept (the embedding width).
    pub k: usize,
    /// Chebyshev filter order p: one gram block product per order.
    pub order: usize,
    /// Number of random signals η; `None` = max(k + 2, ⌈4·ln n⌉).
    pub signals: Option<usize>,
    /// Interpolation CG relative-residual tolerance (also reused as the
    /// early-exit threshold by `tikhonov_interpolate`).
    pub tol: f64,
    /// Matvec budget; the solve is not truncated (its cost is fixed by
    /// p and η up front) but `stats.converged` reports the overrun.
    pub max_matvecs: usize,
}

impl CompressiveOpts {
    pub fn new(k: usize) -> Self {
        CompressiveOpts { k, order: 25, signals: None, tol: 1e-5, max_matvecs: 5000 }
    }

    /// Resolved signal count for an n-row operator.
    pub fn eta(&self, n: usize) -> usize {
        let auto = (4.0 * (n.max(2) as f64).ln()).ceil() as usize;
        self.signals.unwrap_or(auto).max(self.k + 2).min(n.max(1))
    }
}

/// Everything the CSC pipeline consumes beyond the singular triplets.
pub(crate) struct CompressiveParts {
    pub svd: SvdResult,
    /// Filtered signals h_λk(S)·R, n×η.
    pub filtered: Mat,
    /// Inflated spectral upper bound λ̄ ≥ λ_max(S).
    pub lambda_max: f64,
    /// Dichotomy estimate of the k-th eigenvalue (filter threshold).
    pub lambda_k: f64,
}

/// Top-k singular triplets via Chebyshev filtering + Rayleigh–Ritz, with
/// a fresh private workspace.
pub fn compressive_svd<O: SvdOp + ?Sized>(
    a: &O,
    opts: &CompressiveOpts,
    seed: u64,
) -> SvdResult {
    let mut ws = SolverWorkspace::new();
    compressive_svd_ws(a, opts, seed, &mut ws)
}

/// [`compressive_svd`] with an explicit, reusable [`SolverWorkspace`]:
/// after the `ensure` pass at entry, filter iterations perform zero heap
/// allocations.
pub fn compressive_svd_ws<O: SvdOp + ?Sized>(
    a: &O,
    opts: &CompressiveOpts,
    seed: u64,
    ws: &mut SolverWorkspace,
) -> SvdResult {
    compressive_parts_ws(a, opts, seed, ws).svd
}

/// The shared engine behind [`compressive_svd_ws`] and SC_RB's
/// `FilterEmbed`: spectral-interval estimation, the final filter pass,
/// and Rayleigh–Ritz extraction, returning the filtered signals alongside
/// the triplets.
pub(crate) fn compressive_parts_ws<O: SvdOp + ?Sized>(
    a: &O,
    opts: &CompressiveOpts,
    seed: u64,
    ws: &mut SolverWorkspace,
) -> CompressiveParts {
    let n = a.nrows();
    assert!(n > 0, "compressive solver on an empty operator");
    let k = opts.k.min(n).max(1);
    let order = opts.order.max(2);
    let eta = opts.eta(n);
    ws.ensure_compressive(n, eta, order, k);
    a.prepare_gram(&mut ws.gram, eta);
    let mut matvecs = 0usize;

    // Draw every random signal once, up front, from one seeded stream —
    // filtering then touches no RNG at all, which is what makes the
    // embedding bit-reproducible across thread counts (the fused gram
    // kernel accumulates in a fixed order regardless of partitioning).
    let mut rng = Pcg::new(seed, 0x0c5c);
    ws.cb_sig.reset(n, eta);
    for v in ws.cb_sig.data.iter_mut() {
        *v = rng.normal();
    }
    // Counting slice: the leading min(η, 16) columns of the same signals.
    let eta_cnt = eta.min(16);
    {
        let SolverWorkspace { cb_sig, cb_cnt, .. } = ws;
        cb_cnt.reset(n, eta_cnt);
        for i in 0..n {
            cb_cnt.row_mut(i).copy_from_slice(&cb_sig.row(i)[..eta_cnt]);
        }
    }

    // Spectral interval: power iteration bounds λ_max; the Rayleigh
    // quotient is a lower bound, so inflate before mapping the Chebyshev
    // domain (a spectrum point outside [0, λ̄] would diverge).
    let (est, mv) = gram_lambda_max(a, seed ^ 0x9d2c, ws);
    matvecs += mv;
    if est <= 0.0 {
        // Zero operator: every triplet is zero.
        ws.vals.clear();
        ws.vals.resize(k, 0.0);
        let svd = finalize(a, Mat::zeros(n, k), &ws.vals, matvecs, 0, true);
        return CompressiveParts {
            svd,
            filtered: Mat::zeros(n, eta),
            lambda_max: 0.0,
            lambda_k: 0.0,
        };
    }
    let lmax = est * 1.05;

    // Eigencount dichotomy for λ_k: count(t) = ‖h_t(S)·R‖²_F/η ≈
    // #{λᵢ ≥ t} is decreasing in t; bisect for the largest t still
    // counting ≥ k eigenvalues. Counting filters use a reduced order —
    // bisection only needs the smoothed count's crossing point.
    let order_cnt = order.min(16).max(2);
    let (mut lo, mut hi) = (0.0f64, lmax);
    for _ in 0..8 {
        let mid = 0.5 * (lo + hi);
        cheb_step_coeffs(threshold_to_domain(mid, lmax), order_cnt, &mut ws.cb_coef);
        matvecs += apply_filter(a, lmax, order_cnt, true, ws);
        let count = ws.cb_acc.data.iter().map(|v| v * v).sum::<f64>() / eta_cnt as f64;
        if count >= k as f64 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let lk = 0.5 * (lo + hi);

    // Final filter pass at λ_k over the full signal block.
    cheb_step_coeffs(threshold_to_domain(lk, lmax), order, &mut ws.cb_coef);
    matvecs += apply_filter(a, lmax, order, false, ws);

    // Rayleigh–Ritz on span(filtered): orthonormalize the filtered
    // columns, project S, and keep the top-k Ritz pairs — the honest
    // singular-triplet face of the filter.
    {
        let SolverWorkspace { cb_acc, cb_basis, tmp_col, coeff, .. } = ws;
        cb_basis.clear_cols();
        for j in 0..eta {
            tmp_col.clear();
            tmp_col.extend((0..n).map(|i| cb_acc.at(i, j)));
            append_orthonormalized(cb_basis, tmp_col, coeff);
        }
    }
    let m = ws.cb_basis.ncols();
    let take = k.min(m.max(1));
    if m == 0 {
        // Filter annihilated every signal (threshold above the whole
        // spectrum) — report zeros rather than panic.
        ws.vals.clear();
        ws.vals.resize(take, 0.0);
        let svd = finalize(a, Mat::zeros(n, take), &ws.vals, matvecs, order, false);
        let filtered = ws.cb_acc.clone();
        return CompressiveParts { svd, filtered, lambda_max: lmax, lambda_k: lk };
    }
    gather_cols_to_mat(&ws.cb_basis, 0, &mut ws.blk);
    a.gram_matmat_into(&ws.blk, &mut ws.s_blk, &mut ws.gram);
    matvecs += 2 * m;
    ws.cb_sbasis.clear_cols();
    for t in 0..m {
        ws.cb_sbasis.push_col_from_mat(&ws.s_blk, t);
    }
    ws.h.reset(m, m);
    gram_pairs_into(&ws.cb_basis, &ws.cb_sbasis, &mut ws.h.data, m);
    symmetrize_in_place(&mut ws.h.data, m);
    sym_eig_into(&ws.h, &mut ws.eig);
    ws.q.reset(m, take);
    ws.vals.clear();
    for j in 0..take {
        let src = m - 1 - j; // eigenvalues ascend; take the top
        ws.vals.push(ws.eig.w[src].max(0.0));
        for i in 0..m {
            ws.q.set(i, j, ws.eig.vecs.at(i, src));
        }
    }
    combine_into(&ws.cb_basis, &ws.q, take, &mut ws.cb_sbasis);

    // Epilogue (the only allocations after `ensure`).
    let mut u = Mat::zeros(n, take);
    for j in 0..take {
        ws.cb_sbasis.store_col_to_mat(j, &mut u, j);
    }
    let converged = matvecs <= opts.max_matvecs;
    let svd = finalize(a, u, &ws.vals, matvecs, order, converged);
    let filtered = ws.cb_acc.clone();
    CompressiveParts { svd, filtered, lambda_max: lmax, lambda_k: lk }
}

/// λ_max(S) by power iteration through the fused gram kernel, bridged
/// over the workspace's one-column row-major block. Returns (estimate,
/// matvecs spent).
fn gram_lambda_max<O: SvdOp + ?Sized>(a: &O, seed: u64, ws: &mut SolverWorkspace) -> (f64, usize) {
    let n = a.nrows();
    let iters = 30;
    let SolverWorkspace { power, blk, s_blk, gram, .. } = ws;
    let est = power_lambda_max(
        n,
        |x, y| {
            blk.reset(n, 1);
            blk.data.copy_from_slice(x);
            a.gram_matmat_into(blk, s_blk, gram);
            y.copy_from_slice(&s_blk.data);
        },
        iters,
        seed,
        power,
    );
    (est, 2 * iters)
}

/// Map an eigenvalue threshold t ∈ [0, λ̄] to the Chebyshev domain
/// a ∈ [-1, 1] under y = (2x − λ̄)/λ̄.
fn threshold_to_domain(t: f64, lmax: f64) -> f64 {
    (2.0 * t / lmax - 1.0).clamp(-1.0, 1.0)
}

/// Jackson-damped Chebyshev coefficients of the step function 1_{y ≥ a}
/// on [-1, 1]: cⱼ from the closed-form expansion, gⱼ the Jackson kernel
/// that suppresses Gibbs oscillation near the step.
fn cheb_step_coeffs(a: f64, order: usize, out: &mut Vec<f64>) {
    let theta = a.clamp(-1.0, 1.0).acos();
    let pi = std::f64::consts::PI;
    let q = (order + 2) as f64;
    let alpha = pi / q;
    let sin_a = alpha.sin();
    out.clear();
    for j in 0..=order {
        let c = if j == 0 { theta / pi } else { 2.0 * ((j as f64) * theta).sin() / (j as f64 * pi) };
        let g = if j == 0 {
            1.0
        } else {
            let jf = j as f64;
            ((1.0 - jf / q) * sin_a * (jf * alpha).cos() + (jf * alpha).sin() * alpha.cos() / q)
                / sin_a
        };
        out.push(c * g);
    }
}

/// Apply the filter Σⱼ coefⱼ·Tⱼ(y(S)) to a signal block via the
/// three-term recurrence Tⱼ₊₁·B = (4/λ̄)·S·(Tⱼ·B) − 2·(Tⱼ·B) − Tⱼ₋₁·B,
/// one fused gram product per order. Source is the counting slice when
/// `use_cnt` (the dichotomy) or the full signal block (the final pass);
/// the result lands in `ws.cb_acc`. Returns matvecs spent. Buffer
/// rotation is by pointer swap — steady state allocates nothing.
fn apply_filter<O: SvdOp + ?Sized>(
    a: &O,
    lmax: f64,
    order: usize,
    use_cnt: bool,
    ws: &mut SolverWorkspace,
) -> usize {
    let SolverWorkspace { cb_sig, cb_cnt, cb_prev, cb_cur, cb_sg, cb_acc, cb_coef, gram, .. } = ws;
    let src: &Mat = if use_cnt { cb_cnt } else { cb_sig };
    let (n, w) = (src.rows, src.cols);
    debug_assert!(cb_coef.len() == order + 1);
    let mut mv = 0usize;

    // T₀·B = B
    cb_prev.reset(n, w);
    cb_prev.data.copy_from_slice(&src.data);
    cb_acc.reset(n, w);
    let c0 = cb_coef[0];
    for (o, s) in cb_acc.data.iter_mut().zip(src.data.iter()) {
        *o = c0 * *s;
    }
    // T₁·B = y(S)·B = (2/λ̄)·S·B − B
    a.gram_matmat_into(src, cb_sg, gram);
    mv += 2 * w;
    cb_cur.reset(n, w);
    let two_inv = 2.0 / lmax;
    for ((c, sg), s) in cb_cur.data.iter_mut().zip(cb_sg.data.iter()).zip(src.data.iter()) {
        *c = two_inv * *sg - *s;
    }
    let c1 = cb_coef[1];
    for (o, c) in cb_acc.data.iter_mut().zip(cb_cur.data.iter()) {
        *o += c1 * *c;
    }
    // Recurrence for j = 2..=p; cb_sg becomes Tⱼ·B in place.
    let four_inv = 4.0 / lmax;
    for &cj in cb_coef.iter().take(order + 1).skip(2) {
        a.gram_matmat_into(cb_cur, cb_sg, gram);
        mv += 2 * w;
        for ((sg, c), p) in
            cb_sg.data.iter_mut().zip(cb_cur.data.iter()).zip(cb_prev.data.iter())
        {
            *sg = four_inv * *sg - 2.0 * *c - *p;
        }
        for (o, t) in cb_acc.data.iter_mut().zip(cb_sg.data.iter()) {
            *o += cj * *t;
        }
        std::mem::swap(cb_prev, cb_cur);
        std::mem::swap(cb_cur, cb_sg);
    }
    mv
}

/// Uniform sample of `m` distinct row indices out of `n` (sorted
/// ascending), written into caller-owned scratch. Partial Fisher–Yates
/// over an identity permutation — deterministic for a fixed seed.
pub fn sample_rows(n: usize, m: usize, seed: u64, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..n);
    let m = m.min(n);
    let mut rng = Pcg::new(seed, 0x5a3d);
    for i in 0..m {
        let j = i + rng.below(n - i);
        idx.swap(i, j);
    }
    idx.truncate(m);
    idx.sort_unstable();
}

/// Tikhonov-regularized label interpolation (CSC step 4): solve
/// `(M + γ(λ̄I − S))·X = Y` by block CG, where M masks the sampled rows,
/// Y holds their one-hot cluster indicators, and γ(λ̄I − S) is the PSD
/// smoothness regularizer of the gram operator (top eigenvectors of S =
/// smooth cluster indicators). Each CG iteration is one fused gram block
/// product serving all k right-hand sides, with per-column α/β scalars.
/// Returns the n×k score matrix and the matvecs spent.
pub fn tikhonov_interpolate<O: SvdOp + ?Sized>(
    a: &O,
    sample_idx: &[usize],
    sample_labels: &[u32],
    k: usize,
    lmax: f64,
    gamma: f64,
    tol: f64,
    max_iters: usize,
    ws: &mut SolverWorkspace,
) -> (Mat, usize) {
    let n = a.nrows();
    debug_assert_eq!(sample_idx.len(), sample_labels.len());
    let lbar = lmax * (1.0 + 1e-6); // tiny ridge keeps the system PD
    let SolverWorkspace { cg_x, cg_r, cg_p, cg_ap, cg_scal, cg_rs, cg_rs2, cg_mask, gram, .. } =
        ws;
    cg_mask.clear();
    cg_mask.resize(n, 0.0);
    for &i in sample_idx {
        cg_mask[i] = 1.0;
    }
    cg_x.reset(n, k);
    cg_r.reset(n, k); // r₀ = Y − A·0 = Y
    for (pos, &i) in sample_idx.iter().enumerate() {
        cg_r.set(i, sample_labels[pos] as usize, 1.0);
    }
    cg_p.reset(n, k);
    cg_p.data.copy_from_slice(&cg_r.data);
    cg_rs.clear();
    cg_rs.resize(k, 0.0);
    for i in 0..n {
        for (acc, &rv) in cg_rs.iter_mut().zip(cg_r.row(i).iter()) {
            *acc += rv * rv;
        }
    }
    let rs_total0: f64 = cg_rs.iter().sum::<f64>().max(1e-300);
    let tol2 = tol * tol;
    let mut mv = 0usize;
    for _ in 0..max_iters {
        // Ap = M∘p + γ(λ̄·p − S·p): one gram product per iteration.
        a.gram_matmat_into(cg_p, cg_ap, gram);
        mv += 2 * k;
        for i in 0..n {
            let m = cg_mask[i];
            let row_p = i * k;
            for j in 0..k {
                let pv = cg_p.data[row_p + j];
                let sv = cg_ap.data[row_p + j];
                cg_ap.data[row_p + j] = m * pv + gamma * (lbar * pv - sv);
            }
        }
        // α_c = rs_c / (p_c·Ap_c)
        cg_scal.clear();
        cg_scal.resize(k, 0.0);
        for i in 0..n {
            for ((acc, &pv), &av) in
                cg_scal.iter_mut().zip(cg_p.row(i).iter()).zip(cg_ap.row(i).iter())
            {
                *acc += pv * av;
            }
        }
        for (al, &rs) in cg_scal.iter_mut().zip(cg_rs.iter()) {
            *al = if *al > 1e-300 { rs / *al } else { 0.0 };
        }
        // x += α∘p, r −= α∘Ap, rs' = ‖r‖² per column
        cg_rs2.clear();
        cg_rs2.resize(k, 0.0);
        for i in 0..n {
            let row = i * k;
            for j in 0..k {
                let al = cg_scal[j];
                cg_x.data[row + j] += al * cg_p.data[row + j];
                let rv = cg_r.data[row + j] - al * cg_ap.data[row + j];
                cg_r.data[row + j] = rv;
                cg_rs2[j] += rv * rv;
            }
        }
        let rs_total: f64 = cg_rs2.iter().sum();
        if rs_total <= tol2 * rs_total0 {
            std::mem::swap(cg_rs, cg_rs2);
            break;
        }
        // β_c = rs'_c/rs_c, p = r + β∘p
        for (be, (&new, &old)) in cg_scal.iter_mut().zip(cg_rs2.iter().zip(cg_rs.iter())) {
            *be = if old > 1e-300 { new / old } else { 0.0 };
        }
        for i in 0..n {
            let row = i * k;
            for j in 0..k {
                cg_p.data[row + j] = cg_r.data[row + j] + cg_scal[j] * cg_p.data[row + j];
            }
        }
        std::mem::swap(cg_rs, cg_rs2);
    }
    (cg_x.clone(), mv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Diagonal test operator: A = diag(√λ) so S = A·Aᵀ = diag(λ).
    fn diag_op(lambdas: &[f64]) -> Mat {
        let n = lambdas.len();
        let mut a = Mat::zeros(n, n);
        for (i, &l) in lambdas.iter().enumerate() {
            a.set(i, i, l.sqrt());
        }
        a
    }

    #[test]
    fn recovers_gapped_top_eigenspace() {
        // 4 large eigenvalues separated from a low bulk: the filter keeps
        // the top space and Ritz recovers σ = √λ to filter accuracy.
        let mut lambdas = vec![10.0, 9.5, 9.0, 8.5];
        for i in 0..60 {
            lambdas.push(1.0 - 0.01 * i as f64);
        }
        let a = diag_op(&lambdas);
        let mut opts = CompressiveOpts::new(4);
        opts.order = 60;
        opts.signals = Some(16);
        opts.max_matvecs = 1_000_000;
        let r = compressive_svd(&a, &opts, 5);
        assert!(r.stats.converged);
        assert_eq!(r.s.len(), 4);
        for j in 0..4 {
            let want = lambdas[j].sqrt();
            assert!(
                (r.s[j] - want).abs() < 1e-2 * want,
                "σ_{j}: {} vs {want}",
                r.s[j]
            );
        }
        // Ritz vectors align with the top coordinate directions.
        for j in 0..4 {
            let col: Vec<f64> = (0..lambdas.len()).map(|i| r.u.at(i, j)).collect();
            let inside: f64 = col[..4].iter().map(|v| v * v).sum();
            assert!(inside > 0.99, "u_{j} leaks out of the top space: {inside}");
        }
    }

    #[test]
    fn dichotomy_brackets_lambda_k() {
        let mut lambdas = vec![10.0, 9.0, 8.0];
        for _ in 0..80 {
            lambdas.push(0.5);
        }
        let a = diag_op(&lambdas);
        let mut opts = CompressiveOpts::new(3);
        opts.order = 40;
        opts.signals = Some(12);
        let mut ws = SolverWorkspace::new();
        let parts = compressive_parts_ws(&a, &opts, 9, &mut ws);
        assert!(parts.lambda_max >= 10.0, "λ̄ = {}", parts.lambda_max);
        // threshold must separate the top-3 block from the bulk
        assert!(
            parts.lambda_k > 0.5 && parts.lambda_k < 8.0,
            "λ_k estimate {} outside (0.5, 8)",
            parts.lambda_k
        );
    }

    #[test]
    fn workspace_reuse_is_bit_deterministic() {
        let mut rng = Pcg::seed(71);
        let a = Mat::from_vec(50, 20, (0..1000).map(|_| rng.normal()).collect());
        let mut opts = CompressiveOpts::new(3);
        opts.order = 20;
        let fresh = compressive_svd(&a, &opts, 13);
        let mut ws = SolverWorkspace::new();
        let _warm = compressive_svd_ws(&a, &opts, 13, &mut ws);
        let reused = compressive_svd_ws(&a, &opts, 13, &mut ws);
        assert_eq!(fresh.s, reused.s, "singular values drift across workspace reuse");
        assert_eq!(fresh.u.data, reused.u.data, "U drifts across workspace reuse");
        assert_eq!(fresh.v.data, reused.v.data, "V drifts across workspace reuse");
    }

    #[test]
    fn step_coefficients_reproduce_the_indicator() {
        // The damped expansion evaluated by Clenshaw at sample points must
        // track 1_{y ≥ a} away from the step.
        let a = -0.2;
        let order = 120;
        let mut coef = Vec::new();
        cheb_step_coeffs(a, order, &mut coef);
        let eval = |y: f64| {
            // iterative T_j evaluation
            let (mut tm, mut t) = (1.0, y);
            let mut acc = coef[0] * tm + coef[1] * t;
            for c in coef.iter().skip(2) {
                let tn = 2.0 * y * t - tm;
                acc += c * tn;
                tm = t;
                t = tn;
            }
            acc
        };
        for &(y, want) in
            &[(-0.9, 0.0), (-0.5, 0.0), (0.1, 1.0), (0.5, 1.0), (0.9, 1.0)]
        {
            let h = eval(y);
            assert!((h - want).abs() < 0.05, "h({y}) = {h}, want ≈ {want}");
        }
    }

    #[test]
    fn sample_rows_is_sorted_unique_and_seeded() {
        let mut idx = Vec::new();
        sample_rows(100, 20, 7, &mut idx);
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "not sorted/unique: {idx:?}");
        assert!(idx.iter().all(|&i| i < 100));
        let mut idx2 = Vec::new();
        sample_rows(100, 20, 7, &mut idx2);
        assert_eq!(idx, idx2, "same seed, same sample");
        sample_rows(100, 200, 7, &mut idx2); // m > n clamps to n
        assert_eq!(idx2.len(), 100);
    }

    #[test]
    fn tikhonov_spreads_labels_to_smooth_neighbors() {
        // Block-diagonal A: rows 0..5 share one feature, rows 5..10
        // another, so S connects each block internally. Labeling one row
        // per block must pull the whole block to that label.
        let mut a = Mat::zeros(10, 2);
        for i in 0..5 {
            a.set(i, 0, 1.0);
        }
        for i in 5..10 {
            a.set(i, 1, 1.0);
        }
        let mut ws = SolverWorkspace::new();
        ws.ensure_compressive(10, 4, 8, 2);
        let (x, mv) =
            tikhonov_interpolate(&a, &[0, 7], &[0, 1], 2, 5.5, 0.1, 1e-8, 60, &mut ws);
        assert!(mv > 0);
        for i in 0..5 {
            assert!(x.at(i, 0) > x.at(i, 1), "row {i} scores {:?}", x.row(i));
        }
        for i in 5..10 {
            assert!(x.at(i, 1) > x.at(i, 0), "row {i} scores {:?}", x.row(i));
        }
    }
}
