//! "Matlab svds" analogue: restarted Golub–Kahan–Lanczos bidiagonalization
//! with full reorthogonalization and naive (non-thick) restarting.
//!
//! This solver is deliberately the *plain Lanczos class* the paper contrasts
//! PRIMME against (§3.2, §5.3): on well-separated spectra it is fine, but on
//! clustered singular values its simple restart discards subspace
//! information and convergence stalls — reproducing the Fig. 3 gap.

use super::op::SvdOp;
use super::{davidson::finalize, SvdResult};
use crate::linalg::{axpy, dot, nrm2, svd_thin, Mat};

/// Options for the Lanczos-bidiagonalization solver.
#[derive(Clone, Debug)]
pub struct LanczosOpts {
    pub k: usize,
    pub tol: f64,
    pub max_matvecs: usize,
    /// Krylov dimension per restart cycle.
    pub subspace: usize,
}

impl LanczosOpts {
    pub fn new(k: usize) -> Self {
        LanczosOpts { k, tol: 1e-5, max_matvecs: 5000, subspace: (3 * k + 12).max(20) }
    }
}

/// Top-k left singular triplets of `a` via restarted GKL bidiagonalization.
pub fn lanczos_svd<O: SvdOp + ?Sized>(a: &O, opts: &LanczosOpts, seed: u64) -> SvdResult {
    let n = a.nrows();
    let d = a.ncols();
    let k = opts.k.min(n.min(d));
    let m = opts.subspace.clamp(k + 2, n.min(d).max(k + 2));
    let mut rng = crate::util::rng::Pcg::new(seed, 0x1a2c05);

    // Starting vector (restart cycles replace this with the best Ritz u₁..u_k
    // combination — naive restart keeps only u₁'s direction).
    let mut start: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut matvecs = 0usize;
    let mut iters = 0usize;

    // Converged left singular vectors are *locked* (deflated): subsequent
    // Krylov spaces are kept orthogonal to them, which is how a single-
    // vector Lanczos can reach the remaining directions of a degenerate /
    // tightly clustered singular value (the covtype regime). This mirrors
    // what production svds implementations do; the weakness that remains —
    // and that Fig. 3 exercises — is the naive single-vector restart, which
    // discards the unconverged subspace every cycle.
    let mut locked_u: Vec<Vec<f64>> = Vec::new();
    let mut locked_vals: Vec<f64> = Vec::new();
    // best unconverged Ritz data from the last cycle (to fill the answer if
    // we hit the matvec budget before locking k pairs)
    let mut last_ritz: Vec<(f64, Vec<f64>)> = Vec::new();

    while matvecs < opts.max_matvecs && locked_u.len() < k {
        iters += 1;
        // GKL: A Vb = Ub B, Aᵀ Ub = Vb Bᵀ (+ residual), B lower-bidiagonal,
        // run in the complement of the locked subspace.
        let mut us: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut alphas = Vec::with_capacity(m);
        let mut betas = Vec::with_capacity(m);

        reorth(&locked_u, &mut start);
        let nrm = nrm2(&start);
        if nrm <= 1e-14 {
            start = (0..n).map(|_| rng.normal()).collect();
            reorth(&locked_u, &mut start);
        }
        let nrm = nrm2(&start).max(1e-300);
        let mut u: Vec<f64> = start.iter().map(|x| x / nrm).collect();
        us.push(u.clone());

        for j in 0..m {
            // v_j = Aᵀ u_j − β_{j−1} v_{j−1}, reorthogonalized
            let mut v = apply_t_vec(a, &u);
            matvecs += 1;
            if j > 0 {
                let beta_prev: f64 = betas[j - 1];
                axpy(-beta_prev, &vs[j - 1], &mut v);
            }
            reorth(&vs, &mut v);
            let alpha = nrm2(&v);
            alphas.push(alpha);
            if alpha <= 1e-14 {
                vs.push(vec![0.0; d]);
                betas.push(0.0);
                break;
            }
            v.iter_mut().for_each(|x| *x /= alpha);
            vs.push(v.clone());

            // u_{j+1} = A v_j − α_j u_j, reorthogonalized (incl. locked)
            let mut unew = apply_vec(a, &v);
            matvecs += 1;
            axpy(-alpha, &us[j], &mut unew);
            reorth(&locked_u, &mut unew);
            reorth(&us, &mut unew);
            let beta = nrm2(&unew);
            betas.push(beta);
            if beta <= 1e-14 || j + 1 == m {
                break;
            }
            unew.iter_mut().for_each(|x| *x /= beta);
            us.push(unew.clone());
            u = unew;
        }

        // SVD of the small bidiagonal projection: B is p×q with diag
        // alphas and subdiag betas.
        let p = us.len();
        let q = vs.len();
        let mut b = Mat::zeros(p, q);
        for j in 0..q.min(alphas.len()).min(p) {
            b.set(j, j, alphas[j]);
        }
        for j in 0..q.min(betas.len()) {
            if j + 1 < p {
                b.set(j + 1, j, betas[j]);
            }
        }
        let bs = svd_thin(&b);

        // Ritz left vectors for the unconverged slots.
        let want = k - locked_u.len();
        let take = (want + 1).min(bs.s.len()).min(p);
        let mut uritz = Mat::zeros(n, take);
        for jj in 0..take {
            let mut col = vec![0.0; n];
            for (row, uvec) in us.iter().enumerate() {
                let w = bs.u.at(row, jj);
                if w != 0.0 {
                    axpy(w, uvec, &mut col);
                }
            }
            uritz.set_col(jj, &col);
        }

        // Residuals of the Gram problem ‖S u − λ u‖ per Ritz pair.
        let su = a.apply(&a.apply_t(&uritz));
        matvecs += 2 * uritz.cols;
        let scale = locked_vals
            .first()
            .copied()
            .unwrap_or(bs.s.first().map(|s| s * s).unwrap_or(1.0))
            .max(1e-300);
        last_ritz.clear();
        let mut newly_locked = false;
        for j in 0..take {
            let lam = bs.s[j] * bs.s[j];
            let mut rcol = su.col(j);
            let uc = uritz.col(j);
            for (rv, uv) in rcol.iter_mut().zip(uc.iter()) {
                *rv -= lam * *uv;
            }
            let res = nrm2(&rcol) / scale;
            if res <= opts.tol && locked_u.len() < k && !newly_locked_breaks_order(&locked_vals) {
                // lock in descending discovery order
                locked_vals.push(lam);
                locked_u.push(uc);
                newly_locked = true;
            } else {
                last_ritz.push((lam, uc));
            }
        }

        // Restart direction: the best unconverged Ritz vector (naive
        // restart — no thick subspace retained), plus a small random
        // component so degenerate directions are eventually reachable.
        start = match last_ritz.first() {
            Some((_, u0)) => u0.clone(),
            None => (0..n).map(|_| rng.normal()).collect(),
        };
        let snrm = nrm2(&start).max(1e-300);
        for v in start.iter_mut() {
            *v += 1e-6 * snrm * rng.normal();
        }
        let _ = newly_locked;
    }

    let converged = locked_u.len() >= k;
    // Assemble the answer: locked pairs first, then the best remaining
    // Ritz pairs; sort everything descending by value.
    let mut pairs: Vec<(f64, Vec<f64>)> =
        locked_vals.iter().cloned().zip(locked_u.iter().cloned()).collect();
    for (lam, u) in last_ritz {
        if pairs.len() < k {
            pairs.push((lam, u));
        }
    }
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    pairs.truncate(k);
    let mut best_u = Mat::zeros(n, k);
    let mut best_vals = vec![0.0; k];
    for (j, (lam, u)) in pairs.into_iter().enumerate() {
        best_vals[j] = lam;
        best_u.set_col(j, &u);
    }

    finalize(a, best_u, &best_vals, matvecs, iters, converged)
}

/// Placeholder hook kept for clarity: locking is greedy in discovery
/// order, which for GKL means descending Ritz values; no reorder needed.
#[inline]
fn newly_locked_breaks_order(_locked: &[f64]) -> bool {
    false
}

fn apply_vec<O: SvdOp + ?Sized>(a: &O, x: &[f64]) -> Vec<f64> {
    let b = Mat::from_vec(x.len(), 1, x.to_vec());
    a.apply(&b).col(0)
}

fn apply_t_vec<O: SvdOp + ?Sized>(a: &O, x: &[f64]) -> Vec<f64> {
    let b = Mat::from_vec(x.len(), 1, x.to_vec());
    a.apply_t(&b).col(0)
}

/// One full reorthogonalization pass (classical Gram–Schmidt, twice).
fn reorth(basis: &[Vec<f64>], v: &mut Vec<f64>) {
    for _ in 0..2 {
        for b in basis {
            let c = dot(b, v);
            if c != 0.0 {
                axpy(-c, b, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn matches_dense_svd_topk() {
        let mut rng = Pcg::seed(71);
        let a = randmat(&mut rng, 70, 25);
        let dense = crate::linalg::svd_thin(&a);
        let opts = LanczosOpts { tol: 1e-9, max_matvecs: 20_000, ..LanczosOpts::new(4) };
        let r = lanczos_svd(&a, &opts, 5);
        assert!(r.stats.converged, "stats {:?}", r.stats);
        for j in 0..4 {
            assert!(
                (r.s[j] - dense.s[j]).abs() < 1e-6 * dense.s[0],
                "σ_{j}: {} vs {}",
                r.s[j],
                dense.s[j]
            );
        }
    }

    #[test]
    fn orthonormal_left_vectors() {
        let mut rng = Pcg::seed(72);
        let a = randmat(&mut rng, 60, 20);
        let opts = LanczosOpts { tol: 1e-8, max_matvecs: 20_000, ..LanczosOpts::new(3) };
        let r = lanczos_svd(&a, &opts, 2);
        let g = r.u.t_matmul(&r.u);
        assert!(g.sub(&Mat::eye(3)).frob_norm() < 1e-5, "gram {:?}", g);
    }

    #[test]
    fn diagonal_known_values() {
        let n = 40;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, (n - i) as f64);
        }
        let opts = LanczosOpts { tol: 1e-10, max_matvecs: 20_000, ..LanczosOpts::new(3) };
        let r = lanczos_svd(&a, &opts, 9);
        assert!((r.s[0] - n as f64).abs() < 1e-6);
        assert!((r.s[1] - (n - 1) as f64).abs() < 1e-6);
        assert!((r.s[2] - (n - 2) as f64).abs() < 1e-6);
    }
}
