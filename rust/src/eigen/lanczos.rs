//! "Matlab svds" analogue: restarted Golub–Kahan–Lanczos bidiagonalization
//! with full reorthogonalization and naive (non-thick) restarting.
//!
//! This solver is deliberately the *plain Lanczos class* the paper contrasts
//! PRIMME against (§3.2, §5.3): on well-separated spectra it is fine, but on
//! clustered singular values its simple restart discards subspace
//! information and convergence stalls — reproducing the Fig. 3 gap.
//!
//! The mechanics are nonetheless production-shaped: the Krylov bases live
//! in preallocated column-major [`super::workspace::ColBasis`] storage, the
//! full reorthogonalization runs as a *blocked* two-pass CGS (coefficient
//! gemv + update gemv, both streaming) instead of vector-at-a-time
//! dot/axpy interleave, single-vector operator products go through the
//! allocation-free `apply_vec_into`/`apply_t_vec_into` trait hooks, the
//! per-cycle Ritz residuals use the fused `gram_matmat_into` kernel, and
//! the small bidiagonal SVD reuses a [`crate::linalg::SmallSvdWs`] — so
//! steady-state restart cycles perform zero heap allocations (see
//! `tests/alloc.rs`).

use super::op::SvdOp;
use super::workspace::{
    combine_into, fill_normal, gather_cols_to_mat, reorth_blocked, SolverWorkspace,
};
use super::{davidson::finalize, SvdResult};
use crate::linalg::{axpy, nrm2, svd_thin_into, Mat};

/// Options for the Lanczos-bidiagonalization solver.
#[derive(Clone, Debug)]
pub struct LanczosOpts {
    pub k: usize,
    pub tol: f64,
    pub max_matvecs: usize,
    /// Krylov dimension per restart cycle.
    pub subspace: usize,
}

impl LanczosOpts {
    pub fn new(k: usize) -> Self {
        LanczosOpts { k, tol: 1e-5, max_matvecs: 5000, subspace: (3 * k + 12).max(20) }
    }
}

/// Top-k left singular triplets of `a` via restarted GKL bidiagonalization,
/// using a fresh private workspace. Callers running many solves should use
/// [`lanczos_svd_ws`] with a reused [`SolverWorkspace`].
pub fn lanczos_svd<O: SvdOp + ?Sized>(a: &O, opts: &LanczosOpts, seed: u64) -> SvdResult {
    let mut ws = SolverWorkspace::new();
    lanczos_svd_ws(a, opts, seed, &mut ws)
}

/// [`lanczos_svd`] with an explicit workspace: after the `ensure` pass at
/// entry, restart cycles perform zero heap allocations.
pub fn lanczos_svd_ws<O: SvdOp + ?Sized>(
    a: &O,
    opts: &LanczosOpts,
    seed: u64,
    ws: &mut SolverWorkspace,
) -> SvdResult {
    let n = a.nrows();
    let d = a.ncols();
    let k = opts.k.min(n.min(d));
    let m = opts.subspace.clamp(k + 2, n.min(d).max(k + 2));
    let mut rng = crate::util::rng::Pcg::new(seed, 0x1a2c05);
    ws.ensure_lanczos(n, d, m, k);
    a.prepare_gram(&mut ws.gram, (k + 1).min(n));

    // Starting vector (restart cycles replace this with the best Ritz u₁..u_k
    // combination — naive restart keeps only u₁'s direction).
    fill_normal(&mut ws.start, n, &mut rng);
    let mut matvecs = 0usize;
    let mut iters = 0usize;

    // Converged left singular vectors are *locked* (deflated): subsequent
    // Krylov spaces are kept orthogonal to them, which is how a single-
    // vector Lanczos can reach the remaining directions of a degenerate /
    // tightly clustered singular value (the covtype regime). This mirrors
    // what production svds implementations do; the weakness that remains —
    // and that Fig. 3 exercises — is the naive single-vector restart, which
    // discards the unconverged subspace every cycle.
    while matvecs < opts.max_matvecs && ws.locked.ncols() < k {
        iters += 1;
        // GKL: A Vb = Ub B, Aᵀ Ub = Vb Bᵀ (+ residual), B lower-bidiagonal,
        // run in the complement of the locked subspace.
        ws.us.clear_cols();
        ws.vs.clear_cols();
        ws.alphas.clear();
        ws.betas.clear();

        reorth_blocked(&ws.locked, &mut ws.start, &mut ws.coeff);
        if nrm2(&ws.start) <= 1e-14 {
            fill_normal(&mut ws.start, n, &mut rng);
            reorth_blocked(&ws.locked, &mut ws.start, &mut ws.coeff);
        }
        let nrm = nrm2(&ws.start).max(1e-300);
        {
            let u0 = ws.us.push_zero_col();
            for (ui, si) in u0.iter_mut().zip(ws.start.iter()) {
                *ui = si / nrm;
            }
        }

        for j in 0..m {
            // v_j = Aᵀ u_j − β_{j−1} v_{j−1}, blocked-reorthogonalized
            resize_zeroed(&mut ws.vtmp, d);
            a.apply_t_vec_into(ws.us.col(j), &mut ws.vtmp);
            matvecs += 1;
            if j > 0 {
                let beta_prev = ws.betas[j - 1];
                axpy(-beta_prev, ws.vs.col(j - 1), &mut ws.vtmp);
            }
            reorth_blocked(&ws.vs, &mut ws.vtmp, &mut ws.coeff);
            let alpha = nrm2(&ws.vtmp);
            ws.alphas.push(alpha);
            if alpha <= 1e-14 {
                ws.vs.push_zero_col();
                ws.betas.push(0.0);
                break;
            }
            for x in ws.vtmp.iter_mut() {
                *x /= alpha;
            }
            ws.vs.push_col(&ws.vtmp);

            // u_{j+1} = A v_j − α_j u_j, reorthogonalized (incl. locked)
            resize_zeroed(&mut ws.utmp, n);
            a.apply_vec_into(ws.vs.col(j), &mut ws.utmp);
            matvecs += 1;
            axpy(-alpha, ws.us.col(j), &mut ws.utmp);
            reorth_blocked(&ws.locked, &mut ws.utmp, &mut ws.coeff);
            reorth_blocked(&ws.us, &mut ws.utmp, &mut ws.coeff);
            let beta = nrm2(&ws.utmp);
            ws.betas.push(beta);
            if beta <= 1e-14 || j + 1 == m {
                break;
            }
            for x in ws.utmp.iter_mut() {
                *x /= beta;
            }
            ws.us.push_col(&ws.utmp);
        }

        // SVD of the small bidiagonal projection: B is p×q with diag
        // alphas and subdiag betas (p = q by construction of the loop).
        let p = ws.us.ncols();
        let q = ws.vs.ncols();
        ws.bmat.reset(p, q);
        for j in 0..q.min(ws.alphas.len()).min(p) {
            ws.bmat.set(j, j, ws.alphas[j]);
        }
        for j in 0..q.min(ws.betas.len()) {
            if j + 1 < p {
                ws.bmat.set(j + 1, j, ws.betas[j]);
            }
        }
        svd_thin_into(&ws.bmat, &mut ws.svd);

        // Ritz left vectors for the unconverged slots.
        let want = k - ws.locked.ncols();
        let take = (want + 1).min(ws.svd.s.len()).min(p);
        combine_into(&ws.us, &ws.svd.u, take, &mut ws.uritz);

        // Residuals of the Gram problem ‖S u − λ u‖ per Ritz pair, via one
        // fused S·U block product (bridged through the row-major block).
        gather_cols_to_mat(&ws.uritz, 0, &mut ws.blk);
        a.gram_matmat_into(&ws.blk, &mut ws.s_blk, &mut ws.gram);
        matvecs += 2 * take;
        let scale = ws
            .locked_vals
            .first()
            .copied()
            .unwrap_or_else(|| ws.svd.s.first().map(|s| s * s).unwrap_or(1.0))
            .max(1e-300);
        ws.last.clear_cols();
        ws.last_vals.clear();
        for j in 0..take {
            let lam = ws.svd.s[j] * ws.svd.s[j];
            let uc = ws.uritz.col(j);
            let mut rsq = 0.0;
            for (i, &ui) in uc.iter().enumerate() {
                let rv = ws.s_blk.at(i, j) - lam * ui;
                rsq += rv * rv;
            }
            let res = rsq.sqrt() / scale;
            if res <= opts.tol && ws.locked.ncols() < k {
                // lock in descending discovery order
                ws.locked_vals.push(lam);
                let (locked, uritz) = (&mut ws.locked, &ws.uritz);
                locked.push_col(uritz.col(j));
            } else if ws.last.ncols() < k {
                ws.last_vals.push(lam);
                let (last, uritz) = (&mut ws.last, &ws.uritz);
                last.push_col(uritz.col(j));
            }
        }

        // Restart direction: the best unconverged Ritz vector (naive
        // restart — no thick subspace retained), plus a small random
        // component so degenerate directions are eventually reachable.
        ws.start.clear();
        if ws.last.ncols() > 0 {
            let (start, last) = (&mut ws.start, &ws.last);
            start.extend_from_slice(last.col(0));
        } else {
            fill_normal(&mut ws.start, n, &mut rng);
        }
        let snrm = nrm2(&ws.start).max(1e-300);
        for v in ws.start.iter_mut() {
            *v += 1e-6 * snrm * rng.normal();
        }
    }

    let converged = ws.locked.ncols() >= k;
    // Assemble the answer: locked pairs first, then the best remaining
    // Ritz pairs; sort everything descending by value. (Epilogue — the
    // only allocations of the call besides the returned triplets.)
    let mut order: Vec<(f64, bool, usize)> = Vec::with_capacity(k);
    for j in 0..ws.locked.ncols() {
        order.push((ws.locked_vals[j], true, j));
    }
    for j in 0..ws.last.ncols() {
        if order.len() < k {
            order.push((ws.last_vals[j], false, j));
        }
    }
    order.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    order.truncate(k);
    let mut best_u = Mat::zeros(n, k);
    let mut best_vals = vec![0.0; k];
    for (j, &(lam, from_locked, src)) in order.iter().enumerate() {
        best_vals[j] = lam;
        let col = if from_locked { ws.locked.col(src) } else { ws.last.col(src) };
        for (i, &v) in col.iter().enumerate() {
            best_u.set(i, j, v);
        }
    }

    finalize(a, best_u, &best_vals, matvecs, iters, converged)
}

fn resize_zeroed(v: &mut Vec<f64>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn matches_dense_svd_topk() {
        let mut rng = Pcg::seed(71);
        let a = randmat(&mut rng, 70, 25);
        let dense = crate::linalg::svd_thin(&a);
        let opts = LanczosOpts { tol: 1e-9, max_matvecs: 20_000, ..LanczosOpts::new(4) };
        let r = lanczos_svd(&a, &opts, 5);
        assert!(r.stats.converged, "stats {:?}", r.stats);
        for j in 0..4 {
            assert!(
                (r.s[j] - dense.s[j]).abs() < 1e-6 * dense.s[0],
                "σ_{j}: {} vs {}",
                r.s[j],
                dense.s[j]
            );
        }
    }

    #[test]
    fn orthonormal_left_vectors() {
        let mut rng = Pcg::seed(72);
        let a = randmat(&mut rng, 60, 20);
        let opts = LanczosOpts { tol: 1e-8, max_matvecs: 20_000, ..LanczosOpts::new(3) };
        let r = lanczos_svd(&a, &opts, 2);
        let g = r.u.t_matmul(&r.u);
        assert!(g.sub(&Mat::eye(3)).frob_norm() < 1e-5, "gram {:?}", g);
    }

    #[test]
    fn diagonal_known_values() {
        let n = 40;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, (n - i) as f64);
        }
        let opts = LanczosOpts { tol: 1e-10, max_matvecs: 20_000, ..LanczosOpts::new(3) };
        let r = lanczos_svd(&a, &opts, 9);
        assert!((r.s[0] - n as f64).abs() < 1e-6);
        assert!((r.s[1] - (n - 1) as f64).abs() < 1e-6);
        assert!((r.s[2] - (n - 2) as f64).abs() < 1e-6);
    }

    #[test]
    fn workspace_reuse_matches_fresh() {
        let mut rng = Pcg::seed(73);
        let a = randmat(&mut rng, 55, 18);
        let b = randmat(&mut rng, 33, 9);
        let opts_a = LanczosOpts { tol: 1e-9, max_matvecs: 20_000, ..LanczosOpts::new(3) };
        let opts_b = LanczosOpts { tol: 1e-9, max_matvecs: 20_000, ..LanczosOpts::new(2) };
        let mut ws = SolverWorkspace::new();
        let _warm = lanczos_svd_ws(&b, &opts_b, 3, &mut ws);
        let reused = lanczos_svd_ws(&a, &opts_a, 5, &mut ws);
        let fresh = lanczos_svd(&a, &opts_a, 5);
        for j in 0..3 {
            assert!(
                (reused.s[j] - fresh.s[j]).abs() < 1e-9 * (1.0 + fresh.s[j]),
                "σ_{j}: {} vs {}",
                reused.s[j],
                fresh.s[j]
            );
        }
    }
}
