//! PRIMME-analogue: block Generalized Davidson (GD+k flavour) with thick
//! restart and diagonal preconditioning, run on the symmetric PSD operator
//! S = A·Aᵀ (largest eigenpairs of S = largest left singular triplets of A).
//!
//! This is the solver class the paper leans on (§3.2): Generalized-Davidson
//! methods with "advanced subspace restarting and preconditioning" converge
//! near-optimally for a few extreme eigenpairs under limited memory, where
//! plain (restarted) Lanczos struggles on clustered spectra — exactly the
//! covtype-mult regime of Fig. 3.

use super::op::SvdOp;
use super::{SvdResult, SvdStats};
use crate::linalg::{nrm2, orthonormalize_against, sym_eig, Mat};

/// Options for the Davidson solver.
#[derive(Clone, Debug)]
pub struct DavidsonOpts {
    pub k: usize,
    /// Residual tolerance relative to the largest singular value estimate.
    pub tol: f64,
    /// Cap on block-matvec count (each column of a block apply counts 1).
    pub max_matvecs: usize,
    /// Max basis size before a thick restart.
    pub max_basis: usize,
    /// Number of previous Ritz vectors retained at restart (the "+k" of
    /// GD+k; gives CG-like recurrence acceleration).
    pub retained: usize,
    /// Use the diagonal (Jacobi) preconditioner when the operator exposes
    /// its Gram diagonal.
    pub precondition: bool,
}

impl DavidsonOpts {
    pub fn new(k: usize) -> Self {
        DavidsonOpts {
            k,
            tol: 1e-5,
            max_matvecs: 5000,
            max_basis: (4 * k + 16).max(24),
            retained: k.min(3).max(1),
            precondition: true,
        }
    }
}

/// Compute the top-k left singular triplets of `a` (descending).
pub fn davidson_svd<O: SvdOp + ?Sized>(a: &O, opts: &DavidsonOpts, seed: u64) -> SvdResult {
    let n = a.nrows();
    let k = opts.k.min(n);
    assert!(k >= 1, "k must be >= 1");
    let max_basis = opts.max_basis.clamp(2 * k + 2, n.max(2 * k + 2));
    let mut rng = crate::util::rng::Pcg::new(seed, 0x0da71d);

    // Random orthonormal initial block.
    let mut init = Mat::zeros(n, k);
    for v in init.data.iter_mut() {
        *v = rng.normal();
    }
    let mut basis = orthonormalize_against(&init, None); // V: n×m
    // SV cache: S·V columns, kept in lockstep with `basis`.
    let mut s_basis = apply_gram(a, &basis);
    let mut matvecs = 2 * basis.cols;

    let diag = if opts.precondition { a.gram_diag() } else { None };

    let mut prev_ritz: Option<Mat> = None;
    let mut iters = 0usize;
    let mut converged = false;
    let (mut ritz_vals, mut ritz_vecs);

    loop {
        iters += 1;
        // Rayleigh–Ritz on span(V): H = Vᵀ S V (m×m).
        let h = basis.t_matmul(&s_basis);
        let h = symmetrize(h);
        let eig = sym_eig(&h);
        let m = basis.cols;
        // top-k Ritz pairs (descending eigenvalues of S).
        let take = k.min(m);
        let mut q = Mat::zeros(m, take);
        let mut vals = Vec::with_capacity(take);
        for j in 0..take {
            let src = m - 1 - j;
            vals.push(eig.w[src].max(0.0));
            let col = eig.v.col(src);
            q.set_col(j, &col);
        }
        let x = basis.matmul(&q); // n×k Ritz vectors
        let sx = s_basis.matmul(&q); // S·X without new matvecs

        // Residuals r_j = S x_j − λ_j x_j.
        let mut resid = Mat::zeros(n, take);
        let mut worst = 0.0f64;
        let scale = vals.first().copied().unwrap_or(1.0).max(1e-300);
        for j in 0..take {
            let mut rcol = sx.col(j);
            let xcol = x.col(j);
            for (rv, xv) in rcol.iter_mut().zip(xcol.iter()) {
                *rv -= vals[j] * *xv;
            }
            let rn = nrm2(&rcol) / scale;
            worst = worst.max(rn);
            resid.set_col(j, &rcol);
        }

        ritz_vals = vals.clone();
        ritz_vecs = x.clone();

        if worst <= opts.tol {
            converged = true;
            break;
        }
        if matvecs >= opts.max_matvecs {
            break;
        }

        // Davidson correction: precondition residuals with (diag(S) − λ)⁻¹.
        let mut corr = resid;
        if let Some(d) = &diag {
            for j in 0..corr.cols {
                let lam = ritz_vals[j];
                let floor = 1e-3 * scale;
                for i in 0..n {
                    let mut denom = d[i] - lam;
                    if denom.abs() < floor {
                        denom = if denom < 0.0 { -floor } else { floor };
                    }
                    corr.set(i, j, corr.at(i, j) / denom);
                }
            }
        }

        // Thick restart when the basis would overflow.
        if basis.cols + corr.cols > max_basis {
            // Restart basis: [Ritz X | retained previous Ritz] (GD+k).
            let mut restart = x.clone();
            if let Some(prev) = &prev_ritz {
                let extra = orthonormalize_against(prev, Some(&restart));
                let keep = extra.first_cols(extra.cols.min(opts.retained));
                restart = hcat(&restart, &keep);
            }
            basis = orthonormalize_against(&restart, None);
            s_basis = apply_gram(a, &basis);
            matvecs += 2 * basis.cols;
        }

        // Expand basis with the (orthonormalized) corrections.
        let add = orthonormalize_against(&corr, Some(&basis));
        if add.cols == 0 {
            // Corrections fully dependent — random refresh to escape.
            let mut fresh = Mat::zeros(n, 1);
            for v in fresh.data.iter_mut() {
                *v = rng.normal();
            }
            let add2 = orthonormalize_against(&fresh, Some(&basis));
            if add2.cols == 0 {
                break;
            }
            let s_add = apply_gram(a, &add2);
            matvecs += 2 * add2.cols;
            basis = hcat(&basis, &add2);
            s_basis = hcat(&s_basis, &s_add);
        } else {
            let s_add = apply_gram(a, &add);
            matvecs += 2 * add.cols;
            basis = hcat(&basis, &add);
            s_basis = hcat(&s_basis, &s_add);
        }
        prev_ritz = Some(x);
    }

    finalize(a, ritz_vecs, &ritz_vals, matvecs, iters, converged)
}

/// S·B = A·(Aᵀ·B).
fn apply_gram<O: SvdOp + ?Sized>(a: &O, b: &Mat) -> Mat {
    a.apply(&a.apply_t(b))
}

fn symmetrize(mut h: Mat) -> Mat {
    let n = h.rows;
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (h.at(i, j) + h.at(j, i));
            h.set(i, j, avg);
            h.set(j, i, avg);
        }
    }
    h
}

fn hcat(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut out = Mat::zeros(a.rows, a.cols + b.cols);
    for i in 0..a.rows {
        out.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
        out.row_mut(i)[a.cols..].copy_from_slice(b.row(i));
    }
    out
}

/// Shared epilogue: eigenvalues of S → singular values of A, right vectors
/// recovered as v = Aᵀu/σ.
pub(super) fn finalize<O: SvdOp + ?Sized>(
    a: &O,
    u: Mat,
    gram_vals: &[f64],
    matvecs: usize,
    iters: usize,
    converged: bool,
) -> SvdResult {
    let s: Vec<f64> = gram_vals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    let vt_unscaled = a.apply_t(&u); // D×k = Aᵀ U
    let mut v = vt_unscaled;
    for j in 0..s.len() {
        let sj = s[j];
        if sj > 1e-300 {
            for i in 0..v.rows {
                v.set(i, j, v.at(i, j) / sj);
            }
        }
    }
    SvdResult {
        u,
        s,
        v,
        stats: SvdStats { matvecs: matvecs + 1, iterations: iters, converged },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn matches_dense_svd_topk() {
        let mut rng = Pcg::seed(61);
        let a = randmat(&mut rng, 80, 30);
        let dense = crate::linalg::svd_thin(&a);
        let opts = DavidsonOpts { tol: 1e-9, max_matvecs: 20_000, ..DavidsonOpts::new(5) };
        let r = davidson_svd(&a, &opts, 7);
        assert!(r.stats.converged, "did not converge: {:?}", r.stats);
        for j in 0..5 {
            assert!(
                (r.s[j] - dense.s[j]).abs() < 1e-6 * dense.s[0],
                "σ_{j}: {} vs {}",
                r.s[j],
                dense.s[j]
            );
        }
        // subspace alignment: |u_dense · u_iter| ≈ 1 for separated σ
        for j in 0..3 {
            let d = crate::linalg::dot(&dense.u.col(j), &r.u.col(j)).abs();
            assert!(d > 0.999, "u_{j} alignment {d}");
        }
    }

    #[test]
    fn clustered_spectrum_converges() {
        // Diagonal operator with a tight cluster at the top — the Fig. 3
        // regime where restarted Lanczos struggles.
        let n = 300;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            let v = if i < 6 { 10.0 - 1e-5 * i as f64 } else { 5.0 * (1.0 - i as f64 / n as f64) };
            a.set(i, i, v);
        }
        let opts = DavidsonOpts { tol: 1e-8, max_matvecs: 60_000, ..DavidsonOpts::new(4) };
        let r = davidson_svd(&a, &opts, 3);
        assert!(r.stats.converged);
        for j in 0..4 {
            assert!((r.s[j] - (10.0 - 1e-5 * j as f64)).abs() < 1e-5, "σ_{j} = {}", r.s[j]);
        }
    }

    #[test]
    fn orthonormal_left_vectors() {
        let mut rng = Pcg::seed(62);
        let a = randmat(&mut rng, 60, 20);
        let r = davidson_svd(&a, &DavidsonOpts::new(4), 1);
        let g = r.u.t_matmul(&r.u);
        assert!(g.sub(&Mat::eye(4)).frob_norm() < 1e-6);
    }

    #[test]
    fn right_vectors_consistent() {
        let mut rng = Pcg::seed(63);
        let a = randmat(&mut rng, 50, 15);
        let opts = DavidsonOpts { tol: 1e-10, max_matvecs: 20_000, ..DavidsonOpts::new(3) };
        let r = davidson_svd(&a, &opts, 2);
        // A·v_j ≈ σ_j u_j
        let av = a.matmul(&r.v);
        for j in 0..3 {
            for i in 0..50 {
                assert!((av.at(i, j) - r.s[j] * r.u.at(i, j)).abs() < 1e-6);
            }
        }
    }
}
