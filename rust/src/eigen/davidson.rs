//! PRIMME-analogue: block Generalized Davidson (GD+k flavour) with thick
//! restart and diagonal preconditioning, run on the symmetric PSD operator
//! S = A·Aᵀ (largest eigenpairs of S = largest left singular triplets of A).
//!
//! This is the solver class the paper leans on (§3.2): Generalized-Davidson
//! methods with "advanced subspace restarting and preconditioning" converge
//! near-optimally for a few extreme eigenpairs under limited memory, where
//! plain (restarted) Lanczos struggles on clustered spectra — exactly the
//! covtype-mult regime of Fig. 3.
//!
//! Every S·B product goes through [`SvdOp::gram_matmat_into`] — the fused
//! strip-tiled kernel on `EllRb`, which never materializes the D×k
//! intermediate — and every per-iteration buffer (basis, S·V cache, Ritz
//! block, residuals, projected problem) lives in a caller-reusable
//! [`SolverWorkspace`], so steady-state iterations are allocation-free
//! (see `tests/alloc.rs`).

use super::op::SvdOp;
use super::workspace::{
    append_orthonormalized, combine_into, fill_normal, gather_cols_to_mat, gram_pairs_into,
    symmetrize_in_place, SolverWorkspace,
};
use super::{SvdResult, SvdStats};
use crate::linalg::{nrm2, sym_eig_into, Mat};

/// Options for the Davidson solver.
#[derive(Clone, Debug)]
pub struct DavidsonOpts {
    pub k: usize,
    /// Residual tolerance relative to the largest singular value estimate.
    pub tol: f64,
    /// Cap on block-matvec count (each column of a block apply counts 1).
    pub max_matvecs: usize,
    /// Max basis size before a thick restart.
    pub max_basis: usize,
    /// Number of previous Ritz vectors retained at restart (the "+k" of
    /// GD+k; gives CG-like recurrence acceleration).
    pub retained: usize,
    /// Use the diagonal (Jacobi) preconditioner when the operator exposes
    /// its Gram diagonal.
    pub precondition: bool,
}

impl DavidsonOpts {
    pub fn new(k: usize) -> Self {
        DavidsonOpts {
            k,
            tol: 1e-5,
            max_matvecs: 5000,
            max_basis: (4 * k + 16).max(24),
            retained: k.min(3).max(1),
            precondition: true,
        }
    }
}

/// Compute the top-k left singular triplets of `a` (descending), using a
/// fresh private workspace. Callers running many solves should use
/// [`davidson_svd_ws`] with a reused [`SolverWorkspace`].
pub fn davidson_svd<O: SvdOp + ?Sized>(a: &O, opts: &DavidsonOpts, seed: u64) -> SvdResult {
    let mut ws = SolverWorkspace::new();
    davidson_svd_ws(a, opts, seed, &mut ws)
}

/// [`davidson_svd`] with an explicit workspace: after the `ensure` pass at
/// entry (which allocates only what the workspace has not seen before),
/// iterations perform zero heap allocations.
pub fn davidson_svd_ws<O: SvdOp + ?Sized>(
    a: &O,
    opts: &DavidsonOpts,
    seed: u64,
    ws: &mut SolverWorkspace,
) -> SvdResult {
    let n = a.nrows();
    let k = opts.k.min(n);
    assert!(k >= 1, "k must be >= 1");
    let max_basis = opts.max_basis.clamp(2 * k + 2, n.max(2 * k + 2));
    let mut rng = crate::util::rng::Pcg::new(seed, 0x0da71d);
    ws.ensure_davidson(n, k, max_basis);
    a.prepare_gram(&mut ws.gram, max_basis.min(n));

    // Random orthonormal initial block of k columns.
    ws.basis.clear_cols();
    ws.s_basis.clear_cols();
    ws.prev.clear_cols();
    for _ in 0..k {
        fill_normal(&mut ws.tmp_col, n, &mut rng);
        append_orthonormalized(&mut ws.basis, &mut ws.tmp_col, &mut ws.coeff);
    }
    let mut matvecs = refresh_s_block(a, ws);

    let diag = if opts.precondition { a.gram_diag() } else { None };

    let mut iters = 0usize;
    let mut converged = false;

    loop {
        iters += 1;
        let m = ws.basis.ncols();
        // Rayleigh–Ritz on span(V): H = Vᵀ S V (m×m) via the S·V cache.
        ws.h.reset(m, m);
        gram_pairs_into(&ws.basis, &ws.s_basis, &mut ws.h.data, m);
        symmetrize_in_place(&mut ws.h.data, m);
        sym_eig_into(&ws.h, &mut ws.eig);
        // top-k Ritz pairs (descending eigenvalues of S).
        let take = k.min(m);
        ws.q.reset(m, take);
        ws.vals.clear();
        for j in 0..take {
            let src = m - 1 - j;
            ws.vals.push(ws.eig.w[src].max(0.0));
            for i in 0..m {
                ws.q.set(i, j, ws.eig.vecs.at(i, src));
            }
        }
        combine_into(&ws.basis, &ws.q, take, &mut ws.x); // n×take Ritz vectors
        combine_into(&ws.s_basis, &ws.q, take, &mut ws.sx); // S·X, no new matvecs

        // Residuals r_j = S x_j − λ_j x_j.
        let scale = ws.vals.first().copied().unwrap_or(1.0).max(1e-300);
        let mut worst = 0.0f64;
        {
            let (resid, x, sx, vals) = (&mut ws.resid, &ws.x, &ws.sx, &ws.vals);
            resid.clear_cols();
            for j in 0..take {
                let rc = resid.push_zero_col();
                let (xc, sc) = (x.col(j), sx.col(j));
                let lam = vals[j];
                for i in 0..n {
                    rc[i] = sc[i] - lam * xc[i];
                }
                worst = worst.max(nrm2(rc) / scale);
            }
        }

        if worst <= opts.tol {
            converged = true;
            break;
        }
        if matvecs >= opts.max_matvecs {
            break;
        }

        // Davidson correction: precondition residuals with (diag(S) − λ)⁻¹,
        // in place (resid becomes the correction block).
        if let Some(d) = &diag {
            let floor = 1e-3 * scale;
            for j in 0..take {
                let lam = ws.vals[j];
                let rc = ws.resid.col_mut(j);
                for (rv, di) in rc.iter_mut().zip(d.iter()) {
                    let mut denom = di - lam;
                    if denom.abs() < floor {
                        denom = if denom < 0.0 { -floor } else { floor };
                    }
                    *rv /= denom;
                }
            }
        }

        // Thick restart when the basis would overflow: rebuild from
        // [Ritz X | retained previous Ritz] (GD+k).
        if m + take > max_basis {
            ws.basis.clear_cols();
            ws.s_basis.clear_cols();
            for j in 0..take {
                copy_col(&ws.x, j, &mut ws.tmp_col);
                append_orthonormalized(&mut ws.basis, &mut ws.tmp_col, &mut ws.coeff);
            }
            let mut kept_prev = 0usize;
            for j in 0..ws.prev.ncols() {
                if kept_prev >= opts.retained {
                    break;
                }
                copy_col(&ws.prev, j, &mut ws.tmp_col);
                if append_orthonormalized(&mut ws.basis, &mut ws.tmp_col, &mut ws.coeff) {
                    kept_prev += 1;
                }
            }
        }

        // Expand basis with the (orthonormalized) corrections.
        let m0 = ws.basis.ncols();
        for j in 0..take {
            copy_col(&ws.resid, j, &mut ws.tmp_col);
            append_orthonormalized(&mut ws.basis, &mut ws.tmp_col, &mut ws.coeff);
        }
        if ws.basis.ncols() == m0 {
            // Corrections fully dependent — random refresh to escape.
            fill_normal(&mut ws.tmp_col, n, &mut rng);
            append_orthonormalized(&mut ws.basis, &mut ws.tmp_col, &mut ws.coeff);
            if ws.basis.ncols() == m0 {
                break;
            }
        }
        matvecs += refresh_s_block(a, ws);
        ws.prev.copy_from(&ws.x);
    }

    // Materialize the answer (the only allocations of the epilogue).
    let take_final = ws.x.ncols();
    let mut u = Mat::zeros(n, take_final);
    for j in 0..take_final {
        ws.x.store_col_to_mat(j, &mut u, j);
    }
    finalize(a, u, &ws.vals, matvecs, iters, converged)
}

/// Copy column `j` of `src` into the scratch vector.
fn copy_col(src: &super::workspace::ColBasis, j: usize, out: &mut Vec<f64>) {
    out.clear();
    out.extend_from_slice(src.col(j));
}

/// Extend the S·V cache to cover every basis column it is missing:
/// gather the new columns into the row-major bridge block, run one fused
/// gram product, and append the results. Returns the matvecs spent.
fn refresh_s_block<O: SvdOp + ?Sized>(a: &O, ws: &mut SolverWorkspace) -> usize {
    let from = ws.s_basis.ncols();
    let m = ws.basis.ncols();
    let add = m - from;
    if add == 0 {
        return 0;
    }
    gather_cols_to_mat(&ws.basis, from, &mut ws.blk);
    a.gram_matmat_into(&ws.blk, &mut ws.s_blk, &mut ws.gram);
    for t in 0..add {
        ws.s_basis.push_col_from_mat(&ws.s_blk, t);
    }
    2 * add
}

/// Shared epilogue: eigenvalues of S → singular values of A, right vectors
/// recovered as v = Aᵀu/σ.
pub(super) fn finalize<O: SvdOp + ?Sized>(
    a: &O,
    u: Mat,
    gram_vals: &[f64],
    matvecs: usize,
    iters: usize,
    converged: bool,
) -> SvdResult {
    let s: Vec<f64> = gram_vals.iter().map(|&l| l.max(0.0).sqrt()).collect();
    let vt_unscaled = a.apply_t(&u); // D×k = Aᵀ U
    let mut v = vt_unscaled;
    for j in 0..s.len() {
        let sj = s[j];
        if sj > 1e-300 {
            for i in 0..v.rows {
                v.set(i, j, v.at(i, j) / sj);
            }
        }
    }
    SvdResult {
        u,
        s,
        v,
        stats: SvdStats { matvecs: matvecs + 1, iterations: iters, converged },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn matches_dense_svd_topk() {
        let mut rng = Pcg::seed(61);
        let a = randmat(&mut rng, 80, 30);
        let dense = crate::linalg::svd_thin(&a);
        let opts = DavidsonOpts { tol: 1e-9, max_matvecs: 20_000, ..DavidsonOpts::new(5) };
        let r = davidson_svd(&a, &opts, 7);
        assert!(r.stats.converged, "did not converge: {:?}", r.stats);
        for j in 0..5 {
            assert!(
                (r.s[j] - dense.s[j]).abs() < 1e-6 * dense.s[0],
                "σ_{j}: {} vs {}",
                r.s[j],
                dense.s[j]
            );
        }
        // subspace alignment: |u_dense · u_iter| ≈ 1 for separated σ
        for j in 0..3 {
            let d = crate::linalg::dot(&dense.u.col(j), &r.u.col(j)).abs();
            assert!(d > 0.999, "u_{j} alignment {d}");
        }
    }

    #[test]
    fn clustered_spectrum_converges() {
        // Diagonal operator with a tight cluster at the top — the Fig. 3
        // regime where restarted Lanczos struggles.
        let n = 300;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            let v = if i < 6 { 10.0 - 1e-5 * i as f64 } else { 5.0 * (1.0 - i as f64 / n as f64) };
            a.set(i, i, v);
        }
        let opts = DavidsonOpts { tol: 1e-8, max_matvecs: 60_000, ..DavidsonOpts::new(4) };
        let r = davidson_svd(&a, &opts, 3);
        assert!(r.stats.converged);
        for j in 0..4 {
            assert!((r.s[j] - (10.0 - 1e-5 * j as f64)).abs() < 1e-5, "σ_{j} = {}", r.s[j]);
        }
    }

    #[test]
    fn orthonormal_left_vectors() {
        let mut rng = Pcg::seed(62);
        let a = randmat(&mut rng, 60, 20);
        let r = davidson_svd(&a, &DavidsonOpts::new(4), 1);
        let g = r.u.t_matmul(&r.u);
        assert!(g.sub(&Mat::eye(4)).frob_norm() < 1e-6);
    }

    #[test]
    fn right_vectors_consistent() {
        let mut rng = Pcg::seed(63);
        let a = randmat(&mut rng, 50, 15);
        let opts = DavidsonOpts { tol: 1e-10, max_matvecs: 20_000, ..DavidsonOpts::new(3) };
        let r = davidson_svd(&a, &opts, 2);
        // A·v_j ≈ σ_j u_j
        let av = a.matmul(&r.v);
        for j in 0..3 {
            for i in 0..50 {
                assert!((av.at(i, j) - r.s[j] * r.u.at(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn workspace_reuse_matches_fresh() {
        // A workspace carried across problems of different shapes must not
        // leak state into later solves.
        let mut rng = Pcg::seed(64);
        let a = randmat(&mut rng, 70, 22);
        let b = randmat(&mut rng, 45, 10);
        let opts_a = DavidsonOpts { tol: 1e-9, max_matvecs: 20_000, ..DavidsonOpts::new(4) };
        let opts_b = DavidsonOpts { tol: 1e-9, max_matvecs: 20_000, ..DavidsonOpts::new(3) };
        let mut ws = SolverWorkspace::new();
        let _warm = davidson_svd_ws(&b, &opts_b, 11, &mut ws);
        let reused = davidson_svd_ws(&a, &opts_a, 7, &mut ws);
        let fresh = davidson_svd(&a, &opts_a, 7);
        for j in 0..4 {
            assert!(
                (reused.s[j] - fresh.s[j]).abs() < 1e-9 * (1.0 + fresh.s[j]),
                "σ_{j}: {} vs {}",
                reused.s[j],
                fresh.s[j]
            );
        }
    }
}
