//! Shared stage implementations: the normalize stages, the pass-through
//! pieces, the generic degree-normalized SVD embed, and the K-means
//! cluster stage.
//!
//! Method-specific featurize stages live next to their methods in
//! `crate::cluster` (`RbFeaturize` in `sc_rb`, `RfFeaturize` in `sc_rf`,
//! `NysFeaturize` in `sc_nys`, `LscFeaturize` in `sc_lsc`,
//! `ExactFeaturize` in `sc_exact`); the composition table is
//! [`crate::cluster::MethodKind::pipeline`].

use super::artifact::{ClusterArtifact, EmbedArtifact, FeatureArtifact, FeatureMatrix, NormArtifact};
use super::fingerprint::Fingerprint;
use super::{Cluster, DataSource, Embed, Featurize, Normalize};
use crate::cluster::sc_exact::SymOp;
use crate::cluster::Env;
use crate::config::{Engine, Solver};
use crate::eigen::{svds, SvdResult, SvdsOpts};
use crate::error::ScrbError;
use crate::kmeans::{kmeans, AssignEngine, KmeansOpts, NativeAssign};
use crate::linalg::Mat;
use crate::util::timer::StageTimer;
use std::sync::Arc;

// ------------------------------------------------------------- normalize

/// Min-max normalization into `[0, 1]` per feature, keeping the
/// `(min, span)` frame — the preprocessing `scrb fit --data` applies, and
/// the frame a serving model stores so out-of-sample batches are brought
/// into the *fitted* coordinates rather than their own statistics. (A
/// pipeline with `normalize: None` runs in the caller's frame — there is
/// no separate identity stage.) The frame rule is the one definition in
/// [`crate::data::dataset::minmax_params`].
pub struct MinMaxNormalize;

impl Normalize for MinMaxNormalize {
    fn fingerprint(&self, data_fp: u64) -> u64 {
        Fingerprint::new("normalize/minmax").u64(data_fp).finish()
    }

    fn run(&self, x: &Mat, fp: u64) -> Result<NormArtifact, ScrbError> {
        let mut timer = StageTimer::new();
        let (xn, lo, span) = timer.time("normalize", || {
            let (lo, span) = crate::data::dataset::minmax_params(x);
            let mut xn = x.clone();
            for i in 0..xn.rows {
                let row = xn.row_mut(i);
                for j in 0..row.len() {
                    row[j] = (row[j] - lo[j]) / span[j];
                }
            }
            (xn, lo, span)
        });
        Ok(NormArtifact { fingerprint: fp, x: xn, frame: Some((lo, span)), timer })
    }
}

// ------------------------------------------------------------- featurize

/// Identity featurization: the input matrix *is* the feature matrix
/// (plain K-means clusters raw coordinates).
pub struct IdentityFeaturize;

impl Featurize for IdentityFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/identity").u64(input_fp).finish()
    }

    fn run(&self, _env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        let x = data.matrix("K-means")?;
        Ok(FeatureArtifact {
            fingerprint: fp,
            feature_dim: x.cols,
            z: FeatureMatrix::Dense(Arc::new(x.clone())),
            codebook: None,
            kappa: None,
            norm: None,
            stream_labels: None,
            stream_quarantine: None,
            timer: StageTimer::new(),
        })
    }

    /// The artifact is a plain copy of the input with no reuse value —
    /// retaining it in a sweep cache would pin an extra N×d copy.
    fn cacheable(&self) -> bool {
        false
    }
}

// ----------------------------------------------------------------- embed

/// Pass-through embed: the dense feature rows are clustered as-is (plain
/// K-means on the input, KK_RF on the RF features, KK_RS on the whitened
/// Nyström features).
pub struct PassEmbed;

impl Embed for PassEmbed {
    fn fingerprint(&self, upstream: u64) -> u64 {
        Fingerprint::new("embed/pass").u64(upstream).finish()
    }

    fn run(&self, _env: &Env, feat: &FeatureArtifact, fp: u64) -> Result<EmbedArtifact, ScrbError> {
        match &feat.z {
            // shares the upstream dense features (Arc clone — no copy)
            FeatureMatrix::Dense(m) => Ok(EmbedArtifact {
                fingerprint: fp,
                s: Vec::new(),
                u: m.clone(),
                proj: None,
                stats: None,
                timer: StageTimer::new(),
            }),
            _ => Err(ScrbError::unsupported(
                "pass-through embedding needs dense features (sparse substrates embed spectrally)",
            )),
        }
    }

    /// Re-running a pass-through is an `Arc` clone — retaining its
    /// artifact buys nothing, and when the upstream featurization opted
    /// out of caching it would pin the shared matrix in the cache.
    fn cacheable(&self) -> bool {
        false
    }
}

/// How (and whether) an [`SvdEmbed`] degree-normalizes its features
/// before the SVD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegreeMode {
    /// No degree normalization (SV_RF approximates W, not L; LSC bakes
    /// its Λ^{−1/2} into the featurize stage).
    None,
    /// Dense Ẑ = D^{−1/2}Z with degrees d = Z(Zᵀ1) clamped away from
    /// zero (RF features are signed, so approximate degrees can dip
    /// slightly negative at small R). SC_RF and SC_Nys.
    DenseClamped,
}

impl DegreeMode {
    fn tag(&self) -> &'static str {
        match self {
            DegreeMode::None => "none",
            DegreeMode::DenseClamped => "dense-clamped",
        }
    }
}

/// Degree-normalize a dense feature matrix in place: Ẑ = D^{−1/2}Z with
/// d = Z(Zᵀ1) clamped away from zero.
pub fn normalize_dense_by_degree(z: &mut Mat) {
    let ones = vec![1.0; z.rows];
    let col_sums = z.t_matvec(&ones);
    let deg = z.matvec(&col_sums);
    let floor = 1e-8 * deg.iter().map(|d| d.abs()).fold(0.0, f64::max).max(1e-12);
    for i in 0..z.rows {
        let d = deg[i].max(floor);
        let s = 1.0 / d.sqrt();
        for v in z.row_mut(i) {
            *v *= s;
        }
    }
}

/// The generic spectral embed (Algorithm 2 steps 2–3 for the dense/sparse
/// baselines): optional degree normalization, iterative SVD, then the
/// configured post-processing (row normalization for the SC family,
/// Σ-scaled scores for SV_RF). SC_RB's substrate-aware variant with the
/// serving projection is [`crate::cluster::sc_rb::RbEmbed`].
pub struct SvdEmbed {
    /// Number of singular triplets (the embedding width).
    pub k: usize,
    /// Which iterative solver backs the SVD.
    pub solver: Solver,
    /// Solver convergence tolerance.
    pub tol: f64,
    /// Solver matvec budget.
    pub max_matvecs: usize,
    /// Full solver seed (method seed ⊕ per-method salt, resolved at
    /// composition time).
    pub seed: u64,
    /// Degree normalization applied before the SVD.
    pub degree: DegreeMode,
    /// Row-normalize the embedding (Algorithm 2 step 4).
    pub row_normalize: bool,
    /// Scale column j of U by σ_j (kernel-K-means PCA scores, SV_RF).
    pub scale_scores: bool,
    /// Treat the dense feature matrix as the symmetric operator S itself
    /// (exact SC): the solver runs on S with `apply == apply_t`.
    pub symmetric: bool,
    /// Chebyshev filter order (read when `solver` is
    /// [`Solver::Compressive`], part of the fingerprint regardless).
    pub cheb_order: usize,
    /// Random-signal count override for the compressive solver.
    pub cheb_signals: Option<usize>,
}

impl Embed for SvdEmbed {
    fn fingerprint(&self, upstream: u64) -> u64 {
        Fingerprint::new("embed/svd")
            .u64(upstream)
            .usize(self.k)
            .str(self.solver.name())
            .f64(self.tol)
            .usize(self.max_matvecs)
            .u64(self.seed)
            .str(self.degree.tag())
            .bool(self.row_normalize)
            .bool(self.scale_scores)
            .bool(self.symmetric)
            .usize(self.cheb_order)
            .usize(self.cheb_signals.unwrap_or(0))
            .finish()
    }

    fn run(&self, _env: &Env, feat: &FeatureArtifact, fp: u64) -> Result<EmbedArtifact, ScrbError> {
        let mut timer = StageTimer::new();
        let mut sopts = SvdsOpts::new(self.k, self.solver);
        sopts.tol = self.tol;
        sopts.max_matvecs = self.max_matvecs;
        sopts.cheb_order = self.cheb_order;
        sopts.cheb_signals = self.cheb_signals;
        let svd = match &feat.z {
            FeatureMatrix::Dense(m) if self.degree == DegreeMode::DenseClamped => {
                let zhat = timer.time("degrees", || {
                    let mut z = (**m).clone();
                    normalize_dense_by_degree(&mut z);
                    z
                });
                timer.time("svd", || svds(&zhat, &sopts, self.seed))
            }
            FeatureMatrix::Dense(m) if self.symmetric => {
                let op = SymOp(&**m);
                timer.time("svd", || svds(&op, &sopts, self.seed))
            }
            FeatureMatrix::Dense(_) | FeatureMatrix::Sparse(_)
                if self.degree == DegreeMode::None && !self.symmetric =>
            {
                // substrate-agnostic: both dense and CSR features plug in
                // through the solver-operator view
                timer.time("svd", || svds(feat.z.svd_op(), &sopts, self.seed))
            }
            _ => {
                return Err(ScrbError::unsupported(
                    "this embed configuration does not apply to the featurized substrate \
                     (RB substrates embed through the RB embed stage)",
                ))
            }
        };
        let SvdResult { mut u, s, stats, .. } = svd;
        if self.scale_scores {
            for j in 0..s.len() {
                for i in 0..u.rows {
                    u.set(i, j, u.at(i, j) * s[j]);
                }
            }
        }
        if self.row_normalize {
            u.normalize_rows();
        }
        Ok(EmbedArtifact {
            fingerprint: fp,
            s,
            u: Arc::new(u),
            proj: None,
            stats: Some(stats),
            timer,
        })
    }
}

// --------------------------------------------------------------- cluster

/// K-means over the embedding rows (Algorithm 2 step 5) — the one cluster
/// stage every method shares.
#[derive(Clone)]
pub struct KmeansCluster {
    /// Number of clusters K.
    pub k: usize,
    /// Replicates (best inertia wins).
    pub replicates: usize,
    /// Lloyd iteration cap per replicate.
    pub max_iters: usize,
    /// Relative inertia-improvement stopping tolerance.
    pub tol: f64,
    /// RNG seed for initialization.
    pub seed: u64,
    /// Mini-batch size; `None` = full-batch Lloyd (the bit-exactness
    /// regime). The streaming driver engages this above its row
    /// threshold.
    pub batch: Option<usize>,
    /// Re-derive the final labels with the native f64 nearest-centroid
    /// scan (the serving argmin) instead of keeping the engine's
    /// assignment — the train-predict == fit-labels contract for methods
    /// whose serving model predicts in this same space (SC_RB, K-means).
    pub relabel: bool,
    /// Assignment engine selector the environment will honour (part of
    /// the fingerprint: an XLA assignment is not bit-identical to the
    /// native one).
    pub engine: Engine,
}

impl KmeansCluster {
    /// Stage configured from a pipeline config (full-batch, native
    /// labels).
    pub fn from_cfg(cfg: &crate::config::PipelineConfig, k: usize) -> KmeansCluster {
        KmeansCluster {
            k,
            replicates: cfg.kmeans_replicates,
            max_iters: cfg.kmeans_max_iters,
            tol: 1e-6,
            seed: cfg.seed,
            batch: None,
            relabel: false,
            engine: cfg.engine,
        }
    }

    /// Enable the native relabel pass (see [`KmeansCluster::relabel`]).
    pub fn with_relabel(mut self) -> KmeansCluster {
        self.relabel = true;
        self
    }

    /// Set the mini-batch size (streaming huge-N path).
    pub fn with_batch(mut self, batch: Option<usize>) -> KmeansCluster {
        self.batch = batch;
        self
    }
}

impl Cluster for KmeansCluster {
    fn fingerprint(&self, upstream: u64) -> u64 {
        Fingerprint::new("cluster/kmeans")
            .u64(upstream)
            .usize(self.k)
            .usize(self.replicates)
            .usize(self.max_iters)
            .f64(self.tol)
            .u64(self.seed)
            .usize(self.batch.map(|b| b + 1).unwrap_or(0))
            .bool(self.relabel)
            .str(self.engine.name())
            .finish()
    }

    fn run(&self, env: &Env, emb: &EmbedArtifact, fp: u64) -> Result<ClusterArtifact, ScrbError> {
        let mut timer = StageTimer::new();
        let engine = env.assign_engine();
        let opts = KmeansOpts {
            k: self.k,
            replicates: self.replicates,
            max_iters: self.max_iters,
            tol: self.tol,
            seed: self.seed,
            batch: self.batch,
        };
        let km = timer.time("kmeans", || kmeans(&emb.u, &opts, engine.as_ref()));
        let labels: Vec<usize> = if self.relabel {
            // the serving argmin (native f64 nearest-centroid): identical
            // bits to `predict` on the training rows, for every engine
            timer.time("embed", || {
                let (lab, _) = NativeAssign.assign(&emb.u, &km.centroids);
                lab.into_iter().map(|l| l as usize).collect()
            })
        } else {
            km.labels.iter().map(|&l| l as usize).collect()
        };
        Ok(ClusterArtifact {
            fingerprint: fp,
            labels,
            centroids: km.centroids,
            inertia: km.inertia,
            timer,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_stage_scales_to_unit_box() {
        let x = Mat::from_vec(3, 2, vec![0.0, 10.0, 5.0, 20.0, 10.0, 30.0]);
        let fp = MinMaxNormalize.fingerprint(1);
        let art = MinMaxNormalize.run(&x, fp).unwrap();
        assert_eq!(art.x.row(0), &[0.0, 0.0]);
        assert_eq!(art.x.row(2), &[1.0, 1.0]);
        let (lo, span) = art.frame.unwrap();
        assert_eq!(lo, vec![0.0, 10.0]);
        assert_eq!(span, vec![10.0, 20.0]);
        // one frame rule: the stage agrees with the Dataset preprocessing
        let ds_frame = crate::data::dataset::minmax_params(&x);
        assert_eq!((lo, span), ds_frame);
    }

    #[test]
    fn normalize_handles_signed_features() {
        let mut z = Mat::from_vec(3, 2, vec![0.5, -0.5, 0.4, 0.3, -0.2, 0.6]);
        normalize_dense_by_degree(&mut z);
        assert!(z.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fingerprints_cover_every_knob() {
        let base = KmeansCluster {
            k: 3,
            replicates: 2,
            max_iters: 10,
            tol: 1e-6,
            seed: 1,
            batch: None,
            relabel: false,
            engine: Engine::Native,
        };
        let fp0 = base.fingerprint(9);
        let variants = [
            KmeansCluster { k: 4, ..base.clone() },
            KmeansCluster { replicates: 3, ..base.clone() },
            KmeansCluster { seed: 2, ..base.clone() },
            KmeansCluster { batch: Some(0), ..base.clone() },
            KmeansCluster { relabel: true, ..base.clone() },
            KmeansCluster { engine: Engine::Xla, ..base.clone() },
        ];
        for v in &variants {
            assert_ne!(v.fingerprint(9), fp0);
        }
        assert_ne!(base.fingerprint(10), fp0, "upstream identity participates");
    }
}
