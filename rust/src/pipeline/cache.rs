//! Fingerprint-keyed artifact cache — the sweep-reuse engine.
//!
//! A sweep driver holds one [`ArtifactCache`] and runs every grid point's
//! pipeline through it. Each stage looks its fingerprint up before
//! executing; a hit returns the shared artifact (`Arc`), a miss runs the
//! stage and stores the result. Because fingerprints chain (each stage's
//! key folds its upstream artifact's key), a config change re-runs
//! exactly the stages downstream of it:
//!
//! - a **k-sweep** with a pinned embedding width reuses featurization
//!   *and* embedding — only K-means re-runs per grid point;
//! - a **σ-sweep** re-fingerprints the featurization (σ is in its config
//!   slice), so featurize/embed/cluster re-run but the normalized input
//!   frame is reused;
//! - a **solver sweep** reuses featurization and re-runs the embed.
//!
//! Correctness is by construction — a stage's fingerprint covers every
//! input that can change its output (config slice + upstream identity) —
//! and is pinned by the cache-equivalence tests in
//! `tests/pipeline_api.rs` (sweep with cache == sweep without).

use super::artifact::{ClusterArtifact, EmbedArtifact, FeatureArtifact, NormArtifact};
use std::collections::HashMap;
use std::sync::Arc;

/// Shared store of stage artifacts keyed by fingerprint.
pub struct ArtifactCache {
    enabled: bool,
    norms: HashMap<u64, Arc<NormArtifact>>,
    features: HashMap<u64, Arc<FeatureArtifact>>,
    embeds: HashMap<u64, Arc<EmbedArtifact>>,
    clusters: HashMap<u64, Arc<ClusterArtifact>>,
    /// Stage lookups that found a reusable artifact.
    pub hits: usize,
    /// Stage lookups that fell through to a fresh execution.
    pub misses: usize,
}

impl Default for ArtifactCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ArtifactCache {
    /// An enabled cache (sweep drivers hold one of these).
    pub fn new() -> ArtifactCache {
        ArtifactCache {
            enabled: true,
            norms: HashMap::new(),
            features: HashMap::new(),
            embeds: HashMap::new(),
            clusters: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// A pass-through cache: every lookup misses, nothing is stored.
    /// One-shot fits use this — no retention, no memory growth.
    pub fn disabled() -> ArtifactCache {
        ArtifactCache { enabled: false, ..ArtifactCache::new() }
    }

    /// Whether lookups can ever hit.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Number of retained artifacts across all stage kinds.
    pub fn len(&self) -> usize {
        self.norms.len() + self.features.len() + self.embeds.len() + self.clusters.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every retained artifact (hit/miss counters are kept). Sweep
    /// drivers call this between datasets to bound resident memory.
    pub fn clear(&mut self) {
        self.norms.clear();
        self.features.clear();
        self.embeds.clear();
        self.clusters.clear();
    }

    fn count(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    /// Look up a normalize artifact.
    pub fn norm(&mut self, fp: u64) -> Option<Arc<NormArtifact>> {
        let got = self.norms.get(&fp).cloned();
        self.count(got.is_some());
        got
    }

    /// Retain a normalize artifact (no-op when disabled).
    pub fn put_norm(&mut self, a: Arc<NormArtifact>) {
        if self.enabled {
            self.norms.insert(a.fingerprint, a);
        }
    }

    /// Look up a feature artifact.
    pub fn feature(&mut self, fp: u64) -> Option<Arc<FeatureArtifact>> {
        let got = self.features.get(&fp).cloned();
        self.count(got.is_some());
        got
    }

    /// Retain a feature artifact (no-op when disabled).
    pub fn put_feature(&mut self, a: Arc<FeatureArtifact>) {
        if self.enabled {
            self.features.insert(a.fingerprint, a);
        }
    }

    /// Look up an embed artifact.
    pub fn embed(&mut self, fp: u64) -> Option<Arc<EmbedArtifact>> {
        let got = self.embeds.get(&fp).cloned();
        self.count(got.is_some());
        got
    }

    /// Retain an embed artifact (no-op when disabled).
    pub fn put_embed(&mut self, a: Arc<EmbedArtifact>) {
        if self.enabled {
            self.embeds.insert(a.fingerprint, a);
        }
    }

    /// Look up a cluster artifact.
    pub fn cluster(&mut self, fp: u64) -> Option<Arc<ClusterArtifact>> {
        let got = self.clusters.get(&fp).cloned();
        self.count(got.is_some());
        got
    }

    /// Retain a cluster artifact (no-op when disabled).
    pub fn put_cluster(&mut self, a: Arc<ClusterArtifact>) {
        if self.enabled {
            self.clusters.insert(a.fingerprint, a);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::timer::StageTimer;

    fn dummy_cluster(fp: u64) -> Arc<ClusterArtifact> {
        Arc::new(ClusterArtifact {
            fingerprint: fp,
            labels: vec![0, 1],
            centroids: Mat::zeros(2, 2),
            inertia: 0.0,
            timer: StageTimer::new(),
        })
    }

    #[test]
    fn enabled_cache_stores_and_hits() {
        let mut c = ArtifactCache::new();
        assert!(c.cluster(7).is_none());
        c.put_cluster(dummy_cluster(7));
        assert_eq!(c.len(), 1);
        let got = c.cluster(7).expect("hit");
        assert_eq!(got.labels, vec![0, 1]);
        assert_eq!((c.hits, c.misses), (1, 1));
        c.clear();
        assert!(c.is_empty());
        assert!(c.cluster(7).is_none());
    }

    #[test]
    fn disabled_cache_never_retains() {
        let mut c = ArtifactCache::disabled();
        assert!(!c.is_enabled());
        c.put_cluster(dummy_cluster(7));
        assert!(c.is_empty());
        assert!(c.cluster(7).is_none());
        assert_eq!(c.hits, 0);
    }
}
