//! Composable staged pipeline — the paper's Algorithm 2 as typed,
//! cacheable stages.
//!
//! Algorithm 2 is explicitly staged: RB featurization (step 1), the
//! degree-normalized SVD embedding (steps 2–3), K-means on the embedding
//! rows (steps 4–5). Every clustering method in the comparison grid is a
//! swap of exactly these stages (Tremblay & Loukas frame all accelerated
//! SC variants this way), so the crate expresses them as one composition
//! surface instead of nine hand-inlined scaffolds:
//!
//! - [`Normalize`] → [`NormArtifact`]: bring the input into its fitted
//!   coordinate frame (identity, or min-max with the stored frame);
//! - [`Featurize`] → [`FeatureArtifact`]: the method's feature matrix on
//!   its native substrate ([`FeatureMatrix`]), plus the RB codebook /
//!   stream census when applicable;
//! - [`Embed`] → [`EmbedArtifact`]: Σ, the embedding rows U, and (for
//!   SC_RB) the folded serving projection P;
//! - [`Cluster`] → [`ClusterArtifact`]: labels + centroids + inertia.
//!
//! A [`Pipeline`] joins one stage of each kind; [`Pipeline::fit`] drives
//! them in order (the typed unfitted state), producing a
//! [`FittedPipeline`] that exposes the per-stage artifacts alongside the
//! familiar [`FitResult`] (the fitted state). Stage boundaries are where
//! reuse happens: every artifact is fingerprinted (config slice ⊕
//! upstream identity — [`Fingerprint`]), and [`Pipeline::fit_cached`]
//! consults an [`ArtifactCache`] before executing a stage, so sweep
//! drivers re-run only what a config change actually invalidates (a
//! k-sweep with a pinned embedding width reuses featurization *and*
//! embedding; a σ-sweep reuses the normalized input frame).
//!
//! The composition table for the paper's nine methods is
//! [`crate::cluster::MethodKind::pipeline`]; the streaming fit
//! ([`crate::stream::fit_streaming`]) drives the *same* embed → cluster →
//! assemble tail through [`Pipeline::fit_features`], with the featurize
//! stage fed by [`DataSource::Stream`] instead of an in-memory matrix —
//! which is what makes the streamed model byte-identical to the
//! in-memory one by construction rather than by hand-synchronized code.
//!
//! ```no_run
//! use scrb::cluster::{Env, MethodKind};
//! use scrb::config::PipelineConfig;
//! use scrb::data::synth;
//! use scrb::pipeline::ArtifactCache;
//!
//! let ds = synth::two_moons(1000, 0.06, 7);
//! let cfg = PipelineConfig::builder().k(2).r(128).sigma(0.15).build();
//! let mut cache = ArtifactCache::new();
//! // a k-sweep with a pinned embedding width: featurize + embed run once
//! for k in [2usize, 3, 4] {
//!     let cfg_k = cfg.rebuild(|b| b.embed_dim(4).k(k)).unwrap();
//!     let env_k = Env::new(cfg_k.clone());
//!     let fitted = MethodKind::ScRb
//!         .pipeline(&cfg_k)
//!         .fit_cached(&env_k, &ds.x, &mut cache)
//!         .unwrap();
//!     println!("k={k}: inertia {}", fitted.result.output.info.inertia);
//! }
//! ```

pub mod artifact;
pub mod cache;
pub mod fingerprint;
pub mod stages;

pub use artifact::{ClusterArtifact, EmbedArtifact, FeatureArtifact, FeatureMatrix, NormArtifact};
pub use cache::ArtifactCache;
pub use fingerprint::{mat_fingerprint, Fingerprint};
pub use stages::{
    normalize_dense_by_degree, DegreeMode, IdentityFeaturize, KmeansCluster, MinMaxNormalize,
    PassEmbed, SvdEmbed,
};

use crate::cluster::{ClusterOutput, Env, MethodInfo};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{CentroidModel, FitResult, FittedModel, ScRbModel};
use crate::stream::{ChunkReader, IngestPolicy, StreamOpts};
use crate::util::timer::StageTimer;
use std::sync::Arc;

/// What a featurize stage reads: an in-memory matrix, or a chunked
/// out-of-core reader (SC_RB's two-pass streaming featurization). The
/// featurize stage is the *only* stage that sees the data source — the
/// embed/cluster/assemble tail is source-agnostic, which is the
/// in-memory/streaming unification.
pub enum DataSource<'a> {
    /// Rows already resident as a dense matrix.
    Matrix(&'a Mat),
    /// A chunked reader driven in bounded-memory passes.
    Stream {
        /// The chunk source (rewound between passes).
        reader: &'a mut dyn ChunkReader,
        /// Streaming knobs (substrate block granularity etc.).
        opts: &'a StreamOpts,
    },
    /// K chunk sources covering disjoint contiguous row ranges of one
    /// logical dataset, featurized concurrently by the [`crate::shard`]
    /// subsystem and merged into a fit bit-identical to
    /// [`DataSource::Stream`] over the concatenation.
    ShardedStream {
        /// One reader per shard, in dataset order (shard s's rows precede
        /// shard s+1's). Each is rewound between passes independently.
        readers: Vec<&'a mut (dyn ChunkReader + Send)>,
        /// Substrate block granularity in rows (same knob as
        /// [`StreamOpts::block_rows`]).
        block_rows: usize,
        /// Ingest fault policy, applied shard-locally (each shard gets
        /// its own retry budget and quarantine report; reports merge
        /// deterministically).
        policy: IngestPolicy,
    },
}

impl<'a> DataSource<'a> {
    /// The in-memory matrix, or a typed error for stages that cannot
    /// featurize a stream (`method` names the caller in the message).
    pub fn matrix(&self, method: &str) -> Result<&Mat, ScrbError> {
        match self {
            DataSource::Matrix(x) => Ok(*x),
            DataSource::Stream { .. } | DataSource::ShardedStream { .. } => {
                Err(ScrbError::unsupported(format!(
                    "{method} cannot featurize a chunked stream; only SC_RB fits out-of-core"
                )))
            }
        }
    }
}

/// Input-normalization stage: brings the data into the coordinate frame
/// the rest of the pipeline (and the serving model) will live in.
pub trait Normalize {
    /// Cache key of the artifact this stage would produce on input
    /// `data_fp` (must cover every config knob that changes the output).
    fn fingerprint(&self, data_fp: u64) -> u64;
    /// Execute the stage; `fp` is the precomputed [`Normalize::fingerprint`].
    fn run(&self, x: &Mat, fp: u64) -> Result<NormArtifact, ScrbError>;
}

/// Featurization stage (Algorithm 2 step 1 and its baselines' analogues).
pub trait Featurize {
    /// Cache key of the artifact this stage would produce on input
    /// `input_fp` (must cover every config knob that changes the output).
    fn fingerprint(&self, input_fp: u64) -> u64;
    /// Execute the stage; `fp` is the precomputed [`Featurize::fingerprint`].
    fn run(&self, env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError>;
    /// Whether the driver may retain this stage's artifact in a sweep
    /// cache. Default yes; stages whose artifact is huge and never
    /// shareable (the exact-SC N×N similarity) opt out.
    fn cacheable(&self) -> bool {
        true
    }
}

/// Spectral-embedding stage (Algorithm 2 steps 2–4 and the baselines'
/// analogues, including pass-through for the kernel-K-means family).
pub trait Embed {
    /// Cache key given the upstream feature artifact's fingerprint.
    fn fingerprint(&self, upstream: u64) -> u64;
    /// Execute the stage; `fp` is the precomputed [`Embed::fingerprint`].
    fn run(&self, env: &Env, feat: &FeatureArtifact, fp: u64) -> Result<EmbedArtifact, ScrbError>;
    /// Whether the driver may retain this stage's artifact in a sweep
    /// cache. Default yes; trivially re-runnable pass-throughs opt out.
    fn cacheable(&self) -> bool {
        true
    }
}

/// Clustering stage (Algorithm 2 step 5).
pub trait Cluster {
    /// Cache key given the upstream embed artifact's fingerprint.
    fn fingerprint(&self, upstream: u64) -> u64;
    /// Execute the stage; `fp` is the precomputed [`Cluster::fingerprint`].
    fn run(&self, env: &Env, emb: &EmbedArtifact, fp: u64) -> Result<ClusterArtifact, ScrbError>;
}

/// How the fitted pipeline turns its artifacts into a serving
/// [`FittedModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assemble {
    /// The K-means centroids *are* the model (plain K-means: exact
    /// serving).
    Centroids,
    /// Input-space class means of the fitted partition (the transductive
    /// baselines' documented serving approximation).
    ClassMeans,
    /// SC_RB's spectral out-of-sample artifact: codebook + Σ + folded
    /// projection + centroids (+ the input frame when the featurization
    /// computed one).
    ScRb,
}

/// An unfitted pipeline: one stage of each kind plus the model-assembly
/// rule. Compose by hand, or take a method's canonical composition from
/// [`crate::cluster::MethodKind::pipeline`].
pub struct Pipeline {
    /// Optional input-normalization stage (`None` = the caller's frame).
    pub normalize: Option<Box<dyn Normalize>>,
    /// Featurization stage.
    pub featurize: Box<dyn Featurize>,
    /// Embedding stage.
    pub embed: Box<dyn Embed>,
    /// Clustering stage.
    pub cluster: Box<dyn Cluster>,
    /// Serving-model assembly rule.
    pub assemble: Assemble,
}

/// A fitted pipeline: the per-stage artifacts (shareable, cacheable) plus
/// the assembled [`FitResult`]. The artifacts are the redesign's point —
/// e.g. [`FittedPipeline::embedding`] exports Σ/U standalone for
/// downstream analysis without re-running anything.
pub struct FittedPipeline {
    /// The featurization artifact (substrate + codebook).
    pub features: Arc<FeatureArtifact>,
    /// The embedding artifact (Σ, U, projection).
    pub embedding: Arc<EmbedArtifact>,
    /// The clustering artifact (labels, centroids, inertia).
    pub clustering: Arc<ClusterArtifact>,
    /// The assembled training output + serving model.
    pub result: FitResult,
}

impl Pipeline {
    /// Compose a pipeline from its stages (no input normalization).
    pub fn new(
        featurize: Box<dyn Featurize>,
        embed: Box<dyn Embed>,
        cluster: Box<dyn Cluster>,
        assemble: Assemble,
    ) -> Pipeline {
        Pipeline { normalize: None, featurize, embed, cluster, assemble }
    }

    /// Attach an input-normalization stage.
    pub fn with_normalize(mut self, normalize: Box<dyn Normalize>) -> Pipeline {
        self.normalize = Some(normalize);
        self
    }

    /// Fit on `x` without artifact retention — the one-shot path every
    /// [`crate::cluster::MethodKind::fit`] call takes.
    pub fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
        Ok(self.fit_cached(env, x, &mut ArtifactCache::disabled())?.result)
    }

    /// Fit on `x`, reusing (and feeding) `cache`: each stage's
    /// fingerprint is looked up first, so a sweep re-runs only the stages
    /// a config change invalidated. The stages must have been composed
    /// from the same config `env` carries (true for
    /// [`crate::cluster::MethodKind::pipeline`] compositions).
    pub fn fit_cached(
        &self,
        env: &Env,
        x: &Mat,
        cache: &mut ArtifactCache,
    ) -> Result<FittedPipeline, ScrbError> {
        let mut timer = StageTimer::new();
        // Input identity is only a cache key — skip the O(n·d) hashing
        // pass entirely on one-shot (disabled-cache) fits. The XLA
        // runtime's presence participates: under `Engine::Auto` several
        // stages compute different (f32-artifact) results when a runtime
        // is attached, so environments with and without one must never
        // share artifacts.
        let data_fp = if cache.is_enabled() {
            Fingerprint::new("input")
                .bool(env.xla.is_some())
                .u64(mat_fingerprint(x))
                .finish()
        } else {
            0
        };

        // normalize (optional). On a cache hit the artifact's originally
        // measured timer is merged (here and for every later stage), so
        // the output timer always reports the full standalone computation
        // cost of the artifacts the fit is built from — sweeps reusing
        // artifacts save wall-clock without distorting stage accounting.
        let norm_art: Option<Arc<NormArtifact>> = match &self.normalize {
            None => None,
            Some(nz) => {
                let fp = nz.fingerprint(data_fp);
                let art = match cache.norm(fp) {
                    Some(a) => a,
                    None => {
                        let a = Arc::new(nz.run(x, fp)?);
                        cache.put_norm(a.clone());
                        a
                    }
                };
                timer.merge(&art.timer);
                Some(art)
            }
        };
        let (xn, input_fp): (&Mat, u64) = match &norm_art {
            Some(a) => (&a.x, a.fingerprint),
            None => (x, data_fp),
        };

        // featurize (some stages opt out of retention — see
        // [`Featurize::cacheable`])
        let f_fp = self.featurize.fingerprint(input_fp);
        let cached = if self.featurize.cacheable() { cache.feature(f_fp) } else { None };
        let feat = match cached {
            Some(a) => a,
            None => {
                let a = Arc::new(self.featurize.run(env, DataSource::Matrix(xn), f_fp)?);
                if self.featurize.cacheable() {
                    cache.put_feature(a.clone());
                }
                a
            }
        };
        timer.merge(&feat.timer);

        let frame = norm_art.as_ref().and_then(|a| a.frame.clone());
        self.finish(env, Some(xn), feat, frame, cache, timer)
    }

    /// Drive the embed → cluster → assemble tail over an
    /// already-featurized artifact — the entry point the streaming fit
    /// shares with the in-memory path (its featurization came from a
    /// [`DataSource::Stream`], so there is no input matrix; transductive
    /// assemblies are rejected with a typed error).
    pub fn fit_features(
        &self,
        env: &Env,
        feat: Arc<FeatureArtifact>,
        cache: &mut ArtifactCache,
    ) -> Result<FittedPipeline, ScrbError> {
        let mut timer = StageTimer::new();
        timer.merge(&feat.timer);
        self.finish(env, None, feat, None, cache, timer)
    }

    /// Shared tail: embed, cluster, assemble.
    fn finish(
        &self,
        env: &Env,
        x: Option<&Mat>,
        feat: Arc<FeatureArtifact>,
        frame: Option<(Vec<f64>, Vec<f64>)>,
        cache: &mut ArtifactCache,
        mut timer: StageTimer,
    ) -> Result<FittedPipeline, ScrbError> {
        // embed
        let e_fp = self.embed.fingerprint(feat.fingerprint);
        let cached_emb = if self.embed.cacheable() { cache.embed(e_fp) } else { None };
        let emb = match cached_emb {
            Some(a) => a,
            None => {
                let a = Arc::new(self.embed.run(env, &feat, e_fp)?);
                if self.embed.cacheable() {
                    cache.put_embed(a.clone());
                }
                a
            }
        };
        timer.merge(&emb.timer);

        // cluster
        let c_fp = self.cluster.fingerprint(emb.fingerprint);
        let clu = match cache.cluster(c_fp) {
            Some(a) => a,
            None => {
                let a = Arc::new(self.cluster.run(env, &emb, c_fp)?);
                cache.put_cluster(a.clone());
                a
            }
        };
        timer.merge(&clu.timer);

        // assemble the serving model
        let model: Box<dyn FittedModel> = match self.assemble {
            Assemble::Centroids => Box::new(CentroidModel::new(clu.centroids.clone())),
            Assemble::ClassMeans => {
                let x = x.ok_or_else(|| {
                    ScrbError::unsupported(
                        "class-mean model assembly needs the in-memory input matrix",
                    )
                })?;
                Box::new(CentroidModel::from_labels(x, &clu.labels, clu.centroids.rows))
            }
            Assemble::ScRb => {
                let mut m = assemble_scrb(env, &feat, &emb, &clu)?;
                if m.norm.is_none() {
                    if let Some((lo, span)) = frame {
                        m.set_input_norm(lo, span);
                    }
                }
                Box::new(m)
            }
        };

        let output = ClusterOutput {
            labels: clu.labels.clone(),
            timer,
            info: MethodInfo {
                feature_dim: feat.feature_dim,
                svd: emb.stats.clone(),
                kappa: feat.kappa,
                inertia: clu.inertia,
            },
        };
        Ok(FittedPipeline {
            features: feat,
            embedding: emb,
            clustering: clu,
            result: FitResult { model, output },
        })
    }
}

/// Build the SC_RB serving model from pipeline artifacts — the one
/// assembly routine shared by the in-memory and streaming drivers (both
/// produce the same bytes from the same artifacts by construction).
pub fn assemble_scrb(
    env: &Env,
    feat: &FeatureArtifact,
    emb: &EmbedArtifact,
    clu: &ClusterArtifact,
) -> Result<ScRbModel, ScrbError> {
    let codebook = feat.codebook.clone().ok_or_else(|| {
        ScrbError::unsupported("SC_RB model assembly needs the featurize stage's RB codebook")
    })?;
    let proj = emb.proj.clone().ok_or_else(|| {
        ScrbError::unsupported("SC_RB model assembly needs the embed stage's serving projection")
    })?;
    Ok(ScRbModel {
        codebook,
        kernel: env.cfg.kernel,
        s: emb.s.clone(),
        proj,
        centroids: clu.centroids.clone(),
        norm: feat.norm.clone(),
        drift: Default::default(),
        unseen_warn: crate::model::DEFAULT_UNSEEN_WARN,
        update_state: Default::default(),
    })
}
