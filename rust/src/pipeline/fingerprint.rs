//! Stage and data fingerprints for artifact caching.
//!
//! Every pipeline stage hashes its *complete* configuration slice plus the
//! fingerprint of its upstream artifact into one `u64` — the cache key for
//! the artifact it produces. Two stage executions share a fingerprint iff
//! they would compute the same artifact, so a sweep driver can replay a
//! pipeline under a modified config and only the stages downstream of the
//! change re-run (see [`crate::pipeline::ArtifactCache`]).
//!
//! The hash is FNV-1a over little-endian bytes — not cryptographic, just
//! fast, deterministic across runs/platforms, and collision-safe enough
//! for the handful of artifacts a sweep holds (keys additionally embed a
//! per-stage tag string, so artifacts of different kinds can never
//! collide on equal payloads).

use crate::linalg::Mat;
use crate::util::fnv::Fnv64;

/// Builder-style FNV-1a fingerprint accumulator.
///
/// ```
/// use scrb::pipeline::Fingerprint;
/// let a = Fingerprint::new("stage/demo").usize(256).f64(0.25).finish();
/// let b = Fingerprint::new("stage/demo").usize(256).f64(0.25).finish();
/// assert_eq!(a, b);
/// assert_ne!(a, Fingerprint::new("stage/demo").usize(257).f64(0.25).finish());
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Fingerprint(Fnv64);

impl Fingerprint {
    /// Start a fingerprint under a per-stage `tag` (namespaces the key so
    /// different artifact kinds never collide on equal payloads).
    pub fn new(tag: &str) -> Fingerprint {
        Fingerprint(Fnv64::new()).str(tag)
    }

    /// Fold a `u64` (little-endian bytes).
    pub fn u64(mut self, v: u64) -> Fingerprint {
        self.0.write_u64(v);
        self
    }

    /// Fold a `usize`.
    pub fn usize(self, v: usize) -> Fingerprint {
        self.u64(v as u64)
    }

    /// Fold a `bool`.
    pub fn bool(self, v: bool) -> Fingerprint {
        self.u64(v as u64)
    }

    /// Fold an `f64` by its bit pattern (distinguishes `0.0`/`-0.0`,
    /// which is what cache correctness wants: different bits may mean a
    /// different computation).
    pub fn f64(self, v: f64) -> Fingerprint {
        self.u64(v.to_bits())
    }

    /// Fold a string (length-prefixed so concatenations can't collide).
    pub fn str(mut self, s: &str) -> Fingerprint {
        self = self.usize(s.len());
        self.0.write(s.as_bytes());
        self
    }

    /// The accumulated 64-bit fingerprint.
    pub fn finish(self) -> u64 {
        self.0.finish()
    }
}

/// Data-identity fingerprint of a dense matrix: shape plus every element's
/// bit pattern. O(n·d), one linear pass — negligible next to any fit that
/// consumes the matrix, and it makes artifact reuse *sound*: a sweep can
/// only hit the cache when the input bytes are identical.
pub fn mat_fingerprint(x: &Mat) -> u64 {
    let mut f = Fingerprint::new("data/mat").usize(x.rows).usize(x.cols);
    for &v in &x.data {
        f = f.f64(v);
    }
    f.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sensitive() {
        let a = Fingerprint::new("t").usize(1).f64(2.0).str("x").finish();
        let b = Fingerprint::new("t").usize(1).f64(2.0).str("x").finish();
        assert_eq!(a, b);
        assert_ne!(a, Fingerprint::new("t").usize(2).f64(2.0).str("x").finish());
        assert_ne!(a, Fingerprint::new("t").usize(1).f64(2.5).str("x").finish());
        assert_ne!(a, Fingerprint::new("u").usize(1).f64(2.0).str("x").finish());
    }

    #[test]
    fn mat_fingerprint_tracks_bits() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = a.clone();
        assert_eq!(mat_fingerprint(&a), mat_fingerprint(&b));
        b.set(1, 1, 4.0 + 1e-12);
        assert_ne!(mat_fingerprint(&a), mat_fingerprint(&b));
        // shape participates even when the data vector is equal
        let c = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        assert_ne!(mat_fingerprint(&a), mat_fingerprint(&c));
    }

    #[test]
    fn zero_and_negative_zero_differ() {
        let a = Fingerprint::new("t").f64(0.0).finish();
        let b = Fingerprint::new("t").f64(-0.0).finish();
        assert_ne!(a, b);
    }
}
