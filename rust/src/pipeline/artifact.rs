//! Typed intermediate artifacts flowing between pipeline stages.
//!
//! Each stage of Algorithm 2 leaves behind a value another run can pick
//! up: the normalized input frame, the featurization (the RB/RF/landmark
//! feature matrix plus whatever the serving path needs), the spectral
//! embedding (Σ, the embedding rows, and SC_RB's folded projection P),
//! and the clustering (labels + centroids). Artifacts carry their own
//! [fingerprint](crate::pipeline::Fingerprint) and the wallclock timings
//! of the stages that produced them, so a cached artifact is
//! indistinguishable from a freshly computed one — the basis of the
//! sweep-reuse contract tested in `tests/pipeline_api.rs`.

use crate::eigen::{SvdOp, SvdStats};
use crate::linalg::Mat;
use crate::rb::RbCodebook;
use crate::sparse::{BlockEllRb, Csr, EllRb};
use crate::stream::Quarantine;
use crate::util::timer::StageTimer;
use std::sync::Arc;

/// The feature matrix a featurize stage emits, on whichever substrate the
/// method natively produces: the fixed-stride RB substrate (in-memory
/// SC_RB, already degree-normalized — see
/// [`crate::cluster::sc_rb::RbFeaturize`]), its row-blocked streaming
/// variant, a dense matrix (RF / Nyström / exact similarity), or general
/// CSR (the LSC bipartite affinity). Dense features sit behind an `Arc`
/// so pass-through embeds share them without copying N×R (or N×d) data.
pub enum FeatureMatrix {
    /// Fixed-stride RB substrate ([`EllRb`]), degree-normalized Ẑ.
    EllRb(EllRb),
    /// Row-blocked RB substrate ([`BlockEllRb`]), degree-normalized Ẑ.
    Block(BlockEllRb),
    /// Dense features (RF maps, whitened Nyström features, the exact
    /// normalized similarity, or the raw input for plain K-means).
    Dense(Arc<Mat>),
    /// General sparse features (the LSC bipartite affinity).
    Sparse(Csr),
}

impl FeatureMatrix {
    /// Number of data rows.
    pub fn nrows(&self) -> usize {
        match self {
            FeatureMatrix::EllRb(z) => z.rows,
            FeatureMatrix::Block(z) => z.rows,
            FeatureMatrix::Dense(m) => m.rows,
            FeatureMatrix::Sparse(a) => a.rows,
        }
    }

    /// Number of feature columns.
    pub fn ncols(&self) -> usize {
        match self {
            FeatureMatrix::EllRb(z) => z.cols,
            FeatureMatrix::Block(z) => z.cols,
            FeatureMatrix::Dense(m) => m.cols,
            FeatureMatrix::Sparse(a) => a.cols,
        }
    }

    /// View as a solver operator (every substrate implements
    /// [`SvdOp`], so embed stages are substrate-agnostic).
    pub fn svd_op(&self) -> &dyn SvdOp {
        match self {
            FeatureMatrix::EllRb(z) => z,
            FeatureMatrix::Block(z) => z,
            FeatureMatrix::Dense(m) => &**m,
            FeatureMatrix::Sparse(a) => a,
        }
    }
}

/// Output of a [`crate::pipeline::Normalize`] stage: the input brought
/// into the fitted coordinate frame, plus the frame itself so a serving
/// model can normalize out-of-sample batches identically.
pub struct NormArtifact {
    /// Cache key (normalize config ⊕ data identity).
    pub fingerprint: u64,
    /// The normalized input matrix.
    pub x: Mat,
    /// Per-feature `(min, span)` frame, when the stage computes one
    /// (identity normalization stores `None`).
    pub frame: Option<(Vec<f64>, Vec<f64>)>,
    /// Wallclock of the stage execution that produced this artifact.
    pub timer: StageTimer,
}

/// Output of a [`crate::pipeline::Featurize`] stage.
pub struct FeatureArtifact {
    /// Cache key (featurize config ⊕ input identity).
    pub fingerprint: u64,
    /// The feature matrix on its native substrate.
    pub z: FeatureMatrix,
    /// RB codebook (grids + bin→column tables) when the featurization is
    /// RB — what the serving model needs to bin out-of-sample points.
    pub codebook: Option<RbCodebook>,
    /// RB κ estimate (Definition 1), RB featurizations only.
    pub kappa: Option<f64>,
    /// The dimension the method reports as its working size (D for RB, R
    /// for RF/landmark methods, N for the exact similarity).
    pub feature_dim: usize,
    /// Input min/span frame, when the featurization computed one (the
    /// streaming stats pass); folded into the assembled serving model.
    pub norm: Option<(Vec<f64>, Vec<f64>)>,
    /// Raw ground-truth labels collected by a streaming featurization's
    /// census pass (row order), used by the stream driver for K selection
    /// and scoring.
    pub stream_labels: Option<Vec<i64>>,
    /// Merged shard-local quarantine/retry report from a *sharded*
    /// streaming featurization (the single-reader stream path reports
    /// through its `GuardedReader` instead; `None` everywhere else).
    pub stream_quarantine: Option<Quarantine>,
    /// Wallclock of the stage execution that produced this artifact.
    pub timer: StageTimer,
}

/// Output of an [`crate::pipeline::Embed`] stage: the spectral embedding
/// the cluster stage consumes, plus Σ and (for SC_RB) the folded serving
/// projection.
pub struct EmbedArtifact {
    /// Cache key (embed config ⊕ feature-artifact fingerprint).
    pub fingerprint: u64,
    /// Top singular values, descending (empty for pass-through embeds).
    pub s: Vec<f64>,
    /// Embedding rows in the exact space the cluster stage runs K-means
    /// on (row-normalized / score-scaled per the stage's configuration).
    /// Behind an `Arc`: pass-through embeds share the upstream dense
    /// features instead of copying them.
    pub u: Arc<Mat>,
    /// SC_RB's pre-folded serving projection `P = V·Σ⁻¹/√R` (D×K).
    pub proj: Option<Mat>,
    /// Solver statistics when an iterative SVD ran.
    pub stats: Option<SvdStats>,
    /// Wallclock of the stage execution that produced this artifact.
    pub timer: StageTimer,
}

/// Output of a [`crate::pipeline::Cluster`] stage.
pub struct ClusterArtifact {
    /// Cache key (cluster config ⊕ embed-artifact fingerprint).
    pub fingerprint: u64,
    /// Final training-set labels, row order.
    pub labels: Vec<usize>,
    /// K-means centroids in the embedding space (K×K_embed).
    pub centroids: Mat,
    /// K-means inertia of the winning replicate.
    pub inertia: f64,
    /// Wallclock of the stage execution that produced this artifact.
    pub timer: StageTimer,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_matrix_shapes() {
        let m = Mat::zeros(3, 5);
        let fm = FeatureMatrix::Dense(Arc::new(m));
        assert_eq!(fm.nrows(), 3);
        assert_eq!(fm.ncols(), 5);
        assert_eq!(fm.svd_op().nrows(), 3);
        let e = EllRb::new(2, 4, 1, vec![0, 3], vec![1.0, 1.0]);
        let fe = FeatureMatrix::EllRb(e);
        assert_eq!((fe.nrows(), fe.ncols()), (2, 4));
        assert_eq!(fe.svd_op().ncols(), 4);
    }
}
