//! Streaming out-of-core ingestion: chunked readers, block-wise RB
//! featurization, and a bounded-memory fit pipeline.
//!
//! The paper's headline claim is linear scalability in N, but a pipeline
//! that begins by densifying the input into an N×d `Mat` caps out long
//! before "millions of users": for sparse benchmarks (news20: d ≈ 62k)
//! the densified input dwarfs the RB matrix itself. Landmark and
//! sampling accelerations dodge this by subsampling and paying
//! information loss; RB's *data-independent* feature map (Algorithm 1
//! draws grids from the kernel, not the data) lets us instead stream the
//! full dataset through the fit in fixed-size chunks — bounded resident
//! memory, zero approximation beyond RB itself.
//!
//! # The two-pass streaming fit
//!
//! [`fit_streaming`] makes exactly two chunked passes over a
//! [`ChunkReader`]:
//!
//! 1. **Stats pass** — one scan accumulates the per-column min/span
//!    input frame (bit-equal to the dense `minmax_params`), the row
//!    count, the feature dimension, and the label census.
//! 2. **Featurize pass** — the reader rewinds; each chunk is densified
//!    into one reusable `chunk_rows × d` scratch, normalized into the
//!    fitted frame, and binned against incrementally-grown per-grid
//!    dictionaries ([`crate::rb::BinTable::get_or_assign`]). Local bin
//!    ids accumulate into fixed-row-count substrate blocks; when the
//!    stream ends, global column offsets resolve and the blocks become a
//!    [`crate::sparse::BlockEllRb`].
//!
//! Degrees, the iterative SVD, the serving projection, and K-means then
//! run on the block substrate unchanged — every solver product is
//! bit-identical to the monolithic path, so **a streamed fit reproduces
//! the in-memory fit's model byte for byte** on the same data and seed.
//! For huge N the final K-means switches to the mini-batch path over the
//! streamed serving embedding (see [`StreamOpts::minibatch_threshold`]).
//!
//! # Memory bound
//!
//! Peak resident state while featurizing:
//!
//! - `chunk_rows × d × 8 B` — the dense chunk scratch (the only place a
//!   row is ever dense), plus the reusable sparse chunk buffers;
//! - `N × R × 4 B` — the accumulated bin indices, which *are* the final
//!   substrate (no separate copy);
//! - `O(D)` — the per-grid dictionaries and, later, per-block transpose
//!   layouts.
//!
//! The input file itself is never resident. `--chunk-rows` is therefore
//! the knob trading IO granularity against the dense-scratch footprint.
//!
//! # When to prefer `--stream`
//!
//! Use the streaming path when the densified N×d input would not fit in
//! memory (large N, or sparse high-d data), or when fitting straight
//! from files too big to load. For data that fits comfortably, the
//! in-memory path avoids the second file scan and the per-block
//! transpose overhead — the models are identical either way, so the
//! choice is purely operational:
//!
//! ```text
//! scrb fit --stream --data big.libsvm --chunk-rows 4096 \
//!          --sigma 0.25 --k 10 --save model.scrb
//! ```
//!
//! # Fault tolerance
//!
//! Streamed fits run where inputs are dirtiest, so the ingest stack is
//! hardened end to end: the [`policy`] layer retries transient reader
//! errors with bounded backoff and — under `--on-bad-record quarantine` —
//! skips malformed/non-finite records deterministically in both passes,
//! reporting exact counts with file/line/byte context. Long fits persist
//! pass-1 stats and incremental pass-2 state through [`checkpoint`]
//! (`--checkpoint DIR`, `--resume`) and continue **bit-identically**
//! after a kill. The [`fault`] module is the seeded injection harness
//! (transient errors, NaN/Inf corruption, mid-pass kills, byte-level
//! model corruption) all of this is verified under in `tests/faults.rs`.

pub mod checkpoint;
pub mod chunk;
pub mod fault;
pub mod featurize;
pub mod fit;
pub mod policy;
pub mod reader;
pub mod stats;

pub use checkpoint::CheckpointCfg;
pub use chunk::{RowMeta, SparseChunk};
pub use fault::{
    corrupt_libsvm_text, corrupt_model_bytes, tear_frame, FaultPlan, FaultyReader, ServeFaultPlan,
};
pub use featurize::{StreamFeaturizer, StreamFeatures};
pub use fit::{fit_streaming, fit_streaming_sharded, StreamFit, StreamOpts};
pub use policy::{GuardedReader, IngestPolicy, OnBadRecord, Quarantine};
pub use reader::{ChainChunks, ChunkReader, CsvChunks, LibsvmChunks};
pub use stats::{stats_pass, StreamStats};
