//! The streaming fit: Algorithm 2 end-to-end without ever materializing
//! the N×d input — driven through the **same** pipeline stages as the
//! in-memory fit.
//!
//! The featurize stage ([`crate::cluster::sc_rb::RbFeaturize`]) is fed a
//! [`crate::pipeline::DataSource::Stream`]: pass 1 accumulates the
//! min/span frame (bit-equal to the dense `minmax_params`) plus the
//! row/label census, pass 2 densifies one `chunk_rows×d` scratch at a
//! time into the [`crate::sparse::BlockEllRb`] substrate. Everything
//! after that — implicit degrees, the iterative SVD, the serving
//! projection, K-means on the serving embedding — is the *identical*
//! embed → cluster → assemble tail the in-memory fit drives
//! ([`crate::pipeline::Pipeline::fit_features`]), over block kernels
//! that are bit-identical to the monolithic ones.
//!
//! **Bit-exactness:** on the same data and seed, the returned model
//! serializes byte-identically to the in-memory path (`load_libsvm` →
//! min-max normalize → SC_RB fit → store the frame), and the training
//! labels match — now a property of the shared driver rather than of two
//! hand-synchronized functions (`tests/stream.rs`).

use super::checkpoint::CheckpointCfg;
use super::policy::{GuardedReader, IngestPolicy, Quarantine};
use super::reader::ChunkReader;
use crate::cluster::sc_rb::{scrb_stages, RbFeaturize};
use crate::cluster::{ClusterOutput, Env};
use crate::data::libsvm::compact_labels;
use crate::error::ScrbError;
use crate::model::{FitResult, FittedModel, ScRbModel};
use crate::pipeline::{ArtifactCache, DataSource, Featurize, Fingerprint};
use std::sync::Arc;

/// Streaming-fit knobs (the reader's `chunk_rows` is the other one).
#[derive(Clone, Debug)]
pub struct StreamOpts {
    /// Substrate block granularity in rows. Independent of the reader's
    /// chunk size, so the assembled substrate — and everything downstream
    /// — does not depend on how the stream was chunked.
    pub block_rows: usize,
    /// Number of clusters K; `None` = number of distinct labels seen in
    /// the stream (min 2), mirroring how the coordinator derives K from a
    /// dataset.
    pub k: Option<usize>,
    /// Row count at/above which the final K-means switches to mini-batch
    /// (Sculley 2010) over the serving embedding. Below it the full-batch
    /// Lloyd path runs — required for bit-exact agreement with the
    /// in-memory fit.
    pub minibatch_threshold: usize,
    /// Mini-batch size when that path engages.
    pub minibatch_size: usize,
    /// What to do with malformed/non-finite records and transient reader
    /// errors (see [`IngestPolicy`]). Strict by default: the first bad
    /// record fails the fit with a located, typed error.
    pub policy: IngestPolicy,
    /// Checkpoint/resume configuration; `None` = no checkpointing.
    pub checkpoint: Option<CheckpointCfg>,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            block_rows: 65_536,
            k: None,
            minibatch_threshold: 200_000,
            minibatch_size: 10_000,
            policy: IngestPolicy::default(),
            checkpoint: None,
        }
    }
}

/// What a streaming fit returns: the serving model, the training-set
/// clustering output (same shape as the batch fit), and the ground-truth
/// labels collected from the stream for scoring.
pub struct StreamFit {
    pub model: ScRbModel,
    pub output: ClusterOutput,
    /// Ground-truth labels compacted to `0..k_true`, in row order.
    pub y: Vec<usize>,
    /// Number of distinct ground-truth classes in the stream.
    pub k_true: usize,
    /// Rows streamed.
    pub n: usize,
    /// Input dimensionality discovered from the stream.
    pub d: usize,
    /// What the ingest policy skipped/absorbed during the fit (empty
    /// counts under strict mode on clean data).
    pub quarantine: Quarantine,
}

/// Fit SC_RB (Algorithm 2) out-of-core: the two-pass chunked featurize
/// stage over `reader`, then the shared pipeline tail. Bounded resident
/// input memory; bit-identical model to the in-memory fit on the same
/// data and seed.
pub fn fit_streaming(
    env: &Env,
    reader: &mut dyn ChunkReader,
    opts: &StreamOpts,
) -> Result<StreamFit, ScrbError> {
    let cfg = &env.cfg;
    if let Some(0) = opts.k {
        return Err(ScrbError::config("streaming fit needs k >= 1 clusters"));
    }
    // The invariant lives with the driver, not just its CLI wrapper: a
    // streamed fit has no data matrix to run bandwidth selection on, so
    // silently using a default σ would bake a wrong bandwidth into a
    // persisted model (the same rule `PipelineConfig::validate` enforces
    // for configs carrying a stream section).
    if !cfg.sigma_explicit {
        return Err(ScrbError::config(
            "a streamed fit cannot run the in-memory bandwidth selection; pin the kernel \
             bandwidth explicitly (builder .sigma()/.kernel(), or --sigma at the CLI)",
        ));
    }

    // Every reader is wrapped in the fault-policy enforcement layer:
    // bounded retry for transient errors plus (in quarantine mode) the
    // non-finite row screen. The line-level policy is pushed down into
    // the text readers by the wrapper's constructor.
    let mut guarded = GuardedReader::new(reader, opts.policy.clone());

    // Featurize from the stream source (two chunked passes). The stream
    // has no stable in-memory identity to fingerprint, so streamed
    // featurizations are never cache-shared; the fingerprint still chains
    // the config slice for the downstream stages.
    let featurize = RbFeaturize { r: cfg.r, sigma: cfg.kernel.sigma(), seed: cfg.seed };
    let fp = featurize.fingerprint(Fingerprint::new("data/stream").finish());
    // explicit reborrow: the data source borrows the reader only for the
    // featurize call, so the dimension census below can still read it
    let feat =
        Arc::new(featurize.run(env, DataSource::Stream { reader: &mut guarded, opts }, fp)?);
    let d = guarded.dim();
    let quarantine = guarded.report();
    finish_stream_fit(env, feat, opts, d, quarantine)
}

/// Fit SC_RB out-of-core over K parallel shards (dataset order). The
/// merged fit is **bit-identical** to [`fit_streaming`] over the shard
/// concatenation, for any shard count — see [`crate::shard`] for the
/// plan/merge machinery and the equivalence argument. A single shard
/// delegates to the sequential path (keeping checkpoint/resume support);
/// more than one shard currently refuses checkpointing with a typed
/// config error rather than silently ignoring the flag.
pub fn fit_streaming_sharded(
    env: &Env,
    readers: &mut [&mut (dyn ChunkReader + Send)],
    opts: &StreamOpts,
) -> Result<StreamFit, ScrbError> {
    let cfg = &env.cfg;
    if readers.is_empty() {
        return Err(ScrbError::config("sharded streaming fit needs at least one shard"));
    }
    if readers.len() > 1 && opts.checkpoint.is_some() {
        return Err(ScrbError::config(
            "checkpoint/resume (--checkpoint/--resume) is not yet supported with --shards > 1; \
             drop the checkpoint flags or fit with a single shard",
        ));
    }
    if readers.len() == 1 {
        // one shard *is* the sequential fit — same reader, same guard,
        // same checkpoint support
        return fit_streaming(env, &mut *readers[0], opts);
    }
    if let Some(0) = opts.k {
        return Err(ScrbError::config("streaming fit needs k >= 1 clusters"));
    }
    if !cfg.sigma_explicit {
        return Err(ScrbError::config(
            "a streamed fit cannot run the in-memory bandwidth selection; pin the kernel \
             bandwidth explicitly (builder .sigma()/.kernel(), or --sigma at the CLI)",
        ));
    }

    let featurize = RbFeaturize { r: cfg.r, sigma: cfg.kernel.sigma(), seed: cfg.seed };
    // same fingerprint chain as the sequential stream: the shard count is
    // an execution detail, not part of the fit identity
    let fp = featurize.fingerprint(Fingerprint::new("data/stream").finish());
    let source = DataSource::ShardedStream {
        readers: readers.iter_mut().map(|r| &mut **r).collect(),
        block_rows: opts.block_rows,
        policy: opts.policy.clone(),
    };
    let feat = Arc::new(featurize.run(env, source, fp)?);
    let d = readers.iter().map(|r| r.dim()).max().unwrap_or(0);
    let quarantine = feat.stream_quarantine.clone().unwrap_or_default();
    finish_stream_fit(env, feat, opts, d, quarantine)
}

/// The shared tail of every streaming fit: K selection from the label
/// census, the embed → cluster → assemble pipeline (the identical driver
/// the in-memory fit runs), and model recovery.
fn finish_stream_fit(
    env: &Env,
    feat: Arc<crate::pipeline::FeatureArtifact>,
    opts: &StreamOpts,
    d: usize,
    quarantine: Quarantine,
) -> Result<StreamFit, ScrbError> {
    let cfg = &env.cfg;
    let n = feat.z.nrows();

    // K: explicit override wins; otherwise the stream's label census.
    let raw_labels = feat.stream_labels.clone().unwrap_or_default();
    let (y, k_true) = compact_labels(&raw_labels);
    let k = opts.k.unwrap_or_else(|| k_true.max(2));

    // Huge N switches the final K-means to the mini-batch path.
    let batch =
        if n >= opts.minibatch_threshold { Some(opts.minibatch_size.min(n)) } else { None };

    // The shared embed → cluster → assemble tail (one driver with the
    // in-memory fit; the streamed substrate's kernels are bit-identical).
    let pipeline = scrb_stages(cfg, k, batch);
    let fitted = pipeline.fit_features(env, feat, &mut ArtifactCache::disabled())?;

    // Recover the concrete model from the shared assembly step (built
    // exactly once; `Assemble::ScRb` always produces an `ScRbModel`).
    let FitResult { model, output } = fitted.result;
    let model = model
        .into_any()
        .downcast::<ScRbModel>()
        .map(|m| *m)
        .map_err(|_| ScrbError::unsupported("SC_RB pipeline must assemble an ScRbModel"))?;

    Ok(StreamFit { model, output, y, k_true, n, d, quarantine })
}
