//! The two-pass streaming fit: Algorithm 2 end-to-end without ever
//! materializing the N×d input.
//!
//! Pass 1 (`stream_stats`) scans the chunks once for the min/span input
//! frame, the row count, and the label census. Pass 2 (`rb_features`)
//! rewinds and featurizes chunk by chunk into the [`BlockEllRb`]
//! substrate. Everything after that — implicit degrees, the iterative
//! SVD, the serving projection, K-means on the serving embedding — runs
//! on O(N·R·4 B + N·K·8 B) state, never on the input.
//!
//! **Bit-exactness:** on the same data and seed, the returned model
//! serializes byte-identically to the in-memory path (`load_libsvm` →
//! min-max normalize → [`crate::cluster::sc_rb::fit`] → store the frame),
//! and the training labels match. Every stage is arranged for it: the
//! streamed stats equal the dense `minmax_params` exactly, the chunked
//! phase-1 dictionaries assign the batch path's first-seen bin ids, the
//! block substrate's kernels are bit-identical to the monolithic
//! [`crate::sparse::EllRb`], and the embedding/K-means stages reuse the
//! very same code paths.

use super::chunk::SparseChunk;
use super::featurize::StreamFeaturizer;
use super::reader::ChunkReader;
use super::stats::stats_pass;
use crate::cluster::{ClusterOutput, Env, MethodInfo};
use crate::data::libsvm::compact_labels;
use crate::eigen::{svds_ws, SolverWorkspace, SvdResult, SvdsOpts};
use crate::error::ScrbError;
use crate::kmeans::{kmeans, AssignEngine, NativeAssign};
use crate::linalg::Mat;
use crate::model::ScRbModel;
use crate::sparse::BlockEllRb;
use crate::util::threads::parallel_rows_mut;
use crate::util::timer::StageTimer;

/// Streaming-fit knobs (the reader's `chunk_rows` is the other one).
#[derive(Clone, Debug)]
pub struct StreamOpts {
    /// Substrate block granularity in rows. Independent of the reader's
    /// chunk size, so the assembled substrate — and everything downstream
    /// — does not depend on how the stream was chunked.
    pub block_rows: usize,
    /// Number of clusters K; `None` = number of distinct labels seen in
    /// the stream (min 2), mirroring how the coordinator derives K from a
    /// dataset.
    pub k: Option<usize>,
    /// Row count at/above which the final K-means switches to mini-batch
    /// (Sculley 2010) over the serving embedding. Below it the full-batch
    /// Lloyd path runs — required for bit-exact agreement with the
    /// in-memory fit.
    pub minibatch_threshold: usize,
    /// Mini-batch size when that path engages.
    pub minibatch_size: usize,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            block_rows: 65_536,
            k: None,
            minibatch_threshold: 200_000,
            minibatch_size: 10_000,
        }
    }
}

/// What a streaming fit returns: the serving model, the training-set
/// clustering output (same shape as the batch fit), and the ground-truth
/// labels collected from the stream for scoring.
pub struct StreamFit {
    pub model: ScRbModel,
    pub output: ClusterOutput,
    /// Ground-truth labels compacted to `0..k_true`, in row order.
    pub y: Vec<usize>,
    /// Number of distinct ground-truth classes in the stream.
    pub k_true: usize,
    /// Rows streamed.
    pub n: usize,
    /// Input dimensionality discovered from the stream.
    pub d: usize,
}

/// Fit SC_RB (Algorithm 2) out-of-core: two chunked passes over `reader`,
/// bounded resident input memory, bit-identical model to the in-memory
/// fit on the same data and seed.
pub fn fit_streaming(
    env: &Env,
    reader: &mut dyn ChunkReader,
    opts: &StreamOpts,
) -> Result<StreamFit, ScrbError> {
    let cfg = &env.cfg;
    let mut timer = StageTimer::new();
    let mut chunk = SparseChunk::new();

    // Pass 1: min/span frame + row and class census.
    let stats = timer.time("stream_stats", || stats_pass(reader, &mut chunk))?;
    if stats.n == 0 {
        return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
    }
    let n = stats.n;
    let d = reader.dim();
    let k = opts.k.unwrap_or_else(|| stats.classes.len().max(2));
    if k == 0 {
        return Err(ScrbError::config("streaming fit needs k >= 1 clusters"));
    }
    let (lo, span) = stats.finalize(d);

    // Pass 2: block-wise RB featurization in the fitted frame.
    reader.reset()?;
    let mut fz = StreamFeaturizer::new(
        cfg.r,
        d,
        cfg.kernel.sigma(),
        cfg.seed,
        lo.clone(),
        span.clone(),
        opts.block_rows,
        n,
    );
    timer.time("rb_features", || -> Result<(), ScrbError> {
        while reader.next_chunk(&mut chunk)? {
            // a column beyond the stats-pass dimension means the stream
            // changed between passes — surface the typed error here
            // rather than an out-of-bounds panic inside the featurizer
            if reader.dim() > d {
                return Err(ScrbError::invalid_input(format!(
                    "stream changed between passes: dimension grew from {d} to {}",
                    reader.dim()
                )));
            }
            fz.push_chunk(&chunk);
        }
        Ok(())
    })?;
    if fz.rows() != n {
        return Err(ScrbError::invalid_input(format!(
            "stream changed between passes: {} rows in the stats pass, {} in the featurize pass",
            n,
            fz.rows()
        )));
    }
    let feats = fz.finish()?;
    let feature_dim = feats.codebook.dim;
    let kappa = feats.kappa;
    let raw_labels = feats.labels;
    let codebook = feats.codebook;

    // Implicit degrees + normalization (Eq. 6), block-iterated.
    let zhat = timer.time("degrees", || {
        let mut z = feats.z;
        let deg = z.implicit_degrees();
        z.normalize_by_degree(&deg);
        z
    });

    // Top-K singular triplets — same solver, workspace, and seed
    // derivation as the batch fit; the block substrate's products are
    // bit-identical to the monolithic one's, so the whole trajectory is.
    let mut sopts = SvdsOpts::new(k, cfg.solver);
    sopts.tol = cfg.svd_tol;
    sopts.max_matvecs = cfg.svd_max_iters;
    let mut solver_ws = SolverWorkspace::new();
    let svd = timer.time("svd", || svds_ws(&zhat, &sopts, cfg.seed ^ 0x5bd5, &mut solver_ws));
    let SvdResult { s, v, stats: svd_stats, .. } = svd;

    // Serving projection P = V·Σ⁻¹/√R — identical arithmetic to the
    // batch fit (near-zero σ directions dropped, not amplified).
    let proj = timer.time("projection", || {
        let mut p = v;
        let s0 = s.first().copied().unwrap_or(0.0).max(1e-300);
        let rsqrt = 1.0 / (cfg.r as f64).sqrt();
        let col_scale: Vec<f64> = s
            .iter()
            .map(|&sj| if sj > 1e-12 * s0 { rsqrt / sj } else { 0.0 })
            .collect();
        for i in 0..p.rows {
            for (pv, cs) in p.row_mut(i).iter_mut().zip(col_scale.iter()) {
                *pv *= *cs;
            }
        }
        p
    });

    let mut model = ScRbModel {
        codebook,
        kernel: cfg.kernel,
        s,
        proj,
        centroids: Mat::zeros(0, 0),
        norm: Some((lo, span)),
    };

    // Training embedding straight from the substrate's bin columns
    // (training bins always hit the codebook), row-for-row bit-identical
    // to `model.transform` on the densified input.
    let emb = timer.time("embed", || embed_blocks(&zhat, &model));

    // K-means on the serving embedding; huge N switches to mini-batch.
    let engine = env.assign_engine();
    let mut kopts = env.kmeans_opts(k);
    if n >= opts.minibatch_threshold {
        kopts.batch = Some(opts.minibatch_size.min(n));
    }
    let km = timer.time("kmeans", || kmeans(&emb, &kopts, engine.as_ref()));
    model.centroids = km.centroids;
    // Final labels via the same f64 argmin the serving path uses — the
    // train-predict == fit-labels contract, exactly as the batch fit.
    let labels: Vec<usize> = timer.time("embed", || {
        let (lab, _) = NativeAssign.assign(&emb, &model.centroids);
        lab.into_iter().map(|l| l as usize).collect()
    });

    let (y, k_true) = compact_labels(&raw_labels);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(svd_stats),
            kappa: Some(kappa),
            inertia: km.inertia,
        },
    };
    Ok(StreamFit { model, output, y, k_true, n, d })
}

/// Serving embedding of every training row, computed from the substrate's
/// own column indices: row i's occupied bins are exactly its R indices,
/// so the gather-sum + row normalization below performs the identical
/// float sequence [`ScRbModel::embed_into`] would after a codebook
/// lookup.
fn embed_blocks(z: &BlockEllRb, model: &ScRbModel) -> Mat {
    let k = model.embed_dim();
    let mut m = Mat::zeros(z.rows, k);
    if z.rows == 0 || k == 0 {
        return m;
    }
    for (blk, w) in z.blocks.iter().zip(z.row_offsets.windows(2)) {
        let out = &mut m.data[w[0] * k..w[1] * k];
        parallel_rows_mut(out, k, |row0, chunk| {
            for (dr, e) in chunk.chunks_mut(k).enumerate() {
                e.fill(0.0);
                for &c in blk.row_indices(row0 + dr) {
                    let p = model.proj.row(c as usize);
                    for (ej, pj) in e.iter_mut().zip(p.iter()) {
                        *ej += *pj;
                    }
                }
                let norm = e.iter().map(|v| v * v).sum::<f64>().sqrt();
                if norm > 1e-300 {
                    let inv = 1.0 / norm;
                    for v in e.iter_mut() {
                        *v *= inv;
                    }
                }
            }
        });
    }
    m
}
