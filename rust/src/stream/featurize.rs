//! Block-wise RB featurization: Algorithm 1 run one chunk at a time.
//!
//! Phase 1 (bin discovery) is *incremental*: each grid keeps a growable
//! open-addressing [`BinTable`] dictionary ([`BinTable::get_or_assign`])
//! that later chunks keep extending, plus the first-seen hash list and
//! per-bin collision counts. Local bin ids are therefore assigned in
//! global first-seen row order — exactly the ids the batch path's
//! per-grid `HashMap` produces — which makes the featurization invariant
//! to the chunk size, including the phase-1 column assignment.
//!
//! Phase 2 (assembly) cannot finish until the stream ends: a late row can
//! add new bins to an early grid, shifting every later grid's global
//! column offset. So the featurizer accumulates each row's R *local* ids
//! into fixed-row-count substrate blocks (`block_rows`, independent of
//! the reader's chunk size) and converts local→global in place at
//! [`StreamFeaturizer::finish`], yielding a [`BlockEllRb`] plus the
//! serving [`RbCodebook`]. Resident memory: one `chunk_rows × d` dense
//! scratch (the normalized rows being binned) + the N×R×4 B local-id
//! blocks — which *are* the final substrate indices, not an extra copy.
//!
//! Steady state allocates nothing per chunk beyond the block being built:
//! the dense scratch, per-grid local-id buffers, and the chunk buffers
//! are all reused, and dictionary growth happens only when new bins
//! appear (enforced by `tests/alloc.rs`).

use super::chunk::SparseChunk;
use crate::error::ScrbError;
use crate::rb::codebook::BinTable;
use crate::rb::features::codebook_table;
use crate::rb::{sample_grids, Grid, RbCodebook};
use crate::sparse::{BlockEllRb, EllRb};
use crate::util::threads::{num_threads, parallel_chunks_mut, parallel_rows_mut_in};

/// Per-grid incremental phase-1 state.
struct GridState {
    /// Growable bin-hash → local-id dictionary.
    dict: BinTable,
    /// Bin hash of each local id, in first-seen (= id) order.
    hashes: Vec<u64>,
    /// Collision count per local id (κ needs the max).
    counts: Vec<usize>,
    /// This chunk's local ids, one per chunk row (reused buffer).
    locals: Vec<u32>,
}

/// What a completed featurize pass yields.
pub struct StreamFeatures {
    /// Sparse feature matrix Z on the block substrate, nnz = N·R, all
    /// values 1/√R.
    pub z: BlockEllRb,
    /// Serving codebook (grids + bin→column tables), byte-identical to
    /// what a batch [`crate::rb::rb_features_with_codebook`] fit on the
    /// same (normalized) data produces.
    pub codebook: RbCodebook,
    /// Per-grid number of non-empty bins.
    pub bins_per_grid: Vec<usize>,
    /// κ estimate (Definition 1), same estimator as the batch path.
    pub kappa: f64,
    /// Raw labels in row order (compact with
    /// [`crate::data::libsvm::compact_labels`]).
    pub labels: Vec<i64>,
}

/// Incremental RB featurizer: feed normalized-frame chunks with
/// [`StreamFeaturizer::push_chunk`], then [`StreamFeaturizer::finish`].
pub struct StreamFeaturizer {
    r: usize,
    d: usize,
    sigma: f64,
    seed: u64,
    /// Input frame applied while densifying (the stats-pass result).
    lo: Vec<f64>,
    span: Vec<f64>,
    /// Normalized value of an implicit zero, per column: `(0 − lo)/span`.
    zero_row: Vec<f64>,
    grids: Vec<Grid>,
    states: Vec<GridState>,
    /// Densified+normalized chunk scratch, `chunk_rows × d` (sized by the
    /// largest chunk seen, i.e. once).
    dense: Vec<f64>,
    /// Substrate block granularity in rows (independent of chunk size, so
    /// block boundaries — and everything downstream — don't depend on how
    /// the stream was chunked).
    block_rows: usize,
    /// Completed and in-progress blocks of *local* ids, row-major n×R.
    blocks: Vec<Vec<u32>>,
    n_rows: usize,
    /// Row-count hint (from the stats pass) sizing the label buffer and
    /// each block exactly.
    expected_rows: usize,
    labels: Vec<i64>,
    /// Worker-thread budget for the internal parallel sections. Defaults
    /// to the process-wide pool; the sharded fit divides the pool across
    /// K concurrent featurizers so shards don't oversubscribe the cores.
    threads: usize,
}

impl StreamFeaturizer {
    /// Start a featurize pass: `r` grids over `d` input dimensions with
    /// bandwidth `sigma`, deterministic in `seed` (the same grids the
    /// batch path samples). `(lo, span)` is the input frame from the
    /// stats pass; `expected_rows` is the stats-pass row count (0 if
    /// unknown — only buffer pre-sizing depends on it).
    pub fn new(
        r: usize,
        d: usize,
        sigma: f64,
        seed: u64,
        lo: Vec<f64>,
        span: Vec<f64>,
        block_rows: usize,
        expected_rows: usize,
    ) -> StreamFeaturizer {
        assert!(r >= 1, "need at least one grid");
        assert!(block_rows >= 1, "need at least one row per block");
        assert_eq!(lo.len(), d, "one min per dimension");
        assert_eq!(span.len(), d, "one span per dimension");
        let zero_row: Vec<f64> =
            lo.iter().zip(span.iter()).map(|(&l, &s)| (0.0 - l) / s).collect();
        let grids = sample_grids(r, d, sigma, seed);
        let states = (0..r)
            .map(|_| GridState {
                dict: BinTable::new(),
                hashes: Vec::new(),
                counts: Vec::new(),
                locals: Vec::new(),
            })
            .collect();
        StreamFeaturizer {
            r,
            d,
            sigma,
            seed,
            lo,
            span,
            zero_row,
            grids,
            states,
            dense: Vec::new(),
            block_rows,
            blocks: Vec::new(),
            n_rows: 0,
            expected_rows,
            labels: Vec::with_capacity(expected_rows),
            threads: num_threads(),
        }
    }

    /// Cap the internal parallel sections at `threads` workers (at least
    /// one). The binning arithmetic is thread-count-invariant — this only
    /// changes how work is scheduled, never what is computed.
    pub fn with_threads(mut self, threads: usize) -> StreamFeaturizer {
        self.threads = threads.max(1);
        self
    }

    /// Rows featurized so far.
    pub fn rows(&self) -> usize {
        self.n_rows
    }

    /// Bin one chunk: densify+normalize into the reusable scratch, extend
    /// every grid's dictionary (parallel over grids, mirroring the batch
    /// path), and append the rows' local ids to the current block.
    pub fn push_chunk(&mut self, chunk: &SparseChunk) {
        self.push_chunk_from(chunk, 0)
    }

    /// Bin the rows of `chunk` from row `start` on. This is the resume
    /// skip-forward entry point: after a checkpoint restore, the replayed
    /// chunk straddling the `rows_done` boundary is pushed from its first
    /// unseen row, and every earlier chunk is skipped whole.
    pub fn push_chunk_from(&mut self, chunk: &SparseChunk, start: usize) {
        let rows = chunk.rows().saturating_sub(start);
        if rows == 0 {
            return;
        }
        let d = self.d;
        // 1. densify + normalize (parallel over rows; same arithmetic the
        //    batch path's apply_minmax performs on the dense matrix)
        if self.dense.len() < rows * d.max(1) {
            self.dense.resize(rows * d.max(1), 0.0);
        }
        if d > 0 {
            let (lo, span, zero_row) = (&self.lo, &self.span, &self.zero_row);
            let scratch = &mut self.dense[..rows * d];
            parallel_rows_mut_in(scratch, d, self.threads, |row0, out| {
                for (dr, orow) in out.chunks_mut(d).enumerate() {
                    orow.copy_from_slice(zero_row);
                    let (cols, vals) = chunk.row(start + row0 + dr);
                    for (&c, &v) in cols.iter().zip(vals.iter()) {
                        let c = c as usize;
                        orow[c] = (v - lo[c]) / span[c];
                    }
                }
            });
        }
        // 2. phase 1, parallel over grids: each worker owns a contiguous
        //    run of grids and extends their dictionaries independently
        let dense = &self.dense;
        let grids = &self.grids;
        parallel_chunks_mut(&mut self.states, self.threads, |start, slice| {
            for (k, st) in slice.iter_mut().enumerate() {
                let grid = &grids[start + k];
                st.locals.clear();
                st.locals.reserve(rows);
                for i in 0..rows {
                    let h = grid.bin_hash(&dense[i * d..(i + 1) * d]);
                    let id = st.dict.get_or_assign(h);
                    if id as usize == st.counts.len() {
                        st.counts.push(0);
                        st.hashes.push(h);
                    }
                    st.counts[id as usize] += 1;
                    st.locals.push(id);
                }
            }
        });
        // 3. interleave the chunk's local ids into the block being built
        //    (row-major n×R — already the final substrate layout, pending
        //    only the local→global column shift at finish)
        let block_cap = self.block_rows * self.r;
        for dr in 0..rows {
            let block_full = match self.blocks.last() {
                Some(b) => b.len() == block_cap,
                None => true,
            };
            if block_full {
                let remaining = self.expected_rows.saturating_sub(self.n_rows + dr);
                let reserve_rows = self.block_rows.min(remaining.max(1));
                self.blocks.push(Vec::with_capacity(reserve_rows * self.r));
            }
            let block = self.blocks.last_mut().unwrap();
            for st in self.states.iter() {
                block.push(st.locals[dr]);
            }
        }
        self.labels.extend_from_slice(&chunk.labels[start..]);
        self.n_rows += rows;
    }

    // ---- checkpoint plumbing (used by `super::checkpoint`) -------------
    //
    // The full pass-2 state is (per-grid first-seen hashes + counts,
    // local-id blocks, labels): the dictionary is *derived* — replaying
    // the stored hashes through `get_or_assign` in id order reproduces the
    // identical dense first-seen mapping — and `finish` resamples grids
    // deterministically from the seed, so nothing else needs persisting.

    /// Number of grids R (the checkpoint writer iterates `grid_state`).
    pub(crate) fn grid_count(&self) -> usize {
        self.r
    }

    /// Per-grid `(first-seen bin hashes, collision counts)`, id order.
    pub(crate) fn grid_state(&self, j: usize) -> (&[u64], &[usize]) {
        (&self.states[j].hashes, &self.states[j].counts)
    }

    /// Completed and in-progress local-id blocks, row-major n×R.
    pub(crate) fn state_blocks(&self) -> &[Vec<u32>] {
        &self.blocks
    }

    pub(crate) fn state_labels(&self) -> &[i64] {
        &self.labels
    }

    /// Overwrite this (fresh) featurizer with checkpointed pass-2 state:
    /// dictionaries are rebuilt by replaying the stored hashes, so the
    /// restored featurizer continues bit-identically to one that never
    /// stopped.
    pub(crate) fn load_state(
        &mut self,
        grids: Vec<(Vec<u64>, Vec<usize>)>,
        blocks: Vec<Vec<u32>>,
        labels: Vec<i64>,
    ) -> Result<(), ScrbError> {
        if grids.len() != self.r {
            return Err(ScrbError::checkpoint(format!(
                "state has {} grids, expected {}",
                grids.len(),
                self.r
            )));
        }
        if self.n_rows != 0 {
            return Err(ScrbError::checkpoint("state can only be loaded into a fresh featurizer"));
        }
        let n_rows = labels.len();
        let block_slots: usize = blocks.iter().map(|b| b.len()).sum();
        if block_slots != n_rows * self.r {
            return Err(ScrbError::checkpoint(format!(
                "block data holds {} ids, expected {} ({} rows × {} grids)",
                block_slots,
                n_rows * self.r,
                n_rows,
                self.r
            )));
        }
        for (st, (hashes, counts)) in self.states.iter_mut().zip(grids) {
            if hashes.len() != counts.len() {
                return Err(ScrbError::checkpoint("per-grid hash/count lengths disagree"));
            }
            for &h in &hashes {
                st.dict.get_or_assign(h);
            }
            if st.dict.len() != hashes.len() {
                return Err(ScrbError::checkpoint("duplicate bin hashes in checkpoint state"));
            }
            st.hashes = hashes;
            st.counts = counts;
        }
        self.blocks = blocks;
        self.labels = labels;
        self.n_rows = n_rows;
        Ok(())
    }

    /// Tear the featurizer down into its raw pass-2 state — per-grid
    /// `(first-seen bin hashes, collision counts)`, local-id blocks, and
    /// labels — without resolving global columns. This is the shard-worker
    /// exit: a shard's local ids stay local until the
    /// [`crate::shard::CodebookMerger`] unions the per-shard dictionaries
    /// and relabels. Unlike [`StreamFeaturizer::finish`], zero rows are
    /// fine here (an empty shard merges as a no-op).
    pub(crate) fn into_state(self) -> (Vec<(Vec<u64>, Vec<usize>)>, Vec<Vec<u32>>, Vec<i64>) {
        let grids = self.states.into_iter().map(|st| (st.hashes, st.counts)).collect();
        (grids, self.blocks, self.labels)
    }

    /// Finish the pass: resolve global column offsets, shift every block
    /// in place, and assemble the [`BlockEllRb`] + serving codebook.
    pub fn finish(self) -> Result<StreamFeatures, ScrbError> {
        let StreamFeaturizer {
            r,
            d,
            sigma,
            seed,
            grids,
            states,
            blocks,
            n_rows,
            labels,
            threads,
            ..
        } = self;
        if n_rows == 0 {
            return Err(ScrbError::invalid_input("empty dataset"));
        }
        // global column offsets: grid j owns [off_j, off_j + n_bins_j)
        let mut offsets = Vec::with_capacity(r + 1);
        offsets.push(0usize);
        for st in &states {
            offsets.push(offsets.last().unwrap() + st.dict.len());
        }
        let d_total = *offsets.last().unwrap();
        if d_total >= u32::MAX as usize {
            return Err(ScrbError::invalid_input("feature dimension overflows u32"));
        }
        // κ (Definition 1), same estimator and summation order as the
        // batch path
        let kappa = states
            .iter()
            .map(|st| {
                let max_count = st.counts.iter().copied().max().unwrap_or(0);
                if max_count > 0 {
                    n_rows as f64 / max_count as f64
                } else {
                    1.0
                }
            })
            .sum::<f64>()
            / r as f64;
        // local → global in place (div-free running grid cursor), then
        // each block becomes its own EllRb over the full column space
        let val = 1.0 / (r as f64).sqrt();
        let ell_blocks: Vec<EllRb> = blocks
            .into_iter()
            .map(|mut block| {
                parallel_chunks_mut(&mut block, threads, |start, chunk| {
                    let mut j = start % r;
                    for slot in chunk.iter_mut() {
                        *slot = (offsets[j] + *slot as usize) as u32;
                        j += 1;
                        if j == r {
                            j = 0;
                        }
                    }
                });
                let rows_b = block.len() / r;
                EllRb::new(rows_b, d_total, r, block, vec![val; rows_b])
            })
            .collect();
        let z = BlockEllRb::from_blocks(ell_blocks);
        let bins_per_grid: Vec<usize> = states.iter().map(|st| st.dict.len()).collect();
        // serving codebook, rebuilt in first-seen order at a deterministic
        // capacity — byte-identical to the batch fit's codebook
        let tables: Vec<BinTable> = states
            .iter()
            .enumerate()
            .map(|(j, st)| codebook_table(&st.hashes, offsets[j]))
            .collect();
        let codebook = RbCodebook { r, d_in: d, sigma, seed, dim: d_total, grids, tables };
        Ok(StreamFeatures { z, codebook, bins_per_grid, kappa, labels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rb::rb_features_with_codebook;
    use crate::util::rng::Pcg;

    /// Push `x` (already in its final frame) through the featurizer in
    /// `chunk_rows`-sized chunks with an identity min/span frame.
    fn featurize_chunked(x: &Mat, r: usize, sigma: f64, seed: u64, chunk_rows: usize) -> StreamFeatures {
        let d = x.cols;
        let mut fz = StreamFeaturizer::new(
            r,
            d,
            sigma,
            seed,
            vec![0.0; d],
            vec![1.0; d],
            1 << 20,
            x.rows,
        );
        let mut chunk = SparseChunk::new();
        let mut i = 0;
        while i < x.rows {
            chunk.clear();
            let hi = (i + chunk_rows).min(x.rows);
            for row in i..hi {
                chunk.begin_row(0);
                for (j, &v) in x.row(row).iter().enumerate() {
                    chunk.push_entry(j as u32, v);
                }
                chunk.end_row();
            }
            fz.push_chunk(&chunk);
            i = hi;
        }
        fz.finish().unwrap()
    }

    #[test]
    fn matches_batch_featurization_exactly() {
        let mut rng = Pcg::seed(401);
        let n = 120;
        let x = Mat::from_vec(n, 4, (0..n * 4).map(|_| rng.f64()).collect());
        let (batch, batch_cb) = rb_features_with_codebook(&x, 16, 0.5, 9);
        let streamed = featurize_chunked(&x, 16, 0.5, 9, 13);
        assert_eq!(streamed.z.rows, n);
        assert_eq!(streamed.z.to_ell(), batch.z, "substrate must match bitwise");
        assert_eq!(streamed.bins_per_grid, batch.bins_per_grid);
        assert_eq!(streamed.kappa, batch.kappa);
        // codebooks identical down to the serialized table layout
        assert_eq!(streamed.codebook.dim, batch_cb.dim);
        for (a, b) in streamed.codebook.tables.iter().zip(batch_cb.tables.iter()) {
            let av: Vec<(u64, u32)> = a.iter().collect();
            let bv: Vec<(u64, u32)> = b.iter().collect();
            assert_eq!(av, bv);
        }
    }

    #[test]
    fn invariant_to_chunk_size() {
        let mut rng = Pcg::seed(402);
        let n = 61;
        let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.f64()).collect());
        let reference = featurize_chunked(&x, 8, 0.4, 3, n);
        for chunk_rows in [1usize, 7, 64] {
            let f = featurize_chunked(&x, 8, 0.4, 3, chunk_rows);
            assert_eq!(f.z, reference.z, "chunk_rows={chunk_rows}");
            assert_eq!(f.bins_per_grid, reference.bins_per_grid);
            assert_eq!(f.kappa, reference.kappa);
        }
    }

    #[test]
    fn empty_pass_is_an_error() {
        let fz = StreamFeaturizer::new(4, 2, 1.0, 1, vec![0.0; 2], vec![1.0; 2], 64, 0);
        assert!(fz.finish().is_err());
    }

    fn mat_chunk(x: &Mat, lo: usize, hi: usize) -> SparseChunk {
        let mut chunk = SparseChunk::new();
        for row in lo..hi {
            chunk.begin_row(row as i64);
            for (j, &v) in x.row(row).iter().enumerate() {
                chunk.push_entry(j as u32, v);
            }
            chunk.end_row();
        }
        chunk
    }

    #[test]
    fn push_chunk_from_skips_the_prefix() {
        let mut rng = Pcg::seed(403);
        let n = 40;
        let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.f64()).collect());
        let mk = || StreamFeaturizer::new(8, 3, 0.4, 3, vec![0.0; 3], vec![1.0; 3], 16, n);
        let mut whole = mk();
        whole.push_chunk(&mat_chunk(&x, 0, n));
        let mut resumed = mk();
        resumed.push_chunk(&mat_chunk(&x, 0, 25));
        // straddling chunk [20, 40): first 5 rows already seen
        resumed.push_chunk_from(&mat_chunk(&x, 20, n), 5);
        assert_eq!(resumed.rows(), n);
        let (a, b) = (whole.finish().unwrap(), resumed.finish().unwrap());
        assert_eq!(a.z, b.z);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.kappa, b.kappa);
    }

    #[test]
    fn state_roundtrip_continues_bit_identically() {
        let mut rng = Pcg::seed(404);
        let n = 50;
        let x = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.f64()).collect());
        let mk = || StreamFeaturizer::new(6, 2, 0.3, 11, vec![0.0; 2], vec![1.0; 2], 8, n);
        // uninterrupted reference
        let mut whole = mk();
        whole.push_chunk(&mat_chunk(&x, 0, n));
        // featurize half, snapshot, restore into a fresh featurizer
        let mut half = mk();
        half.push_chunk(&mat_chunk(&x, 0, 23));
        let grids: Vec<(Vec<u64>, Vec<usize>)> = (0..6)
            .map(|j| {
                let (h, c) = half.grid_state(j);
                (h.to_vec(), c.to_vec())
            })
            .collect();
        let blocks: Vec<Vec<u32>> = half.state_blocks().to_vec();
        let labels = half.state_labels().to_vec();
        let mut resumed = mk();
        resumed.load_state(grids, blocks, labels).unwrap();
        assert_eq!(resumed.rows(), 23);
        resumed.push_chunk(&mat_chunk(&x, 23, n));
        let (a, b) = (whole.finish().unwrap(), resumed.finish().unwrap());
        assert_eq!(a.z, b.z, "restored pass-2 state must continue bit-identically");
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.bins_per_grid, b.bins_per_grid);
        assert_eq!(a.kappa, b.kappa);
    }

    #[test]
    fn load_state_rejects_inconsistent_state() {
        let mk = || StreamFeaturizer::new(2, 1, 1.0, 1, vec![0.0], vec![1.0], 8, 0);
        // wrong grid count
        let mut fz = mk();
        assert!(fz.load_state(vec![(vec![1], vec![1])], Vec::new(), Vec::new()).is_err());
        // block slots disagree with label count
        let mut fz = mk();
        assert!(fz
            .load_state(vec![(vec![1], vec![1]); 2], vec![vec![0, 0]], vec![0, 0])
            .is_err());
        // duplicate hashes cannot rebuild a dictionary
        let mut fz = mk();
        assert!(fz
            .load_state(vec![(vec![5, 5], vec![1, 1]); 2], vec![vec![0, 1, 0, 1]], vec![0, 0])
            .is_err());
    }
}
