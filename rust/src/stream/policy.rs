//! Ingest fault policy: what a streamed fit does when the input is dirty
//! or the reader hiccups.
//!
//! Three failure classes get three distinct treatments:
//!
//! - **Malformed records** (unparseable lines) and **non-finite records**
//!   (NaN/Inf labels or values): governed by [`OnBadRecord`]. `Strict`
//!   (the default) surfaces the first offender as a located
//!   [`ScrbError::BadRecord`]; `Quarantine` skips the row, counts it, and
//!   keeps a capped sample of offenders with file/line/byte context in a
//!   [`Quarantine`] report. Skipping is per *line*, deterministically, so
//!   a row dropped in the stats pass is dropped again in the featurize
//!   pass — the min/span frame, row count, and label census stay
//!   consistent across the two passes.
//! - **Transient I/O errors** ([`ScrbError::Transient`]): retried with
//!   bounded exponential backoff by [`GuardedReader`], whatever the
//!   record policy; only after [`IngestPolicy::max_retries`] consecutive
//!   failures does the error surface (with its attempt count).
//!
//! [`GuardedReader`] is the enforcement point the fit driver wraps every
//! reader in: retry loop on top, then a non-finite screen over the parsed
//! chunk (rows can acquire NaN/Inf *after* parsing — e.g. an injected
//! fault from [`super::FaultyReader`] — so the parser-level checks alone
//! are not sufficient).
//!
//! [`ScrbError::BadRecord`]: crate::error::ScrbError::BadRecord
//! [`ScrbError::Transient`]: crate::error::ScrbError::Transient

use super::chunk::SparseChunk;
use super::reader::ChunkReader;
use crate::error::{RecordError, RecordKind, ScrbError};

/// What to do with a malformed or non-finite input record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OnBadRecord {
    /// Fail the fit on the first bad record with a located, typed error.
    #[default]
    Strict,
    /// Skip bad records, count them, and sample offenders into the
    /// [`Quarantine`] report.
    Quarantine,
}

impl OnBadRecord {
    /// Parse the CLI spelling (`--on-bad-record strict|quarantine`).
    pub fn parse(s: &str) -> Result<OnBadRecord, ScrbError> {
        match s {
            "strict" => Ok(OnBadRecord::Strict),
            "quarantine" => Ok(OnBadRecord::Quarantine),
            other => Err(ScrbError::config(format!(
                "unknown bad-record policy '{other}' (strict|quarantine)"
            ))),
        }
    }
}

/// Fault-handling knobs for streamed ingestion.
#[derive(Clone, Debug)]
pub struct IngestPolicy {
    pub on_bad_record: OnBadRecord,
    /// Max offender samples kept in the quarantine report (counts are
    /// always exact; only the per-record context is capped).
    pub sample_cap: usize,
    /// Consecutive transient-failure retries before giving up.
    pub max_retries: u32,
    /// Base backoff between retries; doubles per attempt (0 = no sleep,
    /// what tests use).
    pub retry_backoff_ms: u64,
}

impl Default for IngestPolicy {
    fn default() -> Self {
        IngestPolicy {
            on_bad_record: OnBadRecord::Strict,
            sample_cap: 16,
            max_retries: 3,
            retry_backoff_ms: 20,
        }
    }
}

/// What quarantine-mode ingestion skipped (and what the retry layer
/// absorbed) over one pass. Counts are exact; `samples` is capped at
/// [`IngestPolicy::sample_cap`].
#[derive(Clone, Debug, Default)]
pub struct Quarantine {
    /// Rows skipped because they could not be parsed.
    pub malformed: usize,
    /// Rows skipped because they carried NaN/Inf labels or values.
    pub non_finite: usize,
    /// Transient reader errors absorbed by the retry loop.
    pub retries: usize,
    /// Capped sample of skipped records with full source context.
    pub samples: Vec<RecordError>,
}

impl Quarantine {
    /// Total rows skipped (malformed + non-finite).
    pub fn skipped(&self) -> usize {
        self.malformed + self.non_finite
    }

    /// One-line report for logs and the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{} rows quarantined ({} malformed, {} non-finite), {} transient retries",
            self.skipped(),
            self.malformed,
            self.non_finite,
            self.retries
        )
    }

    pub(crate) fn record(&mut self, rec: RecordError, cap: usize) {
        match rec.kind {
            RecordKind::Malformed => self.malformed += 1,
            RecordKind::NonFinite => self.non_finite += 1,
        }
        if self.samples.len() < cap {
            self.samples.push(rec);
        }
    }

    /// Fold another layer's per-pass counts into this report.
    pub(crate) fn absorb(&mut self, other: &Quarantine) {
        self.malformed += other.malformed;
        self.non_finite += other.non_finite;
        self.retries += other.retries;
        for s in &other.samples {
            self.samples.push(s.clone());
        }
    }

    pub(crate) fn clear(&mut self) {
        self.malformed = 0;
        self.non_finite = 0;
        self.retries = 0;
        self.samples.clear();
    }
}

/// The fault-policy enforcement decorator the streaming fit wraps every
/// reader in: bounded retry with backoff for [`ScrbError::Transient`]
/// failures, plus a non-finite screen over each parsed chunk (values that
/// went bad *after* parsing — injected faults, adapter bugs — which the
/// parsers cannot see).
///
/// Line-level handling of malformed records happens below this layer,
/// inside the text readers (only the line pump can skip a bad line and
/// keep going); `GuardedReader` pushes the policy down via
/// [`ChunkReader::set_policy`] and merges the reader's per-pass counts
/// into [`GuardedReader::report`].
///
/// [`ScrbError::Transient`]: crate::error::ScrbError::Transient
pub struct GuardedReader<'a> {
    inner: &'a mut dyn ChunkReader,
    policy: IngestPolicy,
    /// This layer's per-pass skips (non-finite screening) and the
    /// cumulative retry count.
    screen: Quarantine,
}

impl<'a> GuardedReader<'a> {
    pub fn new(inner: &'a mut dyn ChunkReader, policy: IngestPolicy) -> GuardedReader<'a> {
        inner.set_policy(&policy);
        GuardedReader { inner, policy, screen: Quarantine::default() }
    }

    /// The merged quarantine report for the most recent pass: this
    /// layer's non-finite skips and retries plus the wrapped reader's
    /// line-level skips.
    pub fn report(&self) -> Quarantine {
        let mut q = self.screen.clone();
        if let Some(inner_q) = self.inner.quarantine() {
            q.absorb(inner_q);
        }
        q
    }
}

impl ChunkReader for GuardedReader<'_> {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        let mut attempts = 0u32;
        let more = loop {
            match self.inner.next_chunk(chunk) {
                Ok(m) => break m,
                Err(ScrbError::Transient { msg, .. }) => {
                    attempts += 1;
                    if attempts > self.policy.max_retries {
                        return Err(ScrbError::Transient { msg, attempts });
                    }
                    self.screen.retries += 1;
                    let ms = self
                        .policy
                        .retry_backoff_ms
                        .saturating_mul(1u64 << (attempts - 1).min(6));
                    if ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                }
                Err(e) => return Err(e),
            }
        };
        // fast path: a clean chunk costs one linear scan over the values
        if chunk.values.iter().all(|v| v.is_finite()) {
            return Ok(more);
        }
        let has_meta = chunk.meta.len() == chunk.rows();
        let mut bad = vec![false; chunk.rows()];
        for i in 0..chunk.rows() {
            let (_, vals) = chunk.row(i);
            let Some(&v) = vals.iter().find(|v| !v.is_finite()) else { continue };
            bad[i] = true;
            let m = if has_meta { chunk.meta[i] } else { Default::default() };
            let rec = RecordError {
                file: self.inner.source_name().to_string(),
                line: m.line,
                byte: m.byte,
                token: format!("{v}"),
                reason: "non-finite value".to_string(),
                kind: RecordKind::NonFinite,
            };
            match self.policy.on_bad_record {
                OnBadRecord::Strict => return Err(ScrbError::bad_record(rec)),
                OnBadRecord::Quarantine => self.screen.record(rec, self.policy.sample_cap),
            }
        }
        chunk.retain_rows(|i| !bad[i]);
        // `more` is the inner reader's verdict: the chunk may now be
        // empty even mid-stream (every row quarantined) — consumers must
        // key on the return value, not on emptiness
        Ok(more)
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.inner.reset()?;
        // per-pass skip counts restart (the same rows are skipped again in
        // the next pass); the retry count stays cumulative across passes
        let retries = self.screen.retries;
        self.screen.clear();
        self.screen.retries = retries;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn source_name(&self) -> &str {
        self.inner.source_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::LibsvmChunks;

    #[test]
    fn policy_parses_and_defaults_to_strict() {
        assert_eq!(OnBadRecord::parse("strict").unwrap(), OnBadRecord::Strict);
        assert_eq!(OnBadRecord::parse("quarantine").unwrap(), OnBadRecord::Quarantine);
        assert!(OnBadRecord::parse("lenient").is_err());
        assert_eq!(IngestPolicy::default().on_bad_record, OnBadRecord::Strict);
    }

    #[test]
    fn guarded_reader_passes_clean_chunks_through() {
        let text = b"1 1:0.5 2:1.5\n2 1:1.0\n".to_vec();
        let mut inner = LibsvmChunks::from_bytes(text, 8);
        let mut g = GuardedReader::new(&mut inner, IngestPolicy::default());
        let mut chunk = SparseChunk::new();
        assert!(g.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 2);
        assert!(!g.next_chunk(&mut chunk).unwrap());
        assert_eq!(g.report().skipped(), 0);
    }

    #[test]
    fn quarantine_summary_counts_both_kinds() {
        let mut q = Quarantine::default();
        let rec = |kind| RecordError {
            file: "f".into(),
            line: 1,
            byte: 0,
            token: "t".into(),
            reason: "r".into(),
            kind,
        };
        q.record(rec(RecordKind::Malformed), 1);
        q.record(rec(RecordKind::NonFinite), 1);
        q.record(rec(RecordKind::NonFinite), 1);
        assert_eq!(q.skipped(), 3);
        assert_eq!(q.samples.len(), 1, "sample cap respected, counts exact");
        assert!(q.summary().contains("1 malformed"));
        assert!(q.summary().contains("2 non-finite"));
    }
}
