//! Checkpoint/resume for the streaming fit: kill the process mid-pass,
//! rerun with `--resume`, get the **byte-identical** model an
//! uninterrupted fit would have produced.
//!
//! # What is persisted
//!
//! The two-pass fit has exactly two pieces of durable state:
//!
//! 1. **Stats frame** (`stats.bin`) — the pass-1 result: row count, input
//!    dimension, and the per-column min/span frame. Written once when the
//!    stats pass completes; a resumed fit that finds it skips pass 1
//!    entirely.
//! 2. **Featurize state** — the incremental pass-2 state of the
//!    [`super::StreamFeaturizer`]: per-grid first-seen bin hashes and
//!    collision counts, the accumulated local-id blocks, and the labels.
//!    The bin *dictionaries* are derived state (replaying the hashes
//!    through `get_or_assign` in id order rebuilds the identical dense
//!    mapping) and the grids are resampled from the seed, so nothing else
//!    is needed for a bit-identical continuation.
//!
//! Completed substrate blocks are immutable once full, so each is written
//! to its own `block_NNNN.bin` exactly once; the frequently-rewritten
//! `state.bin` carries only the per-grid tables, the labels, and the one
//! in-progress block. Every file is written tmp-then-rename (atomic on
//! POSIX) and ends with the same FNV-1a checksum footer the v2 model
//! format uses — a checkpoint torn by the very crash it exists to survive
//! is detected on load and reported as a typed [`ScrbError::Checkpoint`],
//! never replayed into a silently-wrong model.
//!
//! # Compatibility fingerprint
//!
//! Resuming under different parameters (R, σ, seed, block size — or
//! different data: n, d) would splice incompatible state; every file
//! therefore embeds a fingerprint of those parameters and `load_*`
//! rejects mismatches with a typed error telling the user to delete the
//! checkpoint directory or rerun with the original flags.
//!
//! [`ScrbError::Checkpoint`]: crate::error::ScrbError::Checkpoint

use super::featurize::StreamFeaturizer;
use crate::error::ScrbError;
use crate::model::persist::{split_checksummed, ByteReader, ByteWriter};
use crate::pipeline::Fingerprint;
use std::path::{Path, PathBuf};

const STATS_MAGIC: &[u8; 8] = b"SCRBCKS1";
const STATE_MAGIC: &[u8; 8] = b"SCRBCKT1";
const BLOCK_MAGIC: &[u8; 8] = b"SCRBCKB1";

/// Checkpointing knobs for a streamed fit (`--checkpoint DIR` at the CLI).
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    /// Directory holding the checkpoint files (created if missing).
    pub dir: String,
    /// Featurized-row cadence between state saves.
    pub every_rows: usize,
    /// Resume from existing checkpoint files instead of starting fresh
    /// (`--resume`). Without it an existing checkpoint is overwritten.
    pub resume: bool,
}

impl CheckpointCfg {
    pub fn new(dir: impl Into<String>) -> CheckpointCfg {
        CheckpointCfg { dir: dir.into(), every_rows: 262_144, resume: false }
    }
}

/// The restored pass-1 result.
pub(crate) struct StatsCkpt {
    pub n: usize,
    pub d: usize,
    pub lo: Vec<f64>,
    pub span: Vec<f64>,
}

/// The restored pass-2 featurizer state (see
/// [`StreamFeaturizer::load_state`]).
pub(crate) struct StateCkpt {
    pub grids: Vec<(Vec<u64>, Vec<usize>)>,
    pub blocks: Vec<Vec<u32>>,
    pub labels: Vec<i64>,
}

/// Driver-side checkpoint writer/loader for one streaming fit.
pub(crate) struct Checkpointer {
    dir: PathBuf,
    /// Fingerprint over the fit parameters (R, σ, seed, block_rows).
    fp_params: u64,
    /// `fp_params` extended with the stats-pass result (d, n); guards the
    /// pass-2 state files. Zero until [`Checkpointer::bind`].
    fp_state: u64,
    every_rows: usize,
    resume: bool,
    /// Rows featurized at the last state save.
    last_saved_rows: usize,
    /// Full blocks already persisted to their own files.
    blocks_written: usize,
}

/// Fingerprint of the fit parameters a checkpoint is only valid under.
pub(crate) fn ckpt_fingerprint(r: usize, sigma: f64, seed: u64, block_rows: usize) -> u64 {
    Fingerprint::new("stream/ckpt")
        .usize(r)
        .f64(sigma)
        .u64(seed)
        .usize(block_rows)
        .finish()
}

fn write_atomic(dir: &Path, name: &str, w: ByteWriter) -> Result<(), ScrbError> {
    let bytes = w.finish_with_checksum();
    let tmp = dir.join(format!("{name}.tmp"));
    let path = dir.join(name);
    std::fs::write(&tmp, &bytes).map_err(|e| ScrbError::io(tmp.display().to_string(), e))?;
    std::fs::rename(&tmp, &path).map_err(|e| ScrbError::io(path.display().to_string(), e))
}

/// Read a checkpoint file if it exists, verifying its checksum footer and
/// magic. `Ok(None)` = no such file (nothing to resume).
fn read_verified(dir: &Path, name: &str, magic: &[u8; 8]) -> Result<Option<Vec<u8>>, ScrbError> {
    let path = dir.join(name);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(ScrbError::io(path.display().to_string(), e)),
    };
    let payload = split_checksummed(&bytes).ok_or_else(|| {
        ScrbError::checkpoint(format!(
            "'{}' is corrupt or truncated (checksum mismatch); delete the checkpoint \
             directory and rerun",
            path.display()
        ))
    })?;
    let mut r = ByteReader::new(payload);
    if r.bytes(8).map_err(|_| bad_file(&path, "too short"))? != &magic[..] {
        return Err(bad_file(&path, "wrong file type (bad magic)"));
    }
    Ok(Some(payload[8..].to_vec()))
}

fn bad_file(path: &Path, what: &str) -> ScrbError {
    ScrbError::checkpoint(format!("'{}': {what}", path.display()))
}

/// Map a truncated-payload parse error into a checkpoint error carrying
/// the file name (the payload already passed its checksum, so this only
/// fires on a format bug — but it must still be typed, not a panic).
fn in_file<T>(path: &Path, r: Result<T, ScrbError>) -> Result<T, ScrbError> {
    r.map_err(|e| ScrbError::checkpoint(format!("'{}': {e}", path.display())))
}

impl Checkpointer {
    pub fn new(cfg: &CheckpointCfg, fp_params: u64) -> Result<Checkpointer, ScrbError> {
        if cfg.every_rows == 0 {
            return Err(ScrbError::config("checkpoint cadence must be at least 1 row"));
        }
        let dir = PathBuf::from(&cfg.dir);
        std::fs::create_dir_all(&dir).map_err(|e| ScrbError::io(cfg.dir.clone(), e))?;
        Ok(Checkpointer {
            dir,
            fp_params,
            fp_state: 0,
            every_rows: cfg.every_rows,
            resume: cfg.resume,
            last_saved_rows: 0,
            blocks_written: 0,
        })
    }

    pub fn resume(&self) -> bool {
        self.resume
    }

    /// Derive the state fingerprint once the stats pass has pinned (d, n).
    /// Must be called before any state save/load.
    pub fn bind(&mut self, d: usize, n: usize) {
        self.fp_state =
            Fingerprint::new("stream/ckpt/state").u64(self.fp_params).usize(d).usize(n).finish();
    }

    pub fn save_stats(&self, s: &StatsCkpt) -> Result<(), ScrbError> {
        let mut w = ByteWriter::new();
        w.bytes(STATS_MAGIC);
        w.u64(self.fp_params);
        w.u64(s.n as u64);
        w.u64(s.d as u64);
        w.f64_slice(&s.lo);
        w.f64_slice(&s.span);
        write_atomic(&self.dir, "stats.bin", w)
    }

    pub fn load_stats(&self) -> Result<Option<StatsCkpt>, ScrbError> {
        let Some(body) = read_verified(&self.dir, "stats.bin", STATS_MAGIC)? else {
            return Ok(None);
        };
        let path = self.dir.join("stats.bin");
        let mut r = ByteReader::new(&body);
        let fp = in_file(&path, r.u64())?;
        if fp != self.fp_params {
            return Err(bad_file(
                &path,
                "written with different fit parameters (r/sigma/seed/block-rows); delete the \
                 checkpoint directory or rerun with the original flags",
            ));
        }
        let n = in_file(&path, r.u64())? as usize;
        let d = in_file(&path, r.u64())? as usize;
        let lo = in_file(&path, r.f64_vec(d))?;
        let span = in_file(&path, r.f64_vec(d))?;
        if n == 0 || r.remaining() != 0 {
            return Err(bad_file(&path, "inconsistent stats payload"));
        }
        Ok(Some(StatsCkpt { n, d, lo, span }))
    }

    /// Save pass-2 state when at least `every_rows` rows were featurized
    /// since the last save.
    pub fn maybe_save(&mut self, fz: &StreamFeaturizer) -> Result<(), ScrbError> {
        if fz.rows() - self.last_saved_rows >= self.every_rows {
            self.save_state(fz)?;
        }
        Ok(())
    }

    /// Persist the featurizer's pass-2 state: newly-completed blocks to
    /// their own (write-once) files, everything else into `state.bin`.
    pub fn save_state(&mut self, fz: &StreamFeaturizer) -> Result<(), ScrbError> {
        debug_assert_ne!(self.fp_state, 0, "bind() before saving state");
        let blocks = fz.state_blocks();
        // all blocks but the last are complete and immutable; the last may
        // still grow, so it rides along inside state.bin
        let full = blocks.len().saturating_sub(1);
        for i in self.blocks_written..full {
            let mut w = ByteWriter::new();
            w.bytes(BLOCK_MAGIC);
            w.u64(self.fp_state);
            w.u64(i as u64);
            w.u64(blocks[i].len() as u64);
            for &id in &blocks[i] {
                w.u32(id);
            }
            write_atomic(&self.dir, &block_name(i), w)?;
        }
        self.blocks_written = full;

        let labels = fz.state_labels();
        let mut w = ByteWriter::new();
        w.bytes(STATE_MAGIC);
        w.u64(self.fp_state);
        w.u64(fz.rows() as u64);
        w.u64(full as u64);
        let partial: &[u32] = blocks.last().map(|b| b.as_slice()).unwrap_or(&[]);
        w.u64(partial.len() as u64);
        for &id in partial {
            w.u32(id);
        }
        w.u64(fz.grid_count() as u64);
        for j in 0..fz.grid_count() {
            let (hashes, counts) = fz.grid_state(j);
            w.u64(hashes.len() as u64);
            for &h in hashes {
                w.u64(h);
            }
            for &c in counts {
                w.u64(c as u64);
            }
        }
        w.u64(labels.len() as u64);
        for &l in labels {
            w.u64(l as u64);
        }
        write_atomic(&self.dir, "state.bin", w)?;
        self.last_saved_rows = fz.rows();
        Ok(())
    }

    /// Load pass-2 state, if any. On success the checkpointer's own save
    /// cursors advance to the restored position, so subsequent
    /// [`Checkpointer::maybe_save`] calls continue the cadence without
    /// rewriting already-persisted blocks.
    pub fn load_state(&mut self) -> Result<Option<StateCkpt>, ScrbError> {
        debug_assert_ne!(self.fp_state, 0, "bind() before loading state");
        let Some(body) = read_verified(&self.dir, "state.bin", STATE_MAGIC)? else {
            return Ok(None);
        };
        let path = self.dir.join("state.bin");
        let mut r = ByteReader::new(&body);
        let fp = in_file(&path, r.u64())?;
        if fp != self.fp_state {
            return Err(bad_file(
                &path,
                "written with different fit parameters or data; delete the checkpoint \
                 directory or rerun with the original flags",
            ));
        }
        let rows_done = in_file(&path, r.u64())? as usize;
        let full = in_file(&path, r.u64())? as usize;
        let partial_len = in_file(&path, r.u64())? as usize;
        let mut partial = Vec::with_capacity(partial_len);
        for _ in 0..partial_len {
            partial.push(in_file(&path, r.u32())?);
        }
        let n_grids = in_file(&path, r.u64())? as usize;
        let mut grids = Vec::with_capacity(n_grids);
        for _ in 0..n_grids {
            let n_bins = in_file(&path, r.u64())? as usize;
            let mut hashes = Vec::with_capacity(n_bins);
            for _ in 0..n_bins {
                hashes.push(in_file(&path, r.u64())?);
            }
            let mut counts = Vec::with_capacity(n_bins);
            for _ in 0..n_bins {
                counts.push(in_file(&path, r.u64())? as usize);
            }
            grids.push((hashes, counts));
        }
        let n_labels = in_file(&path, r.u64())? as usize;
        if n_labels != rows_done {
            return Err(bad_file(&path, "label count disagrees with the row cursor"));
        }
        let mut labels = Vec::with_capacity(n_labels);
        for _ in 0..n_labels {
            labels.push(in_file(&path, r.u64())? as i64);
        }
        if r.remaining() != 0 {
            return Err(bad_file(&path, "trailing bytes after state payload"));
        }

        let mut blocks = Vec::with_capacity(full + 1);
        for i in 0..full {
            let bpath = self.dir.join(block_name(i));
            let Some(bbody) = read_verified(&self.dir, &block_name(i), BLOCK_MAGIC)? else {
                return Err(bad_file(&bpath, "missing block file referenced by state.bin"));
            };
            let mut br = ByteReader::new(&bbody);
            if in_file(&bpath, br.u64())? != self.fp_state {
                return Err(bad_file(&bpath, "written with different fit parameters or data"));
            }
            if in_file(&bpath, br.u64())? as usize != i {
                return Err(bad_file(&bpath, "block index disagrees with its file name"));
            }
            let len = in_file(&bpath, br.u64())? as usize;
            let mut ids = Vec::with_capacity(len);
            for _ in 0..len {
                ids.push(in_file(&bpath, br.u32())?);
            }
            if br.remaining() != 0 {
                return Err(bad_file(&bpath, "trailing bytes after block payload"));
            }
            blocks.push(ids);
        }
        if !partial.is_empty() {
            blocks.push(partial);
        }
        self.blocks_written = full;
        self.last_saved_rows = rows_done;
        Ok(Some(StateCkpt { grids, blocks, labels }))
    }
}

fn block_name(i: usize) -> String {
    format!("block_{i:04}.bin")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::stream::SparseChunk;
    use crate::util::rng::Pcg;

    fn tmpdir(tag: &str) -> String {
        let dir = std::env::temp_dir()
            .join(format!("scrb_ckpt_{tag}_{}", std::process::id()))
            .to_str()
            .unwrap()
            .to_string();
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn mat_chunk(x: &Mat, lo: usize, hi: usize) -> SparseChunk {
        let mut chunk = SparseChunk::new();
        for row in lo..hi {
            chunk.begin_row((row % 3) as i64);
            for (j, &v) in x.row(row).iter().enumerate() {
                chunk.push_entry(j as u32, v);
            }
            chunk.end_row();
        }
        chunk
    }

    #[test]
    fn stats_roundtrip_and_fingerprint_guard() {
        let dir = tmpdir("stats");
        let fp = ckpt_fingerprint(8, 0.5, 42, 64);
        let cfg = CheckpointCfg { resume: true, ..CheckpointCfg::new(dir.clone()) };
        let ck = Checkpointer::new(&cfg, fp).unwrap();
        assert!(ck.load_stats().unwrap().is_none(), "empty dir = nothing to resume");
        let stats =
            StatsCkpt { n: 100, d: 3, lo: vec![0.0, -1.0, 2.5], span: vec![1.0, 2.0, 3.0] };
        ck.save_stats(&stats).unwrap();
        let back = ck.load_stats().unwrap().unwrap();
        assert_eq!((back.n, back.d), (100, 3));
        assert_eq!(back.lo, stats.lo);
        assert_eq!(back.span, stats.span);
        // different parameters reject the file with a typed error
        let other = Checkpointer::new(&cfg, ckpt_fingerprint(9, 0.5, 42, 64)).unwrap();
        assert!(matches!(other.load_stats(), Err(ScrbError::Checkpoint(_))));
        // corruption is caught by the checksum footer
        let p = std::path::Path::new(&dir).join("stats.bin");
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[10] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(ck.load_stats(), Err(ScrbError::Checkpoint(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn state_roundtrip_restores_a_bit_identical_featurizer() {
        let dir = tmpdir("state");
        let mut rng = Pcg::seed(77);
        let n = 40;
        let x = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.f64()).collect());
        let mk = || {
            crate::stream::StreamFeaturizer::new(
                4,
                2,
                0.4,
                9,
                vec![0.0; 2],
                vec![1.0; 2],
                8,
                n,
            )
        };
        let mut whole = mk();
        whole.push_chunk(&mat_chunk(&x, 0, n));

        // featurize 27 rows (3 full 8-row blocks + a partial), checkpoint
        let fp = ckpt_fingerprint(4, 0.4, 9, 8);
        let cfg = CheckpointCfg { resume: true, ..CheckpointCfg::new(dir.clone()) };
        let mut ck = Checkpointer::new(&cfg, fp).unwrap();
        ck.bind(2, n);
        let mut part = mk();
        part.push_chunk(&mat_chunk(&x, 0, 27));
        ck.save_state(&part).unwrap();
        assert!(std::path::Path::new(&dir).join("block_0002.bin").exists());

        // a fresh checkpointer (fresh process) restores and continues
        let mut ck2 = Checkpointer::new(&cfg, fp).unwrap();
        ck2.bind(2, n);
        let st = ck2.load_state().unwrap().unwrap();
        assert_eq!(st.labels.len(), 27);
        let mut resumed = mk();
        resumed.load_state(st.grids, st.blocks, st.labels).unwrap();
        resumed.push_chunk(&mat_chunk(&x, 27, n));
        let (a, b) = (whole.finish().unwrap(), resumed.finish().unwrap());
        assert_eq!(a.z, b.z, "resumed featurization must match bit for bit");
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.kappa, b.kappa);

        // a bound fingerprint over different data rejects the state
        let mut ck3 = Checkpointer::new(&cfg, fp).unwrap();
        ck3.bind(2, n + 1);
        assert!(matches!(ck3.load_state(), Err(ScrbError::Checkpoint(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_block_file_is_a_typed_error() {
        let dir = tmpdir("missing_block");
        let mut rng = Pcg::seed(5);
        let n = 20;
        let x = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.f64()).collect());
        let fp = ckpt_fingerprint(3, 0.4, 1, 4);
        let cfg = CheckpointCfg { resume: true, ..CheckpointCfg::new(dir.clone()) };
        let mut ck = Checkpointer::new(&cfg, fp).unwrap();
        ck.bind(2, n);
        let mut fz = crate::stream::StreamFeaturizer::new(
            3,
            2,
            0.4,
            1,
            vec![0.0; 2],
            vec![1.0; 2],
            4,
            n,
        );
        fz.push_chunk(&mat_chunk(&x, 0, n));
        ck.save_state(&fz).unwrap();
        std::fs::remove_file(std::path::Path::new(&dir).join(block_name(1))).unwrap();
        let mut ck2 = Checkpointer::new(&cfg, fp).unwrap();
        ck2.bind(2, n);
        assert!(matches!(ck2.load_state(), Err(ScrbError::Checkpoint(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
