//! Streaming input statistics: the min/span frame in one chunked pass.
//!
//! The batch pipeline computes its preprocessing frame with
//! [`crate::data::Dataset::minmax_params`] over the densified N×d matrix.
//! The streaming fit must produce the **bit-identical** frame without
//! materializing that matrix: explicit sparse entries update running
//! per-column min/max directly, and a per-column presence count records
//! which columns had an implicit zero in at least one row — those fold a
//! single `0.0` into the extrema at finalization. Min/max over a multiset
//! is exact (no rounding) and order-independent, so the result equals the
//! dense scan bit for bit, including the `span = 1.0` collapse for
//! constant columns.

use super::chunk::SparseChunk;
use super::reader::ChunkReader;
use crate::error::ScrbError;
use std::collections::BTreeSet;

/// Running per-column extrema over a chunked pass.
pub struct StreamStats {
    /// Rows seen.
    pub n: usize,
    lo: Vec<f64>,
    hi: Vec<f64>,
    /// Explicit-entry count per column (columns grow as discovered).
    counts: Vec<usize>,
    /// Distinct raw labels seen (the class census the CLI uses when no
    /// `--k` is given).
    pub classes: BTreeSet<i64>,
}

impl Default for StreamStats {
    fn default() -> Self {
        StreamStats::new()
    }
}

impl StreamStats {
    pub fn new() -> StreamStats {
        StreamStats {
            n: 0,
            lo: Vec::new(),
            hi: Vec::new(),
            counts: Vec::new(),
            classes: BTreeSet::new(),
        }
    }

    /// Fold one chunk into the running statistics.
    pub fn update(&mut self, chunk: &SparseChunk) {
        self.n += chunk.rows();
        for &l in &chunk.labels {
            self.classes.insert(l);
        }
        for (&c, &v) in chunk.indices.iter().zip(chunk.values.iter()) {
            let c = c as usize;
            if c >= self.lo.len() {
                self.lo.resize(c + 1, f64::INFINITY);
                self.hi.resize(c + 1, f64::NEG_INFINITY);
                self.counts.resize(c + 1, 0);
            }
            self.lo[c] = self.lo[c].min(v);
            self.hi[c] = self.hi[c].max(v);
            self.counts[c] += 1;
        }
    }

    /// Fold another pass's statistics into this one — the shard-merge
    /// step. Min/max/count/census are all order-independent reductions,
    /// so merging per-shard stats then finalizing is bit-equal to one
    /// sequential pass over the concatenated shards (`tests/shard.rs`
    /// locks this through the full fit).
    pub fn merge(&mut self, other: &StreamStats) {
        self.n += other.n;
        self.classes.extend(other.classes.iter().copied());
        if other.lo.len() > self.lo.len() {
            self.lo.resize(other.lo.len(), f64::INFINITY);
            self.hi.resize(other.hi.len(), f64::NEG_INFINITY);
            self.counts.resize(other.counts.len(), 0);
        }
        for (j, (&l, (&h, &c))) in
            other.lo.iter().zip(other.hi.iter().zip(other.counts.iter())).enumerate()
        {
            self.lo[j] = self.lo[j].min(l);
            self.hi[j] = self.hi[j].max(h);
            self.counts[j] += c;
        }
    }

    /// Finish the pass: per-column `(min, span)` over `d` columns,
    /// bit-equal to [`crate::data::Dataset::minmax_params`] on the
    /// densified data (columns any row left implicit contribute a 0.0;
    /// `span = 1.0` for constant columns).
    pub fn finalize(mut self, d: usize) -> (Vec<f64>, Vec<f64>) {
        self.lo.resize(d, f64::INFINITY);
        self.hi.resize(d, f64::NEG_INFINITY);
        self.counts.resize(d, 0);
        for j in 0..d {
            if self.counts[j] < self.n {
                self.lo[j] = self.lo[j].min(0.0);
                self.hi[j] = self.hi[j].max(0.0);
            }
        }
        let span: Vec<f64> = self
            .lo
            .iter()
            .zip(self.hi.iter())
            .map(|(&l, &h)| if h > l { h - l } else { 1.0 })
            .collect();
        (self.lo, span)
    }
}

/// Run the statistics pass: drain `reader` once through `chunk`,
/// returning the accumulated [`StreamStats`]. The reader is left at end
/// of stream (callers `reset` it for the featurize pass).
pub fn stats_pass(
    reader: &mut dyn ChunkReader,
    chunk: &mut SparseChunk,
) -> Result<StreamStats, ScrbError> {
    let mut stats = StreamStats::new();
    while reader.next_chunk(chunk)? {
        stats.update(chunk);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::linalg::Mat;
    use crate::stream::LibsvmChunks;

    #[test]
    fn matches_dense_minmax_params_bitwise() {
        // sparse file with implicit zeros, negatives, a constant column,
        // and a column that only appears late
        let text = "\
1 1:2.0 2:-3.0 4:1.0
2 1:4.0 4:1.0
1 2:5.0 4:1.0
3 1:-1.0 2:0.5 3:9.0 4:1.0
";
        let mut r = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        let stats = stats_pass(&mut r, &mut chunk).unwrap();
        assert_eq!(stats.n, 4);
        assert_eq!(stats.classes.len(), 3);
        let d = r.dim();
        let (lo, span) = stats.finalize(d);

        // dense reference: the batch loader's view of the same file
        let ds = crate::data::parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        let (dlo, dspan) = ds.minmax_params();
        assert_eq!(lo, dlo);
        assert_eq!(span, dspan);
        // column 3 (0-based) is the constant 1.0 column: span collapses
        assert_eq!(span[3], 1.0);
    }

    #[test]
    fn merged_shard_stats_equal_sequential_stats() {
        let text = "\
1 1:2.0 2:-3.0 4:1.0
2 1:4.0 4:1.0
1 2:5.0 4:1.0
3 1:-1.0 2:0.5 3:9.0 4:1.0
";
        let mut whole = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        let seq = stats_pass(&mut whole, &mut chunk).unwrap();
        let d = whole.dim();
        // split the lines 1|3 and 3|1 (covers a shard missing a column
        // that another shard discovers, and an empty shard)
        let lines: Vec<&str> = text.lines().collect();
        for cut in [0usize, 1, 3, 4] {
            let head = lines[..cut].join("\n") + "\n";
            let tail = lines[cut..].join("\n") + "\n";
            let mut merged = StreamStats::new();
            for part in [head, tail] {
                let mut r = LibsvmChunks::from_bytes(part.into_bytes(), 2);
                let s = stats_pass(&mut r, &mut chunk).unwrap();
                merged.merge(&s);
            }
            assert_eq!(merged.n, seq.n, "cut {cut}");
            assert_eq!(merged.classes, seq.classes);
            let (lo_a, span_a) = merged.finalize(d);
            // finalize consumes; recompute the sequential reference
            let mut whole = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), 2);
            let seq2 = stats_pass(&mut whole, &mut chunk).unwrap();
            let (lo_b, span_b) = seq2.finalize(d);
            assert_eq!(lo_a, lo_b);
            assert_eq!(span_a, span_b);
        }
    }

    #[test]
    fn dense_rows_without_implicit_zeros() {
        // all-explicit chunks (the CSV shape): no zero folding at all
        let x = Mat::from_vec(3, 2, vec![1.0, 5.0, 3.0, 4.0, 2.0, 6.0]);
        let ds = Dataset::new("t", x, vec![0, 1, 0]);
        let mut chunk = SparseChunk::new();
        let mut stats = StreamStats::new();
        for i in 0..3 {
            chunk.clear();
            chunk.begin_row(ds.y[i] as i64);
            for (j, &v) in ds.x.row(i).iter().enumerate() {
                chunk.push_entry(j as u32, v);
            }
            chunk.end_row();
            stats.update(&chunk);
        }
        let (lo, span) = stats.finalize(2);
        let (dlo, dspan) = ds.minmax_params();
        assert_eq!(lo, dlo);
        assert_eq!(span, dspan);
        assert_eq!(lo, vec![1.0, 4.0]);
    }
}
