//! The reusable chunk buffer every [`super::ChunkReader`] fills.

/// Source context of one parsed row: where it came from in the input
/// text. Text readers fill one entry per row so downstream screening
/// (e.g. the non-finite check in [`super::GuardedReader`]) can report
/// file/line/byte context for a row long after the line buffer is gone.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct RowMeta {
    /// 1-based line number.
    pub line: usize,
    /// Byte offset of the start of the line.
    pub byte: u64,
}

/// One chunk of sparse rows in a flat CSR-ish layout. All buffers are
/// reused across [`super::ChunkReader::next_chunk`] calls — `clear`
/// keeps capacity — so a warm chunk loop never touches the heap.
pub struct SparseChunk {
    /// Row offsets into `indices`/`values`, length rows+1.
    pub indptr: Vec<usize>,
    /// 0-based column ids, concatenated row-major.
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
    /// Raw (uncompacted) labels, one per row.
    pub labels: Vec<i64>,
    /// Per-row source context. Text readers keep this in sync with
    /// `labels`; hand-built chunks may leave it empty (then no context is
    /// available, which screening layers must tolerate).
    pub meta: Vec<RowMeta>,
}

impl Default for SparseChunk {
    fn default() -> Self {
        SparseChunk::new()
    }
}

impl SparseChunk {
    pub fn new() -> SparseChunk {
        SparseChunk {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            meta: Vec::new(),
        }
    }

    /// Drop all rows, keeping every buffer's capacity.
    pub fn clear(&mut self) {
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.labels.clear();
        self.meta.clear();
    }

    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// The sparse entries of row `i`: `(column ids, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Start a new row (parsers then [`SparseChunk::push_entry`] its
    /// features and [`SparseChunk::end_row`] it).
    #[inline]
    pub fn begin_row(&mut self, label: i64) {
        self.labels.push(label);
    }

    #[inline]
    pub fn push_entry(&mut self, col: u32, val: f64) {
        self.indices.push(col);
        self.values.push(val);
    }

    #[inline]
    pub fn end_row(&mut self) {
        self.indptr.push(self.indices.len());
    }

    /// Roll back to a snapshot taken before a row parse started: a parser
    /// that fails mid-row leaves a partial row (label pushed, some
    /// entries, no `end_row`) that quarantine mode must discard before
    /// continuing with the next line.
    pub fn truncate_rows(&mut self, rows: usize, nnz: usize) {
        self.labels.truncate(rows);
        self.indices.truncate(nnz);
        self.values.truncate(nnz);
        self.indptr.truncate(rows + 1);
        self.meta.truncate(rows);
    }

    /// Remove every row `keep` rejects, compacting all buffers in place
    /// (no allocation). Used by quarantine-mode screening to drop rows
    /// that parsed but carry non-finite values.
    pub fn retain_rows(&mut self, mut keep: impl FnMut(usize) -> bool) {
        let rows = self.rows();
        let has_meta = self.meta.len() == rows;
        let mut w = 0usize;
        let mut wnnz = 0usize;
        for i in 0..rows {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            if !keep(i) {
                continue;
            }
            if w != i {
                self.indices.copy_within(lo..hi, wnnz);
                self.values.copy_within(lo..hi, wnnz);
                self.labels[w] = self.labels[i];
                if has_meta {
                    self.meta[w] = self.meta[i];
                }
            }
            wnnz += hi - lo;
            w += 1;
            self.indptr[w] = wnnz;
        }
        self.labels.truncate(w);
        if has_meta {
            self.meta.truncate(w);
        }
        self.indptr.truncate(w + 1);
        self.indices.truncate(wnnz);
        self.values.truncate(wnnz);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_clear_keeps_capacity() {
        let mut c = SparseChunk::new();
        c.begin_row(7);
        c.push_entry(2, 0.5);
        c.push_entry(9, -1.0);
        c.end_row();
        c.begin_row(-3);
        c.end_row();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.row(0), (&[2u32, 9][..], &[0.5, -1.0][..]));
        assert_eq!(c.row(1), (&[][..], &[][..]));
        assert_eq!(c.labels, vec![7, -3]);
        let cap = c.indices.capacity();
        c.clear();
        assert_eq!(c.rows(), 0);
        assert_eq!(c.indptr, vec![0]);
        assert_eq!(c.indices.capacity(), cap);
    }

    #[test]
    fn truncate_discards_a_partial_row() {
        let mut c = SparseChunk::new();
        c.begin_row(1);
        c.push_entry(0, 1.0);
        c.end_row();
        c.meta.push(RowMeta { line: 1, byte: 0 });
        let (rows, nnz) = (c.rows(), c.nnz());
        // a failed parse: label + one entry pushed, then abandoned
        c.begin_row(2);
        c.push_entry(1, 0.5);
        c.truncate_rows(rows, nnz);
        assert_eq!(c.rows(), 1);
        assert_eq!(c.nnz(), 1);
        assert_eq!(c.indptr, vec![0, 1]);
        assert_eq!(c.meta.len(), 1);
    }

    #[test]
    fn retain_rows_compacts_in_place() {
        let mut c = SparseChunk::new();
        for i in 0..5i64 {
            c.begin_row(i);
            c.push_entry(i as u32, i as f64);
            if i % 2 == 0 {
                c.push_entry(10 + i as u32, -1.0);
            }
            c.end_row();
            c.meta.push(RowMeta { line: i as usize + 1, byte: 10 * i as u64 });
        }
        let caps = (c.indices.capacity(), c.labels.capacity());
        c.retain_rows(|i| i != 1 && i != 4);
        assert_eq!(c.rows(), 3);
        assert_eq!(c.labels, vec![0, 2, 3]);
        assert_eq!(c.row(0), (&[0u32, 10][..], &[0.0, -1.0][..]));
        assert_eq!(c.row(1), (&[2u32, 12][..], &[2.0, -1.0][..]));
        assert_eq!(c.row(2), (&[3u32][..], &[3.0][..]));
        assert_eq!(c.meta[2], RowMeta { line: 4, byte: 30 });
        assert_eq!((c.indices.capacity(), c.labels.capacity()), caps, "in place, no realloc");
        // removing nothing leaves the chunk untouched
        let before = c.indptr.clone();
        c.retain_rows(|_| true);
        assert_eq!(c.indptr, before);
    }
}
