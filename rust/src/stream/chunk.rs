//! The reusable chunk buffer every [`super::ChunkReader`] fills.

/// One chunk of sparse rows in a flat CSR-ish layout. All four buffers
/// are reused across [`super::ChunkReader::next_chunk`] calls — `clear`
/// keeps capacity — so a warm chunk loop never touches the heap.
pub struct SparseChunk {
    /// Row offsets into `indices`/`values`, length rows+1.
    pub indptr: Vec<usize>,
    /// 0-based column ids, concatenated row-major.
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
    /// Raw (uncompacted) labels, one per row.
    pub labels: Vec<i64>,
}

impl Default for SparseChunk {
    fn default() -> Self {
        SparseChunk::new()
    }
}

impl SparseChunk {
    pub fn new() -> SparseChunk {
        SparseChunk { indptr: vec![0], indices: Vec::new(), values: Vec::new(), labels: Vec::new() }
    }

    /// Drop all rows, keeping every buffer's capacity.
    pub fn clear(&mut self) {
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.labels.clear();
    }

    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// The sparse entries of row `i`: `(column ids, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Start a new row (parsers then [`SparseChunk::push_entry`] its
    /// features and [`SparseChunk::end_row`] it).
    #[inline]
    pub fn begin_row(&mut self, label: i64) {
        self.labels.push(label);
    }

    #[inline]
    pub fn push_entry(&mut self, col: u32, val: f64) {
        self.indices.push(col);
        self.values.push(val);
    }

    #[inline]
    pub fn end_row(&mut self) {
        self.indptr.push(self.indices.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_clear_keeps_capacity() {
        let mut c = SparseChunk::new();
        c.begin_row(7);
        c.push_entry(2, 0.5);
        c.push_entry(9, -1.0);
        c.end_row();
        c.begin_row(-3);
        c.end_row();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.row(0), (&[2u32, 9][..], &[0.5, -1.0][..]));
        assert_eq!(c.row(1), (&[][..], &[][..]));
        assert_eq!(c.labels, vec![7, -3]);
        let cap = c.indices.capacity();
        c.clear();
        assert_eq!(c.rows(), 0);
        assert_eq!(c.indptr, vec![0]);
        assert_eq!(c.indices.capacity(), cap);
    }
}
