//! Deterministic fault injection: the test harness the fault-tolerance
//! layer is verified against.
//!
//! [`FaultyReader`] decorates any [`ChunkReader`] and injects, from a
//! seeded plan, the three failure classes the ingest stack must survive:
//!
//! - **Transient I/O errors** ([`FaultPlan::transient_permille`]) —
//!   surfaced as [`ScrbError::Transient`] *before* the wrapped reader is
//!   touched, and fired at most once per (pass, call) site, so a bounded
//!   retry always succeeds: exactly what a flaky NFS mount or an
//!   interrupted syscall looks like.
//! - **Non-finite corruption** ([`FaultPlan::nonfinite_permille`]) —
//!   NaN/Inf overwrites of parsed values, keyed by the row's absolute
//!   per-pass index (*not* the pass number), so the same rows are
//!   corrupted in the stats and featurize passes and quarantine stays
//!   row-consistent.
//! - **A mid-pass kill** ([`FaultPlan::fail_at`]) — a permanent failure
//!   once a row threshold is crossed in a given pass, for exercising
//!   checkpoint/resume.
//!
//! Text- and byte-level corrupters ([`corrupt_libsvm_text`],
//! [`corrupt_model_bytes`]) complete the harness: garbage/truncated lines
//! for quarantine tests, and seeded flips/truncations for the model
//! checksum property test.
//!
//! Everything here is a pure function of the seed — reruns and both
//! passes of a fit see identical faults.
//!
//! [`ScrbError::Transient`]: crate::error::ScrbError::Transient

use super::chunk::SparseChunk;
use super::policy::{IngestPolicy, Quarantine};
use super::reader::ChunkReader;
use crate::error::ScrbError;
use crate::util::rng::Pcg;
use std::collections::HashSet;

/// Salt separating the row-corruption hash stream from the transient one.
const ROW_SALT: u64 = 0x5eed_f417_5eed_f417;

/// Stateless position hash (splitmix64 finalizer over three words): fault
/// decisions must be pure functions of (seed, site), never of draw order,
/// or retries and second passes would see different faults. Shared with
/// the serving fault plan ([`ServeFaultPlan`]), which keys off request
/// ids the same way this file keys off row/call sites.
pub(crate) fn mix(a: u64, b: u64, c: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ c.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// What faults to inject, and how often. Rates are per-mille so a plan is
/// all-integer (hashable, exactly reproducible).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-mille of `next_chunk` calls that fail once with a transient
    /// error before succeeding on retry.
    pub transient_permille: u32,
    /// Per-mille of rows whose first value is overwritten with NaN/Inf
    /// after parsing.
    pub nonfinite_permille: u32,
    /// `(pass, row)`: once at least `row` rows have been yielded in
    /// 0-based pass `pass`, every subsequent call fails permanently — a
    /// simulated kill for checkpoint/resume tests.
    pub fail_at: Option<(usize, usize)>,
}

/// A [`ChunkReader`] decorator injecting the faults of a [`FaultPlan`].
/// Passes are counted by [`ChunkReader::reset`] calls (the streaming fit
/// resets exactly once between stats and featurize).
pub struct FaultyReader<'a> {
    /// `+ Send` so a per-shard `FaultyReader` can ride a shard worker
    /// thread (every concrete reader is Send; the plain-trait-object
    /// coercion at the call sites keeps working).
    inner: &'a mut (dyn ChunkReader + Send),
    plan: FaultPlan,
    /// 0-based pass index, incremented on reset.
    pass: usize,
    /// `next_chunk` calls answered successfully this pass.
    calls: u64,
    /// Rows yielded this pass (pre-screening: what the wrapped reader
    /// produced).
    rows: usize,
    /// Transient sites that already fired (fire once, then let the retry
    /// through).
    fired: HashSet<(usize, u64)>,
    injected_transient: usize,
    corrupted: usize,
}

impl<'a> FaultyReader<'a> {
    pub fn new(inner: &'a mut (dyn ChunkReader + Send), plan: FaultPlan) -> FaultyReader<'a> {
        FaultyReader {
            inner,
            plan,
            pass: 0,
            calls: 0,
            rows: 0,
            fired: HashSet::new(),
            injected_transient: 0,
            corrupted: 0,
        }
    }

    /// Transient errors injected so far (all passes).
    pub fn injected_transient(&self) -> usize {
        self.injected_transient
    }

    /// Rows corrupted with NaN/Inf this pass.
    pub fn corrupted_rows(&self) -> usize {
        self.corrupted
    }
}

impl ChunkReader for FaultyReader<'_> {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        if let Some((pass, row)) = self.plan.fail_at {
            if self.pass == pass && self.rows >= row {
                return Err(ScrbError::transient("injected permanent failure (simulated kill)"));
            }
        }
        let site = (self.pass, self.calls);
        if self.plan.transient_permille > 0
            && mix(self.plan.seed, site.0 as u64, site.1) % 1000
                < self.plan.transient_permille as u64
            && self.fired.insert(site)
        {
            self.injected_transient += 1;
            return Err(ScrbError::transient("injected transient i/o error"));
        }
        let more = self.inner.next_chunk(chunk)?;
        if self.plan.nonfinite_permille > 0 {
            for i in 0..chunk.rows() {
                // keyed by the absolute per-pass row index only: the same
                // rows go bad in every pass, keeping quarantine decisions
                // pass-consistent
                let h = mix(self.plan.seed ^ ROW_SALT, (self.rows + i) as u64, 0x0bad);
                if h % 1000 < self.plan.nonfinite_permille as u64 {
                    let lo = chunk.indptr[i];
                    let hi = chunk.indptr[i + 1];
                    if lo < hi {
                        chunk.values[lo] = if h & (1 << 10) != 0 { f64::NAN } else { f64::INFINITY };
                        self.corrupted += 1;
                    }
                }
            }
        }
        self.rows += chunk.rows();
        self.calls += 1;
        Ok(more)
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.inner.reset()?;
        self.pass += 1;
        self.calls = 0;
        self.rows = 0;
        self.corrupted = 0;
        Ok(())
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn source_name(&self) -> &str {
        self.inner.source_name()
    }

    fn set_policy(&mut self, policy: &IngestPolicy) {
        self.inner.set_policy(policy);
    }

    fn quarantine(&self) -> Option<&Quarantine> {
        self.inner.quarantine()
    }
}

/// Replace roughly `permille`/1000 of the data lines of a LibSVM text
/// with seeded garbage (unparseable tokens, truncated features,
/// non-finite labels/values). Returns the corrupted text and the 0-based
/// indices of the replaced lines, so a test can reconstruct the clean
/// subset exactly.
pub fn corrupt_libsvm_text(bytes: &[u8], seed: u64, permille: u32) -> (Vec<u8>, Vec<usize>) {
    const BAD: [&str; 6] =
        ["1 nocolon", "1 0:1.0", "garbage ###", "1 3:1.0 2:2.0", "1 1:nan", "nan 1:1.0"];
    let text = std::str::from_utf8(bytes).expect("corrupt_libsvm_text wants UTF-8 input");
    let mut out = String::with_capacity(text.len());
    let mut replaced = Vec::new();
    for (li, line) in text.lines().enumerate() {
        let t = line.trim();
        let is_data = !t.is_empty() && !t.starts_with('#');
        if is_data && mix(seed, li as u64, 0xc0de) % 1000 < permille as u64 {
            let h = mix(seed, li as u64, 0xfeed);
            let choice = (h % (BAD.len() as u64 + 1)) as usize;
            if choice == BAD.len() {
                // truncation: cut the line mid-feature if it has one
                match t.rfind(':') {
                    Some(cut) => out.push_str(&t[..=cut]),
                    None => out.push_str(BAD[0]),
                }
            } else {
                out.push_str(BAD[choice]);
            }
            replaced.push(li);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    (out.into_bytes(), replaced)
}

/// One seeded mutation of a model byte image: a single bit flip, a byte
/// overwrite, or a truncation. Drives the persistence-corruption property
/// test alongside exhaustive position sweeps.
pub fn corrupt_model_bytes(bytes: &[u8], seed: u64) -> Vec<u8> {
    let mut rng = Pcg::seed(seed);
    let mut out = bytes.to_vec();
    if out.is_empty() {
        return out;
    }
    match rng.below(3) {
        0 => {
            let pos = rng.below(out.len());
            out[pos] ^= 1 << rng.below(8);
        }
        1 => {
            let pos = rng.below(out.len());
            out[pos] = out[pos].wrapping_add(1 + rng.below(255) as u8);
        }
        _ => {
            out.truncate(rng.below(out.len()));
        }
    }
    out
}

/// Salt separating serving-panic decisions from serving-stall decisions.
const SERVE_PANIC_SALT: u64 = 0x9a1c_0de0_9a1c_0de0;
const SERVE_STALL_SALT: u64 = 0x57a1_1ed0_57a1_1ed0;

/// Seeded fault plan for the serving daemon ([`crate::serve`]): which
/// requests make a worker panic and which stall inside the batcher.
/// Decisions are pure functions of `(seed, req_id)` — the same request id
/// draws the same fate on every run and on every worker, so tests can
/// predict exact panic/restart and timeout counts from the ids they send.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeFaultPlan {
    pub seed: u64,
    /// Per-mille of request ids that panic the worker processing them.
    pub panic_permille: u32,
    /// Per-mille of request ids that stall the worker for `stall_ms`
    /// before the batch is processed (drives deadline/overload tests).
    pub stall_permille: u32,
    /// How long a stalled request sleeps, in milliseconds.
    pub stall_ms: u64,
}

impl ServeFaultPlan {
    /// Does `req_id` panic its worker under this plan?
    pub fn panics(&self, req_id: u64) -> bool {
        self.panic_permille > 0
            && mix(self.seed ^ SERVE_PANIC_SALT, req_id, 0x7a71c) % 1000
                < self.panic_permille as u64
    }

    /// Does `req_id` stall its worker under this plan?
    pub fn stalls(&self, req_id: u64) -> bool {
        self.stall_permille > 0
            && mix(self.seed ^ SERVE_STALL_SALT, req_id, 0x57a11) % 1000
                < self.stall_permille as u64
    }
}

/// Seeded torn-frame corrupter for serving protocol tests: truncate an
/// encoded frame to a strict prefix (at least 1 byte shorter, possibly
/// empty), simulating a client that died mid-write.
pub fn tear_frame(frame: &[u8], seed: u64) -> Vec<u8> {
    if frame.is_empty() {
        return Vec::new();
    }
    let cut = (mix(seed, frame.len() as u64, 0x7ea2) % frame.len() as u64) as usize;
    frame[..cut].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::policy::{GuardedReader, OnBadRecord};
    use crate::stream::LibsvmChunks;

    const TEXT: &str = "\
1 1:0.5 2:1.5
2 1:1.0
1 2:2.0
2 1:0.25 2:0.75
1 1:0.1
2 2:0.9
";

    fn drain(r: &mut dyn ChunkReader) -> Result<Vec<i64>, ScrbError> {
        let mut chunk = SparseChunk::new();
        let mut labels = Vec::new();
        while r.next_chunk(&mut chunk)? {
            labels.extend_from_slice(&chunk.labels);
        }
        Ok(labels)
    }

    #[test]
    fn transient_faults_fire_once_and_retry_succeeds() {
        let mut inner = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 2);
        let plan = FaultPlan { seed: 42, transient_permille: 1000, ..FaultPlan::default() };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        let mut chunk = SparseChunk::new();
        // every call fails exactly once, then the retry reads real data
        let err = faulty.next_chunk(&mut chunk).unwrap_err();
        assert!(matches!(err, ScrbError::Transient { .. }));
        assert!(faulty.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, 2]);
        assert_eq!(faulty.injected_transient(), 1);
    }

    #[test]
    fn guarded_reader_absorbs_injected_transients() {
        let mut inner = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 2);
        let plan = FaultPlan { seed: 7, transient_permille: 1000, ..FaultPlan::default() };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        let policy = IngestPolicy { retry_backoff_ms: 0, ..IngestPolicy::default() };
        let mut guarded = GuardedReader::new(&mut faulty, policy);
        let labels = drain(&mut guarded).unwrap();
        assert_eq!(labels, vec![1, 2, 1, 2, 1, 2], "faults are invisible after retry");
        assert!(guarded.report().retries >= 3);
    }

    #[test]
    fn nonfinite_corruption_is_pass_consistent() {
        let plan = FaultPlan { seed: 3, nonfinite_permille: 400, ..FaultPlan::default() };
        let policy =
            IngestPolicy { on_bad_record: OnBadRecord::Quarantine, ..IngestPolicy::default() };
        let run = |chunk_rows: usize| {
            let mut inner = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), chunk_rows);
            let mut faulty = FaultyReader::new(&mut inner, plan);
            let mut guarded = GuardedReader::new(&mut faulty, policy.clone());
            let first = drain(&mut guarded).unwrap();
            let skipped = guarded.report().skipped();
            guarded.reset().unwrap();
            let second = drain(&mut guarded).unwrap();
            assert_eq!(first, second, "both passes keep the same rows");
            assert_eq!(guarded.report().skipped(), skipped);
            (first, skipped)
        };
        let (survivors, skipped) = run(2);
        assert!(skipped > 0, "plan should corrupt at least one row");
        assert_eq!(survivors.len() + skipped, 6);
        // chunking must not change which rows are corrupted
        assert_eq!(run(5), (survivors, skipped));
    }

    #[test]
    fn fail_at_kills_the_requested_pass() {
        let mut inner = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 2);
        let plan = FaultPlan { seed: 1, fail_at: Some((1, 4)), ..FaultPlan::default() };
        let mut faulty = FaultyReader::new(&mut inner, plan);
        // pass 0 completes untouched
        assert_eq!(drain(&mut faulty).unwrap().len(), 6);
        faulty.reset().unwrap();
        // pass 1 dies once 4 rows have been yielded
        let err = drain(&mut faulty).unwrap_err();
        assert!(matches!(err, ScrbError::Transient { .. }));
    }

    #[test]
    fn corrupters_are_deterministic() {
        let (a, lines_a) = corrupt_libsvm_text(TEXT.as_bytes(), 9, 500);
        let (b, lines_b) = corrupt_libsvm_text(TEXT.as_bytes(), 9, 500);
        assert_eq!(a, b);
        assert_eq!(lines_a, lines_b);
        assert!(!lines_a.is_empty());
        assert!(lines_a.len() < 6, "some lines survive at 50%");
        let m = corrupt_model_bytes(b"0123456789", 4);
        assert_eq!(m, corrupt_model_bytes(b"0123456789", 4));
        assert_ne!(m, b"0123456789");
    }

    #[test]
    fn serve_plan_is_deterministic_and_rate_bounded() {
        let plan =
            ServeFaultPlan { seed: 42, panic_permille: 100, stall_permille: 100, stall_ms: 5 };
        let panics: Vec<u64> = (0..1000).filter(|&id| plan.panics(id)).collect();
        let stalls: Vec<u64> = (0..1000).filter(|&id| plan.stalls(id)).collect();
        // same plan, same decisions
        assert_eq!(panics, (0..1000).filter(|&id| plan.panics(id)).collect::<Vec<_>>());
        // roughly the requested rate, and the two salts decorrelate
        assert!(!panics.is_empty() && panics.len() < 300);
        assert!(!stalls.is_empty() && stalls.len() < 300);
        assert_ne!(panics, stalls);
        // zero rate fires never
        let off = ServeFaultPlan { seed: 42, ..ServeFaultPlan::default() };
        assert!((0..1000).all(|id| !off.panics(id) && !off.stalls(id)));
    }

    #[test]
    fn tear_frame_strictly_truncates() {
        let frame = vec![7u8; 64];
        for seed in 0..32 {
            let torn = tear_frame(&frame, seed);
            assert!(torn.len() < frame.len());
            assert_eq!(&torn[..], &frame[..torn.len()]);
            assert_eq!(torn, tear_frame(&frame, seed));
        }
        assert!(tear_frame(&[], 1).is_empty());
    }
}
