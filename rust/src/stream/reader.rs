//! Chunked dataset readers: fixed-row-count blocks of sparse rows,
//! yielded through reusable buffers.
//!
//! The [`ChunkReader`] trait is the ingestion boundary of the out-of-core
//! fit: a backend yields [`SparseChunk`]s of at most `chunk_rows` rows —
//! sparse rows stay sparse, nothing is ever densified into an N×d matrix
//! — and can [`ChunkReader::reset`] for another pass (the streaming fit
//! makes two: statistics, then featurization). All per-chunk state lives
//! in caller-owned buffers whose capacity survives across chunks *and*
//! across passes, so a warm steady-state chunk loop performs no heap
//! allocations (enforced by `tests/alloc.rs`).
//!
//! Two backends:
//! - [`LibsvmChunks`] — the LibSVM text format (`label idx:val ...`,
//!   1-based sparse indices), from a file path (buffered single-pass IO,
//!   rewound with one `seek`) or from in-memory bytes (tests, adapters).
//!   The in-memory loader [`crate::data::load_libsvm`] drains this same
//!   reader, so the streamed and batch parse paths cannot drift.
//! - [`CsvChunks`] — dense comma-separated rows (`label,v1,...,vd`), d
//!   fixed by the first data row.
//!
//! Feature dimension is discovered as rows stream by ([`ChunkReader::dim`]
//! is final only after a complete pass) — which is why the fit's first
//! pass doubles as the dimension scan.

use super::chunk::SparseChunk;
use crate::error::ScrbError;
use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};

/// A rewindable source of fixed-row-count sparse chunks.
pub trait ChunkReader {
    /// Fill `chunk` (cleared first) with up to [`ChunkReader::chunk_rows`]
    /// rows. Returns `Ok(false)` when the stream is exhausted (the chunk
    /// is then empty); the final non-empty chunk may be short.
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError>;

    /// Rewind to the first row for another pass. Warm readers rewind
    /// without allocating.
    fn reset(&mut self) -> Result<(), ScrbError>;

    /// Feature dimension d observed so far. LibSVM discovers d as rows
    /// stream by, so this is final only after a complete pass; the CSV
    /// backend knows it from the first data row.
    fn dim(&self) -> usize;

    /// Target rows per chunk (the resident-input-memory knob: the
    /// featurize pass holds one `chunk_rows × d` dense scratch).
    fn chunk_rows(&self) -> usize;
}

/// Parse one LibSVM line (`label idx:val ...`, 1-based strictly-ascending
/// indices) into `chunk`, tracking the running max dimension. Blank lines
/// and `#` comments are skipped (returns false). Shared by the chunked
/// reader and the in-memory loader so the two parse paths are one.
///
/// Ascending indices are the LibSVM convention; enforcing them here also
/// rules out duplicate indices within a row — which would make "presence"
/// ambiguous and break the streamed statistics' exact equivalence with
/// the densified scan.
pub(crate) fn parse_libsvm_line(
    line: &str,
    lineno: usize,
    chunk: &mut SparseChunk,
    max_dim: &mut usize,
) -> Result<bool, ScrbError> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(false);
    }
    let mut parts = line.split_whitespace();
    let label_tok = parts
        .next()
        .ok_or_else(|| ScrbError::parse(format!("line {lineno}: empty")))?;
    let label = label_tok
        .parse::<f64>()
        .map_err(|_| ScrbError::parse(format!("line {lineno}: bad label '{label_tok}'")))?
        as i64;
    chunk.begin_row(label);
    let mut prev_idx = 0usize;
    for tok in parts {
        let (is, vs) = tok
            .split_once(':')
            .ok_or_else(|| ScrbError::parse(format!("line {lineno}: bad feature '{tok}'")))?;
        let idx: usize = is
            .parse()
            .map_err(|_| ScrbError::parse(format!("line {lineno}: bad index '{is}'")))?;
        if idx == 0 {
            return Err(ScrbError::parse(format!("line {lineno}: LibSVM indices are 1-based")));
        }
        if idx > u32::MAX as usize {
            return Err(ScrbError::parse(format!("line {lineno}: index {idx} overflows u32")));
        }
        if idx <= prev_idx {
            return Err(ScrbError::parse(format!(
                "line {lineno}: indices must be strictly ascending ({prev_idx} then {idx})"
            )));
        }
        prev_idx = idx;
        let val: f64 = vs
            .parse()
            .map_err(|_| ScrbError::parse(format!("line {lineno}: bad value '{vs}'")))?;
        *max_dim = (*max_dim).max(idx);
        chunk.push_entry((idx - 1) as u32, val);
    }
    chunk.end_row();
    Ok(true)
}

/// Where a text reader's bytes come from.
enum Source {
    /// Buffered file handle; rewound with one `seek` (no reallocation).
    File(BufReader<File>),
    /// In-memory bytes walked by cursor (tests, adapters).
    Mem(Vec<u8>),
}

/// Shared line pump for the text backends: owns the byte source, the
/// reusable line buffer, the chunk loop, and the rewind logic. A backend
/// is just this plus a per-line parser and its dimension state — so line
/// handling can never drift between formats.
struct TextChunks {
    source: Source,
    /// Cursor into `Source::Mem` bytes.
    pos: usize,
    /// Reusable line buffer for `Source::File`.
    line_buf: String,
    lineno: usize,
    chunk_rows: usize,
}

impl TextChunks {
    fn from_path(path: &str, chunk_rows: usize) -> Result<TextChunks, ScrbError> {
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        let file = File::open(path).map_err(|e| ScrbError::io(path, e))?;
        Ok(TextChunks {
            source: Source::File(BufReader::new(file)),
            pos: 0,
            line_buf: String::new(),
            lineno: 0,
            chunk_rows,
        })
    }

    fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> TextChunks {
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        TextChunks { source: Source::Mem(bytes), pos: 0, line_buf: String::new(), lineno: 0, chunk_rows }
    }

    /// Fill `chunk` (cleared first) by feeding lines to `parse` until
    /// `chunk_rows` rows accumulate or the stream ends.
    fn next_chunk_with(
        &mut self,
        chunk: &mut SparseChunk,
        mut parse: impl FnMut(&str, usize, &mut SparseChunk) -> Result<bool, ScrbError>,
    ) -> Result<bool, ScrbError> {
        chunk.clear();
        while chunk.rows() < self.chunk_rows {
            match &mut self.source {
                Source::Mem(bytes) => {
                    if self.pos >= bytes.len() {
                        break;
                    }
                    let rest = &bytes[self.pos..];
                    let take =
                        rest.iter().position(|&b| b == b'\n').map(|p| p + 1).unwrap_or(rest.len());
                    self.pos += take;
                    self.lineno += 1;
                    let line = std::str::from_utf8(&rest[..take]).map_err(|_| {
                        ScrbError::parse(format!("line {}: invalid UTF-8", self.lineno))
                    })?;
                    parse(line, self.lineno, chunk)?;
                }
                Source::File(reader) => {
                    self.line_buf.clear();
                    let n = reader.read_line(&mut self.line_buf).map_err(|e| {
                        ScrbError::parse(format!("read error at line {}: {e}", self.lineno + 1))
                    })?;
                    if n == 0 {
                        break;
                    }
                    self.lineno += 1;
                    parse(&self.line_buf, self.lineno, chunk)?;
                }
            }
        }
        Ok(chunk.rows() > 0)
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.pos = 0;
        self.lineno = 0;
        if let Source::File(reader) = &mut self.source {
            reader
                .seek(SeekFrom::Start(0))
                .map_err(|e| ScrbError::parse(format!("rewind failed: {e}")))?;
        }
        Ok(())
    }
}

/// Chunked LibSVM reader (see module docs for the format).
pub struct LibsvmChunks {
    text: TextChunks,
    max_dim: usize,
}

impl LibsvmChunks {
    /// Open `path` for chunked reading.
    pub fn from_path(path: &str, chunk_rows: usize) -> Result<LibsvmChunks, ScrbError> {
        Ok(LibsvmChunks { text: TextChunks::from_path(path, chunk_rows)?, max_dim: 0 })
    }

    /// Read from in-memory LibSVM text.
    pub fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> LibsvmChunks {
        LibsvmChunks { text: TextChunks::from_bytes(bytes, chunk_rows), max_dim: 0 }
    }
}

impl ChunkReader for LibsvmChunks {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        let max_dim = &mut self.max_dim;
        self.text
            .next_chunk_with(chunk, |line, lineno, chunk| {
                parse_libsvm_line(line, lineno, chunk, max_dim)
            })
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.text.reset()
    }

    fn dim(&self) -> usize {
        self.max_dim
    }

    fn chunk_rows(&self) -> usize {
        self.text.chunk_rows
    }
}

/// Parse one dense CSV line (`label,v1,...,vd`) into `chunk`. `d` is
/// `None` until the first data row fixes it; later rows must match.
pub(crate) fn parse_csv_line(
    line: &str,
    lineno: usize,
    chunk: &mut SparseChunk,
    d: &mut Option<usize>,
) -> Result<bool, ScrbError> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(false);
    }
    let mut parts = line.split(',');
    let label_tok = parts
        .next()
        .ok_or_else(|| ScrbError::parse(format!("line {lineno}: empty")))?
        .trim();
    let label = label_tok
        .parse::<f64>()
        .map_err(|_| ScrbError::parse(format!("line {lineno}: bad label '{label_tok}'")))?
        as i64;
    chunk.begin_row(label);
    let mut count = 0usize;
    for tok in parts {
        let tok = tok.trim();
        let val: f64 = tok
            .parse()
            .map_err(|_| ScrbError::parse(format!("line {lineno}: bad value '{tok}'")))?;
        chunk.push_entry(count as u32, val);
        count += 1;
    }
    match *d {
        None => *d = Some(count),
        Some(expect) if expect != count => {
            return Err(ScrbError::parse(format!(
                "line {lineno}: {count} features, expected {expect}"
            )));
        }
        _ => {}
    }
    chunk.end_row();
    Ok(true)
}

/// Chunked dense-CSV reader: one `label,v1,...,vd` row per line, d fixed
/// by the first data row. Rows are dense, so every value (zeros included)
/// is an explicit chunk entry.
pub struct CsvChunks {
    text: TextChunks,
    d: Option<usize>,
}

impl CsvChunks {
    /// Open `path` for chunked reading.
    pub fn from_path(path: &str, chunk_rows: usize) -> Result<CsvChunks, ScrbError> {
        Ok(CsvChunks { text: TextChunks::from_path(path, chunk_rows)?, d: None })
    }

    /// Read from in-memory CSV text.
    pub fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> CsvChunks {
        CsvChunks { text: TextChunks::from_bytes(bytes, chunk_rows), d: None }
    }
}

impl ChunkReader for CsvChunks {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        let d = &mut self.d;
        self.text
            .next_chunk_with(chunk, |line, lineno, chunk| parse_csv_line(line, lineno, chunk, d))
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.text.reset()
    }

    fn dim(&self) -> usize {
        self.d.unwrap_or(0)
    }

    fn chunk_rows(&self) -> usize {
        self.text.chunk_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = "\
# comment
+1 1:0.5 3:1.5

-1 2:2.0
+1 1:1.0 2:1.0 3:1.0
2 4:0.25
";

    #[test]
    fn libsvm_chunks_cover_all_rows() {
        let mut r = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        let mut rows = 0usize;
        let mut nnz = 0usize;
        let mut chunks = 0usize;
        while r.next_chunk(&mut chunk).unwrap() {
            assert!(chunk.rows() <= 2);
            rows += chunk.rows();
            nnz += chunk.nnz();
            chunks += 1;
        }
        assert_eq!(rows, 4);
        assert_eq!(nnz, 2 + 1 + 3 + 1);
        assert_eq!(chunks, 2);
        assert_eq!(r.dim(), 4);
        // exhausted reader keeps returning false with an empty chunk
        assert!(!r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 0);
    }

    #[test]
    fn libsvm_reset_replays_identically() {
        let mut r = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 3);
        let mut chunk = SparseChunk::new();
        let mut first: Vec<(Vec<u32>, Vec<f64>, i64)> = Vec::new();
        while r.next_chunk(&mut chunk).unwrap() {
            for i in 0..chunk.rows() {
                let (c, v) = chunk.row(i);
                first.push((c.to_vec(), v.to_vec(), chunk.labels[i]));
            }
        }
        r.reset().unwrap();
        let mut second = Vec::new();
        while r.next_chunk(&mut chunk).unwrap() {
            for i in 0..chunk.rows() {
                let (c, v) = chunk.row(i);
                second.push((c.to_vec(), v.to_vec(), chunk.labels[i]));
            }
        }
        assert_eq!(first, second);
        assert_eq!(first.len(), 4);
        assert_eq!(first[0].0, vec![0, 2]);
        assert_eq!(first[0].2, 1);
        assert_eq!(first[1].0, vec![1]);
    }

    #[test]
    fn libsvm_rejects_malformed() {
        for bad in [
            "1 nocolon\n",
            "1 0:1.0\n",
            "abc 1:1\n",
            "1 9999999999999:1\n",
            "1 2:1.0 2:2.0\n", // duplicate index
            "1 3:1.0 2:2.0\n", // out-of-order indices
        ] {
            let mut r = LibsvmChunks::from_bytes(bad.as_bytes().to_vec(), 4);
            let mut chunk = SparseChunk::new();
            assert!(r.next_chunk(&mut chunk).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn csv_chunks_parse_dense_rows() {
        let text = "# header\n1, 0.5, 1.5, 0.0\n2, 1.0, -1.0, 3.0\n1, 0.0, 0.0, 0.0\n";
        let mut r = CsvChunks::from_bytes(text.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 2);
        assert_eq!(r.dim(), 3);
        let (c, v) = chunk.row(0);
        assert_eq!(c, &[0, 1, 2]);
        assert_eq!(v, &[0.5, 1.5, 0.0]);
        assert_eq!(chunk.labels, vec![1, 2]);
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 1);
        assert!(!r.next_chunk(&mut chunk).unwrap());
        // ragged rows are an error
        let mut bad = CsvChunks::from_bytes(b"1,1.0,2.0\n2,1.0\n".to_vec(), 8);
        assert!(bad.next_chunk(&mut chunk).is_err());
    }

    #[test]
    fn file_backend_reads_and_rewinds() {
        let path = std::env::temp_dir().join("scrb_reader_test.libsvm");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, TEXT).unwrap();
        let mut r = LibsvmChunks::from_path(&path, 3).unwrap();
        let mut chunk = SparseChunk::new();
        let mut rows = 0;
        while r.next_chunk(&mut chunk).unwrap() {
            rows += chunk.rows();
        }
        assert_eq!(rows, 4);
        assert_eq!(r.dim(), 4);
        r.reset().unwrap();
        let mut rows2 = 0;
        while r.next_chunk(&mut chunk).unwrap() {
            rows2 += chunk.rows();
        }
        assert_eq!(rows2, 4);
        std::fs::remove_file(&path).ok();
    }
}
