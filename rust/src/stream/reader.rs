//! Chunked dataset readers: fixed-row-count blocks of sparse rows,
//! yielded through reusable buffers.
//!
//! The [`ChunkReader`] trait is the ingestion boundary of the out-of-core
//! fit: a backend yields [`SparseChunk`]s of at most `chunk_rows` rows —
//! sparse rows stay sparse, nothing is ever densified into an N×d matrix
//! — and can [`ChunkReader::reset`] for another pass (the streaming fit
//! makes two: statistics, then featurization). All per-chunk state lives
//! in caller-owned buffers whose capacity survives across chunks *and*
//! across passes, so a warm steady-state chunk loop performs no heap
//! allocations (enforced by `tests/alloc.rs`).
//!
//! Two backends:
//! - [`LibsvmChunks`] — the LibSVM text format (`label idx:val ...`,
//!   1-based sparse indices), from a file path (buffered single-pass IO,
//!   rewound with one `seek`) or from in-memory bytes (tests, adapters).
//!   The in-memory loader [`crate::data::load_libsvm`] drains this same
//!   reader, so the streamed and batch parse paths cannot drift.
//! - [`CsvChunks`] — dense comma-separated rows (`label,v1,...,vd`), d
//!   fixed by the first data row.
//!
//! Feature dimension is discovered as rows stream by ([`ChunkReader::dim`]
//! is final only after a complete pass) — which is why the fit's first
//! pass doubles as the dimension scan.
//!
//! Every parse failure is a located [`ScrbError::BadRecord`] carrying the
//! source name, 1-based line number, byte offset of the line start, and
//! the quoted offending token — for LibSVM and CSV alike. Under
//! [`OnBadRecord::Quarantine`] (pushed down via
//! [`ChunkReader::set_policy`]) a bad line is rolled back, counted, and
//! sampled into a per-pass [`Quarantine`] report instead of aborting;
//! skipping is a pure function of the line text, so both passes of a fit
//! drop exactly the same rows. Raw I/O failures are
//! [`ScrbError::Transient`] — the retryable class [`super::GuardedReader`]
//! absorbs — never parse errors.
//!
//! [`ScrbError::BadRecord`]: crate::error::ScrbError::BadRecord
//! [`ScrbError::Transient`]: crate::error::ScrbError::Transient

use super::chunk::{RowMeta, SparseChunk};
use super::policy::{IngestPolicy, OnBadRecord, Quarantine};
use crate::error::{RecordError, RecordKind, ScrbError};
use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};

/// A rewindable source of fixed-row-count sparse chunks.
pub trait ChunkReader {
    /// Fill `chunk` (cleared first) with up to [`ChunkReader::chunk_rows`]
    /// rows. Returns `Ok(false)` when the stream is exhausted (the chunk
    /// is then empty); the final non-empty chunk may be short.
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError>;

    /// Rewind to the first row for another pass. Warm readers rewind
    /// without allocating.
    fn reset(&mut self) -> Result<(), ScrbError>;

    /// Feature dimension d observed so far. LibSVM discovers d as rows
    /// stream by, so this is final only after a complete pass; the CSV
    /// backend knows it from the first data row.
    fn dim(&self) -> usize;

    /// Target rows per chunk (the resident-input-memory knob: the
    /// featurize pass holds one `chunk_rows × d` dense scratch).
    fn chunk_rows(&self) -> usize;

    /// Name of the underlying source for error context (file path, or
    /// `"<memory>"`).
    fn source_name(&self) -> &str {
        "<stream>"
    }

    /// Push the ingest fault policy down to the line level. Readers with
    /// no line-level failure mode ignore it.
    fn set_policy(&mut self, _policy: &IngestPolicy) {}

    /// This reader's own per-pass quarantine counts, if it quarantines at
    /// all (decorators report theirs separately and merge).
    fn quarantine(&self) -> Option<&Quarantine> {
        None
    }
}

/// Clip a token for error context: bounded length, no control characters
/// (the offending text may be arbitrary garbage).
fn clip_token(tok: &str) -> String {
    let mut out = String::new();
    for c in tok.chars().take(32) {
        out.push(if c.is_control() { '?' } else { c });
    }
    if tok.chars().nth(32).is_some() {
        out.push('…');
    }
    out
}

/// A parser-level record rejection. File name and byte offset are blank
/// here — only the line pump knows them, and it patches them in before
/// the error escapes (see `process_line`).
fn rec_err(lineno: usize, token: &str, reason: impl Into<String>, kind: RecordKind) -> ScrbError {
    ScrbError::bad_record(RecordError {
        file: String::new(),
        line: lineno,
        byte: 0,
        token: clip_token(token),
        reason: reason.into(),
        kind,
    })
}

/// Parse one LibSVM line (`label idx:val ...`, 1-based strictly-ascending
/// indices) into `chunk`, tracking the running max dimension. Blank lines
/// and `#` comments are skipped (returns false). Shared by the chunked
/// reader and the in-memory loader so the two parse paths are one.
///
/// Ascending indices are the LibSVM convention; enforcing them here also
/// rules out duplicate indices within a row — which would make "presence"
/// ambiguous and break the streamed statistics' exact equivalence with
/// the densified scan. NaN/Inf labels or values are rejected as
/// [`RecordKind::NonFinite`] (they would silently poison the min/span
/// frame otherwise).
pub(crate) fn parse_libsvm_line(
    line: &str,
    lineno: usize,
    chunk: &mut SparseChunk,
    max_dim: &mut usize,
) -> Result<bool, ScrbError> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(false);
    }
    let mut parts = line.split_whitespace();
    let Some(label_tok) = parts.next() else { return Ok(false) };
    let labelf = label_tok
        .parse::<f64>()
        .map_err(|_| rec_err(lineno, label_tok, "bad label", RecordKind::Malformed))?;
    if !labelf.is_finite() {
        return Err(rec_err(lineno, label_tok, "non-finite label", RecordKind::NonFinite));
    }
    chunk.begin_row(labelf as i64);
    let mut prev_idx = 0usize;
    let mut row_max = 0usize;
    for tok in parts {
        let (is, vs) = tok.split_once(':').ok_or_else(|| {
            rec_err(lineno, tok, "bad feature (expected idx:val)", RecordKind::Malformed)
        })?;
        let idx: usize =
            is.parse().map_err(|_| rec_err(lineno, is, "bad index", RecordKind::Malformed))?;
        if idx == 0 {
            return Err(rec_err(lineno, is, "LibSVM indices are 1-based", RecordKind::Malformed));
        }
        if idx > u32::MAX as usize {
            return Err(rec_err(
                lineno,
                is,
                format!("index {idx} overflows u32"),
                RecordKind::Malformed,
            ));
        }
        if idx <= prev_idx {
            return Err(rec_err(
                lineno,
                tok,
                format!("indices must be strictly ascending ({prev_idx} then {idx})"),
                RecordKind::Malformed,
            ));
        }
        prev_idx = idx;
        let val: f64 =
            vs.parse().map_err(|_| rec_err(lineno, vs, "bad value", RecordKind::Malformed))?;
        if !val.is_finite() {
            return Err(rec_err(lineno, vs, "non-finite value", RecordKind::NonFinite));
        }
        row_max = row_max.max(idx);
        chunk.push_entry((idx - 1) as u32, val);
    }
    // commit the dimension only for rows that fully parse: a quarantined
    // row must not be able to grow d
    *max_dim = (*max_dim).max(row_max);
    chunk.end_row();
    Ok(true)
}

/// Feed one line through `parse` under the ingest policy: on success,
/// record the row's source context; on a bad record, roll the chunk back
/// to its pre-row state, patch the source name and byte offset into the
/// error, and either surface it (strict) or quarantine it. A free
/// function over disjoint `TextChunks` fields so the line pump can hold
/// its source borrow across the call.
#[allow(clippy::too_many_arguments)]
fn process_line(
    line: &str,
    lineno: usize,
    line_start: u64,
    name: &str,
    policy: &IngestPolicy,
    quarantine: &mut Quarantine,
    chunk: &mut SparseChunk,
    parse: &mut impl FnMut(&str, usize, &mut SparseChunk) -> Result<bool, ScrbError>,
) -> Result<(), ScrbError> {
    let (rows0, nnz0) = (chunk.rows(), chunk.nnz());
    match parse(line, lineno, chunk) {
        Ok(true) => {
            chunk.meta.push(RowMeta { line: lineno, byte: line_start });
            Ok(())
        }
        Ok(false) => Ok(()),
        Err(e) => {
            chunk.truncate_rows(rows0, nnz0);
            let ScrbError::BadRecord(mut rec) = e else { return Err(e) };
            rec.file = name.to_string();
            rec.byte = line_start;
            match policy.on_bad_record {
                OnBadRecord::Strict => Err(ScrbError::BadRecord(rec)),
                OnBadRecord::Quarantine => {
                    quarantine.record(*rec, policy.sample_cap);
                    Ok(())
                }
            }
        }
    }
}

/// Reject a line that is not valid UTF-8 (strict: error out; quarantine:
/// count and continue).
fn reject_invalid_utf8(
    lineno: usize,
    line_start: u64,
    name: &str,
    policy: &IngestPolicy,
    quarantine: &mut Quarantine,
) -> Result<(), ScrbError> {
    let rec = RecordError {
        file: name.to_string(),
        line: lineno,
        byte: line_start,
        token: "<invalid utf-8>".to_string(),
        reason: "invalid UTF-8".to_string(),
        kind: RecordKind::Malformed,
    };
    match policy.on_bad_record {
        OnBadRecord::Strict => Err(ScrbError::bad_record(rec)),
        OnBadRecord::Quarantine => {
            quarantine.record(rec, policy.sample_cap);
            Ok(())
        }
    }
}

/// Where a text reader's bytes come from.
enum Source {
    /// Buffered file handle; rewound with one `seek` (no reallocation).
    File(BufReader<File>),
    /// In-memory bytes walked by cursor (tests, adapters).
    Mem(Vec<u8>),
}

/// Shared line pump for the text backends: owns the byte source, the
/// reusable line buffer, the chunk loop, the rewind logic, and the
/// per-line fault policy. A backend is just this plus a per-line parser
/// and its dimension state — so line handling (and quarantine semantics)
/// can never drift between formats.
struct TextChunks {
    source: Source,
    /// Source name for error context (path or `"<memory>"`).
    name: String,
    /// Cursor into `Source::Mem` bytes.
    pos: usize,
    /// Byte offset of the next unread line's start (both backends).
    byte: u64,
    /// Reusable raw line buffer for `Source::File` (bytes, not `String`,
    /// so invalid UTF-8 is a quarantinable record with an exact byte
    /// span, not an opaque io error).
    line_buf: Vec<u8>,
    lineno: usize,
    chunk_rows: usize,
    policy: IngestPolicy,
    /// Per-pass line-level quarantine report; cleared on reset.
    quarantine: Quarantine,
    /// Byte-range window for shard readers: reading starts at
    /// `range_start` (a line boundary, the shard planner's job) and stops
    /// at the first line starting at or beyond `range_end`. `None` means
    /// "to EOF". Byte offsets in errors stay absolute, so a quarantine
    /// sample from shard 3 points at the same file position a sequential
    /// read would report.
    range_start: u64,
    range_end: Option<u64>,
}

impl TextChunks {
    fn from_path(path: &str, chunk_rows: usize) -> Result<TextChunks, ScrbError> {
        TextChunks::from_path_range(path, chunk_rows, 0, None)
    }

    fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> TextChunks {
        TextChunks::from_bytes_range(bytes, chunk_rows, 0, None)
    }

    /// Open `path` restricted to the byte window `[start, end)`. `start`
    /// must sit on a line boundary; `end` may fall mid-line (the line
    /// *starting* before `end` is read whole, which is exactly how the
    /// planner makes adjacent shards partition the file).
    fn from_path_range(
        path: &str,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> Result<TextChunks, ScrbError> {
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        let file = File::open(path).map_err(|e| ScrbError::io(path, e))?;
        let mut reader = BufReader::new(file);
        if start > 0 {
            reader.seek(SeekFrom::Start(start)).map_err(|e| ScrbError::io(path, e))?;
        }
        Ok(TextChunks {
            source: Source::File(reader),
            name: path.to_string(),
            pos: start as usize,
            byte: start,
            line_buf: Vec::new(),
            lineno: 0,
            chunk_rows,
            policy: IngestPolicy::default(),
            quarantine: Quarantine::default(),
            range_start: start,
            range_end: end,
        })
    }

    /// In-memory variant of [`TextChunks::from_path_range`].
    fn from_bytes_range(
        bytes: Vec<u8>,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> TextChunks {
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        TextChunks {
            source: Source::Mem(bytes),
            name: "<memory>".to_string(),
            pos: start as usize,
            byte: start,
            line_buf: Vec::new(),
            lineno: 0,
            chunk_rows,
            policy: IngestPolicy::default(),
            quarantine: Quarantine::default(),
            range_start: start,
            range_end: end,
        }
    }

    /// Fill `chunk` (cleared first) by feeding lines to `parse` until
    /// `chunk_rows` rows accumulate or the stream ends.
    fn next_chunk_with(
        &mut self,
        chunk: &mut SparseChunk,
        mut parse: impl FnMut(&str, usize, &mut SparseChunk) -> Result<bool, ScrbError>,
    ) -> Result<bool, ScrbError> {
        chunk.clear();
        while chunk.rows() < self.chunk_rows {
            // a line is read iff it *starts* inside the byte window, so
            // for any cut sequence adjacent windows partition the lines
            if self.range_end.is_some_and(|end| self.byte >= end) {
                break;
            }
            match &mut self.source {
                Source::Mem(bytes) => {
                    if self.pos >= bytes.len() {
                        break;
                    }
                    let rest = &bytes[self.pos..];
                    let take =
                        rest.iter().position(|&b| b == b'\n').map(|p| p + 1).unwrap_or(rest.len());
                    let line_start = self.byte;
                    self.pos += take;
                    self.byte += take as u64;
                    self.lineno += 1;
                    match std::str::from_utf8(&rest[..take]) {
                        Ok(line) => process_line(
                            line,
                            self.lineno,
                            line_start,
                            &self.name,
                            &self.policy,
                            &mut self.quarantine,
                            chunk,
                            &mut parse,
                        )?,
                        Err(_) => reject_invalid_utf8(
                            self.lineno,
                            line_start,
                            &self.name,
                            &self.policy,
                            &mut self.quarantine,
                        )?,
                    }
                }
                Source::File(reader) => {
                    self.line_buf.clear();
                    let n = reader.read_until(b'\n', &mut self.line_buf).map_err(|e| {
                        ScrbError::transient(format!(
                            "read error at line {}: {e}",
                            self.lineno + 1
                        ))
                    })?;
                    if n == 0 {
                        break;
                    }
                    let line_start = self.byte;
                    self.byte += n as u64;
                    self.lineno += 1;
                    match std::str::from_utf8(&self.line_buf) {
                        Ok(line) => process_line(
                            line,
                            self.lineno,
                            line_start,
                            &self.name,
                            &self.policy,
                            &mut self.quarantine,
                            chunk,
                            &mut parse,
                        )?,
                        Err(_) => reject_invalid_utf8(
                            self.lineno,
                            line_start,
                            &self.name,
                            &self.policy,
                            &mut self.quarantine,
                        )?,
                    }
                }
            }
        }
        Ok(chunk.rows() > 0)
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.pos = self.range_start as usize;
        self.byte = self.range_start;
        self.lineno = 0;
        self.quarantine.clear();
        if let Source::File(reader) = &mut self.source {
            reader
                .seek(SeekFrom::Start(self.range_start))
                .map_err(|e| ScrbError::io(self.name.clone(), e))?;
        }
        Ok(())
    }
}

/// Chunked LibSVM reader (see module docs for the format).
pub struct LibsvmChunks {
    text: TextChunks,
    max_dim: usize,
}

impl LibsvmChunks {
    /// Open `path` for chunked reading.
    pub fn from_path(path: &str, chunk_rows: usize) -> Result<LibsvmChunks, ScrbError> {
        Ok(LibsvmChunks { text: TextChunks::from_path(path, chunk_rows)?, max_dim: 0 })
    }

    /// Read from in-memory LibSVM text.
    pub fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> LibsvmChunks {
        LibsvmChunks { text: TextChunks::from_bytes(bytes, chunk_rows), max_dim: 0 }
    }

    /// Open `path` restricted to the byte window `[start, end)` — the
    /// shard-reader form. `start` must sit on a line boundary (byte 0 or
    /// one past a `\n`); a line is read iff it *starts* inside the
    /// window, so adjacent windows partition the file's lines for any
    /// cut sequence. `end = None` reads to EOF.
    pub fn from_path_range(
        path: &str,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> Result<LibsvmChunks, ScrbError> {
        Ok(LibsvmChunks {
            text: TextChunks::from_path_range(path, chunk_rows, start, end)?,
            max_dim: 0,
        })
    }

    /// In-memory variant of [`LibsvmChunks::from_path_range`].
    pub fn from_bytes_range(
        bytes: Vec<u8>,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> LibsvmChunks {
        LibsvmChunks { text: TextChunks::from_bytes_range(bytes, chunk_rows, start, end), max_dim: 0 }
    }
}

impl ChunkReader for LibsvmChunks {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        let max_dim = &mut self.max_dim;
        self.text
            .next_chunk_with(chunk, |line, lineno, chunk| {
                parse_libsvm_line(line, lineno, chunk, max_dim)
            })
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.text.reset()
    }

    fn dim(&self) -> usize {
        self.max_dim
    }

    fn chunk_rows(&self) -> usize {
        self.text.chunk_rows
    }

    fn source_name(&self) -> &str {
        &self.text.name
    }

    fn set_policy(&mut self, policy: &IngestPolicy) {
        self.text.policy = policy.clone();
    }

    fn quarantine(&self) -> Option<&Quarantine> {
        Some(&self.text.quarantine)
    }
}

/// Parse one dense CSV line (`label,v1,...,vd`) into `chunk`. `d` is
/// `None` until the first data row fixes it; later rows must match
/// (ragged rows are malformed records). NaN/Inf labels or values are
/// rejected as [`RecordKind::NonFinite`].
pub(crate) fn parse_csv_line(
    line: &str,
    lineno: usize,
    chunk: &mut SparseChunk,
    d: &mut Option<usize>,
) -> Result<bool, ScrbError> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(false);
    }
    let mut parts = line.split(',');
    let Some(label_tok) = parts.next() else { return Ok(false) };
    let label_tok = label_tok.trim();
    let labelf = label_tok
        .parse::<f64>()
        .map_err(|_| rec_err(lineno, label_tok, "bad label", RecordKind::Malformed))?;
    if !labelf.is_finite() {
        return Err(rec_err(lineno, label_tok, "non-finite label", RecordKind::NonFinite));
    }
    chunk.begin_row(labelf as i64);
    let mut count = 0usize;
    for tok in parts {
        let tok = tok.trim();
        let val: f64 =
            tok.parse().map_err(|_| rec_err(lineno, tok, "bad value", RecordKind::Malformed))?;
        if !val.is_finite() {
            return Err(rec_err(lineno, tok, "non-finite value", RecordKind::NonFinite));
        }
        chunk.push_entry(count as u32, val);
        count += 1;
    }
    match *d {
        None => *d = Some(count),
        Some(expect) if expect != count => {
            return Err(rec_err(
                lineno,
                line,
                format!("{count} features, expected {expect}"),
                RecordKind::Malformed,
            ));
        }
        _ => {}
    }
    chunk.end_row();
    Ok(true)
}

/// Chunked dense-CSV reader: one `label,v1,...,vd` row per line, d fixed
/// by the first data row. Rows are dense, so every value (zeros included)
/// is an explicit chunk entry.
pub struct CsvChunks {
    text: TextChunks,
    d: Option<usize>,
}

impl CsvChunks {
    /// Open `path` for chunked reading.
    pub fn from_path(path: &str, chunk_rows: usize) -> Result<CsvChunks, ScrbError> {
        Ok(CsvChunks { text: TextChunks::from_path(path, chunk_rows)?, d: None })
    }

    /// Read from in-memory CSV text.
    pub fn from_bytes(bytes: Vec<u8>, chunk_rows: usize) -> CsvChunks {
        CsvChunks { text: TextChunks::from_bytes(bytes, chunk_rows), d: None }
    }

    /// Open `path` restricted to the byte window `[start, end)`; see
    /// [`LibsvmChunks::from_path_range`] for the window contract.
    pub fn from_path_range(
        path: &str,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> Result<CsvChunks, ScrbError> {
        Ok(CsvChunks { text: TextChunks::from_path_range(path, chunk_rows, start, end)?, d: None })
    }

    /// In-memory variant of [`CsvChunks::from_path_range`].
    pub fn from_bytes_range(
        bytes: Vec<u8>,
        chunk_rows: usize,
        start: u64,
        end: Option<u64>,
    ) -> CsvChunks {
        CsvChunks { text: TextChunks::from_bytes_range(bytes, chunk_rows, start, end), d: None }
    }
}

impl ChunkReader for CsvChunks {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        let d = &mut self.d;
        self.text
            .next_chunk_with(chunk, |line, lineno, chunk| parse_csv_line(line, lineno, chunk, d))
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        self.text.reset()
    }

    fn dim(&self) -> usize {
        self.d.unwrap_or(0)
    }

    fn chunk_rows(&self) -> usize {
        self.text.chunk_rows
    }

    fn source_name(&self) -> &str {
        &self.text.name
    }

    fn set_policy(&mut self, policy: &IngestPolicy) {
        self.text.policy = policy.clone();
    }

    fn quarantine(&self) -> Option<&Quarantine> {
        Some(&self.text.quarantine)
    }
}

/// A [`ChunkReader`] over a sequence of part readers, drained in order —
/// the multi-file dataset backend (`scrb fit --data 'a.svm,b.svm'`, or a
/// glob). Semantically the chain *is* the concatenation of its parts: a
/// fit over a `ChainChunks` is byte-identical to a fit over one file
/// holding the parts' lines in order.
///
/// Each part keeps its own per-pass quarantine (with its own file name
/// and per-file line numbers); the chain absorbs a part's report the
/// moment the part is exhausted, so after a full pass
/// [`ChunkReader::quarantine`] is the deterministic part-ordered merge.
pub struct ChainChunks {
    parts: Vec<Box<dyn ChunkReader + Send>>,
    cur: usize,
    chunk_rows: usize,
    name: String,
    /// Part-ordered merge of exhausted parts' per-pass reports.
    quarantine: Quarantine,
}

impl ChainChunks {
    /// Chain `parts` in order. Panics on an empty part list (an empty
    /// *part* is fine; a dataset with no sources is a planner bug).
    pub fn new(parts: Vec<Box<dyn ChunkReader + Send>>) -> ChainChunks {
        assert!(!parts.is_empty(), "ChainChunks needs at least one part");
        let chunk_rows = parts[0].chunk_rows();
        let name = if parts.len() == 1 {
            parts[0].source_name().to_string()
        } else {
            format!("<chain of {} sources>", parts.len())
        };
        ChainChunks { parts, cur: 0, chunk_rows, name, quarantine: Quarantine::default() }
    }
}

impl ChunkReader for ChainChunks {
    fn next_chunk(&mut self, chunk: &mut SparseChunk) -> Result<bool, ScrbError> {
        while self.cur < self.parts.len() {
            if self.parts[self.cur].next_chunk(chunk)? {
                return Ok(true);
            }
            if let Some(q) = self.parts[self.cur].quarantine() {
                self.quarantine.absorb(q);
            }
            self.cur += 1;
        }
        chunk.clear();
        Ok(false)
    }

    fn reset(&mut self) -> Result<(), ScrbError> {
        for part in &mut self.parts {
            part.reset()?;
        }
        self.cur = 0;
        self.quarantine.clear();
        Ok(())
    }

    fn dim(&self) -> usize {
        self.parts.iter().map(|p| p.dim()).max().unwrap_or(0)
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn source_name(&self) -> &str {
        &self.name
    }

    fn set_policy(&mut self, policy: &IngestPolicy) {
        for part in &mut self.parts {
            part.set_policy(policy);
        }
    }

    fn quarantine(&self) -> Option<&Quarantine> {
        Some(&self.quarantine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TEXT: &str = "\
# comment
+1 1:0.5 3:1.5

-1 2:2.0
+1 1:1.0 2:1.0 3:1.0
2 4:0.25
";

    #[test]
    fn libsvm_chunks_cover_all_rows() {
        let mut r = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        let mut rows = 0usize;
        let mut nnz = 0usize;
        let mut chunks = 0usize;
        while r.next_chunk(&mut chunk).unwrap() {
            assert!(chunk.rows() <= 2);
            assert_eq!(chunk.meta.len(), chunk.rows(), "meta stays row-aligned");
            rows += chunk.rows();
            nnz += chunk.nnz();
            chunks += 1;
        }
        assert_eq!(rows, 4);
        assert_eq!(nnz, 2 + 1 + 3 + 1);
        assert_eq!(chunks, 2);
        assert_eq!(r.dim(), 4);
        // exhausted reader keeps returning false with an empty chunk
        assert!(!r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 0);
    }

    #[test]
    fn libsvm_reset_replays_identically() {
        let mut r = LibsvmChunks::from_bytes(TEXT.as_bytes().to_vec(), 3);
        let mut chunk = SparseChunk::new();
        let mut first: Vec<(Vec<u32>, Vec<f64>, i64)> = Vec::new();
        while r.next_chunk(&mut chunk).unwrap() {
            for i in 0..chunk.rows() {
                let (c, v) = chunk.row(i);
                first.push((c.to_vec(), v.to_vec(), chunk.labels[i]));
            }
        }
        r.reset().unwrap();
        let mut second = Vec::new();
        while r.next_chunk(&mut chunk).unwrap() {
            for i in 0..chunk.rows() {
                let (c, v) = chunk.row(i);
                second.push((c.to_vec(), v.to_vec(), chunk.labels[i]));
            }
        }
        assert_eq!(first, second);
        assert_eq!(first.len(), 4);
        assert_eq!(first[0].0, vec![0, 2]);
        assert_eq!(first[0].2, 1);
        assert_eq!(first[1].0, vec![1]);
    }

    #[test]
    fn libsvm_rejects_malformed() {
        for bad in [
            "1 nocolon\n",
            "1 0:1.0\n",
            "abc 1:1\n",
            "1 9999999999999:1\n",
            "1 2:1.0 2:2.0\n", // duplicate index
            "1 3:1.0 2:2.0\n", // out-of-order indices
            "1 1:nan\n",       // non-finite value
            "inf 1:1.0\n",     // non-finite label
        ] {
            let mut r = LibsvmChunks::from_bytes(bad.as_bytes().to_vec(), 4);
            let mut chunk = SparseChunk::new();
            assert!(r.next_chunk(&mut chunk).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn strict_errors_carry_location() {
        let text = "1 1:0.5\n2 2:oops\n";
        let mut r = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), 8);
        let mut chunk = SparseChunk::new();
        let err = r.next_chunk(&mut chunk).unwrap_err();
        let ScrbError::BadRecord(rec) = err else { panic!("expected BadRecord, got {err}") };
        assert_eq!(rec.file, "<memory>");
        assert_eq!(rec.line, 2);
        assert_eq!(rec.byte, 8, "byte offset of the offending line's start");
        assert_eq!(rec.token, "oops");
        assert_eq!(rec.kind, RecordKind::Malformed);
    }

    #[test]
    fn quarantine_skips_bad_lines_with_exact_counts() {
        let quarantine_policy = IngestPolicy {
            on_bad_record: OnBadRecord::Quarantine,
            ..IngestPolicy::default()
        };
        let text = "1 1:0.5\n1 nocolon\n2 1:nan\n-1 2:2.0 9:0.1\nnan 1:1.0\n2 1:1.0\n";
        let mut r = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), 64);
        r.set_policy(&quarantine_policy);
        let mut chunk = SparseChunk::new();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, -1, 2], "only good rows survive");
        assert_eq!(chunk.meta[1].line, 4, "meta points at the source line");
        let q = r.quarantine().unwrap();
        assert_eq!(q.malformed, 1);
        assert_eq!(q.non_finite, 2);
        assert_eq!(q.samples.len(), 3);
        assert_eq!(q.samples[0].line, 2);
        assert_eq!(q.samples[1].kind, RecordKind::NonFinite);
        // d is untouched by quarantined rows; survivors still grow it
        assert_eq!(r.dim(), 9);
        // a second pass replays the same decisions from a clean slate
        r.reset().unwrap();
        assert_eq!(r.quarantine().unwrap().skipped(), 0);
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, -1, 2]);
        assert_eq!(r.quarantine().unwrap().skipped(), 3);
    }

    #[test]
    fn quarantine_handles_invalid_utf8_and_partial_rows() {
        let quarantine_policy = IngestPolicy {
            on_bad_record: OnBadRecord::Quarantine,
            ..IngestPolicy::default()
        };
        // middle line is invalid UTF-8; last bad line fails mid-row after
        // two good entries (rollback must discard them)
        let mut text = b"1 1:0.5\n".to_vec();
        text.extend_from_slice(&[0xff, 0xfe, b'\n']);
        text.extend_from_slice(b"2 1:1.0 2:2.0 3:bad\n-1 1:0.25\n");
        let mut r = LibsvmChunks::from_bytes(text, 64);
        r.set_policy(&quarantine_policy);
        let mut chunk = SparseChunk::new();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, -1]);
        assert_eq!(chunk.nnz(), 2, "partial row fully rolled back");
        let q = r.quarantine().unwrap();
        assert_eq!(q.malformed, 2);
        assert_eq!(q.samples[0].token, "<invalid utf-8>");
        // strict mode refuses the same bytes outright
        let mut text = b"1 1:0.5\n".to_vec();
        text.extend_from_slice(&[0xff, 0xfe, b'\n']);
        let mut strict = LibsvmChunks::from_bytes(text, 64);
        assert!(strict.next_chunk(&mut chunk).is_err());
    }

    #[test]
    fn csv_chunks_parse_dense_rows() {
        let text = "# header\n1, 0.5, 1.5, 0.0\n2, 1.0, -1.0, 3.0\n1, 0.0, 0.0, 0.0\n";
        let mut r = CsvChunks::from_bytes(text.as_bytes().to_vec(), 2);
        let mut chunk = SparseChunk::new();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 2);
        assert_eq!(r.dim(), 3);
        let (c, v) = chunk.row(0);
        assert_eq!(c, &[0, 1, 2]);
        assert_eq!(v, &[0.5, 1.5, 0.0]);
        assert_eq!(chunk.labels, vec![1, 2]);
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.rows(), 1);
        assert!(!r.next_chunk(&mut chunk).unwrap());
        // ragged rows are a located error
        let mut bad = CsvChunks::from_bytes(b"1,1.0,2.0\n2,1.0\n".to_vec(), 8);
        let err = bad.next_chunk(&mut chunk).unwrap_err();
        let ScrbError::BadRecord(rec) = err else { panic!("expected BadRecord, got {err}") };
        assert_eq!(rec.line, 2);
        assert_eq!(rec.byte, 10);
        // non-finite CSV values are typed NonFinite
        let mut nf = CsvChunks::from_bytes(b"1,1.0,inf\n".to_vec(), 8);
        let err = nf.next_chunk(&mut chunk).unwrap_err();
        let ScrbError::BadRecord(rec) = err else { panic!("expected BadRecord, got {err}") };
        assert_eq!(rec.kind, RecordKind::NonFinite);
    }

    #[test]
    fn csv_quarantine_keeps_passes_consistent() {
        let quarantine_policy = IngestPolicy {
            on_bad_record: OnBadRecord::Quarantine,
            ..IngestPolicy::default()
        };
        let text = "1,0.5,1.5\n2,nan,1.0\n3,1.0\n4,2.0,3.0\n";
        let mut r = CsvChunks::from_bytes(text.as_bytes().to_vec(), 64);
        r.set_policy(&quarantine_policy);
        let mut chunk = SparseChunk::new();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, 4]);
        assert_eq!(r.quarantine().unwrap().non_finite, 1);
        assert_eq!(r.quarantine().unwrap().malformed, 1);
        r.reset().unwrap();
        assert!(r.next_chunk(&mut chunk).unwrap());
        assert_eq!(chunk.labels, vec![1, 4], "same rows skipped on every pass");
    }

    #[test]
    fn byte_ranges_partition_the_lines() {
        let bytes = TEXT.as_bytes().to_vec();
        // collect (label, cols) per row for a reader
        fn drain(r: &mut dyn ChunkReader) -> Vec<(i64, Vec<u32>)> {
            let mut chunk = SparseChunk::new();
            let mut out = Vec::new();
            while r.next_chunk(&mut chunk).unwrap() {
                for i in 0..chunk.rows() {
                    out.push((chunk.labels[i], chunk.row(i).0.to_vec()));
                }
            }
            out
        }
        let mut whole = LibsvmChunks::from_bytes(bytes.clone(), 2);
        let all = drain(&mut whole);
        assert_eq!(all.len(), 4);
        // every line-boundary cut partitions the rows: the two windows
        // together replay the sequential read exactly
        let n = bytes.len() as u64;
        for cut in 0..=n {
            let on_boundary =
                cut == 0 || cut == n || bytes[cut as usize - 1] == b'\n';
            if !on_boundary {
                continue; // mid-line starts are the planner's job to avoid
            }
            let mut a = LibsvmChunks::from_bytes_range(bytes.clone(), 2, 0, Some(cut));
            let mut b = LibsvmChunks::from_bytes_range(bytes.clone(), 2, cut, None);
            let head = drain(&mut a);
            let tail = drain(&mut b);
            let mut got = head.clone();
            got.extend(tail.iter().cloned());
            assert_eq!(got, all, "cut at byte {cut}");
            // ranged readers rewind to their own window start, not byte 0
            a.reset().unwrap();
            b.reset().unwrap();
            assert_eq!(drain(&mut a), head, "reset replays the head window");
            assert_eq!(drain(&mut b), tail, "reset replays the tail window");
        }
        // an empty window yields zero rows and keeps returning false
        let mut empty = LibsvmChunks::from_bytes_range(bytes.clone(), 2, 0, Some(0));
        assert!(drain(&mut empty).is_empty());
        let mut chunk = SparseChunk::new();
        assert!(!empty.next_chunk(&mut chunk).unwrap());
    }

    #[test]
    fn chain_concatenates_parts_and_merges_quarantine() {
        let part_a = "1 1:0.5\n1 nocolon\n2 2:1.5\n";
        let part_b = "# comment\n-1 3:2.0\nnan 1:1.0\n2 1:0.25\n";
        let policy = IngestPolicy {
            on_bad_record: OnBadRecord::Quarantine,
            ..IngestPolicy::default()
        };
        let mut chain = ChainChunks::new(vec![
            Box::new(LibsvmChunks::from_bytes(part_a.as_bytes().to_vec(), 2)),
            Box::new(LibsvmChunks::from_bytes(part_b.as_bytes().to_vec(), 2)),
        ]);
        chain.set_policy(&policy);
        let mut chunk = SparseChunk::new();
        let mut labels = Vec::new();
        while chain.next_chunk(&mut chunk).unwrap() {
            labels.extend_from_slice(&chunk.labels);
        }
        assert_eq!(labels, vec![1, 2, -1, 2], "parts drained in order");
        assert_eq!(chain.dim(), 3, "dim is the max over parts");
        let q = chain.quarantine().unwrap();
        assert_eq!(q.malformed, 1);
        assert_eq!(q.non_finite, 1);
        assert_eq!(q.samples.len(), 2);
        assert_eq!(q.samples[0].line, 2, "per-part line numbers survive the merge");
        assert_eq!(q.samples[1].line, 3);
        // reset replays identically from a clean report
        chain.reset().unwrap();
        assert_eq!(chain.quarantine().unwrap().skipped(), 0);
        let mut again = Vec::new();
        while chain.next_chunk(&mut chunk).unwrap() {
            again.extend_from_slice(&chunk.labels);
        }
        assert_eq!(again, labels);
        assert_eq!(chain.quarantine().unwrap().skipped(), 2);
    }

    #[test]
    fn file_backend_reads_and_rewinds() {
        let path = std::env::temp_dir().join("scrb_reader_test.libsvm");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, TEXT).unwrap();
        let mut r = LibsvmChunks::from_path(&path, 3).unwrap();
        let mut chunk = SparseChunk::new();
        let mut rows = 0;
        while r.next_chunk(&mut chunk).unwrap() {
            rows += chunk.rows();
        }
        assert_eq!(rows, 4);
        assert_eq!(r.dim(), 4);
        assert_eq!(r.source_name(), path);
        r.reset().unwrap();
        let mut rows2 = 0;
        while r.next_chunk(&mut chunk).unwrap() {
            rows2 += chunk.rows();
        }
        assert_eq!(rows2, 4);
        std::fs::remove_file(&path).ok();
    }
}
