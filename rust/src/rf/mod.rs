//! Random Fourier features (Rahimi–Recht) — the baseline random-feature
//! family the paper compares RB against (SC_RF / SV_RF / KK_RF).
//!
//! z(x) = √(2/R)·cos(Wx + b), with the rows of W drawn from the kernel's
//! spectral density: Normal(0, 1/σ²) for the Gaussian kernel, Cauchy(0, 1/σ)
//! for the Laplacian kernel — so RB and RF approximate the *same* kernel in
//! the Fig. 2 convergence comparison.

use crate::config::Kernel;
use crate::linalg::Mat;
use crate::util::rng::Pcg;
use crate::util::threads::parallel_rows_mut;

/// Spectral sample: projection matrix W (d×R) and phases b (R).
pub struct RfMap {
    pub w: Mat,
    pub b: Vec<f64>,
    pub kernel: Kernel,
}

impl RfMap {
    /// Draw an RF map with `r` features for the given kernel.
    pub fn sample(kernel: Kernel, d: usize, r: usize, seed: u64) -> RfMap {
        let mut rng = Pcg::new(seed, 0x0f0f);
        let mut w = Mat::zeros(d, r);
        match kernel {
            Kernel::Gaussian { sigma } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() / sigma;
                }
            }
            Kernel::Laplacian { sigma } => {
                for v in w.data.iter_mut() {
                    *v = rng.cauchy() / sigma;
                }
            }
        }
        let b: Vec<f64> = (0..r).map(|_| rng.range_f64(0.0, 2.0 * std::f64::consts::PI)).collect();
        RfMap { w, b, kernel }
    }

    /// Number of features R.
    pub fn r(&self) -> usize {
        self.b.len()
    }

    /// Apply the map: Z = √(2/R)·cos(X·W + b), N×R dense.
    pub fn features(&self, x: &Mat) -> Mat {
        let mut z = x.matmul(&self.w);
        let r = self.r();
        let scale = (2.0 / r as f64).sqrt();
        let b = &self.b;
        parallel_rows_mut(&mut z.data, r, |_row0, chunk| {
            for row in chunk.chunks_mut(r) {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = scale * (*v + b[j]).cos();
                }
            }
        });
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::kernel_matrix;

    fn rand_data(rng: &mut Pcg, n: usize, d: usize) -> Mat {
        Mat::from_vec(n, d, (0..n * d).map(|_| rng.f64()).collect())
    }

    #[test]
    fn gram_approximates_gaussian_kernel() {
        let mut rng = Pcg::seed(111);
        let x = rand_data(&mut rng, 50, 3);
        let kernel = Kernel::Gaussian { sigma: 0.8 };
        let exact = kernel_matrix(kernel, &x);
        let mut errs = Vec::new();
        for &r in &[32usize, 1024] {
            let map = RfMap::sample(kernel, 3, r, 5);
            let z = map.features(&x);
            let approx = z.matmul_t(&z);
            errs.push(approx.sub(&exact).frob_norm() / exact.frob_norm());
        }
        assert!(errs[1] < errs[0], "more features must reduce error: {errs:?}");
        assert!(errs[1] < 0.1, "R=1024 err {}", errs[1]);
    }

    #[test]
    fn gram_approximates_laplacian_kernel() {
        let mut rng = Pcg::seed(112);
        let x = rand_data(&mut rng, 40, 2);
        let kernel = Kernel::Laplacian { sigma: 1.2 };
        let exact = kernel_matrix(kernel, &x);
        let map = RfMap::sample(kernel, 2, 4096, 7);
        let z = map.features(&x);
        let approx = z.matmul_t(&z);
        let err = approx.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(err < 0.12, "Laplacian RF err {err}");
    }

    #[test]
    fn feature_scale_bounded() {
        let mut rng = Pcg::seed(113);
        let x = rand_data(&mut rng, 20, 4);
        let map = RfMap::sample(Kernel::Gaussian { sigma: 1.0 }, 4, 64, 3);
        let z = map.features(&x);
        let bound = (2.0f64 / 64.0).sqrt() + 1e-12;
        assert!(z.data.iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn deterministic_in_seed() {
        let map1 = RfMap::sample(Kernel::Gaussian { sigma: 1.0 }, 3, 16, 9);
        let map2 = RfMap::sample(Kernel::Gaussian { sigma: 1.0 }, 3, 16, 9);
        assert_eq!(map1.w, map2.w);
        assert_eq!(map1.b, map2.b);
    }
}
