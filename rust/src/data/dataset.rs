//! Dataset container and preprocessing.

use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// A labeled clustering dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// N×d feature matrix.
    pub x: Mat,
    /// Ground-truth labels, length N.
    pub y: Vec<usize>,
    /// Number of true classes K.
    pub k: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<usize>) -> Dataset {
        assert_eq!(x.rows, y.len(), "label/row mismatch");
        let k = y.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        Dataset { name: name.into(), x, y, k }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Per-dimension min-max parameters of the current rows: `(min, span)`
    /// with span 1.0 for constant dimensions — exactly what
    /// [`Dataset::minmax_normalize`] applies. Callers that fit a serving
    /// model keep these (the fitted frame) so out-of-sample batches can be
    /// normalized **by the training statistics**, not their own.
    pub fn minmax_params(&self) -> (Vec<f64>, Vec<f64>) {
        minmax_params(&self.x)
    }

    /// Apply an explicit min-max frame: `x[i][j] ← (x[i][j] − lo[j]) / span[j]`.
    pub fn apply_minmax(&mut self, lo: &[f64], span: &[f64]) {
        let (n, d) = (self.x.rows, self.x.cols);
        assert_eq!(lo.len(), d, "one min per dimension");
        assert_eq!(span.len(), d, "one span per dimension");
        for i in 0..n {
            let row = self.x.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - lo[j]) / span[j];
            }
        }
    }

    /// Min-max scale every dimension to [0, 1] (constant dims collapse to
    /// 0). Standard preprocessing before kernel methods — bin widths and
    /// bandwidths then live on a comparable scale across datasets.
    pub fn minmax_normalize(&mut self) {
        if self.x.rows == 0 {
            return;
        }
        let (lo, span) = self.minmax_params();
        self.apply_minmax(&lo, &span);
    }

    /// Shuffle rows (and labels) in place.
    pub fn shuffle(&mut self, rng: &mut Pcg) {
        let n = self.n();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            if i != j {
                // swap rows i and j of x
                let cols = self.x.cols;
                for c in 0..cols {
                    let a = self.x.at(i, c);
                    let b = self.x.at(j, c);
                    self.x.set(i, c, b);
                    self.x.set(j, c, a);
                }
                self.y.swap(i, j);
            }
        }
    }

    /// Keep only the first `n` rows (after an external shuffle).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.n() {
            return;
        }
        self.x = self.x.row_block(0, n);
        self.y.truncate(n);
        self.k = self.y.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    }

    /// Per-class sizes.
    pub fn class_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &c in &self.y {
            sizes[c] += 1;
        }
        sizes
    }
}

/// Per-dimension `(min, span)` of a matrix, span 1.0 for constant
/// dimensions. The **one** definition of the min-max frame: the
/// [`Dataset`] preprocessing, the pipeline's min-max normalize stage,
/// and (bit-for-bit, by its own accumulation) the streaming stats pass
/// all agree on it — the streamed-vs-in-memory byte-identity contract
/// depends on there being exactly one rule.
pub fn minmax_params(x: &Mat) -> (Vec<f64>, Vec<f64>) {
    let (n, d) = (x.rows, x.cols);
    let mut lo = vec![f64::INFINITY; d];
    let mut hi = vec![f64::NEG_INFINITY; d];
    for i in 0..n {
        for (j, &v) in x.row(i).iter().enumerate() {
            lo[j] = lo[j].min(v);
            hi[j] = hi[j].max(v);
        }
    }
    let span: Vec<f64> =
        lo.iter().zip(hi.iter()).map(|(&l, &h)| if h > l { h - l } else { 1.0 }).collect();
    (lo, span)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Mat::from_vec(4, 2, vec![0.0, 10.0, 2.0, 30.0, 4.0, 20.0, 2.0, 10.0]);
        Dataset::new("toy", x, vec![0, 1, 1, 0])
    }

    #[test]
    fn normalize_into_unit_box() {
        let mut ds = toy();
        ds.minmax_normalize();
        for i in 0..ds.n() {
            for &v in ds.x.row(i) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
        assert_eq!(ds.x.at(0, 0), 0.0);
        assert_eq!(ds.x.at(2, 0), 1.0);
    }

    #[test]
    fn shuffle_preserves_pairs() {
        let mut ds = toy();
        let before: Vec<(Vec<f64>, usize)> =
            (0..4).map(|i| (ds.x.row(i).to_vec(), ds.y[i])).collect();
        let mut rng = Pcg::seed(3);
        ds.shuffle(&mut rng);
        let mut after: Vec<(Vec<f64>, usize)> =
            (0..4).map(|i| (ds.x.row(i).to_vec(), ds.y[i])).collect();
        for b in &before {
            let pos = after.iter().position(|a| a == b).expect("row/label pair lost");
            after.remove(pos);
        }
    }

    #[test]
    fn truncate_updates_k() {
        let mut ds = toy();
        ds.truncate(2);
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.k, 2);
    }

    #[test]
    fn class_sizes_sum() {
        let ds = toy();
        assert_eq!(ds.class_sizes(), vec![2, 2]);
    }
}
