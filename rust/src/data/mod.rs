//! Dataset substrate: containers, synthetic Table-1 stand-ins, and a
//! LibSVM parser for real benchmark files.

pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::Dataset;
pub use libsvm::{compact_labels, dataset_from_chunks, load_libsvm, parse_libsvm};
pub use synth::{
    concentric_rings, gaussian_blobs, latent_blobs, paper_benchmark, spec_by_name, two_moons,
    BenchSpec, PAPER_BENCHMARKS, SUSY,
};
