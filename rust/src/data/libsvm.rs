//! LibSVM-format dataset parser, so the real Table 1 benchmarks drop in
//! when their files are available (`scrb run --data path.libsvm`, and the
//! `fit`/`predict` serving commands). Malformed lines surface as typed,
//! *located* [`ScrbError::BadRecord`] values carrying the file, 1-based
//! line, byte offset, and offending token (the same
//! [`crate::error::RecordError`] context the CSV reader emits) — one
//! clean line at the CLI, never an abort. Under
//! [`crate::stream::OnBadRecord::Quarantine`] the same records are
//! skipped with exact counts instead of failing the run.
//!
//! Format per line: `<label> <index>:<value> <index>:<value> ...`
//! Indices are 1-based, strictly ascending within a row (the LibSVM
//! convention — also what keeps streamed statistics exactly equivalent to
//! the densified scan), and may be sparse; labels may be arbitrary
//! integers/floats (compacted to 0..K−1 in first-seen sorted order).
//!
//! The in-memory loader is a thin drain over the *chunked* reader
//! ([`crate::stream::LibsvmChunks`]): one pass discovers rows, features,
//! and dimension together in flat buffers (no per-row `Vec`s, no second
//! scan), and the streaming fit parses through the identical code path —
//! the two loaders cannot drift.

use super::dataset::Dataset;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::stream::{ChunkReader, LibsvmChunks, SparseChunk};
use std::collections::BTreeMap;
use std::io::BufRead;

/// Compact arbitrary integer labels to `0..K` in sorted raw-value order
/// (the paper benchmarks use ad-hoc label alphabets). Returns the
/// compacted labels and K.
pub fn compact_labels(raw: &[i64]) -> (Vec<usize>, usize) {
    let uniq: BTreeMap<i64, usize> = {
        let mut set: Vec<i64> = raw.to_vec();
        set.sort_unstable();
        set.dedup();
        set.into_iter().enumerate().map(|(i, l)| (l, i)).collect()
    };
    (raw.iter().map(|l| uniq[l]).collect(), uniq.len())
}

/// Drain a chunked reader into an in-memory [`Dataset`]: rows accumulate
/// sparse in flat buffers during the single pass, densification happens
/// once at the end when the final dimension is known.
pub fn dataset_from_chunks(
    reader: &mut dyn ChunkReader,
    name: &str,
) -> Result<Dataset, ScrbError> {
    let mut chunk = SparseChunk::new();
    let mut indptr: Vec<usize> = vec![0];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    let mut labels: Vec<i64> = Vec::new();
    while reader.next_chunk(&mut chunk)? {
        for i in 0..chunk.rows() {
            let (cols, vals) = chunk.row(i);
            indices.extend_from_slice(cols);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        labels.extend_from_slice(&chunk.labels);
    }
    if labels.is_empty() {
        return Err(ScrbError::invalid_input("empty dataset"));
    }
    let n = labels.len();
    let d = reader.dim();
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let row = x.row_mut(i);
        for p in indptr[i]..indptr[i + 1] {
            row[indices[p] as usize] = values[p];
        }
    }
    let (y, _k) = compact_labels(&labels);
    Ok(Dataset::new(name, x, y))
}

/// Rows per chunk for the in-memory loaders (IO granularity only — the
/// whole dataset is materialized anyway).
const LOAD_CHUNK_ROWS: usize = 8192;

/// Parse a LibSVM text stream (fully in memory).
pub fn parse_libsvm<R: BufRead>(mut reader: R, name: &str) -> Result<Dataset, ScrbError> {
    let mut bytes = Vec::new();
    reader
        .read_to_end(&mut bytes)
        .map_err(|e| ScrbError::parse(format!("read error: {e}")))?;
    let mut chunks = LibsvmChunks::from_bytes(bytes, LOAD_CHUNK_ROWS);
    dataset_from_chunks(&mut chunks, name)
}

/// Load a LibSVM file from disk — one buffered pass through the chunked
/// reader, never holding more than a chunk of parsed rows plus the flat
/// accumulation buffers.
pub fn load_libsvm(path: &str) -> Result<Dataset, ScrbError> {
    let name = std::path::Path::new(path)
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    let mut chunks = LibsvmChunks::from_path(path, LOAD_CHUNK_ROWS)?;
    dataset_from_chunks(&mut chunks, &name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "\
+1 1:0.5 3:1.5
-1 2:2.0
+1 1:1.0 2:1.0 3:1.0
";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.k, 2);
        assert_eq!(ds.y, vec![1, 0, 1]); // -1 → 0, +1 → 1 (sorted order)
        assert_eq!(ds.x.at(0, 0), 0.5);
        assert_eq!(ds.x.at(0, 1), 0.0); // sparse hole
        assert_eq!(ds.x.at(1, 1), 2.0);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# header\n\n1 1:1\n2 1:2\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.k, 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_libsvm(std::io::Cursor::new("1 nocolon\n"), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new("1 0:1.0\n"), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new(""), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new("abc 1:1\n"), "t").is_err());
    }

    #[test]
    fn multiclass_labels_compact() {
        let text = "10 1:1\n30 1:2\n20 1:3\n10 1:4\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.k, 3);
        assert_eq!(ds.y, vec![0, 2, 1, 0]);
    }

    #[test]
    fn compact_labels_sorted_order() {
        let (y, k) = compact_labels(&[5, -2, 5, 9, -2]);
        assert_eq!(y, vec![1, 0, 1, 2, 0]);
        assert_eq!(k, 3);
    }

    #[test]
    fn chunk_size_does_not_change_the_dataset() {
        // the in-memory loader is a drain over the chunked reader; any
        // chunk size must assemble the identical dataset
        let text = "1 1:0.5 3:1.5\n-1 2:2.0\n1 1:1.0 2:1.0 3:1.0\n2 5:0.25\n";
        let reference = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        for chunk_rows in [1usize, 2, 3, 100] {
            let mut r = LibsvmChunks::from_bytes(text.as_bytes().to_vec(), chunk_rows);
            let ds = dataset_from_chunks(&mut r, "t").unwrap();
            assert_eq!(ds.x.data, reference.x.data, "chunk_rows={chunk_rows}");
            assert_eq!(ds.y, reference.y);
            assert_eq!(ds.k, reference.k);
        }
    }

    #[test]
    fn csv_chunks_assemble_a_dataset_too() {
        let text = "1,0.5,0.0,1.5\n2,0.0,2.0,0.0\n";
        let mut r = crate::stream::CsvChunks::from_bytes(text.as_bytes().to_vec(), 8);
        let ds = dataset_from_chunks(&mut r, "csv").unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 3));
        assert_eq!(ds.x.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.x.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![0, 1]);
    }
}
