//! LibSVM-format dataset parser, so the real Table 1 benchmarks drop in
//! when their files are available (`scrb run --data path.libsvm`, and the
//! `fit`/`predict` serving commands). Malformed lines surface as typed
//! [`ScrbError::Parse`] values — one clean line at the CLI, never an
//! abort.
//!
//! Format per line: `<label> <index>:<value> <index>:<value> ...`
//! Indices are 1-based and may be sparse; labels may be arbitrary
//! integers/floats (compacted to 0..K−1 in first-seen sorted order).

use super::dataset::Dataset;
use crate::error::ScrbError;
use crate::linalg::Mat;
use std::collections::BTreeMap;
use std::io::BufRead;

/// Parse a LibSVM text stream.
pub fn parse_libsvm<R: BufRead>(reader: R, name: &str) -> Result<Dataset, ScrbError> {
    let mut raw_rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut max_dim = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line =
            line.map_err(|e| ScrbError::parse(format!("read error at line {}: {e}", lineno + 1)))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| ScrbError::parse(format!("line {}: empty", lineno + 1)))?;
        let label = label_tok
            .parse::<f64>()
            .map_err(|_| ScrbError::parse(format!("line {}: bad label '{label_tok}'", lineno + 1)))?
            as i64;
        let mut feats = Vec::new();
        for tok in parts {
            let (is, vs) = tok
                .split_once(':')
                .ok_or_else(|| ScrbError::parse(format!("line {}: bad feature '{tok}'", lineno + 1)))?;
            let idx: usize = is
                .parse()
                .map_err(|_| ScrbError::parse(format!("line {}: bad index '{is}'", lineno + 1)))?;
            if idx == 0 {
                return Err(ScrbError::parse(format!(
                    "line {}: LibSVM indices are 1-based",
                    lineno + 1
                )));
            }
            let val: f64 = vs
                .parse()
                .map_err(|_| ScrbError::parse(format!("line {}: bad value '{vs}'", lineno + 1)))?;
            max_dim = max_dim.max(idx);
            feats.push((idx - 1, val));
        }
        raw_rows.push(feats);
        raw_labels.push(label);
    }
    if raw_rows.is_empty() {
        return Err(ScrbError::invalid_input("empty dataset"));
    }
    // compact labels
    let uniq: BTreeMap<i64, usize> = {
        let mut set: Vec<i64> = raw_labels.clone();
        set.sort_unstable();
        set.dedup();
        set.into_iter().enumerate().map(|(i, l)| (l, i)).collect()
    };
    let n = raw_rows.len();
    let mut x = Mat::zeros(n, max_dim);
    for (i, feats) in raw_rows.into_iter().enumerate() {
        for (j, v) in feats {
            x.set(i, j, v);
        }
    }
    let y: Vec<usize> = raw_labels.iter().map(|l| uniq[l]).collect();
    Ok(Dataset::new(name, x, y))
}

/// Load a LibSVM file from disk.
pub fn load_libsvm(path: &str) -> Result<Dataset, ScrbError> {
    let file = std::fs::File::open(path).map_err(|e| ScrbError::io(path, e))?;
    let name = std::path::Path::new(path)
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    parse_libsvm(std::io::BufReader::new(file), &name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "\
+1 1:0.5 3:1.5
-1 2:2.0
+1 1:1.0 2:1.0 3:1.0
";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.k, 2);
        assert_eq!(ds.y, vec![1, 0, 1]); // -1 → 0, +1 → 1 (sorted order)
        assert_eq!(ds.x.at(0, 0), 0.5);
        assert_eq!(ds.x.at(0, 1), 0.0); // sparse hole
        assert_eq!(ds.x.at(1, 1), 2.0);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = "# header\n\n1 1:1\n2 1:2\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.k, 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_libsvm(std::io::Cursor::new("1 nocolon\n"), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new("1 0:1.0\n"), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new(""), "t").is_err());
        assert!(parse_libsvm(std::io::Cursor::new("abc 1:1\n"), "t").is_err());
    }

    #[test]
    fn multiclass_labels_compact() {
        let text = "10 1:1\n30 1:2\n20 1:3\n10 1:4\n";
        let ds = parse_libsvm(std::io::Cursor::new(text), "t").unwrap();
        assert_eq!(ds.k, 3);
        assert_eq!(ds.y, vec![0, 2, 1, 0]);
    }
}
