//! Synthetic dataset generators.
//!
//! The paper evaluates on 8 LibSVM benchmarks (Table 1) plus SUSY. Those
//! files are not available offline, so each benchmark gets a synthetic
//! stand-in matching its (N, d, K) exactly and emulating the *clustering
//! character* that drives the paper's comparisons (see DESIGN.md §5):
//! non-convex structure where SC should beat K-means, heavy overlap where
//! spectra are clustered (covtype-mult, the Fig. 3 stress case), and
//! near-structureless data where all methods tie (poker). Real LibSVM
//! files drop in through `data::libsvm` when available.

use super::dataset::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// Isotropic Gaussian blobs around `k` random centers in `d` dims.
/// `sep` is the center spacing in units of the cluster std.
pub fn gaussian_blobs(n: usize, d: usize, k: usize, sep: f64, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed, 0xb10b);
    let std = 1.0;
    // centers ~ N(0, sep²/d · I): expected center spacing ≈ sep·std
    let mut centers = Mat::zeros(k, d);
    for v in centers.data.iter_mut() {
        *v = rng.normal() * sep / (d as f64).sqrt();
    }
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0usize; n];
    for i in 0..n {
        let c = i % k;
        y[i] = c;
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = centers.at(c, j) + rng.normal() * std / (d as f64).sqrt();
        }
    }
    let mut ds = Dataset::new("blobs", x, y);
    ds.shuffle(&mut Pcg::new(seed, 0x5f1e));
    ds
}

/// The classic two-moons non-convex benchmark (embedded in 2 dims) —
/// K-means fails, spectral clustering succeeds.
pub fn two_moons(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed, 0x3005);
    let mut x = Mat::zeros(n, 2);
    let mut y = vec![0usize; n];
    for i in 0..n {
        let c = i % 2;
        y[i] = c;
        let t = std::f64::consts::PI * rng.f64();
        let (mut px, mut py) = if c == 0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        px += noise * rng.normal();
        py += noise * rng.normal();
        x.set(i, 0, px);
        x.set(i, 1, py);
    }
    let mut ds = Dataset::new("two_moons", x, y);
    ds.shuffle(&mut Pcg::new(seed, 0x5f2e));
    ds
}

/// Concentric rings: `k` circles of increasing radius with Gaussian
/// radial noise. For d > 2 the 2-D rings are pushed through a random
/// linear embedding into all `d` dims (signal mixed into every coordinate;
/// per-dim min-max normalization would otherwise blow pure-noise dims up
/// to the signal scale and bury the manifold).
pub fn concentric_rings(n: usize, d: usize, k: usize, noise: f64, seed: u64) -> Dataset {
    assert!(d >= 2);
    let mut rng = Pcg::new(seed, 0x0717);
    // random 2→d embedding (identity when d == 2)
    let mut embed = Mat::zeros(2, d);
    if d == 2 {
        embed.set(0, 0, 1.0);
        embed.set(1, 1, 1.0);
    } else {
        for v in embed.data.iter_mut() {
            *v = rng.normal() / (2f64).sqrt();
        }
    }
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0usize; n];
    for i in 0..n {
        let c = i % k;
        y[i] = c;
        let radius = 1.0 + 2.0 * c as f64 + noise * rng.normal();
        let theta = rng.range_f64(0.0, 2.0 * std::f64::consts::PI);
        let (p, q) = (radius * theta.cos(), radius * theta.sin());
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = p * embed.at(0, j) + q * embed.at(1, j) + noise * rng.normal();
        }
    }
    let mut ds = Dataset::new("rings", x, y);
    ds.shuffle(&mut Pcg::new(seed, 0x5f3e));
    ds
}

/// Blobs generated in a `latent`-dimensional subspace, pushed through a
/// random linear embedding into `d` dims, with optional sinusoidal warp —
/// the high-dimensional benchmarks (mnist-like) use this.
#[allow(clippy::too_many_arguments)]
pub fn latent_blobs(
    n: usize,
    d: usize,
    k: usize,
    latent: usize,
    sep: f64,
    noise: f64,
    warp: f64,
    class_weights: Option<&[f64]>,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg::new(seed, 0x1a7e);
    let mut centers = Mat::zeros(k, latent);
    for v in centers.data.iter_mut() {
        *v = rng.normal() * sep;
    }
    // random embedding latent → d
    let mut embed = Mat::zeros(latent, d);
    for v in embed.data.iter_mut() {
        *v = rng.normal() / (latent as f64).sqrt();
    }
    // cumulative class distribution
    let weights: Vec<f64> = match class_weights {
        Some(w) => {
            assert_eq!(w.len(), k);
            let s: f64 = w.iter().sum();
            w.iter().map(|v| v / s).collect()
        }
        None => vec![1.0 / k as f64; k],
    };
    let mut cum = vec![0.0; k];
    let mut acc = 0.0;
    for (c, w) in weights.iter().enumerate() {
        acc += w;
        cum[c] = acc;
    }
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0usize; n];
    let mut z = vec![0.0; latent];
    for i in 0..n {
        let u = rng.f64();
        let c = cum.iter().position(|&cv| u <= cv).unwrap_or(k - 1);
        y[i] = c;
        for (l, zv) in z.iter_mut().enumerate() {
            *zv = centers.at(c, l) + rng.normal();
        }
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            let mut s = 0.0;
            for (l, zv) in z.iter().enumerate() {
                s += zv * embed.at(l, j);
            }
            if warp > 0.0 {
                s += warp * (s * 1.7).sin();
            }
            *v = s + noise * rng.normal();
        }
    }
    Dataset::new("latent_blobs", x, y)
}

/// Benchmark descriptors matching the paper's Table 1 (plus SUSY, §5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BenchSpec {
    pub name: &'static str,
    pub k: usize,
    pub d: usize,
    pub n: usize,
}

/// Table 1 of the paper.
pub const PAPER_BENCHMARKS: [BenchSpec; 8] = [
    BenchSpec { name: "pendigits", k: 10, d: 16, n: 10_992 },
    BenchSpec { name: "letter", k: 26, d: 16, n: 15_500 },
    BenchSpec { name: "mnist", k: 10, d: 780, n: 70_000 },
    BenchSpec { name: "acoustic", k: 3, d: 50, n: 98_528 },
    BenchSpec { name: "ijcnn1", k: 2, d: 22, n: 126_701 },
    BenchSpec { name: "cod_rna", k: 2, d: 8, n: 321_054 },
    BenchSpec { name: "covtype-mult", k: 7, d: 54, n: 581_012 },
    BenchSpec { name: "poker", k: 10, d: 10, n: 1_025_010 },
];

/// SUSY (used by the Fig. 4 scalability sweep).
pub const SUSY: BenchSpec = BenchSpec { name: "susy", k: 2, d: 18, n: 4_000_000 };

pub fn spec_by_name(name: &str) -> Option<BenchSpec> {
    if name == "susy" {
        return Some(SUSY);
    }
    PAPER_BENCHMARKS.iter().copied().find(|s| s.name == name)
}

/// Build the synthetic stand-in for a paper benchmark. `scale` divides N
/// (1 = full paper size); min 64 points per class are kept. All outputs
/// are min-max normalized to the unit box.
pub fn paper_benchmark(name: &str, scale: usize, seed: u64) -> Dataset {
    let spec = spec_by_name(name)
        .unwrap_or_else(|| panic!("unknown benchmark '{name}' (see Table 1 names)"));
    let n = (spec.n / scale.max(1)).max(64 * spec.k);
    let (d, k) = (spec.d, spec.k);
    let mut ds = match name {
        // pendigits: well-separated digit strokes — easy for everyone.
        "pendigits" => latent_blobs(n, d, k, 6, 2.2, 0.35, 0.5, None, seed),
        // letter: 26 moderately overlapping classes — K-means ranks poorly.
        "letter" => latent_blobs(n, d, k, 8, 1.3, 0.4, 0.7, None, seed),
        // mnist: 10 classes in a low-dim manifold inside 780 dims.
        "mnist" => latent_blobs(n, d, k, 10, 1.7, 0.3, 0.9, None, seed),
        // acoustic: 3 broad overlapping sources.
        "acoustic" => latent_blobs(n, d, k, 5, 1.0, 0.5, 0.3, Some(&[3.0, 2.0, 1.5]), seed),
        // ijcnn1: binary, non-convex (ring + core) — SC territory.
        "ijcnn1" => {
            let mut ds = concentric_rings(n, d, k, 0.09, seed);
            ds.name = "ijcnn1".into();
            ds
        }
        // cod_rna: binary, imbalanced 2:1, mild nonlinearity.
        "cod_rna" => latent_blobs(n, d, k, 4, 1.1, 0.45, 0.8, Some(&[2.0, 1.0]), seed),
        // covtype-mult: 7 heavily overlapping classes — tiny eigengaps
        // (the Fig. 3 "clustered spectrum" stress case).
        "covtype-mult" => latent_blobs(
            n,
            d,
            k,
            6,
            0.9,
            0.35,
            0.2,
            Some(&[8.0, 10.0, 2.0, 1.0, 0.6, 1.2, 0.9]),
            seed,
        ),
        // poker: hands are near-uniform — almost no geometric structure;
        // every method lands in the same place (paper: scores all ≈ equal).
        "poker" => latent_blobs(n, d, k, 2, 0.25, 0.9, 0.0, None, seed),
        // susy: 2 broad classes, mild overlap (scalability driver only).
        "susy" => latent_blobs(n, d, k, 4, 1.6, 0.3, 0.1, None, seed),
        other => panic!("unhandled benchmark '{other}'"),
    };
    ds.name = spec.name.into();
    ds.minmax_normalize();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table1() {
        assert_eq!(PAPER_BENCHMARKS.len(), 8);
        let poker = spec_by_name("poker").unwrap();
        assert_eq!((poker.n, poker.d, poker.k), (1_025_010, 10, 10));
        let mnist = spec_by_name("mnist").unwrap();
        assert_eq!((mnist.n, mnist.d, mnist.k), (70_000, 780, 10));
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn benchmark_shapes_and_normalization() {
        for spec in &PAPER_BENCHMARKS {
            let ds = paper_benchmark(spec.name, 64, 7);
            assert_eq!(ds.d(), spec.d, "{}", spec.name);
            assert_eq!(ds.k, spec.k, "{}", spec.name);
            assert!(ds.n() >= 64 * spec.k);
            for i in 0..ds.n().min(50) {
                for &v in ds.x.row(i) {
                    assert!((0.0..=1.0).contains(&v), "{} not normalized", spec.name);
                }
            }
            // every class present
            assert!(ds.class_sizes().iter().all(|&s| s > 0), "{}", spec.name);
        }
    }

    #[test]
    fn moons_nonconvex_structure() {
        let ds = two_moons(400, 0.05, 3);
        assert_eq!(ds.k, 2);
        assert_eq!(ds.class_sizes(), vec![200, 200]);
    }

    #[test]
    fn rings_radii_separate_classes() {
        let ds = concentric_rings(300, 2, 2, 0.05, 5);
        for i in 0..ds.n() {
            let r = (ds.x.at(i, 0).powi(2) + ds.x.at(i, 1).powi(2)).sqrt();
            let expected = 1.0 + 2.0 * ds.y[i] as f64;
            assert!((r - expected).abs() < 1.0, "r {r} vs class {}", ds.y[i]);
        }
    }

    #[test]
    fn imbalance_respected() {
        let ds = paper_benchmark("cod_rna", 512, 9);
        let sizes = ds.class_sizes();
        assert!(sizes[0] > sizes[1], "cod_rna should be imbalanced: {sizes:?}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = paper_benchmark("pendigits", 64, 11);
        let b = paper_benchmark("pendigits", 64, 11);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }
}
