//! Crate-wide error type: every fallible API surface (model fit/predict,
//! persistence, dataset loading, configuration, CLI dispatch) reports a
//! [`ScrbError`] instead of panicking or returning bare `String`s, so a
//! malformed LibSVM line or a missing model file is a clean one-line error
//! at the CLI and a typed, matchable value in library callers.

use std::fmt;

/// Why an input record was rejected: structurally broken text vs a
/// syntactically fine record carrying NaN/Inf values. Quarantine reports
/// count the two classes separately because they point at different
/// upstream problems (corrupted transport vs a broken feature producer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// The record could not be parsed at all (bad token, ragged row,
    /// invalid UTF-8, out-of-order indices, …).
    Malformed,
    /// The record parsed but holds a non-finite label or value.
    NonFinite,
}

/// One rejected input record with uniform source context: file name, line
/// number, byte offset of the line start, and the quoted offending token.
/// Carried boxed inside [`ScrbError::BadRecord`] and sampled (capped) into
/// quarantine reports.
#[derive(Debug, Clone)]
pub struct RecordError {
    /// Source name: the file path, or `"<memory>"` for in-memory readers.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Byte offset of the start of the offending line.
    pub byte: u64,
    /// The offending token (sanitized, truncated).
    pub token: String,
    /// What was wrong with it.
    pub reason: String,
    pub kind: RecordKind,
}

impl fmt::Display for RecordError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{} (byte {}): {} (token '{}')",
            self.file, self.line, self.byte, self.reason, self.token
        )
    }
}

/// The error type of the `scrb` crate.
#[derive(Debug)]
pub enum ScrbError {
    /// Filesystem access failed; carries the offending path.
    Io { path: String, source: std::io::Error },
    /// Malformed input data (LibSVM lines, numeric fields, …).
    Parse(String),
    /// One specific input record was rejected, with full source context
    /// (file, line, byte offset, offending token). The located form of
    /// `Parse` that ingest policies can match on: strict mode surfaces it,
    /// quarantine mode skips the row and samples it into the report.
    BadRecord(Box<RecordError>),
    /// A retryable I/O failure (interrupted read, injected fault).
    /// Distinct from permanent parse failures so the bounded-retry layer
    /// knows what is safe to retry; surfaced only after retries exhaust,
    /// with the attempt count.
    Transient { msg: String, attempts: u32 },
    /// Checkpoint state is missing required pieces, corrupt, or was
    /// written with incompatible parameters.
    Checkpoint(String),
    /// Bad configuration, CLI usage, or unknown names.
    Config(String),
    /// Model persistence failure: bad magic, unsupported version,
    /// truncated or corrupt payload.
    Model(String),
    /// A serving-path failure: protocol framing, admission control
    /// (shed/overload), a missed deadline, or a rejected model swap. The
    /// daemon answers these on the wire as typed protocol errors (see
    /// `serve::ErrorCode`); this is their library-side face.
    Serve(String),
    /// An API input violates a shape/domain precondition (dimension
    /// mismatch, size cap, empty data).
    InvalidInput(String),
    /// The operation is not supported by this method/model (e.g. a
    /// spectral embedding for a transductive baseline).
    Unsupported(String),
}

impl ScrbError {
    pub fn io(path: impl Into<String>, source: std::io::Error) -> ScrbError {
        ScrbError::Io { path: path.into(), source }
    }

    pub fn parse(msg: impl Into<String>) -> ScrbError {
        ScrbError::Parse(msg.into())
    }

    pub fn bad_record(rec: RecordError) -> ScrbError {
        ScrbError::BadRecord(Box::new(rec))
    }

    pub fn transient(msg: impl Into<String>) -> ScrbError {
        ScrbError::Transient { msg: msg.into(), attempts: 1 }
    }

    pub fn checkpoint(msg: impl Into<String>) -> ScrbError {
        ScrbError::Checkpoint(msg.into())
    }

    pub fn config(msg: impl Into<String>) -> ScrbError {
        ScrbError::Config(msg.into())
    }

    pub fn model(msg: impl Into<String>) -> ScrbError {
        ScrbError::Model(msg.into())
    }

    pub fn serve(msg: impl Into<String>) -> ScrbError {
        ScrbError::Serve(msg.into())
    }

    pub fn invalid_input(msg: impl Into<String>) -> ScrbError {
        ScrbError::InvalidInput(msg.into())
    }

    pub fn unsupported(msg: impl Into<String>) -> ScrbError {
        ScrbError::Unsupported(msg.into())
    }
}

impl fmt::Display for ScrbError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScrbError::Io { path, source } => write!(f, "cannot access '{path}': {source}"),
            ScrbError::Parse(m) => write!(f, "parse error: {m}"),
            ScrbError::BadRecord(rec) => write!(f, "parse error: {rec}"),
            ScrbError::Transient { msg, attempts } => {
                write!(f, "transient i/o error (after {attempts} attempt(s)): {msg}")
            }
            ScrbError::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            ScrbError::Config(m) => write!(f, "{m}"),
            ScrbError::Model(m) => write!(f, "model error: {m}"),
            ScrbError::Serve(m) => write!(f, "serve error: {m}"),
            ScrbError::InvalidInput(m) => write!(f, "invalid input: {m}"),
            ScrbError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for ScrbError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ScrbError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Bridge for the crate's older `Result<_, String>` helpers (config file
/// parsing, enum `parse` functions): a bare message is a config error.
impl From<String> for ScrbError {
    fn from(msg: String) -> ScrbError {
        ScrbError::Config(msg)
    }
}

impl From<&str> for ScrbError {
    fn from(msg: &str) -> ScrbError {
        ScrbError::Config(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_one_line() {
        let cases: Vec<ScrbError> = vec![
            ScrbError::io("/no/such", std::io::Error::new(std::io::ErrorKind::NotFound, "gone")),
            ScrbError::parse("line 3: bad label 'x'"),
            ScrbError::bad_record(RecordError {
                file: "data.libsvm".into(),
                line: 3,
                byte: 57,
                token: "x".into(),
                reason: "bad label".into(),
                kind: RecordKind::Malformed,
            }),
            ScrbError::transient("read interrupted"),
            ScrbError::checkpoint("state written with different parameters"),
            ScrbError::config("unknown key 'nope'"),
            ScrbError::model("bad magic"),
            ScrbError::serve("queue full: request shed"),
            ScrbError::invalid_input("expected 16 features, got 3"),
            ScrbError::unsupported("no spectral embedding"),
        ];
        for e in cases {
            let s = e.to_string();
            assert!(!s.is_empty() && !s.contains('\n'), "{s:?}");
        }
    }

    #[test]
    fn bad_record_display_carries_full_context() {
        let e = ScrbError::bad_record(RecordError {
            file: "f.csv".into(),
            line: 12,
            byte: 340,
            token: "abc".into(),
            reason: "bad value".into(),
            kind: RecordKind::NonFinite,
        });
        let s = e.to_string();
        for part in ["f.csv", ":12", "byte 340", "'abc'", "bad value"] {
            assert!(s.contains(part), "missing {part:?} in {s:?}");
        }
    }

    #[test]
    fn string_bridge_maps_to_config() {
        let e: ScrbError = String::from("bad value").into();
        assert!(matches!(e, ScrbError::Config(_)));
        let e: ScrbError = "bad value".into();
        assert!(matches!(e, ScrbError::Config(_)));
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error;
        let e = ScrbError::io("p", std::io::Error::other("x"));
        assert!(e.source().is_some());
    }
}
