//! Crate-wide error type: every fallible API surface (model fit/predict,
//! persistence, dataset loading, configuration, CLI dispatch) reports a
//! [`ScrbError`] instead of panicking or returning bare `String`s, so a
//! malformed LibSVM line or a missing model file is a clean one-line error
//! at the CLI and a typed, matchable value in library callers.

use std::fmt;

/// The error type of the `scrb` crate.
#[derive(Debug)]
pub enum ScrbError {
    /// Filesystem access failed; carries the offending path.
    Io { path: String, source: std::io::Error },
    /// Malformed input data (LibSVM lines, numeric fields, …).
    Parse(String),
    /// Bad configuration, CLI usage, or unknown names.
    Config(String),
    /// Model persistence failure: bad magic, unsupported version,
    /// truncated or corrupt payload.
    Model(String),
    /// An API input violates a shape/domain precondition (dimension
    /// mismatch, size cap, empty data).
    InvalidInput(String),
    /// The operation is not supported by this method/model (e.g. a
    /// spectral embedding for a transductive baseline).
    Unsupported(String),
}

impl ScrbError {
    pub fn io(path: impl Into<String>, source: std::io::Error) -> ScrbError {
        ScrbError::Io { path: path.into(), source }
    }

    pub fn parse(msg: impl Into<String>) -> ScrbError {
        ScrbError::Parse(msg.into())
    }

    pub fn config(msg: impl Into<String>) -> ScrbError {
        ScrbError::Config(msg.into())
    }

    pub fn model(msg: impl Into<String>) -> ScrbError {
        ScrbError::Model(msg.into())
    }

    pub fn invalid_input(msg: impl Into<String>) -> ScrbError {
        ScrbError::InvalidInput(msg.into())
    }

    pub fn unsupported(msg: impl Into<String>) -> ScrbError {
        ScrbError::Unsupported(msg.into())
    }
}

impl fmt::Display for ScrbError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScrbError::Io { path, source } => write!(f, "cannot access '{path}': {source}"),
            ScrbError::Parse(m) => write!(f, "parse error: {m}"),
            ScrbError::Config(m) => write!(f, "{m}"),
            ScrbError::Model(m) => write!(f, "model error: {m}"),
            ScrbError::InvalidInput(m) => write!(f, "invalid input: {m}"),
            ScrbError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for ScrbError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ScrbError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Bridge for the crate's older `Result<_, String>` helpers (config file
/// parsing, enum `parse` functions): a bare message is a config error.
impl From<String> for ScrbError {
    fn from(msg: String) -> ScrbError {
        ScrbError::Config(msg)
    }
}

impl From<&str> for ScrbError {
    fn from(msg: &str) -> ScrbError {
        ScrbError::Config(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_one_line() {
        let cases: Vec<ScrbError> = vec![
            ScrbError::io("/no/such", std::io::Error::new(std::io::ErrorKind::NotFound, "gone")),
            ScrbError::parse("line 3: bad label 'x'"),
            ScrbError::config("unknown key 'nope'"),
            ScrbError::model("bad magic"),
            ScrbError::invalid_input("expected 16 features, got 3"),
            ScrbError::unsupported("no spectral embedding"),
        ];
        for e in cases {
            let s = e.to_string();
            assert!(!s.is_empty() && !s.contains('\n'), "{s:?}");
        }
    }

    #[test]
    fn string_bridge_maps_to_config() {
        let e: ScrbError = String::from("bad value").into();
        assert!(matches!(e, ScrbError::Config(_)));
        let e: ScrbError = "bad value".into();
        assert!(matches!(e, ScrbError::Config(_)));
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error;
        let e = ScrbError::io("p", std::io::Error::other("x"));
        assert!(e.source().is_some());
    }
}
