//! Hand-rolled CLI argument parsing (no clap offline).
//!
//! Grammar: `scrb <command> [positional...] [--key value | --flag]...`

use crate::error::ScrbError;
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, ScrbError> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(ScrbError::config("bare '--' not supported"));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, ScrbError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, ScrbError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ScrbError::config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ScrbError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ScrbError::config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ScrbError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ScrbError::config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// Parse a comma-separated list of usizes, e.g. `--rs 16,64,256`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, ScrbError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| ScrbError::config(format!("--{name}: bad entry '{s}'")))
                })
                .collect(),
        }
    }

    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_positional_options_flags() {
        let a = parse("run mnist --r 1024 --sigma=2.5 --verbose --out /tmp/x");
        assert_eq!(a.command, "run");
        assert_eq!(a.positional, vec!["mnist"]);
        assert_eq!(a.get_usize("r", 0).unwrap(), 1024);
        assert_eq!(a.get_f64("sigma", 0.0).unwrap(), 2.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert_eq!(a.command, "bench");
    }

    #[test]
    fn lists() {
        let a = parse("fig 2 --rs 16,64,256");
        assert_eq!(a.positional, vec!["2"]);
        assert_eq!(a.get_usize_list("rs", &[]).unwrap(), vec![16, 64, 256]);
        assert_eq!(a.get_usize_list("other", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("run --r nope");
        assert!(a.get_usize("r", 0).is_err());
    }
}
