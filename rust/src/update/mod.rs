//! Online model maintenance: incremental codebook expansion, subspace
//! tracking, and the drift-triggered refit escalation.
//!
//! A fitted [`ScRbModel`] ages as the data distribution moves: serving
//! rows start landing in bins the fit never saw (counted by the serving
//! [`DriftMonitor`]), and even in-vocabulary rows stop being expressible
//! by the tracked rank-k subspace. Refitting from scratch on every batch
//! of new data throws away the paper's R-sparsity advantage — the model
//! is a *codebook*, and codebooks can grow. This module maintains a live
//! model from data chunks at a small fraction of refit cost:
//!
//! 1. **Admission** ([`admit`]): new rows are binned against the fitted
//!    codebook; unseen bins get fresh global columns at the end of the
//!    column space ([`RbCodebook::admit`]) and the projection `P` is
//!    widened with matching zero rows. Fit-time columns never move.
//! 2. **Subspace tracking** ([`subspace`]): each sub-block of rows is
//!    folded into `(P, σ)` by a Brand-style rank-k incremental SVD. The
//!    residual basis is restricted to the sub-block's freshly admitted
//!    columns (orthogonal to the old basis by construction); the dropped
//!    in-span residual mass is *measured* and fed to the drift tracker.
//! 3. **Warm-start K-means**: after the subspace refresh, the previous
//!    centroids — rotated into the new coordinates — are polished by a
//!    few damped Lloyd passes over the chunk's embedding. No reseeding,
//!    no replicates: the previous solution is the seed.
//! 4. **Drift-triggered refit** ([`drift`]): EWMAs of the unseen-bin
//!    rate and the subspace residual persist in the model
//!    ([`UpdateState`], the SCRBMODL v3 trailer). Past a configured
//!    threshold, [`ScRbModel::update`] returns
//!    [`UpdateOutcome::RefitNeeded`] and the caller escalates to the
//!    full streamed refit (`scrb update --refit`, or the serve daemon's
//!    validated hot-swap slot).
//!
//! The hot path is allocation-free at steady state: all scratch lives in
//! a caller-owned [`UpdateWorkspace`] (the same reusable-workspace
//! discipline as the solver and serving paths), and only an actual
//! admission — a genuinely new bin — touches the heap.
//!
//! In-distribution chunks are **byte-invisible**: a chunk that admits
//! nothing and whose residual stays under [`UpdateConfig::residual_tol`]
//! skips the subspace fold entirely, so the saved model changes only in
//! its persisted update counters (a property `tests/update.rs` checks
//! byte for byte).
//!
//! [`DriftMonitor`]: crate::model::DriftMonitor
//! [`RbCodebook::admit`]: crate::rb::RbCodebook::admit
//! [`UpdateState`]: crate::model::UpdateState

pub mod admit;
pub mod drift;
pub mod subspace;

pub use admit::ChunkBins;
pub use drift::{DriftTracker, UpdateOutcome};
pub use subspace::SubspaceStep;

pub use crate::config::UpdateConfig;

use crate::error::ScrbError;
use crate::kmeans::nearest_centroid;
use crate::linalg::Mat;
use crate::model::ScRbModel;
use crate::stream::{ChunkReader, GuardedReader, IngestPolicy, Quarantine, SparseChunk};

/// What one [`ScRbModel::update`] call did.
#[derive(Clone, Debug)]
pub struct UpdateReport {
    /// Keep serving incrementally, or escalate to a full refit.
    pub outcome: UpdateOutcome,
    /// Rows absorbed from this chunk.
    pub rows: usize,
    /// Bins admitted (new global columns) by this chunk.
    pub admitted: usize,
    /// This chunk's pre-admission unseen-bin rate over `rows × R`
    /// lookups.
    pub unseen_rate: f64,
    /// Mean fraction of per-row embedding energy outside the tracked
    /// subspace (before the fold).
    pub residual_ratio: f64,
    /// Persisted EWMA of `unseen_rate` after this update.
    pub unseen_ewma: f64,
    /// Persisted EWMA of `residual_ratio` after this update.
    pub residual_ewma: f64,
}

/// Caller-owned scratch for [`ScRbModel::update`]: binning buffers, the
/// incremental-SVD step, Lloyd-polish accumulators, and the drift
/// tracker (created lazily from the first update's
/// [`UpdateConfig::seed`], so a fresh workspace replays the same
/// trigger pattern). Reuse one workspace across a maintenance session —
/// steady-state updates then never allocate.
#[derive(Default)]
pub struct UpdateWorkspace {
    bins: ChunkBins,
    step: SubspaceStep,
    /// Whole-chunk bin table (rows × R) for the post-fold Lloyd polish.
    all_bins: Vec<u32>,
    emb: Vec<f64>,
    accum: Mat,
    counts: Vec<f64>,
    tracker: Option<DriftTracker>,
}

impl UpdateWorkspace {
    pub fn new() -> UpdateWorkspace {
        UpdateWorkspace::default()
    }
}

impl ScRbModel {
    /// Absorb one chunk of new rows into the fitted model: admit unseen
    /// bins, fold the rows into the spectral subspace, polish the
    /// k-means centroids, and account the drift (see the [module
    /// docs](crate::update)). Returns
    /// [`UpdateOutcome::RefitNeeded`] in the report when the persisted
    /// drift EWMAs cross the configured thresholds — the model is still
    /// updated and serviceable, but a full refit is advised.
    ///
    /// Rows must be in the model's *raw* input frame (the same frame the
    /// fit ingested); the stored normalization is re-applied here.
    pub fn update(
        &mut self,
        chunk: &SparseChunk,
        cfg: &UpdateConfig,
        ws: &mut UpdateWorkspace,
    ) -> Result<UpdateReport, ScrbError> {
        cfg.validate()?;
        let rows = chunk.rows();
        if rows == 0 {
            // Nothing observed: bump the call counter, leave every other
            // byte of the model — EWMAs included — untouched.
            self.update_state.updates += 1;
            return Ok(UpdateReport {
                outcome: UpdateOutcome::Updated,
                rows: 0,
                admitted: 0,
                unseen_rate: 0.0,
                residual_ratio: 0.0,
                unseen_ewma: self.update_state.unseen_ewma,
                residual_ewma: self.update_state.residual_ewma,
            });
        }
        let k = self.embed_dim();
        let r = self.codebook.r;
        let chunk_base = self.codebook.dim;
        let mut admitted_total = 0usize;
        let mut unseen_total = 0usize;
        let mut rho2_total = 0.0f64;
        let mut did_fold = false;
        ws.all_bins.clear();
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + cfg.block).min(rows);
            let block_base = self.codebook.dim;
            let norm = self.norm.as_ref().map(|(lo, span)| (lo.as_slice(), span.as_slice()));
            let (admitted, unseen) =
                ws.bins.bin_rows(&mut self.codebook, norm, chunk, r0, r1, chunk_base)?;
            if admitted > 0 {
                // Widen P with zero rows for the admitted columns; the
                // fold below rotates real mass into them.
                self.proj.data.resize(self.codebook.dim * k, 0.0);
                self.proj.rows = self.codebook.dim;
            }
            let c = r1 - r0;
            let rho2 = ws.step.measure(&self.proj, &self.s, &ws.bins.bins, c, r, block_base);
            if admitted > 0 || rho2 / c as f64 > cfg.residual_tol {
                ws.step.fold(&mut self.proj, &mut self.s, &mut self.centroids, block_base);
                did_fold = true;
            }
            admitted_total += admitted;
            unseen_total += unseen;
            rho2_total += rho2;
            ws.all_bins.extend_from_slice(&ws.bins.bins);
            r0 = r1;
        }
        if did_fold {
            self.polish_centroids(cfg, ws, rows);
        }
        let unseen_rate = unseen_total as f64 / (rows * r) as f64;
        let residual_ratio = rho2_total / rows as f64;
        let tracker = ws.tracker.get_or_insert_with(|| DriftTracker::new(cfg));
        let st = &mut self.update_state;
        let outcome = tracker.observe(st, cfg, unseen_rate, residual_ratio);
        st.updates += 1;
        st.rows_absorbed += rows as u64;
        st.bins_admitted += admitted_total as u64;
        Ok(UpdateReport {
            outcome,
            rows,
            admitted: admitted_total,
            unseen_rate,
            residual_ratio,
            unseen_ewma: st.unseen_ewma,
            residual_ewma: st.residual_ewma,
        })
    }

    /// Damped warm-start Lloyd passes over the chunk's (post-fold)
    /// embedding: each centroid carries a pseudo-count of prior mass so
    /// a small chunk nudges rather than overwrites the solution.
    /// Deterministic — no reseeding, fixed iteration count.
    fn polish_centroids(&mut self, cfg: &UpdateConfig, ws: &mut UpdateWorkspace, rows: usize) {
        let kc = self.centroids.rows;
        let k = self.embed_dim();
        if kc == 0 || cfg.lloyd_iters == 0 {
            return;
        }
        let r = self.codebook.r;
        let prior = (self.update_state.rows_absorbed as f64 / kc as f64).max(16.0);
        ws.emb.resize(k, 0.0);
        ws.counts.resize(kc, 0.0);
        for _ in 0..cfg.lloyd_iters {
            ws.accum.reset(kc, k);
            for ci in 0..kc {
                let crow = self.centroids.row(ci);
                for (a, &cv) in ws.accum.row_mut(ci).iter_mut().zip(crow.iter()) {
                    *a = prior * cv;
                }
                ws.counts[ci] = prior;
            }
            for i in 0..rows {
                ws.emb.fill(0.0);
                for &b in &ws.all_bins[i * r..(i + 1) * r] {
                    for (e, p) in ws.emb.iter_mut().zip(self.proj.row(b as usize).iter()) {
                        *e += *p;
                    }
                }
                let nrm = ws.emb.iter().map(|v| v * v).sum::<f64>().sqrt();
                if nrm > 1e-300 {
                    for v in ws.emb.iter_mut() {
                        *v /= nrm;
                    }
                }
                let (best, _) = nearest_centroid(&ws.emb, &self.centroids);
                let arow = ws.accum.row_mut(best as usize);
                for (a, &e) in arow.iter_mut().zip(ws.emb.iter()) {
                    *a += e;
                }
                ws.counts[best as usize] += 1.0;
            }
            for ci in 0..kc {
                let inv = 1.0 / ws.counts[ci];
                for (cv, &a) in self.centroids.row_mut(ci).iter_mut().zip(ws.accum.row(ci).iter())
                {
                    *cv = a * inv;
                }
            }
        }
    }
}

/// Aggregate result of [`update_streaming`].
#[derive(Debug, Default)]
pub struct StreamUpdate {
    /// One report per absorbed chunk, in stream order.
    pub reports: Vec<UpdateReport>,
    /// Total rows absorbed.
    pub rows: usize,
    /// Total bins admitted.
    pub admitted: usize,
    /// `RefitNeeded` iff the pass stopped early on a drift signal.
    pub refit_needed: bool,
    /// Ingest-policy report (quarantined rows, absorbed retries) for the
    /// pass.
    pub quarantine: Quarantine,
}

/// Maintain `model` from a whole stream: chunks pass through the same
/// hardened ingest stack as the streamed fit ([`GuardedReader`]:
/// bounded transient retries, quarantine screening under the configured
/// [`IngestPolicy`]), each absorbed by [`ScRbModel::update`]. Stops at
/// the first [`UpdateOutcome::RefitNeeded`] — absorbing more chunks
/// incrementally once the model has asked for a refit only compounds
/// the drift — and reports how far it got.
pub fn update_streaming(
    model: &mut ScRbModel,
    reader: &mut dyn ChunkReader,
    cfg: &UpdateConfig,
    policy: IngestPolicy,
    ws: &mut UpdateWorkspace,
) -> Result<StreamUpdate, ScrbError> {
    let mut guarded = GuardedReader::new(reader, policy);
    let mut chunk = SparseChunk::new();
    let mut out = StreamUpdate::default();
    while guarded.next_chunk(&mut chunk)? {
        if chunk.rows() == 0 {
            continue;
        }
        let rep = model.update(&chunk, cfg, ws)?;
        out.rows += rep.rows;
        out.admitted += rep.admitted;
        let refit = rep.outcome == UpdateOutcome::RefitNeeded;
        out.reports.push(rep);
        if refit {
            out.refit_needed = true;
            break;
        }
    }
    out.quarantine = guarded.report();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::UPDATE_TRAILER_BYTES;
    use crate::serve::test_model;
    use crate::stream::LibsvmChunks;
    use crate::util::rng::Pcg;

    /// Model bytes with the mutable tail (update trailer + checksum)
    /// masked off.
    fn frozen_prefix(m: &ScRbModel) -> Vec<u8> {
        let mut b = m.to_bytes();
        b.truncate(b.len() - UPDATE_TRAILER_BYTES - 8);
        b
    }

    fn known_chunk(seed: u64, rows: usize) -> SparseChunk {
        // test_model(seed) builds its codebook over Pcg(seed) uniforms —
        // replaying the generator reproduces in-vocabulary rows.
        let mut rng = Pcg::seed(seed);
        let mut c = SparseChunk::new();
        for _ in 0..rows {
            c.begin_row(0);
            for j in 0..3 {
                c.push_entry(j, rng.f64());
            }
            c.end_row();
        }
        c
    }

    #[test]
    fn zero_row_chunk_only_bumps_the_call_counter() {
        let mut m = test_model(50, 4, 3, 9);
        let before = frozen_prefix(&m);
        let chunk = SparseChunk::new();
        let mut ws = UpdateWorkspace::new();
        let rep = m.update(&chunk, &UpdateConfig::default(), &mut ws).unwrap();
        assert_eq!(rep.outcome, UpdateOutcome::Updated);
        assert_eq!(m.update_state.updates, 1);
        assert_eq!(m.update_state.rows_absorbed, 0);
        assert_eq!((m.update_state.unseen_ewma, m.update_state.residual_ewma), (0.0, 0.0));
        assert_eq!(frozen_prefix(&m), before, "no byte outside the trailer moved");
    }

    #[test]
    fn all_known_chunk_below_threshold_is_byte_invisible() {
        let mut m = test_model(50, 4, 3, 9);
        let before = frozen_prefix(&m);
        let chunk = known_chunk(9, 50);
        let mut ws = UpdateWorkspace::new();
        let rep = m.update(&chunk, &UpdateConfig::default(), &mut ws).unwrap();
        assert_eq!(rep.admitted, 0, "replayed training rows are all in vocabulary");
        assert_eq!(rep.unseen_rate, 0.0);
        assert_eq!(frozen_prefix(&m), before, "gate kept the fold off");
        assert_eq!(m.update_state.rows_absorbed, 50);
    }

    #[test]
    fn drifted_chunk_admits_widens_and_roundtrips() {
        let mut m = test_model(40, 4, 3, 11);
        let dim0 = m.codebook.dim;
        let mut c = SparseChunk::new();
        for i in 0..8 {
            c.begin_row(0);
            for j in 0..3u32 {
                c.push_entry(j, 40.0 + (i * 3 + j as usize) as f64);
            }
            c.end_row();
        }
        let mut ws = UpdateWorkspace::new();
        let rep = m.update(&c, &UpdateConfig::default(), &mut ws).unwrap();
        assert!(rep.admitted > 0);
        assert!(rep.unseen_rate > 0.0);
        assert_eq!(m.codebook.dim, dim0 + rep.admitted);
        assert_eq!(m.proj.rows, m.codebook.dim, "P widened to cover admissions");
        assert_eq!(m.update_state.bins_admitted, rep.admitted as u64);
        // the grown model persists and reloads exactly
        let bytes = m.to_bytes();
        let back = ScRbModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.codebook.dim, m.codebook.dim);
        assert_eq!(back.update_state, m.update_state);
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn update_streaming_quarantines_and_reports() {
        let mut m = test_model(40, 4, 3, 13);
        let text = b"0 1:0.5 2:0.5 3:0.5\n0 1:bad 2:x\n0 1:0.2 2:0.4 3:0.1\n".to_vec();
        let mut reader = LibsvmChunks::from_bytes(text, 2);
        let mut ws = UpdateWorkspace::new();
        let policy = IngestPolicy {
            on_bad_record: crate::stream::OnBadRecord::Quarantine,
            retry_backoff_ms: 0,
            ..Default::default()
        };
        let out =
            update_streaming(&mut m, &mut reader, &UpdateConfig::default(), policy, &mut ws)
                .unwrap();
        assert_eq!(out.rows, 2, "good rows absorbed");
        assert_eq!(out.quarantine.skipped(), 1, "bad row quarantined, not fatal");
        assert!(!out.refit_needed);
        assert_eq!(m.update_state.rows_absorbed, 2);
    }

    #[test]
    fn sustained_drift_escalates_to_refit() {
        let mut m = test_model(40, 4, 3, 17);
        let cfg = UpdateConfig { ewma: 0.9, unseen_refit: 0.3, ..Default::default() };
        let mut ws = UpdateWorkspace::new();
        let mut fired_at = None;
        for step in 0..6 {
            let mut c = SparseChunk::new();
            for i in 0..10 {
                c.begin_row(0);
                for j in 0..3u32 {
                    c.push_entry(j, 1000.0 + (step * 100 + i * 3 + j as usize) as f64);
                }
                c.end_row();
            }
            let rep = m.update(&c, &cfg, &mut ws).unwrap();
            if rep.outcome == UpdateOutcome::RefitNeeded {
                fired_at = Some(step);
                break;
            }
        }
        assert!(fired_at.is_some(), "saturated unseen rate must trigger");
        assert_eq!(m.update_state.refits_signaled, 1);
    }
}
