//! Rank-k incremental SVD over the gather-sum embedding.
//!
//! The fitted model stores `P = V·Σ⁻¹/√R` (D×K) and the singular values
//! `σ` of the normalized RB feature matrix `Ẑ` (every row has exactly R
//! entries of `1/√R`, one per grid, so `‖ẑ‖ = 1`). An update chunk is a
//! block of new `Ẑ` rows in a column space that admission may have just
//! widened; folding them in is a Brand-style secular update:
//!
//! ```text
//! [Ẑ ; Z_new] ≈ [U 0; 0 I] · M · [V  Q̃]ᵀ,   M = [diag σ   0]
//!                                                [  B      S]
//! ```
//!
//! where `B = Z_new·V` (computed bin-gather style: `B[i,j] = σ_j ·
//! Σ_{b ∈ bins(i)} P[b,j]`), and `Q̃`/`S` come from a modified
//! Gram-Schmidt over the residual **restricted to the columns this
//! sub-block admitted**. Those columns have all-zero `V` rows until
//! their own fold (the caller widens `P` with zero rows first), so `Q̃ ⊥
//! V` holds by construction and the update never needs to orthogonalize
//! against the full D×K basis. The in-span residual — new-row energy
//! inside the old columns but orthogonal to `V` — is **dropped but
//! measured**: its per-row mass `ρ_i² = 1 − ‖B_i‖² − ‖S_i‖²` is exactly
//! what the rank-k subspace cannot express, and its chunk mean feeds the
//! drift tracker's residual EWMA. Dropping it keeps the update O(c·(K +
//! R + a)) per row with no D-sized scratch.
//!
//! The thin SVD of the small `(K+c)×(K+q)` matrix `M` (c ≥ q, so it is
//! tall) yields the rotation `G` and new singular values `σ'`; `P`, `σ`
//! and the k-means centroids are rotated in place:
//!
//! ```text
//! P'[b,j] = (Σ_l G[l,j]·σ_l·P[b,l] + Σ_t G[K+t,j]·Q_t[b−base]/√R) / σ'_j
//! c'[j]   ∝  Σ_l G[l,j]·σ_l·c[l]
//! ```
//!
//! All scratch lives in [`SubspaceStep`]; once shapes stabilize (no
//! admission), `measure` + `fold` are allocation-free.

use crate::linalg::{svd_thin_into, Mat, SmallSvdWs};

/// Reusable workspace for one sub-block's measure/fold step.
#[derive(Default)]
pub struct SubspaceStep {
    /// `B = Z_new·V`, c×K.
    b: Mat,
    /// Residual restricted to this sub-block's admitted columns, c×a.
    resid: Mat,
    /// Orthonormal residual basis rows (first `q` rows valid), ≤c×a.
    qbasis: Mat,
    /// Gram-Schmidt coefficients `S` (first `q` columns valid), c×≤c.
    coeff: Mat,
    /// The small secular matrix `M`, (K+c)×(K+q).
    m: Mat,
    svd: SmallSvdWs,
    sig_old: Vec<f64>,
    row_tmp: Vec<f64>,
    /// `1/√R` of the most recent [`SubspaceStep::measure`] — the scale
    /// of one `Ẑ` entry, needed again when `fold` maps the Q basis into
    /// projection units.
    inv_sqrt_r: f64,
    /// Residual rank `q` of the most recent [`SubspaceStep::fold`].
    pub rank: usize,
}

impl SubspaceStep {
    pub fn new() -> SubspaceStep {
        SubspaceStep::default()
    }

    /// Project the sub-block onto the tracked subspace: fill `B` and the
    /// admitted-column residual, and return the summed out-of-span
    /// energy `Σ_i ρ_i²` (each ρ_i² clamped to [0, 1]; divide by the row
    /// count for the mean the drift tracker wants).
    ///
    /// `bins` is the sub-block's flat `rows × r` global-column table
    /// (admission already done); `block_base` is the projection height
    /// *before* this sub-block admitted, so columns `≥ block_base` are
    /// exactly the `a = proj.rows − block_base` freshly admitted ones.
    pub fn measure(
        &mut self,
        proj: &Mat,
        sigma: &[f64],
        bins: &[u32],
        rows: usize,
        r: usize,
        block_base: usize,
    ) -> f64 {
        let k = sigma.len();
        let a = proj.rows - block_base;
        debug_assert_eq!(proj.cols, k);
        debug_assert_eq!(bins.len(), rows * r);
        let inv_sqrt_r = 1.0 / (r as f64).sqrt();
        self.inv_sqrt_r = inv_sqrt_r;
        self.b.reset(rows, k);
        self.resid.reset(rows, a);
        let mut rho2 = 0.0;
        for i in 0..rows {
            let brow = self.b.row_mut(i);
            for &c in &bins[i * r..(i + 1) * r] {
                let c = c as usize;
                if c < block_base {
                    for (bj, pj) in brow.iter_mut().zip(proj.row(c).iter()) {
                        *bj += *pj;
                    }
                } else {
                    // V row is still all-zero: the whole 1/√R entry is
                    // residual mass in the admitted block.
                    self.resid.row_mut(i)[c - block_base] += inv_sqrt_r;
                }
            }
            // B[i,j] = ẑ_i·V[:,j] with V[b,j] = P[b,j]·σ_j·√R and ẑ
            // entries 1/√R — the √R factors cancel: B[i,j] = σ_j·Σ_b P[b,j].
            let mut inspan = 0.0;
            for (bj, &sj) in brow.iter_mut().zip(sigma.iter()) {
                *bj *= sj;
                inspan += *bj * *bj;
            }
            let res = self.resid.row(i).iter().map(|v| v * v).sum::<f64>();
            rho2 += (1.0 - inspan - res).clamp(0.0, 1.0);
        }
        rho2
    }

    /// Fold the sub-block measured by the latest
    /// [`SubspaceStep::measure`] into the model factors, rotating
    /// `proj`, `sigma` and `centroids` in place. `proj` must already be
    /// widened to cover the admitted columns (zero rows at the end).
    pub fn fold(&mut self, proj: &mut Mat, sigma: &mut [f64], centroids: &mut Mat, block_base: usize) {
        let k = sigma.len();
        let c = self.b.rows;
        let a = self.resid.cols;
        debug_assert_eq!(proj.rows, block_base + a);
        // Modified Gram-Schmidt over the residual rows → qbasis (q×a),
        // coeff (c×q). Orthogonality against V is free (disjoint support).
        let qcap = c.min(a);
        self.qbasis.reset(qcap, a);
        self.coeff.reset(c, qcap);
        let mut q = 0usize;
        for i in 0..c {
            self.row_tmp.clear();
            self.row_tmp.extend_from_slice(self.resid.row(i));
            for t in 0..q {
                let qt = self.qbasis.row(t);
                let dot: f64 = self.row_tmp.iter().zip(qt.iter()).map(|(x, y)| x * y).sum();
                self.coeff.set(i, t, dot);
                for (x, y) in self.row_tmp.iter_mut().zip(qt.iter()) {
                    *x -= dot * y;
                }
            }
            let left: f64 = self.row_tmp.iter().map(|v| v * v).sum::<f64>().sqrt();
            if left > 1e-10 && q < qcap {
                let inv = 1.0 / left;
                for (slot, x) in self.qbasis.row_mut(q).iter_mut().zip(self.row_tmp.iter()) {
                    *slot = x * inv;
                }
                self.coeff.set(i, q, left);
                q += 1;
            }
        }
        self.rank = q;
        // M = [[diag σ, 0], [B, S]], (k+c)×(k+q) — tall because q ≤ c.
        self.m.reset(k + c, k + q);
        for l in 0..k {
            self.m.set(l, l, sigma[l]);
        }
        for i in 0..c {
            for j in 0..k {
                self.m.set(k + i, j, self.b.at(i, j));
            }
            for t in 0..q {
                self.m.set(k + i, k + t, self.coeff.at(i, t));
            }
        }
        svd_thin_into(&self.m, &mut self.svd);
        let g = &self.svd.v; // (k+q)×(k+q) rotation
        self.sig_old.clear();
        self.sig_old.extend_from_slice(sigma);
        for (j, s) in sigma.iter_mut().enumerate() {
            *s = self.svd.s[j];
        }
        // Rotate the projection: rows below block_base are pure V
        // rotations; admitted rows additionally pick up the Q basis.
        self.row_tmp.resize(k, 0.0);
        for bidx in 0..proj.rows {
            let row = proj.row_mut(bidx);
            self.row_tmp.copy_from_slice(row);
            for (j, slot) in row.iter_mut().enumerate() {
                let sj = self.svd.s[j];
                if sj < 1e-12 {
                    *slot = 0.0;
                    continue;
                }
                let mut acc = 0.0;
                for l in 0..k {
                    acc += g.at(l, j) * self.sig_old[l] * self.row_tmp[l];
                }
                if bidx >= block_base {
                    // P' = V'·Σ'⁻¹/√R and the Q̃ block of V' is the raw
                    // (unit-scale) basis, so its contribution carries the
                    // 1/√R the old-V terms already had folded into P.
                    let bb = bidx - block_base;
                    for t in 0..q {
                        acc += g.at(k + t, j) * self.qbasis.at(t, bb) * self.inv_sqrt_r;
                    }
                }
                *slot = acc / sj;
            }
        }
        // Rotate centroids into the new coordinates and re-normalize
        // (embeddings are L2-normalized, so centroids should stay
        // comparable to unit vectors; the Lloyd polish refines after).
        for i in 0..centroids.rows {
            let row = centroids.row_mut(i);
            self.row_tmp.copy_from_slice(row);
            let mut nrm = 0.0;
            for (j, slot) in row.iter_mut().enumerate() {
                let sj = self.svd.s[j];
                let mut acc = 0.0;
                if sj >= 1e-12 {
                    for l in 0..k {
                        acc += g.at(l, j) * self.sig_old[l] * self.row_tmp[l];
                    }
                    acc /= sj;
                }
                *slot = acc;
                nrm += acc * acc;
            }
            let nrm = nrm.sqrt();
            if nrm > 1e-300 {
                for v in row.iter_mut() {
                    *v /= nrm;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_thin;

    const R: usize = 2;

    /// Build `Ẑ` rows (entries 1/√R) from (grid0 col, grid1 col) pairs
    /// over `d` global columns, plus the flat bins table.
    fn z_from_pairs(pairs: &[(usize, usize)], d: usize) -> (Mat, Vec<u32>) {
        let inv = 1.0 / (R as f64).sqrt();
        let mut z = Mat::zeros(pairs.len(), d);
        let mut bins = Vec::new();
        for (i, &(c0, c1)) in pairs.iter().enumerate() {
            z.set(i, c0, inv);
            z.set(i, c1, inv);
            bins.push(c0 as u32);
            bins.push(c1 as u32);
        }
        (z, bins)
    }

    /// Fit-time factors from `Ẑ`: keep the k numerically nonzero
    /// directions, P = V·Σ⁻¹/√R.
    fn factors(z: &Mat) -> (Mat, Vec<f64>) {
        let svd = svd_thin(z);
        let k = svd.s.iter().filter(|&&s| s > 1e-9).count();
        let mut proj = Mat::zeros(z.cols, k);
        let sqrt_r = (R as f64).sqrt();
        for b in 0..z.cols {
            for j in 0..k {
                proj.set(b, j, svd.v.at(b, j) / (svd.s[j] * sqrt_r));
            }
        }
        (proj, svd.s[..k].to_vec())
    }

    fn v_gram_error(proj: &Mat, sigma: &[f64]) -> f64 {
        // V[b,j] = P[b,j]·σ_j·√R must have orthonormal columns.
        let k = sigma.len();
        let sqrt_r = (R as f64).sqrt();
        let mut worst: f64 = 0.0;
        for i in 0..k {
            for j in 0..k {
                let mut dot = 0.0;
                for b in 0..proj.rows {
                    dot += proj.at(b, i) * sigma[i] * sqrt_r * proj.at(b, j) * sigma[j] * sqrt_r;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((dot - want).abs());
            }
        }
        worst
    }

    const TRAIN: &[(usize, usize)] =
        &[(0, 3), (1, 4), (2, 5), (0, 4), (1, 5), (2, 3), (0, 5), (1, 3), (2, 4), (0, 3)];

    #[test]
    fn duplicate_rows_fold_exactly() {
        let (z1, _) = z_from_pairs(TRAIN, 6);
        let (mut proj, mut sigma) = factors(&z1);
        let k = sigma.len();
        let mut centroids = Mat::zeros(2, k);
        centroids.set(0, 0, 1.0);
        centroids.set(1, 1, 1.0);
        // new chunk = 4 rows repeating known patterns: in rowspace(Z1)
        let dup = &TRAIN[2..6];
        let (z2, bins) = z_from_pairs(dup, 6);
        let mut step = SubspaceStep::new();
        let rho2 = step.measure(&proj, &sigma, &bins, dup.len(), R, 6);
        assert!(rho2 / dup.len() as f64 <= 0.3, "duplicates are mostly in span, got {rho2}");
        step.fold(&mut proj, &mut sigma, &mut centroids, 6);
        // ground truth: svd of the stacked matrix (same rowspace → exact)
        let mut stacked = Mat::zeros(z1.rows + z2.rows, 6);
        for i in 0..z1.rows {
            stacked.row_mut(i).copy_from_slice(z1.row(i));
        }
        for i in 0..z2.rows {
            stacked.row_mut(z1.rows + i).copy_from_slice(z2.row(i));
        }
        let truth = svd_thin(&stacked);
        for j in 0..k {
            assert!(
                (sigma[j] - truth.s[j]).abs() < 1e-8,
                "σ'_{j}: incremental {} vs direct {}",
                sigma[j],
                truth.s[j]
            );
        }
        assert!(v_gram_error(&proj, &sigma) < 1e-8, "V' stays orthonormal");
    }

    #[test]
    fn admitted_columns_enter_the_basis() {
        let (z1, _) = z_from_pairs(TRAIN, 6);
        let (mut proj, mut sigma) = factors(&z1);
        let k = sigma.len();
        let mut centroids = Mat::zeros(2, k);
        centroids.set(0, 0, 1.0);
        centroids.set(1, 1, 1.0);
        // chunk admits columns 6 and 7 (e.g. two new bins in grid 0)
        let chunk = &[(6, 3), (7, 4), (6, 4), (7, 3)];
        let (_, bins) = z_from_pairs(chunk, 8);
        // caller contract: proj widened with zero rows before measure
        proj.data.resize(8 * k, 0.0);
        proj.rows = 8;
        let mut step = SubspaceStep::new();
        let rho2 = step.measure(&proj, &sigma, &bins, chunk.len(), R, 6);
        assert!(rho2 > 0.5, "half of each new row's energy is admitted-column residual");
        let s_before = sigma.clone();
        step.fold(&mut proj, &mut sigma, &mut centroids, 6);
        assert!(step.rank >= 1 && step.rank <= 2, "two admitted columns → residual rank ≤ 2");
        // the admitted rows are no longer zero: the new columns joined V'
        let tail_energy: f64 = (6..8).map(|b| proj.row(b).iter().map(|v| v * v).sum::<f64>()).sum();
        assert!(tail_energy > 0.0);
        assert!(v_gram_error(&proj, &sigma) < 1e-8, "V' orthonormal after admission");
        for j in 1..k {
            assert!(sigma[j] <= sigma[j - 1] + 1e-12, "σ' descending");
        }
        assert!(sigma[0] >= s_before[0] - 1e-12, "energy only grows");
        // centroids stay unit-norm after the rotation
        for i in 0..2 {
            let n: f64 = centroids.row(i).iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-9, "centroid {i} norm {n}");
        }
    }

    #[test]
    fn out_of_span_mass_is_measured_even_when_dropped() {
        let (z1, _) = z_from_pairs(TRAIN, 6);
        let (proj, sigma) = factors(&z1);
        // a pattern never seen: (2, 4) appears in TRAIN... use rank
        // deficiency instead — K3,3 incidence has rank 5 < 6, so e.g.
        // a fresh single-bin-heavy combination keeps some mass outside
        // span(V). Any known-bins row has ρ² = 1 − ‖B‖² ≥ 0.
        let chunk = &[(0, 3), (1, 4)];
        let (_, bins) = z_from_pairs(chunk, 6);
        let mut step = SubspaceStep::new();
        let rho2 = step.measure(&proj, &sigma, &bins, chunk.len(), R, 6);
        assert!((0.0..=2.0).contains(&rho2));
        // B matches the direct projection Z2·V
        let sqrt_r = (R as f64).sqrt();
        for (i, &(c0, c1)) in chunk.iter().enumerate() {
            for j in 0..sigma.len() {
                let inv = 1.0 / sqrt_r;
                let direct = inv * proj.at(c0, j) * sigma[j] * sqrt_r
                    + inv * proj.at(c1, j) * sigma[j] * sqrt_r;
                assert!((step.b.at(i, j) - direct).abs() < 1e-12);
            }
        }
    }
}
