//! Chunk binning with codebook admission.
//!
//! Incoming update rows are brought into the model's stored input frame
//! (the same densify + min-max mapping the streamed fit applies:
//! implicit zeros map to `(0 − min)/span`, explicit entries to
//! `(v − min)/span`), then binned against the fitted codebook with
//! **admission**: a bin never seen before gets the next global column
//! via [`RbCodebook::admit`], growing the column space at the end so
//! every fit-time column keeps its meaning. The caller widens the
//! projection with matching zero rows before any embedding math runs.
//!
//! All scratch lives in [`ChunkBins`] — once provisioned for the model's
//! input width and the configured block size, re-binning chunks whose
//! bins are already known allocates nothing (only an actual admission
//! can grow the underlying tables).

use crate::error::ScrbError;
use crate::rb::RbCodebook;
use crate::stream::SparseChunk;

/// Reusable binning scratch: the dense row buffer, the precomputed
/// normalized-zero row, and the flattened `rows × R` bin-column output.
#[derive(Default)]
pub struct ChunkBins {
    dense: Vec<f64>,
    zero_row: Vec<f64>,
    /// Global column of every (row, grid) lookup for the most recent
    /// [`ChunkBins::bin_rows`] call, row-major `c × R`.
    pub bins: Vec<u32>,
}

impl ChunkBins {
    pub fn new() -> ChunkBins {
        ChunkBins::default()
    }

    /// Size the dense scratch for `d_in` input features and refresh the
    /// implicit-zero row for `norm`. Idempotent and allocation-free once
    /// the buffers have seen `d_in`.
    fn ensure(&mut self, d_in: usize, norm: Option<(&[f64], &[f64])>) {
        self.dense.resize(d_in, 0.0);
        self.zero_row.resize(d_in, 0.0);
        match norm {
            Some((lo, span)) => {
                for c in 0..d_in {
                    self.zero_row[c] = (0.0 - lo[c]) / span[c];
                }
            }
            None => self.zero_row.fill(0.0),
        }
    }

    /// Densify, normalize and bin chunk rows `[r0, r1)` against
    /// `codebook`, admitting unseen bins. `chunk_base` is the codebook
    /// dimension at the start of the whole update chunk: every lookup
    /// that lands at or past it would have missed the *fit-time*
    /// codebook, which is the pre-admission unseen count the drift
    /// tracker wants. Returns `(admitted, unseen_hits)`; the per-lookup
    /// columns land in `self.bins` (row-major `(r1 − r0) × R`).
    pub fn bin_rows(
        &mut self,
        codebook: &mut RbCodebook,
        norm: Option<(&[f64], &[f64])>,
        chunk: &SparseChunk,
        r0: usize,
        r1: usize,
        chunk_base: usize,
    ) -> Result<(usize, usize), ScrbError> {
        let d_in = codebook.d_in;
        let r = codebook.r;
        self.ensure(d_in, norm);
        self.bins.clear();
        self.bins.resize((r1 - r0) * r, 0);
        let mut admitted = 0usize;
        let mut unseen = 0usize;
        for (bi, i) in (r0..r1).enumerate() {
            let (cols, vals) = chunk.row(i);
            self.dense.copy_from_slice(&self.zero_row);
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                let c = c as usize;
                if c >= d_in {
                    return Err(ScrbError::invalid_input(format!(
                        "update chunk row {i} has feature index {c}, but the model was \
                         fitted on {d_in} input features"
                    )));
                }
                self.dense[c] = match norm {
                    Some((lo, span)) => (v - lo[c]) / span[c],
                    None => v,
                };
            }
            let out = &mut self.bins[bi * r..(bi + 1) * r];
            for (j, slot) in out.iter_mut().enumerate() {
                let (col, was_admitted) = codebook.admit(j, &self.dense);
                admitted += was_admitted as usize;
                unseen += (col as usize >= chunk_base) as usize;
                *slot = col;
            }
        }
        Ok((admitted, unseen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rb::rb_features_with_codebook;
    use crate::util::rng::Pcg;

    fn chunk_from_rows(x: &Mat, rows: std::ops::Range<usize>) -> SparseChunk {
        let mut c = SparseChunk::new();
        for i in rows {
            c.begin_row(0);
            for (j, &v) in x.row(i).iter().enumerate() {
                c.push_entry(j as u32, v);
            }
            c.end_row();
        }
        c
    }

    #[test]
    fn known_rows_bin_without_admission_and_match_lookup() {
        let mut rng = Pcg::seed(5);
        let n = 40;
        let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.f64()).collect());
        let (_, mut cb) = rb_features_with_codebook(&x, 6, 0.5, 11);
        let dim0 = cb.dim;
        let chunk = chunk_from_rows(&x, 0..n);
        let mut ws = ChunkBins::new();
        let (admitted, unseen) = ws.bin_rows(&mut cb, None, &chunk, 0, n, dim0).unwrap();
        assert_eq!((admitted, unseen), (0, 0), "training rows are all known");
        assert_eq!(cb.dim, dim0);
        for i in 0..n {
            for j in 0..cb.r {
                assert_eq!(Some(ws.bins[i * cb.r + j]), cb.lookup(j, x.row(i)));
            }
        }
    }

    #[test]
    fn shifted_rows_admit_new_tail_columns() {
        let mut rng = Pcg::seed(6);
        let n = 30;
        let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.f64()).collect());
        let (_, mut cb) = rb_features_with_codebook(&x, 4, 0.5, 13);
        let dim0 = cb.dim;
        let far = Mat::from_vec(2, 3, vec![50.0, -40.0, 30.0, 51.0, -41.0, 31.0]);
        let chunk = chunk_from_rows(&far, 0..2);
        let mut ws = ChunkBins::new();
        let (admitted, unseen) = ws.bin_rows(&mut cb, None, &chunk, 0, 2, dim0).unwrap();
        assert!(admitted > 0, "far rows must admit");
        assert!(unseen >= admitted, "every admitted lookup counts as unseen");
        assert_eq!(cb.dim, dim0 + admitted);
        // admitted columns are contiguous at the end of the column space
        for &c in &ws.bins {
            assert!((c as usize) < cb.dim);
        }
        // binning the same rows again: fully known now
        let (a2, u2) = ws.bin_rows(&mut cb, None, &chunk, 0, 2, cb.dim).unwrap();
        assert_eq!((a2, u2), (0, 0));
    }

    #[test]
    fn normalization_matches_the_streamed_frame() {
        // one feature, norm (min=1, span=2): implicit zero -> -0.5, v=3 -> 1.0
        use crate::rb::grid::sample_grids;
        use crate::rb::BinTable;
        let grids = sample_grids(2, 2, 0.7, 3);
        let mut cb = RbCodebook {
            r: 2,
            d_in: 2,
            sigma: 0.7,
            seed: 3,
            dim: 0,
            grids,
            tables: vec![BinTable::new(), BinTable::new()],
        };
        let lo = vec![1.0, 1.0];
        let span = vec![2.0, 2.0];
        let mut sparse = SparseChunk::new();
        sparse.begin_row(0);
        sparse.push_entry(1, 3.0); // feature 0 implicit zero
        sparse.end_row();
        let mut ws = ChunkBins::new();
        ws.bin_rows(&mut cb, Some((&lo, &span)), &sparse, 0, 1, 0).unwrap();
        // the dense frame the row was binned in is [-0.5, 1.0]
        let expect = [-0.5, 1.0];
        for j in 0..2 {
            assert_eq!(Some(ws.bins[j]), cb.lookup(j, &expect));
        }
    }

    #[test]
    fn out_of_range_feature_is_a_typed_error() {
        let mut rng = Pcg::seed(7);
        let x = Mat::from_vec(10, 2, (0..20).map(|_| rng.f64()).collect());
        let (_, mut cb) = rb_features_with_codebook(&x, 3, 0.5, 17);
        let mut sparse = SparseChunk::new();
        sparse.begin_row(0);
        sparse.push_entry(9, 1.0);
        sparse.end_row();
        let mut ws = ChunkBins::new();
        let e = ws.bin_rows(&mut cb, None, &sparse, 0, 1, cb.dim).unwrap_err();
        assert!(matches!(e, ScrbError::InvalidInput(_)), "{e}");
    }
}
