//! Drift accounting and the refit trigger.
//!
//! Every [`ScRbModel::update`](crate::model::ScRbModel::update) call
//! produces two scalar drift observations:
//!
//! - the **pre-admission unseen-bin rate** — the fraction of the chunk's
//!   `rows × R` bin lookups that the fit-time codebook would have missed
//!   (the same signal the serving [`DriftMonitor`] counts, measured here
//!   *before* admission papers over it);
//! - the **subspace residual ratio** — the fraction of the chunk's
//!   embedding energy the tracked rank-k subspace could not express
//!   (in-span drift that admission alone cannot see).
//!
//! [`DriftTracker::observe`] folds both into the EWMAs persisted in the
//! model's [`UpdateState`] and decides whether the incremental path is
//! still sound. Past either configured threshold it escalates with
//! [`UpdateOutcome::RefitNeeded`] — the caller (CLI `scrb update`, serve
//! daemon) is expected to run the full streamed refit and publish the
//! result through the validated hot-swap slot. The trigger is
//! **deterministic under a fixed seed**: the EWMA arithmetic is exact,
//! and the only randomness — a jittered cool-down that keeps a caller
//! who ignores the signal from being re-signalled on every subsequent
//! chunk — comes from a [`Pcg`] stream seeded by
//! [`UpdateConfig::seed`].
//!
//! [`DriftMonitor`]: crate::model::DriftMonitor

use crate::config::UpdateConfig;
use crate::model::UpdateState;
use crate::util::rng::Pcg;

/// Outcome of one incremental update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOutcome {
    /// The chunk was absorbed; the model keeps serving incrementally.
    Updated,
    /// Drift crossed a configured threshold: the chunk was still
    /// absorbed, but the caller should escalate to a full streamed refit
    /// (and publish it through the serve daemon's hot-swap slot).
    RefitNeeded,
}

/// EWMA drift accumulator + seeded refit trigger (see the module doc).
/// Lives inside the [`UpdateWorkspace`](crate::update::UpdateWorkspace)
/// so its cool-down and RNG stream persist across the updates of one
/// maintenance session; the EWMAs themselves persist *in the model*
/// ([`UpdateState`]), surviving save/load.
#[derive(Debug)]
pub struct DriftTracker {
    rng: Pcg,
    /// Updates remaining before another `RefitNeeded` may fire.
    cooldown: u64,
}

impl DriftTracker {
    pub fn new(cfg: &UpdateConfig) -> DriftTracker {
        DriftTracker { rng: Pcg::seed(cfg.seed ^ 0x5bcb_d81f_u64), cooldown: 0 }
    }

    /// Fold one update's observations into the persisted EWMAs and
    /// decide. `unseen` and `residual` are rates in [0, 1]; the caller
    /// guarantees both are finite.
    pub fn observe(
        &mut self,
        st: &mut UpdateState,
        cfg: &UpdateConfig,
        unseen: f64,
        residual: f64,
    ) -> UpdateOutcome {
        let a = cfg.ewma;
        st.unseen_ewma = (a * unseen + (1.0 - a) * st.unseen_ewma).clamp(0.0, 1.0);
        st.residual_ewma = (a * residual + (1.0 - a) * st.residual_ewma).clamp(0.0, 1.0);
        let over =
            st.unseen_ewma > cfg.unseen_refit || st.residual_ewma > cfg.residual_refit;
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
        if over && self.cooldown == 0 {
            // jittered cool-down before re-signalling: a caller that keeps
            // updating past a signal gets a bounded number of repeats, not
            // one per chunk. Seeded, so the firing pattern is reproducible.
            self.cooldown = 1 + self.rng.below(4) as u64;
            st.refits_signaled += 1;
            return UpdateOutcome::RefitNeeded;
        }
        UpdateOutcome::Updated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> UpdateConfig {
        UpdateConfig { ewma: 0.5, unseen_refit: 0.2, residual_refit: 0.9, ..Default::default() }
    }

    #[test]
    fn ewmas_accumulate_and_trigger_deterministically() {
        let cfg = cfg();
        let runs: Vec<Vec<UpdateOutcome>> = (0..2)
            .map(|_| {
                let mut t = DriftTracker::new(&cfg);
                let mut st = UpdateState::default();
                (0..12).map(|i| t.observe(&mut st, &cfg, if i >= 4 { 0.5 } else { 0.0 }, 0.1)).collect()
            })
            .collect();
        // identical seed -> identical firing pattern
        assert_eq!(runs[0], runs[1]);
        // quiet phase never fires; drifted phase fires at a fixed step
        assert!(runs[0][..4].iter().all(|&o| o == UpdateOutcome::Updated));
        let first = runs[0].iter().position(|&o| o == UpdateOutcome::RefitNeeded);
        assert_eq!(first, Some(4), "0.5 obs at ewma 0.5 crosses 0.2 immediately");
    }

    #[test]
    fn cooldown_bounds_resignalling() {
        let cfg = cfg();
        let mut t = DriftTracker::new(&cfg);
        let mut st = UpdateState::default();
        let fired: usize = (0..50)
            .map(|_| t.observe(&mut st, &cfg, 1.0, 0.0))
            .filter(|&o| o == UpdateOutcome::RefitNeeded)
            .count();
        assert!(fired >= 10, "sustained drift keeps signalling ({fired})");
        assert!(fired < 50, "cool-down suppresses per-chunk spam ({fired})");
        assert_eq!(st.refits_signaled, fired as u64);
    }

    #[test]
    fn residual_threshold_is_an_independent_trigger() {
        let cfg = cfg();
        let mut t = DriftTracker::new(&cfg);
        let mut st = UpdateState::default();
        // unseen stays clean; residual saturates past 0.9
        let mut outcomes = Vec::new();
        for _ in 0..8 {
            outcomes.push(t.observe(&mut st, &cfg, 0.0, 1.0));
        }
        assert!(outcomes.contains(&UpdateOutcome::RefitNeeded));
        assert_eq!(st.unseen_ewma, 0.0);
        assert!(st.residual_ewma > 0.9);
    }
}
