//! Sharded parallel featurization with mergeable RB codebooks.
//!
//! The streaming fit in [`crate::stream`] is a two-pass, single-reader
//! scan. This module parallelizes it across K independent readers —
//! byte-range windows of one file or runs of whole files, planned by
//! [`ShardPlanner`] — while keeping the headline guarantee of the
//! sequential path: the merged fit is **bit-identical** to the
//! sequential fit over the shard concatenation, for any shard count.
//!
//! The run has three phases:
//!
//! 1. **Stats** — K scoped worker threads each run the statistics pass
//!    over their shard behind a fresh per-shard
//!    [`GuardedReader`](crate::stream::GuardedReader); the per-shard
//!    extrema/census merge exactly (min/max/sum are associative), fixing
//!    the global `(lo, span)` frame and per-shard row counts.
//! 2. **Featurize** — the workers reset their readers (the sequential
//!    path's one reset, so per-shard fault injection sees identical
//!    pass/retry semantics) and featurize their rows with a
//!    [`StreamFeaturizer`] pinned to the *global* frame, emitting
//!    shard-local codebooks and local-id substrate blocks. Each worker
//!    gets `num_threads() / K` inner threads so K shards don't
//!    oversubscribe the pool.
//! 3. **Merge** — [`CodebookMerger`] unions the shard codebooks in
//!    canonical first-seen order, relabels every block into global
//!    columns, recomputes κ exactly, and concatenates labels and
//!    quarantine reports ([`merge_quarantines`]) in shard order.
//!
//! Phase errors surface deterministically: the lowest-index failing
//! shard wins, so a bad byte produces the same error no matter how the
//! thread race falls.

pub mod merge;
pub mod planner;

pub use merge::{merge_quarantines, CodebookMerger, ShardState};
pub use planner::{expand_patterns, ShardFormat, ShardPart, ShardPlan, ShardPlanner};

use crate::error::ScrbError;
use crate::stream::stats::stats_pass;
use crate::stream::{
    ChunkReader, GuardedReader, IngestPolicy, Quarantine, SparseChunk, StreamFeaturizer,
    StreamFeatures, StreamStats,
};
use crate::util::threads::num_threads;
use std::time::{Duration, Instant};

/// Result of a sharded featurization: the merged features plus the
/// global frame and phase accounting the fit driver folds into its
/// artifact and timers.
pub struct ShardedFeatures {
    /// Merged features, bit-identical to the sequential fit's.
    pub features: StreamFeatures,
    /// Total rows across shards.
    pub n: usize,
    /// Input dimensionality (max over shard readers).
    pub d: usize,
    /// Global per-column minimum from the merged stats pass.
    pub lo: Vec<f64>,
    /// Global per-column span from the merged stats pass.
    pub span: Vec<f64>,
    /// Merged quarantine/retry report, shard-then-line sample order.
    pub quarantine: Quarantine,
    /// Wallclock of the parallel stats phase.
    pub stats_time: Duration,
    /// Wallclock of the parallel featurize phase.
    pub featurize_time: Duration,
    /// Wallclock of the codebook/substrate merge.
    pub merge_time: Duration,
}

/// Run the sharded two-pass featurization over `readers` (shard =
/// dataset order) and merge the results. Each shard runs behind its own
/// [`GuardedReader`] under `policy`; `block_rows` is the substrate block
/// size within each shard (the cut points differ from the sequential
/// run's, which is fine — the substrate kernels and the serialized model
/// are partition-invariant).
pub fn featurize_sharded(
    r: usize,
    sigma: f64,
    seed: u64,
    readers: &mut [&mut (dyn ChunkReader + Send)],
    block_rows: usize,
    policy: &IngestPolicy,
) -> Result<ShardedFeatures, ScrbError> {
    if readers.is_empty() {
        return Err(ScrbError::config("sharded featurization needs at least one shard"));
    }
    let k = readers.len();

    // phase 1: per-shard stats, in parallel
    let t0 = Instant::now();
    let phase_a: Vec<(StreamStats, usize, usize)> = par_shards(readers, |_s, reader| {
        let mut guarded = GuardedReader::new(reader, policy.clone());
        let mut chunk = SparseChunk::new();
        let stats = stats_pass(&mut guarded, &mut chunk)?;
        let d_s = guarded.dim();
        let retries = guarded.report().retries;
        Ok((stats, d_s, retries))
    })?;
    let stats_time = t0.elapsed();

    let mut merged = StreamStats::new();
    let mut d = 0usize;
    let mut shard_rows = Vec::with_capacity(k);
    let mut shard_retries = Vec::with_capacity(k);
    for (stats, d_s, retries) in &phase_a {
        shard_rows.push(stats.n);
        shard_retries.push(*retries);
        d = d.max(*d_s);
        merged.merge(stats);
    }
    let n = merged.n;
    if n == 0 {
        return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
    }
    let (lo, span) = merged.finalize(d);

    // phase 2: per-shard featurization against the global frame; divide
    // the thread pool so K workers don't oversubscribe it
    let inner_threads = (num_threads() / k).max(1);
    let t1 = Instant::now();
    let phase_b: Vec<(ShardState, Quarantine)> = par_shards(readers, |s, reader| {
        let mut guarded = GuardedReader::new(reader, policy.clone());
        guarded.reset()?;
        let mut fz = StreamFeaturizer::new(
            r,
            d,
            sigma,
            seed,
            lo.clone(),
            span.clone(),
            block_rows,
            shard_rows[s],
        )
        .with_threads(inner_threads);
        let mut chunk = SparseChunk::new();
        while guarded.next_chunk(&mut chunk)? {
            if guarded.dim() > d {
                return Err(ScrbError::invalid_input(format!(
                    "stream changed between passes: dimension grew from {d} to {} in shard {s}",
                    guarded.dim()
                )));
            }
            fz.push_chunk(&chunk);
        }
        if fz.rows() != shard_rows[s] {
            return Err(ScrbError::invalid_input(format!(
                "stream changed between passes: shard {s} had {} rows in the stats pass, {} in \
                 the featurize pass",
                shard_rows[s],
                fz.rows()
            )));
        }
        // the fresh phase-2 guard lost phase 1's transient-retry count;
        // fold it back so the merged report covers both passes, like the
        // sequential single-guard run
        let mut report = guarded.report();
        report.retries += shard_retries[s];
        let (grids, blocks, labels) = fz.into_state();
        Ok((ShardState { grids, blocks, labels }, report))
    })?;
    let featurize_time = t1.elapsed();

    // phase 3: merge
    let t2 = Instant::now();
    let (states, reports): (Vec<ShardState>, Vec<Quarantine>) = phase_b.into_iter().unzip();
    let quarantine = merge_quarantines(reports, policy.sample_cap);
    let merger = CodebookMerger { r, d_in: d, sigma, seed };
    let features = merger.merge(states)?;
    let merge_time = t2.elapsed();

    Ok(ShardedFeatures {
        features,
        n,
        d,
        lo,
        span,
        quarantine,
        stats_time,
        featurize_time,
        merge_time,
    })
}

/// Run `f` once per shard on scoped worker threads, collecting results
/// in shard order. On failure the *lowest-index* shard's error is
/// returned regardless of thread timing, keeping failures deterministic.
fn par_shards<T, F>(
    readers: &mut [&mut (dyn ChunkReader + Send)],
    f: F,
) -> Result<Vec<T>, ScrbError>
where
    T: Send,
    F: Fn(usize, &mut (dyn ChunkReader + Send)) -> Result<T, ScrbError> + Sync,
{
    let mut slots: Vec<Option<Result<T, ScrbError>>> = Vec::with_capacity(readers.len());
    slots.resize_with(readers.len(), || None);
    std::thread::scope(|scope| {
        for (s, (slot, reader)) in slots.iter_mut().zip(readers.iter_mut()).enumerate() {
            let f = &f;
            scope.spawn(move || {
                *slot = Some(f(s, &mut **reader));
            });
        }
    });
    let mut out = Vec::with_capacity(slots.len());
    for slot in slots {
        out.push(slot.expect("shard worker writes its slot before exiting")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::LibsvmChunks;

    fn reader_of(bytes: &[u8]) -> LibsvmChunks {
        LibsvmChunks::from_bytes(bytes.to_vec(), 5)
    }

    #[test]
    fn sharded_matches_sequential_single_shard() {
        let text = b"0 1:0.5 2:1.5\n1 1:-0.5 2:0.25\n0 2:2.0\n1 1:1.0\n".to_vec();
        let policy = IngestPolicy::default();
        let mut seq = reader_of(&text);
        let mut seq_ref: &mut (dyn ChunkReader + Send) = &mut seq;
        let one = featurize_sharded(
            8,
            0.7,
            11,
            std::slice::from_mut(&mut seq_ref),
            3,
            &policy,
        )
        .unwrap();

        let mut a = reader_of(b"0 1:0.5 2:1.5\n1 1:-0.5 2:0.25\n");
        let mut b = reader_of(b"0 2:2.0\n1 1:1.0\n");
        let mut refs: Vec<&mut (dyn ChunkReader + Send)> = vec![&mut a, &mut b];
        let two = featurize_sharded(8, 0.7, 11, &mut refs, 3, &policy).unwrap();

        assert_eq!(one.n, two.n);
        assert_eq!(one.d, two.d);
        assert_eq!(one.lo, two.lo);
        assert_eq!(one.span, two.span);
        assert_eq!(one.features.labels, two.features.labels);
        assert_eq!(one.features.bins_per_grid, two.features.bins_per_grid);
        assert_eq!(one.features.kappa.to_bits(), two.features.kappa.to_bits());
    }

    #[test]
    fn empty_dataset_is_rejected() {
        let mut a = reader_of(b"");
        let mut b = reader_of(b"");
        let mut refs: Vec<&mut (dyn ChunkReader + Send)> = vec![&mut a, &mut b];
        let err = featurize_sharded(4, 1.0, 1, &mut refs, 2, &IngestPolicy::default());
        assert!(err.is_err());
        assert!(featurize_sharded(4, 1.0, 1, &mut [], 2, &IngestPolicy::default()).is_err());
    }

    #[test]
    fn zero_row_shards_merge_as_noops() {
        let text = b"0 1:0.5\n1 1:1.5\n0 1:2.5\n".to_vec();
        let policy = IngestPolicy::default();
        let mut seq = reader_of(&text);
        let mut seq_ref: &mut (dyn ChunkReader + Send) = &mut seq;
        let one =
            featurize_sharded(4, 0.9, 5, std::slice::from_mut(&mut seq_ref), 2, &policy).unwrap();

        let mut a = reader_of(b"");
        let mut b = reader_of(&text);
        let mut c = reader_of(b"");
        let mut refs: Vec<&mut (dyn ChunkReader + Send)> = vec![&mut a, &mut b, &mut c];
        let three = featurize_sharded(4, 0.9, 5, &mut refs, 2, &policy).unwrap();
        assert_eq!(one.features.labels, three.features.labels);
        assert_eq!(one.features.bins_per_grid, three.features.bins_per_grid);
    }
}
