//! Shard planning: split a dataset into K contiguous row ranges, each
//! backed by its own independent [`ChunkReader`].
//!
//! Two dataset shapes are supported, both with a deterministic shard
//! order (shard s's rows precede shard s+1's rows in the logical
//! concatenation — the invariant the codebook merge's first-seen
//! equivalence proof rests on):
//!
//! - **A single file** is split by byte range: the K-1 interior cut
//!   points land at `i·size/K` and are then rolled forward to the next
//!   line start, so every line belongs to exactly one shard and the cut
//!   sequence is a pure function of (file bytes, K). Shards near the end
//!   of a small file may be empty — a zero-row shard featurizes to
//!   nothing and merges as a no-op.
//! - **Multiple files** (explicit list and/or `*`/`?` globs, expanded in
//!   sorted order) are partitioned contiguously by cumulative byte size:
//!   file boundaries are the only cut points, each shard gets a
//!   consecutive run of files (possibly none, possibly several chained
//!   behind one [`ChainChunks`]).
//!
//! The plan is data: inspectable, loggable, and — because it is
//! deterministic — reproducible across runs and machines reading the
//! same bytes.

use crate::error::ScrbError;
use crate::stream::reader::{ChainChunks, CsvChunks, LibsvmChunks};
use crate::stream::ChunkReader;
use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};

/// Text format of the dataset being sharded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardFormat {
    /// LibSVM sparse rows (`label idx:val ...`).
    Libsvm,
    /// Dense CSV rows (`label,v1,...,vd`).
    Csv,
}

/// One contiguous byte window of one file — the unit a shard is made of.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPart {
    /// Source file path.
    pub path: String,
    /// First byte of the window (always a line boundary).
    pub start: u64,
    /// One past the last byte of the window; `None` = to EOF. A line is
    /// read iff it *starts* inside the window.
    pub end: Option<u64>,
}

/// A complete sharding of a dataset: `shards[s]` lists shard s's parts in
/// dataset order. Empty part lists are legal (zero-row shards).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Text format every part is parsed as.
    pub format: ShardFormat,
    /// Rows per chunk for the readers [`ShardPlanner::open`] builds.
    pub chunk_rows: usize,
    /// Per-shard part lists, shard order = dataset order.
    pub shards: Vec<Vec<ShardPart>>,
}

impl ShardPlan {
    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True when the plan has no shards (never produced by the planner).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }
}

/// Plans and opens shard readers; see the module docs for the split
/// rules.
pub struct ShardPlanner {
    shards: usize,
    chunk_rows: usize,
    format: ShardFormat,
}

impl ShardPlanner {
    /// A planner for `shards` shards reading `chunk_rows`-row chunks.
    pub fn new(shards: usize, chunk_rows: usize, format: ShardFormat) -> ShardPlanner {
        assert!(shards >= 1, "need at least one shard");
        assert!(chunk_rows >= 1, "chunk_rows must be at least 1");
        ShardPlanner { shards, chunk_rows, format }
    }

    /// Build the shard plan for `patterns` (file paths and/or `*`/`?`
    /// globs over file names). One matched file splits by byte range;
    /// several partition contiguously by size.
    pub fn plan(&self, patterns: &[String]) -> Result<ShardPlan, ScrbError> {
        let files = expand_patterns(patterns)?;
        let sized: Vec<(String, u64)> = files
            .into_iter()
            .map(|p| {
                let meta = std::fs::metadata(&p).map_err(|e| ScrbError::io(&p, e))?;
                Ok((p, meta.len()))
            })
            .collect::<Result<_, ScrbError>>()?;
        let shards = if sized.len() == 1 {
            let (path, size) = &sized[0];
            plan_byte_ranges(path, *size, self.shards)?
        } else {
            plan_file_runs(&sized, self.shards)
        };
        Ok(ShardPlan { format: self.format, chunk_rows: self.chunk_rows, shards })
    }

    /// Open one independent reader per shard of `plan`. A one-part shard
    /// gets a ranged reader on its window; a multi-part shard chains its
    /// parts; a zero-part shard gets an empty in-memory reader.
    pub fn open(plan: &ShardPlan) -> Result<Vec<Box<dyn ChunkReader + Send>>, ScrbError> {
        plan.shards
            .iter()
            .map(|parts| match parts.len() {
                0 => Ok(empty_reader(plan.format, plan.chunk_rows)),
                1 => part_reader(plan.format, plan.chunk_rows, &parts[0]),
                _ => {
                    let readers = parts
                        .iter()
                        .map(|p| part_reader(plan.format, plan.chunk_rows, p))
                        .collect::<Result<Vec<_>, ScrbError>>()?;
                    Ok(Box::new(ChainChunks::new(readers)) as Box<dyn ChunkReader + Send>)
                }
            })
            .collect()
    }
}

fn empty_reader(format: ShardFormat, chunk_rows: usize) -> Box<dyn ChunkReader + Send> {
    match format {
        ShardFormat::Libsvm => Box::new(LibsvmChunks::from_bytes(Vec::new(), chunk_rows)),
        ShardFormat::Csv => Box::new(CsvChunks::from_bytes(Vec::new(), chunk_rows)),
    }
}

fn part_reader(
    format: ShardFormat,
    chunk_rows: usize,
    part: &ShardPart,
) -> Result<Box<dyn ChunkReader + Send>, ScrbError> {
    Ok(match format {
        ShardFormat::Libsvm => {
            Box::new(LibsvmChunks::from_path_range(&part.path, chunk_rows, part.start, part.end)?)
        }
        ShardFormat::Csv => {
            Box::new(CsvChunks::from_path_range(&part.path, chunk_rows, part.start, part.end)?)
        }
    })
}

/// Expand `patterns` into a flat file list. A `*`/`?` wildcard is only
/// honored in the final path component; matches are sorted so the
/// dataset order — and with it every shard's row range — is independent
/// of directory-iteration order. Plain paths pass through untouched; a
/// glob matching nothing is a config error (a silent empty dataset hides
/// typos).
pub fn expand_patterns(patterns: &[String]) -> Result<Vec<String>, ScrbError> {
    if patterns.is_empty() {
        return Err(ScrbError::config("no input files given"));
    }
    let mut out = Vec::new();
    for pat in patterns {
        if !pat.contains('*') && !pat.contains('?') {
            out.push(pat.clone());
            continue;
        }
        let (dir, name_pat) = match pat.rfind('/') {
            Some(i) => (&pat[..i], &pat[i + 1..]),
            None => (".", &pat[..]),
        };
        if dir.contains('*') || dir.contains('?') {
            return Err(ScrbError::config(format!(
                "glob wildcards are only supported in the file name, not in directories: '{pat}'"
            )));
        }
        let entries = std::fs::read_dir(dir).map_err(|e| ScrbError::io(dir, e))?;
        let mut matched = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| ScrbError::io(dir, e))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if wildcard_match(name_pat, name) && entry.path().is_file() {
                matched.push(format!("{dir}/{name}"));
            }
        }
        if matched.is_empty() {
            return Err(ScrbError::config(format!("glob '{pat}' matched no files")));
        }
        matched.sort();
        out.extend(matched);
    }
    Ok(out)
}

/// Glob-lite matcher: `*` spans any run (including empty), `?` any one
/// character, everything else literal. Iterative backtracking — no
/// recursion, no allocation.
fn wildcard_match(pattern: &str, name: &str) -> bool {
    let (p, n) = (pattern.as_bytes(), name.as_bytes());
    let (mut pi, mut ni) = (0usize, 0usize);
    let (mut star, mut mark) = (usize::MAX, 0usize);
    while ni < n.len() {
        if pi < p.len() && (p[pi] == b'?' || p[pi] == n[ni]) {
            pi += 1;
            ni += 1;
        } else if pi < p.len() && p[pi] == b'*' {
            star = pi;
            mark = ni;
            pi += 1;
        } else if star != usize::MAX {
            pi = star + 1;
            mark += 1;
            ni = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == b'*' {
        pi += 1;
    }
    pi == p.len()
}

/// Split one file of `size` bytes into `k` line-aligned byte windows:
/// interior cuts start at `i·size/k` and roll forward to the next line
/// start. Cuts are monotone by construction, so windows partition the
/// file; trailing windows may be empty.
fn plan_byte_ranges(path: &str, size: u64, k: usize) -> Result<Vec<Vec<ShardPart>>, ScrbError> {
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push(0u64);
    if k > 1 {
        let file = File::open(path).map_err(|e| ScrbError::io(path, e))?;
        let mut reader = BufReader::new(file);
        let mut scratch = Vec::new();
        for i in 1..k {
            let target = size * i as u64 / k as u64;
            bounds.push(align_to_line(&mut reader, path, target, size, &mut scratch)?);
        }
    }
    bounds.push(size);
    Ok(bounds
        .windows(2)
        .map(|w| vec![ShardPart { path: path.to_string(), start: w[0], end: Some(w[1]) }])
        .collect())
}

/// Roll `target` forward to the next line start of the open file (or to
/// `size` if no newline follows). `target` itself is kept when it already
/// sits on a line boundary.
fn align_to_line(
    reader: &mut BufReader<File>,
    path: &str,
    target: u64,
    size: u64,
    scratch: &mut Vec<u8>,
) -> Result<u64, ScrbError> {
    if target == 0 || target >= size {
        return Ok(target.min(size));
    }
    // read from target-1: if that byte is '\n', target is a line start
    // and the scan stops after one byte — otherwise it swallows the rest
    // of the straddled line
    reader.seek(SeekFrom::Start(target - 1)).map_err(|e| ScrbError::io(path, e))?;
    scratch.clear();
    let n = reader.read_until(b'\n', scratch).map_err(|e| ScrbError::io(path, e))?;
    Ok((target - 1 + n as u64).min(size))
}

/// Partition `files` (in order) into `k` contiguous runs by cumulative
/// byte size: a file starting at cumulative offset `c` of `total` bytes
/// goes to shard `c·k/total`. Monotone in `c`, so runs are contiguous;
/// shards a small dataset never reaches stay empty.
fn plan_file_runs(files: &[(String, u64)], k: usize) -> Vec<Vec<ShardPart>> {
    let total: u64 = files.iter().map(|(_, s)| s).sum();
    let mut shards = vec![Vec::new(); k];
    let mut cum = 0u64;
    for (path, size) in files {
        let s = if total == 0 { 0 } else { ((cum * k as u64) / total).min(k as u64 - 1) as usize };
        shards[s].push(ShardPart { path: path.clone(), start: 0, end: None });
        cum += size;
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::SparseChunk;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("scrb_planner_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn drain_labels(r: &mut dyn ChunkReader) -> Vec<i64> {
        let mut chunk = SparseChunk::new();
        let mut out = Vec::new();
        while r.next_chunk(&mut chunk).unwrap() {
            out.extend_from_slice(&chunk.labels);
        }
        out
    }

    #[test]
    fn wildcard_matcher_basics() {
        assert!(wildcard_match("*", "anything"));
        assert!(wildcard_match("part-?.svm", "part-3.svm"));
        assert!(!wildcard_match("part-?.svm", "part-33.svm"));
        assert!(wildcard_match("*.svm", "a.svm"));
        assert!(!wildcard_match("*.svm", "a.csv"));
        assert!(wildcard_match("a*b*c", "aXXbYYc"));
        assert!(!wildcard_match("a*b*c", "aXXbYY"));
        assert!(wildcard_match("", ""));
        assert!(!wildcard_match("", "x"));
    }

    #[test]
    fn single_file_ranges_partition_all_rows() {
        let dir = temp_dir("single");
        let path = dir.join("data.svm").to_str().unwrap().to_string();
        let mut text = String::new();
        for i in 0..37 {
            text.push_str(&format!("{} 1:{}.5\n", i % 5, i));
        }
        std::fs::write(&path, &text).unwrap();
        let mut whole = LibsvmChunks::from_path(&path, 4).unwrap();
        let all = drain_labels(&mut whole);
        for k in [1usize, 2, 3, 8, 64] {
            let plan = ShardPlanner::new(k, 4, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
            assert_eq!(plan.len(), k);
            let mut readers = ShardPlanner::open(&plan).unwrap();
            let mut got = Vec::new();
            for r in &mut readers {
                got.extend(drain_labels(r.as_mut()));
            }
            assert_eq!(got, all, "k={k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_file_and_glob_runs_cover_in_sorted_order() {
        let dir = temp_dir("multi");
        let mut all = Vec::new();
        for f in 0..3 {
            let mut text = String::new();
            for i in 0..10 {
                let label = f * 100 + i;
                text.push_str(&format!("{label} 1:0.5\n"));
                all.push(label as i64);
            }
            std::fs::write(dir.join(format!("part-{f}.svm")), text).unwrap();
        }
        let glob = format!("{}/part-?.svm", dir.to_str().unwrap());
        for k in [1usize, 2, 3, 8] {
            let plan = ShardPlanner::new(k, 4, ShardFormat::Libsvm).plan(&[glob.clone()]).unwrap();
            assert_eq!(plan.len(), k);
            // files are whole: no part may carry a byte range
            for parts in &plan.shards {
                for p in parts {
                    assert_eq!((p.start, p.end), (0, None));
                }
            }
            let mut readers = ShardPlanner::open(&plan).unwrap();
            let mut got = Vec::new();
            for r in &mut readers {
                got.extend(drain_labels(r.as_mut()));
            }
            assert_eq!(got, all, "k={k}");
        }
        // a glob matching nothing is a loud config error
        let bad = format!("{}/nope-*.svm", dir.to_str().unwrap());
        assert!(ShardPlanner::new(2, 4, ShardFormat::Libsvm).plan(&[bad]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn plans_are_deterministic() {
        let dir = temp_dir("det");
        let path = dir.join("d.svm").to_str().unwrap().to_string();
        std::fs::write(&path, "1 1:1.0\n2 1:2.0\n3 1:3.0\n4 1:4.0\n").unwrap();
        let p1 = ShardPlanner::new(3, 2, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
        let p2 = ShardPlanner::new(3, 2, ShardFormat::Libsvm).plan(&[path.clone()]).unwrap();
        assert_eq!(p1.shards, p2.shards);
        // every interior bound sits on a line start
        for parts in &p1.shards {
            let start = parts[0].start;
            if start > 0 {
                let bytes = std::fs::read(&path).unwrap();
                assert_eq!(bytes[start as usize - 1], b'\n');
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
