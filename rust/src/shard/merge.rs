//! Codebook / substrate / report merging: turn K shard-local fits into
//! the one fit the sequential stream would have produced.
//!
//! The byte-identity argument, grid by grid: a shard worker records its
//! grid's bin hashes in *shard-local* first-seen order. Replaying those
//! lists through a fresh [`BinTable::get_or_assign`] in shard order
//! visits every bin hash in exactly the order the sequential pass first
//! met it (shards are contiguous row ranges, and a hash's first shard-
//! local occurrence is its first global occurrence), so the merged
//! dictionary assigns the *same* dense ids the sequential fit assigns —
//! by induction over shards. Collision counts are integer sums, so they
//! are exact; κ is then recomputed from the merged counts with the
//! sequential estimator. Shard-local substrate blocks only need their
//! local ids rewritten through the per-shard remap tables — the blocks
//! concatenate in shard order as they are, because every downstream
//! kernel is block-partition invariant (locked by the partition tests in
//! `sparse::block`) and the serialized model never encodes the
//! partition.

use crate::error::ScrbError;
use crate::rb::codebook::BinTable;
use crate::rb::features::codebook_table;
use crate::rb::{sample_grids, RbCodebook};
use crate::sparse::{BlockEllRb, EllRb};
use crate::stream::{Quarantine, StreamFeatures};
use crate::util::threads::{num_threads, parallel_chunks_mut, parallel_map};

/// Everything a shard worker hands to the merger: the per-grid phase-1
/// state (first-seen bin hashes + collision counts, shard-local id
/// order), the local-id substrate blocks, and the label census — i.e.
/// [`crate::stream::StreamFeaturizer::into_state`] for one shard.
pub struct ShardState {
    /// Per grid: (bin hashes in shard-local first-seen order, collision
    /// count per local id). One entry per grid, length R.
    pub grids: Vec<(Vec<u64>, Vec<usize>)>,
    /// Local-id substrate blocks (flat `rows_b × R` each), shard row
    /// order.
    pub blocks: Vec<Vec<u32>>,
    /// Raw labels in shard row order.
    pub labels: Vec<i64>,
}

/// Per-grid merge result: the global first-seen dictionary plus each
/// shard's local→global id remap.
#[derive(Clone, Default)]
struct GridMerge {
    /// Bin hashes in global first-seen (= global id) order.
    hashes: Vec<u64>,
    /// Collision count per global id (exact integer sums).
    counts: Vec<usize>,
    /// `remaps[s][local_id] = global_id` for shard s.
    remaps: Vec<Vec<u32>>,
}

/// Merges K shard-local fits into one [`StreamFeatures`], bit-identical
/// to the sequential fit over the shard concatenation (see the module
/// docs for why). The field values must match the ones every shard
/// worker featurized with.
pub struct CodebookMerger {
    /// Number of grids R.
    pub r: usize,
    /// Input dimensionality d (the max over shard readers).
    pub d_in: usize,
    /// Kernel bandwidth σ.
    pub sigma: f64,
    /// Grid-sampling seed.
    pub seed: u64,
}

impl CodebookMerger {
    /// Union the shard codebooks (canonical first-seen order), relabel
    /// every shard block into global columns, and rebuild κ and the
    /// serving codebook. `states` must be in shard (= dataset) order;
    /// zero-row shards are legal no-ops.
    pub fn merge(&self, states: Vec<ShardState>) -> Result<StreamFeatures, ScrbError> {
        let r = self.r;
        for st in &states {
            assert_eq!(st.grids.len(), r, "every shard state must carry R grids");
        }
        let n_rows: usize = states.iter().map(|s| s.labels.len()).sum();
        if n_rows == 0 {
            return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
        }

        // grid-by-grid dictionary union — grids are independent, so this
        // fans out across the pool
        let merges: Vec<GridMerge> = parallel_map(r, |j| {
            let mut dict = BinTable::new();
            let mut gm = GridMerge {
                hashes: Vec::new(),
                counts: Vec::new(),
                remaps: Vec::with_capacity(states.len()),
            };
            for st in &states {
                let (hashes, counts) = &st.grids[j];
                let mut remap = Vec::with_capacity(hashes.len());
                for (&h, &c) in hashes.iter().zip(counts.iter()) {
                    let gid = dict.get_or_assign(h);
                    if gid as usize == gm.hashes.len() {
                        gm.hashes.push(h);
                        gm.counts.push(0);
                    }
                    gm.counts[gid as usize] += c;
                    remap.push(gid);
                }
                gm.remaps.push(remap);
            }
            gm
        });

        // global column offsets, cumulative over per-grid bin counts —
        // the same layout the sequential finish computes
        let mut offsets = Vec::with_capacity(r + 1);
        offsets.push(0usize);
        for gm in &merges {
            offsets.push(offsets.last().unwrap() + gm.hashes.len());
        }
        let d_total = *offsets.last().unwrap();
        if d_total >= u32::MAX as usize {
            return Err(ScrbError::invalid_input(format!(
                "feature dimension {d_total} overflows the u32 column index"
            )));
        }

        // κ with the sequential estimator, over the merged exact counts
        let kappa = merges
            .iter()
            .map(|gm| {
                let max_count = gm.counts.iter().copied().max().unwrap_or(0);
                if max_count > 0 {
                    n_rows as f64 / max_count as f64
                } else {
                    1.0
                }
            })
            .sum::<f64>()
            / r as f64;

        // relabel every shard block local→global in place and stack in
        // shard order; the cursor walks (row-major) R-strided slots, so
        // chunk starts land mid-row safely via `start % r`
        let val = 1.0 / (r as f64).sqrt();
        let mut ell_blocks = Vec::with_capacity(states.iter().map(|s| s.blocks.len()).sum());
        let mut labels = Vec::with_capacity(n_rows);
        for (s, st) in states.into_iter().enumerate() {
            let ShardState { blocks, labels: shard_labels, .. } = st;
            labels.extend(shard_labels);
            for mut block in blocks {
                parallel_chunks_mut(&mut block, num_threads(), |start, chunk| {
                    let mut j = start % r;
                    for slot in chunk.iter_mut() {
                        let gid = merges[j].remaps[s][*slot as usize] as usize;
                        *slot = (offsets[j] + gid) as u32;
                        j += 1;
                        if j == r {
                            j = 0;
                        }
                    }
                });
                let rows_b = block.len() / r;
                ell_blocks.push(EllRb::new(rows_b, d_total, r, block, vec![val; rows_b]));
            }
        }
        let z = BlockEllRb::from_blocks(ell_blocks);

        let bins_per_grid: Vec<usize> = merges.iter().map(|gm| gm.hashes.len()).collect();
        let tables: Vec<BinTable> =
            merges.iter().enumerate().map(|(j, gm)| codebook_table(&gm.hashes, offsets[j])).collect();
        let codebook = RbCodebook {
            r,
            d_in: self.d_in,
            sigma: self.sigma,
            seed: self.seed,
            dim: d_total,
            grids: sample_grids(r, self.d_in, self.sigma, self.seed),
            tables,
        };
        Ok(StreamFeatures { z, codebook, bins_per_grid, kappa, labels })
    }
}

/// Merge per-shard quarantine reports into one: counts and retry totals
/// are exact integer sums; located samples are ordered shard-index first,
/// then (line, byte) within the shard, and truncated to `sample_cap`
/// like a single reader's report would be.
pub fn merge_quarantines(reports: Vec<Quarantine>, sample_cap: usize) -> Quarantine {
    let mut out = Quarantine::default();
    for mut q in reports {
        out.malformed += q.malformed;
        out.non_finite += q.non_finite;
        out.retries += q.retries;
        // a single shard's report interleaves screen (non-finite) and
        // parse samples out of line order; sort_by is stable, so equal
        // lines keep their within-shard arrival order
        q.samples.sort_by(|a, b| (a.line, a.byte).cmp(&(b.line, b.byte)));
        out.samples.extend(q.samples);
    }
    out.samples.truncate(sample_cap);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::{RecordError, RecordKind};
    use crate::stream::{stats_pass, LibsvmChunks, SparseChunk, StreamFeaturizer};
    use crate::util::rng::Pcg;

    fn synth_libsvm(n: usize, d: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg::seed(seed);
        let mut text = String::new();
        for i in 0..n {
            text.push_str(&format!("{}", i % 3));
            for j in 0..d {
                if rng.f64() < 0.7 {
                    text.push_str(&format!(" {}:{:.6}", j + 1, rng.range_f64(-2.0, 2.0)));
                }
            }
            text.push('\n');
        }
        text.into_bytes()
    }

    fn featurize_rows(
        bytes: &[u8],
        r: usize,
        sigma: f64,
        seed: u64,
        d: usize,
        lo: &[f64],
        span: &[f64],
        block_rows: usize,
    ) -> ShardState {
        let mut reader = LibsvmChunks::from_bytes(bytes.to_vec(), 7);
        let mut chunk = SparseChunk::new();
        let mut fz = StreamFeaturizer::new(
            r,
            d,
            sigma,
            seed,
            lo.to_vec(),
            span.to_vec(),
            block_rows,
            0,
        );
        while reader.next_chunk(&mut chunk).unwrap() {
            fz.push_chunk(&chunk);
        }
        let (grids, blocks, labels) = fz.into_state();
        ShardState { grids, blocks, labels }
    }

    #[test]
    fn merged_shards_equal_sequential_featurization() {
        let (r, sigma, seed, d) = (16usize, 0.8f64, 7u64, 6usize);
        let bytes = synth_libsvm(101, d, 3);
        // shared frame from a stats pass over the whole stream
        let mut reader = LibsvmChunks::from_bytes(bytes.clone(), 7);
        let mut chunk = SparseChunk::new();
        let stats = stats_pass(&mut reader, &mut chunk).unwrap();
        let n = stats.n;
        let (lo, span) = stats.finalize(d);

        // sequential reference
        reader.reset().unwrap();
        let mut fz = StreamFeaturizer::new(r, d, sigma, seed, lo.clone(), span.clone(), 13, n);
        while reader.next_chunk(&mut chunk).unwrap() {
            fz.push_chunk(&chunk);
        }
        let want = fz.finish().unwrap();

        // shard at line boundaries (incl. an empty middle shard) with a
        // *different* block size, then merge
        let text = String::from_utf8(bytes.clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        for cuts in [vec![0usize, 40, 101], vec![0, 33, 33, 70, 101], vec![0, 101]] {
            let states: Vec<ShardState> = cuts
                .windows(2)
                .map(|w| {
                    let part = lines[w[0]..w[1]].join("\n");
                    let part = if part.is_empty() { part } else { part + "\n" };
                    featurize_rows(part.as_bytes(), r, sigma, seed, d, &lo, &span, 9)
                })
                .collect();
            let merger = CodebookMerger { r, d_in: d, sigma, seed };
            let got = merger.merge(states).unwrap();
            assert_eq!(got.labels, want.labels);
            assert_eq!(got.bins_per_grid, want.bins_per_grid);
            assert_eq!(got.kappa.to_bits(), want.kappa.to_bits());
            assert_eq!(got.codebook.dim, want.codebook.dim);
            // identical bin→column tables, grid by grid
            for j in 0..r {
                let mut a: Vec<(u64, u32)> = got.codebook.tables[j].iter().collect();
                let mut b: Vec<(u64, u32)> = want.codebook.tables[j].iter().collect();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "grid {j} table");
            }
            // identical substrate semantics: same gram row sums
            let dg = got.z.implicit_degrees();
            let dw = want.z.implicit_degrees();
            assert_eq!(dg.len(), dw.len());
            for (x, y) in dg.iter().zip(dw.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn merge_rejects_empty_dataset() {
        let merger = CodebookMerger { r: 4, d_in: 2, sigma: 1.0, seed: 1 };
        let empty = ShardState {
            grids: vec![(Vec::new(), Vec::new()); 4],
            blocks: Vec::new(),
            labels: Vec::new(),
        };
        assert!(merger.merge(vec![empty]).is_err());
    }

    #[test]
    fn quarantine_merge_orders_and_caps_samples() {
        let sample = |line: usize, byte: u64| RecordError {
            file: "f".to_string(),
            line,
            byte,
            token: "t".to_string(),
            reason: "r".to_string(),
            kind: RecordKind::Malformed,
        };
        let mut q0 = Quarantine::default();
        q0.malformed = 2;
        q0.retries = 1;
        // out of line order, as a screen/parse interleave produces
        q0.samples.push(sample(9, 90));
        q0.samples.push(sample(2, 20));
        let mut q1 = Quarantine::default();
        q1.non_finite = 1;
        q1.samples.push(sample(1, 10));
        let merged = merge_quarantines(vec![q0.clone(), q1.clone()], 16);
        assert_eq!((merged.malformed, merged.non_finite, merged.retries), (2, 1, 1));
        // shard order first, line order within a shard
        let lines: Vec<usize> = merged.samples.iter().map(|s| s.line).collect();
        assert_eq!(lines, vec![2, 9, 1]);
        // cap applies to the merged list
        let capped = merge_quarantines(vec![q0, q1], 2);
        assert_eq!(capped.samples.len(), 2);
        assert_eq!(capped.malformed, 2);
    }
}
