//! **SC_Nys** [13] — Nyström spectral clustering: sample R landmark points,
//! approximate W ≈ C·W₁₁⁻¹·Cᵀ with C = K(X, landmarks), W₁₁ = K(landmarks,
//! landmarks), and run the spectral pipeline on the implicit low-rank form
//! Ẑ = D^{−1/2}·C·W₁₁^{−1/2}.
//!
//! Serving: transductive here (the degree normalization couples every
//! point), so the fitted model is the input-space class-mean fallback
//! ([`crate::model::CentroidModel`]).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use crate::config::Kernel;
use crate::eigen::{svds, SvdsOpts};
use crate::error::ScrbError;
use crate::kernels::kernel_block;
use crate::linalg::{cholesky_jittered, whiten_rows, Mat};
use crate::model::{CentroidModel, FitResult};
use crate::runtime::ArtifactKind;
use crate::util::rng::Pcg;
use crate::util::timer::StageTimer;

/// Kernel block through the XLA artifact when available (shared with the
/// landmark methods).
pub(super) fn kernel_block_env(env: &Env, x: &Mat, y: &Mat) -> Mat {
    if let Some(rt) = env.xla {
        let force = env.cfg.engine == crate::config::Engine::Xla;
        if env.cfg.engine != crate::config::Engine::Native {
            let (kind, gamma) = match env.cfg.kernel {
                Kernel::Laplacian { sigma } => (ArtifactKind::KernelBlockLaplacian, 1.0 / sigma),
                Kernel::Gaussian { sigma } => {
                    (ArtifactKind::KernelBlockGaussian, 1.0 / (2.0 * sigma * sigma))
                }
            };
            if force || rt.kernel_block_worthwhile(kind, x.cols.max(y.cols)) {
                if let Some(w) = rt.kernel_block(kind, x, y, gamma) {
                    return w;
                }
            }
        }
    }
    kernel_block(env.cfg.kernel, x, y)
}

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let cfg = &env.cfg;
    let m = cfg.r.min(x.rows);
    let mut timer = StageTimer::new();

    // landmarks: uniform sample (standard Nyström)
    let mut rng = Pcg::new(cfg.seed, 0x4e79);
    let idx = rng.sample_indices(x.rows, m);
    let landmarks = x.select_rows(&idx);

    // C = K(X, L) (N×m), W11 = K(L, L) (m×m)
    let c = timer.time("kernel_blocks", || kernel_block_env(env, x, &landmarks));
    let w11 = timer.time("kernel_blocks", || kernel_block_env(env, &landmarks, &landmarks));

    // Ẑ = D^{-1/2} C W11^{-1/2}, degrees d = C·(W11⁻¹·(Cᵀ1)) ≈ Ŵ·1
    let zny = timer.time("degrees", || {
        // Cholesky whitening ≡ W₁₁^{−1/2} up to a right rotation, which
        // changes neither Ŵ = z·zᵀ nor the left singular subspace.
        let l = cholesky_jittered(&w11);
        let mut z = whiten_rows(&c, &l); // N×m, Ŵ = z zᵀ
        let ones = vec![1.0; z.rows];
        let col = z.t_matvec(&ones);
        let deg = z.matvec(&col);
        let floor = 1e-8 * deg.iter().map(|d| d.abs()).fold(0.0, f64::max).max(1e-12);
        for i in 0..z.rows {
            let s = 1.0 / deg[i].max(floor).sqrt();
            for v in z.row_mut(i) {
                *v *= s;
            }
        }
        z
    });

    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let svd = timer.time("svd", || svds(&zny, &opts, cfg.seed ^ 0x4ce5));

    let (labels, km) = embed_and_cluster(svd.u, env, &mut timer, true);
    let model = CentroidModel::from_labels(x, &labels, cfg.k);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim: m,
            svd: Some(svd.stats),
            kappa: None,
            inertia: km.inertia,
        },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 29);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(64)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_Nys on blobs: {acc}");
    }

    #[test]
    fn solves_two_moons_with_enough_landmarks() {
        let ds = synth::two_moons(500, 0.05, 31);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(200)
            .kernel(Kernel::Gaussian { sigma: 0.12 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SC_Nys on moons: {acc}");
    }
}
