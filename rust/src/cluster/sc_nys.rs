//! **SC_Nys** [13] — Nyström spectral clustering: sample R landmark points,
//! approximate W ≈ C·W₁₁⁻¹·Cᵀ with C = K(X, landmarks), W₁₁ = K(landmarks,
//! landmarks), and run the spectral pipeline on the implicit low-rank form
//! Ẑ = D^{−1/2}·C·W₁₁^{−1/2}.
//!
//! As a stage composition: [`NysFeaturize`] (landmark sampling + kernel
//! blocks + Cholesky whitening; shared with KK_RS, which differs only in
//! its sampling salt) → the clamped-degree [`crate::pipeline::SvdEmbed`]
//! → the shared K-means stage.
//!
//! Serving: transductive here (the degree normalization couples every
//! point), so the fitted model is the input-space class-mean fallback
//! ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::config::{Engine, Kernel};
use crate::error::ScrbError;
use crate::kernels::kernel_block;
use crate::linalg::{cholesky_jittered, whiten_rows, Mat};
use crate::model::FitResult;
use crate::pipeline::{DataSource, FeatureArtifact, FeatureMatrix, Featurize, Fingerprint};
use crate::runtime::ArtifactKind;
use crate::util::rng::Pcg;
use crate::util::timer::StageTimer;

/// Kernel block through the XLA artifact when available (shared with the
/// landmark methods).
pub fn kernel_block_env(env: &Env, x: &Mat, y: &Mat) -> Mat {
    if let Some(rt) = env.xla {
        let force = env.cfg.engine == crate::config::Engine::Xla;
        if env.cfg.engine != crate::config::Engine::Native {
            let (kind, gamma) = match env.cfg.kernel {
                Kernel::Laplacian { sigma } => (ArtifactKind::KernelBlockLaplacian, 1.0 / sigma),
                Kernel::Gaussian { sigma } => {
                    (ArtifactKind::KernelBlockGaussian, 1.0 / (2.0 * sigma * sigma))
                }
            };
            if force || rt.kernel_block_worthwhile(kind, x.cols.max(y.cols)) {
                if let Some(w) = rt.kernel_block(kind, x, y, gamma) {
                    return w;
                }
            }
        }
    }
    kernel_block(env.cfg.kernel, x, y)
}

/// Nyström featurization stage: uniform landmark sample, kernel blocks
/// C = K(X, L) and W₁₁ = K(L, L), then Cholesky whitening — rows of
/// C·L^{−T} span the same similarity as C·W₁₁^{−1/2} (the right rotation
/// changes neither Ŵ = z·zᵀ nor the left singular subspace). Shared by
/// SC_Nys (salt `0x4e79`, whitening accounted under "degrees") and KK_RS
/// (salt `0x4b72`, whitening accounted under "embed").
pub struct NysFeaturize {
    /// Kernel (kind + bandwidth) for both blocks.
    pub kernel: Kernel,
    /// Number of landmarks R (capped to N at run time).
    pub r: usize,
    /// Method seed.
    pub seed: u64,
    /// Landmark-sampling salt (SC_Nys and KK_RS draw different samples).
    pub salt: u64,
    /// Timer stage the whitening is accounted under (legacy stage names
    /// differ between the two consumers).
    pub whiten_stage: &'static str,
    /// Engine selector (part of the fingerprint: the XLA kernel-block
    /// artifact computes in f32).
    pub engine: Engine,
}

impl Featurize for NysFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/nystrom")
            .u64(input_fp)
            .str(self.kernel.name())
            .f64(self.kernel.sigma())
            .usize(self.r)
            .u64(self.seed)
            .u64(self.salt)
            .str(self.engine.name())
            .finish()
    }

    fn run(&self, env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        let x = data.matrix("Nyström featurization")?;
        let m = self.r.min(x.rows);
        let mut timer = StageTimer::new();

        // landmarks: uniform sample (standard Nyström)
        let mut rng = Pcg::new(self.seed, self.salt);
        let idx = rng.sample_indices(x.rows, m);
        let landmarks = x.select_rows(&idx);

        // C = K(X, L) (N×m), W11 = K(L, L) (m×m)
        let c = timer.time("kernel_blocks", || kernel_block_env(env, x, &landmarks));
        let w11 = timer.time("kernel_blocks", || kernel_block_env(env, &landmarks, &landmarks));

        let z = timer.time(self.whiten_stage, || {
            let l = cholesky_jittered(&w11);
            whiten_rows(&c, &l)
        });
        Ok(FeatureArtifact {
            fingerprint: fp,
            z: FeatureMatrix::Dense(std::sync::Arc::new(z)),
            codebook: None,
            kappa: None,
            feature_dim: m,
            norm: None,
            stream_labels: None,
            stream_quarantine: None,
            timer,
        })
    }
}

/// Fit SC_Nys through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::ScNys.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 29);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(64)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_Nys on blobs: {acc}");
    }

    #[test]
    fn solves_two_moons_with_enough_landmarks() {
        let ds = synth::two_moons(500, 0.05, 31);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(200)
            .kernel(Kernel::Gaussian { sigma: 0.12 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SC_Nys on moons: {acc}");
    }

    #[test]
    fn sampling_salt_separates_scnys_from_kkrs() {
        let base = NysFeaturize {
            kernel: Kernel::Gaussian { sigma: 0.5 },
            r: 32,
            seed: 42,
            salt: 0x4e79,
            whiten_stage: "degrees",
            engine: Engine::Native,
        };
        let fp_nys = base.fingerprint(3);
        let kkrs = NysFeaturize { salt: 0x4b72, whiten_stage: "embed", ..base };
        assert_ne!(fp_nys, kkrs.fingerprint(3));
    }
}
