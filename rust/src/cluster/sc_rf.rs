//! **SC_RF** — the paper's modification of SV_RF into a true SC method:
//! approximate the *normalized Laplacian* with RF features (degree
//! normalization + top-K left singular vectors of Ẑ), then K-means.
//! The direct convergence-rate competitor to SC_RB in Fig. 2.
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]). (Unlike RB, the RF degree
//! normalization does not cancel under row normalization per point, so an
//! exact projection-based extension is not available here.)

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use crate::eigen::{svds, SvdsOpts};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{CentroidModel, FitResult};
use crate::rf::RfMap;
use crate::util::timer::StageTimer;

/// Build the dense RF feature matrix for `x` (XLA artifact when available,
/// native otherwise). Shared by SC_RF / SV_RF / KK_RF.
pub(super) fn rf_matrix(env: &Env, x: &Mat) -> Mat {
    let cfg = &env.cfg;
    let map = RfMap::sample(cfg.kernel, x.cols, cfg.r, cfg.seed ^ 0x8f8f);
    if let Some(rt) = env.xla {
        let force = cfg.engine == crate::config::Engine::Xla;
        if cfg.engine != crate::config::Engine::Native
            && (force || rt.rf_worthwhile(x.rows, x.cols, cfg.r))
        {
            if let Some(mut z) = rt.rf_features(x, &map.w, &map.b) {
                // artifact computes cos(xW+b); apply the √(2/R) scale here
                z.scale((2.0 / cfg.r as f64).sqrt());
                return z;
            }
        }
    }
    map.features(x)
}

/// Degree-normalize a dense feature matrix: Ẑ = D^{−1/2}Z with
/// d = Z(Zᵀ1) clamped away from zero (RF features are signed, so the
/// approximate degrees can be slightly negative on small R).
pub(super) fn normalize_dense_by_degree(z: &mut Mat) {
    let ones = vec![1.0; z.rows];
    let col_sums = z.t_matvec(&ones);
    let deg = z.matvec(&col_sums);
    let floor = 1e-8 * deg.iter().map(|d| d.abs()).fold(0.0, f64::max).max(1e-12);
    for i in 0..z.rows {
        let d = deg[i].max(floor);
        let s = 1.0 / d.sqrt();
        for v in z.row_mut(i) {
            *v *= s;
        }
    }
}

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let cfg = &env.cfg;
    let mut timer = StageTimer::new();
    let mut z = timer.time("rf_features", || rf_matrix(env, x));
    let feature_dim = z.cols;
    timer.time("degrees", || normalize_dense_by_degree(&mut z));

    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let svd = timer.time("svd", || svds(&z, &opts, cfg.seed ^ 0x5cf5));

    let (labels, km) = embed_and_cluster(svd.u, env, &mut timer, true);
    let model = CentroidModel::from_labels(x, &labels, cfg.k);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(svd.stats),
            kappa: None,
            inertia: km.inertia,
        },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 17);
        // R large enough that RF noise (~1/√R) sits well under the
        // within-cluster kernel value — the regime Fig. 2 converges in.
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 1.2 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SC_RF on blobs: {acc}");
    }

    #[test]
    fn normalize_handles_signed_features() {
        let mut z = Mat::from_vec(3, 2, vec![0.5, -0.5, 0.4, 0.3, -0.2, 0.6]);
        normalize_dense_by_degree(&mut z);
        assert!(z.data.iter().all(|v| v.is_finite()));
    }
}
