//! **SC_RF** — the paper's modification of SV_RF into a true SC method:
//! approximate the *normalized Laplacian* with RF features (degree
//! normalization + top-K left singular vectors of Ẑ), then K-means.
//! The direct convergence-rate competitor to SC_RB in Fig. 2.
//!
//! As a stage composition: [`RfFeaturize`] (shared verbatim with SV_RF
//! and KK_RF, so a method sweep reuses one RF feature artifact across all
//! three) → the clamped-degree [`crate::pipeline::SvdEmbed`] → the shared
//! K-means stage. See [`crate::cluster::MethodKind::pipeline`].
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]). (Unlike RB, the RF degree
//! normalization does not cancel under row normalization per point, so an
//! exact projection-based extension is not available here.)

use super::method::Env;
use crate::config::{Engine, Kernel};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::FitResult;
use crate::pipeline::{DataSource, FeatureArtifact, FeatureMatrix, Featurize, Fingerprint};
use crate::rf::RfMap;
use crate::util::timer::StageTimer;

/// Build the dense RF feature matrix for `x` (XLA artifact when available,
/// native otherwise). Shared by SC_RF / SV_RF / KK_RF.
pub fn rf_matrix(env: &Env, x: &Mat) -> Mat {
    let cfg = &env.cfg;
    let map = RfMap::sample(cfg.kernel, x.cols, cfg.r, cfg.seed ^ 0x8f8f);
    if let Some(rt) = env.xla {
        let force = cfg.engine == crate::config::Engine::Xla;
        if cfg.engine != crate::config::Engine::Native
            && (force || rt.rf_worthwhile(x.rows, x.cols, cfg.r))
        {
            if let Some(mut z) = rt.rf_features(x, &map.w, &map.b) {
                // artifact computes cos(xW+b); apply the √(2/R) scale here
                z.scale((2.0 / cfg.r as f64).sqrt());
                return z;
            }
        }
    }
    map.features(x)
}

/// Random-Fourier featurization stage: the dense N×R feature matrix
/// `√(2/R)·cos(xW + b)` with ω drawn for the configured kernel.
pub struct RfFeaturize {
    /// Kernel the frequencies are drawn for (kind + bandwidth).
    pub kernel: Kernel,
    /// Number of random features R.
    pub r: usize,
    /// Method seed (the map salts it internally).
    pub seed: u64,
    /// Engine selector (part of the fingerprint: the XLA artifact path
    /// computes in f32 and is not bit-identical to the native map).
    pub engine: Engine,
}

impl Featurize for RfFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/rf")
            .u64(input_fp)
            .str(self.kernel.name())
            .f64(self.kernel.sigma())
            .usize(self.r)
            .u64(self.seed)
            .str(self.engine.name())
            .finish()
    }

    fn run(&self, env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        let x = data.matrix("RF featurization")?;
        let mut timer = StageTimer::new();
        let z = timer.time("rf_features", || rf_matrix(env, x));
        let feature_dim = z.cols;
        Ok(FeatureArtifact {
            fingerprint: fp,
            z: FeatureMatrix::Dense(std::sync::Arc::new(z)),
            codebook: None,
            kappa: None,
            feature_dim,
            norm: None,
            stream_labels: None,
            stream_quarantine: None,
            timer,
        })
    }
}

/// Fit SC_RF through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::ScRf.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 17);
        // R large enough that RF noise (~1/√R) sits well under the
        // within-cluster kernel value — the regime Fig. 2 converges in.
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 1.2 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SC_RF on blobs: {acc}");
    }

    #[test]
    fn rf_features_are_shared_across_the_rf_family() {
        // one featurize fingerprint for SC_RF / SV_RF / KK_RF at equal
        // config — the cache-reuse contract for method sweeps
        let cfg = PipelineConfig::builder().k(2).r(64).build();
        let stage = RfFeaturize {
            kernel: cfg.kernel,
            r: cfg.r,
            seed: cfg.seed,
            engine: cfg.engine,
        };
        let a = stage.fingerprint(11);
        let b = stage.fingerprint(11);
        assert_eq!(a, b);
        let other = RfFeaturize { r: 128, ..RfFeaturize {
            kernel: cfg.kernel,
            r: cfg.r,
            seed: cfg.seed,
            engine: cfg.engine,
        } };
        assert_ne!(other.fingerprint(11), a);
    }
}
