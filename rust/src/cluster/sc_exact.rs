//! **Exact spectral clustering** [21] — the quadratic reference the paper
//! dashes out ("−") for N ≥ ~98k. As a stage composition:
//! [`ExactFeaturize`] builds the full N×N normalized similarity
//! S = D^{−1/2} W D^{−1/2} (optionally through the XLA kernel-block
//! artifact), then the symmetric [`crate::pipeline::SvdEmbed`] extracts
//! the top-K eigenvectors with the iterative solver applied to [`SymOp`].
//!
//! Serving: exact SC is transductive (the embedding exists only for the
//! points the eigenproblem was solved over), so the fitted model is the
//! input-space class-mean fallback ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::config::{Engine, Kernel};
use crate::eigen::SvdOp;
use crate::error::ScrbError;
use crate::kernels::kernel_matrix;
use crate::linalg::Mat;
use crate::model::FitResult;
use crate::pipeline::{DataSource, FeatureArtifact, FeatureMatrix, Featurize, Fingerprint};
use crate::runtime::ArtifactKind;
use crate::util::timer::StageTimer;

/// Hard cap: above this the dense W would not fit time/memory budgets
/// (mirrors the paper reporting "−" for the larger datasets).
pub const MAX_EXACT_N: usize = 30_000;

/// Symmetric PSD operator wrapper: `apply == apply_t == S·B`. Running the
/// Gram-based solvers on it finds eigenpairs of S² — same eigenvectors,
/// singular values equal to |λ(S)|.
pub struct SymOp<'m>(pub &'m Mat);

impl<'m> SvdOp for SymOp<'m> {
    fn nrows(&self) -> usize {
        self.0.rows
    }
    fn ncols(&self) -> usize {
        self.0.rows
    }
    fn apply(&self, b: &Mat) -> Mat {
        self.0.matmul(b)
    }
    fn apply_t(&self, b: &Mat) -> Mat {
        self.0.matmul(b)
    }
    fn gram_diag(&self) -> Option<Vec<f64>> {
        // diag(S²) = row squared norms of S
        Some((0..self.0.rows).map(|i| crate::linalg::dot(self.0.row(i), self.0.row(i))).collect())
    }
}

/// Exact-SC featurization stage: the full similarity matrix W (XLA
/// kernel-block path when available) normalized to
/// S = D^{−1/2} W D^{−1/2}. Refuses N above [`MAX_EXACT_N`] with a typed
/// error.
pub struct ExactFeaturize {
    /// Similarity kernel (kind + bandwidth).
    pub kernel: Kernel,
    /// Engine selector (part of the fingerprint: the XLA kernel-block
    /// artifact computes in f32).
    pub engine: Engine,
}

impl Featurize for ExactFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/exact")
            .u64(input_fp)
            .str(self.kernel.name())
            .f64(self.kernel.sigma())
            .str(self.engine.name())
            .finish()
    }

    fn run(&self, env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        let x = data.matrix("exact spectral clustering")?;
        if x.rows > MAX_EXACT_N {
            return Err(ScrbError::invalid_input(format!(
                "exact SC is O(N²); refusing N={} > {MAX_EXACT_N} (the paper reports '-' here too)",
                x.rows
            )));
        }
        let mut timer = StageTimer::new();

        // Full similarity matrix W (XLA kernel-block path when available).
        let w = timer.time("kernel_matrix", || build_w(env, x));

        // Normalized similarity S = D^{-1/2} W D^{-1/2}.
        let s = timer.time("degrees", || {
            let n = w.rows;
            let mut scale = vec![0.0; n];
            for i in 0..n {
                let d: f64 = w.row(i).iter().sum();
                scale[i] = if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 };
            }
            let mut s = w;
            for i in 0..n {
                let si = scale[i];
                for j in 0..n {
                    s.set(i, j, si * s.at(i, j) * scale[j]);
                }
            }
            s
        });

        Ok(FeatureArtifact {
            fingerprint: fp,
            feature_dim: x.rows,
            z: FeatureMatrix::Dense(std::sync::Arc::new(s)),
            codebook: None,
            kappa: None,
            norm: None,
            stream_labels: None,
            stream_quarantine: None,
            timer,
        })
    }

    /// The N×N similarity is the largest artifact any stage can produce
    /// and is never shared with another method — retaining it in a sweep
    /// cache would pin O(N²) memory for no reuse.
    fn cacheable(&self) -> bool {
        false
    }
}

fn build_w(env: &Env, x: &Mat) -> Mat {
    if let Some(rt) = env.xla {
        let force = env.cfg.engine == crate::config::Engine::Xla;
        if env.cfg.engine != crate::config::Engine::Native {
            let (kind, gamma) = match env.cfg.kernel {
                Kernel::Laplacian { sigma } => (ArtifactKind::KernelBlockLaplacian, 1.0 / sigma),
                Kernel::Gaussian { sigma } => {
                    (ArtifactKind::KernelBlockGaussian, 1.0 / (2.0 * sigma * sigma))
                }
            };
            if force || rt.kernel_block_worthwhile(kind, x.cols) {
                if let Some(w) = rt.kernel_block(kind, x, x, gamma) {
                    return w;
                }
            }
        }
    }
    kernel_matrix(env.cfg.kernel, x)
}

/// Fit exact SC through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::ScExact.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn solves_two_moons() {
        let ds = synth::two_moons(400, 0.05, 11);
        let cfg = PipelineConfig::builder()
            .k(2)
            .kernel(Kernel::Gaussian { sigma: 0.12 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.95, "exact SC on two moons: {acc}");
    }

    #[test]
    fn agrees_with_rb_on_blobs() {
        let ds = synth::gaussian_blobs(250, 3, 3, 9.0, 13);
        let cfg = PipelineConfig::builder()
            .k(3)
            .kernel(Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(5)
            .build();
        let exact = fit(&Env::new(cfg.clone()), &ds.x).unwrap().output;
        let rb_cfg = cfg.rebuild(|b| b.r(512)).unwrap();
        let rb = super::super::sc_rb::fit(&Env::new(rb_cfg), &ds.x).unwrap().output;
        let a_exact = accuracy(&exact.labels, &ds.y);
        let a_rb = accuracy(&rb.labels, &ds.y);
        assert!(a_exact > 0.95 && a_rb > 0.95, "exact {a_exact} rb {a_rb}");
    }

    #[test]
    fn refuses_large_n_with_typed_error() {
        let x = Mat::zeros(MAX_EXACT_N + 1, 2);
        let cfg = PipelineConfig::default();
        let err = fit(&Env::new(cfg), &x).unwrap_err();
        assert!(matches!(err, ScrbError::InvalidInput(_)));
        assert!(err.to_string().contains("refusing"), "{err}");
    }
}
