//! **SC_LSC** [9] — Landmark-based Spectral Clustering: a sparse bipartite
//! graph between data points and R landmarks (each point keeps its `s`
//! nearest landmarks with kernel weights, rows normalized to sum 1), then
//! the spectral embedding from the SVD of Â = A·Λ^{−1/2}.
//!
//! As a stage composition: [`LscFeaturize`] (K-means landmarks + sparse
//! affinity + the Λ^{−1/2} column scaling) → a plain
//! [`crate::pipeline::SvdEmbed`] (no further degree work) → the shared
//! K-means stage.
//!
//! Note (paper §5.1): this is a KNN-style graph, *not* the fully connected
//! graph the other SC methods use — which is exactly why its behaviour
//! diverges (better on manifold-ish digits, worse elsewhere).
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::config::Kernel;
use crate::error::ScrbError;
use crate::kmeans::{kmeans, KmeansOpts, NativeAssign};
use crate::linalg::Mat;
use crate::model::FitResult;
use crate::pipeline::{DataSource, FeatureArtifact, FeatureMatrix, Featurize, Fingerprint};
use crate::sparse::Csr;
use crate::util::rng::Pcg;
use crate::util::timer::StageTimer;

/// Nearest landmarks kept per point (Chen & Cai use ~5).
pub const S_NEAREST: usize = 5;

/// LSC featurization stage: landmarks via a light K-means on a subsample
/// (the LSC-K variant), the s-nearest kernel-weighted row-stochastic
/// affinity A, and the landmark-side normalization Â = A·Λ^{−1/2} with
/// Λ = diag(Aᵀ1) — everything up to the SVD.
pub struct LscFeaturize {
    /// Kernel (kind + bandwidth) weighting the bipartite edges.
    pub kernel: Kernel,
    /// Number of landmarks R (capped to N at run time).
    pub r: usize,
    /// Method seed.
    pub seed: u64,
}

impl Featurize for LscFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/lsc")
            .u64(input_fp)
            .str(self.kernel.name())
            .f64(self.kernel.sigma())
            .usize(self.r)
            .u64(self.seed)
            .usize(S_NEAREST)
            .finish()
    }

    fn run(&self, _env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        let x = data.matrix("LSC featurization")?;
        let p = self.r.min(x.rows); // number of landmarks
        let s = S_NEAREST.min(p);
        let mut timer = StageTimer::new();

        // Landmarks via a light K-means on a subsample (the LSC-K variant —
        // better landmarks than uniform sampling, as in the original paper).
        let landmarks = timer.time("landmarks", || {
            let mut rng = Pcg::new(self.seed, 0x15c0);
            let sub = (10 * p).min(x.rows);
            let idx = rng.sample_indices(x.rows, sub);
            let xs = x.select_rows(&idx);
            let opts = KmeansOpts { k: p, replicates: 1, max_iters: 10, ..KmeansOpts::new(p) };
            kmeans(&xs, &opts, &NativeAssign).centroids
        });

        // Sparse affinity A: s nearest landmarks per point, kernel-weighted,
        // row-normalized (row-stochastic).
        let a = timer.time("affinity", || {
            let n = x.rows;
            let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
            let kernel = self.kernel;
            for i in 0..n {
                let xi = x.row(i);
                // top-s by kernel value (equivalently nearest by distance)
                let mut vals: Vec<(u32, f64)> = (0..p)
                    .map(|l| (l as u32, kernel.eval(xi, landmarks.row(l))))
                    .collect();
                vals.sort_by(|u, v| v.1.partial_cmp(&u.1).unwrap());
                vals.truncate(s);
                let sum: f64 = vals.iter().map(|(_, w)| w).sum();
                if sum > 1e-300 {
                    for e in vals.iter_mut() {
                        e.1 /= sum;
                    }
                }
                rows.push(vals);
            }
            Csr::from_rows(n, p, rows)
        });

        // Â = A·Λ^{-1/2} with Λ = diag(Aᵀ1): the landmark-side degree
        // normalization that makes ÂÂᵀ the bipartite similarity.
        let ahat = timer.time("degrees", || {
            let lam = a.col_sums();
            let mut ahat = a;
            let scale: Vec<f64> =
                lam.iter().map(|&l| if l > 1e-300 { 1.0 / l.sqrt() } else { 0.0 }).collect();
            // column scaling: multiply every entry by scale[col]
            for p_ in 0..ahat.data.len() {
                ahat.data[p_] *= scale[ahat.indices[p_] as usize];
            }
            ahat
        });

        Ok(FeatureArtifact {
            fingerprint: fp,
            z: FeatureMatrix::Sparse(ahat),
            codebook: None,
            kappa: None,
            feature_dim: p,
            norm: None,
            stream_labels: None,
            stream_quarantine: None,
            timer,
        })
    }
}

/// Fit SC_LSC through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::ScLsc.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 41);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(50)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_LSC on blobs: {acc}");
    }

    #[test]
    fn affinity_rows_are_sparse() {
        let ds = synth::gaussian_blobs(150, 3, 2, 6.0, 43);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(30)
            .kernel(Kernel::Gaussian { sigma: 0.5 })
            .kmeans_replicates(2)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        assert_eq!(out.info.feature_dim, 30);
        assert_eq!(out.labels.len(), 150);
    }
}
