//! Clustering methods: the paper's SC_RB (Algorithm 2) and the eight
//! baselines of the Table 2/3 comparison grid, all expressed as
//! compositions of [`crate::pipeline`] stages behind one [`MethodKind`]
//! dispatch ([`MethodKind::pipeline`] is the composition table).
//!
//! Every method is a [`crate::model::ClusterModel`]: `fit` produces the
//! training-set [`ClusterOutput`] plus a serving
//! [`crate::model::FittedModel`] (SC_RB's spectral out-of-sample
//! projection, the K-means centroids, or the class-mean fallback for the
//! transductive baselines). [`MethodKind::run`] keeps the old batch shape
//! as a thin wrapper over `fit`. Method-specific featurize/embed stages
//! live in their method's module (e.g. [`sc_rb::RbFeaturize`],
//! [`sc_rb::RbEmbed`], [`sc_rf::RfFeaturize`]).

pub mod kk_rf;
pub mod kk_rs;
pub mod kmeans_base;
pub mod method;
pub mod sc_exact;
pub mod sc_lsc;
pub mod sc_nys;
pub mod sc_rb;
pub mod sc_rf;
pub mod sv_rf;

pub use method::{ClusterOutput, Env, MethodInfo, MethodKind};
pub use sc_rb::ScRb;

/// Re-export used by doc examples.
pub use method::MethodKind as Method;
