//! Clustering methods: the paper's SC_RB (Algorithm 2) and the eight
//! baselines of the Table 2/3 comparison grid, all behind one
//! [`MethodKind`] dispatch.

pub mod kk_rf;
pub mod kk_rs;
pub mod kmeans_base;
pub mod method;
pub mod sc_exact;
pub mod sc_lsc;
pub mod sc_nys;
pub mod sc_rb;
pub mod sc_rf;
pub mod sv_rf;

pub use method::{embed_and_cluster, ClusterOutput, Env, MethodInfo, MethodKind};
pub use sc_rb::ScRb;

/// Re-export used by doc examples.
pub use method::MethodKind as Method;
