//! **SV_RF** [11] — fast kernel K-means on the top singular vectors of the
//! RF feature matrix Z (approximating the similarity matrix W = ZZᵀ, *not*
//! the normalized Laplacian — the distinction §5.2 highlights).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use super::sc_rf::rf_matrix;
use crate::eigen::{svds, SvdsOpts};
use crate::linalg::Mat;
use crate::util::timer::StageTimer;

pub fn run(env: &Env, x: &Mat) -> ClusterOutput {
    let cfg = &env.cfg;
    let mut timer = StageTimer::new();
    let z = timer.time("rf_features", || rf_matrix(env, x));
    let feature_dim = z.cols;

    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let svd = timer.time("svd", || svds(&z, &opts, cfg.seed ^ 0x57f5));

    // kernel-kmeans view: cluster the PCA scores U·Σ (no row normalization,
    // no degree scaling — this approximates W, not L).
    let mut scores = svd.u;
    for j in 0..svd.s.len() {
        for i in 0..scores.rows {
            scores.set(i, j, scores.at(i, j) * svd.s[j]);
        }
    }
    let (labels, km) = embed_and_cluster(scores, env, &mut timer, false);
    ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(svd.stats),
            kappa: None,
            inertia: km.inertia,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 19);
        let mut cfg = PipelineConfig::default();
        cfg.k = 3;
        cfg.r = 512;
        cfg.kernel = Kernel::Gaussian { sigma: 1.2 };
        cfg.kmeans_replicates = 5;
        let out = run(&Env::new(cfg), &ds.x);
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SV_RF on blobs: {acc}");
    }
}
