//! **SV_RF** [11] — fast kernel K-means on the top singular vectors of the
//! RF feature matrix Z (approximating the similarity matrix W = ZZᵀ, *not*
//! the normalized Laplacian — the distinction §5.2 highlights).
//!
//! As a stage composition: the shared
//! [`RfFeaturize`](crate::cluster::sc_rf::RfFeaturize) → an
//! [`crate::pipeline::SvdEmbed`] with **no** degree normalization and
//! Σ-scaled scores (the kernel-K-means PCA view: cluster U·Σ, no row
//! normalization) → the shared K-means stage. See
//! [`crate::cluster::MethodKind::pipeline`].
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::FitResult;

/// Fit SV_RF through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::SvRf.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 19);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 1.2 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SV_RF on blobs: {acc}");
    }
}
