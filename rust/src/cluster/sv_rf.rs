//! **SV_RF** [11] — fast kernel K-means on the top singular vectors of the
//! RF feature matrix Z (approximating the similarity matrix W = ZZᵀ, *not*
//! the normalized Laplacian — the distinction §5.2 highlights).
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use super::sc_rf::rf_matrix;
use crate::eigen::{svds, SvdsOpts};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{CentroidModel, FitResult};
use crate::util::timer::StageTimer;

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let cfg = &env.cfg;
    let mut timer = StageTimer::new();
    let z = timer.time("rf_features", || rf_matrix(env, x));
    let feature_dim = z.cols;

    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let svd = timer.time("svd", || svds(&z, &opts, cfg.seed ^ 0x57f5));

    // kernel-kmeans view: cluster the PCA scores U·Σ (no row normalization,
    // no degree scaling — this approximates W, not L).
    let mut scores = svd.u;
    for j in 0..svd.s.len() {
        for i in 0..scores.rows {
            scores.set(i, j, scores.at(i, j) * svd.s[j]);
        }
    }
    let (labels, km) = embed_and_cluster(scores, env, &mut timer, false);
    let model = CentroidModel::from_labels(x, &labels, cfg.k);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(svd.stats),
            kappa: None,
            inertia: km.inertia,
        },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(300, 4, 3, 9.0, 19);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(512)
            .kernel(Kernel::Gaussian { sigma: 1.2 })
            .kmeans_replicates(5)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "SV_RF on blobs: {acc}");
    }
}
