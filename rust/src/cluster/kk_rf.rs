//! **KK_RF** [11] — approximate kernel K-means run *directly* on the dense
//! N×R RF feature matrix. No SVD; the K-means itself costs O(NRKt), which
//! is why the paper finds this method blows up at large R (Fig. 5).
//!
//! As a stage composition: the shared
//! [`RfFeaturize`](crate::cluster::sc_rf::RfFeaturize) (one artifact
//! serves SC_RF / SV_RF / KK_RF in a method sweep) → pass-through embed →
//! the shared K-means stage. See
//! [`crate::cluster::MethodKind::pipeline`].
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::FitResult;

/// Fit KK_RF through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::KkRf.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 23);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(128)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(3)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "KK_RF on blobs: {acc}");
    }
}
