//! **KK_RF** [11] — approximate kernel K-means run *directly* on the dense
//! N×R RF feature matrix. No SVD; the K-means itself costs O(NRKt), which
//! is why the paper finds this method blows up at large R (Fig. 5).
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use super::sc_rf::rf_matrix;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{CentroidModel, FitResult};
use crate::util::timer::StageTimer;

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let mut timer = StageTimer::new();
    let z = timer.time("rf_features", || rf_matrix(env, x));
    let feature_dim = z.cols;
    let (labels, km) = embed_and_cluster(z, env, &mut timer, false);
    let model = CentroidModel::from_labels(x, &labels, env.cfg.k);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo { feature_dim, svd: None, kappa: None, inertia: km.inertia },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 23);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(128)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(3)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "KK_RF on blobs: {acc}");
    }
}
