//! **KK_RF** [11] — approximate kernel K-means run *directly* on the dense
//! N×R RF feature matrix. No SVD; the K-means itself costs O(NRKt), which
//! is why the paper finds this method blows up at large R (Fig. 5).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use super::sc_rf::rf_matrix;
use crate::linalg::Mat;
use crate::util::timer::StageTimer;

pub fn run(env: &Env, x: &Mat) -> ClusterOutput {
    let mut timer = StageTimer::new();
    let z = timer.time("rf_features", || rf_matrix(env, x));
    let feature_dim = z.cols;
    let (labels, km) = embed_and_cluster(z, env, &mut timer, false);
    ClusterOutput {
        labels,
        timer,
        info: MethodInfo { feature_dim, svd: None, kappa: None, inertia: km.inertia },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 23);
        let mut cfg = PipelineConfig::default();
        cfg.k = 3;
        cfg.r = 128;
        cfg.kernel = Kernel::Gaussian { sigma: 0.6 };
        cfg.kmeans_replicates = 3;
        let out = run(&Env::new(cfg), &ds.x);
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "KK_RF on blobs: {acc}");
    }
}
