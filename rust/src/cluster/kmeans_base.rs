//! **K-means baseline** [15]: plain Lloyd on the raw data (the paper's
//! geometry-limited reference point — strong on convex blobs, weak on
//! non-convex structure).
//!
//! Serving: the fitted centroids *are* the model, so the
//! [`CentroidModel`] this fit returns predicts exactly — training points
//! reproduce their fit labels, new points get the true K-means
//! assignment.

use super::method::{ClusterOutput, Env, MethodInfo};
use crate::error::ScrbError;
use crate::kmeans::kmeans;
use crate::linalg::Mat;
use crate::model::{CentroidModel, FitResult, FittedModel};
use crate::util::timer::StageTimer;

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let mut timer = StageTimer::new();
    let engine = env.assign_engine();
    let opts = env.kmeans_opts(env.cfg.k);
    let result = timer.time("kmeans", || kmeans(x, &opts, engine.as_ref()));
    let model = CentroidModel::new(result.centroids);
    // Final labels via the model's own (native f64) assignment — on the
    // native engine these are bit-identical to the K-means assignment;
    // under the f32 XLA assign engine this overrides borderline rounding
    // so training-set `predict` reproduces fit labels on every engine.
    let labels = model.predict(x)?;
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim: x.cols,
            svd: None,
            kappa: None,
            inertia: result.inertia,
        },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn blobs_ok_moons_poor() {
        let blobs = synth::gaussian_blobs(300, 3, 3, 9.0, 3);
        let cfg = PipelineConfig::builder().k(3).kmeans_replicates(5).build();
        let out = fit(&Env::new(cfg), &blobs.x).unwrap().output;
        assert!(accuracy(&out.labels, &blobs.y) > 0.95);

        // non-convex: K-means should clearly fail where SC succeeds
        let moons = synth::two_moons(600, 0.05, 3);
        let cfg = PipelineConfig::builder().k(2).kmeans_replicates(5).build();
        let out = fit(&Env::new(cfg), &moons.x).unwrap().output;
        let acc = accuracy(&out.labels, &moons.y);
        assert!(acc < 0.95, "K-means should not solve two moons: {acc}");
    }

    #[test]
    fn fitted_model_reproduces_training_labels() {
        let blobs = synth::gaussian_blobs(200, 3, 3, 9.0, 5);
        let cfg = PipelineConfig::builder().k(3).kmeans_replicates(3).build();
        let fitted = fit(&Env::new(cfg), &blobs.x).unwrap();
        let predicted = fitted.model.predict(&blobs.x).unwrap();
        assert_eq!(predicted, fitted.output.labels);
    }
}
