//! **K-means baseline** [15]: plain Lloyd on the raw data (the paper's
//! geometry-limited reference point — strong on convex blobs, weak on
//! non-convex structure).
//!
//! As a stage composition: identity featurize (the input *is* the feature
//! matrix) → pass-through embed → the shared K-means stage with the
//! native relabel pass. See [`crate::cluster::MethodKind::pipeline`].
//!
//! Serving: the fitted centroids *are* the model, so the
//! [`crate::model::CentroidModel`] this fit returns predicts exactly —
//! training points reproduce their fit labels, new points get the true
//! K-means assignment.

use super::method::Env;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::FitResult;

/// Fit the K-means baseline through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::KMeans.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn blobs_ok_moons_poor() {
        let blobs = synth::gaussian_blobs(300, 3, 3, 9.0, 3);
        let cfg = PipelineConfig::builder().k(3).kmeans_replicates(5).build();
        let out = fit(&Env::new(cfg), &blobs.x).unwrap().output;
        assert!(accuracy(&out.labels, &blobs.y) > 0.95);

        // non-convex: K-means should clearly fail where SC succeeds
        let moons = synth::two_moons(600, 0.05, 3);
        let cfg = PipelineConfig::builder().k(2).kmeans_replicates(5).build();
        let out = fit(&Env::new(cfg), &moons.x).unwrap().output;
        let acc = accuracy(&out.labels, &moons.y);
        assert!(acc < 0.95, "K-means should not solve two moons: {acc}");
    }

    #[test]
    fn fitted_model_reproduces_training_labels() {
        use crate::model::FittedModel;
        let blobs = synth::gaussian_blobs(200, 3, 3, 9.0, 5);
        let cfg = PipelineConfig::builder().k(3).kmeans_replicates(3).build();
        let fitted = fit(&Env::new(cfg), &blobs.x).unwrap();
        let predicted = fitted.model.predict(&blobs.x).unwrap();
        assert_eq!(predicted, fitted.output.labels);
    }
}
