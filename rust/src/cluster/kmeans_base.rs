//! **K-means baseline** [15]: plain Lloyd on the raw data (the paper's
//! geometry-limited reference point — strong on convex blobs, weak on
//! non-convex structure).

use super::method::{ClusterOutput, Env, MethodInfo};
use crate::kmeans::kmeans;
use crate::linalg::Mat;
use crate::util::timer::StageTimer;

pub fn run(env: &Env, x: &Mat) -> ClusterOutput {
    let mut timer = StageTimer::new();
    let engine = env.assign_engine();
    let opts = env.kmeans_opts(env.cfg.k);
    let result = timer.time("kmeans", || kmeans(x, &opts, engine.as_ref()));
    ClusterOutput {
        labels: result.labels.iter().map(|&l| l as usize).collect(),
        timer,
        info: MethodInfo {
            feature_dim: x.cols,
            svd: None,
            kappa: None,
            inertia: result.inertia,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn blobs_ok_moons_poor() {
        let blobs = synth::gaussian_blobs(300, 3, 3, 9.0, 3);
        let mut cfg = PipelineConfig::default();
        cfg.k = 3;
        cfg.kmeans_replicates = 5;
        let out = run(&Env::new(cfg.clone()), &blobs.x);
        assert!(accuracy(&out.labels, &blobs.y) > 0.95);

        // non-convex: K-means should clearly fail where SC succeeds
        let moons = synth::two_moons(600, 0.05, 3);
        cfg.k = 2;
        let out = run(&Env::new(cfg), &moons.x);
        let acc = accuracy(&out.labels, &moons.y);
        assert!(acc < 0.95, "K-means should not solve two moons: {acc}");
    }
}
