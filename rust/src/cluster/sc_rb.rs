//! **SC_RB — the paper's method (Algorithm 2).**
//!
//! 1. Build the sparse RB feature matrix Z (Algorithm 1) — the similarity
//!    graph Ŵ = Z·Zᵀ is never materialized. Z lands on the fixed-stride
//!    [`crate::sparse::EllRb`] substrate, transpose layout included; the
//!    fit additionally keeps the [`crate::rb::RbCodebook`] (grids +
//!    bin→column tables) for out-of-sample serving.
//! 2. Degrees d = Z(Zᵀ1) (Eq. 6); Ẑ = D^{−1/2}Z folds into the per-row
//!    scale vector — O(N), no pass over the non-zeros.
//! 3. Top-K singular triplets of Ẑ via the PRIMME-style solver
//!    (equivalently: smallest eigenvectors of L̂ = I − ẐẐᵀ); every solver
//!    iteration is one fused strip-tiled gram product.
//! 4. Row-normalize the embedding.
//! 5. K-means on the embedding rows.
//!
//! The fit returns a [`crate::model::ScRbModel`]: Σ and V fold into the
//! projection `P = V·Σ⁻¹/√R`, so a new point embeds as the sum of the P
//! rows of its occupied bins (then row-normalized — which cancels the
//! unknown degree scalar) and labels as the nearest K-means centroid.
//!
//! One deliberate twist versus the batch-only pipeline: steps 4–5 run on
//! the **serving embedding** `normalize(z·V·Σ⁻¹)` computed through the
//! model's own gather path, not on the solver's U directly. The two agree
//! up to solver tolerance (U ≈ Ẑ·V·Σ⁻¹ at convergence, and the per-row
//! degree scalar cancels under normalization), but routing fit through
//! the identical code path makes training-set `predict` reproduce fit
//! labels **bit-exactly**, not just within tolerance.

use super::method::{cluster_embedding, ClusterOutput, Env, MethodInfo};
use crate::config::PipelineConfig;
use crate::eigen::{svds_ws, SolverWorkspace, SvdResult, SvdsOpts};
use crate::error::ScrbError;
use crate::kmeans::{AssignEngine, NativeAssign};
use crate::linalg::Mat;
use crate::model::{FitResult, FittedModel, ScRbModel};
use crate::rb::rb_features_with_codebook;
use crate::util::timer::StageTimer;

/// Fit Algorithm 2 on data `x`, producing the training clustering and the
/// serving model.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let cfg = &env.cfg;
    if x.rows == 0 {
        return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
    }
    let mut timer = StageTimer::new();

    // Step 1: RB feature generation (Algorithm 1), keeping the codebook
    // (grids + bin→column maps) the serving path needs.
    let (rb, codebook) = timer.time("rb_features", || {
        rb_features_with_codebook(x, cfg.r, cfg.kernel.sigma(), cfg.seed)
    });
    let feature_dim = rb.dim();
    let kappa = rb.kappa;

    // Step 2: implicit degrees + normalization (Eq. 6). On EllRb the
    // normalization rescales N row values instead of mutating N·R entries.
    let zhat = timer.time("degrees", || {
        let mut z = rb.z;
        let d = z.implicit_degrees();
        z.normalize_by_degree(&d);
        z
    });

    // Step 3: top-K singular triplets of Ẑ (PRIMME role). Every
    // iteration's S·B runs through the fused strip-tiled gram kernel and a
    // preallocated SolverWorkspace — the steady-state hot loop does not
    // touch the heap.
    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let mut solver_ws = SolverWorkspace::new();
    let svd = timer.time("svd", || svds_ws(&zhat, &opts, cfg.seed ^ 0x5bd5, &mut solver_ws));
    let SvdResult { s, v, stats, .. } = svd;

    // Serving projection P = V·Σ⁻¹/√R: folds the right singular vectors,
    // the inverse spectrum, and the shared RB value 1/√R into one D×K
    // matrix, so embedding a point is a plain gather-sum over its bins.
    // Near-zero σ directions are dropped (scale 0) rather than amplified.
    let proj = timer.time("projection", || {
        let mut p = v;
        let s0 = s.first().copied().unwrap_or(0.0).max(1e-300);
        let rsqrt = 1.0 / (cfg.r as f64).sqrt();
        let col_scale: Vec<f64> = s
            .iter()
            .map(|&sj| if sj > 1e-12 * s0 { rsqrt / sj } else { 0.0 })
            .collect();
        for i in 0..p.rows {
            for (pv, cs) in p.row_mut(i).iter_mut().zip(col_scale.iter()) {
                *pv *= *cs;
            }
        }
        p
    });

    // Steps 4–5 on the serving embedding: rows of normalize(z·V·Σ⁻¹),
    // computed through the model's own gather path so that training-set
    // predictions reproduce the fit labels bit-exactly (`transform`
    // already unit-normalizes the rows, so no further normalization).
    let mut model = ScRbModel {
        codebook,
        kernel: cfg.kernel,
        s,
        proj,
        centroids: Mat::zeros(0, 0),
        norm: None,
    };
    let emb = timer.time("embed", || model.transform(x))?;
    let (_, km) = cluster_embedding(&emb, env, &mut timer);
    model.centroids = km.centroids;
    // Final labels via the same f64 argmin the serving path uses (the
    // NativeAssign engine and model predict share one nearest-centroid
    // scan) — identical bits to `predict` on the training rows. On the
    // native engine this equals the K-means assignment; under the f32
    // XLA assign engine it overrides borderline rounding so the
    // train-predict == fit-labels contract holds for every engine.
    let labels: Vec<usize> = timer.time("embed", || {
        let (lab, _) = NativeAssign.assign(&emb, &model.centroids);
        lab.into_iter().map(|l| l as usize).collect()
    });
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(stats),
            kappa: Some(kappa),
            inertia: km.inertia,
        },
    };
    Ok(FitResult { model: Box::new(model), output })
}

/// Convenience wrapper used by the quickstart/docs: owns a config and runs
/// SC_RB without an XLA runtime.
pub struct ScRb {
    pub cfg: PipelineConfig,
}

impl ScRb {
    pub fn new(cfg: PipelineConfig) -> ScRb {
        ScRb { cfg }
    }

    /// Fit on `x`: training clustering + serving model.
    pub fn fit(&self, x: &Mat) -> Result<FitResult, ScrbError> {
        let env = Env::new(self.cfg.clone());
        fit(&env, x)
    }

    /// Batch convenience: fit and return only the training output.
    pub fn run(&self, x: &Mat) -> Result<ClusterOutput, ScrbError> {
        Ok(self.fit(x)?.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn separates_two_moons() {
        // the signature SC-beats-KMeans case
        let ds = synth::two_moons(600, 0.05, 3);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(256)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.15 })
            .kmeans_replicates(5)
            .build();
        let out = ScRb::new(cfg).run(&ds.x).unwrap();
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_RB accuracy on two moons: {acc}");
        assert!(out.info.kappa.unwrap() >= 1.0);
        assert!(out.info.feature_dim > 0);
        assert!(out.timer.secs("rb_features") >= 0.0);
    }

    #[test]
    fn recovers_blobs_with_high_accuracy() {
        let ds = synth::gaussian_blobs(400, 4, 3, 8.0, 5);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(128)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.8 })
            .kmeans_replicates(5)
            .build();
        let out = ScRb::new(cfg).run(&ds.x).unwrap();
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.95, "SC_RB accuracy on blobs: {acc}");
    }

    #[test]
    fn works_with_both_solvers() {
        let ds = synth::gaussian_blobs(200, 3, 2, 8.0, 7);
        for solver in [crate::config::Solver::Davidson, crate::config::Solver::Lanczos] {
            let cfg = PipelineConfig::builder()
                .k(2)
                .r(64)
                .solver(solver)
                .kernel(crate::config::Kernel::Laplacian { sigma: 0.5 })
                .kmeans_replicates(3)
                .build();
            let out = ScRb::new(cfg).run(&ds.x).unwrap();
            let acc = accuracy(&out.labels, &ds.y);
            assert!(acc > 0.9, "{solver:?} accuracy {acc}");
        }
    }

    #[test]
    fn fit_exposes_consistent_model_shape() {
        let ds = synth::gaussian_blobs(150, 3, 3, 8.0, 9);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(32)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(2)
            .build();
        let fitted = ScRb::new(cfg).fit(&ds.x).unwrap();
        use crate::model::FittedModel;
        assert_eq!(fitted.model.n_clusters(), 3);
        assert_eq!(fitted.model.input_dim(), 3);
        assert_eq!(fitted.output.labels.len(), 150);
        let emb = fitted.model.transform(&ds.x).unwrap();
        assert_eq!((emb.rows, emb.cols), (150, 3));
        // embedding rows are unit-normalized (or zero)
        for i in 0..emb.rows {
            let n2: f64 = emb.row(i).iter().map(|v| v * v).sum();
            assert!((n2 - 1.0).abs() < 1e-9 || n2 == 0.0, "row {i} norm² {n2}");
        }
    }

    #[test]
    fn empty_input_is_an_error() {
        let cfg = PipelineConfig::builder().k(2).r(8).build();
        assert!(ScRb::new(cfg).fit(&Mat::zeros(0, 3)).is_err());
    }
}
