//! **SC_RB — the paper's method (Algorithm 2)** as a stage composition:
//! [`RbFeaturize`] (step 1) → [`RbEmbed`] (steps 2–4 + the serving
//! projection) → the shared K-means cluster stage (step 5).
//!
//! [`RbFeaturize`] is the one featurize stage that reads **both** data
//! sources: an in-memory matrix (Algorithm 1 batch binning onto the
//! fixed-stride [`crate::sparse::EllRb`] substrate) or a chunked
//! [`crate::stream::ChunkReader`] (the two-pass bounded-memory
//! featurization onto [`crate::sparse::BlockEllRb`]). Everything
//! downstream is substrate-agnostic, which is what makes a streamed fit
//! **byte-identical** to the in-memory fit on the same data and seed — a
//! property of the shared driver, not of two hand-synchronized functions
//! (locked by `tests/stream.rs`).
//!
//! [`RbFeaturize`] also performs step 2 (Eq. 6): the implicit degrees
//! fold into the substrate's O(N) per-row scale vector, so the artifact
//! holds Ẑ directly and the embed stage borrows it instead of copying
//! the index arrays. [`RbEmbed`] runs step 3 (top-K singular triplets
//! via the PRIMME-style solver over the fused gram kernel), folds the
//! serving projection `P = V·Σ⁻¹/√R`, and
//! computes the clustering embedding through the **serving gather path**:
//! row i's embedding is the sum of the P rows of its occupied bins
//! (read straight off the substrate's indices, which store one column
//! per grid in grid order), then row-normalized. That is float-for-float
//! the sequence [`crate::model::ScRbModel::embed_into`] performs after a
//! codebook lookup, so training-set `predict` reproduces fit labels
//! **bit-exactly** — not just within tolerance.

use super::method::Env;
use crate::config::{PipelineConfig, Solver};
use crate::eigen::compressive::{compressive_parts_ws, sample_rows, tikhonov_interpolate};
use crate::eigen::{svds_ws, CompressiveOpts, SolverWorkspace, SvdOp, SvdResult, SvdsOpts};
use crate::error::ScrbError;
use crate::kmeans::kmeans;
use crate::linalg::Mat;
use crate::model::FitResult;
use crate::pipeline::{
    Assemble, DataSource, Embed, EmbedArtifact, FeatureArtifact, FeatureMatrix, Featurize,
    Fingerprint, KmeansCluster, Pipeline,
};
use crate::rb::{rb_features_with_codebook, RbFeatures};
use crate::sparse::EllRb;
use crate::stream::checkpoint::{ckpt_fingerprint, Checkpointer, StatsCkpt};
use crate::stream::{stats_pass, SparseChunk, StreamFeaturizer};
use crate::util::threads::parallel_rows_mut;
use crate::util::timer::StageTimer;

/// RB featurization stage (Algorithm 1 + the Eq. 6 degree fold): emits
/// the degree-normalized sparse substrate Ẑ plus the serving codebook.
/// Reads an in-memory matrix or a chunked stream — the only stage whose
/// behaviour is chosen by data source.
pub struct RbFeaturize {
    /// Number of grids R.
    pub r: usize,
    /// Kernel bandwidth σ (grid widths are drawn from Gamma(2, σ)).
    pub sigma: f64,
    /// Grid-sampling seed.
    pub seed: u64,
}

impl Featurize for RbFeaturize {
    fn fingerprint(&self, input_fp: u64) -> u64 {
        Fingerprint::new("featurize/rb")
            .u64(input_fp)
            .usize(self.r)
            .f64(self.sigma)
            .u64(self.seed)
            .finish()
    }

    fn run(&self, _env: &Env, data: DataSource<'_>, fp: u64) -> Result<FeatureArtifact, ScrbError> {
        match data {
            DataSource::Matrix(x) => {
                if x.rows == 0 {
                    return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
                }
                let mut timer = StageTimer::new();
                let (rb, codebook) = timer.time("rb_features", || {
                    rb_features_with_codebook(x, self.r, self.sigma, self.seed)
                });
                let feature_dim = rb.dim();
                let RbFeatures { mut z, kappa, .. } = rb;
                // Step 2 (Eq. 6) folds into the artifact: the implicit
                // degrees rescale the O(N) per-row scale vector only, so
                // storing Ẑ costs nothing extra — and the embed stage
                // then never needs its own copy of the substrate (the
                // indices are by far the largest resident structure).
                timer.time("degrees", || {
                    let d = z.implicit_degrees();
                    z.normalize_by_degree(&d);
                });
                Ok(FeatureArtifact {
                    fingerprint: fp,
                    z: FeatureMatrix::EllRb(z),
                    codebook: Some(codebook),
                    kappa: Some(kappa),
                    feature_dim,
                    norm: None,
                    stream_labels: None,
                    stream_quarantine: None,
                    timer,
                })
            }
            DataSource::Stream { reader, opts } => {
                let mut timer = StageTimer::new();
                let mut chunk = SparseChunk::new();
                let mut ckpt = match &opts.checkpoint {
                    Some(cfg) => Some(Checkpointer::new(
                        cfg,
                        ckpt_fingerprint(self.r, self.sigma, self.seed, opts.block_rows),
                    )?),
                    None => None,
                };

                // Pass 1: min/span frame + row and class census — or its
                // checkpointed result, which lets a resumed fit skip the
                // whole scan.
                let restored_stats = match &ckpt {
                    Some(c) if c.resume() => c.load_stats()?,
                    _ => None,
                };
                let (n, d, lo, span) = match restored_stats {
                    Some(s) => (s.n, s.d, s.lo, s.span),
                    None => {
                        let stats =
                            timer.time("stream_stats", || stats_pass(reader, &mut chunk))?;
                        if stats.n == 0 {
                            return Err(ScrbError::invalid_input("cannot fit on an empty dataset"));
                        }
                        let n = stats.n;
                        let d = reader.dim();
                        let (lo, span) = stats.finalize(d);
                        if let Some(c) = &ckpt {
                            c.save_stats(&StatsCkpt {
                                n,
                                d,
                                lo: lo.clone(),
                                span: span.clone(),
                            })?;
                        }
                        (n, d, lo, span)
                    }
                };

                // Pass 2: block-wise RB featurization in the fitted frame.
                // Exactly one reset in both the fresh and the resumed path,
                // so pass-indexed state (e.g. injected faults) is identical
                // either way.
                reader.reset()?;
                let mut fz = StreamFeaturizer::new(
                    self.r,
                    d,
                    self.sigma,
                    self.seed,
                    lo.clone(),
                    span.clone(),
                    opts.block_rows,
                    n,
                );
                // On resume, restore the featurizer mid-pass and fast-skip
                // the rows it already holds while replaying the stream.
                let mut skip = 0usize;
                if let Some(c) = &mut ckpt {
                    c.bind(d, n);
                    if c.resume() {
                        if let Some(st) = c.load_state()? {
                            skip = st.labels.len();
                            fz.load_state(st.grids, st.blocks, st.labels)?;
                        }
                    }
                }
                timer.time("rb_features", || -> Result<(), ScrbError> {
                    while reader.next_chunk(&mut chunk)? {
                        // a column beyond the stats-pass dimension means
                        // the stream changed between passes — surface the
                        // typed error here rather than an out-of-bounds
                        // panic inside the featurizer
                        if reader.dim() > d {
                            return Err(ScrbError::invalid_input(format!(
                                "stream changed between passes: dimension grew from {d} to {}",
                                reader.dim()
                            )));
                        }
                        let rows = chunk.rows();
                        if skip >= rows {
                            skip -= rows;
                            continue;
                        }
                        fz.push_chunk_from(&chunk, skip);
                        skip = 0;
                        if let Some(c) = &mut ckpt {
                            c.maybe_save(&fz)?;
                        }
                    }
                    Ok(())
                })?;
                if skip > 0 {
                    return Err(ScrbError::checkpoint(format!(
                        "stream ended {skip} rows before the checkpointed position — the \
                         input shrank since the checkpoint was written"
                    )));
                }
                if fz.rows() != n {
                    return Err(ScrbError::invalid_input(format!(
                        "stream changed between passes: {} rows in the stats pass, {} in the \
                         featurize pass",
                        n,
                        fz.rows()
                    )));
                }
                let feats = fz.finish()?;
                let feature_dim = feats.codebook.dim;
                let mut z = feats.z;
                // same Eq. 6 fold as the in-memory arm (block-iterated)
                timer.time("degrees", || {
                    let d = z.implicit_degrees();
                    z.normalize_by_degree(&d);
                });
                Ok(FeatureArtifact {
                    fingerprint: fp,
                    z: FeatureMatrix::Block(z),
                    codebook: Some(feats.codebook),
                    kappa: Some(feats.kappa),
                    feature_dim,
                    norm: Some((lo, span)),
                    stream_labels: Some(feats.labels),
                    stream_quarantine: None,
                    timer,
                })
            }
            DataSource::ShardedStream { mut readers, block_rows, policy } => {
                let mut timer = StageTimer::new();
                let sharded = crate::shard::featurize_sharded(
                    self.r,
                    self.sigma,
                    self.seed,
                    &mut readers,
                    block_rows,
                    &policy,
                )?;
                timer.add("stream_stats", sharded.stats_time);
                timer.add("rb_features", sharded.featurize_time);
                timer.add("shard_merge", sharded.merge_time);
                let feats = sharded.features;
                let feature_dim = feats.codebook.dim;
                let mut z = feats.z;
                // same Eq. 6 fold as the other arms (block-iterated)
                timer.time("degrees", || {
                    let d = z.implicit_degrees();
                    z.normalize_by_degree(&d);
                });
                Ok(FeatureArtifact {
                    fingerprint: fp,
                    z: FeatureMatrix::Block(z),
                    codebook: Some(feats.codebook),
                    kappa: Some(feats.kappa),
                    feature_dim,
                    norm: Some((sharded.lo, sharded.span)),
                    stream_labels: Some(feats.labels),
                    stream_quarantine: Some(sharded.quarantine),
                    timer,
                })
            }
        }
    }
}

/// SC_RB's embed stage (Algorithm 2 steps 3–4): top-K singular triplets
/// of the already-normalized Ẑ, the folded serving projection
/// `P = V·Σ⁻¹/√R`, and the clustering embedding computed through the
/// serving gather path. Borrows the substrate from the feature artifact
/// — no copy of the index arrays.
pub struct RbEmbed {
    /// Embedding width (singular triplets kept).
    pub k: usize,
    /// Number of RB grids R (the shared 1/√R value folds into P).
    pub r: usize,
    /// Which iterative solver backs step 3.
    pub solver: crate::config::Solver,
    /// Solver convergence tolerance.
    pub tol: f64,
    /// Solver matvec budget.
    pub max_matvecs: usize,
    /// Full solver seed (method seed ⊕ the SC_RB salt).
    pub seed: u64,
}

impl Embed for RbEmbed {
    fn fingerprint(&self, upstream: u64) -> u64 {
        Fingerprint::new("embed/rb")
            .u64(upstream)
            .usize(self.k)
            .usize(self.r)
            .str(self.solver.name())
            .f64(self.tol)
            .usize(self.max_matvecs)
            .u64(self.seed)
            .finish()
    }

    fn run(
        &self,
        _env: &Env,
        feat: &crate::pipeline::FeatureArtifact,
        fp: u64,
    ) -> Result<crate::pipeline::EmbedArtifact, ScrbError> {
        let mut timer = StageTimer::new();
        let mut sopts = SvdsOpts::new(self.k, self.solver);
        sopts.tol = self.tol;
        sopts.max_matvecs = self.max_matvecs;
        let mut solver_ws = SolverWorkspace::new();

        // Step 3 + the projection fold + the gather embedding, on
        // whichever RB substrate the featurize stage emitted (already
        // degree-normalized there — this stage borrows the substrate, it
        // never copies it). The block substrate's kernels are
        // bit-identical to the monolithic one's, so the whole solver
        // trajectory is too.
        let (s, proj, stats, u) = match &feat.z {
            FeatureMatrix::EllRb(z0) => {
                let svd = timer.time("svd", || svds_ws(z0, &sopts, self.seed, &mut solver_ws));
                let SvdResult { s, v, stats, .. } = svd;
                let proj = timer.time("projection", || fold_projection(v, &s, self.r));
                let offsets = [0usize, z0.rows];
                let u = timer.time("embed", || {
                    gather_embedding(std::slice::from_ref(z0), &offsets, &proj)
                });
                (s, proj, stats, u)
            }
            FeatureMatrix::Block(z0) => {
                let svd = timer.time("svd", || svds_ws(z0, &sopts, self.seed, &mut solver_ws));
                let SvdResult { s, v, stats, .. } = svd;
                let proj = timer.time("projection", || fold_projection(v, &s, self.r));
                let u = timer.time("embed", || {
                    gather_embedding(&z0.blocks, &z0.row_offsets, &proj)
                });
                (s, proj, stats, u)
            }
            _ => {
                return Err(ScrbError::unsupported(
                    "the RB embed stage needs an RB substrate (EllRb or BlockEllRb)",
                ))
            }
        };
        Ok(crate::pipeline::EmbedArtifact {
            fingerprint: fp,
            s,
            u: std::sync::Arc::new(u),
            proj: Some(proj),
            stats: Some(stats),
            timer,
        })
    }
}

/// SC_RB's compressive embed stage — full Compressive Spectral Clustering
/// (`--solver compressive`) behind the same artifact contract as
/// [`RbEmbed`]: Chebyshev-filter η random signals through the fused gram
/// kernel, k-means a uniformly sampled row subset of the filtered
/// signals, Tikhonov-interpolate the sample labels back to all N rows
/// (a block-CG solve on the same kernel), then fold the cluster-score
/// basis into the serving projection `P·C` so the clustering embedding
/// is computed through the **serving gather path** — train-set `predict`
/// reproduces fit labels bit-exactly, just like the eigensolver path.
/// Works unchanged on both RB substrates (monolithic [`EllRb`] and
/// streamed `BlockEllRb`), whose kernels are bit-identical.
pub struct FilterEmbed {
    /// Singular triplets extracted from the filtered span (embedding
    /// basis width; ≥ `kc`).
    pub k: usize,
    /// Cluster count the sample k-means / interpolation works with (the
    /// final embedding has `kc` columns).
    pub kc: usize,
    /// Number of RB grids R (folds into the serving projection).
    pub r: usize,
    /// Chebyshev filter order p.
    pub order: usize,
    /// Random-signal count η; `None` = O(log n) auto.
    pub signals: Option<usize>,
    /// Sampled-row count m; `None` = max(100, 4·kc·ln n).
    pub sample: Option<usize>,
    /// Filter/CG tolerance.
    pub tol: f64,
    /// Matvec budget (reported through `stats.converged`).
    pub max_matvecs: usize,
    /// Full solver seed (method seed ⊕ the SC_RB salt).
    pub seed: u64,
}

impl FilterEmbed {
    /// The substrate-generic body: `a` is the solver-operator view and
    /// `blocks`/`row_offsets` its serving-gather view (one block for the
    /// monolithic substrate, many for the streamed one).
    fn embed_on<O: SvdOp + ?Sized>(
        &self,
        env: &Env,
        a: &O,
        blocks: &[EllRb],
        row_offsets: &[usize],
        fp: u64,
    ) -> Result<EmbedArtifact, ScrbError> {
        let mut timer = StageTimer::new();
        let mut ws = SolverWorkspace::new();
        let mut opts = CompressiveOpts::new(self.k);
        opts.order = self.order;
        opts.signals = self.signals;
        opts.tol = self.tol;
        opts.max_matvecs = self.max_matvecs;

        // Spectral interval + Chebyshev filter + Rayleigh–Ritz triplets.
        let parts = timer.time("svd", || compressive_parts_ws(a, &opts, self.seed, &mut ws));
        let lmax = parts.lambda_max;
        let mut filtered = parts.filtered;
        let SvdResult { s, u, v, mut stats } = parts.svd;
        let n = filtered.rows;
        let kc = self.kc.max(1);

        // CSC steps 3–4: cluster a uniform row sample of the (row
        // normalized) filtered signals, then spread the sample labels to
        // every row by the Tikhonov-regularized solve on the same kernel.
        let scores = timer.time("interpolate", || {
            filtered.normalize_rows();
            let auto = (4.0 * kc as f64 * (n.max(2) as f64).ln()).ceil() as usize;
            let m = self.sample.unwrap_or_else(|| auto.max(100)).clamp(kc.min(n), n);
            // take the index scratch out of the workspace so the
            // interpolation can borrow the workspace mutably alongside it
            let mut idx = std::mem::take(&mut ws.cb_sample_idx);
            sample_rows(n, m, self.seed ^ 0x5a17, &mut idx);
            let xs = filtered.select_rows(&idx);
            let mut kopts = env.kmeans_opts(kc);
            kopts.seed = self.seed ^ 0x17aa;
            let engine = env.assign_engine();
            let km = kmeans(&xs, &kopts, &*engine);
            let (x, cg_mv) = tikhonov_interpolate(
                a,
                &idx,
                &km.labels,
                kc,
                lmax,
                0.1,
                self.tol.max(1e-8),
                20,
                &mut ws,
            );
            ws.cb_sample_idx = idx;
            stats.matvecs += cg_mv;
            x
        });

        // Serving-consistency fold: C = Uᵀ·X expresses the interpolated
        // cluster scores in the Ritz basis, so `P·C` is a D×kc serving
        // projection and the training embedding can be computed through
        // the identical gather-sum + row normalization the model performs
        // at predict time. Directions dropped by `fold_projection`'s σ
        // threshold vanish automatically (their P columns are zero).
        let proj = timer.time("projection", || {
            let c = u.t_matmul(&scores);
            fold_projection(v, &s, self.r).matmul(&c)
        });
        let u_emb = timer.time("embed", || gather_embedding(blocks, row_offsets, &proj));
        Ok(EmbedArtifact {
            fingerprint: fp,
            s,
            u: std::sync::Arc::new(u_emb),
            proj: Some(proj),
            stats: Some(stats),
            timer,
        })
    }
}

impl Embed for FilterEmbed {
    fn fingerprint(&self, upstream: u64) -> u64 {
        Fingerprint::new("embed/filter")
            .u64(upstream)
            .usize(self.k)
            .usize(self.kc)
            .usize(self.r)
            .usize(self.order)
            .usize(self.signals.unwrap_or(0))
            .usize(self.sample.unwrap_or(0))
            .f64(self.tol)
            .usize(self.max_matvecs)
            .u64(self.seed)
            .finish()
    }

    fn run(&self, env: &Env, feat: &FeatureArtifact, fp: u64) -> Result<EmbedArtifact, ScrbError> {
        match &feat.z {
            FeatureMatrix::EllRb(z0) => {
                let offsets = [0usize, z0.rows];
                self.embed_on(env, z0, std::slice::from_ref(z0), &offsets, fp)
            }
            FeatureMatrix::Block(z0) => self.embed_on(env, z0, &z0.blocks, &z0.row_offsets, fp),
            _ => Err(ScrbError::unsupported(
                "the compressive embed stage needs an RB substrate (EllRb or BlockEllRb)",
            )),
        }
    }
}

/// Fold V, Σ⁻¹, and the shared RB value 1/√R into the serving projection
/// `P = V·Σ⁻¹/√R` (D×K) — embedding a point becomes a plain gather-sum
/// over its bins. Near-zero σ directions are dropped (scale 0) rather
/// than amplified.
fn fold_projection(v: Mat, s: &[f64], r: usize) -> Mat {
    let mut p = v;
    let s0 = s.first().copied().unwrap_or(0.0).max(1e-300);
    let rsqrt = 1.0 / (r as f64).sqrt();
    let col_scale: Vec<f64> =
        s.iter().map(|&sj| if sj > 1e-12 * s0 { rsqrt / sj } else { 0.0 }).collect();
    for i in 0..p.rows {
        for (pv, cs) in p.row_mut(i).iter_mut().zip(col_scale.iter()) {
            *pv *= *cs;
        }
    }
    p
}

/// Serving embedding of every training row, computed from the substrate's
/// own column indices: row i's occupied bins are exactly its R indices
/// (one per grid, stored in grid order), so the gather-sum + row
/// normalization below performs the identical float sequence
/// [`crate::model::ScRbModel::embed_into`] would after a codebook lookup.
/// Shared by the in-memory (single block) and streamed (many blocks)
/// paths.
fn gather_embedding(blocks: &[EllRb], row_offsets: &[usize], proj: &Mat) -> Mat {
    let k = proj.cols;
    let rows = *row_offsets.last().unwrap_or(&0);
    let mut m = Mat::zeros(rows, k);
    if rows == 0 || k == 0 {
        return m;
    }
    for (blk, w) in blocks.iter().zip(row_offsets.windows(2)) {
        let out = &mut m.data[w[0] * k..w[1] * k];
        parallel_rows_mut(out, k, |row0, chunk| {
            for (dr, e) in chunk.chunks_mut(k).enumerate() {
                e.fill(0.0);
                for &c in blk.row_indices(row0 + dr) {
                    let p = proj.row(c as usize);
                    for (ej, pj) in e.iter_mut().zip(p.iter()) {
                        *ej += *pj;
                    }
                }
                let norm = e.iter().map(|v| v * v).sum::<f64>().sqrt();
                if norm > 1e-300 {
                    let inv = 1.0 / norm;
                    for v in e.iter_mut() {
                        *v *= inv;
                    }
                }
            }
        });
    }
    m
}

/// SC_RB's stage composition with an explicit cluster count and optional
/// mini-batch size — the streaming driver composes this with the census
/// K and its huge-N batch switch; [`crate::cluster::MethodKind::pipeline`]
/// uses `cfg.k` and full-batch.
pub(crate) fn scrb_stages(cfg: &PipelineConfig, k: usize, batch: Option<usize>) -> Pipeline {
    // never narrower than K: a streamed fit derives K from the label
    // census at run time, which config validation cannot see
    let edim = cfg.embed_dim.unwrap_or(k).max(k);
    let embed: Box<dyn Embed> = if cfg.solver == Solver::Compressive {
        Box::new(FilterEmbed {
            k: edim,
            kc: k,
            r: cfg.r,
            order: cfg.cheb_order,
            signals: cfg.cheb_signals,
            sample: cfg.cheb_sample,
            tol: cfg.svd_tol,
            max_matvecs: cfg.svd_max_iters,
            seed: cfg.seed ^ 0x5bd5,
        })
    } else {
        Box::new(RbEmbed {
            k: edim,
            r: cfg.r,
            solver: cfg.solver,
            tol: cfg.svd_tol,
            max_matvecs: cfg.svd_max_iters,
            seed: cfg.seed ^ 0x5bd5,
        })
    };
    Pipeline::new(
        Box::new(RbFeaturize { r: cfg.r, sigma: cfg.kernel.sigma(), seed: cfg.seed }),
        embed,
        Box::new(KmeansCluster::from_cfg(cfg, k).with_batch(batch).with_relabel()),
        Assemble::ScRb,
    )
}

/// Fit Algorithm 2 on data `x` through the stage composition, producing
/// the training clustering and the serving model.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::ScRb.fit(env, x)
}

/// Convenience wrapper used by the quickstart/docs: owns a config and runs
/// SC_RB without an XLA runtime.
pub struct ScRb {
    pub cfg: PipelineConfig,
}

impl ScRb {
    pub fn new(cfg: PipelineConfig) -> ScRb {
        ScRb { cfg }
    }

    /// Fit on `x`: training clustering + serving model.
    pub fn fit(&self, x: &Mat) -> Result<FitResult, ScrbError> {
        let env = Env::new(self.cfg.clone());
        fit(&env, x)
    }

    /// Batch convenience: fit and return only the training output.
    pub fn run(&self, x: &Mat) -> Result<super::method::ClusterOutput, ScrbError> {
        Ok(self.fit(x)?.output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn separates_two_moons() {
        // the signature SC-beats-KMeans case
        let ds = synth::two_moons(600, 0.05, 3);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(256)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.15 })
            .kmeans_replicates(5)
            .build();
        let out = ScRb::new(cfg).run(&ds.x).unwrap();
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_RB accuracy on two moons: {acc}");
        assert!(out.info.kappa.unwrap() >= 1.0);
        assert!(out.info.feature_dim > 0);
        assert!(out.timer.secs("rb_features") >= 0.0);
    }

    #[test]
    fn recovers_blobs_with_high_accuracy() {
        let ds = synth::gaussian_blobs(400, 4, 3, 8.0, 5);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(128)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.8 })
            .kmeans_replicates(5)
            .build();
        let out = ScRb::new(cfg).run(&ds.x).unwrap();
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.95, "SC_RB accuracy on blobs: {acc}");
    }

    #[test]
    fn works_with_every_solver() {
        let ds = synth::gaussian_blobs(200, 3, 2, 8.0, 7);
        for solver in crate::config::Solver::ALL {
            let cfg = PipelineConfig::builder()
                .k(2)
                .r(64)
                .solver(solver)
                .kernel(crate::config::Kernel::Laplacian { sigma: 0.5 })
                .kmeans_replicates(3)
                .build();
            let out = ScRb::new(cfg).run(&ds.x).unwrap();
            let acc = accuracy(&out.labels, &ds.y);
            assert!(acc > 0.9, "{solver:?} accuracy {acc}");
        }
    }

    #[test]
    fn compressive_train_predict_reproduces_fit_labels() {
        // the serving-consistency contract must hold for the filter path
        // too: the embed stage computes the training embedding through the
        // same gather-sum the model performs at predict time
        let ds = synth::gaussian_blobs(150, 3, 3, 8.0, 11);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(32)
            .solver(crate::config::Solver::Compressive)
            .cheb_order(30)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(2)
            .build();
        let fitted = ScRb::new(cfg).fit(&ds.x).unwrap();
        use crate::model::FittedModel;
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted, fitted.output.labels, "train predict == fit labels, bit-exact");
    }

    #[test]
    fn fit_exposes_consistent_model_shape() {
        let ds = synth::gaussian_blobs(150, 3, 3, 8.0, 9);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(32)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(2)
            .build();
        let fitted = ScRb::new(cfg).fit(&ds.x).unwrap();
        use crate::model::FittedModel;
        assert_eq!(fitted.model.n_clusters(), 3);
        assert_eq!(fitted.model.input_dim(), 3);
        assert_eq!(fitted.output.labels.len(), 150);
        let emb = fitted.model.transform(&ds.x).unwrap();
        assert_eq!((emb.rows, emb.cols), (150, 3));
        // embedding rows are unit-normalized (or zero)
        for i in 0..emb.rows {
            let n2: f64 = emb.row(i).iter().map(|v| v * v).sum();
            assert!((n2 - 1.0).abs() < 1e-9 || n2 == 0.0, "row {i} norm² {n2}");
        }
    }

    #[test]
    fn gather_embedding_matches_model_transform() {
        // the bit-exactness pivot: the embed stage's gather over substrate
        // indices performs the identical float sequence as the serving
        // model's codebook-lookup path on the training rows
        let ds = synth::gaussian_blobs(80, 3, 2, 8.0, 21);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(16)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(2)
            .build();
        let fitted = ScRb::new(cfg).fit(&ds.x).unwrap();
        use crate::model::FittedModel;
        let via_codebook = fitted.model.transform(&ds.x).unwrap();
        let predicted = fitted.model.predict(&ds.x).unwrap();
        assert_eq!(predicted, fitted.output.labels, "train predict == fit labels, bit-exact");
        // row norms are exactly 1 (or 0) in both paths
        assert_eq!(via_codebook.rows, 80);
    }

    #[test]
    fn embed_dim_decouples_from_k() {
        let ds = synth::gaussian_blobs(120, 3, 2, 8.0, 27);
        let cfg = PipelineConfig::builder()
            .k(2)
            .r(32)
            .embed_dim(4)
            .kernel(crate::config::Kernel::Laplacian { sigma: 0.6 })
            .kmeans_replicates(2)
            .build();
        let fitted = ScRb::new(cfg).fit(&ds.x).unwrap();
        use crate::model::FittedModel;
        // 4-dimensional embedding, 2 clusters
        assert_eq!(fitted.model.n_clusters(), 2);
        let emb = fitted.model.transform(&ds.x).unwrap();
        assert_eq!(emb.cols, 4);
    }

    #[test]
    fn empty_input_is_an_error() {
        let cfg = PipelineConfig::builder().k(2).r(8).build();
        assert!(ScRb::new(cfg).fit(&Mat::zeros(0, 3)).is_err());
    }
}
