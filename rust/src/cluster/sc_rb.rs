//! **SC_RB — the paper's method (Algorithm 2).**
//!
//! 1. Build the sparse RB feature matrix Z (Algorithm 1) — the similarity
//!    graph Ŵ = Z·Zᵀ is never materialized. Z lands on the fixed-stride
//!    [`crate::sparse::EllRb`] substrate, transpose layout included.
//! 2. Degrees d = Z(Zᵀ1) (Eq. 6); Ẑ = D^{−1/2}Z folds into the per-row
//!    scale vector — O(N), no pass over the non-zeros.
//! 3. Top-K left singular vectors of Ẑ via the PRIMME-style solver
//!    (equivalently: smallest eigenvectors of L̂ = I − ẐẐᵀ); every solver
//!    iteration is one EllRb `matmat` plus one strip-parallel `t_matmat`.
//! 4. Row-normalize U.
//! 5. K-means on the rows of U.

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use crate::config::PipelineConfig;
use crate::eigen::{svds_ws, SolverWorkspace, SvdsOpts};
use crate::linalg::Mat;
use crate::rb::rb_features;
use crate::util::timer::StageTimer;

/// Run Algorithm 2 on data `x`.
pub fn run(env: &Env, x: &Mat) -> ClusterOutput {
    let cfg = &env.cfg;
    let mut timer = StageTimer::new();

    // Step 1: RB feature generation (Algorithm 1).
    let rb = timer.time("rb_features", || {
        rb_features(x, cfg.r, cfg.kernel.sigma(), cfg.seed)
    });
    let feature_dim = rb.dim();
    let kappa = rb.kappa;

    // Step 2: implicit degrees + normalization (Eq. 6). On EllRb the
    // normalization rescales N row values instead of mutating N·R entries.
    let zhat = timer.time("degrees", || {
        let mut z = rb.z;
        let d = z.implicit_degrees();
        z.normalize_by_degree(&d);
        z
    });

    // Step 3: top-K left singular vectors of Ẑ (PRIMME role). Every
    // iteration's S·B runs through the fused strip-tiled gram kernel and a
    // preallocated SolverWorkspace — the steady-state hot loop does not
    // touch the heap.
    let mut opts = SvdsOpts::new(cfg.k, cfg.solver);
    opts.tol = cfg.svd_tol;
    opts.max_matvecs = cfg.svd_max_iters;
    let mut solver_ws = SolverWorkspace::new();
    let svd = timer.time("svd", || svds_ws(&zhat, &opts, cfg.seed ^ 0x5bd5, &mut solver_ws));

    // Steps 4–5: row-normalize + K-means.
    let (labels, km) = embed_and_cluster(svd.u, env, &mut timer, true);

    ClusterOutput {
        labels,
        timer,
        info: MethodInfo {
            feature_dim,
            svd: Some(svd.stats),
            kappa: Some(kappa),
            inertia: km.inertia,
        },
    }
}

/// Convenience wrapper used by the quickstart/docs: owns a config and runs
/// SC_RB without an XLA runtime.
pub struct ScRb {
    pub cfg: PipelineConfig,
}

impl ScRb {
    pub fn new(cfg: PipelineConfig) -> ScRb {
        ScRb { cfg }
    }

    pub fn run(&self, x: &Mat) -> ClusterOutput {
        let env = Env::new(self.cfg.clone());
        run(&env, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn separates_two_moons() {
        // the signature SC-beats-KMeans case
        let ds = synth::two_moons(600, 0.05, 3);
        let mut cfg = PipelineConfig::default();
        cfg.k = 2;
        cfg.r = 256;
        cfg.kernel = crate::config::Kernel::Laplacian { sigma: 0.15 };
        cfg.kmeans_replicates = 5;
        let out = ScRb::new(cfg).run(&ds.x);
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.9, "SC_RB accuracy on two moons: {acc}");
        assert!(out.info.kappa.unwrap() >= 1.0);
        assert!(out.info.feature_dim > 0);
        assert!(out.timer.secs("rb_features") >= 0.0);
    }

    #[test]
    fn recovers_blobs_with_high_accuracy() {
        let ds = synth::gaussian_blobs(400, 4, 3, 8.0, 5);
        let mut cfg = PipelineConfig::default();
        cfg.k = 3;
        cfg.r = 128;
        cfg.kernel = crate::config::Kernel::Laplacian { sigma: 0.8 };
        cfg.kmeans_replicates = 5;
        let out = ScRb::new(cfg).run(&ds.x);
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.95, "SC_RB accuracy on blobs: {acc}");
    }

    #[test]
    fn works_with_both_solvers() {
        let ds = synth::gaussian_blobs(200, 3, 2, 8.0, 7);
        for solver in [crate::config::Solver::Davidson, crate::config::Solver::Lanczos] {
            let mut cfg = PipelineConfig::default();
            cfg.k = 2;
            cfg.r = 64;
            cfg.solver = solver;
            cfg.kernel = crate::config::Kernel::Laplacian { sigma: 0.5 };
            cfg.kmeans_replicates = 3;
            let out = ScRb::new(cfg).run(&ds.x);
            let acc = accuracy(&out.labels, &ds.y);
            assert!(acc > 0.9, "{solver:?} accuracy {acc}");
        }
    }
}
