//! **KK_RS** [10] — approximate kernel K-means by random sampling: restrict
//! the cluster centers to the span of R sampled points. Equivalent to
//! K-means in the Nyström feature space K(X,L)·K(L,L)^{−1/2} *without* the
//! Laplacian normalization or SVD (the contrast with SC_Nys the paper
//! draws).
//!
//! As a stage composition: the shared
//! [`NysFeaturize`](crate::cluster::sc_nys::NysFeaturize) (its own
//! sampling salt `0x4b72`) → pass-through embed (no SVD, no degrees) →
//! the shared K-means stage. See
//! [`crate::cluster::MethodKind::pipeline`].
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::Env;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::FitResult;

/// Fit KK_RS through its stage composition.
pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    super::method::MethodKind::KkRs.fit(env, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 37);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(48)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(3)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "KK_RS on blobs: {acc}");
    }
}
