//! **KK_RS** [10] — approximate kernel K-means by random sampling: restrict
//! the cluster centers to the span of R sampled points. Equivalent to
//! K-means in the Nyström feature space K(X,L)·K(L,L)^{−1/2} *without* the
//! Laplacian normalization or SVD (the contrast with SC_Nys the paper draws).
//!
//! Serving: transductive — the fitted model is the input-space class-mean
//! fallback ([`crate::model::CentroidModel`]).

use super::method::{embed_and_cluster, ClusterOutput, Env, MethodInfo};
use super::sc_nys::kernel_block_env;
use crate::error::ScrbError;
use crate::linalg::{cholesky_jittered, whiten_rows, Mat};
use crate::model::{CentroidModel, FitResult};
use crate::util::rng::Pcg;
use crate::util::timer::StageTimer;

pub fn fit(env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
    let cfg = &env.cfg;
    let m = cfg.r.min(x.rows);
    let mut timer = StageTimer::new();

    let mut rng = Pcg::new(cfg.seed, 0x4b72);
    let idx = rng.sample_indices(x.rows, m);
    let landmarks = x.select_rows(&idx);

    let c = timer.time("kernel_blocks", || kernel_block_env(env, x, &landmarks));
    let w11 = timer.time("kernel_blocks", || kernel_block_env(env, &landmarks, &landmarks));
    // Cholesky whitening: rows of C·L^{−T} have the same pairwise
    // distances as C·W₁₁^{−1/2} (see linalg::chol), at O(m³/3).
    let z = timer.time("embed", || {
        let l = cholesky_jittered(&w11);
        whiten_rows(&c, &l)
    });

    let (labels, km) = embed_and_cluster(z, env, &mut timer, false);
    let model = CentroidModel::from_labels(x, &labels, cfg.k);
    let output = ClusterOutput {
        labels,
        timer,
        info: MethodInfo { feature_dim: m, svd: None, kappa: None, inertia: km.inertia },
    };
    Ok(FitResult { model: Box::new(model), output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Kernel, PipelineConfig};
    use crate::data::synth;
    use crate::metrics::accuracy;

    #[test]
    fn clusters_blobs() {
        let ds = synth::gaussian_blobs(250, 4, 3, 9.0, 37);
        let cfg = PipelineConfig::builder()
            .k(3)
            .r(48)
            .kernel(Kernel::Gaussian { sigma: 0.6 })
            .kmeans_replicates(3)
            .build();
        let out = fit(&Env::new(cfg), &ds.x).unwrap().output;
        let acc = accuracy(&out.labels, &ds.y);
        assert!(acc > 0.85, "KK_RS on blobs: {acc}");
    }
}
