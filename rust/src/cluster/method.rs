//! Common method interface: every clustering algorithm in the comparison
//! grid (Table 2/3) runs through [`MethodKind::fit`] — the
//! [`crate::model::ClusterModel`] entry point — producing a
//! [`crate::model::FitResult`]: the training-set [`ClusterOutput`]
//! (labels, per-stage timings, solver telemetry) plus a serving
//! [`crate::model::FittedModel`]. [`MethodKind::run`] is the batch
//! convenience wrapper (fit, keep only the training output).

use crate::config::{Engine, PipelineConfig};
use crate::eigen::SvdStats;
use crate::error::ScrbError;
use crate::kmeans::{kmeans, AssignEngine, KmeansOpts, KmeansResult, NativeAssign};
use crate::linalg::Mat;
use crate::model::{ClusterModel, FitResult};
use crate::runtime::{XlaAssign, XlaRuntime};
use crate::util::timer::StageTimer;

/// Execution environment shared by all methods: configuration plus the
/// optional XLA runtime for the dense hot spots.
pub struct Env<'a> {
    pub cfg: PipelineConfig,
    pub xla: Option<&'a XlaRuntime>,
}

impl<'a> Env<'a> {
    pub fn new(cfg: PipelineConfig) -> Env<'a> {
        Env { cfg, xla: None }
    }

    pub fn with_xla(cfg: PipelineConfig, xla: Option<&'a XlaRuntime>) -> Env<'a> {
        Env { cfg, xla }
    }

    /// The K-means assignment engine this environment prescribes.
    pub fn assign_engine(&self) -> Box<dyn AssignEngine + '_> {
        match (self.cfg.engine, self.xla) {
            (Engine::Native, _) | (_, None) => Box::new(NativeAssign),
            // Auto applies the runtime's calibrated cost model per call;
            // Xla forces the artifact path (ablation / debugging).
            (Engine::Xla, Some(rt)) => Box::new(XlaAssign { runtime: rt, force: true }),
            (Engine::Auto, Some(rt)) => Box::new(XlaAssign::new(rt)),
        }
    }

    /// K-means options from the pipeline config.
    pub fn kmeans_opts(&self, k: usize) -> KmeansOpts {
        KmeansOpts {
            k,
            replicates: self.cfg.kmeans_replicates,
            max_iters: self.cfg.kmeans_max_iters,
            tol: 1e-6,
            seed: self.cfg.seed,
            batch: None,
        }
    }
}

/// Extra telemetry a method reports besides labels.
#[derive(Clone, Debug, Default)]
pub struct MethodInfo {
    /// Feature/embedding dimension the method worked in (D for RB, R for
    /// RF/landmark methods, N for exact SC).
    pub feature_dim: usize,
    /// Eigensolver statistics if an iterative SVD ran.
    pub svd: Option<SvdStats>,
    /// RB κ estimate (Definition 1), SC_RB only.
    pub kappa: Option<f64>,
    /// K-means inertia of the final clustering step.
    pub inertia: f64,
}

/// The result of one clustering run (training-set labels plus telemetry).
#[derive(Clone)]
pub struct ClusterOutput {
    pub labels: Vec<usize>,
    pub timer: StageTimer,
    pub info: MethodInfo,
}

/// All methods in the paper's comparison (Table 2 column order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    /// Standard K-means on the raw data [15].
    KMeans,
    /// Exact spectral clustering [21] — quadratic; capped to small N.
    ScExact,
    /// Approximate kernel K-means by random sampling [10].
    KkRs,
    /// Kernel K-means directly on the RF feature matrix [11].
    KkRf,
    /// Kernel K-means on singular vectors of the RF feature matrix [11].
    SvRf,
    /// Landmark-based spectral clustering (bipartite KNN graph) [9].
    ScLsc,
    /// Nyström spectral clustering [13].
    ScNys,
    /// SC on the RF-approximated Laplacian (paper's SV_RF variant).
    ScRf,
    /// This paper: SC via Random Binning features + PRIMME-style SVD.
    ScRb,
}

impl MethodKind {
    pub const ALL: [MethodKind; 9] = [
        MethodKind::KMeans,
        MethodKind::ScExact,
        MethodKind::KkRs,
        MethodKind::KkRf,
        MethodKind::SvRf,
        MethodKind::ScLsc,
        MethodKind::ScNys,
        MethodKind::ScRf,
        MethodKind::ScRb,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::KMeans => "K-means",
            MethodKind::ScExact => "SC",
            MethodKind::KkRs => "KK_RS",
            MethodKind::KkRf => "KK_RF",
            MethodKind::SvRf => "SV_RF",
            MethodKind::ScLsc => "SC_LSC",
            MethodKind::ScNys => "SC_Nys",
            MethodKind::ScRf => "SC_RF",
            MethodKind::ScRb => "SC_RB",
        }
    }

    pub fn parse(s: &str) -> Result<MethodKind, ScrbError> {
        let canon = s.to_lowercase().replace(['-', '_'], "");
        match canon.as_str() {
            "kmeans" => Ok(MethodKind::KMeans),
            "sc" | "scexact" | "exact" => Ok(MethodKind::ScExact),
            "kkrs" => Ok(MethodKind::KkRs),
            "kkrf" => Ok(MethodKind::KkRf),
            "svrf" => Ok(MethodKind::SvRf),
            "sclsc" | "lsc" => Ok(MethodKind::ScLsc),
            "scnys" | "nystrom" | "nys" => Ok(MethodKind::ScNys),
            "scrf" => Ok(MethodKind::ScRf),
            "scrb" | "rb" => Ok(MethodKind::ScRb),
            other => Err(ScrbError::config(format!("unknown method '{other}'"))),
        }
    }

    /// Fit this method on `x`: the training-set clustering plus a serving
    /// model (SC_RB's spectral out-of-sample extension; input-space
    /// nearest-centroid for K-means and the transductive baselines).
    pub fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
        match self {
            MethodKind::KMeans => super::kmeans_base::fit(env, x),
            MethodKind::ScExact => super::sc_exact::fit(env, x),
            MethodKind::KkRs => super::kk_rs::fit(env, x),
            MethodKind::KkRf => super::kk_rf::fit(env, x),
            MethodKind::SvRf => super::sv_rf::fit(env, x),
            MethodKind::ScLsc => super::sc_lsc::fit(env, x),
            MethodKind::ScNys => super::sc_nys::fit(env, x),
            MethodKind::ScRf => super::sc_rf::fit(env, x),
            MethodKind::ScRb => super::sc_rb::fit(env, x),
        }
    }

    /// Batch convenience: fit and return only the training-set output
    /// (the pre-model-API shape).
    pub fn run(&self, env: &Env, x: &Mat) -> Result<ClusterOutput, ScrbError> {
        Ok(self.fit(env, x)?.output)
    }
}

impl ClusterModel for MethodKind {
    fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
        MethodKind::fit(self, env, x)
    }
}

/// Shared spectral epilogue (Algorithm 2 steps 4–5): optionally row-
/// normalize the embedding, then K-means it into K clusters.
pub fn embed_and_cluster(
    mut u: Mat,
    env: &Env,
    timer: &mut StageTimer,
    row_normalize: bool,
) -> (Vec<usize>, KmeansResult) {
    if row_normalize {
        u.normalize_rows();
    }
    cluster_embedding(&u, env, timer)
}

/// K-means over already-prepared embedding rows, by reference — callers
/// that keep the embedding afterwards (the SC_RB fit labels its rows
/// through the serving model) avoid copying it.
pub fn cluster_embedding(
    u: &Mat,
    env: &Env,
    timer: &mut StageTimer,
) -> (Vec<usize>, KmeansResult) {
    let engine = env.assign_engine();
    let opts = env.kmeans_opts(env.cfg.k);
    let result = timer.time("kmeans", || kmeans(u, &opts, engine.as_ref()));
    (result.labels.iter().map(|&l| l as usize).collect(), result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for kind in MethodKind::ALL {
            assert_eq!(MethodKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(MethodKind::parse("sc_rb").unwrap(), MethodKind::ScRb);
        assert_eq!(MethodKind::parse("SC-Nys").unwrap(), MethodKind::ScNys);
        assert!(MethodKind::parse("nope").is_err());
    }

    #[test]
    fn all_covers_table2_columns() {
        assert_eq!(MethodKind::ALL.len(), 9);
    }
}
