//! Common method interface: every clustering algorithm in the comparison
//! grid (Table 2/3) is a composition of pipeline stages
//! ([`MethodKind::pipeline`] — the table that replaced nine hand-inlined
//! scaffolds), and [`MethodKind::fit`] — the
//! [`crate::model::ClusterModel`] entry point — drives that composition,
//! producing a [`crate::model::FitResult`]: the training-set
//! [`ClusterOutput`] (labels, per-stage timings, solver telemetry) plus a
//! serving [`crate::model::FittedModel`]. [`MethodKind::run`] is the
//! batch convenience wrapper (fit, keep only the training output).

use crate::config::{Engine, PipelineConfig};
use crate::eigen::SvdStats;
use crate::error::ScrbError;
use crate::kmeans::{AssignEngine, KmeansOpts, NativeAssign};
use crate::linalg::Mat;
use crate::model::{ClusterModel, FitResult};
use crate::pipeline::{
    Assemble, DegreeMode, IdentityFeaturize, KmeansCluster, PassEmbed, Pipeline, SvdEmbed,
};
use crate::runtime::{XlaAssign, XlaRuntime};
use crate::util::timer::StageTimer;

/// Execution environment shared by all methods: configuration plus the
/// optional XLA runtime for the dense hot spots.
pub struct Env<'a> {
    pub cfg: PipelineConfig,
    pub xla: Option<&'a XlaRuntime>,
}

impl<'a> Env<'a> {
    pub fn new(cfg: PipelineConfig) -> Env<'a> {
        Env { cfg, xla: None }
    }

    pub fn with_xla(cfg: PipelineConfig, xla: Option<&'a XlaRuntime>) -> Env<'a> {
        Env { cfg, xla }
    }

    /// The K-means assignment engine this environment prescribes.
    pub fn assign_engine(&self) -> Box<dyn AssignEngine + '_> {
        match (self.cfg.engine, self.xla) {
            (Engine::Native, _) | (_, None) => Box::new(NativeAssign),
            // Auto applies the runtime's calibrated cost model per call;
            // Xla forces the artifact path (ablation / debugging).
            (Engine::Xla, Some(rt)) => Box::new(XlaAssign { runtime: rt, force: true }),
            (Engine::Auto, Some(rt)) => Box::new(XlaAssign::new(rt)),
        }
    }

    /// K-means options from the pipeline config.
    pub fn kmeans_opts(&self, k: usize) -> KmeansOpts {
        KmeansOpts {
            k,
            replicates: self.cfg.kmeans_replicates,
            max_iters: self.cfg.kmeans_max_iters,
            tol: 1e-6,
            seed: self.cfg.seed,
            batch: None,
        }
    }
}

/// Extra telemetry a method reports besides labels.
#[derive(Clone, Debug, Default)]
pub struct MethodInfo {
    /// Feature/embedding dimension the method worked in (D for RB, R for
    /// RF/landmark methods, N for exact SC).
    pub feature_dim: usize,
    /// Eigensolver statistics if an iterative SVD ran.
    pub svd: Option<SvdStats>,
    /// RB κ estimate (Definition 1), SC_RB only.
    pub kappa: Option<f64>,
    /// K-means inertia of the final clustering step.
    pub inertia: f64,
}

/// The result of one clustering run (training-set labels plus telemetry).
#[derive(Clone)]
pub struct ClusterOutput {
    pub labels: Vec<usize>,
    pub timer: StageTimer,
    pub info: MethodInfo,
}

/// All methods in the paper's comparison (Table 2 column order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodKind {
    /// Standard K-means on the raw data [15].
    KMeans,
    /// Exact spectral clustering [21] — quadratic; capped to small N.
    ScExact,
    /// Approximate kernel K-means by random sampling [10].
    KkRs,
    /// Kernel K-means directly on the RF feature matrix [11].
    KkRf,
    /// Kernel K-means on singular vectors of the RF feature matrix [11].
    SvRf,
    /// Landmark-based spectral clustering (bipartite KNN graph) [9].
    ScLsc,
    /// Nyström spectral clustering [13].
    ScNys,
    /// SC on the RF-approximated Laplacian (paper's SV_RF variant).
    ScRf,
    /// This paper: SC via Random Binning features + PRIMME-style SVD.
    ScRb,
}

impl MethodKind {
    pub const ALL: [MethodKind; 9] = [
        MethodKind::KMeans,
        MethodKind::ScExact,
        MethodKind::KkRs,
        MethodKind::KkRf,
        MethodKind::SvRf,
        MethodKind::ScLsc,
        MethodKind::ScNys,
        MethodKind::ScRf,
        MethodKind::ScRb,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::KMeans => "K-means",
            MethodKind::ScExact => "SC",
            MethodKind::KkRs => "KK_RS",
            MethodKind::KkRf => "KK_RF",
            MethodKind::SvRf => "SV_RF",
            MethodKind::ScLsc => "SC_LSC",
            MethodKind::ScNys => "SC_Nys",
            MethodKind::ScRf => "SC_RF",
            MethodKind::ScRb => "SC_RB",
        }
    }

    pub fn parse(s: &str) -> Result<MethodKind, ScrbError> {
        let canon = s.to_lowercase().replace(['-', '_'], "");
        match canon.as_str() {
            "kmeans" => Ok(MethodKind::KMeans),
            "sc" | "scexact" | "exact" => Ok(MethodKind::ScExact),
            "kkrs" => Ok(MethodKind::KkRs),
            "kkrf" => Ok(MethodKind::KkRf),
            "svrf" => Ok(MethodKind::SvRf),
            "sclsc" | "lsc" => Ok(MethodKind::ScLsc),
            "scnys" | "nystrom" | "nys" => Ok(MethodKind::ScNys),
            "scrf" => Ok(MethodKind::ScRf),
            "scrb" | "rb" => Ok(MethodKind::ScRb),
            other => Err(ScrbError::config(format!("unknown method '{other}'"))),
        }
    }

    /// This method's canonical stage composition under `cfg` — the table
    /// that unifies the nine methods over the
    /// [`crate::pipeline`] API. The SC family (SC_RB, SC_RF, SC_Nys,
    /// SC_LSC, exact SC) shares one spectral embed + K-means tail and
    /// differs only in featurization (and SC_RB's serving projection);
    /// the kernel-K-means family (K-means, KK_RS, KK_RF) shares the
    /// pass-through embed. Compositions built from the *same* `cfg` the
    /// [`Env`] carries fit identically through [`MethodKind::fit`] or a
    /// cached [`Pipeline::fit_cached`] sweep.
    pub fn pipeline(&self, cfg: &PipelineConfig) -> Pipeline {
        // embedding width: decoupled from K when pinned (k-sweep reuse);
        // clamped to ≥ K (validation enforces this for built configs, the
        // clamp additionally covers hand-poked ones)
        let edim = cfg.embed_dim.unwrap_or(cfg.k).max(cfg.k);
        let svd_embed = |seed_salt: u64, degree: DegreeMode, row_normalize: bool,
                         scale_scores: bool, symmetric: bool| {
            Box::new(SvdEmbed {
                k: edim,
                solver: cfg.solver,
                tol: cfg.svd_tol,
                max_matvecs: cfg.svd_max_iters,
                seed: cfg.seed ^ seed_salt,
                degree,
                row_normalize,
                scale_scores,
                symmetric,
                cheb_order: cfg.cheb_order,
                cheb_signals: cfg.cheb_signals,
            })
        };
        let kmeans = || Box::new(KmeansCluster::from_cfg(cfg, cfg.k));
        match self {
            MethodKind::KMeans => Pipeline::new(
                Box::new(IdentityFeaturize),
                Box::new(PassEmbed),
                Box::new(KmeansCluster::from_cfg(cfg, cfg.k).with_relabel()),
                Assemble::Centroids,
            ),
            MethodKind::ScExact => Pipeline::new(
                Box::new(super::sc_exact::ExactFeaturize {
                    kernel: cfg.kernel,
                    engine: cfg.engine,
                }),
                svd_embed(0xe8ac7, DegreeMode::None, true, false, true),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::KkRs => Pipeline::new(
                Box::new(super::sc_nys::NysFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                    salt: 0x4b72,
                    whiten_stage: "embed",
                    engine: cfg.engine,
                }),
                Box::new(PassEmbed),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::KkRf => Pipeline::new(
                Box::new(super::sc_rf::RfFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                    engine: cfg.engine,
                }),
                Box::new(PassEmbed),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::SvRf => Pipeline::new(
                Box::new(super::sc_rf::RfFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                    engine: cfg.engine,
                }),
                svd_embed(0x57f5, DegreeMode::None, false, true, false),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::ScLsc => Pipeline::new(
                Box::new(super::sc_lsc::LscFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                }),
                svd_embed(0x15ce, DegreeMode::None, true, false, false),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::ScNys => Pipeline::new(
                Box::new(super::sc_nys::NysFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                    salt: 0x4e79,
                    whiten_stage: "degrees",
                    engine: cfg.engine,
                }),
                svd_embed(0x4ce5, DegreeMode::DenseClamped, true, false, false),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::ScRf => Pipeline::new(
                Box::new(super::sc_rf::RfFeaturize {
                    kernel: cfg.kernel,
                    r: cfg.r,
                    seed: cfg.seed,
                    engine: cfg.engine,
                }),
                svd_embed(0x5cf5, DegreeMode::DenseClamped, true, false, false),
                kmeans(),
                Assemble::ClassMeans,
            ),
            MethodKind::ScRb => super::sc_rb::scrb_stages(cfg, cfg.k, None),
        }
    }

    /// Fit this method on `x`: the training-set clustering plus a serving
    /// model (SC_RB's spectral out-of-sample extension; input-space
    /// nearest-centroid for K-means and the transductive baselines).
    /// Drives [`MethodKind::pipeline`] without artifact retention.
    pub fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
        self.pipeline(&env.cfg).fit(env, x)
    }

    /// Batch convenience: fit and return only the training-set output
    /// (the pre-model-API shape).
    pub fn run(&self, env: &Env, x: &Mat) -> Result<ClusterOutput, ScrbError> {
        Ok(self.fit(env, x)?.output)
    }
}

impl ClusterModel for MethodKind {
    fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError> {
        MethodKind::fit(self, env, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for kind in MethodKind::ALL {
            assert_eq!(MethodKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(MethodKind::parse("sc_rb").unwrap(), MethodKind::ScRb);
        assert_eq!(MethodKind::parse("SC-Nys").unwrap(), MethodKind::ScNys);
        assert!(MethodKind::parse("nope").is_err());
    }

    #[test]
    fn all_covers_table2_columns() {
        assert_eq!(MethodKind::ALL.len(), 9);
    }

    #[test]
    fn every_method_has_a_composition() {
        let cfg = PipelineConfig::builder().k(3).r(16).build();
        for kind in MethodKind::ALL {
            let p = kind.pipeline(&cfg);
            // serving assembly is typed per method
            match kind {
                MethodKind::KMeans => assert_eq!(p.assemble, Assemble::Centroids),
                MethodKind::ScRb => assert_eq!(p.assemble, Assemble::ScRb),
                _ => assert_eq!(p.assemble, Assemble::ClassMeans),
            }
        }
    }
}
