//! Dense symmetric eigensolver (cyclic Jacobi) for the small projected
//! problems inside Rayleigh–Ritz (k ≤ ~200). Jacobi is simple, robust, and
//! accurate to machine precision for these sizes.

use super::dense::Mat;

/// Eigendecomposition A = V diag(w) Vᵀ of a symmetric matrix.
/// `w` ascending; `v` columns are the corresponding eigenvectors.
pub struct SymEig {
    pub w: Vec<f64>,
    pub v: Mat,
}

/// Reusable buffers for [`sym_eig_into`]: the Jacobi working copy, the
/// rotation accumulator, and the sorted outputs. After a `reserve` (or a
/// first call at the largest size), repeated calls are allocation-free —
/// the Rayleigh–Ritz step inside every Davidson iteration runs on one of
/// these.
pub struct SymEigWs {
    m: Mat,
    v: Mat,
    idx: Vec<usize>,
    /// Eigenvalues, ascending (valid after `sym_eig_into`).
    pub w: Vec<f64>,
    /// Eigenvectors, column j ↔ w\[j\] (valid after `sym_eig_into`).
    pub vecs: Mat,
}

impl Default for SymEigWs {
    fn default() -> Self {
        Self::new()
    }
}

impl SymEigWs {
    pub fn new() -> SymEigWs {
        SymEigWs {
            m: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            idx: Vec::new(),
            w: Vec::new(),
            vecs: Mat::zeros(0, 0),
        }
    }

    /// Pre-provision for problems up to n×n.
    pub fn reserve(&mut self, n: usize) {
        self.m.reserve_for(n, n);
        self.v.reserve_for(n, n);
        self.vecs.reserve_for(n, n);
        self.idx.reserve(n.saturating_sub(self.idx.len()));
        self.w.reserve(n.saturating_sub(self.w.len()));
    }
}

/// Cyclic Jacobi with threshold sweeps. `a` must be symmetric.
/// Allocating wrapper over [`sym_eig_into`].
pub fn sym_eig(a: &Mat) -> SymEig {
    let mut ws = SymEigWs::new();
    sym_eig_into(a, &mut ws);
    SymEig {
        w: std::mem::take(&mut ws.w),
        v: std::mem::replace(&mut ws.vecs, Mat::zeros(0, 0)),
    }
}

/// Cyclic Jacobi into reusable workspace buffers: results land in `ws.w`
/// (ascending) and `ws.vecs`. Allocation-free once `ws` has seen the size.
pub fn sym_eig_into(a: &Mat, ws: &mut SymEigWs) {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "sym_eig expects square matrix");
    if n == 0 {
        ws.w.clear();
        ws.vecs.reset(0, 0);
        return;
    }
    let m = &mut ws.m;
    m.reset(n, n);
    m.data.copy_from_slice(&a.data);
    // symmetry check (debug builds only)
    debug_assert!({
        let mut ok = true;
        for i in 0..n {
            for j in 0..i {
                ok &= (m.at(i, j) - m.at(j, i)).abs()
                    <= 1e-8 * (1.0 + m.at(i, j).abs().max(m.at(j, i).abs()));
            }
        }
        ok
    });
    let v = &mut ws.v;
    v.reset(n, n);
    for i in 0..n {
        v.set(i, i, 1.0);
    }
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                // Rutishauser rotation
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // update rows/cols p and q of m
                for i in 0..n {
                    let aip = m.at(i, p);
                    let aiq = m.at(i, q);
                    m.set(i, p, c * aip - s * aiq);
                    m.set(i, q, s * aip + c * aiq);
                }
                for j in 0..n {
                    let apj = m.at(p, j);
                    let aqj = m.at(q, j);
                    m.set(p, j, c * apj - s * aqj);
                    m.set(q, j, s * apj + c * aqj);
                }
                // accumulate rotations into v
                for i in 0..n {
                    let vip = v.at(i, p);
                    let viq = v.at(i, q);
                    v.set(i, p, c * vip - s * viq);
                    v.set(i, q, s * vip + c * viq);
                }
            }
        }
    }
    // extract, sort ascending — via the reusable index permutation, so no
    // per-call pair vector and no column clones
    ws.idx.clear();
    ws.idx.extend(0..n);
    {
        let diag: &Mat = &*m; // shared reborrow; `m` stays usable below
        ws.idx
            .sort_unstable_by(|&x, &y| diag.at(x, x).partial_cmp(&diag.at(y, y)).unwrap());
    }
    ws.w.clear();
    for &src in &ws.idx {
        ws.w.push(m.at(src, src));
    }
    ws.vecs.reset(n, n);
    for (newj, &src) in ws.idx.iter().enumerate() {
        for i in 0..n {
            ws.vecs.set(i, newj, v.at(i, src));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn rand_sym(rng: &mut Pcg, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.range_f64(-1.0, 1.0);
                a.set(i, j, x);
                a.set(j, i, x);
            }
        }
        a
    }

    #[test]
    fn diagonalizes_random_symmetric() {
        let mut rng = Pcg::seed(21);
        for &n in &[1usize, 2, 3, 10, 40] {
            let a = rand_sym(&mut rng, n);
            let SymEig { w, v } = sym_eig(&a);
            // A v_i = w_i v_i
            for j in 0..n {
                let vj = v.col(j);
                let av = a.matvec(&vj);
                for i in 0..n {
                    assert!(
                        (av[i] - w[j] * vj[i]).abs() < 1e-9,
                        "n={n} j={j}: residual {}",
                        (av[i] - w[j] * vj[i]).abs()
                    );
                }
            }
            // sorted ascending
            for j in 1..n {
                assert!(w[j] >= w[j - 1]);
            }
            // orthonormal V
            let g = v.t_matmul(&v);
            assert!(g.sub(&Mat::eye(n)).frob_norm() < 1e-10);
        }
    }

    #[test]
    fn known_eigenvalues() {
        // [[2,1],[1,2]] -> eigenvalues 1 and 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.w[0] - 1.0).abs() < 1e-12);
        assert!((e.w[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_passthrough() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = sym_eig(&a);
        assert_eq!(e.w.iter().map(|x| x.round() as i64).collect::<Vec<_>>(), vec![-1, 2, 3]);
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Pcg::seed(22);
        let a = rand_sym(&mut rng, 25);
        let tr: f64 = (0..25).map(|i| a.at(i, i)).sum();
        let e = sym_eig(&a);
        assert!((e.w.iter().sum::<f64>() - tr).abs() < 1e-9);
    }
}
